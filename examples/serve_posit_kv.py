"""Serving example: batched generation with a posit8 KV cache.

    PYTHONPATH=src python examples/serve_posit_kv.py

Compares f32 / bf16 / posit8 KV-cache policies on the same prompts: identical
greedy tokens (or near-identical — KV rounding may flip a borderline argmax),
4x smaller cache than f32 — the paper's scratchpad-savings at the serving
bottleneck.
"""
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.pcsr import TransPolicy
from repro.models.registry import build_model

ARCH = "internvl2-2b"   # VLM serving: patch prefix + text decode
GEN = 24


def cache_nbytes(cache):
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache)
               if hasattr(x, "size"))


def main():
    cfg = get_arch(ARCH).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    B, PROMPT = 4, 16
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, PROMPT)))
    patches = jnp.asarray(
        rng.normal(0, 1, (B, cfg.n_patches, cfg.d_model)).astype(np.float32))

    results = {}
    for name, policy in {
        "f32-kv": TransPolicy(),
        "bf16-kv": TransPolicy(compute_dtype="bf16"),
        "p8-kv": TransPolicy.from_names(kv_cache="p8_0"),
    }.items():
        logits, cache = model.prefill(params, tokens, policy,
                                      S_max=PROMPT + GEN + cfg.n_patches,
                                      patch_embeds=patches)
        decode = jax.jit(lambda p, t, c: model.decode_step(p, t, c, policy))
        tok = jnp.argmax(logits, -1)
        outs = [tok]
        t0 = time.perf_counter()
        for _ in range(GEN - 1):
            logits, cache = decode(params, tok, cache)
            tok = jnp.argmax(logits, -1)
            outs.append(tok)
        jax.block_until_ready(tok)
        results[name] = {
            "tokens": np.stack([np.asarray(t) for t in outs], 1).tolist(),
            "kv_bytes": cache_nbytes(cache),
            "tok_per_s": round(B * (GEN - 1) / (time.perf_counter() - t0), 1),
        }

    f32 = results["f32-kv"]
    for name, r in results.items():
        match = np.mean(np.asarray(r["tokens"]) == np.asarray(f32["tokens"]))
        print(json.dumps({
            "policy": name, "kv_bytes": r["kv_bytes"],
            "kv_vs_f32": f"{r['kv_bytes'] / f32['kv_bytes']:.2f}x",
            "greedy_token_match_vs_f32": f"{float(match):.3f}",
            "tok_per_s": r["tok_per_s"],
        }))


if __name__ == "__main__":
    main()
