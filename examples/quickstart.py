"""Quickstart: the paper's mechanisms in 60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (
    F32, P8_0, P16_1, OperandSlots, TransPolicy,
    fcvt_p16_s, fcvt_p8_p16, fcvt_s_p16,
    posit_decode, posit_dot, posit_encode,
)

# 1. The codecs (paper Fig. 2(b)): FP32 <-> posit, bit-exact, saturating.
x = jnp.asarray(np.linspace(-3, 3, 8, dtype=np.float32))
codes = posit_encode(x, 16, es=1)          # -> uint16 posit codes
back = posit_decode(codes, 16, es=1)       # decode is exact
print("fp32 :", x)
print("p16,1:", back, f"(storage: {codes.dtype}, {codes.nbytes} bytes)")

# 2. Dynamic es — one executable, es is data (the pcsr pes field).
import jax
enc = jax.jit(lambda v, es: posit_encode(v, 16, es))
for es in (0, 1, 2, 3):
    q = posit_decode(enc(x, jnp.int32(es)), 16, es)
    print(f"es={es}: max_rel_err={float(jnp.nanmax(jnp.abs((q - x) / x))):.2e}")

# 3. Table-I conversion instructions.
p16 = fcvt_p16_s(x, es=1)                  # fcvt.p16.s
f32 = fcvt_s_p16(p16, es=1)                # fcvt.s.p16
p8 = fcvt_p8_p16(p16, es_in=1, es_out=0)   # fcvt.p8.p16 (cross precision+es)
print("p16->p8 :", posit_decode(p8, 8, 0))

# 4. Mixed-format GEMM through the pcsr operand slots (posit A x float B).
rng = np.random.default_rng(0)
A = jnp.asarray(rng.normal(0, 1, (4, 8)).astype(np.float32))
B = jnp.asarray(rng.normal(0, 1, (8, 4)).astype(np.float32))
Ac = posit_encode(A, 8, 0)
y = posit_dot(Ac, B, OperandSlots(rs1=P8_0, rs2=F32, rd=F32))
print("mixed-format GEMM max err:",
      float(jnp.max(jnp.abs(y - posit_decode(Ac, 8, 0) @ B))))

# 5. A whole-run policy (weights in p16, KV cache in p8, bf16 datapath).
policy = TransPolicy.from_names(weights="p16_1", kv_cache="p8_0",
                                compute_dtype="bf16")
print("policy:", policy.describe())

# 6. The quire (beyond-paper, PERCIVAL-style): exact accumulation with ONE
#    terminal rounding. maxpos^2 - maxpos^2 + minpos^2 survives exactly —
#    any rounded accumulator (f32 FPU or PAU) loses it.
from repro.core import P16_2, qclr, qma, qms, qround

maxpos, minpos = jnp.uint16(0x7FFF), jnp.uint16(1)
q = qclr((), 16, es=2)
q = qma(q, maxpos, maxpos, 16, 2)     # += maxpos^2
q = qms(q, maxpos, maxpos, 16, 2)     # -= maxpos^2  (cancels exactly)
q = qma(q, minpos, minpos, 16, 2)     # += minpos^2
print("quire recovers minpos^2:", posit_decode(qround(q, 16, 2), 16, 2))

#    Same capability as a GEMM dataflow, selected through the pcsr:
Aq = posit_encode(A, 16, 2)
Bq = posit_encode(B, 16, 2)
y_exact = posit_dot(Aq, Bq, OperandSlots.uniform(P16_2, dataflow="quire"))
print("quire GEMM (exact accumulation):", posit_decode(y_exact, 16, 2)[0, :4])
