"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps under
three pcsr policies and compare loss curves — the DNN-training face of the
paper's claim that posit arithmetic sustains FP32-class accuracy.

    PYTHONPATH=src python examples/train_transprecision.py [--steps 300]

Policies:
  fp32        — IEEE bypass (paper baseline)
  p16-weights — weights posit(16,1) STE-quantized; optimizer moments p16 + EF
  p8-weights  — weights posit(8,0) (stress case; visible but bounded gap)
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelCfg
from repro.core.pcsr import TransPolicy
from repro.data.pipeline import SyntheticLMPipeline
from repro.launch.steps import make_train_step
from repro.models.registry import build_model
from repro.optim import AdamWConfig, adamw_init

# ~100M params: 12L x d768 x ff3072, vocab 32k
CFG = ModelCfg(name="lm-100m", family="dense", n_layers=12, d_model=768,
               n_heads=12, n_kv=12, d_ff=3072, vocab=32000)

POLICIES = {
    "fp32": TransPolicy(),
    "p16-weights": TransPolicy.from_names(weights="p16_1", optimizer="p16_1"),
    "p8-weights": TransPolicy.from_names(weights="p8_0"),
}


def train_one(policy_name: str, steps: int, batch: int, seq: int, seed: int = 0):
    policy = POLICIES[policy_name]
    model = build_model(CFG)
    n_params = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(lambda: model.init(jax.random.key(0)))))
    opt_cfg = AdamWConfig(lr=3e-4, moment_fmt=policy.optimizer)
    params = model.init(jax.random.key(seed))
    opt = adamw_init(params, opt_cfg)
    pipe = SyntheticLMPipeline(vocab=CFG.vocab, seq_len=seq,
                               global_batch=batch, seed=seed)
    step_fn = jax.jit(make_train_step(model, policy, opt_cfg,
                                      warmup=steps // 10, total_steps=steps),
                      donate_argnums=(0, 1))
    curve = []
    t0 = time.perf_counter()
    for step in range(steps):
        params, opt, metrics = step_fn(params, opt, pipe.batch_at(step),
                                       jnp.asarray(step))
        if step % 20 == 0 or step == steps - 1:
            curve.append((step, float(metrics["ce"])))
            print(f"[{policy_name}] step {step:4d} ce={curve[-1][1]:.4f}",
                  flush=True)
    wall = time.perf_counter() - t0
    return {"policy": policy_name, "n_params": int(n_params), "curve": curve,
            "final_ce": curve[-1][1], "wall_s": round(wall, 1)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--policies", default="fp32,p16-weights,p8-weights")
    args = ap.parse_args()

    results = [train_one(p, args.steps, args.batch, args.seq)
               for p in args.policies.split(",")]
    print(json.dumps(results, indent=1))
    base = results[0]["final_ce"]
    for r in results[1:]:
        gap = r["final_ce"] - base
        print(f"{r['policy']}: final CE gap vs fp32 = {gap:+.4f} "
              f"({'OK — transprecision holds' if abs(gap) < 0.1 else 'degraded'})")


if __name__ == "__main__":
    main()
