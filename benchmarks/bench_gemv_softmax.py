"""Paper §IV-C: GEMV and softmax benchmarks (DNN-kernel workloads)."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import F32, P8_0, P16_1
from repro.core.codec import posit_encode
from repro.core.pcsr import OperandSlots as OS
from repro.core.dot import posit_gemv, posit_softmax


def run():
    rng = np.random.default_rng(0)
    # GEMV 4x4 .. 32x32 (paper range) + a realistic 4096
    for n in (4, 8, 16, 32, 4096):
        A = jnp.asarray(rng.normal(0, 1, (n, n)).astype(np.float32))
        x = jnp.asarray(rng.normal(0, 1, (n,)).astype(np.float32))
        base = time_fn(jax.jit(lambda A, x: A @ x), A, x)
        emit(f"gemv/{n}/fp32", base, f"{2 * n * n / base:.1f}MFLOPS")
        for fmt, label in ((P8_0, "p8_0"), (P16_1, "p16_1")):
            Ac = posit_encode(A, fmt.nbits, fmt.es)
            xc = posit_encode(x, fmt.nbits, fmt.es)
            slots = OS(rs1=fmt, rs2=fmt, rd=F32)
            f_f = jax.jit(lambda A, x, s=slots: posit_gemv(A, x, s, impl="fused"))
            f_u = jax.jit(lambda A, x, s=slots: posit_gemv(A, x, s, impl="unfused"))
            us_f, us_u = time_fn(f_f, Ac, xc), time_fn(f_u, Ac, xc)
            emit(f"gemv/{n}/{label}/fused", us_f,
                 f"{2 * n * n / us_f:.1f}MFLOPS vs_fp32={us_f / base:.2f}x")
            emit(f"gemv/{n}/{label}/unfused_[7]", us_u,
                 f"fused_speedup={us_u / us_f:.2f}x")

    # softmax 8..128 classes (paper range), batch 1024 rows
    for c in (8, 32, 128):
        logits = jnp.asarray(rng.normal(0, 3, (1024, c)).astype(np.float32))
        base = time_fn(jax.jit(lambda x: jax.nn.softmax(x, -1)), logits)
        emit(f"softmax/{c}/fp32", base, "-")
        codes = posit_encode(logits, 16, 1)
        f = jax.jit(lambda c: posit_softmax(c, P16_1))
        us = time_fn(f, codes)
        emit(f"softmax/{c}/p16_1", us, f"vs_fp32={us / base:.2f}x")
    return True


if __name__ == "__main__":
    run()
