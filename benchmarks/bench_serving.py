"""Serving benchmark: flash-decode dispatch vs full-cache decode, static vs
continuous batching over the ragged posit KV cache (BENCH_serving.json).

Decode attention at the serving bottleneck is HBM-bandwidth-bound, so the
paper's posit-KV memory win only materializes if the decode path actually
moves fewer bytes.  Two levers are measured here:

* ``attn_impl``: ``kernel`` (tile-wise decode at the attention boundary —
  Pallas on TPU, length-bounded tiled XLA elsewhere) vs ``xla`` (decode the
  whole S_max cache every step, the pre-engine baseline).  The analytical
  ``decoded_kv_bytes_per_step`` model below pins the byte asymmetry and is
  asserted by tests/test_engine.py.
* batching mode: lockstep static batch vs the continuous-batching engine
  (launch/engine.py) with Poisson arrivals — tokens/s plus p50/p95 per-token
  latency.

The kernel-vs-xla throughput assertion (kernel >= xla) runs in both smoke
and full mode: the tiled path decodes ceil(len/block) tiles while the xla
path decodes all of S_max, so at S_max >= 512 with short live sequences the
ratio is comfortably > 1.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_arch
from repro.core.pcsr import TransPolicy
from repro.launch.config import ServeConfig
from repro.launch.engine import poisson_requests
from repro.launch.serve import kv_cache_bytes
from repro.obs.metrics import percentile_ms
from repro.models.registry import build_model


def decoded_kv_bytes_per_step(S_max: int, length: int, *, n_layers: int,
                              n_kv: int, head_dim: int, code_bytes: int,
                              impl: str, block_s: int = 256) -> int:
    """HBM bytes the KV-decode path touches for ONE decode step.

    ``xla``   : reads every code in the S_max cache and materializes the f32
                decode in HBM (one write + one read by the attention einsum):
                ``S_max * (code_bytes + 8)`` per element position.
    ``kernel``: streams only the live tiles (``ceil(len/block)*block``
                positions) of codes and decodes in VMEM/registers — no f32
                round trip: ``tiles*block * code_bytes``.

    This is the model the acceptance test pins: the kernel path's decoded
    bytes per step scale with the *ragged occupancy*, the xla path's with
    the *allocated* cache.
    """
    elems = 2 * n_layers * n_kv * head_dim   # K + V, per sequence position
    if impl == "xla":
        return elems * S_max * (code_bytes + 8)
    bs = min(block_s, S_max)
    tiles = -(-min(length, S_max) // bs)
    return elems * tiles * bs * code_bytes


def _measure_decode_paired(model, params, policies, *, B, prompt_len, S_max,
                           steps, rounds=4):
    """us per decode step (warm) for each policy in ``policies``.

    Paired-interleaved rounds with a min statistic (the bench_mixed_gemm
    construction): each round times every impl back-to-back, so neighbor
    load hits all impls alike instead of whichever happened to run in the
    slow window, and min-over-rounds discards the loaded samples.
    """
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, model.cfg.vocab, (B, prompt_len)))
    state = {}
    for name, policy in policies.items():
        logits, cache = model.prefill(params, tokens, policy, S_max=S_max)
        decode = jax.jit(lambda p, t, c, _pol=policy:
                         model.decode_step(p, t, c, _pol))
        tok = jnp.argmax(logits, -1)
        logits, cache = decode(params, tok, cache)      # compile / warm
        tok = jnp.argmax(logits, -1)
        jax.block_until_ready(tok)
        state[name] = [decode, cache, tok]
    best = {name: float("inf") for name in policies}
    for _ in range(rounds):
        for name in policies:
            decode, cache, tok = state[name]
            t0 = time.perf_counter()
            for _ in range(steps):
                logits, cache = decode(params, tok, cache)
                tok = jnp.argmax(logits, -1)
            jax.block_until_ready(tok)
            dt = (time.perf_counter() - t0) / steps * 1e6
            state[name] = [decode, cache, tok]
            best[name] = min(best[name], dt)
    return best


def run(smoke: bool = False) -> None:
    S_max = 512 if smoke else 2048
    B = 2 if smoke else 4
    prompt_len = 16 if smoke else 32
    steps = 6 if smoke else 24
    cfg = get_arch("yi-34b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    base = TransPolicy.from_names(kv_cache="p8_0", compute_dtype="bf16")
    us = _measure_decode_paired(
        model, params,
        {impl: dataclasses.replace(base, attn_impl=impl)
         for impl in ("kernel", "xla")},
        B=B, prompt_len=prompt_len, S_max=S_max, steps=steps)
    tok_s = {}
    for impl in ("kernel", "xla"):
        tok_s[impl] = B / (us[impl] / 1e6)
        mb = decoded_kv_bytes_per_step(
            S_max, prompt_len + steps, n_layers=cfg.n_layers, n_kv=cfg.n_kv,
            head_dim=cfg.hd, code_bytes=1, impl=impl) / 1e6
        emit(f"decode_{impl}_p8", us[impl],
             f"tok_s={tok_s[impl]:.1f} S_max={S_max} "
             f"model_decode_MB_per_step={mb:.3f}")

    ratio = tok_s["kernel"] / tok_s["xla"]
    emit("kernel_vs_xla_ratio", 0.0, f"ratio={ratio:.2f} S_max={S_max}")
    assert ratio >= 1.0, (
        f"kernel-path decode ({tok_s['kernel']:.1f} tok/s) slower than "
        f"full-cache xla decode ({tok_s['xla']:.1f} tok/s) at S_max={S_max}")

    # KV footprint per token: posit codes vs float cache
    for name, kv in (("p8", "p8_0"), ("f32", None)):
        policy = TransPolicy.from_names(kv_cache=kv)
        cache = model.init_cache(B, S_max, policy)
        bpt = kv_cache_bytes(cache) // (B * S_max)
        emit(f"kv_bytes_per_token_{name}", 0.0, f"kv_bpt={bpt}")

    # static vs continuous batching at the same request load
    slots = 2 if smoke else 4
    n_req = 3 * slots
    gen = 8 if smoke else 16
    policy = dataclasses.replace(base, attn_impl="kernel")
    scfg = ServeConfig(arch="yi-34b", reduced=True, continuous=True,
                       max_slots=slots, prompt_len=prompt_len,
                       gen=S_max - prompt_len).validate()
    eng = scfg.build_engine(model, params, policy)
    warm = poisson_requests(1, arrival_rate=0.0, prompt_lens=(prompt_len,),
                            max_new_tokens=2, vocab=cfg.vocab)
    eng.run(warm)
    eng.reset()

    # static vs continuous both run closed-loop (rate 0: all requests at t=0)
    # so their tokens/s compare like-for-like; the poisson row then opens the
    # loop so admission genuinely interleaves with decode (slots drain and
    # refill mid-flight) and the latency percentiles reflect arrival pressure
    arrival = 30.0 if smoke else 60.0
    for mode, rate in (("static", 0.0), ("continuous", 0.0),
                       ("continuous_poisson", arrival)):
        eng.reset()
        reqs = poisson_requests(n_req, arrival_rate=rate,
                                prompt_lens=(prompt_len,),
                                max_new_tokens=gen, vocab=cfg.vocab, seed=1)
        t0 = time.perf_counter()
        if mode == "static":
            # lockstep: admit a full batch, drain it completely, repeat
            clock = lambda: time.perf_counter() - t0  # noqa: E731
            pending = list(reqs)
            while pending or eng.active.any() or eng.queue:
                take, pending = pending[:slots], pending[slots:]
                for r in take:
                    eng.submit(r)
                eng.admit(clock=clock)
                while eng.active.any():
                    eng.step(now=clock())
        else:
            eng.run(reqs)
        dt = max(time.perf_counter() - t0, 1e-9)
        done = list(eng.completions)
        n_tok = sum(len(c.tokens) for c in done)
        per_tok = [t for c in done for t in c.per_token_s()]
        p50 = percentile_ms(per_tok, 50)
        p95 = percentile_ms(per_tok, 95)
        emit(f"{mode}_batching", dt / max(n_tok, 1) * 1e6,
             f"tok_s={n_tok / dt:.1f} p50_ms={p50:.2f} p95_ms={p95:.2f} "
             f"requests={len(done)} rate={rate}")


if __name__ == "__main__":
    run(smoke=True)
