"""Paged prefix-sharing KV cache vs the dense slot grid (DESIGN.md §14).

The experiment holds the KV byte budget FIXED: the paged engine's block pool
is sized to exactly the slot grid's token capacity (``blocks_for(G * S_max)``
pages), and both engines serve the same workload — N requests sharing a
common prompt prefix (50 / 90 / 95 % overlap) with unique tails, p8 KV codes,
greedy decode.  Prefix sharing dedupes the *storage* of the shared blocks
(prefill always runs — the exactness contract), so inside the same bytes the
paged engine sustains more concurrent decode slots and the aggregate decode
throughput rises with overlap; the slot grid, which owns ``S_max`` private
rows per slot, cannot.

Gates (CI fails on any):

* ``paged_vs_grid_ratio_overlap90``: paged decode tokens/s >= 1.5x the slot
  grid at 90 % overlap — the headline capacity win.
* bit-exactness: every request's token stream is identical under both
  engines (storage dedup must not change a single sampled token).
* snapshot/resume: a mid-stream ``snapshot()`` -> ``reset()`` ->
  ``restore()`` -> drain loses zero tokens (block table + refcounts ride
  the snapshot).

Also reports open-loop p95 TTFT for both engines at 90 % overlap (queueing
under Poisson arrivals is where the extra slots show up for latency).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_arch
from repro.core.pcsr import TransPolicy
from repro.launch.config import ServeConfig
from repro.launch.engine import Request
from repro.models.registry import build_model


def _requests(n, prompt_len, overlap, gen, vocab, seed=0, rate=0.0):
    """N requests: a shared prefix of ``overlap * prompt_len`` tokens plus
    per-request unique tails (same prefix draw for every seed/rate)."""
    rng = np.random.default_rng(1234)       # prefix fixed across workloads
    n_shared = int(round(overlap * prompt_len))
    shared = rng.integers(0, vocab, size=n_shared)
    rng = np.random.default_rng(seed)
    arrivals = np.zeros(n) if rate <= 0 else \
        np.cumsum(rng.exponential(1.0 / rate, size=n))
    reqs = []
    for i in range(n):
        tail = rng.integers(0, vocab, size=prompt_len - n_shared)
        reqs.append(Request(
            rid=i, prompt=np.concatenate([shared, tail]).astype(np.int32),
            max_new_tokens=gen, arrival_time=float(arrivals[i])))
    return reqs


def _serve_closed(eng, reqs):
    """Closed loop (all requests at t=0); returns ({rid: tokens},
    decode_tok_s, wall_s) with decode throughput measured over step() time
    only — prefill cost is identical in both engines (the exactness
    contract: sharing dedupes storage, not FLOPs) so it would only dilute
    the capacity signal being measured."""
    eng.reset()
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    step_s = 0.0
    dec_tokens = 0
    while eng.queue or eng.active.any():
        now = time.perf_counter() - t0
        if eng.queue and eng.free_slots():
            eng.admit(now=now)
        if eng.active.any():
            ts = time.perf_counter()
            dec_tokens += int(eng.active.sum())
            eng.step(now=now)
            step_s += time.perf_counter() - ts
    wall = time.perf_counter() - t0
    toks = {c.rid: list(c.tokens) for c in eng.completions}
    return toks, dec_tokens / max(step_s, 1e-9), wall


def _serve_best(eng, reqs, repeats=3):
    """Best-of-N decode throughput: the workload is short (a few dozen
    steps), so single-shot timing is scheduler-noise dominated; the token
    streams are deterministic and asserted identical across repeats."""
    best_tok_s, toks = 0.0, None
    for _ in range(repeats):
        t, tok_s, _ = _serve_closed(eng, list(reqs))
        assert toks is None or t == toks, "nondeterministic token streams"
        toks = t
        best_tok_s = max(best_tok_s, tok_s)
    return toks, best_tok_s


def run(smoke: bool = False) -> None:
    # prompts much longer than the generation budget: the regime prefix
    # caching targets (long shared system prompt, short completions) — and
    # the one where storage dedup buys whole extra decode slots.  Sized so
    # a warm request's decode growth stays inside its partial prompt-tail
    # block (prompt % 16 + gen <= 16): one private page per warm stream
    prompt_len = 90 if smoke else 180
    gen = 6 if smoke else 12
    grid_slots = 2 if smoke else 4
    # enough requests that steady-state decode dominates the ramp-up and
    # drain waves — the throughput ratio is a steady-state claim
    n_req = 8 * grid_slots
    cfg = get_arch("yi-34b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    policy = TransPolicy.from_names(kv_cache="p8_0", compute_dtype="bf16",
                                    attn_impl="kernel")

    scfg_grid = ServeConfig(arch="yi-34b", reduced=True, continuous=True,
                            max_slots=grid_slots, prompt_len=prompt_len,
                            gen=gen).validate()
    grid = scfg_grid.build_engine(model, params, policy)

    # equal byte budget: the pool holds exactly the grid's token capacity;
    # the paged engine gets 4x the slot *tables* (cheap) — whether it can
    # USE them is down to prefix sharing stretching the same bytes
    from repro.core.paged_kv import PageGeometry
    from repro.models.transformer import attn_cfg
    acfg = attn_cfg(cfg)
    geom = PageGeometry(n_layers=cfg.n_layers, n_kv=acfg.n_kv,
                        head_dim=acfg.head_dim, code_bytes=1, page_bytes=2048)
    n_blocks = geom.blocks_for(grid_slots * (prompt_len + gen))
    scfg_paged = ServeConfig(arch="yi-34b", reduced=True, continuous=True,
                             paged=True, page_bytes=2048, n_blocks=n_blocks,
                             max_slots=4 * grid_slots, prompt_len=prompt_len,
                             gen=gen).validate()
    paged = scfg_paged.build_engine(model, params, policy)
    assert paged.manager.geom.pool_bytes(n_blocks) <= \
        grid_slots * (prompt_len + gen) * cfg.n_layers \
        * 2 * acfg.n_kv * acfg.head_dim + geom.page_bytes * cfg.n_layers, \
        "paged pool exceeds the grid byte budget"

    # warm both jit caches off the measured path
    warm = _requests(1, prompt_len, 0.0, 2, cfg.vocab)
    grid.run(list(warm))
    paged.run(list(warm))

    grid_toks, grid_tok_s = _serve_best(
        grid, _requests(n_req, prompt_len, 0.9, gen, cfg.vocab))
    emit("grid_p8", 1e6 / grid_tok_s,
         f"decode_tok_s={grid_tok_s:.1f} slots={grid_slots} "
         f"budget_blocks={n_blocks}")

    ratio_90 = None
    for overlap in (0.5, 0.9, 0.95):
        reqs = _requests(n_req, prompt_len, overlap, gen, cfg.vocab)
        toks, tok_s = _serve_best(paged, reqs)
        st = paged.prefix_stats()
        name = f"paged_overlap{int(overlap * 100)}"
        emit(name, 1e6 / tok_s,
             f"decode_tok_s={tok_s:.1f} ratio={tok_s / grid_tok_s:.2f} "
             f"hits={st['hits']} hit_tokens={st['hit_tokens']} "
             f"cow={st['cow_copies']} slots={4 * grid_slots}")
        if overlap == 0.9:
            ratio_90 = tok_s / grid_tok_s
            # storage dedup must not change one sampled token: the paged
            # decode reads the same round-tripped p8 codes the grid wrote
            assert toks == grid_toks, (
                "paged tokens diverge from slot-grid tokens at "
                f"overlap={overlap}: "
                f"{ {r: (toks.get(r), grid_toks.get(r)) for r in toks if toks.get(r) != grid_toks.get(r)} }")
            emit("paged_bitexact", 0.0,
                 f"match=1 requests={n_req} gen={gen}")
    assert ratio_90 is not None and ratio_90 >= 1.5, (
        f"paged decode throughput only {ratio_90:.2f}x the slot grid at 90% "
        f"overlap (gate: >= 1.5x at equal KV bytes)")

    # open-loop p95 TTFT: Poisson arrivals at a rate the grid queues under
    rate = 30.0 if smoke else 60.0
    for name, eng in (("grid", grid), ("paged", paged)):
        eng.reset()
        reqs = _requests(n_req, prompt_len, 0.9, gen, cfg.vocab,
                         seed=7, rate=rate)
        eng.run(reqs)
        ttfts = sorted(c.ttft_s for c in eng.completions)
        p95 = ttfts[min(len(ttfts) - 1, int(0.95 * len(ttfts)))] * 1e3
        emit(f"ttft_p95_{name}", p95 * 1e3,
             f"ttft_p95_ms={p95:.2f} rate={rate} requests={n_req}")

    # kill/resume: snapshot mid-stream, reset, restore, drain — the block
    # table + refcounts ride the snapshot, so not one token may be lost
    paged.reset()
    reqs = _requests(n_req, prompt_len, 0.9, gen, cfg.vocab)
    for r in reqs:
        paged.submit(r)
    paged.admit(now=0.0)
    for _ in range(3):
        paged.step(now=0.0)
    mid = paged.snapshot()
    while paged.queue or paged.active.any():
        if paged.queue and paged.free_slots():
            paged.admit(now=0.0)
        if paged.active.any():
            paged.step(now=0.0)
    expect = {c.rid: list(c.tokens) for c in paged.completions}
    paged.reset()
    paged.restore(mid, now=0.0)
    paged.run([])
    got = {c.rid: list(c.tokens) for c in paged.completions}
    lost = sum(1 for r in expect if got.get(r) != expect[r])
    emit("paged_resume", 0.0,
         f"lost_streams={lost} requests={n_req} snapshot_step=3")
    assert lost == 0, f"resume lost/changed {lost} streams: " + str({
        r: (expect[r], got.get(r)) for r in expect
        if got.get(r) != expect[r]})


if __name__ == "__main__":
    run(smoke=True)
