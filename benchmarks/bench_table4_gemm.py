"""Paper Table IV: GEMM throughput, ours (fused codec) vs [7] (conversion
instructions), for FP32 baseline / P(16,1) / P(8,0), plus the scratchpad-
memory-savings table.

The paper's cycle-accurate quantity is reproduced two ways:
  * measured: wall-time of the XLA-fused vs barrier-separated pipelines
    (CPU timings are indicative; the *ratio* is the paper's claim)
  * analytic: operand bytes moved through memory per GEMM — deterministic,
    hardware-independent, and the actual mechanism behind [7]'s slowdown
    (two extra conversion round-trips per operand).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import F32, P8_0, P16_1
from repro.core.codec import posit_encode
from repro.core.pcsr import OperandSlots as OS
from repro.kernels.posit_gemm.ops import gemm

SIZES = (4, 8, 12, 16, 20, 256, 1024)
SMOKE_SIZES = (4, 16, 256)  # CI per-PR configuration (benchmarks.run --smoke)


def _operands(n, fmt, seed=0):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(0, 1, (n, n)).astype(np.float32))
    b = jnp.asarray(rng.normal(0, 1, (n, n)).astype(np.float32))
    if fmt is F32:
        return a, b
    return (posit_encode(a, fmt.nbits, fmt.es),
            posit_encode(b, fmt.nbits, fmt.es))


def _bytes_moved(n, fmt, impl) -> int:
    """HBM traffic model: operands in + result out (+ [7]'s decode round trip:
    read codes, write f32, read f32 again; and encode round trip on output)."""
    el = 4 if fmt is F32 else fmt.storage_bytes
    base = 2 * n * n * el + n * n * el
    if impl == "unfused" and fmt is not F32:
        base += 2 * (n * n * (el + 4 + 4))  # decode pass per operand
        base += n * n * (4 + 4 + el)        # encode pass on result
    return base


def run(smoke: bool = False):
    sizes = SMOKE_SIZES if smoke else SIZES
    for fmt, label in ((F32, "fp32"), (P16_1, "p16_1"), (P8_0, "p8_0")):
        slots = OS(rs1=fmt, rs2=fmt, rd=fmt)
        for n in sizes:
            a, b = _operands(n, fmt)
            fns = {}
            for impl in ("xla", "unfused") if fmt is not F32 else ("xla",):
                f = jax.jit(lambda a, b, i=impl: gemm(a, b, slots, impl=i))
                us = time_fn(f, a, b)
                flops = 2 * n ** 3
                fns[impl] = us
                mflops = flops / us  # us -> MFLOPS directly
                emit(f"table4/gemm{n}x{n}/{label}/{impl}", us, f"{mflops:.1f}MFLOPS")
            if fmt is not F32:
                ratio = fns["unfused"] / fns["xla"]
                br = _bytes_moved(n, fmt, "unfused") / _bytes_moved(n, fmt, "xla")
                emit(f"table4/gemm{n}x{n}/{label}/fused_speedup",
                     fns["xla"], f"measured={ratio:.2f}x bytes={br:.2f}x")

    # ours vs fp32 baseline at same sizes (paper: ~1.0x, pcsr config is free)
    for n in ((256,) if smoke else (256, 1024)):
        af, bf = _operands(n, F32)
        base = time_fn(jax.jit(lambda a, b: gemm(a, b, OS(rs1=F32, rs2=F32, rd=F32))), af, bf)
        a8, b8 = _operands(n, P8_0)
        s8 = OS(rs1=P8_0, rs2=P8_0, rd=P8_0)
        ours = time_fn(jax.jit(lambda a, b: gemm(a, b, s8, impl="xla")), a8, b8)
        emit(f"table4/posit_vs_fp32_overhead/{n}", ours,
             f"{ours / base:.2f}x_of_fp32")

    # scratchpad-savings table: max NxN GEMM (3 operands resident) per budget
    for budget_kb, name in ((8, "8KB"), (64, "64KB")):
        budget = budget_kb * 1024
        row = {}
        for fmt, label in ((F32, "fp32"), (P16_1, "p16_1"), (P8_0, "p8_0")):
            el = 4 if fmt is F32 else fmt.storage_bytes
            n = int((budget / (3 * el)) ** 0.5)
            row[label] = n
        emit(f"table4/max_gemm_in_{name}", 0.0,
             f"fp32={row['fp32']} p16={row['p16_1']} p8={row['p8_0']}")
    return True


if __name__ == "__main__":
    run()
