"""Benchmark harness utilities: wall-clock timing + CSV/JSON emission.

``emit`` both prints the CSV row (the historical interface) and records it in
a module-level buffer; ``drain_rows`` hands the buffered rows to the runner,
which serializes them as ``BENCH_<name>.json`` — the machine-readable perf
trajectory CI and later PRs diff against.
"""
from __future__ import annotations

import time

import jax

_ROWS: list[dict] = []


def time_fn(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall-time per call in microseconds (jit-compiled fn)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str) -> None:
    _ROWS.append({"name": name, "us_per_call": round(float(us), 1),
                  "derived": derived})
    print(f"{name},{us:.1f},{derived}")


def drain_rows() -> list[dict]:
    """Return and clear the rows emitted since the last drain."""
    rows = list(_ROWS)
    _ROWS.clear()
    return rows
