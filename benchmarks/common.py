"""Benchmark harness utilities: wall-clock timing + CSV/JSON emission.

``emit`` both prints the CSV row (the historical interface) and records it in
a module-level buffer; ``drain_rows`` hands the buffered rows to the runner,
which serializes them as ``BENCH_<name>.json`` — the machine-readable perf
trajectory CI and later PRs diff against.
"""
from __future__ import annotations

import time

import jax

_ROWS: list[dict] = []


def time_fn(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Best (min) wall-time per call in microseconds (jit-compiled fn).

    Min, not median: scheduler/neighbor load only ever *adds* time, so the
    minimum over iters is the load-robust location statistic — the one the
    CI regression gate (benchmarks/compare.py) can meaningfully diff across
    runs (bench_epilogue_fusion already reports min us for the same reason).
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return min(times) * 1e6


def emit(name: str, us: float, derived: str) -> None:
    _ROWS.append({"name": name, "us_per_call": round(float(us), 1),
                  "derived": derived})
    print(f"{name},{us:.1f},{derived}")


def drain_rows() -> list[dict]:
    """Return and clear the rows emitted since the last drain."""
    rows = list(_ROWS)
    _ROWS.clear()
    return rows
