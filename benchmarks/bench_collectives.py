"""Beyond-paper: posit-compressed cross-pod gradient collective.

Runs in a subprocess with 8 simulated host devices (mesh (2,4) =
("pod","data")) so the parent process keeps its single-device view. Reports:
  * wall time f32 psum vs posit-compressed psum (CPU: indicative only)
  * HLO collective payload bytes on the pod axis (deterministic — the claim)
  * error-feedback quality: compressed-sum relative error with/without EF
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.types import P16_1, P8_0
from repro.distributed.collectives import compressed_psum

mesh = jax.make_mesh((2, 4), ("pod", "data"))
N = 1 << 20
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(0, 1e-3, (8, N)).astype(np.float32))  # grad-like

def run(fmt):
    def f(x):
        y, res = compressed_psum(x, fmt, intra_axis="data", inter_axis="pod")
        return y
    sm = jax.shard_map(f, mesh=mesh, in_specs=P(("pod", "data")),
                       out_specs=P(("pod", "data")), check_vma=False)
    jf = jax.jit(sm)
    lo = jf.lower(x)
    txt = lo.compile().as_text()
    coll_bytes = {}
    for line in txt.splitlines():
        for op in ("all-reduce", "all-gather", "reduce-scatter", "all-to-all"):
            if f" {op}(" in line or f" {op}-start(" in line:
                import re
                for dt, dims in re.findall(r"\b(f32|bf16|u8|u16|s32)\[([0-9,]*)\]",
                                            line.split(op)[0]):
                    n = 1
                    for d in dims.split(","):
                        if d: n *= int(d)
                    sz = {"f32": 4, "bf16": 2, "u8": 1, "u16": 2, "s32": 4}[dt]
                    coll_bytes[dt] = coll_bytes.get(dt, 0) + n * sz
    out = jf(x)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(5):
        jax.block_until_ready(jf(x))
    us = (time.perf_counter() - t0) / 5 * 1e6
    # exactness vs true sum
    true = np.asarray(x, np.float64).reshape(8, N).sum(0)
    got = np.asarray(out, np.float64)[0]
    rel = float(np.abs(got - true).mean() / (np.abs(true).mean() + 1e-12))
    return {"us": us, "coll_bytes": coll_bytes, "rel_err": rel}

res = {"f32": run(None), "p16": run(P16_1), "p8": run(P8_0)}

# error feedback over steps: EF should beat no-EF on accumulated updates
def ef_trial(use_ef):
    fmt = P8_0
    res_buf = jnp.zeros((8, N // 64), jnp.float32)
    acc_c = np.zeros(N // 64); acc_t = np.zeros(N // 64)
    xs = rng.normal(0, 1e-3, (20, 8, N // 64)).astype(np.float32)
    def f(x, r):
        y, r2 = compressed_psum(x, fmt, intra_axis="data", inter_axis="pod",
                                residual=r if use_ef else None)
        return y, (r2 if use_ef and r2 is not None else jnp.zeros_like(x))
    sm = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=(P(("pod", "data")),) * 2,
                 out_specs=(P(("pod", "data")),) * 2, check_vma=False))
    for i in range(20):
        y, res_buf = sm(jnp.asarray(xs[i]), res_buf)
        acc_c += np.asarray(y, np.float64)[0]
        acc_t += xs[i].astype(np.float64).reshape(8, -1).sum(0)
    return float(np.abs(acc_c - acc_t).mean() / np.abs(acc_t).mean())

res["ef_err"] = ef_trial(True)
res["noef_err"] = ef_trial(False)
print("RESULT " + json.dumps(res))
"""


def run():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         capture_output=True, text=True, timeout=600)
    line = [ln for ln in out.stdout.splitlines() if ln.startswith("RESULT ")]
    if not line:
        emit("collectives/error", 0.0, out.stderr[-200:].replace("\n", " "))
        return False
    res = json.loads(line[0][7:])
    f32b = sum(res["f32"]["coll_bytes"].values())
    for k in ("f32", "p16", "p8"):
        r = res[k]
        tot = sum(r["coll_bytes"].values())
        emit(f"collectives/psum_{k}", r["us"],
             f"bytes={tot} vs_f32={tot / max(f32b, 1):.2f}x rel_err={r['rel_err']:.2e}")
    emit("collectives/error_feedback_gain", 0.0,
         f"ef={res['ef_err']:.2e} no_ef={res['noef_err']:.2e} "
         f"better={res['ef_err'] < res['noef_err']}")
    return True


if __name__ == "__main__":
    run()
