"""Paper Table II: design-point comparison. The SOTA rows become executable
baselines in our framework:

  * "unified codec+FPU" (this work)  — fused decode -> MXU/FPU -> encode
  * "parallel PAU" (PERCIVAL [5])    — true posit ALU (integer datapath),
                                       repro.core.alu; costs a long scalar op
                                       chain instead of the native FP unit
  * "conversion instructions" ([7])  — unfused decode/encode passes

plus the feature matrix (multi-precision | mixed-precision | dynamic es).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import P8_0
from repro.core.alu import posit_add, posit_mul
from repro.core.codec import posit_decode, posit_encode
from repro.core.pcsr import OperandSlots as OS
from repro.kernels.posit_gemm.ops import gemm

N = 64  # PAU-path GEMM is O(N^3) scalar ALU ops — keep small like the paper


def _alu_gemm(a_codes, b_codes, n):
    """GEMM on the integer PAU: every multiply and accumulate is a true posit
    op (never touches float) — the PERCIVAL design point."""
    acc = jnp.zeros((N, N), jnp.uint8)
    for k in range(n):
        prod = posit_mul(a_codes[:, k:k + 1], b_codes[k:k + 1, :], 8, 0)
        acc = posit_add(acc, prod, 8, 0)
    return acc


def run():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(0, 0.5, (N, N)).astype(np.float32))
    b = jnp.asarray(rng.normal(0, 0.5, (N, N)).astype(np.float32))
    ac, bc = posit_encode(a, 8, 0), posit_encode(b, 8, 0)
    slots = OS(rs1=P8_0, rs2=P8_0, rd=P8_0)

    ours = jax.jit(lambda a, b: gemm(a, b, slots, impl="xla"))
    conv7 = jax.jit(lambda a, b: gemm(a, b, slots, impl="unfused"))
    pau = jax.jit(lambda a, b: _alu_gemm(a, b, N))

    us_ours = time_fn(ours, ac, bc)
    us_conv = time_fn(conv7, ac, bc)
    us_pau = time_fn(pau, ac, bc, iters=3)

    emit("table2/unified_codec_fpu(this_work)", us_ours, "1.00x")
    emit("table2/conversion_insns[7]", us_conv, f"{us_conv / us_ours:.2f}x_slower")
    emit("table2/parallel_pau[5]", us_pau, f"{us_pau / us_ours:.2f}x_slower")

    # numerics: PAU (single rounding) vs codec+FPU (FP32 datapath) agree to
    # the last posit bit on elementwise ops
    x = posit_encode(jnp.asarray(rng.normal(0, 1, 4096).astype(np.float32)), 8, 0)
    y = posit_encode(jnp.asarray(rng.normal(0, 1, 4096).astype(np.float32)), 8, 0)
    via_alu = posit_mul(x, y, 8, 0)
    via_fpu = posit_encode(posit_decode(x, 8, 0) * posit_decode(y, 8, 0), 8, 0)
    agree = float(np.mean(np.asarray(via_alu) == np.asarray(via_fpu)))
    emit("table2/pau_vs_fpu_mul_bit_agreement", 0.0, f"{agree:.4f}")

    features = ("multi_prec=yes mixed_prec=yes dynamic_es=yes "
                "ieee_compat=yes pau=none(unified)")
    emit("table2/feature_matrix(this_work)", 0.0, features)
    return True


if __name__ == "__main__":
    run()
