"""Paper Table III / Fig. 4: cost of adding posit capabilities to the FPU.

ASIC area/delay have no CPU analogue, so each variant's cost is reported as
  * wall-time overhead vs the FP32 baseline pipeline (delay proxy)
  * HLO op count of the lowered pipeline (area proxy — structural size of the
    datapath), clearly labelled a proxy.

Variants mirror the paper's: Baseline (FPU), +P8 (8-bit codecs), +MP
(8+16-bit muxed), +MP+ES (dynamic exponent size from the pcsr). A fifth
beyond-paper variant, +QUIRE (PERCIVAL-style exact accumulator), is reported
on a GEMV row pair so PAU-rounded vs quire-exact accumulation share a
workload: the quire never touches the MXU, so its delay proxy is the price
of exactness, not a like-for-like FPU delta.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.codec import posit_decode, posit_encode
from repro.core.quire import quire_matmul
from repro.core.types import P8_0

N = 512


def _hlo_ops(jitted, *args) -> int:
    txt = jitted.lower(*args).compile().as_text()
    return sum(1 for line in txt.splitlines()
               if "=" in line and not line.strip().startswith(("//", "ENTRY",
                                                               "HloModule")))


def run():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (N, N)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 1, (N, N)).astype(np.float32))
    w8 = posit_encode(w, 8, 0)
    w16 = posit_encode(w, 16, 1)

    variants = {}

    def baseline(x, w):
        return jnp.matmul(x, w, preferred_element_type=jnp.float32)
    variants["fpu_baseline"] = (jax.jit(baseline), (x, w))

    def p8(x, w8):
        return jnp.matmul(x, posit_decode(w8, 8, 0),
                          preferred_element_type=jnp.float32)
    variants["fpu_p8"] = (jax.jit(p8), (x, w8))

    def mp(x, w8, w16, sel):
        wa = posit_decode(w8, 8, 0)
        wb = posit_decode(w16, 16, 1)
        return jnp.matmul(x, jnp.where(sel, wa, wb),
                          preferred_element_type=jnp.float32)
    variants["fpu_mp"] = (jax.jit(mp), (x, w8, w16, jnp.bool_(True)))

    def mp_es(x, w8, w16, sel, es):
        wa = posit_decode(w8, 8, es)
        wb = posit_decode(w16, 16, es)
        return jnp.matmul(x, jnp.where(sel, wa, wb),
                          preferred_element_type=jnp.float32)
    variants["fpu_mp_es"] = (jax.jit(mp_es),
                             (x, w8, w16, jnp.bool_(True), jnp.int32(1)))

    base_us = base_ops = None
    for name, (fn, args) in variants.items():
        us = time_fn(fn, *args)
        ops = _hlo_ops(fn, *args)
        if name == "fpu_baseline":
            base_us, base_ops = us, ops
            emit(f"table3/{name}", us, f"ops={ops}")
        else:
            emit(f"table3/{name}", us,
                 f"ops={ops} time+{(us / base_us - 1) * 100:.1f}% "
                 f"area_proxy+{(ops / base_ops - 1) * 100:.1f}%")

    # +QUIRE variant: PAU-rounded vs quire-exact accumulation on one GEMV row
    # (x_row @ W, K=N). The fused path rounds the f32 accumulation once at
    # encode; the quire path is bit-exact with a single terminal rounding.
    x8 = posit_encode(x[:1, :], 8, 0)
    def p8_gemv(x8, w8):
        y = jnp.matmul(posit_decode(x8, 8, 0), posit_decode(w8, 8, 0),
                       preferred_element_type=jnp.float32)
        return posit_encode(y, 8, 0)
    gemv = jax.jit(p8_gemv)
    us_g = time_fn(gemv, x8, w8)
    ops_g = _hlo_ops(gemv, x8, w8)
    emit("table3/fpu_p8_gemv", us_g, f"ops={ops_g} (rounded-accum reference)")

    quire = jax.jit(lambda a, b: quire_matmul(a, b, P8_0))
    us_q = time_fn(quire, x8, w8)
    ops_q = _hlo_ops(quire, x8, w8)
    emit("table3/fpu_p8_quire", us_q,
         f"ops={ops_q} time+{(us_q / us_g - 1) * 100:.1f}% "
         f"area_proxy+{(ops_q / ops_g - 1) * 100:.1f}% (exact-accum)")
    return True


if __name__ == "__main__":
    run()
