"""Fused vs chained serving layer: the per-op launch + round-trip tax.

A serving linear layer is decode(W) -> gemm -> bias -> activation ->
residual -> encode.  The chained baseline runs it the way the motivation
([7], PPU-light designs) pays for it: **each stage is its own XLA op** —
its own dispatch, its own materialized result crossing memory.  The fused
path (this PR) runs the whole layer as one op: the decode feeds the matmul
in-register and the epilogue rides in the producer (``posit_matmul_wx`` with
``epilogue="fused"``; the Pallas kernel path does the same inside one
``pallas_call``).

Two measurements per configuration:
  * analytic bytes-moved model (deterministic, hardware-independent — the
    actual mechanism, same accounting as Table IV), asserted strictly lower
    for the fused path, and
  * measured wall time, sampled as *paired interleaved rounds* with the
    median of per-round chained/fused ratios — adjacent rounds share machine
    conditions, so shared-host noise cancels instead of deciding the verdict.

In smoke mode (the CI configuration) the measured ratio must be > 1.
Results land in BENCH_epilogue.json via benchmarks.run.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import P8_0, P16_1
from repro.core.codec import posit_decode, posit_encode
from repro.core.dot import posit_matmul_wx


def _bytes_moved(M, K, N, w_bytes, out_bytes, *, chained: bool,
                 with_residual: bool) -> int:
    """Memory traffic model for one layer: x + W codes + bias in, result out.
    The chained pipeline additionally round-trips the decoded (K, N) f32
    weights and the (M, N) f32 intermediate at every stage boundary
    (gemm->bias, bias->act, act->residual, residual->encode)."""
    base = M * K * 4 + K * N * w_bytes + N * 4 + M * N * out_bytes
    if with_residual:
        base += M * N * 4
    if chained:
        base += 2 * K * N * 4            # decode pass: write + re-read f32 W
        base += 4 * (2 * M * N * 4)      # four stage boundaries, write + read
    return base


def _median_paired_ratio(fused, chained, args, rounds: int):
    """(median ratio, min fused us, min chained us) over interleaved rounds."""
    for fn in (fused, chained):  # compile + warm caches
        jax.block_until_ready(fn(*args))
        jax.block_until_ready(fn(*args))
    ratios, tf_all, tc_all = [], [], []
    for _ in range(rounds):
        t0 = time.perf_counter()
        jax.block_until_ready(fused(*args))
        tf = time.perf_counter() - t0
        t0 = time.perf_counter()
        jax.block_until_ready(chained(*args))
        tc = time.perf_counter() - t0
        ratios.append(tc / tf)
        tf_all.append(tf)
        tc_all.append(tc)
    ratios.sort()
    return ratios[len(ratios) // 2], min(tf_all) * 1e6, min(tc_all) * 1e6


def run(smoke: bool = False):
    M, K, N = (512, 256, 1024) if smoke else (1024, 256, 1024)
    rounds = 10 if smoke else 16
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (M, K)).astype(np.float32))
    bias = jnp.asarray(rng.normal(0, 1, N).astype(np.float32))
    residual = jnp.asarray(rng.normal(0, 1, (M, N)).astype(np.float32))

    ratios = {}
    for fmt, label in ((P8_0, "p8_0"), (P16_1, "p16_1")):
        w = jnp.asarray(rng.normal(0, K ** -0.5, (K, N)).astype(np.float32))
        wc = posit_encode(w, fmt.nbits, fmt.es)

        fused = jax.jit(lambda a, wv, bv, rv, _f=fmt: posit_matmul_wx(
            a, wv, _f, bias=bv, activation="relu", residual=rv,
            out_fmt=_f, epilogue="fused", compute_dtype=jnp.float32))

        # the chained baseline: every stage a separate XLA op (own launch,
        # own materialized result), exactly the pre-fusion layer pipeline
        s_dec = jax.jit(lambda wv, _f=fmt: posit_decode(wv, _f.nbits, _f.es))
        s_gemm = jax.jit(lambda a, wf: jnp.matmul(
            a, wf, preferred_element_type=jnp.float32))
        s_bias = jax.jit(lambda y, bv: y + bv)
        s_act = jax.jit(jax.nn.relu)
        s_res = jax.jit(lambda y, rv: y + rv)
        s_enc = jax.jit(lambda y, _f=fmt: posit_encode(y, _f.nbits, _f.es))

        def chained(a, wv, bv, rv):
            return s_enc(s_res(s_act(s_bias(s_gemm(a, s_dec(wv)), bv)), rv))

        ratio, us_f, us_c = _median_paired_ratio(
            fused, chained, (x, wc, bias, residual), rounds)

        by_f = _bytes_moved(M, K, N, fmt.storage_bytes, fmt.storage_bytes,
                            chained=False, with_residual=True)
        by_c = _bytes_moved(M, K, N, fmt.storage_bytes, fmt.storage_bytes,
                            chained=True, with_residual=True)
        assert by_f < by_c, "fused epilogue must move strictly fewer HBM bytes"
        ratios[label] = ratio
        emit(f"epilogue/layer{M}x{K}x{N}/{label}/fused", us_f,
             f"{by_f / 1e6:.2f}MB_moved")
        emit(f"epilogue/layer{M}x{K}x{N}/{label}/chained", us_c,
             f"{by_c / 1e6:.2f}MB_moved")
        emit(f"epilogue/layer{M}x{K}x{N}/{label}/fused_speedup", us_f,
             f"measured={ratio:.2f}x bytes={by_c / by_f:.2f}x")

    if smoke:
        # every format must beat the baseline — max() would let one format
        # regress silently behind the other
        worst = min(ratios.values())
        assert worst > 1.0, (
            f"fused epilogue must beat the chained baseline, got {ratios}")
    return True


if __name__ == "__main__":
    run(smoke=True)
