"""Training-telemetry overhead gate: step throughput with the §16 stack on.

The training-plane observability (probed-twin gradient/activation telemetry,
step-health JSONL log, metrics registry — DESIGN.md §16) is only deployable
if the *plain* steps stay free and the probe cost amortizes away at the
default cadence.  This benchmark runs the same train step two ways —
telemetry OFF (the bare jitted step) vs fully ON (a ``TrainingTelemetry`` at
its default cadence, routing every ``every``-th step through the probed twin
and draining the JSONL log at probe boundaries) — with the paired-interleaved
min-statistic construction (bench_obs_overhead / DESIGN.md §8: each round
times both configurations back-to-back, rotating who runs first;
min-over-rounds discards loaded samples), and **asserts** the instrumented
loop stays within ``MAX_OVERHEAD`` (5%) of the bare loop.

One timing round spans exactly one probe cadence cycle (``telemetry.every``
steps), so every round pays exactly one probed-twin step plus one drain —
the steady-state amortized cost, never a lucky probe-free window.

The instrumented run's artifacts are written to the cwd for CI upload:
``train_metrics.json`` (registry snapshot + telemetry report) and
``train_profile.json``/``.md`` (the per-kernel roofline-attribution report
from tracing the step under the §16 profiler).
"""
from __future__ import annotations

import os
import tempfile
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs import get_arch
from repro.data.pipeline import SyntheticLMPipeline
from repro.launch.dryrun import _parse_policy
from repro.launch.steps import make_train_step
from repro.models.registry import build_model
from repro.obs import prof
from repro.obs.train import TrainingTelemetry
from repro.optim import AdamWConfig, adamw_init

#: Acceptance ceiling: the telemetry-on loop may cost at most this much more
#: than the bare loop at the default probe cadence.
MAX_OVERHEAD = 0.05


def run(smoke: bool = False) -> None:
    rounds = 2 if smoke else 4
    cfg = get_arch("xlstm-125m").reduced()
    policy = _parse_policy("p16-train")
    model = build_model(cfg)
    opt_cfg = AdamWConfig(lr=1e-3, moment_fmt=policy.optimizer)
    params = model.init(jax.random.key(0))
    pipe = SyntheticLMPipeline(vocab=cfg.vocab, seq_len=16, global_batch=2,
                               seed=0)
    batch = pipe.batch_at(0)   # fixed batch: host-side generation stays
    #                            out of the timed window for both configs

    step_kw = dict(warmup=1, total_steps=10_000)
    step_fn_raw = make_train_step(model, policy, opt_cfg, **step_kw)
    jitted = jax.jit(step_fn_raw)
    jitted_probed = jax.jit(
        make_train_step(model, policy, opt_cfg, telemetry=True, **step_kw))

    log_path = os.path.join(tempfile.mkdtemp(prefix="bench_train_obs_"),
                            "steps.jsonl")
    telemetry = TrainingTelemetry(policy=policy, log_path=log_path)
    steps = telemetry.every    # one round == one full probe cadence cycle

    def loop_off(state, base, n):
        p, o = state
        for i in range(n):
            p, o, _ = jitted(p, o, batch, jnp.asarray(base + i))
        jax.block_until_ready((p, o))
        return p, o

    def loop_on(state, base, n):
        p, o = state
        for i in range(n):
            step = base + i
            if telemetry.should_probe(step):
                with telemetry.observing():
                    p, o, m = jitted_probed(p, o, batch, jnp.asarray(step))
            else:
                p, o, m = jitted(p, o, batch, jnp.asarray(step))
            telemetry.on_step(step, m, probed=telemetry.should_probe(step))
        jax.block_until_ready((p, o))
        return p, o

    opt = adamw_init(params, opt_cfg)
    loops = {"off": loop_off, "on": loop_on}
    # independent param/opt states per config so both see identical update
    # trajectories; warm both executables (plain + probed twin) off-clock
    states = {n: (params, opt) for n in loops}
    clock = {n: 0 for n in loops}
    for name, fn in loops.items():
        states[name] = fn(states[name], clock[name], 2)
        clock[name] += 2
    with telemetry.observing():
        jax.block_until_ready(
            jitted_probed(*states["on"], batch, jnp.asarray(clock["on"])))

    best = {n: float("inf") for n in loops}
    order = list(loops)
    for r in range(rounds):
        # rotate who runs first: the first-timed loop in a round sees cold
        # caches, and a fixed order would book that cost to one configuration
        for name in order[r % len(order):] + order[:r % len(order)]:
            # align the "on" loop to the cadence so the round pays exactly
            # one probed step wherever the warmup left the counter
            base = clock[name]
            if name == "on":
                base = ((base + steps - 1) // steps) * steps
            t0 = time.perf_counter()
            states[name] = loops[name](states[name], base, steps)
            best[name] = min(best[name],
                             (time.perf_counter() - t0) / steps * 1e6)
            clock[name] = base + steps

    overhead = best["on"] / best["off"] - 1.0
    emit("train_step_plain", best["off"],
         f"steps_per_s={1e6 / best['off']:.2f}")
    emit("train_step_telemetry", best["on"],
         f"steps_per_s={1e6 / best['on']:.2f} "
         f"overhead={overhead * 100:+.2f}% "
         f"probes={telemetry.watcher.probes} every={steps}")

    # the uploaded artifacts: metrics snapshot + roofline attribution
    telemetry.close()
    telemetry.metrics.set_context(arch=cfg.name, bench="train_obs_overhead",
                                  telemetry=telemetry.report())
    telemetry.metrics.save("train_metrics.json")
    # tracing (not running) the step under the profiler yields the analytic
    # attribution report; the jaxpr caches must be dropped first or the
    # warmed inner jits skip their Python bodies and nothing records
    jax.clear_caches()
    profiler = prof.KernelProfiler()
    with prof.profiling(profiler):
        jax.make_jaxpr(step_fn_raw)(params, opt, batch, jnp.asarray(0))
    profiler.save("train_profile.json")

    assert telemetry.watcher.probes > 0, "no probed step ran"
    with open(log_path) as f:
        n_recs = sum(1 for _ in f)
    assert n_recs == telemetry.steps, (
        f"JSONL step log lost records ({n_recs} != {telemetry.steps})")
    assert profiler.records, "profiler recorded no kernel dispatches"
    assert overhead <= MAX_OVERHEAD, (
        f"training-telemetry overhead {overhead:.1%} exceeds the "
        f"{MAX_OVERHEAD:.0%} gate (off={best['off']:.0f}us "
        f"on={best['on']:.0f}us per step)")


if __name__ == "__main__":
    run(smoke=True)
