"""Observability overhead gate: decode throughput with the full §12 stack on.

The serving-plane observability (metrics registry + Chrome-trace recorder +
cadenced numerics probes, DESIGN.md §12) is only deployable if it is close to
free on the decode fast path.  This benchmark runs the continuous-batching
engine over a full slot grid twice — observability OFF vs fully ON (metrics +
tracer + a ``NumericsWatcher`` at the default cadence) — with the
paired-interleaved min-statistic construction (bench_mixed_gemm / DESIGN.md
§8: each round times both configurations back-to-back so neighbor load hits
them alike, min-over-rounds discards loaded samples), and **asserts** the
instrumented decode stays within ``MAX_OVERHEAD`` (5%) of bare decode.

Two CI gates ride on this file:

* the in-bench assertion (a >5% overhead fails the bench, which fails
  ``benchmarks.run``),
* the emitted ``us_per_call`` rows land in ``BENCH_obs_overhead.json`` and
  are diffed against the previous main run by ``benchmarks/compare.py``.

The instrumented run's metrics snapshot and Chrome trace are written next to
the cwd's BENCH output (``obs_metrics.json`` / ``obs_trace.json``) so CI can
upload them as inspectable artifacts.
"""
from __future__ import annotations

import tempfile
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_arch
from repro.core.pcsr import TransPolicy
from repro.ft import EngineSnapshotter
from repro.launch.engine import ContinuousBatchingEngine, Request
from repro.models.registry import build_model
from repro.obs.metrics import MetricsRegistry
from repro.obs.numerics import NumericsWatcher
from repro.obs.trace import TraceRecorder

#: Acceptance ceiling: instrumented decode may cost at most this much more
#: than bare decode (tokens/s within 5%).
MAX_OVERHEAD = 0.05

#: Snapshot cadence on the instrumented engine (the ft default): the gate
#: now covers the whole deployable serving plane — §12 observability PLUS
#: §13 crash-safe snapshotting — not observability alone.
SNAPSHOT_EVERY = 256


def _fill_slots(eng, cfg, slots: int, prompt_len: int, budget: int) -> None:
    """Admit ``slots`` requests with enough token budget to outlive timing."""
    rng = np.random.default_rng(0)
    for rid in range(slots):
        eng.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab, (prompt_len,)).astype(np.int32),
            max_new_tokens=budget))
    eng.admit()


def run(smoke: bool = False) -> None:
    slots = 2 if smoke else 4
    prompt_len = 16
    rounds = 4 if smoke else 6
    warmup = 2
    watcher = NumericsWatcher(policy=TransPolicy.from_names(
        kv_cache="p8_0", compute_dtype="bf16", attn_impl="kernel"))
    # one timing round spans exactly one probe cadence cycle, so every round
    # pays exactly one probed step — the steady-state amortized cost, not a
    # lucky probe-free window (min-over-rounds would otherwise happily report
    # the cadence's gaps and the gate would be vacuous)
    steps = watcher.every
    budget = warmup + rounds * steps + 4          # tokens per request
    S_max = prompt_len + budget + 8
    cfg = get_arch("yi-34b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    policy = watcher.policy

    metrics, tracer = MetricsRegistry(), TraceRecorder()
    snap_dir = tempfile.mkdtemp(prefix="bench_obs_snap_")
    snapshotter = EngineSnapshotter(snap_dir, every=SNAPSHOT_EVERY,
                                    metrics=metrics)
    engines = {
        "off": ContinuousBatchingEngine(
            model, params, policy, max_slots=slots, S_max=S_max),
        "on": ContinuousBatchingEngine(
            model, params, policy, max_slots=slots, S_max=S_max,
            metrics=metrics, tracer=tracer, numerics=watcher,
            snapshotter=snapshotter),
    }
    # fill every slot and warm both executables (the "on" engine's first two
    # steps compile the probed twin AND the plain decode) outside the clock
    for eng in engines.values():
        _fill_slots(eng, cfg, slots, prompt_len, budget)
        for _ in range(warmup):
            eng.step(now=time.perf_counter())
        assert int(eng.active.sum()) == slots, "timing must run a full grid"

    best = {name: float("inf") for name in engines}
    order = list(engines)
    for r in range(rounds):
        # rotate who runs first: the first-timed engine in a round sees cold
        # caches/branch predictors, and a fixed order would book that cost to
        # one configuration (measured: up to ~4% phantom overhead either way)
        for name in order[r % len(order):] + order[:r % len(order)]:
            eng = engines[name]
            t0 = time.perf_counter()
            for _ in range(steps):
                eng.step(now=time.perf_counter())
            best[name] = min(best[name],
                             (time.perf_counter() - t0) / steps * 1e6)
    for eng in engines.values():
        assert int(eng.active.sum()) == slots, "a slot evicted mid-timing"

    tok_s = {n: slots / us * 1e6 for n, us in best.items()}
    overhead = best["on"] / best["off"] - 1.0
    emit("decode_obs_off", best["off"], f"tok_s={tok_s['off']:.1f}")
    emit("decode_obs_on", best["on"],
         f"tok_s={tok_s['on']:.1f} overhead={overhead * 100:+.2f}% "
         f"probes={engines['on'].numerics.probes} "
         f"snapshots={snapshotter.saves}")
    snapshotter.close()    # drain + surface any background save failure
    assert snapshotter.saves > 0, "no snapshot fired inside the timed window"

    # the uploaded artifacts: what the instrumented run actually recorded
    engines["on"].numerics.check()
    metrics.set_context(arch=cfg.name, bench="obs_overhead",
                        numerics=engines["on"].numerics.report())
    metrics.save("obs_metrics.json")
    tracer.save("obs_trace.json")

    assert metrics.counter("decode_steps").total >= warmup + rounds * steps
    assert engines["on"].numerics.probes > 0, "no probed step ran"
    assert overhead <= MAX_OVERHEAD, (
        f"observability overhead {overhead:.1%} exceeds the "
        f"{MAX_OVERHEAD:.0%} gate (off={best['off']:.1f}us "
        f"on={best['on']:.1f}us per step)")


if __name__ == "__main__":
    run(smoke=True)
