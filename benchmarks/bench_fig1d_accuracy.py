"""Paper Fig. 1(d): decimal accuracy vs magnitude, posit vs IEEE-754.

Decimal accuracy at a representable value x: -log10(relative rounding error
bound) = -log10((next(x) - x) / (2|x|)). Computed exhaustively from the codec
for posit formats and from ml_dtypes for IEEE float16 / float8.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import ml_dtypes

from benchmarks.common import emit
from repro.core.codec import posit_decode


def _posit_accuracy(nbits: int, es: int):
    n_codes = 1 << nbits
    codes = np.arange(n_codes, dtype=np.uint16 if nbits == 16 else np.uint8)
    vals = np.asarray(posit_decode(jnp.asarray(codes), nbits, es), np.float64)
    pos = np.sort(vals[np.isfinite(vals) & (vals > 0)])
    x, nxt = pos[:-1], pos[1:]
    acc = -np.log10((nxt - x) / (2 * x))
    return x, acc


def _ieee_accuracy(dtype):
    try:
        bits = np.finfo(dtype).bits
    except ValueError:
        bits = ml_dtypes.finfo(dtype).bits
    codes = np.arange(1 << bits, dtype=np.uint16 if bits == 16 else np.uint8)
    vals = codes.view(dtype).astype(np.float64)
    pos = np.unique(vals[np.isfinite(vals) & (vals > 0)])
    x, nxt = pos[:-1], pos[1:]
    acc = -np.log10((nxt - x) / (2 * x))
    return x, acc


def _bucketize(x, acc, lo=-16, hi=17):
    rows = {}
    for b in range(lo, hi):
        sel = (np.log10(x) >= b) & (np.log10(x) < b + 1)
        if sel.any():
            rows[b] = float(acc[sel].mean())
    return rows


def run():
    table = {}
    for name, (n, es) in {"P(16,1)": (16, 1), "P(16,2)": (16, 2),
                          "P(8,0)": (8, 0), "P(8,2)": (8, 2)}.items():
        x, acc = _posit_accuracy(n, es)
        table[name] = _bucketize(x, acc)
    for name, dt in {"fp16": ml_dtypes.float16 if hasattr(ml_dtypes, "float16")
                     else np.float16, "bf16": ml_dtypes.bfloat16,
                     "fp8e4m3": ml_dtypes.float8_e4m3fn}.items():
        x, acc = _ieee_accuracy(dt)
        table[name] = _bucketize(x, acc)

    # the paper's headline: near 1.0, P(16,1) beats fp16; at the tails fp16 wins
    p16_at_0 = table["P(16,1)"].get(0, 0)
    fp16_at_0 = table["fp16"].get(0, 0)
    emit("fig1d/p16_1_central_decimal_accuracy", 0.0, f"{p16_at_0:.2f}")
    emit("fig1d/fp16_central_decimal_accuracy", 0.0, f"{fp16_at_0:.2f}")
    emit("fig1d/posit_beats_ieee_near_1", 0.0, str(p16_at_0 > fp16_at_0))
    p8_at_0 = table["P(8,0)"].get(0, 0)
    f8_at_0 = table["fp8e4m3"].get(0, 0)
    emit("fig1d/p8_0_vs_fp8e4m3_central", 0.0, f"{p8_at_0:.2f}vs{f8_at_0:.2f}")
    # tapered: P(16,1) at |x|~1e6 below its central accuracy
    tail = table["P(16,1)"].get(6, 0)
    emit("fig1d/p16_1_tapered_tail_at_1e6", 0.0, f"{tail:.2f}")
    return table


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
