"""Calibration benchmark: the calibrated dynamic-es policy vs the presets on
the accuracy-vs-weight-bytes frontier (BENCH_calibration.json).

The paper's precision-scalability claim is that *per-operation* exponent size
— picked from the data, not fixed globally — buys accuracy at constant
storage.  This benchmark pins that end to end through repro.calib
(DESIGN.md §11), on two registry models (dense + MoE):

* **equal-bytes win** (asserted, smoke and full): the calibrated policy at
  the p8 floor budget (1 byte/weight — exactly the ``p8-weights`` preset's
  storage) achieves strictly lower measured forward error than the preset,
  at equal-or-fewer weight bytes.
* **frontier**: calibrated error at 1x / 1.25x / 1.5x / 2x the p8 floor —
  the byte-budgeted knapsack trading storage back for accuracy (2x = uniform
  p16 storage).
* **artifact round trip** (asserted): a saved ``--policy-out`` artifact
  reloaded via ``@cal.json`` reproduces bit-identical quantized weights.
* **decode throughput**: tokens/s through the continuous-batching engine
  (launch/engine.py) under calibrated-quantized vs preset-quantized weights.
  Equal bytes and the same all-p8 datapath make parity the expectation, but
  reduced-size engine steps are dispatch-overhead-dominated (measured ratio
  swings ~0.75-1.25x with runner load), so only a catastrophic slowdown is
  gated (>= 0.6, full mode) — smoke asserts the accuracy claim, which is
  deterministic.
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.calib.observe import collect_stats
from repro.calib.search import (build_site_plans, calibration_batches,
                                emit_policy, save_artifact, search)
from repro.configs import get_arch
from repro.core.pcsr import TransPolicy
from repro.core.policy import (PRECISION_PRESETS, PrecisionPolicy,
                               get_precision_policy)
from repro.launch.engine import ContinuousBatchingEngine, poisson_requests
from repro.models.layers import policy_weight_bytes, quantize_params
from repro.models.registry import build_model

ARCHS = ("phi3-mini-3.8b", "olmoe-1b-7b")   # dense + MoE call-site coverage


def _rel_rmse(model, params, batch, policy, ref) -> float:
    h = model.forward(params, batch, policy)
    return float(jnp.sqrt(jnp.mean((h - ref) ** 2) / jnp.mean(ref ** 2)))


def _tree_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        bool(jnp.array_equal(x, y)) for x, y in zip(la, lb))


def _engine_tok_per_s(model, params_q, policy, *, vocab, prompt_len,
                      gen, slots, S_max, rounds) -> float:
    eng = ContinuousBatchingEngine(model, params_q, policy,
                                   max_slots=slots, S_max=S_max)
    warm = poisson_requests(1, arrival_rate=0.0, prompt_lens=(prompt_len,),
                           max_new_tokens=2, vocab=vocab)
    eng.run(warm)
    best = 0.0
    for _ in range(rounds):
        eng.reset()
        reqs = poisson_requests(2 * slots, arrival_rate=0.0,
                                prompt_lens=(prompt_len,),
                                max_new_tokens=gen, vocab=vocab, seed=1)
        t0 = time.perf_counter()
        eng.run(reqs)
        dt = max(time.perf_counter() - t0, 1e-9)
        n_tok = sum(len(c.tokens) for c in eng.completions)
        best = max(best, n_tok / dt)
    return best


def _bench_arch(arch: str, smoke: bool) -> PrecisionPolicy:
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    n_cal = 2 if smoke else 4
    B, S = (2, 48) if smoke else (4, 96)
    cal_batches = calibration_batches(cfg, np.random.default_rng(0), n_cal,
                                      batch=B, seq=S)
    eval_batch = calibration_batches(cfg, np.random.default_rng(99), 1,
                                     batch=B, seq=S)[0]   # held out
    base = TransPolicy()                            # f32 datapath: the error
    ref = model.forward(params, eval_batch, base)   # measured is the codec's

    # one observation pass feeds every budget's search; drive model.loss so
    # the lm_head site is observed too (forward stops at the hidden states)
    t0 = time.perf_counter()
    observer = collect_stats(
        lambda b: model.loss(params, b, base)[0], cal_batches)
    plans = build_site_plans(params, observer)
    observe_s = time.perf_counter() - t0

    preset = PRECISION_PRESETS["p8-weights"].with_base(base)
    err_preset = _rel_rmse(model, params, eval_batch, preset, ref)
    bytes_preset = policy_weight_bytes(params, preset)["weight_bytes_policy"]
    emit(f"{arch}_preset_p8", 0.0,
         f"rel_err={err_preset:.5f} weight_bytes={bytes_preset}")

    cal_policies = {}
    for mult in ((1.0, 2.0) if smoke else (1.0, 1.25, 1.5, 2.0)):
        choice, report = search(plans, f"{mult}x")
        pol = emit_policy(plans, choice, base=base,
                          name=f"calibrated-{cfg.name}-{mult}x")
        err = _rel_rmse(model, params, eval_batch, pol, ref)
        nbytes = policy_weight_bytes(params, pol)["weight_bytes_policy"]
        cal_policies[mult] = (pol, err, nbytes)
        # observation wall time rides in derived (not us_per_call: that
        # column is regression-gated, and host-callback wall clock on a
        # shared runner is not a stable throughput statistic)
        extra = f" observe_s={observe_s:.2f}" if mult == 1.0 else ""
        emit(f"{arch}_calibrated_{mult}x", 0.0,
             f"rel_err={err:.5f} weight_bytes={nbytes} "
             f"pred_score={report['predicted_err_score']:.3e} "
             f"sites={len(report['sites'])}{extra}")

    # acceptance: equal-bytes win for the dynamic-es schedule, every mode
    pol1, err1, bytes1 = cal_policies[1.0]
    assert err1 < err_preset, (
        f"{arch}: calibrated policy at the p8 byte budget must beat the "
        f"p8-weights preset: {err1:.5f} vs {err_preset:.5f}")
    assert bytes1 <= bytes_preset, (
        f"{arch}: calibrated bytes {bytes1} exceed preset {bytes_preset}")
    # more bytes must not predict worse accuracy (knapsack sanity)
    err2 = cal_policies[2.0][1]
    assert err2 <= err1, (
        f"{arch}: 2x budget error {err2:.5f} worse than 1x {err1:.5f}")

    # artifact round trip: reloaded policy -> bit-identical quantized weights
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "cal.json")
        _, report = search(plans, None)
        save_artifact(path, pol1, report)
        loaded = get_precision_policy("@" + path)
        q_direct = quantize_params(params, pol1)
        q_loaded = quantize_params(params, loaded)
        with open(path) as f:
            n_rules = len(json.load(f)["rules"])
        ok = _tree_equal(q_direct, q_loaded)
        emit(f"{arch}_artifact_roundtrip", 0.0,
             f"bitexact={int(ok)} rules={n_rules}")
        assert ok, f"{arch}: reloaded artifact quantized weights differ"
    return pol1


def _bench_decode(arch: str, pol_cal, smoke: bool) -> None:
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    serve_base = TransPolicy.from_names(kv_cache="p8_0", compute_dtype="bf16")
    # compare against the *packed* p8 preset: the calibrated schedule stores
    # packed lanes wherever eligible, so this pairing isolates the dynamic-es
    # choice (es changes decode arithmetic not at all) from the lane layout
    preset = PRECISION_PRESETS["p8-packed"]
    tok_s = {}
    for name, pol in (("preset", preset.with_base(serve_base)),
                      ("calibrated", pol_cal.with_base(serve_base))):
        params_q = quantize_params(params, pol)
        tok_s[name] = _engine_tok_per_s(
            model, params_q, pol, vocab=cfg.vocab,
            prompt_len=8 if smoke else 16, gen=6 if smoke else 16,
            slots=2, S_max=64 if smoke else 128, rounds=2 if smoke else 4)
    ratio = tok_s["calibrated"] / max(tok_s["preset"], 1e-9)
    emit(f"{arch}_engine_decode", 0.0,
         f"cal_tok_s={tok_s['calibrated']:.1f} "
         f"preset_tok_s={tok_s['preset']:.1f} ratio={ratio:.2f}")
    if not smoke:
        # parity is the expectation (equal bytes, same all-p8 datapath), but
        # reduced-size engine steps are dispatch-overhead-dominated and the
        # measured ratio swings ~0.75-1.25 under shared-runner load — gate
        # only the catastrophic case, with margin under the observed floor
        assert ratio >= 0.6, (
            f"{arch}: calibrated decode {tok_s['calibrated']:.1f} tok/s fell "
            f"far below preset {tok_s['preset']:.1f} tok/s at equal bytes")


def run(smoke: bool = False) -> None:
    for arch in ARCHS:
        pol_cal = _bench_arch(arch, smoke)
        if arch == ARCHS[0]:
            _bench_decode(arch, pol_cal, smoke)


if __name__ == "__main__":
    run(smoke=True)
