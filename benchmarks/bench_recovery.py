"""Fault-tolerance gates: recovery time, zero token loss, degraded throughput.

Three measured claims about the §13 serving plane, each asserted (a failure
fails ``benchmarks.run``), each emitted as a ``us_per_call`` row for the
``compare.py`` perf trajectory:

* **Kill-and-resume is lossless** — serve a workload, preempt mid-stream
  (``FaultPlan`` raises the in-process preemption flag), drain-then-snapshot,
  restore into the same engine (same compiled executables — XLA:CPU compiles
  are not bit-stable across program instances, so cross-process identity is
  the integration test's job; in-process identity is the stronger bitwise
  claim) and finish.  Every request's token stream must equal the
  uninterrupted run's **bit-for-bit**, under temperature sampling: the
  snapshot carries the PRNG key, so even the random continuation replays.
* **Recovery is fast** — ``restore_into`` (disk -> engine, full KV cache +
  slot grid) is timed; the row is the trajectory record, the assertion only
  that restore beats re-serving the already-emitted tokens from scratch.
* **Degraded mode still serves** — NaR injection trips the precision ladder
  (packed-p8 -> p8), the poisoned slot quarantines, and the *surviving*
  slots' throughput is measured and must stay within ``MAX_DEGRADED_SLOWDOWN``
  of the healthy engine's (the ladder widens weights; it must not fall off a
  performance cliff or kill unaffected traffic).
"""
from __future__ import annotations

import tempfile
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_arch
from repro.core.pcsr import TransPolicy
from repro.core.policy import get_precision_policy
from repro.ft import (DegradationController, EngineSnapshotter, FaultPlan,
                      PreemptionSignal)
from repro.launch.engine import ContinuousBatchingEngine, poisson_requests
from repro.models.registry import build_model
from repro.obs.numerics import NumericsWatcher

#: Degraded-mode (post-ladder, quarantined slot evicted) decode throughput
#: may be at most this much slower than the healthy engine's.
MAX_DEGRADED_SLOWDOWN = 3.0


def _tokens_by_rid(completions) -> dict:
    return {c.rid: list(c.tokens) for c in completions}


def _drain(eng, now: float = 1e9) -> None:
    """Serve whatever is inside the engine (queue + active) to completion."""
    while eng.active.any() or eng.queue:
        if eng.queue and eng.free_slots():
            eng.admit(now=now)
        eng.step(now=now)


def run(smoke: bool = False) -> None:
    slots = 2 if smoke else 4
    n_req = 2 * slots
    gen = 12 if smoke else 24
    prompt_len = 8
    # headroom: phase 3 times fixed-size grids, so its requests get a token
    # budget that outlives the timing window without hitting cache_full
    S_max = prompt_len + gen + 40
    cfg = get_arch("yi-34b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    policy = TransPolicy.from_names(kv_cache="p8_0", compute_dtype="bf16")
    reqs = lambda: poisson_requests(  # noqa: E731 — fresh copies per phase
        n_req, arrival_rate=0.0, prompt_lens=(prompt_len,),
        max_new_tokens=gen, vocab=cfg.vocab, seed=1)

    # -- phase 1: uninterrupted truth run (temperature>0: RNG is load-bearing)
    snap_dir = tempfile.mkdtemp(prefix="bench_recovery_")
    snapshotter = EngineSnapshotter(snap_dir, every=10 ** 9)  # manual saves
    eng = ContinuousBatchingEngine(
        model, params, policy, max_slots=slots, S_max=S_max,
        temperature=0.8, top_k=8, seed=0, snapshotter=snapshotter)
    truth = _tokens_by_rid(eng.run(reqs(), clock=lambda: 0.0))
    assert len(truth) == n_req

    # -- phase 2: same workload, preempted mid-stream, snapshot, restore,
    #    finish — token streams must match phase 1 bit-for-bit
    eng.reset(seed=0)
    preemption = PreemptionSignal()
    kill_at = eng.steps + max(2, gen // 3)
    faults = FaultPlan(preempt_at_step=kill_at, preemption=preemption)
    eng.faults = faults
    interrupted = eng.run(reqs(), clock=lambda: 0.0, preemption=preemption)
    done_before = len(interrupted)
    in_flight = int(eng.active.sum()) + len(eng.queue)
    assert preemption.triggered and in_flight > 0, \
        "preemption must land mid-workload (raise kill_at margin otherwise)"

    # crash-equivalent restore: wipe the engine, reload the forced snapshot
    eng.faults = None
    eng.reset(seed=0)
    t0 = time.perf_counter()
    assert snapshotter.restore_into(eng, now=0.0)
    restore_s = time.perf_counter() - t0
    _drain(eng)
    resumed = _tokens_by_rid(eng.completions)
    lost = {rid for rid in truth
            if truth[rid] != resumed.get(rid)}
    assert not lost, f"token loss / divergence after resume: rids {sorted(lost)}"
    emit("recovery_restore", restore_s * 1e6,
         f"zero_token_loss=True resumed_in_flight={in_flight} "
         f"done_before_kill={done_before}")

    # -- phase 3: healthy vs degraded throughput
    def timed_run(engine, n_steps: int) -> float:
        t0 = time.perf_counter()
        for _ in range(n_steps):
            engine.step(now=0.0)
        return (time.perf_counter() - t0) / n_steps * 1e6

    steps = 8 if smoke else 16
    base_pol = get_precision_policy("p8-packed", base=policy)
    # full-budget requests: no slot may evict mid-timing (a shrinking grid
    # would make the healthy/degraded step times incomparable)
    reqs3 = lambda: poisson_requests(  # noqa: E731
        n_req, arrival_rate=0.0, prompt_lens=(prompt_len,),
        max_new_tokens=gen + 30, vocab=cfg.vocab, seed=1)
    healthy = ContinuousBatchingEngine(
        model, params, base_pol, max_slots=slots, S_max=S_max, seed=0)
    for r in reqs3():
        healthy.submit(r)
    healthy.admit()
    healthy.step(now=0.0)        # warm the decode executable
    healthy_us = timed_run(healthy, steps)

    watcher = NumericsWatcher(policy=base_pol, every=2)
    dog = DegradationController(watcher)
    # inject on a PROBED step (cadence 2): the engine injects before the
    # decode, so that step's probe records the NaN — one step later the
    # quarantine has already scrubbed the slot and the probe would see zeros
    faults = FaultPlan(nar_at_step=4, nar_slot=0, nar_count=4)
    degraded = ContinuousBatchingEngine(
        model, params, base_pol, max_slots=slots, S_max=S_max, seed=0,
        numerics=watcher, faults=faults, watchdog=dog, check_every_probes=2)
    for r in reqs3():
        degraded.submit(r)
    degraded.admit()
    for _ in range(8):           # inject, quarantine, step the ladder
        degraded.step(now=0.0)
    assert dog.events, "NaR injection did not step the precision ladder"
    assert any(c.finish_reason == "numerics" for c in degraded.completions), \
        "the poisoned slot did not quarantine"
    survivors = int(degraded.active.sum())
    assert survivors > 0, "degradation killed unaffected slots"
    # the gate measures the *precision ladder's* cost, not probe overhead
    # (bench_obs_overhead owns that): stretch the probe cadence past the
    # timing window so both engines run plain decode steps
    watcher.every = 10 ** 9
    degraded.step(now=0.0)       # warm the re-jitted (post-ladder) executable
    degraded_us = timed_run(degraded, steps)
    slowdown = degraded_us / healthy_us
    emit("recovery_healthy_step", healthy_us, f"slots={slots}")
    emit("recovery_degraded_step", degraded_us,
         f"survivors={survivors} ladder_steps={len(dog.events)} "
         f"slowdown={slowdown:.2f}x")
    assert slowdown <= MAX_DEGRADED_SLOWDOWN, (
        f"degraded-mode decode {slowdown:.2f}x slower than healthy "
        f"(gate {MAX_DEGRADED_SLOWDOWN}x)")
    snapshotter.close()


if __name__ == "__main__":
    run(smoke=True)
