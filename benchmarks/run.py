"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig1d,table4,...]

Output format: ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

BENCHES = {
    "fig1d": "benchmarks.bench_fig1d_accuracy",        # Fig. 1(d) accuracy
    "table3": "benchmarks.bench_table3_fpu_variants",  # Table III / Fig. 4
    "table4": "benchmarks.bench_table4_gemm",          # Table IV GEMM + memory
    "gemv_softmax": "benchmarks.bench_gemv_softmax",   # §IV-C
    "table2": "benchmarks.bench_table2_features",      # Table II SOTA baselines
    "collectives": "benchmarks.bench_collectives",     # beyond-paper
    "quire": "benchmarks.bench_quire_accuracy",        # beyond-paper: exact acc
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(BENCHES))
    args = ap.parse_args(argv)
    names = args.only.split(",") if args.only else list(BENCHES)

    print("name,us_per_call,derived")
    failures = []
    for name in names:
        mod_name = BENCHES[name]
        t0 = time.time()
        try:
            mod = __import__(mod_name, fromlist=["run"])
            mod.run()
            print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
        except Exception:
            failures.append(name)
            print(f"# {name} FAILED:", file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(f"benchmarks failed: {failures}")


if __name__ == "__main__":
    main()
