"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig1d,table4,...]
                                            [--smoke] [--json-dir DIR]

Output format: ``name,us_per_call,derived`` CSV rows on stdout, plus one
machine-readable ``BENCH_<name>.json`` per benchmark in ``--json-dir``
(default: the current directory) — the perf trajectory record: whether a PR
regressed throughput is answerable by diffing these files across commits.

``--smoke`` runs each benchmark at reduced sizes/iterations (passed through
to modules whose ``run`` accepts a ``smoke`` kwarg) — the CI configuration
that keeps every perf path (LUT codec, fused epilogue, quire GEMM) executed
on every PR.
"""
from __future__ import annotations

import argparse
import inspect
import json
import os
import sys
import time
import traceback

import jax

from benchmarks.common import drain_rows

# Precision schedules each bench's run() exercises end to end, as
# (arch, policy-spec) pairs.  Under --smoke these are pre-audited with the
# repro.analysis jaxpr auditor before the bench is timed: a schedule that no
# longer lowers cleanly (float dot_general at a quire-declared site, raw
# code-tensor arithmetic, an all-dead rule list) fails the bench up front
# instead of spending its timing budget measuring broken numerics.
POLICY_AUDIT = {
    "calibration": (("phi3-mini-3.8b", "p8-weights"),
                    ("phi3-mini-3.8b", "p8-packed")),
    "recovery": (("yi-34b", "p8-packed"),),
}

BENCHES = {
    "fig1d": "benchmarks.bench_fig1d_accuracy",        # Fig. 1(d) accuracy
    "table3": "benchmarks.bench_table3_fpu_variants",  # Table III / Fig. 4
    "table4": "benchmarks.bench_table4_gemm",          # Table IV GEMM + memory
    "gemv_softmax": "benchmarks.bench_gemv_softmax",   # §IV-C
    "table2": "benchmarks.bench_table2_features",      # Table II SOTA baselines
    "collectives": "benchmarks.bench_collectives",     # beyond-paper
    "quire": "benchmarks.bench_quire_accuracy",        # beyond-paper: exact acc
    "codec": "benchmarks.bench_codec",                 # LUT vs bit-pipeline
    "epilogue": "benchmarks.bench_epilogue_fusion",    # fused vs chained layer
    "mixed": "benchmarks.bench_mixed_gemm",            # packed/mixed precision
    "serving": "benchmarks.bench_serving",             # engine + attn dispatch
    "calibration": "benchmarks.bench_calibration",     # dynamic-es calibration
    "obs_overhead": "benchmarks.bench_obs_overhead",   # §12 observability cost
    "train_obs": "benchmarks.bench_train_obs_overhead",  # §16 telemetry cost
    "recovery": "benchmarks.bench_recovery",           # §13 fault tolerance
    "prefix_cache": "benchmarks.bench_prefix_cache",   # §14 paged prefix KV
}


def _preaudit(name: str) -> list:
    """Audit the bench's declared (arch, policy) pairs; return error findings."""
    from repro.analysis.jaxpr_audit import audit_model
    from repro.core.policy import get_precision_policy

    errors = []
    for arch, spec in POLICY_AUDIT.get(name, ()):
        findings = audit_model(arch, get_precision_policy(spec))
        errors += [f for f in findings if f.severity == "error"]
    return errors


def _call_run(mod, smoke: bool):
    """Invoke mod.run, passing smoke= only to modules that accept it."""
    if "smoke" in inspect.signature(mod.run).parameters:
        return mod.run(smoke=smoke)
    return mod.run()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(BENCHES))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes/iters (CI per-PR perf-path coverage)")
    ap.add_argument("--json-dir", default=".",
                    help="directory for BENCH_<name>.json results ('' = none)")
    args = ap.parse_args(argv)
    names = args.only.split(",") if args.only else list(BENCHES)

    print("name,us_per_call,derived")
    failures = []
    for name in names:
        mod_name = BENCHES[name]
        t0 = time.perf_counter()
        drain_rows()  # isolate each benchmark's rows
        ok = True
        try:
            if args.smoke and name in POLICY_AUDIT:
                bad = _preaudit(name)
                if bad:
                    for f in bad:
                        print(f"# {name} policy audit: {f.format()}",
                              file=sys.stderr)
                    raise RuntimeError(
                        f"{name}: {len(bad)} numerics-audit error(s) in its "
                        "precision schedule — not timing a broken lowering")
                print(f"# {name} policy audit clean "
                      f"({time.perf_counter() - t0:.1f}s)", file=sys.stderr)
            mod = __import__(mod_name, fromlist=["run"])
            _call_run(mod, args.smoke)
            print(f"# {name} done in {time.perf_counter() - t0:.1f}s", file=sys.stderr)
        except Exception:
            ok = False
            failures.append(name)
            print(f"# {name} FAILED:", file=sys.stderr)
            traceback.print_exc()
        if args.json_dir:
            os.makedirs(args.json_dir, exist_ok=True)
            path = os.path.join(args.json_dir, f"BENCH_{name}.json")
            with open(path, "w") as f:
                json.dump({
                    "bench": name,
                    "ok": ok,
                    "smoke": args.smoke,
                    "backend": jax.default_backend(),
                    # the regression gate only compares same-jax runs:
                    # XLA fusion changes shift accuracy metrics
                    # deterministically across versions (DESIGN.md §8 note)
                    "jax": jax.__version__,
                    "elapsed_s": round(time.perf_counter() - t0, 2),
                    "rows": drain_rows(),
                }, f, indent=1)
            print(f"# wrote {path}", file=sys.stderr)
    if failures:
        sys.exit(f"benchmarks failed: {failures}")


if __name__ == "__main__":
    main()
