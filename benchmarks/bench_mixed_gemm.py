"""Mixed-precision GEMM study — the paper's Table-IV packed/multi-precision
throughput trend, reproduced at the memory system.

Four weight configurations per (M, K, N), activations held at p16:

  * ``uniform-p16``  — widen-everything baseline: p16 acts x p16 weights
  * ``mixed-p8w``    — p16 acts x p8 weights (independent es per operand)
  * ``packed-p8w``   — p16 acts x packed-p8 weights: two codes per uint16
                       lane (core/pack.py), half the weight words moved
  * ``widen-first``  — the [7]-style baseline for the packed case: each
                       conversion is its *own compiled op* (the analogue of
                       [7]'s separate conversion instructions, same
                       construction as bench_epilogue_fusion's chained
                       baseline): decode A, decode+widen B into a
                       materialized f32 tensor, then a separate matmul op

Emitted per case: wall time, an analytic operand-bytes model (the actual
mechanism behind the paper's 2.54x — conversion/widening round trips), and
the accuracy delta vs the f32 GEMM of the unquantized operands.  Smoke mode
(CI) asserts the packed-p8 path moves >= 1.8x fewer operand bytes than
uniform-p16 and measures faster than the widen-first baseline.

Also swept: the es grid for the mixed pair (dynamic exponent size is *data*
— one compiled program serves every (es_a, es_b) pair, DESIGN.md §2).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

import time

from benchmarks.common import emit
from repro.core import F32, P8_0, P16_1, pack_p8, posit_encode
from repro.core.dot import posit_dot
from repro.core.lut import decode_with_impl
from repro.core.pack import packed_decode_p8
from repro.core.pcsr import OperandSlots as OS

SIZES = ((8, 1024, 1024), (64, 1024, 1024), (256, 1024, 1024))
SMOKE_SIZES = ((8, 512, 512),)  # CI per-PR configuration
ROUNDS = 21  # interleaved timing rounds per size


def _interleaved_min_us(cases: dict) -> dict:
    """Per-case best wall time, measured round-robin.

    All cases are timed within the *same* round before any case repeats, so
    scheduler/neighbor load perturbs every case alike and the cross-case
    ratios stay honest even on throttled machines (same construction as
    bench_epilogue_fusion's paired rounds); min over rounds then discards
    the noise floor (see common.time_fn).
    """
    for fn, a, b in cases.values():
        for _ in range(2):
            jax.block_until_ready(fn(a, b))
    best = {label: float("inf") for label in cases}
    for _ in range(ROUNDS):
        for label, (fn, a, b) in cases.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn(a, b))
            dt = time.perf_counter() - t0
            best[label] = min(best[label], dt * 1e6)
    return best


def _operands(m, k, n, seed=0):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(0, 1, (m, k)).astype(np.float32))
    b = jnp.asarray(rng.normal(0, 1, (k, n)).astype(np.float32))
    return a, b


def _operand_bytes(m, k, n, a_bytes, b_bytes, widen_first=False) -> int:
    """HBM operand-traffic model: A in + B in (+ widen-first's decode round
    trip on B: read codes, write f32, read f32 into the matmul)."""
    total = m * k * a_bytes + k * n * b_bytes
    if widen_first:
        total += k * n * (4 + 4)
    return total


def _rel_err(y, ref) -> float:
    num = float(jnp.linalg.norm((y - ref).astype(jnp.float32)))
    den = float(jnp.linalg.norm(ref.astype(jnp.float32))) or 1.0
    return num / den


def _size_cases(a16, b16, b8, b8p):
    """label -> (a operand, b operand, slots | None for the widen-first
    chain, A bytes/elt, B bytes/elt, widen-first round trips)."""
    packed = OS(rs1=P16_1, rs2=P8_0, rd=F32, rs2_packed=True)
    return {
        "uniform-p16": (a16, b16, OS(rs1=P16_1, rs2=P16_1, rd=F32), 2, 2, False),
        "mixed-p8w": (a16, b8, OS(rs1=P16_1, rs2=P8_0, rd=F32), 2, 1, False),
        "packed-p8w": (a16, b8p, packed, 2, 1, False),
        "widen-first": (a16, b8p, None, 2, 1, True),
    }


def run(smoke: bool = False):
    sizes = SMOKE_SIZES if smoke else SIZES
    for m, k, n in sizes:
        a, b = _operands(m, k, n)
        ref = jnp.matmul(a, b, preferred_element_type=jnp.float32)
        a16 = posit_encode(a, 16, 1)
        b16 = posit_encode(b, 16, 1)
        b8 = posit_encode(b, 8, 0)
        b8p = pack_p8(b8)

        # [7]-style widen-first chain: every conversion its own compiled op
        # (separate dispatch + full materialization of the widened tensors),
        # then a separate matmul — the two-extra-instructions dataflow that
        # costs [7] its 2.54x in the paper
        dec_a = jax.jit(lambda x: decode_with_impl(x, 16, 1, "auto"))
        dec_b = jax.jit(lambda y: packed_decode_p8(y, 0))
        mm = jax.jit(lambda af, bf: jnp.matmul(af, bf, preferred_element_type=jnp.float32))

        def widen_first(ac, bc):
            return mm(dec_a(ac), dec_b(bc))

        cases = _size_cases(a16, b16, b8, b8p)
        timed = {}
        bytes_moved = {}
        errs = {}
        for label, case in cases.items():
            ac, bc, slots, ab, bb, widen = case
            if slots is None:
                fn = widen_first
            else:
                fn = jax.jit(lambda x, y, s=slots: posit_dot(x, y, s))
            timed[label] = (fn, ac, bc)
            bytes_moved[label] = _operand_bytes(m, k, n, ab, bb, widen)
            errs[label] = _rel_err(fn(ac, bc), ref)
        us = _interleaved_min_us(timed)
        for label in cases:
            mflops = 2 * m * k * n / us[label]
            derived = f"{mflops:.1f}MFLOPS bytes={bytes_moved[label]} rel_err={errs[label]:.5f}"
            emit(f"mixed/gemm{m}x{k}x{n}/{label}", us[label], derived)

        byte_ratio = bytes_moved["uniform-p16"] / bytes_moved["packed-p8w"]
        widen_ratio = us["widen-first"] / us["packed-p8w"]
        name = f"mixed/gemm{m}x{k}x{n}"
        emit(f"{name}/packed_vs_uniform_bytes", us["packed-p8w"], f"bytes_ratio={byte_ratio:.2f}x")
        emit(f"{name}/packed_vs_widen_first", us["packed-p8w"], f"measured={widen_ratio:.2f}x")
        # the paper's packed-lane claims hold in the weight/conversion-
        # dominated regime (small M — the serving/decode shape, the CI smoke
        # configuration, and Table IV's own sizes): packed p8 moves >= 1.8x
        # fewer operand bytes than uniform-p16 (the byte-model ratio
        # (2M + 2N) / (2M + N) reaches 1.8 exactly when N >= 8M) and the
        # fused packed path beats the widen-first conversion-op baseline.
        # At large M the GEMM goes compute-bound, the activation term
        # dilutes both effects, and the rows are reported unasserted.
        if 8 * m <= n:
            msg = f"packed-p8 must move >=1.8x fewer operand bytes, got {byte_ratio:.2f}x at {name}"
            assert byte_ratio >= 1.8, msg
            msg = f"packed-p8 fused must beat widen-first, got {widen_ratio:.2f}x at {name}"
            assert widen_ratio > 1.0, msg

    # es-pair sweep on the mixed case: accuracy across the dynamic-es grid,
    # one compiled program for all pairs (es is a traced scalar)
    m, k, n = (8, 256, 256) if smoke else (32, 512, 512)
    a, b = _operands(m, k, n, seed=1)
    ref = jnp.matmul(a, b, preferred_element_type=jnp.float32)
    slots = OS(rs1=P16_1, rs2=P8_0, rd=F32)
    fn = jax.jit(lambda x, y, ea, eb: posit_dot(x, y, slots, es_a=ea, es_b=eb))
    for es_a in (0, 1, 2):
        for es_b in (0, 1, 2):
            ac = posit_encode(a, 16, es_a)
            bc = posit_encode(b, 8, es_b)
            err = _rel_err(fn(ac, bc, es_a, es_b), ref)
            emit(f"mixed/es_pair/p16_{es_a}xp8_{es_b}", 0.0, f"rel_err={err:.5f}")
    return True


if __name__ == "__main__":
    run()
