"""Quire dataflow: exact-vs-rounded accumulation ULP error + throughput.

Quantifies what the quire buys (and costs) against the paper's codec+FPU
fused path on the same posit GEMM:

  * accuracy — ULP distance (signed posit-code space: posit codes are
    value-ordered, so |signed(a) - signed(b)| is exactly "roundings apart")
    of each dataflow vs the Fraction-arithmetic exact-sum oracle. The quire
    column must read 0 by construction; the fused column shows the f32
    double-rounding accumulation error the quire removes.
  * throughput — us/call of the quire GEMM (integer VPU datapath) vs the
    fused GEMM (MXU datapath) on identical shapes. The quire is expected to
    be much slower: it exists for the reductions where exactness matters
    (losses, norms, long-K dots at p8/p16), not for bulk FLOPs.
"""
from __future__ import annotations

from fractions import Fraction

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import ref_codec
from repro.core.codec import posit_encode
from repro.core.dot import posit_dot
from repro.core.pcsr import OperandSlots
from repro.core.quire import quire_matmul
from repro.core.types import P8_0, P16_1, PositFmt

# accuracy problem: M independent K-long dot products
M_ACC, K_ACC = 48, 512
# throughput problem (small: the quire path is a software accumulator)
M_T, K_T, N_T = 32, 256, 32


def _signed(codes: np.ndarray, nbits: int) -> np.ndarray:
    s = codes.astype(np.int64)
    return np.where(s >= 1 << (nbits - 1), s - (1 << nbits), s)


def _ulp(a: np.ndarray, b: np.ndarray, nbits: int) -> np.ndarray:
    return np.abs(_signed(a, nbits) - _signed(b, nbits))


def _exact_codes(a: np.ndarray, b: np.ndarray, n: int, es: int) -> np.ndarray:
    """Fraction oracle for each row-dot of a (M,K) x b (K,)."""
    out = np.empty(a.shape[0], dtype=a.dtype)
    vb = [ref_codec.ref_decode(int(y), n, es) for y in b]
    for i in range(a.shape[0]):
        acc = Fraction(0)
        for x, v in zip(a[i], vb):
            acc += ref_codec.ref_decode(int(x), n, es) * v
        out[i] = ref_codec.ref_encode_exact(acc, n, es)
    return out


def _accuracy(fmt: PositFmt) -> None:
    n, es = fmt.nbits, fmt.es
    rng = np.random.default_rng(0)
    # Cancellation-heavy dot: large mirrored pairs (posit negation is exact,
    # so each pair cancels exactly in the quire) swamping a small O(1) signal
    # in the first columns of each half. The f32 partial sums run ~big^2 *
    # sqrt(K) while the true result is O(1), so rounded accumulation error
    # lands above the posit ulp — the regime the quire exists for.
    big = min(fmt.maxpos / 8, 1024.0)  # keep f32 partials finite for p16
    half = K_ACC // 2
    av = rng.normal(0, big, (M_ACC, K_ACC)).astype(np.float32)
    bv = rng.normal(0, big, K_ACC).astype(np.float32)
    av[:, half:] = av[:, :half]
    bv[half:] = -bv[:half]
    av[:, :8] = rng.normal(0, 1, (M_ACC, 8))
    bv[:8] = rng.normal(0, 1, 8)
    av[:, half:half + 8] = rng.normal(0, 1, (M_ACC, 8))
    bv[half:half + 8] = rng.normal(0, 1, 8)
    a = np.asarray(posit_encode(jnp.asarray(av), n, es))
    b = np.asarray(posit_encode(jnp.asarray(bv), n, es))
    want = _exact_codes(a, b, n, es)

    slots = OperandSlots.uniform(fmt)
    fused = np.asarray(posit_dot(jnp.asarray(a), jnp.asarray(b[:, None]),
                                 slots, impl="fused"))[:, 0]
    quire = np.asarray(quire_matmul(jnp.asarray(a), jnp.asarray(b[:, None]),
                                    fmt))[:, 0]
    uf, uq = _ulp(fused, want, n), _ulp(quire, want, n)
    emit(f"quire/acc_{fmt.name}", 0.0,
         f"K={K_ACC} fused_mean_ulp={uf.mean():.3f} fused_max_ulp={uf.max()} "
         f"quire_mean_ulp={uq.mean():.3f} quire_max_ulp={uq.max()} "
         f"quire_exact={bool((quire == want).all())}")
    assert (quire == want).all(), "quire dataflow must be bit-exact"


def _throughput(fmt: PositFmt) -> None:
    n, es = fmt.nbits, fmt.es
    rng = np.random.default_rng(1)
    a = posit_encode(jnp.asarray(rng.normal(0, 1, (M_T, K_T)).astype(np.float32)), n, es)
    b = posit_encode(jnp.asarray(rng.normal(0, 1, (K_T, N_T)).astype(np.float32)), n, es)
    slots = OperandSlots.uniform(fmt)

    fused = jax.jit(lambda a, b: posit_dot(a, b, slots, impl="fused"))
    quire = jax.jit(lambda a, b: quire_matmul(a, b, fmt))
    us_f = time_fn(fused, a, b)
    us_q = time_fn(quire, a, b)
    emit(f"quire/gemm_{fmt.name}_fused", us_f, f"shape={M_T}x{K_T}x{N_T}")
    emit(f"quire/gemm_{fmt.name}_quire", us_q,
         f"shape={M_T}x{K_T}x{N_T} slowdown_x{us_q / us_f:.1f} (exact)")


def run():
    for fmt in (P8_0, P16_1):
        _accuracy(fmt)
        _throughput(fmt)
    return True


if __name__ == "__main__":
    run()
