"""Benchmark regression gate — diff two directories of BENCH_<name>.json.

    python -m benchmarks.compare --old prev-bench --new bench-results \
        [--threshold 0.15] [--min-us 50] [--summary out.md] [--allow-missing]

CI downloads the previous main-branch artifact into ``--old`` and fails the
job when this run regresses:

* **throughput**: a row's ``us_per_call`` grew by more than ``--threshold``
  (relative; rows under ``--min-us`` are skipped as timer noise),
* **accuracy**: any lower-is-better metric parsed from the ``derived``
  column (``rel_err=`` / ``*ulp=`` / ``mse=`` tokens) grew at all (beyond
  float-print noise).

Rows are matched by (bench, row name); old rows that disappeared are
reported but don't fail (benchmarks evolve); new rows are listed as
additions.  Runs are only compared when backend and smoke-mode match.
The delta table is markdown — ``--summary`` appends it to a file
($GITHUB_STEP_SUMMARY in CI).
"""

from __future__ import annotations

import argparse
import glob
import json
import math
import os
import re
import sys

_ACC_KEY = re.compile(r"(\w*(?:ulp|err|mse)\w*)=([-+0-9.e]+|nan|[-+]?inf)", re.IGNORECASE)
_ACC_EPS = 1e-9  # float-print noise floor for accuracy comparisons
# runs only compare like-for-like: jax version drift shifts accuracy metrics
# deterministically (XLA fusion), which must rebaseline, not fail the gate
_META_KEYS = ("ok", "smoke", "backend", "jax")


def load_dir(path: str) -> dict:
    """{bench_name: {"meta": {...}, "rows": {row_name: row}}} for a dir."""
    out = {}
    for fn in sorted(glob.glob(os.path.join(path, "BENCH_*.json"))):
        with open(fn) as f:
            rec = json.load(f)
        name = rec.get("bench") or os.path.basename(fn)[6:-5]
        out[name] = {
            "meta": {k: rec.get(k) for k in _META_KEYS},
            "rows": {r["name"]: r for r in rec.get("rows", [])},
        }
    return out


def accuracy_metrics(row: dict) -> dict:
    """Lower-is-better metrics parsed from the derived column."""
    return {k: float(v) for k, v in _ACC_KEY.findall(row.get("derived", ""))}


def _table_row(bench, row, metric, old, new, delta, status) -> dict:
    return {
        "bench": bench,
        "row": row,
        "metric": metric,
        "old": old,
        "new": new,
        "delta": delta,
        "status": status,
    }


def compare(old: dict, new: dict, *, threshold: float = 0.15, min_us: float = 50.0):
    """Returns (table_rows, regressions); each table row is a dict."""
    rows, regressions = [], []
    for bench, nrec in sorted(new.items()):
        orec = old.get(bench)
        if orec is None:
            rows.append(_table_row(bench, "(new benchmark)", "-", "-", "-", "-", "added"))
            continue
        if orec["meta"] != nrec["meta"]:
            o_meta, n_meta = str(orec["meta"]), str(nrec["meta"])
            rows.append(_table_row(bench, "(config mismatch)", "-", o_meta, n_meta, "-", "skipped"))
            continue
        for name, nrow in nrec["rows"].items():
            orow = orec["rows"].get(name)
            if orow is None:
                rows.append(_table_row(bench, name, "-", "-", "-", "-", "added"))
                continue
            o_us, n_us = orow.get("us_per_call", 0), nrow.get("us_per_call", 0)
            # gate rows where EITHER side crosses the noise floor — keying on
            # the old value alone would let a 40us -> 400us blow-up escape
            if o_us > 0 and n_us > 0 and max(o_us, n_us) >= min_us:
                rel = (n_us - o_us) / o_us
                status = "REGRESSION" if rel > threshold else "ok"
                old_s, new_s = f"{o_us:.1f}", f"{n_us:.1f}"
                row = _table_row(bench, name, "us_per_call", old_s, new_s, f"{rel:+.1%}", status)
                rows.append(row)
                if status != "ok":
                    regressions.append(row)
            o_acc, n_acc = accuracy_metrics(orow), accuracy_metrics(nrow)
            for key in sorted(set(o_acc) | set(n_acc)):
                ov = o_acc.get(key, float("nan"))
                nv = n_acc.get(key, float("nan"))
                # a metric going NaN (or vanishing from the row) IS a
                # regression; NaN comparisons are False, so test explicitly
                worse = (math.isnan(nv) and not math.isnan(ov)) or (
                    nv > ov + _ACC_EPS + abs(ov) * 1e-6
                )
                if worse:
                    delta = f"{nv - ov:+g}"
                    row = _table_row(bench, name, key, f"{ov:g}", f"{nv:g}", delta, "REGRESSION")
                    rows.append(row)
                    regressions.append(row)
        for name in orec["rows"]:
            if name not in nrec["rows"]:
                rows.append(_table_row(bench, name, "-", "-", "-", "-", "removed"))
    return rows, regressions


def to_markdown(rows: list, regressions: list) -> str:
    lines = ["## Benchmark delta (old = previous main run)", ""]
    if not rows:
        lines.append("no comparable rows")
    else:
        lines.append("| bench | row | metric | old | new | Δ | status |")
        lines.append("|---|---|---|---|---|---|---|")
        for r in rows:
            cells = (r["bench"], r["row"], r["metric"], r["old"], r["new"], r["delta"], r["status"])
            lines.append("| " + " | ".join(cells) + " |")
    lines.append("")
    verdict = f"**{len(regressions)} regression(s)**" if regressions else "**no regressions**"
    lines.append(verdict)
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--old", required=True, help="previous-run BENCH_*.json dir")
    ap.add_argument("--new", required=True, help="this-run BENCH_*.json dir")
    ap.add_argument("--threshold", type=float, default=0.15, help="relative us growth that fails")
    ap.add_argument("--min-us", type=float, default=50.0, help="skip faster rows (timer noise)")
    ap.add_argument("--summary", default=None, help="append the markdown table to this file")
    ap.add_argument("--allow-missing", action="store_true", help="exit 0 when --old is empty")
    args = ap.parse_args(argv)

    old = load_dir(args.old) if os.path.isdir(args.old) else {}
    new = load_dir(args.new)
    if not old:
        msg = f"no previous benchmark data under {args.old!r}"
        print(msg)
        if args.summary:
            with open(args.summary, "a") as f:
                f.write(f"## Benchmark delta\n\n{msg} — gate skipped\n")
        return 0 if args.allow_missing else 1
    if not new:
        print(f"no benchmark data under {args.new!r}", file=sys.stderr)
        return 1

    rows, regressions = compare(old, new, threshold=args.threshold, min_us=args.min_us)
    md = to_markdown(rows, regressions)
    print(md)
    if args.summary:
        with open(args.summary, "a") as f:
            f.write(md)
    if regressions:
        print(f"FAIL: {len(regressions)} benchmark regression(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
