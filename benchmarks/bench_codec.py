"""Codec micro-benchmark: table-driven fast paths vs the bit pipeline.

The paper's codec cost is two conversion ops around the FPU; our two
implementations of those ops are the ~40-op integer bit pipeline
(Mosaic-friendly) and the LUT/bucketize path (gather-friendly backends,
repro.core.lut).  This measures both on p8/p16 decode and p8 encode, plus the
p16 two-level split-table decode, and reports the speedup ratios — the
numbers behind the ``codec_impl="auto"`` policy default.

Results land in BENCH_codec.json via benchmarks.run.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.codec import posit_decode, posit_encode
from repro.core.lut import lut_decode_p8, lut_decode_p16, lut_encode_p8


def run(smoke: bool = False):
    n_elems = 1 << 16 if smoke else 1 << 20
    iters = 5 if smoke else 10
    rng = np.random.default_rng(0)
    es = 1

    c8 = jnp.asarray(rng.integers(0, 256, n_elems).astype(np.uint8))
    c16 = jnp.asarray(rng.integers(0, 65536, n_elems).astype(np.uint16))
    x = jnp.asarray(rng.normal(0, 4, n_elems).astype(np.float32))

    pairs = {
        "decode_p8": (
            jax.jit(lambda c: posit_decode(c, 8, es)),
            jax.jit(lambda c: lut_decode_p8(c, es)), c8),
        "decode_p16": (
            jax.jit(lambda c: posit_decode(c, 16, es)),
            jax.jit(lambda c: lut_decode_p16(c, es)), c16),
        "encode_p8": (
            jax.jit(lambda v: posit_encode(v, 8, es)),
            jax.jit(lambda v: lut_encode_p8(v, es)), x),
    }
    for name, (bits_fn, lut_fn, arg) in pairs.items():
        us_bits = time_fn(bits_fn, arg, iters=iters)
        us_lut = time_fn(lut_fn, arg, iters=iters)
        melem_bits = n_elems / us_bits
        melem_lut = n_elems / us_lut
        emit(f"codec/{name}/bits", us_bits, f"{melem_bits:.1f}Melem/s")
        emit(f"codec/{name}/lut", us_lut, f"{melem_lut:.1f}Melem/s")
        emit(f"codec/{name}/lut_speedup", us_lut,
             f"{us_bits / us_lut:.2f}x_vs_bits")
    return True


if __name__ == "__main__":
    run()
