"""Tests: posit_dot fused/unfused dataflows + pcsr operand-slot semantics."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    BF16, F32, OperandSlots, P8_0, P16_1, TransPolicy,
    posit_decode, posit_dot, posit_encode, posit_gemv, posit_softmax,
)
from repro.core.pcsr import OperandSlots as OS


def _mk(m, k, n, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.normal(0, 1, (m, k)).astype(np.float32)
    b = rng.normal(0, 1, (k, n)).astype(np.float32)
    return jnp.asarray(a), jnp.asarray(b)


def test_float_slots_bypass_codec():
    """pfmt=float must be bit-identical to a plain matmul (IEEE compatibility)."""
    a, b = _mk(16, 32, 8)
    y = posit_dot(a, b, OS.uniform(F32))
    want = jnp.matmul(a, b, preferred_element_type=jnp.float32)
    assert (np.asarray(y) == np.asarray(want)).all()


@pytest.mark.parametrize("fmt", [P8_0, P16_1])
def test_fused_equals_unfused_numerics(fmt):
    """[7]'s dataflow and ours differ in *performance*, never in value."""
    a, b = _mk(12, 24, 10, seed=1)
    ac = posit_encode(a, fmt.nbits, fmt.es)
    bc = posit_encode(b, fmt.nbits, fmt.es)
    slots = OS(rs1=fmt, rs2=fmt, rd=fmt)
    y_f = posit_dot(ac, bc, slots, impl="fused")
    y_u = posit_dot(ac, bc, slots, impl="unfused")
    assert (np.asarray(y_f) == np.asarray(y_u)).all()


def test_posit_dot_matches_manual_pipeline():
    a, b = _mk(8, 16, 8, seed=2)
    ac = posit_encode(a, 16, 1)
    bc = posit_encode(b, 16, 1)
    y = posit_dot(ac, bc, OS(rs1=P16_1, rs2=P16_1, rd=F32))
    want = jnp.matmul(
        posit_decode(ac, 16, 1), posit_decode(bc, 16, 1),
        preferred_element_type=jnp.float32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=0, atol=0)


def test_mixed_format_gemm():
    """posit A x float B — per-operand pfmt (the paper's inter-format ops)."""
    a, b = _mk(8, 16, 8, seed=3)
    ac = posit_encode(a, 8, 0)
    y = posit_dot(ac, b, OS(rs1=P8_0, rs2=F32, rd=F32))
    want = jnp.matmul(
        posit_decode(ac, 8, 0).astype(jnp.float32), b,
        preferred_element_type=jnp.float32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=1e-6)


def test_posit_output_encoding():
    a, b = _mk(8, 16, 8, seed=4)
    y = posit_dot(a, b, OS(rs1=F32, rs2=F32, rd=P16_1))
    assert y.dtype == jnp.uint16
    want = posit_encode(jnp.matmul(a, b), 16, 1)
    assert (np.asarray(y) == np.asarray(want)).all()


def test_gemv_and_softmax():
    rng = np.random.default_rng(5)
    A = jnp.asarray(rng.normal(0, 1, (16, 16)).astype(np.float32))
    x = jnp.asarray(rng.normal(0, 1, (16,)).astype(np.float32))
    Ac = posit_encode(A, 8, 0)
    xc = posit_encode(x, 8, 0)
    y = posit_gemv(Ac, xc, OS(rs1=P8_0, rs2=P8_0, rd=F32))
    want = posit_decode(Ac, 8, 0) @ posit_decode(xc, 8, 0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=1e-6)

    logits = posit_encode(jnp.asarray(rng.normal(0, 2, (4, 128)).astype(np.float32)), 16, 1)
    sm = posit_softmax(logits, P16_1)
    vals = posit_decode(sm, 16, 1)
    np.testing.assert_allclose(np.asarray(vals.sum(-1)), 1.0, atol=0.02)


def test_pcsr_encode_bits_layout():
    slots = OperandSlots(rs1=P8_0, rs2=P16_1, rs3=F32, rd=P8_0)
    word = slots.encode_bits()
    assert word & 0b0001          # rs1 posit
    assert word & 0b0010          # rs2 posit
    assert not (word & 0b0100)    # rs3 float
    assert word & 0b1000          # rd posit
    assert (word >> 4) & 0b0010   # rs2 is 16-bit
    assert ((word >> (8 + 3)) & 0b111) == 1  # rs2 es == 1


def test_policy_from_names():
    p = TransPolicy.from_names(weights="p8_0", kv_cache="p8_0", compute_dtype="bf16")
    assert p.weights.nbits == 8 and p.kv_cache.es == 0 and p.gradients is None
    assert "weights=p8_0" in p.describe()
    with pytest.raises(KeyError):
        p.fmt_for("nonexistent_role")
