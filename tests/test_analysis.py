"""repro.analysis: lint rules, baselines, jaxpr hazards, quire contract.

Three layers of coverage:

* fixtures — every RA rule fires on the seeded-violation tree under
  ``tests/fixtures/analysis`` and is silenced by ``# repro: noqa``,
* the merged tree itself lints clean (the CI gate, asserted in-suite so a
  regression fails the fast tests too),
* jaxpr hazards — synthetic positives/negatives per hazard class, plus the
  ISSUE-9 acceptance sweep: every registry family audits clean under
  uniform-p16, and under a quire-dataflow base every quire-declared site
  lowers to quire dataflow (no float dot_general) with the seeded
  unquantized violation firing.
"""
import dataclasses
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (RULES, lint_repo, lint_source, load_baseline,
                            new_findings, save_baseline, stdout_kinds)
from repro.analysis.__main__ import main as analysis_main
from repro.analysis.jaxpr_audit import (DEFAULT_AUDIT_ARCHS,
                                        audit_closed_jaxpr, audit_model,
                                        audit_quire_sites, dead_rules)
from repro.configs import ARCH_IDS, get_arch
from repro.core.codec import posit_decode, posit_encode
from repro.core.policy import (LayerRule, PRECISION_PRESETS, PrecisionPolicy,
                               get_precision_policy)
from repro.models.layers import apply_linear, init_linear, quantize_linear
from repro.models.registry import build_model

ROOT = pathlib.Path(__file__).resolve().parents[1]
FIXROOT = ROOT / "tests" / "fixtures" / "analysis"

UNIFORM = PRECISION_PRESETS["uniform-p16"]
QUIRE_UNIFORM = dataclasses.replace(
    UNIFORM, base=dataclasses.replace(UNIFORM.base, dataflow="quire"))


# ------------------------------------------------------------- lint rules ----

def _fixture_findings():
    return lint_repo(str(FIXROOT))


def test_every_rule_fires_on_fixtures():
    fired = {f.rule for f in _fixture_findings() if not f.suppressed}
    assert fired == set(RULES), (
        f"rules registered but not proven by a fixture: {set(RULES) - fired}")


def test_noqa_suppresses_per_line():
    by_rule = {}
    for f in _fixture_findings():
        by_rule.setdefault(f.rule, []).append(f)
    # each of these rules has one deliberate noqa line in the fixtures
    for rule in ("RA001", "RA003", "RA004"):
        sup = [f for f in by_rule[rule] if f.suppressed]
        assert len(sup) == 1, (rule, [f.format() for f in by_rule[rule]])
    # suppressed findings never count as new
    assert all(f not in new_findings(by_rule[rule])
               for rule in ("RA001", "RA003", "RA004")
               for f in by_rule[rule] if f.suppressed)


def test_rule_path_scoping():
    # an RA004 pattern outside checkpoint/ does not fire
    src = "import numpy as np\n\ndef f(p):\n    np.savez(p)\n"
    assert lint_source(src, "src/repro/launch/other.py") == [] or all(
        f.rule != "RA004" for f in lint_source(src, "src/repro/launch/other.py"))
    assert any(f.rule == "RA004"
               for f in lint_source(src, "src/repro/checkpoint/other.py"))


def test_repo_tree_lints_clean():
    """The merged tree is the zero-finding state CI gates on."""
    assert new_findings(lint_repo(str(ROOT))) == []


def test_stdout_kinds_extraction():
    kinds = stdout_kinds(["src/repro/launch/bad_stdout.py"], root=str(FIXROOT))
    assert kinds == {"fixture/ok": "src/repro/launch/bad_stdout.py"}


def test_baseline_roundtrip(tmp_path):
    findings = [f for f in _fixture_findings() if not f.suppressed]
    assert findings
    bl = tmp_path / "baseline.json"
    save_baseline(str(bl), findings)
    fps = load_baseline(str(bl))
    assert fps == {f.fingerprint() for f in findings if f.severity == "error"}
    assert new_findings(findings, fps) == []
    with pytest.raises(ValueError):
        other = tmp_path / "not_baseline.json"
        other.write_text(json.dumps({"kind": "something/else"}))
        load_baseline(str(other))


def test_cli_fixture_gate_and_baseline(tmp_path):
    """The CI recipe end to end: fixtures fail, a written baseline passes."""
    assert analysis_main(["--root", str(FIXROOT)]) == 1
    bl = tmp_path / "bl.json"
    assert analysis_main(["--root", str(FIXROOT),
                          "--write-baseline", str(bl)]) == 0
    assert analysis_main(["--root", str(FIXROOT), "--baseline", str(bl)]) == 0
    report = tmp_path / "report.json"
    assert analysis_main(["--root", str(FIXROOT), "--json", str(report)]) == 1
    doc = json.loads(report.read_text())
    assert doc["kind"] == "repro/analysis-report" and doc["n_new"] > 0


# ------------------------------------------------------------ jaxpr audit ----

def test_jp001_raw_code_arithmetic():
    c = jax.make_jaxpr(lambda a, b: a + b)(
        jnp.zeros((4,), jnp.uint8), jnp.ones((4,), jnp.uint8))
    assert [f.rule for f in audit_closed_jaxpr(c)] == ["JP001"]

    # decode-style bitwise field extraction kills taint: no finding
    def dec(codes):
        return (codes.astype(jnp.uint32) & 0x7F).astype(jnp.float32) * 2.0
    c = jax.make_jaxpr(dec)(jnp.zeros((4,), jnp.uint8))
    assert audit_closed_jaxpr(c) == []

    # LUT-style gather indexed by codes produces clean values
    def lut(codes, table):
        return jnp.take(table, codes.astype(jnp.int32)) * 2.0
    c = jax.make_jaxpr(lut)(jnp.zeros((4,), jnp.uint8), jnp.ones((256,)))
    assert audit_closed_jaxpr(c) == []


def test_jp003_encode_decode_churn():
    pos = jax.make_jaxpr(
        lambda x: posit_decode(posit_encode(x, 16, 1), 16, 1))(
        jnp.ones((8,), jnp.float32))
    assert "JP003" in {f.rule for f in audit_closed_jaxpr(pos)}

    # the training-path STE is the deliberate exception
    def ste(w):
        wf = w.astype(jnp.float32)
        qw = posit_decode(posit_encode(wf, 16, 1), 16, 1)
        return w + jax.lax.stop_gradient(qw - wf)
    neg = jax.make_jaxpr(ste)(jnp.ones((8,), jnp.float32))
    assert audit_closed_jaxpr(neg) == []


def test_jp004_narrow_accumulator():
    pos = jax.make_jaxpr(
        lambda a, b: jnp.matmul(a.astype(jnp.bfloat16), b.astype(jnp.bfloat16))
    )(jnp.ones((4, 4)), jnp.ones((4, 4)))
    assert "JP004" in {f.rule for f in audit_closed_jaxpr(pos)}

    neg = jax.make_jaxpr(
        lambda a, b: jnp.matmul(a.astype(jnp.bfloat16), b.astype(jnp.bfloat16),
                                preferred_element_type=jnp.float32)
    )(jnp.ones((4, 4)), jnp.ones((4, 4)))
    assert audit_closed_jaxpr(neg) == []


def test_jp005_callback_in_serving_executable():
    def probed(x):
        jax.debug.callback(lambda v: None, x)
        return x * 2
    c = jax.make_jaxpr(probed)(jnp.ones((2,)))
    assert [f.rule for f in audit_closed_jaxpr(c, probed=False)] == ["JP005"]
    assert audit_closed_jaxpr(c, probed=True) == []


def test_jp005_training_executables():
    """§16 probed-twin contract: the plain train step audits callback-free,
    the telemetry twin (grad taps live) is exempt, and a leaked observer
    context around the plain step's trace is the seeded positive."""
    from repro.analysis.jaxpr_audit import audit_train, trace_train_step

    # negative: plain step clean, probed twin exempt — one call covers both
    assert audit_train("xlstm-125m", UNIFORM) == []

    # positive: tracing the plain step inside observing() bakes the §11
    # callbacks (including grad_tap cotangent hooks) into the steady-state
    # executable — JP005, attributed to a layer path via the marker keys
    fs = audit_closed_jaxpr(
        trace_train_step("xlstm-125m", UNIFORM, observed=True),
        trace="xlstm-125m:train", probed=False)
    assert fs and {f.rule for f in fs} == {"JP005"}
    assert any("/" in f.path for f in fs), [f.path for f in fs]


def test_jp006_dead_rules():
    cfg = get_arch("xlstm-125m").reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    # one dead rule among live non-catchall ones: a warning, never a gate
    mixed = PRECISION_PRESETS["attn-p16-mlp-p8"]
    pol = dataclasses.replace(
        mixed, rules=(LayerRule("*no_such_block*", mixed.base.weights),)
        + mixed.rules)
    fs = dead_rules(pol, params)
    assert fs and all(f.severity == "warn" for f in fs)
    assert any("no_such_block" in f.message for f in fs)
    # every non-catchall rule dead: the schedule is a no-op — an error
    pol = dataclasses.replace(
        UNIFORM, rules=(LayerRule("*typo_a*", UNIFORM.base.weights),
                        LayerRule("*typo_b*", UNIFORM.base.weights),
                        LayerRule("*", UNIFORM.base.weights)))
    fs = dead_rules(pol, params)
    assert len(fs) == 1 and fs[0].severity == "error"
    # presets over a real model carry no dead rules
    assert dead_rules(UNIFORM, params) == []


# ---------------------------------------------------------- quire contract ----

def test_quire_sites_clean_and_seeded_violation_fires():
    qf, n = audit_quire_sites("xlstm-125m", QUIRE_UNIFORM)
    assert n > 0 and qf == []
    # seeded violation: unquantized params at quire-declared sites degrade
    # to a float dot_general and must fire at every site
    qf, n = audit_quire_sites("xlstm-125m", QUIRE_UNIFORM, quantize=False)
    assert len(qf) == n > 0
    assert all(f.rule == "JP002" for f in qf)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_quire_contract_all_families(arch):
    """ISSUE-9 acceptance: under uniform-p16 with dataflow="quire", every
    registry family's quire-declared sites lower to quire dataflow — no
    float dot_general anywhere in the traced linear."""
    qf, n = audit_quire_sites(arch, QUIRE_UNIFORM)
    assert n > 0, f"{arch}: no quire-declared linear sites found"
    assert qf == [], [f.format() for f in qf]


def test_quire_linear_numerics():
    """The quire lowering computes the same linear (exactly-accumulated, so
    at least as close to the float reference as the fused path)."""
    key = jax.random.PRNGKey(3)
    p = init_linear(key, 32, 16)
    x = jax.random.normal(jax.random.PRNGKey(4), (4, 32), jnp.float32) * 0.5
    fmt = UNIFORM.base.weights
    q = quantize_linear(p, fmt)
    y_ref = x @ p["w"]
    y_fused = apply_linear(q, x, UNIFORM.base, path="t")
    y_quire = apply_linear(q, x, QUIRE_UNIFORM.base, path="t")
    err_fused = float(jnp.max(jnp.abs(y_fused - y_ref)))
    err_quire = float(jnp.max(jnp.abs(y_quire - y_ref)))
    # both paths see the same quantized operands; quire's exact accumulation
    # keeps it within the fused path's error envelope
    assert err_quire <= err_fused * 1.5 + 1e-3, (err_quire, err_fused)
    assert err_quire < 0.1


# ------------------------------------------------------ model-level audits ----

@pytest.mark.parametrize("arch", ["xlstm-125m"])
def test_model_audit_clean_fast(arch):
    assert audit_model(arch, UNIFORM) == []


@pytest.mark.slow
@pytest.mark.parametrize("arch", DEFAULT_AUDIT_ARCHS)
def test_model_audit_clean_per_family(arch):
    """One arch per registry family audits clean under uniform-p16, under
    p8-packed (dense rep only — packed lanes everywhere), and under a
    calibrated-artifact-style mixed policy."""
    assert audit_model(arch, UNIFORM) == []


@pytest.mark.slow
def test_model_audit_clean_p8_packed_and_mixed():
    assert audit_model("phi3-mini-3.8b", PRECISION_PRESETS["p8-packed"]) == []
    mixed = get_precision_policy("*attn*=p16_1,*mlp*=p8_0:packed,*=p16_1")
    errors = [f for f in audit_model("phi3-mini-3.8b", mixed)
              if f.severity == "error"]
    assert errors == []
