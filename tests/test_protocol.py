"""The stdout protocol is versioned documentation, not an accident.

Every JSON line the launch CLIs print is tagged with a ``"kind"`` key and
documented in the DESIGN.md §14 protocol table.  Extraction and enforcement
share one implementation: ``repro.analysis.lint.stdout_kinds`` walks the
emitters' ASTs (the same walk rule RA003 lints), so adding a new stdout
line without documenting it fails CI — the table and the code cannot drift
apart silently — and a print that RA003 would reject never even reaches
the kind table.
"""
import pathlib
import re

from repro.analysis import lint_source, stdout_kinds

ROOT = pathlib.Path(__file__).resolve().parents[1]
EMITTERS = [
    "src/repro/launch/serve.py",
    "src/repro/launch/server.py",
    "src/repro/launch/train.py",
    "src/repro/launch/costprobe.py",
    "src/repro/launch/dryrun.py",
    "src/repro/launch/hillclimb.py",
]
_PREFIXES = "serve|server|train|costprobe|dryrun|hillclimb"


def _emitted_kinds():
    return stdout_kinds(EMITTERS, root=str(ROOT))


def test_emitters_actually_emit_kinds():
    """Guard the guard: if the AST walk ever stops matching the source, the
    documentation test below would pass vacuously."""
    kinds = _emitted_kinds()
    assert "serve/report" in kinds and "server/start" in kinds
    assert "train/step" in kinds and "dryrun/cell" in kinds
    assert len(kinds) >= 14, sorted(kinds)


def test_every_emitted_kind_is_documented():
    design = (ROOT / "DESIGN.md").read_text()
    missing = {k: src for k, src in _emitted_kinds().items()
               if f"`{k}`" not in design}
    assert not missing, (
        f"stdout kinds emitted but absent from the DESIGN.md §14 protocol "
        f"table: {missing}")


def test_documented_kinds_are_emitted():
    """The table must not advertise lines nothing prints (stale docs are
    worse than none).  Only rows of the protocol table are checked — the
    fault-event kinds (`nar`, `stall`, ...) live inside serve/report's
    payload, not on stdout lines of their own."""
    design = (ROOT / "DESIGN.md").read_text()
    table = re.findall(
        rf"^\| `((?:{_PREFIXES})/[a-z0-9_-]+)` \|", design, re.MULTILINE)
    assert table, "DESIGN.md protocol table not found"
    emitted = set(_emitted_kinds())
    stale = [k for k in table if k not in emitted]
    assert not stale, f"documented but never emitted: {stale}"


def test_emitter_stdout_is_protocol_clean():
    """Every stdout print in the emitters passes RA003: exactly one
    json.dumps of a dict literal carrying "kind" (stderr exempt).  This is
    the same rule the repo-wide ``python -m repro.analysis`` gate runs —
    asserted here so a protocol regression fails the fast unit suite too."""
    for rel in EMITTERS:
        findings = [f for f in lint_source((ROOT / rel).read_text(), rel,
                                           rules=["RA003"])
                    if not f.suppressed]
        assert not findings, [f.format() for f in findings]
