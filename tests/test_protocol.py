"""The stdout protocol is versioned documentation, not an accident.

Every JSON line the serving CLIs print is tagged with a ``"kind"`` key and
documented in the DESIGN.md §14 protocol table.  These tests extract the
kind literals from the *source* of serve.py and server.py, so adding a new
stdout line without documenting it fails CI — the table and the code
cannot drift apart silently.
"""
import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
EMITTERS = ["src/repro/launch/serve.py", "src/repro/launch/server.py"]

_KIND = re.compile(r'"kind":\s*"([a-z0-9_/-]+)"')


def _emitted_kinds():
    kinds = {}
    for rel in EMITTERS:
        for k in _KIND.findall((ROOT / rel).read_text()):
            kinds.setdefault(k, rel)
    return kinds


def test_emitters_actually_emit_kinds():
    """Guard the guard: if the regex ever stops matching the source, the
    documentation test below would pass vacuously."""
    kinds = _emitted_kinds()
    assert "serve/report" in kinds and "server/start" in kinds
    assert len(kinds) >= 9, sorted(kinds)


def test_every_emitted_kind_is_documented():
    design = (ROOT / "DESIGN.md").read_text()
    missing = {k: src for k, src in _emitted_kinds().items()
               if f"`{k}`" not in design}
    assert not missing, (
        f"stdout kinds emitted but absent from the DESIGN.md §14 protocol "
        f"table: {missing}")


def test_documented_kinds_are_emitted():
    """The table must not advertise lines nothing prints (stale docs are
    worse than none).  Only rows of the protocol table are checked — the
    fault-event kinds (`nar`, `stall`, ...) live inside serve/report's
    payload, not on stdout lines of their own."""
    design = (ROOT / "DESIGN.md").read_text()
    table = re.findall(r"^\| `((?:serve|server)/[a-z0-9_-]+)` \|", design,
                       re.MULTILINE)
    assert table, "DESIGN.md protocol table not found"
    emitted = set(_emitted_kinds())
    stale = [k for k in table if k not in emitted]
    assert not stale, f"documented but never emitted: {stale}"


@pytest.mark.parametrize("rel", EMITTERS)
def test_kind_lines_are_json_objects(rel):
    """Every print() in the emitters that contains a kind tag goes through
    json.dumps — the protocol promises parseable lines, not repr soup."""
    src = (ROOT / rel).read_text()
    for line_no, line in enumerate(src.splitlines(), 1):
        if '"kind"' in line and "print(" in line:
            assert "json.dumps" in line, (rel, line_no, line.strip())
