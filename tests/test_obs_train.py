"""Training-plane observability (DESIGN.md §16): gradient observer, kernel
profiler, drift latch, step log.

* the ``grad_tap`` cotangent hook is a bit-exact identity (gradients with
  and without the tap are equal bitwise) whose recorded stats match a numpy
  oracle, and it records exactly once per step under ``jit`` + ``lax.scan``
  + ``jax.checkpoint`` rematerialization,
* the profiler's analytic bytes/FLOPs agree with the ``launch/roofline.py``
  closed forms computed by hand for the GEMM and attention families (the
  ISSUE acceptance bar), and eager vs traced dispatches are kept apart,
* the drift latch fires when a mid-run parameter scaling shifts a site's
  activation distribution away from its self-baseline,
* ``JsonlStepLog`` bounds its record count and ``TrainingTelemetry`` drains
  device scalars into gauges/log off the step path.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.calib import observe
from repro.calib.observe import Observer, observing
from repro.core import (
    OperandSlots, P8_0, P16_1, TransPolicy, posit_encode,
)
from repro.kernels.posit_attention import ops as attn_ops
from repro.kernels.posit_gemm import ops as gemm_ops
from repro.launch import roofline
from repro.models.layers import apply_linear, init_linear
from repro.obs import prof
from repro.obs.train import JsonlStepLog, TrainingTelemetry


def _drain_callbacks():
    """debug.callback effects are asynchronous; drain before reading stats."""
    barrier = getattr(jax, "effects_barrier", None)
    if barrier is not None:
        barrier()


# ------------------------------------------------------------- grad observer --

def test_grad_tap_identity_and_numpy_oracle():
    """The tap never perturbs the computation (bitwise-identical gradients)
    and the recorded cotangent stats match the hand-derived numpy grad."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (4, 8)).astype(np.float32))
    W = jnp.asarray(rng.normal(0, 1, (8, 6)).astype(np.float32))

    def loss_plain(x):
        return jnp.sum(jnp.tanh(x @ W))

    def loss_tapped(x):
        return jnp.sum(jnp.tanh(observe.grad_tap("site", x) @ W))

    g_plain = jax.jit(jax.grad(loss_plain))(x)
    obs = Observer(kinds=("act", "grad"))
    with observing(obs):
        g_tapped = jax.jit(jax.grad(loss_tapped))(x)
        jax.block_until_ready(g_tapped)
    _drain_callbacks()
    assert np.array_equal(np.asarray(g_plain), np.asarray(g_tapped))

    # numpy oracle for the cotangent arriving at the tap: dL/dx
    xn, Wn = np.asarray(x, np.float64), np.asarray(W, np.float64)
    g_ref = (1.0 - np.tanh(xn @ Wn) ** 2) @ Wn.T
    st = obs.stats[("site", "grad")]
    assert st.n == x.size and st.nonfinite == 0
    np.testing.assert_allclose(st.sum_sq, np.sum(g_ref ** 2), rtol=1e-5)
    np.testing.assert_allclose(st.abs_max, np.abs(g_ref).max(), rtol=1e-6)


def test_grad_tap_records_once_under_scan_and_checkpoint():
    """``jax.checkpoint`` replays the *forward* during the backward pass; the
    custom_vjp bwd must still run exactly once per scan iteration, or every
    histogram count doubles and drift scoring is silently biased."""
    W = jnp.eye(8, dtype=jnp.float32) * 0.5
    x = jnp.ones((1, 8), jnp.float32)

    def body(h, _):
        return jnp.tanh(observe.grad_tap("s", h) @ W), None

    def loss(x):
        run = jax.checkpoint(
            lambda h: jax.lax.scan(body, h, None, length=3)[0])
        return jnp.sum(run(x))

    obs = Observer(kinds=("act", "grad"))
    with observing(obs):
        jax.block_until_ready(jax.jit(jax.grad(loss))(x))
    _drain_callbacks()
    st = obs.stats[("s", "grad")]
    assert st.n == 3 * x.size, st.n


def test_grad_tap_is_noop_without_grad_kind():
    """Calibration's default observer must not gain tap overhead: with no
    "grad" channel armed the tap is the identity function itself."""
    x = jnp.ones((2, 2))
    obs = Observer()                    # default: ("weight", "act")
    with observing(obs):
        assert observe.grad_tap("p", x) is x
    assert observe.grad_tap("p", x) is x   # and outside any context


# ---------------------------------------------------------- kernel profiler ---

def test_profiler_gemm_bytes_match_roofline_hand_formula():
    M, K, N = 4, 8, 16
    rng = np.random.default_rng(1)
    a = posit_encode(jnp.asarray(rng.normal(0, 1, (M, K)), jnp.float32), 8, 0)
    b = posit_encode(jnp.asarray(rng.normal(0, 1, (K, N)), jnp.float32), 8, 0)
    slots = OperandSlots(rs1=P8_0, rs2=P8_0, rd=P16_1)

    profiler = prof.KernelProfiler()
    with prof.profiling(profiler), prof.site("blk/up"):
        gemm_ops.gemm(a, b, slots, impl="xla")
    (rec,) = [r for r in profiler.records.values() if r.family == "gemm"]

    # hand formula (DESIGN.md §6/§16): 2MKN FLOPs; A and B move at code
    # width (1 byte for p8), the output at its storage width (2 for p16)
    assert rec.flops == 2 * M * K * N
    assert rec.bytes == M * K * 1 + K * N * 1 + M * N * 2
    ref = roofline.gemm_cost(M, K, N, a_bytes=1, b_bytes=1, out_bytes=2)
    assert rec.flops == ref["flops"] and rec.bytes == ref["bytes"]
    assert rec.path == "blk/up" and rec.calls == 1 and rec.traced == 0
    assert rec.seconds > 0


def test_profiler_attention_bytes_match_roofline_hand_formula():
    B, Hq, Hkv, S, d = 2, 4, 2, 64, 16
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(0, 1, (B, Hq, d)), jnp.float32)
    kc = posit_encode(
        jnp.asarray(rng.normal(0, 1, (B, Hkv, S, d)), jnp.float32), 8, 0)
    vc = posit_encode(
        jnp.asarray(rng.normal(0, 1, (B, Hkv, S, d)), jnp.float32), 8, 0)
    lengths = jnp.full((B,), S, jnp.int32)

    profiler = prof.KernelProfiler()
    with prof.profiling(profiler):
        attn_ops.decode_attention(q, kc, vc, lengths, 0, kv_bits=8,
                                  impl="tiled")
    (rec,) = [r for r in profiler.records.values()
              if r.family == "attention"]

    # hand formula: QK^T + AV = 4*B*Hq*S*d FLOPs; K and V stream once at
    # code width over the allocated S, q and the output move at f32
    assert rec.flops == 4 * B * Hq * S * d
    assert rec.bytes == B * Hq * d * (4 + 4) + 2 * B * Hkv * S * d * 1
    ref = roofline.attention_decode_cost(B, Hq, Hkv, S, d, kv_bytes=1)
    assert rec.flops == ref["flops"] and rec.bytes == ref["bytes"]


def test_profiler_traced_vs_eager_dispatch():
    """Dispatches under a jit trace count as ``traced`` (once per compile,
    never timed); eager dispatches are counted and timed."""
    x = jnp.ones((2, 8), jnp.float32)
    p = init_linear(jax.random.PRNGKey(0), 8, 4)
    policy = TransPolicy.from_names()

    profiler = prof.KernelProfiler()
    with prof.profiling(profiler):
        jax.jit(lambda p, x: apply_linear(p, x, policy, path="l"))(p, x)
        apply_linear(p, x, policy, path="l")
    rec = profiler.records[("l", "gemm", "xla")]
    assert rec.traced == 1 and rec.calls == 1
    rep = profiler.report(measured_total_s=1.0)
    assert rep["totals"]["dispatches"] == 2
    assert rep["rows"][0]["bound"] in ("compute", "memory")


def test_profiler_inactive_is_invisible():
    assert not prof.is_active()
    x = jnp.ones((2, 4), jnp.float32)
    p = init_linear(jax.random.PRNGKey(1), 4, 4)
    y = apply_linear(p, x, TransPolicy.from_names(), path="l")
    assert y.shape == (2, 4)


# ---------------------------------------------------------------- drift latch --

def test_drift_latch_fires_on_midrun_param_scale():
    """Two chained linears under a probed-twin-style telemetry loop: scaling
    the first layer's weights mid-run shifts the second site's activation
    binades off its self-baseline and must latch ``recalibrate``."""
    policy = TransPolicy.from_names()
    tel = TrainingTelemetry(policy=policy, every=1, check_every=1)
    rng = np.random.default_rng(3)
    p1 = init_linear(jax.random.PRNGKey(0), 16, 16)
    p2 = init_linear(jax.random.PRNGKey(1), 16, 8)

    def probed_step(step, p1):
        x = jnp.asarray(rng.normal(0, 1, (8, 16)), jnp.float32)
        with tel.observing():
            h = apply_linear(p1, x, policy, path="l1")
            y = apply_linear(p2, h, policy, path="l2")
        jax.block_until_ready(y)
        _drain_callbacks()
        return tel.on_step(step, {"loss": jnp.sum(y)}, probed=True)

    events = [probed_step(s, p1) for s in range(2)]
    assert events == [None, None] and not tel.recalibrate

    p1_scaled = {k: v * 2.0 ** 8 for k, v in p1.items()}
    events = [probed_step(2 + s, p1_scaled) for s in range(2)]
    fired = [e for e in events if e is not None]
    assert fired, "drift never latched after the mid-run param scale"
    assert fired[0]["recalibrate"] and "l2" in fired[0]["drifted"]
    assert tel.recalibrate
    assert tel.metrics.gauge("train_recalibrate").val == 1.0
    rep = tel.report()
    assert rep["numerics"]["recalibrate"]


# ------------------------------------------------------- step log / telemetry --

def test_jsonl_step_log_bounded(tmp_path):
    path = str(tmp_path / "steps.jsonl")
    log = JsonlStepLog(path, max_records=4)
    for i in range(6):
        log.append({"step": i})
    log.close()
    recs = [json.loads(l) for l in open(path)]
    assert [r["step"] for r in recs] == [0, 1, 2, 3]
    assert log.stats() == {"path": path, "records": 4, "dropped": 2,
                           "max_records": 4}


def test_telemetry_drains_off_step_path(tmp_path):
    """Un-probed steps only buffer (no host sync, no file I/O); the probe
    boundary drains everything pending into the log and gauges."""
    path = str(tmp_path / "steps.jsonl")
    tel = TrainingTelemetry(every=4, check_every=2, log_path=path)
    for step in range(3):
        assert tel.on_step(step, {"loss": jnp.float32(step)}) is None
    assert len(tel._pending) == 3 and tel.log.written == 0

    with tel.observing():
        pass    # a probe with no sites recorded is still a probe
    tel.on_step(3,{"loss": jnp.float32(3.0), "gnorm": jnp.float32(2.0),
                    "update_ratio": jnp.float32(0.5),
                    "grad_nonfinite": jnp.int32(0),
                    "opt_nonfinite": jnp.int32(1)}, probed=True)
    assert tel._pending == [] and tel.log.written == 4
    assert tel.metrics.gauge("train_loss").val == 3.0
    assert tel.metrics.gauge("train_update_ratio").val == 0.5
    tel.close()
    recs = [json.loads(l) for l in open(path)]
    assert len(recs) == 4 and recs[3]["opt_nonfinite"] == 1
    rep = tel.report()
    assert rep["steps"] == 4 and rep["log"]["records"] == 4
