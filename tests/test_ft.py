"""Fault-tolerant serving plane (repro.ft.serving, DESIGN.md §13).

The load-bearing guarantees (ISSUE 7 acceptance):

* kill mid-stream -> restore -> every in-flight request continues
  **bit-identically** (same policy + same RNG + same executables), under
  temperature sampling — the snapshot carries the PRNG key,
* injected NaR trips the quarantine + precision-escalation ladder without
  killing unaffected slots,
* deadlines evict as partial completions; preemption drains-then-snapshots;
  checkpoint IO failures surface promptly and retry with decorrelated
  jitter.

Bit-identity restores into the SAME engine (``reset()`` + ``restore()``):
XLA:CPU compiles are not bit-stable across program instances, so cross-
process resume is validated functionally by the serve.py integration test
at the bottom (completion counts, not token bits).
"""
import json
import os
import signal
import subprocess
import sys
import time

import numpy as np
import jax
import pytest

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import get_arch
from repro.core.pcsr import TransPolicy
from repro.core.policy import (LayerRule, PrecisionPolicy,
                               get_precision_policy)
from repro.core.types import PositFmt
from repro.ft import (DegradationController, EngineSnapshotter, FaultPlan,
                      PreemptionSignal, StragglerMonitor, next_rung,
                      with_retries)
from repro.launch.engine import (ContinuousBatchingEngine, Completion,
                                 Request, poisson_requests, scrub_slot)
from repro.models.registry import build_model
from repro.obs.metrics import MetricsRegistry
from repro.obs.numerics import NumericsWatcher

S_MAX = 64


@pytest.fixture(scope="module")
def dense_model():
    cfg = get_arch("yi-34b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _requests(cfg, n, gen=10, seed=1):
    return poisson_requests(n, arrival_rate=0.0, prompt_lens=(8,),
                            max_new_tokens=gen, vocab=cfg.vocab, seed=seed)


def _drain(eng, now=50.0):
    while eng.active.any() or eng.queue:
        if eng.queue and eng.free_slots():
            eng.admit(now=now)
        eng.step(now=now)
    return {c.rid: list(c.tokens) for c in eng.completions}


# ---------------------------------------------------------------- runtime ----

def test_with_retries_allowlist():
    """Only listed exception types are retried; bugs propagate first-throw."""
    calls = []

    def boom(exc):
        calls.append(1)
        raise exc

    with pytest.raises(KeyboardInterrupt):
        with_retries(lambda: boom(KeyboardInterrupt()), retries=5,
                     base_delay=0.001)
    assert len(calls) == 1
    calls.clear()
    with pytest.raises(AssertionError):
        with_retries(lambda: boom(AssertionError("bug")), retries=5,
                     base_delay=0.001)
    assert len(calls) == 1
    calls.clear()
    with pytest.raises(ValueError):   # custom allowlist, exhausted
        with_retries(lambda: boom(ValueError()), retries=2, base_delay=0.001,
                     retryable=(ValueError,))
    assert len(calls) == 3            # 1 + 2 retries


def test_with_retries_decorrelated_jitter(monkeypatch):
    """Jittered sleeps are drawn from [base, 3*prev], capped at max_delay."""
    sleeps = []
    monkeypatch.setattr(time, "sleep", lambda s: sleeps.append(s))
    import random
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 6:
            raise RuntimeError("transient")
        return "ok"

    assert with_retries(flaky, retries=8, base_delay=0.1, max_delay=1.0,
                        rng=random.Random(0)) == "ok"
    assert len(sleeps) == 5
    prev = 0.1
    for s in sleeps:
        assert 0.1 <= s <= 1.0
        assert s <= max(prev * 3.0, 0.1) + 1e-12
        prev = s
    assert len(set(sleeps)) > 1, "jitter must not be a fixed schedule"


def test_with_retries_on_retry_and_deterministic():
    seen = []
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError("io")
        return 7

    assert with_retries(flaky, retries=4, base_delay=0.0, jitter=False,
                        on_retry=lambda n, e: seen.append(n)) == 7
    assert seen == [1, 2]


def test_preemption_signal_real_sigterm():
    """install_sigterm=True catches a real in-process SIGTERM."""
    old = signal.getsignal(signal.SIGTERM)
    try:
        sig = PreemptionSignal(install_sigterm=True)
        assert not sig.triggered
        os.kill(os.getpid(), signal.SIGTERM)
        deadline = time.time() + 5.0
        while not sig.triggered and time.time() < deadline:
            time.sleep(0.01)
        assert sig.triggered
    finally:
        signal.signal(signal.SIGTERM, old)


def test_straggler_monitor_threshold_edges():
    m = StragglerMonitor(threshold=2.0, alpha=0.5)
    assert not m.observe(1.0)            # first sample seeds the EWMA
    assert not m.observe(1.9)            # under 2x: folded in
    ewma = m._ewma
    assert m.observe(ewma * 2.0 + 1e-6)  # just over: straggler
    assert m._ewma == ewma, "outliers must not drag the baseline"
    assert not m.observe(ewma * 2.0 - 1e-6)
    assert m.events == 1


# ------------------------------------------------------------- checkpoints ----

def test_ckpt_manager_gc_tmp_on_init(tmp_path):
    crash = tmp_path / "step_00000007.tmp"
    crash.mkdir()
    (crash / "junk").write_text("partial write")
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.gc_tmp_reaped == 1
    assert not crash.exists()
    mgr.close()


def test_ckpt_manager_surfaces_failure_and_retries(tmp_path):
    """Injected IO failures retry (counter moves); a terminal failure is
    surfaced on metrics immediately and re-raised on the next wait()."""
    metrics = MetricsRegistry()
    plan = FaultPlan(ckpt_fail_times=2)   # fail twice, then succeed
    mgr = CheckpointManager(str(tmp_path), metrics=metrics, retries=3,
                            retry_base_delay=0.001,
                            pre_save=plan.ckpt_pre_save)
    mgr.save_async(1, {"x": np.arange(4)})
    mgr.wait()                            # retried to success
    assert metrics.counter("ckpt_save_retries").total == 2
    assert metrics.counter("ckpt_saves").total == 1
    assert metrics.counter("ckpt_save_errors").total == 0
    assert metrics.gauges["ckpt_last_saved_step"].val == 1

    plan.ckpt_fail_times = 10             # more failures than retries
    mgr.save_async(2, {"x": np.arange(4)})
    with pytest.raises(RuntimeError, match="async checkpoint failed"):
        mgr.wait()
    assert metrics.counter("ckpt_save_errors").total == 1
    with pytest.raises(RuntimeError):
        mgr.close()


# ------------------------------------------------------------ policy ladder ----

def test_layer_rule_bypass_resolution_and_json():
    base = TransPolicy.from_names(weights="p8_0", kv_cache="p8_0",
                                  pack_weights=True)
    pol = PrecisionPolicy(base=base, rules=(
        LayerRule("mlp/up", None, bypass=True),
        LayerRule("*", PositFmt(8, 0), packed=True),
    ))
    assert pol.policy_for("blocks/mlp/up").weights is None
    assert not pol.policy_for("blocks/mlp/up").pack_weights
    assert pol.policy_for("blocks/mlp/gate").weights == PositFmt(8, 0)
    assert "mlp/up->float" in pol.describe()
    rt = PrecisionPolicy.from_json(pol.to_json())
    assert rt.rules[0].bypass and rt.rules[0].weights is None
    assert rt.policy_for("blocks/mlp/up").weights is None
    with pytest.raises(ValueError):
        LayerRule("x", PositFmt(8, 0), bypass=True)   # fmt + bypass conflict


def test_precision_spec_float_bypass():
    pol = get_precision_policy("attn*=p16_1,mlp/up=float,*=p8_0")
    assert pol.rule_for("mlp/up").bypass
    assert pol.policy_for("blocks/mlp/up").weights is None
    with pytest.raises(ValueError):
        get_precision_policy("mlp/up=float:packed")


def test_next_rung_ladder():
    p8 = PositFmt(8, 0)
    assert next_rung(p8, True) == (p8, False, False)          # unpack
    assert next_rung(p8, False) == (PositFmt(16, 1), False, False)
    assert next_rung(PositFmt(16, 1), False) == (None, False, True)
    assert next_rung(None, False) is None                     # already float


# -------------------------------------------------------- snapshot/restore ----

def test_snapshot_restore_bit_identical(dense_model):
    """Mid-stream snapshot -> finish -> restore into the SAME engine ->
    identical continuation tokens, under temperature sampling (the RNG key
    rides in the snapshot)."""
    cfg, model, params = dense_model
    policy = TransPolicy.from_names(kv_cache="p8_0")
    eng = ContinuousBatchingEngine(model, params, policy, max_slots=2,
                                   S_max=S_MAX, temperature=0.7, top_k=8,
                                   seed=3)
    for r in _requests(cfg, 3):
        eng.submit(r)
    eng.admit()
    for i in range(4):
        eng.step(now=float(i))
    mid = eng.snapshot()
    truth = _drain(eng)
    eng.reset(seed=3)
    eng.restore(mid, now=0.0)
    assert eng.steps == mid["meta"]["steps"]
    replay = _drain(eng)
    assert truth == replay


def test_snapshot_restore_roundtrips_disk(dense_model, tmp_path):
    """snapshotter save -> restore_into reproduces device state bit-for-bit
    (raw npz storage: posit KV codes are never re-encoded)."""
    cfg, model, params = dense_model
    policy = TransPolicy.from_names(kv_cache="p8_0")
    snap = EngineSnapshotter(str(tmp_path), every=10 ** 9)
    eng = ContinuousBatchingEngine(model, params, policy, max_slots=2,
                                   S_max=S_MAX, seed=0, snapshotter=snap)
    for r in _requests(cfg, 2):
        eng.submit(r)
    eng.admit()
    eng.step()
    snap.force(eng)
    before = eng.snapshot()
    eng.reset(seed=0)
    assert snap.restore_into(eng, now=0.0)
    after = eng.snapshot()
    assert before["meta"] == after["meta"]
    b, a = jax.tree.leaves(before["arrays"]), jax.tree.leaves(after["arrays"])
    assert all(np.array_equal(x, y) for x, y in zip(b, a))
    snap.close()


def test_restore_rejects_mismatched_config(dense_model):
    cfg, model, params = dense_model
    policy = TransPolicy.from_names(kv_cache="p8_0")
    eng = ContinuousBatchingEngine(model, params, policy, max_slots=2,
                                   S_max=S_MAX, seed=0)
    snap = eng.snapshot()
    wrong_grid = json.loads(json.dumps(snap["meta"]))
    wrong_grid["max_slots"] = 5
    with pytest.raises(ValueError, match="grid"):
        eng.restore({"arrays": snap["arrays"], "meta": wrong_grid})
    wrong_pol = json.loads(json.dumps(snap["meta"]))
    wrong_pol["policy"] = "something else"
    with pytest.raises(ValueError, match="policy"):
        eng.restore({"arrays": snap["arrays"], "meta": wrong_pol})


def test_request_completion_json_roundtrip():
    req = Request(rid=4, prompt=np.arange(5, dtype=np.int32),
                  max_new_tokens=7, arrival_time=1.5, deadline_s=2.0)
    rt = Request.from_json(json.loads(json.dumps(req.to_json())))
    assert rt.rid == 4 and rt.deadline_s == 2.0
    assert np.array_equal(rt.prompt, req.prompt)
    comp = Completion(rid=4, prompt_len=5, tokens=[1, 2], arrival_time=1.5,
                      admitted_time=2.0, finished_time=3.0,
                      token_times=[2.1, 2.2], finish_reason="timeout")
    assert Completion.from_json(
        json.loads(json.dumps(comp.to_json()))) == comp


# --------------------------------------------------------------- chaos plan ----

def test_nar_injection_quarantines_only_poisoned_slot(dense_model):
    """Injected NaR: the poisoned slot quarantines (finish_reason=numerics,
    KV rows scrubbed), unaffected slots finish their full budget, and the
    controller steps the precision ladder."""
    cfg, model, params = dense_model
    base = TransPolicy.from_names(kv_cache="p8_0", compute_dtype="bf16")
    pol = get_precision_policy("p8-packed", base=base)
    watcher = NumericsWatcher(policy=pol, every=2)
    metrics = MetricsRegistry()
    dog = DegradationController(watcher, metrics=metrics)
    plan = FaultPlan(nar_at_step=4, nar_slot=0, nar_count=4)
    eng = ContinuousBatchingEngine(
        model, params, pol, max_slots=3, S_max=S_MAX, seed=0,
        numerics=watcher, faults=plan, watchdog=dog, check_every_probes=2)
    for r in _requests(cfg, 3, gen=14):
        eng.submit(r)
    eng.admit()
    comps = _drain(eng)
    by_reason = {}
    for c in eng.completions:
        by_reason.setdefault(c.finish_reason, []).append(c)
    assert len(by_reason.get("numerics", [])) == 1
    poisoned = by_reason["numerics"][0]
    assert 0 < len(poisoned.tokens) < 14, "partial completion expected"
    healthy = by_reason.get("max_new", [])
    assert len(healthy) == 2 and all(len(c.tokens) == 14 for c in healthy), \
        "unaffected slots must serve their full budget"
    assert plan.fired and plan.fired[0]["kind"] == "nar"
    assert dog.events, "fresh NaR breach must step the ladder"
    assert all(ev["kind"] == "nar" for ev in dog.events)
    assert metrics.counter("degradations").value(label="nar") == \
        len(dog.events)
    # ladder rung 1: packed-p8 -> unpacked p8 on the breached sites
    stepped_site = dog.events[0]["site"]
    site_pol = eng.policy.policy_for(stepped_site)
    assert site_pol.weights == PositFmt(8, 0) and not site_pol.pack_weights
    assert comps  # silence unused warnings; everything completed


def test_degradation_ladder_reaches_float(dense_model):
    """Repeated fresh breaches walk one site packed-p8 -> p8 -> p16 ->
    float bypass, then stop (nothing wider exists)."""
    cfg, model, params = dense_model
    base = TransPolicy.from_names(kv_cache="p8_0", compute_dtype="bf16")
    pol = get_precision_policy("p8-packed", base=base)
    watcher = NumericsWatcher(policy=pol, every=1)
    dog = DegradationController(watcher)
    eng = ContinuousBatchingEngine(
        model, params, pol, max_slots=1, S_max=S_MAX, seed=0,
        numerics=watcher, watchdog=dog, check_every_probes=1)
    h_path = None
    for rung in range(5):
        eng.faults = FaultPlan(nar_at_step=eng.steps, nar_slot=0, nar_count=2)
        if not eng.active.any():
            for r in _requests(cfg, 1, gen=40):
                eng.submit(r)
            eng.admit()
        eng.step()
        if h_path is None and dog.events:
            h_path = dog.events[0]["site"]
    assert h_path is not None
    transitions = [(e["from"], e["to"]) for e in dog.events
                   if e["site"] == h_path]
    assert ("p8_0(packed)", "p8_0") in transitions
    assert ("p8_0", "p16_1") in transitions
    assert ("p16_1", "float") in transitions
    assert eng.policy.policy_for(h_path).weights is None  # bypass live


def test_stale_health_rows_do_not_retrigger(dense_model):
    """A breach row retained from an old check (the watcher keeps a site's
    last readout when a window has no traffic for it) must not re-step the
    ladder on later checks — ``check_id`` gating."""
    from repro.obs.numerics import SiteHealth

    cfg, model, params = dense_model
    base = TransPolicy.from_names(kv_cache="p8_0", compute_dtype="bf16")
    pol = get_precision_policy("p8-packed", base=base)
    watcher = NumericsWatcher(policy=pol, every=1)
    dog = DegradationController(watcher)
    eng = ContinuousBatchingEngine(model, params, pol, max_slots=1,
                                   S_max=S_MAX, seed=0, numerics=watcher,
                                   watchdog=dog)
    row = SiteHealth(path="attn/wq", n=100.0, saturation_rate=None,
                     underflow_rate=None, nonfinite=7.0, drift_score=None,
                     drift_threshold=None, drifted=False, check_id=1)
    watcher.health["attn/wq"] = row
    watcher.checks = 1
    assert dog.maybe_degrade(eng) == 1    # fresh breach: ladder steps
    # next check window has no traffic for the site: the row is retained
    # with its old check_id — the controller must treat it as stale
    watcher.checks = 2
    assert dog.maybe_degrade(eng) == 0, \
        "stale health row re-triggered the ladder"
    assert len(dog.events) == 1


def test_stall_fault_trips_straggler(dense_model):
    cfg, model, params = dense_model
    policy = TransPolicy.from_names(kv_cache="p8_0")
    eng = ContinuousBatchingEngine(model, params, policy, max_slots=1,
                                   S_max=S_MAX, seed=0)
    mon = StragglerMonitor(threshold=3.0)
    for r in _requests(cfg, 1, gen=12):
        eng.submit(r)
    eng.admit()
    eng.step()     # compile outside the monitored window: the first step's
    eng.step()     # jit cost would seed the EWMA and mask the stall
    eng.faults = FaultPlan(stall_at_step=eng.steps + 2, stall_s=0.3)
    straggled = 0
    while eng.active.any():
        t0 = time.perf_counter()
        eng.step()
        straggled += mon.observe(time.perf_counter() - t0)
    assert [f["kind"] for f in eng.faults.fired] == ["stall"]
    assert straggled >= 1, "the stalled step must register as a straggler"


# ---------------------------------------------------------------- deadlines ----

def test_deadline_evicts_active_and_queued(dense_model):
    cfg, model, params = dense_model
    policy = TransPolicy.from_names(kv_cache="p8_0")
    eng = ContinuousBatchingEngine(model, params, policy, max_slots=1,
                                   S_max=S_MAX, seed=0, deadline_s=5.0,
                                   watchdog=None)
    rng = np.random.default_rng(0)
    mk = lambda rid, deadline=None: Request(  # noqa: E731
        rid=rid, prompt=rng.integers(0, cfg.vocab, (6,)).astype(np.int32),
        max_new_tokens=30, arrival_time=0.0, deadline_s=deadline)
    eng.submit(mk(0))                 # active; engine default deadline 5s
    eng.submit(mk(1, deadline=2.0))   # queued; per-request override 2s
    eng.admit(now=0.0)
    eng.step(now=1.0)
    assert eng.active[0] and len(eng.queue) == 1
    eng.step(now=3.0)                 # rid 1 expires in queue (2s < 3s)
    reasons = {c.rid: c.finish_reason for c in eng.completions}
    assert reasons.get(1) == "timeout"
    assert [c for c in eng.completions if c.rid == 1][0].tokens == []
    eng.step(now=6.0)                 # rid 0 expires mid-flight (5s < 6s)
    reasons = {c.rid: c.finish_reason for c in eng.completions}
    assert reasons.get(0) == "timeout"
    partial = [c for c in eng.completions if c.rid == 0][0]
    assert 0 < len(partial.tokens) < 30, "timeout keeps the partial stream"
    assert not eng.active.any()


# --------------------------------------------------------- preemption drain ----

def test_run_preemption_drains_then_snapshots(dense_model, tmp_path):
    """SIGTERM-style preemption mid-run: the loop exits with a forced durable
    snapshot carrying every unfinished request; a restore + run([]) finishes
    the workload with zero token loss vs the uninterrupted run."""
    cfg, model, params = dense_model
    policy = TransPolicy.from_names(kv_cache="p8_0")
    snap = EngineSnapshotter(str(tmp_path), every=10 ** 9)
    eng = ContinuousBatchingEngine(model, params, policy, max_slots=2,
                                   S_max=S_MAX, temperature=0.6, top_k=8,
                                   seed=0, snapshotter=snap)
    reqs = lambda: _requests(cfg, 4, gen=10)  # noqa: E731
    truth = {c.rid: list(c.tokens)
             for c in eng.run(reqs(), clock=lambda: 0.0)}
    eng.reset(seed=0)

    sig = PreemptionSignal()
    eng.faults = FaultPlan(preempt_at_step=3, preemption=sig)
    done = eng.run(reqs(), clock=lambda: 0.0, preemption=sig)
    assert sig.triggered
    in_flight = int(eng.active.sum()) + len(eng.queue)
    assert in_flight > 0, "preemption must land mid-workload"
    assert len(done) < 4

    eng.faults = None
    eng.reset(seed=0)
    assert snap.restore_into(eng, now=0.0)
    resumed = {c.rid: list(c.tokens)
               for c in eng.run([], clock=lambda: 0.0)}
    assert resumed == truth, "kill/resume lost or diverged tokens"
    snap.close()


# ------------------------------------------------- serve.py integration ----

@pytest.mark.slow
def test_serve_kill_and_resume_integration(tmp_path):
    """End-to-end: serve.py snapshotting run SIGTERMs itself mid-stream
    (FaultPlan chaos flag), exits cleanly with in-flight work; a --resume
    run restores the snapshot and finishes every request."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    snap_dir = str(tmp_path / "snaps")
    base = [sys.executable, "-m", "repro.launch.serve", "--arch", "yi-34b",
            "--reduced", "--continuous", "--max-slots", "2",
            "--requests", "4", "--prompt-len", "8", "--gen", "24",
            "--policy", "p8-serve", "--snapshot-every", "2",
            "--snapshot-dir", snap_dir]

    def run(extra):
        # generous timeout: two full jit compiles ride on each invocation
        p = subprocess.run(base + extra, env=env, cwd=repo,
                           capture_output=True, text=True, timeout=900)
        assert p.returncode == 0, f"serve failed:\n{p.stderr[-3000:]}"
        return [json.loads(ln) for ln in p.stdout.splitlines()
                if ln.startswith("{")]

    first = run(["--chaos-preempt-step", "6"])
    rep1 = [d for d in first if d.get("kind") == "serve/report"][0]
    assert rep1["preempted"] and rep1["in_flight_at_exit"] > 0
    assert rep1["requests"] < 4

    second = run(["--resume"])
    resume = [d for d in second if d.get("kind") == "serve/resume"]
    assert resume and resume[0]["active_slots"] + resume[0]["queued"] > 0
    rep2 = [d for d in second if d.get("kind") == "serve/report"][0]
    assert rep2["resumed"] and not rep2["preempted"]
    assert rep2["requests"] == 4, "resume must finish every request"
    assert rep2["in_flight_at_exit"] == 0


# ----------------------------------------------------------------- helpers ----

def test_scrub_slot_zeroes_only_that_slot(dense_model):
    cfg, model, params = dense_model
    policy = TransPolicy.from_names(kv_cache="p8_0")
    eng = ContinuousBatchingEngine(model, params, policy, max_slots=2,
                                   S_max=S_MAX, seed=0)
    for r in _requests(cfg, 2, gen=6):
        eng.submit(r)
    eng.admit()
    eng.step()
    eng.inject_nar_into(0, 3)
    cache = scrub_slot(eng.cache, 0)

    def rows(c, slot):
        out = []
        from repro.launch.engine import _slot_index, map_kv_rows
        map_kv_rows(c, lambda keys, leaf:
                    out.append(np.asarray(leaf[_slot_index(leaf, slot)]))
                    or leaf)
        return out
    assert all((r == 0).all() for r in rows(cache, 0))
    before1, after1 = rows(eng.cache, 1), rows(cache, 1)
    assert all(np.array_equal(a, b) for a, b in zip(before1, after1)), \
        "scrub must not touch other slots"
