"""Mixed-precision subsystem tests (DESIGN.md §9).

* packed-p8 lanes: pack/unpack roundtrip, exhaustive dual-lane decode
  bit-exactness vs the unpacked codec
* mixed p8 x p16 operand formats: exhaustive product correctness vs the
  ref_codec Fraction oracle across all es pairs; fused == unfused;
  format-pair dispatch plan
* packed Pallas GEMM kernel vs its jnp oracle (interpret mode)
* quire-dataflow mixed dot: bit-exact vs the exact Fraction sum
* per-layer PrecisionPolicy resolution + packed quantized layers
"""
from fractions import Fraction

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    BF16, F32, P8_0, P16_1, OperandSlots, TransPolicy, posit_decode,
    posit_dot, posit_encode,
)
from repro.core import ref_codec
from repro.core.dot import format_pair_plan
from repro.core.pack import (
    pack_p8, packed_decode_p8, packed_half_k, split_activations, unpack_p8,
)
from repro.core.pcsr import OperandSlots as OS
from repro.core.policy import (
    PRECISION_PRESETS, LayerRule, PrecisionPolicy, get_precision_policy,
)
from repro.core.types import PositFmt
from repro.kernels.posit_gemm.ops import gemm
from repro.kernels.posit_gemm.ref import posit_gemm_ref


def _bits(x):
    return np.asarray(x).view(np.uint32)


# ---------------------------------------------------------------- packing ----

@pytest.mark.parametrize("k", [6, 7, 16])
def test_pack_unpack_roundtrip(k):
    rng = np.random.default_rng(0)
    c = jnp.asarray(rng.integers(0, 256, (k, 5)).astype(np.uint8))
    p = pack_p8(c)
    assert p.shape == (packed_half_k(k), 5) and p.dtype == jnp.uint16
    assert (np.asarray(unpack_p8(p, k)) == np.asarray(c)).all()


def test_pack_stacked_leading_dims():
    rng = np.random.default_rng(1)
    c = jnp.asarray(rng.integers(0, 256, (3, 8, 4)).astype(np.uint8))
    assert (np.asarray(unpack_p8(pack_p8(c))) == np.asarray(c)).all()


@pytest.mark.parametrize("es", [0, 1, 2, 3])
def test_packed_decode_exhaustive_bit_exact(es):
    """All 65536 (lo, hi) lane combinations decode bit-identically to the
    unpacked p8 codec — both lanes, every code, including NaR/zero."""
    lanes = jnp.arange(65536, dtype=jnp.uint16).reshape(2, 32768)
    got = packed_decode_p8(lanes, es)  # (4, 32768): lo rows then hi rows
    lo = (np.arange(65536, dtype=np.uint16) & 0xFF).astype(np.uint8)
    hi = (np.arange(65536, dtype=np.uint16) >> 8).astype(np.uint8)
    want_lo = posit_decode(jnp.asarray(lo.reshape(2, 32768)), 8, es)
    want_hi = posit_decode(jnp.asarray(hi.reshape(2, 32768)), 8, es)
    assert (_bits(got[:2]) == _bits(want_lo)).all()
    assert (_bits(got[2:]) == _bits(want_hi)).all()


def test_split_activations_pairs_lanes():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(0, 1, (3, 7)).astype(np.float32))
    kh = packed_half_k(7)
    xl, xh = split_activations(x, kh)
    assert xl.shape == xh.shape == (3, kh)
    assert (np.asarray(xh[:, -1]) == 0).all()  # odd-K pad column is zero


# --------------------------------------------------- mixed operand formats ----

@pytest.mark.parametrize("es_a", [0, 1, 2, 3])
@pytest.mark.parametrize("es_b", [0, 1, 2, 3])
def test_p8_x_p16_products_vs_ref_oracle(es_a, es_b):
    """Exhaustive p8 codes x sampled p16 codes: the f32 datapath product
    equals the correctly-rounded product of the ref_codec oracle values.

    Every posit decode is exact in f64 and products carry <= 20 significand
    bits, so the f64 oracle product rounded to f32 is the RNE of the exact
    product — which is what one f32 FPU multiply must produce.
    """
    rng = np.random.default_rng(es_a * 4 + es_b)
    a8 = np.arange(256, dtype=np.uint8)                     # exhaustive p8
    b16 = rng.integers(0, 1 << 16, 256).astype(np.uint16)   # sampled p16
    va = np.asarray(posit_decode(jnp.asarray(a8), 8, es_a))
    vb = np.asarray(posit_decode(jnp.asarray(b16), 16, es_b))
    # oracle decode must agree exactly first
    for i in (0, 1, 128, 255):
        rv = ref_codec.ref_decode_float(int(a8[i]), 8, es_a)
        assert (np.isnan(rv) and np.isnan(va[i])) or rv == va[i]
    got = np.asarray(jnp.multiply(jnp.asarray(va)[:, None],
                                  jnp.asarray(vb)[None, :]))
    want = (va.astype(np.float64)[:, None]
            * vb.astype(np.float64)[None, :]).astype(np.float32)
    assert (_bits(got) == _bits(want)).all()


@pytest.mark.parametrize("es_a", [0, 1, 2, 3])
@pytest.mark.parametrize("es_b", [0, 1, 2, 3])
def test_mixed_dot_all_es_pairs(es_a, es_b):
    """p16 x p8 GEMM through the pcsr equals the decode-then-matmul
    reference, fused == unfused, for every es pair."""
    rng = np.random.default_rng(10 + es_a * 4 + es_b)
    a = jnp.asarray(rng.normal(0, 1, (6, 12)).astype(np.float32))
    b = jnp.asarray(rng.normal(0, 1, (12, 5)).astype(np.float32))
    ac = posit_encode(a, 16, es_a)
    bc = posit_encode(b, 8, es_b)
    slots = OS(rs1=P16_1, rs2=P8_0, rd=F32)
    y_f = posit_dot(ac, bc, slots, es_a=es_a, es_b=es_b, impl="fused")
    y_u = posit_dot(ac, bc, slots, es_a=es_a, es_b=es_b, impl="unfused")
    want = jnp.matmul(
        posit_decode(ac, 16, es_a),
        posit_decode(bc, 8, es_b).astype(jnp.float32),
        preferred_element_type=jnp.float32)
    assert (_bits(y_f) == _bits(want)).all()
    assert (_bits(y_u) == _bits(want)).all()


def test_packed_dot_matches_unpacked():
    """Packing is a storage transform: bit-identical results, fewer bytes."""
    rng = np.random.default_rng(3)
    for k in (16, 17):  # even + odd contraction dims
        a = jnp.asarray(rng.normal(0, 1, (8, k)).astype(np.float32))
        b = jnp.asarray(rng.normal(0, 1, (k, 6)).astype(np.float32))
        ac = posit_encode(a, 16, 1)
        bc = posit_encode(b, 8, 0)
        y_packed = posit_dot(ac, pack_p8(bc),
                             OS(rs1=P16_1, rs2=P8_0, rd=F32, rs2_packed=True))
        y_plain = posit_dot(ac, bc, OS(rs1=P16_1, rs2=P8_0, rd=F32))
        assert (_bits(y_packed) == _bits(y_plain)).all(), k


def test_format_pair_plan_table():
    """The DESIGN.md §9 dispatch table, spot-checked."""
    p88 = format_pair_plan(OS(rs1=P8_0, rs2=P8_0, rd=P8_0))
    assert p88.compute_dtype_name == "bfloat16" and p88.quire_ok
    p816 = format_pair_plan(OS(rs1=P8_0, rs2=P16_1, rd=P16_1))
    assert p816.compute_dtype_name == "float32" and p816.quire_ok
    pf = format_pair_plan(OS(rs1=F32, rs2=P8_0, rd=F32))
    assert pf.compute_dtype_name == "float32" and not pf.quire_ok
    assert not pf.decode_a and pf.decode_b and not pf.encode_out
    pb = format_pair_plan(OS(rs1=BF16, rs2=P8_0, rd=F32))
    assert pb.compute_dtype_name == "bfloat16"
    pk = format_pair_plan(OS(rs1=P16_1, rs2=P8_0, rd=F32, rs2_packed=True))
    assert pk.packed_b


def test_packed_requires_p8():
    with pytest.raises(ValueError):
        OS(rs1=P16_1, rs2=P16_1, rd=F32, rs2_packed=True)
    with pytest.raises(ValueError):
        TransPolicy.from_names(weights="p16_1", pack_weights=True)


# ------------------------------------------------------------ packed kernel ----

@pytest.mark.parametrize("k", [33, 64])
def test_packed_kernel_vs_ref(k):
    """Pallas packed GEMM (interpret) vs the jnp oracle: bit-exact for posit
    rd (the encode swallows tile-order f32 last-bit wobble is NOT assumed —
    posit outputs compare exactly; float rd compares to 1e-5 rel)."""
    rng = np.random.default_rng(k)
    a = jnp.asarray(rng.normal(0, 1, (16, k)).astype(np.float32))
    b = jnp.asarray(rng.normal(0, 1, (k, 12)).astype(np.float32))
    ac = posit_encode(a, 16, 1)
    bp = pack_p8(posit_encode(b, 8, 0))
    es = jnp.asarray([1, 0, 1], jnp.int32)
    slots = OS(rs1=P16_1, rs2=P8_0, rd=P16_1, rs2_packed=True)
    y_k = gemm(ac, bp, slots, impl="pallas")
    y_r = posit_gemm_ref(ac, bp, es, a_fmt=P16_1, b_fmt=P8_0, out_fmt=P16_1,
                         b_packed=True)
    assert (np.asarray(y_k) == np.asarray(y_r)).all()


def test_packed_kernel_epilogue():
    rng = np.random.default_rng(5)
    a = jnp.asarray(rng.normal(0, 1, (8, 32)).astype(np.float32))
    b = jnp.asarray(rng.normal(0, 1, (32, 8)).astype(np.float32))
    bias = jnp.asarray(rng.normal(0, 1, (8,)).astype(np.float32))
    ac = posit_encode(a, 16, 1)
    bp = pack_p8(posit_encode(b, 8, 0))
    es = jnp.asarray([1, 0, 1], jnp.int32)
    slots = OS(rs1=P16_1, rs2=P8_0, rd=P16_1, rs2_packed=True)
    y_k = gemm(ac, bp, slots, impl="pallas", bias=bias, activation="relu")
    y_r = posit_gemm_ref(ac, bp, es, a_fmt=P16_1, b_fmt=P8_0, out_fmt=P16_1,
                         b_packed=True, bias=bias, activation="relu")
    assert (np.asarray(y_k) == np.asarray(y_r)).all()


# ------------------------------------------------------- quire mixed exact ----

def test_quire_mixed_dot_exact_vs_fraction():
    """p16 x p8 dot under dataflow="quire": the posit result is the single
    RNE of the exact Fraction sum of the mixed products."""
    rng = np.random.default_rng(7)
    K = 24
    ac = rng.integers(0, 1 << 16, K).astype(np.uint16)
    bc = rng.integers(0, 256, K).astype(np.uint8)
    # exclude NaR to test the numeric path (NaR propagation is tested below)
    ac[ac == 0x8000] = 1
    bc[bc == 0x80] = 1
    slots = OS(rs1=P16_1, rs2=P8_0, rd=P16_1, dataflow="quire")
    got = posit_dot(jnp.asarray(ac)[None, :], jnp.asarray(bc)[:, None], slots)
    acc = Fraction(0)
    for x, y in zip(ac, bc):
        acc += (ref_codec.ref_decode(int(x), 16, 1)
                * ref_codec.ref_decode(int(y), 8, 0))
    want = ref_codec.ref_encode_exact(acc, 16, 1)
    assert int(np.asarray(got)[0, 0]) == want


def test_quire_mixed_dot_packed_and_nar():
    """Packed rs2 unpacks into the same exact quire; NaR propagates."""
    rng = np.random.default_rng(8)
    K = 16
    a = jnp.asarray(rng.normal(0, 1, (4, K)).astype(np.float32))
    b = jnp.asarray(rng.normal(0, 1, (K, 4)).astype(np.float32))
    ac = posit_encode(a, 16, 1)
    bc = posit_encode(b, 8, 0)
    slots = OS(rs1=P16_1, rs2=P8_0, rd=P16_1, dataflow="quire")
    y_plain = posit_dot(ac, bc, slots)
    y_packed = posit_dot(ac, pack_p8(bc), slots.with_packed())
    assert (np.asarray(y_plain) == np.asarray(y_packed)).all()
    bc_nar = np.asarray(bc).copy()
    bc_nar[0, 0] = 0x80  # NaR weight poisons column 0 only
    y_nar = posit_dot(ac, jnp.asarray(bc_nar), slots)
    assert (np.asarray(y_nar)[:, 0] == 0x8000).all()
    assert (np.asarray(y_nar)[:, 1:] == np.asarray(y_plain)[:, 1:]).all()


# --------------------------------------------------------- per-layer policy ----

def test_precision_policy_resolution_order():
    base = TransPolicy.from_names(weights="p16_1", kv_cache="p8_0",
                                  compute_dtype="bf16")
    pol = PrecisionPolicy(base=base, rules=(
        LayerRule("*attn/wq", PositFmt(16, 2)),
        LayerRule("*attn*", PositFmt(16, 1)),
        LayerRule("*mlp*", PositFmt(8, 0), packed=True),
    ))
    # first match wins, in declaration order
    assert pol.policy_for("blocks/attn/wq").weights == PositFmt(16, 2)
    assert pol.policy_for("blocks/attn/wk").weights == PositFmt(16, 1)
    mlp = pol.policy_for("blocks/mlp/gate")
    assert mlp.weights == PositFmt(8, 0) and mlp.pack_weights
    # no match -> base unchanged
    assert pol.policy_for("lm_head") == base
    # non-weight roles delegate to the base (duck-typed TransPolicy)
    assert pol.kv_cache == base.kv_cache
    assert pol.compute_dtype == "bf16"
    assert "precision=" in pol.describe()


def test_precision_presets_and_spec_parsing():
    for name in ("uniform-p16", "p8-weights", "p8-packed", "attn-p16-mlp-p8"):
        pol = get_precision_policy(name)
        assert pol.name == name
    mixed = get_precision_policy("attn-p16-mlp-p8")
    assert mixed.policy_for("blocks/attn/wq").weights.nbits == 16
    mlp = mixed.policy_for("blocks/mlp/down")
    assert mlp.weights.nbits == 8 and mlp.pack_weights
    spec = get_precision_policy("*mlp*=p8_0:packed,*=p16_1")
    assert spec.policy_for("x/mlp/up").pack_weights
    assert spec.policy_for("anything").weights == PositFmt(16, 1)
    with pytest.raises(KeyError):
        get_precision_policy("no-such-preset")
    with pytest.raises(ValueError):
        LayerRule("*", PositFmt(16, 1), packed=True)  # packed requires p8
    # overlay keeps the new base's non-weight roles
    base = TransPolicy.from_names(kv_cache="p8_0", compute_dtype="bf16")
    over = get_precision_policy("attn-p16-mlp-p8", base=base)
    assert over.kv_cache == base.kv_cache


def test_preset_schedule_survives_base_overlay():
    """Preset weight schedules live in rules, so overlaying a serving base
    (which supplies kv_cache/compute roles) keeps the schedule intact."""
    base = TransPolicy.from_names(kv_cache="p8_0", compute_dtype="bf16")
    p = get_precision_policy("p8-packed", base=base)
    r = p.policy_for("blocks/mlp/gate")
    assert r.weights is not None and r.weights.nbits == 8 and r.pack_weights
    assert p.kv_cache == base.kv_cache
    # the mixed preset's p16 fallback covers unmatched layers, and
    # encoder-decoder self-attention counts as attention
    m = get_precision_policy("attn-p16-mlp-p8", base=TransPolicy())
    assert m.policy_for("blocks/ssm/x_proj").weights == PositFmt(16, 1)
    assert m.policy_for("dec_blocks/self/wq").weights == PositFmt(16, 1)


def test_none_rule_pins_base_format():
    """A weights=None rule pins the layer to the base format (it does NOT
    strip quantization) and stops later rules from firing."""
    base = TransPolicy.from_names(weights="p16_1")
    pol = PrecisionPolicy(base=base, rules=(
        LayerRule("*attn*"),                       # pin attention at base
        LayerRule("*", PositFmt(8, 0), packed=True),
    ))
    assert pol.policy_for("blocks/attn/wq") == base
    assert pol.policy_for("blocks/mlp/up").weights == PositFmt(8, 0)


def test_anchored_rule_matches_tree_and_callsite_paths():
    """Anchored (non-*) patterns match both the call-site logical path and
    the param-tree path, so quantize-time and decode-time formats agree."""
    from repro.models.layers import quantize_params

    pol = get_precision_policy("mlp/up=p8_0:packed,*=p16_1")
    # call-site spelling and tree spelling resolve identically
    assert pol.policy_for("mlp/up").pack_weights
    assert pol.policy_for("blocks/mlp/up").pack_weights
    assert pol.policy_for("blocks/attn/wq").weights == PositFmt(16, 1)
    params = {"blocks": {"mlp": {"up": {"w": jnp.ones((8, 4), jnp.float32)}}}}
    q = quantize_params(params, pol)
    assert "w_packed" in q["blocks"]["mlp"]["up"]


def test_cross_attention_quantize_apply_agreement():
    """Cross-attention params quantize under the tree path ("cross/wq") and
    apply under the same spelling (attention's ``path="cross"``), so a
    *cross*-targeting rule yields identical formats on both sides — the
    p16-codes-decoded-as-p8 corruption scenario cannot occur."""
    from repro.models import attention as attn
    from repro.models.layers import quantize_params

    cfg = attn.AttnCfg(d_model=32, n_heads=4, n_kv=2, head_dim=8,
                       is_cross=True, causal=False, use_rope=False)
    params = {"cross": attn.init_attention(jax.random.key(0), cfg)}
    pol = get_precision_policy("*cross*=p8_0,*=p16_1")
    q = quantize_params(params, pol)
    assert q["cross"]["wq"]["w_codes"].dtype == jnp.uint8  # p8 per the rule
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.normal(0, 1, (2, 6, 32)).astype(np.float32))
    kv = jnp.asarray(rng.normal(0, 1, (2, 5, 32)).astype(np.float32))
    y_q = attn.apply_attention(q["cross"], cfg, x, pol, xattn_kv=kv,
                               path="cross")
    # oracle: same math with the p8-rounded weights as plain floats
    deq = {
        name: {"w": posit_decode(q["cross"][name]["w_codes"], 8, 0)}
        for name in ("wq", "wk", "wv", "wo")
    }
    y_ref = attn.apply_attention(deq, cfg, x, TransPolicy(), xattn_kv=kv)
    np.testing.assert_allclose(np.asarray(y_q), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_quantize_params_skips_raw_conv_weights():
    """SSM causal-conv {"w","b"} dicts are consumed raw — never quantized."""
    from repro.models.layers import quantize_params

    params = {"ssm": {"conv_x": {"w": jnp.ones((4, 8), jnp.float32),
                                 "b": jnp.zeros((8,), jnp.float32)},
                      "x_proj": {"w": jnp.ones((8, 8), jnp.float32)}}}
    q = quantize_params(params, get_precision_policy("p8-weights"))
    assert "w" in q["ssm"]["conv_x"]          # untouched
    assert "w_codes" in q["ssm"]["x_proj"]    # quantized


def test_apply_linear_packed_layer():
    """A packed-quantized layer computes bit-identically to unpacked codes."""
    from repro.models.layers import apply_linear, init_linear, quantize_linear

    rng = np.random.default_rng(9)
    p = init_linear(jax.random.key(0), 32, 16, bias=True)
    x = jnp.asarray(rng.normal(0, 1, (4, 32)).astype(np.float32))
    pol = TransPolicy.from_names(weights="p8_0", compute_dtype="bf16",
                                 pack_weights=True)
    q_plain = quantize_linear(p, pol.weights)
    q_packed = quantize_linear(p, pol.weights, packed=True)
    assert "w_packed" in q_packed and q_packed["w_packed"].dtype == jnp.uint16
    y_plain = apply_linear(q_plain, x, pol, activation="gelu")
    y_packed = apply_linear(q_packed, x, pol, activation="gelu")
    assert (_bits(y_plain) == _bits(y_packed)).all()


def test_quantize_params_per_layer():
    """quantize_params routes each layer per the resolved policy: packed p8
    for MLP weights, p16 codes for attention, per the mixed preset."""
    from repro.models.layers import quantize_params

    params = {
        "blocks": {
            "attn": {"wq": {"w": jnp.ones((8, 8), jnp.float32)}},
            "mlp": {"up": {"w": jnp.ones((8, 16), jnp.float32)}},
        },
        "lm_head": {"w": jnp.ones((8, 10), jnp.float32)},
        "norm": {"g": jnp.ones((8,), jnp.float32)},
    }
    pol = get_precision_policy("attn-p16-mlp-p8")
    q = quantize_params(params, pol)
    assert q["blocks"]["attn"]["wq"]["w_codes"].dtype == jnp.uint16
    assert q["blocks"]["mlp"]["up"]["w_packed"].shape == (4, 16)
    assert q["lm_head"]["w_packed"].dtype == jnp.uint16
    assert "g" in q["norm"]  # non-linear params untouched
    assert "w" in params["blocks"]["mlp"]["up"]  # source tree not mutated
