"""Fused GEMM epilogue: kernels vs oracles, layers vs chained baseline.

Bit-exactness contract: epilogues without transcendental activations
(none/relu, bias, residual) are bit-exact between the Pallas kernels and
their jnp oracles; gelu/silu are allowed one posit-code ulp (XLA fuses the
surrounding multiply chain differently across lowering contexts — the same
tolerance the softmax kernel tests use).  The quire kernel's epilogue readout
is exact for any tiling, so it is compared bit-exactly for every activation
modulo that same transcendental caveat."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import F32, P8_0, P16_1, TransPolicy
from repro.core.codec import posit_decode, posit_encode
from repro.core.dot import posit_dot, posit_matmul_wx
from repro.core.pcsr import OperandSlots as OS
from repro.kernels.posit_gemm.posit_gemm import posit_gemm
from repro.kernels.posit_gemm.ref import posit_gemm_ref
from repro.kernels.posit_quire_gemm.posit_quire_gemm import posit_quire_gemm
from repro.kernels.posit_quire_gemm.ref import posit_quire_gemm_ref
from repro.models.layers import apply_gelu_mlp, apply_linear, apply_swiglu, init_linear, init_swiglu, init_gelu_mlp, quantize_linear

EXACT_ACTS = ("none", "relu")
TRANS_ACTS = ("gelu", "silu")


def _mk(m, k, n, seed=0):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.normal(0, 1, (m, k)).astype(np.float32)),
            jnp.asarray(rng.normal(0, 1, (k, n)).astype(np.float32)),
            jnp.asarray(rng.normal(0, 1, n).astype(np.float32)),
            jnp.asarray(rng.normal(0, 1, (m, n)).astype(np.float32)))


def _code_ulp_diff(got, want, nbits):
    """Max distance in signed code space (posit codes are value-ordered)."""
    full, half = 1 << nbits, 1 << (nbits - 1)
    sg = np.asarray(got).astype(np.int64)
    sw = np.asarray(want).astype(np.int64)
    sg = np.where(sg >= half, sg - full, sg)
    sw = np.where(sw >= half, sw - full, sw)
    return np.abs(sg - sw).max()


# ------------------------------------------------------ posit_gemm kernel -----
@pytest.mark.parametrize("fmt", [P8_0, P16_1])
@pytest.mark.parametrize("act", EXACT_ACTS)
def test_gemm_kernel_epilogue_bitexact(fmt, act):
    a, b, bias, res = _mk(32, 48, 24, seed=1)
    ac, bc = posit_encode(a, fmt.nbits, fmt.es), posit_encode(b, fmt.nbits, fmt.es)
    esv = jnp.asarray([fmt.es] * 3, jnp.int32)
    kw = dict(a_fmt=fmt, b_fmt=fmt, out_fmt=fmt)
    for use_b in (None, bias):
        for use_r in (None, res):
            got = posit_gemm(ac, bc, esv, interpret=True, block_m=32,
                             block_n=24, block_k=64, bias=use_b,
                             residual=use_r, activation=act, **kw)
            want = posit_gemm_ref(ac, bc, esv, bias=use_b, residual=use_r,
                                  activation=act, **kw)
            assert (np.asarray(got) == np.asarray(want)).all(), \
                (fmt, act, use_b is not None, use_r is not None)


@pytest.mark.parametrize("fmt", [P8_0, P16_1])
@pytest.mark.parametrize("act", TRANS_ACTS)
def test_gemm_kernel_epilogue_transcendental_1ulp(fmt, act):
    a, b, bias, res = _mk(32, 48, 24, seed=2)
    ac, bc = posit_encode(a, fmt.nbits, fmt.es), posit_encode(b, fmt.nbits, fmt.es)
    esv = jnp.asarray([fmt.es] * 3, jnp.int32)
    kw = dict(a_fmt=fmt, b_fmt=fmt, out_fmt=fmt)
    got = posit_gemm(ac, bc, esv, interpret=True, block_m=32, block_n=24,
                     block_k=64, bias=bias, residual=res, activation=act, **kw)
    want = posit_gemm_ref(ac, bc, esv, bias=bias, residual=res,
                          activation=act, **kw)
    assert _code_ulp_diff(got, want, fmt.nbits) <= 1


def test_gemm_kernel_epilogue_float_out():
    a, b, bias, res = _mk(32, 48, 24, seed=3)
    ac = posit_encode(a, 8, 0)
    esv = jnp.asarray([0, 0, 0], jnp.int32)
    got = posit_gemm(ac, b, esv, interpret=True, a_fmt=P8_0, b_fmt=F32,
                     out_fmt=F32, block_m=32, block_n=24, block_k=64,
                     bias=bias, residual=res, activation="relu")
    want = posit_gemm_ref(ac, b, esv, a_fmt=P8_0, b_fmt=F32, out_fmt=F32,
                          bias=bias, residual=res, activation="relu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_gemm_kernel_epilogue_multitile():
    """bias/residual BlockSpecs must index correctly across a multi-tile grid."""
    fmt = P16_1
    a, b, bias, res = _mk(100, 130, 50, seed=4)
    ac, bc = posit_encode(a, 16, 1), posit_encode(b, 16, 1)
    esv = jnp.asarray([1, 1, 1], jnp.int32)
    kw = dict(a_fmt=fmt, b_fmt=fmt, out_fmt=fmt)
    got = posit_gemm(ac, bc, esv, interpret=True, block_m=32, block_n=128,
                     block_k=128, bias=bias, residual=res, activation="relu", **kw)
    want = posit_gemm_ref(ac, bc, esv, bias=bias, residual=res,
                          activation="relu", **kw)
    # multi-k-tile accumulation order may flip the last posit rounding
    assert _code_ulp_diff(got, want, 16) <= 1


# ------------------------------------------------ posit_quire_gemm kernel -----
@pytest.mark.parametrize("act", EXACT_ACTS)
def test_quire_kernel_epilogue_bitexact_any_tiling(act):
    """Quire accumulation is exact, so tiling cannot shift the epilogue:
    kernel == oracle bit-for-bit even multi-tile."""
    fmt = P16_1
    a, b, bias, res = _mk(32, 48, 24, seed=5)
    ac, bc = posit_encode(a, 16, 1), posit_encode(b, 16, 1)
    esv = jnp.asarray([1, 1, 1], jnp.int32)
    kw = dict(a_fmt=fmt, b_fmt=fmt, out_fmt=fmt)
    got = posit_quire_gemm(ac, bc, esv, interpret=True, block_m=16,
                           block_n=16, block_k=16, bias=bias, residual=res,
                           activation=act, **kw)
    want = posit_quire_gemm_ref(ac, bc, esv, bias=bias, residual=res,
                                activation=act, **kw)
    assert (np.asarray(got) == np.asarray(want)).all()


def test_quire_kernel_no_epilogue_unchanged():
    """Without an epilogue the readout stays the exact quire->posit path."""
    fmt = P16_1
    a, b, _, _ = _mk(16, 32, 16, seed=6)
    ac, bc = posit_encode(a, 16, 1), posit_encode(b, 16, 1)
    esv = jnp.asarray([1, 1, 1], jnp.int32)
    kw = dict(a_fmt=fmt, b_fmt=fmt, out_fmt=fmt)
    got = posit_quire_gemm(ac, bc, esv, interpret=True, **kw)
    want = posit_quire_gemm_ref(ac, bc, esv, **kw)
    assert (np.asarray(got) == np.asarray(want)).all()


# -------------------------------------------------------------- posit_dot -----
@pytest.mark.parametrize("impl", ["fused", "unfused"])
def test_posit_dot_epilogue_fused_equals_chained(impl):
    """epilogue='chained' only reorders the schedule (barriers), never values."""
    a, b, bias, res = _mk(24, 40, 16, seed=7)
    ac, bc = posit_encode(a, 16, 1), posit_encode(b, 16, 1)
    slots = OS(rs1=P16_1, rs2=P16_1, rd=P16_1)
    outs = [posit_dot(ac, bc, slots, impl=impl, bias=bias, activation="gelu",
                      residual=res, epilogue=mode)
            for mode in ("fused", "chained")]
    assert (np.asarray(outs[0]) == np.asarray(outs[1])).all()


def test_posit_dot_quire_epilogue():
    """Quire dataflow + epilogue: exact accumulation, then f32 epilogue."""
    from repro.core.quire import quire_matmul

    a, b, bias, res = _mk(12, 64, 8, seed=8)
    ac, bc = posit_encode(a, 16, 1), posit_encode(b, 16, 1)
    slots = OS.uniform(P16_1, dataflow="quire")
    got = posit_dot(ac, bc, slots, bias=bias, activation="relu", residual=res)
    y = quire_matmul(ac, bc, P16_1, as_float=True)
    want = posit_encode(jnp.maximum(y + bias, 0.0) + res, 16, 1)
    assert (np.asarray(got) == np.asarray(want)).all()


def test_posit_matmul_wx_epilogue_encode():
    a, b, bias, res = _mk(24, 40, 16, seed=9)
    wc = posit_encode(b, 8, 0)
    got = posit_matmul_wx(a, wc, P8_0, bias=bias, activation="relu",
                          residual=res, out_fmt=P8_0,
                          compute_dtype=jnp.float32)
    y = jnp.matmul(a, posit_decode(wc, 8, 0).astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    want = posit_encode(jnp.maximum(y + bias, 0.0) + res, 8, 0)
    assert got.dtype == jnp.uint8
    assert (np.asarray(got) == np.asarray(want)).all()


# ------------------------------------------------------------ model layers ----
def test_apply_linear_fused_matches_manual():
    key = jax.random.key(0)
    p = init_linear(key, 32, 16, bias=True)
    pol = TransPolicy.from_names(weights="p8_0")
    q = quantize_linear(p, pol.weights)
    x = jax.random.normal(jax.random.key(1), (4, 32), jnp.float32)
    res = jax.random.normal(jax.random.key(2), (4, 16), jnp.float32)
    got = apply_linear(q, x, pol, activation="relu", residual=res)
    w = posit_decode(q["w_codes"], 8, 0).astype(jnp.float32)
    want = (jnp.maximum(x @ w + q["b"], 0.0) + res).astype(x.dtype)
    assert (np.asarray(got) == np.asarray(want)).all()


def test_apply_linear_chained_policy_same_values():
    key = jax.random.key(3)
    p = init_linear(key, 16, 24, bias=True)
    x = jax.random.normal(jax.random.key(4), (8, 16), jnp.float32)
    pol_f = TransPolicy.from_names(weights="p16_1")
    pol_c = TransPolicy.from_names(weights="p16_1", epilogue="chained")
    q = quantize_linear(p, pol_f.weights)
    yf = apply_linear(q, x, pol_f, activation="gelu")
    yc = apply_linear(q, x, pol_c, activation="gelu")
    assert (np.asarray(yf) == np.asarray(yc)).all()


def test_swiglu_and_gelu_mlp_residual_fusion():
    """MLP outputs must equal the unfused reference computation."""
    key = jax.random.key(5)
    pol = TransPolicy()
    x = jax.random.normal(jax.random.key(6), (2, 8, 16), jnp.float32)

    ps = init_swiglu(key, 16, 32)
    got = apply_swiglu(ps, x, pol, residual=x)
    g = x @ ps["gate"]["w"]
    u = x @ ps["up"]["w"]
    want = (jax.nn.silu(g) * u) @ ps["down"]["w"] + x
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)

    pg = init_gelu_mlp(key, 16, 32)
    got = apply_gelu_mlp(pg, x, pol, residual=x)
    h = jax.nn.gelu(x @ pg["up"]["w"] + pg["up"]["b"])
    want = h @ pg["down"]["w"] + pg["down"]["b"] + x
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


# ----------------------------------------------------- block-size rounding ----
@pytest.mark.parametrize("M,K,N", [(4, 520, 4), (3, 7, 5), (17, 100, 33)])
def test_gemm_small_dims_hardware_friendly_blocks(M, K, N):
    """min(block, dim) used to hand Mosaic ragged sub-lane tiles for small
    dims; blocks now round up to (sublane, lane) multiples and pad."""
    rng = np.random.default_rng(10)
    a = jnp.asarray(rng.normal(0, 1, (M, K)).astype(np.float32))
    b = jnp.asarray(rng.normal(0, 1, (K, N)).astype(np.float32))
    ac, bc = posit_encode(a, 8, 2), posit_encode(b, 8, 2)
    esv = jnp.asarray([2, 2, 2], jnp.int32)
    kw = dict(a_fmt=P8_0.with_es(2), b_fmt=P8_0.with_es(2),
              out_fmt=P8_0.with_es(2))
    got = posit_gemm(ac, bc, esv, interpret=True, **kw)
    want = posit_gemm_ref(ac, bc, esv, **kw)
    assert _code_ulp_diff(got, want, 8) <= 1
    assert got.shape == (M, N)


def test_round_block_properties():
    from repro.kernels import round_block, sublane

    assert sublane(jnp.uint8) == 32
    assert sublane(jnp.uint16) == 16
    assert sublane(jnp.float32) == 8
    for dim, block, mult in [(4, 256, 8), (300, 256, 128), (17, 64, 32)]:
        r = round_block(dim, block, mult)
        assert r % mult == 0 and r >= min(block, dim)
