"""repro.calib tests: observer streaming, the analytic posit error model vs
measured codec round-trips, the byte-budgeted search, policy JSON round
trips, and the fmt[@es][:packed] rule grammar (DESIGN.md §11)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.calib import errmodel, observe
from repro.calib.observe import Observer, TensorStats, observing
from repro.calib.search import (calibrate_model, emit_policy, p8_floor_bytes,
                                resolve_budget, save_artifact, search)
from repro.configs import get_arch
from repro.core.pcsr import TransPolicy
from repro.core.policy import (PRECISION_PRESETS, PrecisionPolicy,
                               get_precision_policy, parse_fmt_token)
from repro.core.types import P8_0, P8_2, P16_1, P16_3, PositFmt
from repro.models.layers import quantize_params
from repro.models.registry import build_model


# ---------------------------------------------------------------- grammar ----

def test_parse_fmt_token_plain_and_at_es():
    assert parse_fmt_token("p8_0") == P8_0
    assert parse_fmt_token("p8@2") == P8_2
    assert parse_fmt_token("p16@3") == P16_3
    assert parse_fmt_token(" p16_1@3 ") == P16_3   # @es overrides the suffix
    assert parse_fmt_token("p8_0@0") == P8_0


@pytest.mark.parametrize("tok", ["p8@4", "p16@-1", "p8@99"])
def test_parse_fmt_token_es_out_of_range(tok):
    with pytest.raises(ValueError, match="out of range"):
        parse_fmt_token(tok)


def test_parse_fmt_token_malformed():
    with pytest.raises(ValueError, match="integer"):
        parse_fmt_token("p8@x")
    with pytest.raises(ValueError, match="needs an exponent size"):
        parse_fmt_token("p16")
    with pytest.raises(ValueError, match="posit"):
        parse_fmt_token("bf16@1")
    with pytest.raises(ValueError, match="posit"):
        parse_fmt_token("f32")


def test_spec_parser_dynamic_es():
    pol = get_precision_policy("*attn*=p16@2,*mlp*=p8@1:packed,*=p16_1")
    assert pol.policy_for("blocks/attn/wq").weights == PositFmt(16, 2)
    mlp = pol.policy_for("blocks/mlp/up")
    assert mlp.weights == PositFmt(8, 1) and mlp.pack_weights
    assert pol.policy_for("lm_head").weights == P16_1


def test_spec_parser_rejects_bad_rules():
    with pytest.raises(ValueError, match="out of range"):
        get_precision_policy("*=p8@7")
    with pytest.raises(ValueError):                 # packed needs p8
        get_precision_policy("*=p16@1:packed")
    with pytest.raises(ValueError, match="modifier"):
        get_precision_policy("*=p8_0:quick")


# ------------------------------------------------------------ policy JSON ----

def test_transpolicy_json_roundtrip():
    pol = TransPolicy.from_names(weights="p8_0", kv_cache="p8_2",
                                 gradients="p16_1", compute_dtype="bf16",
                                 pack_weights=True, codec_impl="lut",
                                 epilogue="chained", attn_impl="xla",
                                 exact_collectives=True)
    assert TransPolicy.from_json(pol.to_json()) == pol
    assert TransPolicy.from_json(TransPolicy().to_json()) == TransPolicy()


def test_transpolicy_json_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown"):
        TransPolicy.from_json({"weights": "p8_0", "wat": 1})


def test_precision_policy_json_roundtrip():
    for name, pol in PRECISION_PRESETS.items():
        back = PrecisionPolicy.from_json(pol.to_json())
        assert back == pol, name
    # es survives the round trip (the dynamic-es bit of the artifact)
    pol = get_precision_policy("*mlp*=p8@3:packed,*=p16@2")
    back = PrecisionPolicy.from_json(pol.to_json())
    assert back.rules == pol.rules and back.base == pol.base


def test_precision_policy_file_loading(tmp_path):
    pol = PRECISION_PRESETS["attn-p16-mlp-p8"]
    doc = pol.to_json()
    doc["meta"] = {"anything": "ignored on load"}
    path = tmp_path / "cal.json"
    path.write_text(json.dumps(doc))
    loaded = get_precision_policy("@" + str(path))
    assert loaded.rules == pol.rules
    # base= overlay keeps the serving policy's non-weight roles
    serve_base = TransPolicy.from_names(kv_cache="p8_0", compute_dtype="bf16")
    overlaid = get_precision_policy("@" + str(path), base=serve_base)
    assert overlaid.kv_cache == serve_base.kv_cache
    assert overlaid.rules == pol.rules


def test_precision_policy_file_rejects_other_docs(tmp_path):
    path = tmp_path / "not_policy.json"
    path.write_text(json.dumps({"kind": "something/else", "rules": []}))
    with pytest.raises(ValueError, match="not a precision-policy"):
        get_precision_policy("@" + str(path))


def test_precision_policy_json_rejects_typo_rules():
    # a hand-edited {"weight": ...} rule must not silently pin to base
    with pytest.raises(ValueError, match="unknown keys"):
        PrecisionPolicy.from_json({
            "base": TransPolicy().to_json(),
            "rules": [{"pattern": "mlp/*", "weight": "p8_2"}]})
    with pytest.raises(ValueError, match="missing 'pattern'"):
        PrecisionPolicy.from_json({
            "base": TransPolicy().to_json(),
            "rules": [{"weights": "p8_2"}]})


# ------------------------------------------------------------- error model ----

def test_significand_bits_taper():
    # p8 es=0 near 1.0: sign + 2 regime bits leave 5 fraction bits
    assert errmodel.significand_bits(8, 0, 0) == (5, 0)
    # p16 es=1 near 1.0: sign + 2 regime + 1 exponent -> 12 fraction bits
    assert errmodel.significand_bits(16, 1, 0) == (12, 0)
    # accuracy tapers away from 1.0 (non-increasing fraction bits)
    for es in range(4):
        fs = [errmodel.significand_bits(8, es, s)[0] for s in range(0, 40)]
        assert fs == sorted(fs, reverse=True)


def _assert_model_matches(nbits, es, s, n_samples):
    ana = errmodel.expected_sq_rel_err(nbits, es, s)
    mea = errmodel.measured_sq_rel_err(nbits, es, s, n_samples)
    assert mea > 0, (nbits, es, s)
    ratio = ana / mea
    # the clamp / truncated-es / f=0 branches are closed-form exact; the
    # f>=1 RNE-grid branch is a small-bias approximation (measured spread
    # over the full sweep: [0.995, 1.03]); bounds leave sampling-noise room
    assert 0.85 <= ratio <= 1.2, (
        f"P({nbits},{es}) binade {s}: analytic {ana:.4e} vs "
        f"measured {mea:.4e} (ratio {ratio:.3f})")


@pytest.mark.parametrize("es", [0, 1, 2, 3])
def test_errmodel_p8_exhaustive_binades(es):
    """Exhaustive p8 sweep: every binade the 256-code dynamic range spans
    (plus clamp margins), analytic vs measured through the real codec."""
    for s in range(-52, 52):
        _assert_model_matches(8, es, s, 8192)


@pytest.mark.parametrize("es", [0, 1, 2, 3])
def test_errmodel_p16_regime_boundaries(es):
    """p16 sweep concentrated on regime boundaries (where the significand
    width steps) plus the clamp edges of each es's dynamic range."""
    ms = (16 - 2) << es
    binades = {0, 1, -1, 2, -2, 5, -5}
    for k in (1, 2, 3, 6, 10, 13):               # regime steps
        step = k << es
        binades |= {step - 1, step, step + 1, -step - 1, -step, -step + 1}
    binades |= {ms - 1, ms, ms + 2, -ms, -ms - 1, -ms + 1}
    for s in sorted(binades):
        if abs(s) > 120:
            continue                              # beyond f32 normal range
        _assert_model_matches(16, es, s, 16384)


def test_errmodel_zero_and_outlier_accounting():
    st = TensorStats()
    # half zeros, half sitting exactly at 2^0
    hist = np.zeros((observe.NBINS,))
    hist[-observe.BIN_LO] = 50
    st.n, st.zeros, st.hist = 100.0, 50.0, hist
    e = errmodel.tensor_sq_rel_err(st, P8_0)
    assert e == pytest.approx(0.5 * errmodel.expected_sq_rel_err(8, 0, 0))
    assert errmodel.outlier_mass(st, P8_0) == 0.0
    st.hist = np.roll(hist, 10)                   # shift mass to 2^10 > maxpos
    assert errmodel.outlier_mass(st, P8_0) == pytest.approx(0.5)


def test_hist_range_sees_p8_es3_saturation():
    """BIN_HI must sit at/above p8_3's max_scale (48): saturating mass that
    clamps into an *in-range* bin would be scored as truncated-es error
    (~4x too small) and vanish from outlier_mass."""
    from repro.core.types import P8_3

    assert observe.BIN_HI >= P8_3.max_scale
    st = TensorStats()
    st.n = 10.0
    hist = np.zeros((observe.NBINS,))
    hist[P8_3.max_scale - observe.BIN_LO] = 10    # all mass at 2^48
    st.hist = hist
    assert errmodel.outlier_mass(st, P8_3) == pytest.approx(1.0)
    assert errmodel.tensor_sq_rel_err(st, P8_3) == pytest.approx(
        errmodel.expected_sq_rel_err(8, 3, P8_3.max_scale))


# ---------------------------------------------------------------- observer ----

def test_observer_streams_exact_stats():
    obs = Observer()
    arr = jnp.asarray([0.0, 0.75, 3.0, -4.0])
    with observing(obs):
        observe.record("site", "weight", arr)
    jax.effects_barrier()
    st = obs.get("site", "weight")
    assert st.n == 4 and st.zeros == 1
    assert st.abs_max == 4.0
    assert st.sum_sq == pytest.approx(0.75 ** 2 + 9.0 + 16.0)
    hist = st.hist
    assert hist[-1 - observe.BIN_LO] == 1         # 0.75 -> binade -1
    assert hist[1 - observe.BIN_LO] == 1          # 3.0  -> binade  1
    assert hist[2 - observe.BIN_LO] == 1          # 4.0  -> binade  2
    assert hist.sum() == 3                        # zeros excluded


def test_observer_streams_from_scan_and_jit():
    """The hook must work inside traced code (scanned layer stacks)."""
    obs = Observer()
    xs = jnp.stack([jnp.full((8,), 2.0 ** i) for i in range(3)])

    def body(c, x):
        observe.record("scanned", "act", x)
        return c, x * 1.0

    with observing(obs):
        jax.jit(lambda xs: jax.lax.scan(body, 0.0, xs))(xs)
    jax.effects_barrier()
    st = obs.get("scanned", "act")
    assert st.n == 24 and st.zeros == 0
    for i in range(3):
        assert st.hist[i - observe.BIN_LO] == 8


def test_observer_inactive_is_noop():
    observe.record("nowhere", "act", jnp.ones((4,)))  # must not raise
    assert not observe.is_active()


def test_observer_hist_counts_are_integer_exact():
    """Counts stream in int32: a float32 scatter-add saturates at 2^24 per
    binade, silently dropping mass for full-size linears."""
    obs = Observer()
    n = (1 << 21) + 3                 # one record, single binade
    with observing(obs):
        for _ in range(4):            # 4 * (2^21 + 3) > 2^23, exact in f64
            observe.record("big", "weight", jnp.ones((n,)))
    jax.effects_barrier()
    st = obs.get("big", "weight")
    assert st.hist[-observe.BIN_LO] == 4 * n
    assert st.n == 4 * n and st.zeros == 0


# ------------------------------------------------------------------ search ----

def _stats_at(s: int, n: float = 1000.0) -> TensorStats:
    st = TensorStats()
    st.n = n
    hist = np.zeros((observe.NBINS,))
    hist[s - observe.BIN_LO] = n
    st.hist = hist
    st.sum_sq = n * 4.0 ** s
    return st


def _toy_plans():
    from repro.calib.search import SitePlan

    # "attn" carries big activations (important), "mlp" small ones
    return [
        SitePlan("attn/wq", 1000, True, _stats_at(-4), act_rms=4.0),
        SitePlan("mlp/up", 4000, True, _stats_at(-4), act_rms=0.25),
        SitePlan("moe/w_up", 2000, False, _stats_at(-4), act_rms=1.0),
    ]


def test_resolve_budget_spellings():
    assert resolve_budget(None, 7000) == 7000
    assert resolve_budget("1.5x", 7000) == 10500
    assert resolve_budget("12345", 7000) == 12345
    assert resolve_budget(9000, 7000) == 9000


def test_search_respects_floor_and_budget():
    plans = _toy_plans()
    assert p8_floor_bytes(plans) == 7000
    with pytest.raises(ValueError, match="below the p8 floor"):
        search(plans, 6999)

    choice, report = search(plans, None)          # floor: everything p8
    assert all(f.nbits == 8 for f in choice.values())
    assert report["weight_bytes"] == 7000

    choice, report = search(plans, "2x")          # room for p16 everywhere
    assert all(f.nbits == 16 for f in choice.values())
    assert report["weight_bytes"] == 14000


def test_search_upgrades_best_error_per_byte_first():
    plans = _toy_plans()
    # room to upgrade only the smallest sites: the high-importance small
    # "attn/wq" (gain/byte beats the big low-importance "mlp/up")
    choice, report = search(plans, 7000 + 1000 + 2000)
    assert choice["attn/wq"].nbits == 16
    assert choice["moe/w_up"].nbits == 16
    assert choice["mlp/up"].nbits == 8
    # monotone: more budget never predicts worse error
    scores = [search(plans, b)[1]["predicted_err_score"]
              for b in (7000, 9000, 11000, 14000)]
    assert scores == sorted(scores, reverse=True)


def test_emit_policy_packed_and_pin():
    plans = _toy_plans()
    choice, _ = search(plans, None)
    pol = emit_policy(plans, choice, base=TransPolicy(), name="t")
    attn = pol.policy_for("blocks/attn/wq")       # tree-path spelling
    assert attn.weights.nbits == 8 and attn.pack_weights
    moe = pol.policy_for("moe/w_up")              # pack_ok=False site
    assert moe.weights.nbits == 8 and not moe.pack_weights
    # the catch-all pins unobserved layers to the base (float) format
    assert pol.policy_for("never/observed").weights is None


# ------------------------------------------------------------- end to end ----

@pytest.fixture(scope="module")
def small_model():
    cfg = get_arch("phi3-mini-3.8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        bool(jnp.array_equal(x, y)) for x, y in zip(la, lb))


def test_calibrate_model_end_to_end(small_model, tmp_path):
    cfg, model, params = small_model
    rng = np.random.default_rng(0)

    def batch():
        return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32))),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)))}

    base = TransPolicy()
    pol, report = calibrate_model(
        lambda b: model.loss(params, b, base)[0], [batch(), batch()],
        params, base=base, name="t")
    # every linear the quantizer walks got a calibrated rule (incl. lm_head)
    sites = {s["path"] for s in report["sites"]}
    assert {"attn/wq", "mlp/gate", "mlp/down", "lm_head"} <= sites
    # floor budget -> p8 everywhere, per-site es chosen from the data
    assert all(s["fmt"].startswith("p8_") for s in report["sites"])
    assert any(not s["fmt"].endswith("_0") for s in report["sites"]), \
        "calibration should move es off the uniform-0 default somewhere"

    # measured acceptance at equal bytes: calibrated beats the p8 preset
    eval_batch = batch()
    ref = model.forward(params, eval_batch, base)

    def rel_err(p):
        h = model.forward(params, eval_batch, p)
        return float(jnp.sqrt(jnp.mean((h - ref) ** 2) / jnp.mean(ref ** 2)))

    preset = PRECISION_PRESETS["p8-weights"].with_base(base)
    assert rel_err(pol) < rel_err(preset)

    # artifact round trip: reloaded policy quantizes bit-identically
    path = tmp_path / "cal.json"
    save_artifact(str(path), pol, report)
    loaded = get_precision_policy("@" + str(path))
    assert _tree_equal(quantize_params(params, pol),
                       quantize_params(params, loaded))
    meta = json.loads(path.read_text())["meta"]
    assert meta["n_sites"] == len(sites)


def test_observer_does_not_perturb_forward(small_model):
    cfg, model, params = small_model
    b = {"tokens": jnp.asarray(np.arange(64).reshape(2, 32) % cfg.vocab)}
    pol = TransPolicy.from_names(weights="p8_0")
    ref = model.forward(params, b, pol)
    with observing(Observer()):
        seen = model.forward(params, b, pol)
    assert _tree_equal(ref, seen)


def test_hillclimb_calibrated_variant_builds():
    from repro.launch.hillclimb import VARIANTS, _calibrated_policy

    assert "prec_calibrated" in VARIANTS
    pol = _calibrated_policy(get_arch("phi3-mini-3.8b"))
    assert isinstance(pol, PrecisionPolicy)
    assert pol.compute_dtype == "bf16"
    assert pol.policy_for("blocks/mlp/up").weights is not None


def test_serve_calibrate_cli(tmp_path, capsys):
    from repro.launch import serve

    out = tmp_path / "cal.json"
    serve.main(["--arch", "phi3-mini-3.8b", "--reduced", "--batch", "2",
                "--prompt-len", "8", "--gen", "4", "--policy", "p8-serve",
                "--calibrate", "2", "--policy-out", str(out),
                "--quantize-weights"])
    lines = [json.loads(ln) for ln in capsys.readouterr().out.splitlines()
             if ln.startswith("{")]
    # every stdout line is a JSON object tagged with its kind
    assert all("kind" in ln for ln in lines)
    cal = next(ln["calibration"] for ln in lines
               if ln["kind"] == "serve/calibration")
    assert cal["n_sites"] >= 4
    assert os.path.exists(out)
    # the artifact reloads as a serving policy
    pol = get_precision_policy("@" + str(out))
    assert pol.policy_for("blocks/mlp/up").weights.nbits == 8
    final = next(ln for ln in lines if ln["kind"] == "serve/report")
    assert "weight_bytes_policy" in final and "decode_tok_per_s" in final
