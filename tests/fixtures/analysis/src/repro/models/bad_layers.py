"""RA001 fixture: linear entry call sites missing ``path=``."""
from repro.models.layers import apply_linear


def forward(p, x, policy):
    y = apply_linear(p["up"], x, policy)
    y = apply_linear(p["down"], y, policy)  # repro: noqa=RA001
    return apply_linear(p["out"], y, policy, path="out")
