"""RA004 fixture: zip-under-the-GIL checkpoint writes."""
import numpy as np


def save(path, params):
    np.savez(path, **params)
    np.savez_compressed(path + ".z", **params)  # repro: noqa=RA004
