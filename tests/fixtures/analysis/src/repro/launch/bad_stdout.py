"""RA002/RA003 fixture: wall-clock timing and untagged stdout prints."""
import json
import sys
import time


def report(stats):
    t0 = time.time()
    print("starting run")
    print(json.dumps(stats))
    print(json.dumps({"elapsed": time.time() - t0}))
    print("suppressed human diagnostics")  # repro: noqa=RA003
    print("real diagnostics", file=sys.stderr)
    print(json.dumps({"kind": "fixture/ok", "n": len(stats)}))
