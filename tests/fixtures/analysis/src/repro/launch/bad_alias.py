"""RA006 fixture: zero-copy asarray aliasing of a mutated host buffer."""
import jax.numpy as jnp
import numpy as np


class Grid:
    def __init__(self, n):
        self.lens = np.zeros((n,), np.int32)

    def bump(self, i):
        self.lens[i] += 1

    def device_lens(self):
        return jnp.asarray(self.lens)

    def device_lens_safe(self):
        return jnp.asarray(self.lens.copy())
