"""RA005 fixture: engine mutation outside the EngineDriver surface."""


class EngineDriver:
    def __init__(self, engine):
        self.engine = engine

    def drive(self):
        self.engine.step()  # inside the driver surface: allowed


def hot_patch(driver, policy):
    driver.engine.apply_policy(policy)
    driver.engine.paused = True
