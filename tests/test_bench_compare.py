"""benchmarks/compare.py — the CI benchmark regression gate."""
import json

import pytest

from benchmarks import compare as cmp


def _write(dirpath, bench, rows, *, ok=True, smoke=True, backend="cpu"):
    rec = {"bench": bench, "ok": ok, "smoke": smoke, "backend": backend,
           "elapsed_s": 1.0, "rows": rows}
    (dirpath / f"BENCH_{bench}.json").write_text(json.dumps(rec))


def _row(name, us, derived=""):
    return {"name": name, "us_per_call": us, "derived": derived}


@pytest.fixture
def dirs(tmp_path):
    old = tmp_path / "old"
    new = tmp_path / "new"
    old.mkdir()
    new.mkdir()
    return old, new


def test_identical_runs_pass(dirs):
    old, new = dirs
    rows = [_row("g/x", 100.0, "rel_err=0.01000")]
    _write(old, "mixed", rows)
    _write(new, "mixed", rows)
    rc = cmp.main(["--old", str(old), "--new", str(new)])
    assert rc == 0


def test_throughput_regression_fails(dirs):
    old, new = dirs
    _write(old, "mixed", [_row("g/x", 100.0)])
    _write(new, "mixed", [_row("g/x", 130.0)])  # +30% > 15% gate
    assert cmp.main(["--old", str(old), "--new", str(new)]) == 1
    # within the gate -> pass
    _write(new, "mixed", [_row("g/x", 110.0)])
    assert cmp.main(["--old", str(old), "--new", str(new)]) == 0


def test_small_rows_skipped_as_noise(dirs):
    old, new = dirs
    _write(old, "mixed", [_row("g/tiny", 10.0)])
    _write(new, "mixed", [_row("g/tiny", 40.0)])  # 4x, but under --min-us
    assert cmp.main(["--old", str(old), "--new", str(new)]) == 0
    assert cmp.main(["--old", str(old), "--new", str(new),
                     "--min-us", "5"]) == 1


def test_accuracy_regression_fails(dirs):
    old, new = dirs
    _write(old, "quire", [_row("dot", 500.0, "mean_ulp=0.0 rel_err=0.00900")])
    _write(new, "quire", [_row("dot", 500.0, "mean_ulp=2.0 rel_err=0.00900")])
    assert cmp.main(["--old", str(old), "--new", str(new)]) == 1
    # equal accuracy passes
    _write(new, "quire", [_row("dot", 500.0, "mean_ulp=0.0 rel_err=0.00900")])
    assert cmp.main(["--old", str(old), "--new", str(new)]) == 0
    # improvement passes
    _write(new, "quire", [_row("dot", 500.0, "mean_ulp=0.0 rel_err=0.00100")])
    assert cmp.main(["--old", str(old), "--new", str(new)]) == 0


def test_accuracy_nan_or_vanished_metric_fails(dirs):
    old, new = dirs
    _write(old, "mixed", [_row("g/x", 500.0, "rel_err=0.00123")])
    # metric collapses to NaN -> regression
    _write(new, "mixed", [_row("g/x", 500.0, "rel_err=nan")])
    assert cmp.main(["--old", str(old), "--new", str(new)]) == 1
    # metric goes to inf -> regression
    _write(new, "mixed", [_row("g/x", 500.0, "rel_err=inf")])
    assert cmp.main(["--old", str(old), "--new", str(new)]) == 1
    # metric vanishes from the row entirely -> regression
    _write(new, "mixed", [_row("g/x", 500.0, "")])
    assert cmp.main(["--old", str(old), "--new", str(new)]) == 1


def test_missing_old_dir(dirs, tmp_path):
    _, new = dirs
    _write(new, "mixed", [_row("g/x", 100.0)])
    missing = str(tmp_path / "nope")
    assert cmp.main(["--old", missing, "--new", str(new)]) == 1
    assert cmp.main(["--old", missing, "--new", str(new),
                     "--allow-missing"]) == 0


def test_added_removed_rows_and_config_mismatch(dirs):
    old, new = dirs
    _write(old, "mixed", [_row("g/old_only", 100.0)])
    _write(new, "mixed", [_row("g/new_only", 100.0)])
    _write(new, "table4", [_row("g/x", 100.0)])  # whole bench is new
    assert cmp.main(["--old", str(old), "--new", str(new)]) == 0
    rows, regs = cmp.compare(cmp.load_dir(str(old)), cmp.load_dir(str(new)))
    status = {(r["bench"], r["row"]): r["status"] for r in rows}
    assert status[("mixed", "g/new_only")] == "added"
    assert status[("mixed", "g/old_only")] == "removed"
    assert status[("table4", "(new benchmark)")] == "added"
    # smoke vs full runs never compare
    _write(new, "mixed", [_row("g/old_only", 900.0)], smoke=False)
    assert cmp.main(["--old", str(old), "--new", str(new)]) == 0


def test_summary_markdown(dirs, tmp_path):
    old, new = dirs
    _write(old, "mixed", [_row("g/x", 100.0)])
    _write(new, "mixed", [_row("g/x", 130.0)])
    summary = tmp_path / "summary.md"
    rc = cmp.main(["--old", str(old), "--new", str(new),
                   "--summary", str(summary)])
    assert rc == 1
    text = summary.read_text()
    assert "| bench |" in text and "REGRESSION" in text
