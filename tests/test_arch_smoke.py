"""Per-architecture smoke tests: reduced config, one forward/train step on CPU,
output shapes + finiteness; serving decode smoke for every family.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.core.pcsr import FP32_POLICY, TransPolicy
from repro.models.registry import build_model

B, S = 2, 32


def _smoke_batch(cfg, rng):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S))),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S))),
    }
    if cfg.family == "whisper":
        batch["frames"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.enc_frames, cfg.d_model)).astype(np.float32))
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.n_patches, cfg.d_model)).astype(np.float32))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_train_step(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    params = model.init(jax.random.key(0))
    batch = _smoke_batch(cfg, rng)

    def loss_fn(p):
        loss, metrics = model.loss(p, batch, FP32_POLICY)
        return loss, metrics

    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    assert np.isfinite(float(loss)), (arch, float(loss))
    # a uniform-random-token CE should start near log(vocab)
    assert float(metrics["ce"]) < np.log(cfg.vocab) + 1.0
    # every gradient leaf finite and at least one nonzero
    leaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in leaves), arch
    assert any(float(jnp.abs(g).max()) > 0 for g in leaves), arch
    # one SGD step changes the params
    new_params = jax.tree.map(lambda p, g: p - 0.01 * g, params, grads)
    assert any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_train_step_posit_policy(arch):
    """Same smoke under a posit transprecision policy (STE weights + p8 KV)."""
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    rng = np.random.default_rng(1)
    policy = TransPolicy.from_names(weights="p16_1")
    batch = _smoke_batch(cfg, rng)
    params = model.init(jax.random.key(1))
    loss, metrics = model.loss(params, batch, policy)
    assert np.isfinite(float(loss)), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_decode_smoke(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    rng = np.random.default_rng(2)
    params = model.init(jax.random.key(2))
    policy = TransPolicy.from_names(kv_cache="p8_0")
    S_max = 64

    if cfg.family == "whisper":
        batch = _smoke_batch(cfg, rng)
        cache = model.init_cache(params, batch, policy, S_max)
    else:
        cache = model.init_cache(B, S_max, policy)

    tok = jnp.asarray(rng.integers(0, cfg.vocab, (B,)))
    logits, cache = model.decode_step(params, tok, cache, policy)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all(), arch
    # second step advances pos and stays finite
    logits2, cache = model.decode_step(params, tok, cache, policy)
    assert int(cache["pos"]) == 2
    assert np.isfinite(np.asarray(logits2)).all(), arch


@pytest.mark.parametrize("arch", ["yi-34b", "gemma3-4b", "olmoe-1b-7b",
                                  "internvl2-2b"])
def test_arch_prefill_then_decode(arch):
    """Prefill path consistency: greedy next token from prefill == from forward."""
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    rng = np.random.default_rng(3)
    params = model.init(jax.random.key(3))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, 16)))
    kw = {}
    if cfg.family == "vlm":
        kw["patch_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.n_patches, cfg.d_model)).astype(np.float32))
    logits, cache = model.prefill(params, tokens, FP32_POLICY, S_max=48, **kw)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    tok = jnp.argmax(logits, -1)
    logits2, cache = model.decode_step(params, tok, cache, FP32_POLICY)
    assert np.isfinite(np.asarray(logits2)).all()
