"""Tests: true-posit integer ALU (PERCIVAL baseline) and Table-I fcvt ops."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

# hypothesis is optional (declared in pyproject [test] extras): collection of
# this module must never hard-error without it — only the property test skips.
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover
    given = settings = st = None

from repro.core import alu, convert, ref_codec
from repro.core.codec import posit_decode


# --------------------------------------------------------------------- ALU ----
@pytest.mark.parametrize("es", [0, 1, 2, 3])
def test_alu_add_p8_sampled_vs_oracle(es):
    rng = np.random.default_rng(es)
    a = rng.integers(0, 256, 4000).astype(np.uint8)
    b = rng.integers(0, 256, 4000).astype(np.uint8)
    got = np.asarray(alu.posit_add(jnp.asarray(a), jnp.asarray(b), 8, es))
    want = np.array([ref_codec.ref_add(int(x), int(y), 8, es) for x, y in zip(a, b)])
    assert (got == want).all(), (a[got != want][:5], b[got != want][:5])


@pytest.mark.parametrize("es", [0, 1, 2, 3])
def test_alu_mul_p8_sampled_vs_oracle(es):
    rng = np.random.default_rng(10 + es)
    a = rng.integers(0, 256, 4000).astype(np.uint8)
    b = rng.integers(0, 256, 4000).astype(np.uint8)
    got = np.asarray(alu.posit_mul(jnp.asarray(a), jnp.asarray(b), 8, es))
    want = np.array([ref_codec.ref_mul(int(x), int(y), 8, es) for x, y in zip(a, b)])
    assert (got == want).all()


@pytest.mark.parametrize("op", ["add", "mul"])
@pytest.mark.parametrize("es", [0, 1, 3])
def test_alu_p16_sampled_vs_oracle(op, es):
    rng = np.random.default_rng(99)
    a = rng.integers(0, 65536, 2500).astype(np.uint16)
    b = rng.integers(0, 65536, 2500).astype(np.uint16)
    fn = alu.posit_add if op == "add" else alu.posit_mul
    ref = ref_codec.ref_add if op == "add" else ref_codec.ref_mul
    got = np.asarray(fn(jnp.asarray(a), jnp.asarray(b), 16, es))
    want = np.array([ref(int(x), int(y), 16, es) for x, y in zip(a, b)])
    assert (got == want).all(), (a[got != want][:5], b[got != want][:5])


def test_alu_edge_cases():
    # 0 + x == x; NaR propagates; x - x == 0
    for n, es in [(8, 0), (16, 1)]:
        dt = np.uint8 if n == 8 else np.uint16
        nar = dt(1 << (n - 1))
        rng = np.random.default_rng(5)
        x = rng.integers(0, 1 << n, 64).astype(dt)
        zero = np.zeros(64, dtype=dt)
        assert (np.asarray(alu.posit_add(jnp.asarray(zero), jnp.asarray(x), n, es)) == x).all()
        got = np.asarray(alu.posit_add(jnp.asarray(np.full(64, nar)), jnp.asarray(x), n, es))
        assert (got == nar).all()
        x_no_nar = np.where(x == nar, dt(0), x)
        got = np.asarray(alu.posit_sub(jnp.asarray(x_no_nar), jnp.asarray(x_no_nar), n, es))
        assert (got == 0).all()


if st is not None:
    @settings(max_examples=150, deadline=None)
    @given(st.integers(0, 255), st.integers(0, 255), st.sampled_from([0, 1, 2]))
    def test_alu_add_commutative(a, b, es):
        r1 = int(np.asarray(alu.posit_add(jnp.uint8(a), jnp.uint8(b), 8, es)))
        r2 = int(np.asarray(alu.posit_add(jnp.uint8(b), jnp.uint8(a), 8, es)))
        assert r1 == r2
else:
    def test_alu_add_commutative():
        pytest.importorskip("hypothesis")


# ------------------------------------------------------------------- fcvt -----
def test_fcvt_roundtrip_f32():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 4, 512).astype(np.float32))
    # p16 -> f32 -> p16 is identity on p16-representable values
    p = convert.fcvt_p16_s(x, es=1)
    f = convert.fcvt_s_p16(p, es=1)
    p2 = convert.fcvt_p16_s(f, es=1)
    assert (np.asarray(p) == np.asarray(p2)).all()


def test_fcvt_p8_to_p16_exact():
    """Every p8 value is exactly representable in p16 with the same es."""
    for es in (0, 1, 2):
        codes8 = jnp.asarray(np.arange(256, dtype=np.uint8))
        up = convert.fcvt_p16_p8(codes8, es_in=es, es_out=es)
        back = convert.fcvt_p8_p16(up, es_in=es, es_out=es)
        v8 = np.asarray(posit_decode(codes8, 8, es))
        v16 = np.asarray(posit_decode(up, 16, es))
        ok = (v8 == v16) | (np.isnan(v8) & np.isnan(v16))
        assert ok.all()
        assert (np.asarray(back) == np.asarray(codes8)).all()


def test_fcvt_cross_es_matches_oracle():
    rng = np.random.default_rng(1)
    codes = rng.integers(0, 65536, 2000).astype(np.uint16)
    got = np.asarray(convert.fcvt_p16_p16(jnp.asarray(codes), es_in=3, es_out=0))
    want = np.array([ref_codec.ref_convert(int(c), 16, 3, 16, 0) for c in codes])
    assert (got == want).all()


def test_fcvt_p16_to_p8_matches_oracle():
    rng = np.random.default_rng(2)
    codes = rng.integers(0, 65536, 2000).astype(np.uint16)
    got = np.asarray(convert.fcvt_p8_p16(jnp.asarray(codes), es_in=1, es_out=0))
    want = np.array([ref_codec.ref_convert(int(c), 16, 1, 8, 0) for c in codes])
    assert (got == want).all()


def test_fcvt_dynamic_es_no_retrace():
    calls = []

    @jax.jit
    def cvt(c, es_in, es_out):
        calls.append(1)
        return convert.fcvt_p16_p16(c, es_in, es_out)

    codes = jnp.asarray(np.arange(0, 65536, 7, dtype=np.uint16))
    for ei in (0, 1, 2, 3):
        for eo in (0, 1, 2, 3):
            out = np.asarray(cvt(codes, jnp.int32(ei), jnp.int32(eo)))
            want = np.asarray(convert.fcvt_p16_p16(codes, ei, eo))
            assert (out == want).all(), (ei, eo)
    assert len(calls) == 1
