"""Integration tests for the async serving plane (launch/server.py).

One real ``ServingServer`` (paged engine, port 0) runs on a background
event-loop thread for the whole module; tests speak actual HTTP/1.1 and
RFC 6455 WebSocket over sockets — no test doubles anywhere, so the drive
thread, op inbox, subscriber bridging, chunked encoding, and the WS
handshake are all exercised end to end.

Marked ``slow``: building the paged engine compiles prefill + decode.
"""
import base64
import hashlib
import json
import socket
import threading
import time

import pytest

pytestmark = pytest.mark.slow

MAX_QUEUE = 4


def _http(port, method, path, body=None, timeout=120):
    """One-shot HTTP/1.1 exchange; de-chunks streamed responses."""
    s = socket.create_connection(("127.0.0.1", port), timeout=timeout)
    payload = json.dumps(body).encode() if body is not None else b""
    s.sendall((f"{method} {path} HTTP/1.1\r\nHost: x\r\n"
               f"Content-Length: {len(payload)}\r\n\r\n").encode() + payload)
    data = b""
    while True:
        chunk = s.recv(65536)
        if not chunk:
            break
        data += chunk
    s.close()
    head, _, rest = data.partition(b"\r\n\r\n")
    status = int(head.split()[1])
    if b"chunked" not in head.lower():
        return status, rest
    out = b""
    while rest:
        n_hex, _, rest = rest.partition(b"\r\n")
        n = int(n_hex, 16)
        if n == 0:
            break
        out, rest = out + rest[:n], rest[n + 2:]
    return status, out


@pytest.fixture(scope="module")
def server():
    import asyncio

    from repro.launch.config import ServeConfig
    from repro.launch.server import build_server

    scfg = ServeConfig(arch="yi-34b", reduced=True, continuous=True,
                       paged=True, max_slots=2, prompt_len=32, gen=480,
                       port=0, max_queue=MAX_QUEUE).validate()
    loop = asyncio.new_event_loop()
    box = {}
    started = threading.Event()

    def run():
        asyncio.set_event_loop(loop)
        box["server"] = build_server(scfg)
        loop.run_until_complete(box["server"].start())
        started.set()
        loop.run_forever()

    t = threading.Thread(target=run, daemon=True, name="server-loop")
    t.start()
    assert started.wait(300), "server did not start"
    yield box["server"]
    asyncio.run_coroutine_threadsafe(box["server"].stop(), loop).result(30)

    async def _drain():
        # connection handlers abandoned by the tests (flood sockets) die
        # here rather than as destroyed-pending warnings at loop teardown
        tasks = [x for x in asyncio.all_tasks()
                 if x is not asyncio.current_task()]
        for x in tasks:
            x.cancel()
        await asyncio.gather(*tasks, return_exceptions=True)

    asyncio.run_coroutine_threadsafe(_drain(), loop).result(30)
    loop.call_soon_threadsafe(loop.stop)
    t.join(10)


def test_generate_blocking(server):
    st, body = _http(server.port, "POST", "/v1/generate",
                     {"prompt": [1, 2, 3, 4], "max_new_tokens": 4})
    comp = json.loads(body)
    assert st == 200, (st, comp)
    assert len(comp["tokens"]) == 4 and comp["finish_reason"] == "max_new"
    assert comp["v"] == 1                       # Completion schema version


def test_generate_validates_against_s_max(server):
    s_max = server.driver.engine.S_max
    st, body = _http(server.port, "POST", "/v1/generate",
                     {"prompt": [1, 2, 3], "max_new_tokens": s_max})
    assert st == 400 and b"S_max" in body
    st, body = _http(server.port, "POST", "/v1/generate",
                     {"prompt": "not ids", "max_new_tokens": 2})
    assert st == 400


def test_ndjson_stream(server):
    st, body = _http(server.port, "POST", "/v1/generate",
                     {"prompt": [5, 6, 7], "max_new_tokens": 3,
                      "stream": True})
    assert st == 200
    evs = [json.loads(line) for line in body.decode().splitlines()]
    toks = [e["token"] for e in evs if e["event"] == "token"]
    assert len(toks) == 3 and evs[-1]["event"] == "finish"


def test_detach_then_websocket_replays_stream(server):
    st, body = _http(server.port, "POST", "/v1/generate",
                     {"prompt": [9, 8, 7], "max_new_tokens": 3,
                      "detach": True})
    assert st == 202
    rid = json.loads(body)["rid"]
    s = socket.create_connection(("127.0.0.1", server.port), timeout=120)
    key = base64.b64encode(b"0123456789abcdef").decode()
    s.sendall((f"GET /v1/stream?rid={rid} HTTP/1.1\r\nHost: x\r\n"
               f"Upgrade: websocket\r\nConnection: Upgrade\r\n"
               f"Sec-WebSocket-Key: {key}\r\n"
               f"Sec-WebSocket-Version: 13\r\n\r\n").encode())
    buf = b""
    while b"\r\n\r\n" not in buf:
        buf += s.recv(4096)
    head, _, buf = buf.partition(b"\r\n\r\n")
    assert b"101" in head.split(b"\r\n")[0]
    want = base64.b64encode(hashlib.sha1(
        (key + "258EAFA5-E914-47DA-95CA-C5AB0DC85B11").encode())
        .digest()).decode()
    assert want in head.decode()                # RFC 6455 accept token
    events = []
    while True:
        while len(buf) < 2:
            buf += s.recv(4096)
        op, n, off = buf[0] & 0x0F, buf[1] & 0x7F, 2
        if n == 126:
            while len(buf) < 4:
                buf += s.recv(4096)
            n, off = int.from_bytes(buf[2:4], "big"), 4
        while len(buf) < off + n:
            buf += s.recv(4096)
        payload, buf = buf[off:off + n], buf[off + n:]
        if op == 0x8:                           # close frame
            break
        events.append(json.loads(payload))
        if events[-1]["event"] == "finish":
            break
    s.close()
    toks = [e["token"] for e in events if e["event"] == "token"]
    assert len(toks) == 3 and events[-1]["event"] == "finish"


def test_disconnect_mid_stream_evicts(server):
    before = len([c for c in server.driver.engine.completions
                  if c.finish_reason == "cancel"])
    s = socket.create_connection(("127.0.0.1", server.port), timeout=120)
    payload = json.dumps({"prompt": [3, 3, 3], "max_new_tokens": 400,
                          "stream": True}).encode()
    s.sendall((f"POST /v1/generate HTTP/1.1\r\nHost: x\r\n"
               f"Content-Length: {len(payload)}\r\n\r\n").encode() + payload)
    s.recv(1024)                                # stream has started
    s.close()                                   # hang up mid-generation
    deadline = time.time() + 60
    while time.time() < deadline:
        cancels = len([c for c in server.driver.engine.completions
                       if c.finish_reason == "cancel"])
        if cancels > before:
            break
        time.sleep(0.2)
    else:
        pytest.fail("disconnect did not cancel/evict the request")


def test_backpressure_429_past_max_queue(server):
    socks, codes = [], []
    for _ in range(3 * MAX_QUEUE):
        s = socket.create_connection(("127.0.0.1", server.port), timeout=120)
        p = json.dumps({"prompt": [1, 1, 1], "max_new_tokens": 400,
                        "stream": True}).encode()
        s.sendall((f"POST /v1/generate HTTP/1.1\r\nHost: x\r\n"
                   f"Content-Length: {len(p)}\r\n\r\n").encode() + p)
        codes.append(int(s.recv(64).split()[1]))
        socks.append(s)
    assert 429 in codes, codes                  # bounded admission queue
    assert codes[0] == 200                      # but requests do get in
    for s in socks:
        s.close()                               # disconnect-evict drains


def test_stats_healthz_metrics(server):
    st, body = _http(server.port, "GET", "/healthz")
    assert st == 200 and json.loads(body)["ok"]
    st, body = _http(server.port, "GET", "/v1/stats")
    d = json.loads(body)
    assert st == 200
    assert d["config"]["kind"] == "repro/serve-config"
    assert d["max_queue"] == MAX_QUEUE
    assert "prefix_cache" in d                  # paged engine exposes pool
    st, body = _http(server.port, "GET", "/metrics")
    assert st == 200 and b"# HELP" in body
