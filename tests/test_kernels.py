"""Pallas kernel validation (interpret=True) against pure-jnp oracles.

Sweeps shapes / posit precisions / es values per kernel; single-k-tile GEMM
cases assert bit-exact posit outputs, multi-tile cases compare decoded values
(tile-order FP accumulation may differ in the last ulp before posit rounding).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import F32, BF16, P8_0, P8_2, P16_1, P16_2
from repro.core.codec import posit_decode, posit_encode
from repro.kernels.posit_gemm.posit_gemm import posit_gemm
from repro.kernels.posit_gemm.ref import posit_gemm_ref
from repro.kernels.posit_codec.posit_codec import decode_kernel, encode_kernel
from repro.kernels.posit_codec import ref as codec_ref
from repro.kernels.posit_attention import ops as attn_ops
from repro.kernels.posit_attention.posit_attention import posit_decode_attention
from repro.kernels.posit_attention.ref import posit_decode_attention_ref
from repro.kernels.posit_softmax.posit_softmax import posit_softmax_kernel
from repro.kernels.posit_softmax.ref import posit_softmax_ref


def _rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, scale, shape).astype(np.float32))


# ------------------------------------------------------------------ GEMM ------
@pytest.mark.parametrize("fmt,es", [(P8_0, 0), (P8_2, 2), (P16_1, 1), (P16_2, 3)])
def test_gemm_posit_x_posit_single_ktile_bitexact(fmt, es):
    a = _rand((32, 48), 1)
    b = _rand((48, 24), 2)
    ac, bc = posit_encode(a, fmt.nbits, es), posit_encode(b, fmt.nbits, es)
    esv = jnp.asarray([es, es, es], jnp.int32)
    kw = dict(a_fmt=fmt, b_fmt=fmt, out_fmt=fmt)
    got = posit_gemm(ac, bc, esv, interpret=True, block_m=32, block_n=24,
                     block_k=64, **kw)
    want = posit_gemm_ref(ac, bc, esv, **kw)
    assert got.dtype == want.dtype
    assert (np.asarray(got) == np.asarray(want)).all()


@pytest.mark.parametrize(
    "M,K,N,bm,bn,bk",
    [(128, 256, 64, 64, 64, 64),   # multi-tile every dim
     (100, 130, 50, 64, 64, 64),   # ragged/padded
     (8, 8, 8, 128, 128, 128),     # tiny, single tile padded
     (256, 512, 128, 128, 128, 256)],
)
def test_gemm_posit16_shapes_sweep(M, K, N, bm, bn, bk):
    fmt = P16_1
    a, b = _rand((M, K), 3), _rand((K, N), 4)
    ac, bc = posit_encode(a, 16, 1), posit_encode(b, 16, 1)
    esv = jnp.asarray([1, 1, 1], jnp.int32)
    kw = dict(a_fmt=fmt, b_fmt=fmt, out_fmt=fmt)
    got = posit_gemm(ac, bc, esv, interpret=True, block_m=bm, block_n=bn,
                     block_k=bk, **kw)
    want = posit_gemm_ref(ac, bc, esv, **kw)
    gv = np.asarray(posit_decode(got, 16, 1))
    wv = np.asarray(posit_decode(want, 16, 1))
    # accumulation order may differ across k tiles: the f32 reorder noise can
    # flip one posit rounding -> allow one p16 ulp at tapered-precision
    # magnitudes (2^-9 rel) plus an absolute floor of f32 dot-product noise
    np.testing.assert_allclose(gv, wv, rtol=2 ** -9, atol=K * 2e-6)


@pytest.mark.parametrize("out_fmt", [F32, BF16])
def test_gemm_float_output(out_fmt):
    a, b = _rand((64, 64), 5), _rand((64, 64), 6)
    ac = posit_encode(a, 8, 0)
    esv = jnp.asarray([0, 0, 0], jnp.int32)
    kw = dict(a_fmt=P8_0, b_fmt=F32, out_fmt=out_fmt)
    got = posit_gemm(ac, b, esv, interpret=True, block_m=64, block_n=64,
                     block_k=64, **kw)
    want = posit_gemm_ref(ac, b, esv, **kw)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=1e-6, atol=1e-6)


def test_gemm_float_x_float_bypass():
    """All-float slots: kernel must equal a plain f32 matmul (IEEE path)."""
    a, b = _rand((64, 96), 7), _rand((96, 32), 8)
    esv = jnp.asarray([0, 0, 0], jnp.int32)
    got = posit_gemm(a, b, esv, interpret=True, a_fmt=F32, b_fmt=F32,
                     out_fmt=F32, block_m=64, block_n=32, block_k=96)
    want = jnp.matmul(a, b, preferred_element_type=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_gemm_dynamic_es_matches_static():
    a, b = _rand((32, 64), 9), _rand((64, 32), 10)
    ac, bc = posit_encode(a, 16, 2), posit_encode(b, 16, 0)
    kw = dict(a_fmt=P16_2, b_fmt=P16_1, out_fmt=P16_1,
              interpret=True, block_m=32, block_n=32, block_k=64)
    got = posit_gemm(ac, bc, jnp.asarray([2, 0, 3], jnp.int32), **kw)
    want = posit_gemm_ref(ac, bc, jnp.asarray([2, 0, 3], jnp.int32),
                          a_fmt=P16_2, b_fmt=P16_1, out_fmt=P16_1)
    assert (np.asarray(got) == np.asarray(want)).all()


# ----------------------------------------------------------- streaming codec --
@pytest.mark.parametrize("nbits,es", [(8, 0), (8, 3), (16, 1)])
@pytest.mark.parametrize("shape", [(1000,), (17, 300), (4, 5, 333)])
def test_codec_kernel_decode(nbits, es, shape):
    rng = np.random.default_rng(0)
    dt = np.uint8 if nbits == 8 else np.uint16
    codes = jnp.asarray(rng.integers(0, 1 << nbits, shape).astype(dt))
    got = decode_kernel(codes, es, nbits=nbits, interpret=True)
    want = codec_ref.decode_ref(codes, es, nbits=nbits)
    g, w = np.asarray(got), np.asarray(want)
    assert ((g == w) | (np.isnan(g) & np.isnan(w))).all()
    assert got.shape == shape


@pytest.mark.parametrize("nbits,es", [(8, 1), (16, 2)])
def test_codec_kernel_encode(nbits, es):
    x = _rand((33, 257), 11, scale=10.0)
    got = encode_kernel(x, es, nbits=nbits, interpret=True)
    want = codec_ref.encode_ref(x, es, nbits=nbits)
    assert (np.asarray(got) == np.asarray(want)).all()
    assert got.shape == x.shape


def test_codec_kernel_roundtrip_bf16_exact_for_p8():
    """p8 -> bf16 decode is exact (DESIGN.md: full-MXU-speed claim)."""
    codes = jnp.asarray(np.arange(256, dtype=np.uint8))
    f32 = decode_kernel(codes, 2, nbits=8, interpret=True)
    bf = decode_kernel(codes, 2, nbits=8, out_dtype_name="bfloat16", interpret=True)
    g, w = np.asarray(bf.astype(jnp.float32)), np.asarray(f32)
    assert ((g == w) | (np.isnan(g) & np.isnan(w))).all()


# ------------------------------------------------------------- attention ------
@pytest.mark.parametrize("kv_bits,es", [(8, 0), (16, 1)])
@pytest.mark.parametrize(
    "B,Hq,Hkv,S,d,bs",
    [(2, 4, 2, 256, 64, 128),    # GQA 2:1, multi s-tile
     (1, 8, 1, 128, 128, 128),   # MQA
     (3, 6, 6, 100, 32, 64)],    # MHA, ragged S
)
def test_decode_attention_vs_ref(kv_bits, es, B, Hq, Hkv, S, d, bs):
    rng = np.random.default_rng(12)
    q = jnp.asarray(rng.normal(0, 1, (B, Hq, d)).astype(np.float32))
    kf = rng.normal(0, 1, (B, Hkv, S, d)).astype(np.float32)
    vf = rng.normal(0, 1, (B, Hkv, S, d)).astype(np.float32)
    kc = posit_encode(jnp.asarray(kf), kv_bits, es)
    vc = posit_encode(jnp.asarray(vf), kv_bits, es)
    lengths = jnp.asarray(rng.integers(S // 2, S + 1, B), jnp.int32)
    got = posit_decode_attention(
        q, kc, vc, lengths, es, kv_bits=kv_bits, block_s=bs, interpret=True)
    want = posit_decode_attention_ref(q, kc, vc, lengths, es, kv_bits=kv_bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_respects_lengths():
    """Cache positions beyond `length` must not influence the output."""
    B, H, S, d = 1, 2, 128, 64
    rng = np.random.default_rng(13)
    q = jnp.asarray(rng.normal(0, 1, (B, H, d)).astype(np.float32))
    kf = rng.normal(0, 1, (B, H, S, d)).astype(np.float32)
    vf = rng.normal(0, 1, (B, H, S, d)).astype(np.float32)
    # poison the invalid tail
    kf[:, :, 64:] = 1e9
    vf[:, :, 64:] = -1e9
    kc, vc = posit_encode(jnp.asarray(kf), 8, 0), posit_encode(jnp.asarray(vf), 8, 0)
    lengths = jnp.asarray([64], jnp.int32)
    got = posit_decode_attention(q, kc, vc, lengths, 0, kv_bits=8,
                                 block_s=64, interpret=True)
    want = posit_decode_attention_ref(
        q, kc[:, :, :64], vc[:, :, :64], lengths, 0, kv_bits=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("kv_bits,es", [(8, 0), (16, 1)])
@pytest.mark.parametrize("B,Hq,Hkv,S,d,bs",
                         [(2, 4, 2, 256, 64, 128), (3, 6, 6, 100, 32, 64)])
def test_decode_attention_tiled_vs_ref(kv_bits, es, B, Hq, Hkv, S, d, bs):
    """The length-bounded tiled XLA path (the off-TPU serving contract)
    matches the full-softmax oracle on ragged lengths."""
    rng = np.random.default_rng(21)
    q = jnp.asarray(rng.normal(0, 1, (B, Hq, d)).astype(np.float32))
    kc = posit_encode(jnp.asarray(
        rng.normal(0, 1, (B, Hkv, S, d)).astype(np.float32)), kv_bits, es)
    vc = posit_encode(jnp.asarray(
        rng.normal(0, 1, (B, Hkv, S, d)).astype(np.float32)), kv_bits, es)
    lengths = jnp.asarray(rng.integers(1, S + 1, B), jnp.int32)
    got = attn_ops.posit_decode_attention_tiled(
        q, kc, vc, lengths, es, kv_bits=kv_bits, block_s=bs)
    want = posit_decode_attention_ref(q, kc, vc, lengths, es, kv_bits=kv_bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("impl", ["pallas", "tiled", "xla"])
def test_decode_attention_zero_length_rows(impl):
    """A row with length 0 (a free engine slot) returns exact zeros on every
    impl, not the uniform-softmax garbage a fully-masked softmax would
    produce (on TPU that garbage would be stale recycled-slot V)."""
    rng = np.random.default_rng(22)
    q = jnp.asarray(rng.normal(0, 1, (2, 2, 32)).astype(np.float32))
    kc = posit_encode(jnp.asarray(
        rng.normal(0, 1, (2, 2, 64, 32)).astype(np.float32)), 8, 0)
    vc = posit_encode(jnp.asarray(
        rng.normal(0, 1, (2, 2, 64, 32)).astype(np.float32)), 8, 0)
    got = attn_ops.decode_attention(q, kc, vc, jnp.asarray([0, 40]), 0,
                                    kv_bits=8, impl=impl, block_s=32)
    assert np.abs(np.asarray(got)[0]).max() == 0.0
    want = posit_decode_attention_ref(q, kc, vc, jnp.asarray([0, 40]), 0,
                                      kv_bits=8)
    np.testing.assert_allclose(np.asarray(got)[1], np.asarray(want)[1],
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("impl", ["pallas", "tiled"])
def test_decode_attention_rolling_mode(impl):
    """Rolling (circular window buffer) validity: lengths past the buffer
    size clamp to 'every slot valid' — the oracle with clamped lengths."""
    B, H, S, d = 2, 2, 128, 64
    rng = np.random.default_rng(23)
    q = jnp.asarray(rng.normal(0, 1, (B, H, d)).astype(np.float32))
    kc = posit_encode(jnp.asarray(
        rng.normal(0, 1, (B, H, S, d)).astype(np.float32)), 8, 0)
    vc = posit_encode(jnp.asarray(
        rng.normal(0, 1, (B, H, S, d)).astype(np.float32)), 8, 0)
    # row 0 has wrapped its window 3x over; row 1 is still filling it
    lengths = jnp.asarray([3 * S + 17, 40], jnp.int32)
    got = attn_ops.decode_attention(q, kc, vc, lengths, 0, kv_bits=8,
                                    impl=impl, rolling=True, block_s=64)
    want = posit_decode_attention_ref(
        q, kc, vc, jnp.minimum(lengths, S), 0, kv_bits=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("impl", ["pallas", "tiled", "xla"])
def test_decode_attention_float_kv_bypass(impl):
    """kv_bits=0 (float KV cache): identical masking/tiling contract, no
    codec — every impl agrees with a dense float softmax attention."""
    B, Hq, Hkv, S, d = 2, 4, 2, 96, 32
    rng = np.random.default_rng(24)
    q = jnp.asarray(rng.normal(0, 1, (B, Hq, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (B, Hkv, S, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (B, Hkv, S, d)).astype(np.float32))
    lengths = jnp.asarray([50, 96], jnp.int32)
    got = attn_ops.decode_attention(q, k, v, lengths, 0, kv_bits=0,
                                    impl=impl, block_s=32)
    kg = jnp.repeat(k, Hq // Hkv, axis=1)
    vg = jnp.repeat(v, Hq // Hkv, axis=1)
    scores = jnp.einsum("bhd,bhsd->bhs", q, kg) * (d ** -0.5)
    scores = jnp.where(jnp.arange(S)[None, None, :] < lengths[:, None, None],
                       scores, -1e30)
    want = jnp.einsum("bhs,bhsd->bhd", jax.nn.softmax(scores, -1), vg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------- softmax ------
@pytest.mark.parametrize("nbits,es", [(8, 0), (16, 1)])
@pytest.mark.parametrize("R,C", [(8, 8), (64, 128), (10, 300)])
def test_posit_softmax_kernel(nbits, es, R, C):
    rng = np.random.default_rng(14)
    logits = jnp.asarray(rng.normal(0, 3, (R, C)).astype(np.float32))
    codes = posit_encode(logits, nbits, es)
    got = posit_softmax_kernel(codes, es, nbits=nbits, interpret=True)
    want = posit_softmax_ref(codes, es, nbits=nbits)
    gv = np.asarray(posit_decode(got, nbits, es))
    wv = np.asarray(posit_decode(want, nbits, es))
    # f32 softmax then posit encode on both sides; padding may shift the last
    # ulp — compare in signed code space (posit codes are value-ordered), where
    # "one rounding flip" is exactly distance 1
    full, half = 1 << nbits, 1 << (nbits - 1)
    sg = np.asarray(got).astype(np.int64)
    sw = np.asarray(want).astype(np.int64)
    sg = np.where(sg >= half, sg - full, sg)
    sw = np.where(sw >= half, sw - full, sw)
    assert np.abs(sg - sw).max() <= 1
    np.testing.assert_allclose(gv, wv, rtol=2 ** -(nbits - 8), atol=1e-6)
    if nbits == 16:
        # sum~1 only survives encoding at p16; p8 rounds tiny probabilities up
        # systematically (values below ~2^-6 keep almost no fraction bits)
        np.testing.assert_allclose(gv.sum(-1), 1.0, atol=0.05)
