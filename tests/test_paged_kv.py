"""Paged posit KV cache: geometry, allocator invariants, engine exactness.

Three layers (DESIGN.md §14):

* ``PageGeometry`` — the kv_bits-aware page layout: at a fixed byte budget
  a p8 page holds 2x the tokens of a p16 page and 4x an f32 page.
* ``PagedKVCache`` — pure host allocator: chained block hashes, refcounts,
  COW, LRU retention of released prefixes.  Adversarial admit/fork/evict
  orders must keep :meth:`check_invariants` green after every mutation.
* ``PagedContinuousBatchingEngine`` — the exactness contract: a prefix-hit
  (warm) admission decodes bit-for-bit like the cold one, lifetime block
  reservation means admitted streams never die ``cache_full``, and a
  mid-stream snapshot -> reset -> restore loses zero tokens.

Engine comparisons reuse the same engine object (``reset()`` keeps the
compiled executables): XLA:CPU programs are not bit-identical across
separate compilations.
"""
import dataclasses

import numpy as np
import jax
import pytest

from repro.configs import get_arch
from repro.core.paged_kv import (PagedKVCache, PageGeometry, PoolExhausted,
                                 ROOT_DIGEST)
from repro.core.pcsr import TransPolicy
from repro.launch.engine import Request
from repro.launch.paged_engine import PagedContinuousBatchingEngine
from repro.models.registry import build_model


# --------------------------------------------------------------- geometry ---

def test_page_geometry_kv_bits_scaling():
    """Same page bytes: p8 codes hold 2x the tokens of p16, 4x of f32."""
    mk = lambda cb: PageGeometry(n_layers=2, n_kv=2, head_dim=16,
                                 code_bytes=cb, page_bytes=2048)
    p8, p16, f32 = mk(1), mk(2), mk(4)
    assert p8.block_tokens == 2 * p16.block_tokens == 4 * f32.block_tokens
    assert p8.block_tokens == 2048 // (2 * 2 * 16)
    # pool bytes are budgeted per page, so equal pages => equal bytes
    assert p8.pool_bytes(8) == p16.pool_bytes(8) == f32.pool_bytes(8)


def test_page_geometry_blocks_for_and_validation():
    g = PageGeometry(n_layers=1, n_kv=2, head_dim=16, code_bytes=1,
                     page_bytes=512)          # bt = 8
    assert g.block_tokens == 8
    assert g.blocks_for(1) == 1 and g.blocks_for(8) == 1
    assert g.blocks_for(9) == 2 and g.blocks_for(17) == 3
    with pytest.raises(ValueError, match="code_bytes"):
        PageGeometry(n_layers=1, n_kv=2, head_dim=16, code_bytes=3)
    with pytest.raises(ValueError, match="holds no tokens"):
        PageGeometry(n_layers=1, n_kv=64, head_dim=128, code_bytes=4,
                     page_bytes=64)


# --------------------------------------------------------------- allocator ---

def _mgr(n_blocks=8, max_slots=4, bt=4):
    geom = PageGeometry(n_layers=1, n_kv=1, head_dim=4, code_bytes=1,
                        page_bytes=2 * 4 * bt)
    assert geom.block_tokens == bt
    return PagedKVCache(geom, n_blocks=n_blocks, max_slots=max_slots)


def _admit(mgr, slot, tokens):
    """The engine's prefill bookkeeping, minus the device copies: match,
    claim, append fresh blocks, content-address full fresh chunks."""
    bt = mgr.geom.block_tokens
    match = mgr.match_prefix(tokens)
    mgr.claim_blocks(match.bids)
    mgr.begin_slot(slot, match.bids)
    digests = mgr.chunk_digests(tokens)
    parent = match.tail_digest
    pos = match.n_tokens
    while pos < len(tokens):
        n = min(bt, len(tokens) - pos)
        try:
            bid = mgr.append_block(slot)
        except PoolExhausted:
            mgr.release_slot(slot)      # the engine's unwind path
            raise
        if n == bt:
            digest, chunk = digests[pos // bt]
            mgr.register_full_block(bid, digest, parent, chunk)
            parent = digest
        pos += n
    return match


def test_chained_hash_covers_whole_prefix():
    """Identical chunk tokens after different prefixes hash differently —
    KV codes at a position depend on every earlier token."""
    mgr = _mgr(bt=4)
    a = mgr.chunk_digests([1, 2, 3, 4, 9, 9, 9, 9])
    b = mgr.chunk_digests([5, 6, 7, 8, 9, 9, 9, 9])
    assert a[0][1] != b[0][1] and a[0][0] != b[0][0]
    assert a[1][1] == b[1][1] == (9, 9, 9, 9)
    assert a[1][0] != b[1][0]           # same tokens, different chain
    # and the chain anchors at the module-level root digest
    assert mgr.chunk_digests([])== [] and isinstance(ROOT_DIGEST, str)


def test_block_table_round_trip_and_sentinel():
    mgr = _mgr(n_blocks=8, max_slots=3, bt=4)
    _admit(mgr, 0, list(range(10)))     # 3 blocks (2 full + tail)
    _admit(mgr, 1, list(range(4)))      # prefix hit on block 0
    tab = mgr.device_table(width=4)
    assert tab.shape == (3, 4) and tab.dtype == np.int32
    assert list(tab[0, :3]) == mgr.tables[0] and tab[0, 3] == mgr.sentinel
    assert tab[1, 0] == mgr.tables[0][0]        # shared first block
    assert (tab[2] == mgr.sentinel).all()
    with pytest.raises(AssertionError, match="table width"):
        mgr.device_table(width=2)
    mgr.check_invariants()


def test_prefix_hit_claim_and_lru_retention():
    mgr = _mgr(n_blocks=6, max_slots=2, bt=4)
    _admit(mgr, 0, list(range(8)))              # 2 published blocks
    mgr.release_slot(0)
    # released published blocks park in the LRU, still matchable
    assert len(mgr.lru) == 2 and mgr.available() == 6
    m = mgr.match_prefix(list(range(8)) + [99])
    assert m.n_tokens == 8 and len(m.bids) == 2
    mgr.claim_blocks(m.bids)                    # un-caches them
    mgr.begin_slot(0, m.bids)
    assert len(mgr.lru) == 0
    assert all(mgr.refcount[b] == 1 for b in m.bids)
    mgr.check_invariants()


def test_alloc_recycles_lru_and_unregisters():
    mgr = _mgr(n_blocks=2, max_slots=2, bt=4)
    _admit(mgr, 0, list(range(8)))
    mgr.release_slot(0)
    assert not mgr.free and len(mgr.lru) == 2
    bid = mgr.alloc()                   # recycles the least recently used
    assert bid not in mgr.hash_of       # its cached prefix is gone
    assert mgr.match_prefix(list(range(8))).n_tokens < 8
    mgr.release(bid)
    mgr.check_invariants()


def test_pool_exhausted_and_refcount_underflow():
    mgr = _mgr(n_blocks=1, max_slots=1, bt=4)
    bid = mgr.alloc()
    with pytest.raises(PoolExhausted):
        mgr.alloc()
    mgr.release(bid)
    with pytest.raises(AssertionError, match="underflow"):
        mgr.release(bid)


def test_first_writer_wins_registration():
    mgr = _mgr(bt=4)
    _admit(mgr, 0, list(range(4)))
    first = mgr.tables[0][0]
    # identical prompt admitted again while the first is still live: the
    # newcomer matches (storage dedup), no duplicate registration
    _admit(mgr, 1, list(range(4)))
    assert mgr.tables[1][0] == first and mgr.refcount[first] == 2
    # force a private duplicate and try to re-publish the same digest
    bid = mgr.append_block(1)
    digest, chunk = mgr.chunk_digests(list(range(4)))[0]
    mgr.register_full_block(bid, digest, ROOT_DIGEST, chunk)
    assert mgr.by_hash[digest] == first         # first writer kept
    assert bid not in mgr.hash_of
    mgr.check_invariants()


def test_cow_on_shared_and_published_tails():
    mgr = _mgr(n_blocks=8, max_slots=3, bt=4)
    _admit(mgr, 0, list(range(4)))              # tail full + published
    # published tail is immutable even at refcount 1
    cow = mgr.ensure_writable(0)
    assert cow is not None and cow[1] == mgr.tables[0][-1] != cow[0]
    assert mgr.cow_copies == 1
    mgr.check_invariants()
    # fork: aliased tail; each side's first write gets a private copy
    _admit(mgr, 1, [7, 7, 7, 7, 5])             # tail partial + private
    mgr.fork_slot(1, 2)
    assert mgr.tables[2] == mgr.tables[1]
    shared = mgr.tables[1][-1]
    assert mgr.refcount[shared] == 2
    assert mgr.ensure_writable(1) is not None
    assert mgr.tables[1][-1] != mgr.tables[2][-1] == shared
    assert mgr.ensure_writable(2) is None       # now private again
    mgr.check_invariants()


def test_invariants_under_adversarial_op_order():
    """Random admit / append / fork / COW / release storm; every mutation
    must keep refcounts == table references and the free/LRU/live
    partition exact."""
    rng = np.random.default_rng(0)
    mgr = _mgr(n_blocks=12, max_slots=4, bt=4)
    live = set()
    for _ in range(400):
        op = rng.integers(0, 5)
        try:
            if op == 0:                  # admit a prompt (maybe shared)
                free = [s for s in range(4) if s not in live]
                if free:
                    n = int(rng.integers(1, 10))
                    toks = list(rng.integers(0, 3, size=n))   # tiny vocab:
                    _admit(mgr, free[0], toks)                # hits likely
                    live.add(free[0])
            elif op == 1 and live:       # decode growth
                mgr.append_block(int(rng.choice(sorted(live))))
            elif op == 2 and live:       # COW before a tail write
                mgr.ensure_writable(int(rng.choice(sorted(live))))
            elif op == 3 and live:       # fork into a free slot
                free = [s for s in range(4) if s not in live]
                if free:
                    src = int(rng.choice(sorted(live)))
                    mgr.fork_slot(src, free[0])
                    live.add(free[0])
            elif op == 4 and live:       # eviction
                s = int(rng.choice(sorted(live)))
                mgr.release_slot(s)
                live.remove(s)
        except PoolExhausted:
            if live:                     # engine's response: evict someone
                s = int(rng.choice(sorted(live)))
                mgr.release_slot(s)
                live.remove(s)
        mgr.check_invariants()
    for s in sorted(live):
        mgr.release_slot(s)
    mgr.check_invariants()
    assert int((mgr.refcount > 0).sum()) == 0


def test_snapshot_meta_round_trip_and_geometry_guard():
    mgr = _mgr(n_blocks=8, max_slots=3, bt=4)
    _admit(mgr, 0, list(range(9)))
    _admit(mgr, 1, list(range(4)))
    mgr.ensure_writable(1)
    mgr.release_slot(0)
    meta = mgr.snapshot_meta()
    fresh = _mgr(n_blocks=8, max_slots=3, bt=4)
    fresh.restore_meta(meta)
    assert fresh.stats() == mgr.stats()
    assert fresh.tables == mgr.tables
    assert fresh.seen_digests() == mgr.seen_digests()
    assert list(fresh.lru) == list(mgr.lru)     # LRU order preserved
    wrong = _mgr(n_blocks=8, max_slots=3, bt=8)
    with pytest.raises(ValueError, match="geometry"):
        wrong.restore_meta(meta)
    small = _mgr(n_blocks=4, max_slots=3, bt=4)
    with pytest.raises(ValueError, match="blocks"):
        small.restore_meta(meta)


def test_begin_slot_requires_released_table():
    mgr = _mgr()
    _admit(mgr, 0, [1, 2])
    with pytest.raises(AssertionError, match="not released"):
        mgr.begin_slot(0, [])


# ------------------------------------------------------------------ engine ---

@pytest.fixture(scope="module")
def paged_setup():
    cfg = get_arch("yi-34b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    policy = TransPolicy.from_names(kv_cache="p8_0", compute_dtype="bf16",
                                    attn_impl="kernel")
    return cfg, model, params, policy


def _prompts(cfg, n, prompt_len, overlap):
    rng = np.random.default_rng(1234)
    n_shared = int(round(overlap * prompt_len))
    shared = rng.integers(0, cfg.vocab, size=n_shared)
    rng = np.random.default_rng(7)
    return [np.concatenate([shared,
                            rng.integers(0, cfg.vocab,
                                         size=prompt_len - n_shared)])
            .astype(np.int32) for _ in range(n)]


def _drain(eng):
    while eng.queue or eng.active.any():
        if eng.queue and eng.free_slots():
            eng.admit(now=0.0)
        if eng.active.any():
            eng.step(now=0.0)
    return {c.rid: (list(c.tokens), c.finish_reason)
            for c in eng.completions}


def test_warm_prefix_hit_decodes_bit_for_bit(paged_setup):
    """A prefix-hit admission reads claimed blocks where the cold one wrote
    fresh ones — the sampled streams must be identical, token for token."""
    cfg, model, params, policy = paged_setup
    eng = PagedContinuousBatchingEngine(model, params, policy, max_slots=2,
                                        S_max=64, page_bytes=2048,
                                        n_blocks=24)
    bt = eng.geom.block_tokens
    prompt = _prompts(cfg, 1, 2 * bt + 3, 1.0)[0]
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=5))
    cold = _drain(eng)[0]
    assert eng.prefix_stats()["hits"] == 0
    eng.submit(Request(rid=1, prompt=prompt.copy(), max_new_tokens=5))
    warm = _drain(eng)[1]
    st = eng.prefix_stats()
    assert st["hits"] == 1 and st["hit_tokens"] == 2 * bt
    assert warm == cold, (warm, cold)
    eng.manager.check_invariants()


def test_lifetime_reservation_no_mid_stream_eviction(paged_setup):
    """Admission reserves the whole request lifetime (prompt + decode
    growth): a pool too small for every request at once must queue, never
    evict an admitted stream as ``cache_full``."""
    cfg, model, params, policy = paged_setup
    eng = PagedContinuousBatchingEngine(model, params, policy, max_slots=4,
                                        S_max=64, page_bytes=2048,
                                        n_blocks=6)
    bt = eng.geom.block_tokens
    gen = 4
    prompts = _prompts(cfg, 4, bt + 2, 0.0)     # disjoint: no sharing help
    # 6 blocks, each lifetime needs 2 => at most 3 concurrent, 4 submitted
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=gen))
    done = _drain(eng)
    assert set(done) == set(range(4))
    for rid, (toks, reason) in done.items():
        assert reason == "max_new" and len(toks) == gen, (rid, done[rid])
    eng.manager.check_invariants()
    assert int((eng.manager.refcount > 0).sum()) == 0


def test_fork_cow_streams_complete(paged_setup):
    """A mid-decode fork aliases every block; both streams must finish and
    the divergence must go through copy-on-write, not corruption."""
    cfg, model, params, policy = paged_setup
    eng = PagedContinuousBatchingEngine(model, params, policy, max_slots=2,
                                        S_max=64, page_bytes=2048,
                                        n_blocks=24)
    prompt = _prompts(cfg, 1, eng.geom.block_tokens + 1, 1.0)[0]
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=6))
    eng.admit(now=0.0)
    eng.step(now=0.0)
    eng.fork(0, 1)
    done = _drain(eng)
    assert set(done) == {0, 1}
    assert done[0][1] == done[1][1] == "max_new"
    # greedy sampling: the clone must replay the parent exactly
    assert done[0][0] == done[1][0]
    assert eng.prefix_stats()["cow_copies"] >= 1
    eng.manager.check_invariants()


def test_snapshot_restore_mid_stream_zero_loss(paged_setup):
    """snapshot() after a few decode steps -> drain -> reset -> restore ->
    drain again: every stream finishes with the same tokens (block table,
    refcounts, and hash index ride the snapshot meta)."""
    cfg, model, params, policy = paged_setup
    eng = PagedContinuousBatchingEngine(model, params, policy, max_slots=4,
                                        S_max=64, page_bytes=2048,
                                        n_blocks=32)
    gen = 5
    prompts = _prompts(cfg, 4, 2 * eng.geom.block_tokens + 2, 0.9)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=gen))
    eng.admit(now=0.0)
    for _ in range(2):
        eng.step(now=0.0)
    mid = eng.snapshot()
    expect = _drain(eng)
    eng.reset()
    assert eng.prefix_stats()["hits"] == 0      # reset really cleared it
    eng.restore(mid, now=0.0)
    eng.manager.check_invariants()
    got = _drain(eng)
    assert got == expect
    # a slot-grid snapshot (no paged meta) must be refused
    bare = dict(mid)
    bare["meta"] = {k: v for k, v in mid["meta"].items() if k != "paged"}
    with pytest.raises(ValueError, match="paged"):
        eng.restore(bare, now=0.0)
