"""Distributed tests: posit-compressed collectives on a simulated 8-device
mesh (subprocess isolation so other tests keep a single-device view), plus
single-process tests for ftz / auto_es / pow2 scaling."""
import json
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.codec import auto_es, posit_decode, posit_encode
from repro.core import ref_codec

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.types import P8_0, P16_1
from repro.core.codec import posit_encode
from repro.core import ref_codec
from repro.distributed.collectives import (compressed_allreduce,
                                           compressed_psum, quire_psum_posit)

# jax.shard_map + check_vma are the current API; fall back to the
# experimental name + check_rep on older jax
if hasattr(jax, "shard_map"):
    _sm, _sm_kw = jax.shard_map, {"check_vma": False}
else:
    from jax.experimental.shard_map import shard_map as _sm
    _sm_kw = {"check_rep": False}

from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((2, 4), ("pod", "data"))
rng = np.random.default_rng(0)
M = 1 << 14
x = jnp.asarray(rng.normal(0, 1e-3, (8, M)).astype(np.float32))
out = {}

# two-hop compressed allreduce == true sum (within p16 tolerance)
f = jax.jit(_sm(
    lambda v: compressed_allreduce(v, P16_1, "pod"),
    mesh=mesh, in_specs=P(("pod", "data")), out_specs=P(("pod", "data")),
    **_sm_kw))
got = np.asarray(f(x), np.float64)
true = np.tile(x.reshape(2, 4, M).sum(0), (2, 1, 1)).reshape(8, M)
out["allreduce_rel"] = float(np.abs(got - true).mean() / np.abs(true).mean())

# compressed_psum f32 bypass is exact
g = jax.jit(_sm(
    lambda v: compressed_psum(v, None)[0],
    mesh=mesh, in_specs=P(("pod", "data")), out_specs=P(("pod", "data")),
    **_sm_kw))
got2 = np.asarray(g(x), np.float64)
true2 = np.tile(x.astype(np.float64).sum(0), (8, 1))
out["bypass_exact"] = bool(np.allclose(got2, true2, rtol=1e-6))

# error feedback: residual returned and nonzero for p8
h = jax.jit(_sm(
    lambda v, r: compressed_psum(v, P8_0, residual=r)[1],
    mesh=mesh, in_specs=(P(("pod", "data")),) * 2,
    out_specs=P(("pod", "data")), **_sm_kw))
res = np.asarray(h(x, jnp.zeros_like(x)))
out["residual_nonzero"] = bool(np.abs(res).max() > 0)

# quire-domain psum of posit codes is EXACT: bit-identical to the Fraction
# sum of the per-device values with one terminal rounding
Mq = 256
xq = jnp.asarray(rng.normal(0, 1.0, (8, Mq)).astype(np.float32))
codes = posit_encode(xq, 16, 1)
qf = jax.jit(_sm(
    lambda c: quire_psum_posit(c, P16_1, "pod"),
    mesh=mesh, in_specs=P(("pod", "data")), out_specs=P(("pod", "data")),
    **_sm_kw))
got_q = np.asarray(qf(codes)).reshape(2, 4 * Mq)
host = np.asarray(codes).reshape(2, 4 * Mq)
want_q = np.empty(4 * Mq, np.uint16)
for j in range(4 * Mq):
    acc = sum(ref_codec.ref_decode(int(host[d, j]), 16, 1) for d in range(2))
    want_q[j] = ref_codec.ref_encode_exact(acc, 16, 1)
out["quire_psum_exact"] = bool((got_q == want_q[None, :]).all())

# exact compressed_psum: inter hop in the quire domain, still accurate
pe = jax.jit(_sm(
    lambda v: compressed_psum(v, P16_1, exact=True)[0],
    mesh=mesh, in_specs=P(("pod", "data")), out_specs=P(("pod", "data")),
    **_sm_kw))
got_e = np.asarray(pe(x), np.float64)
true_e = np.tile(x.astype(np.float64).sum(0), (8, 1))
rel = np.abs(got_e - true_e).mean() / np.abs(true_e).mean()
out["exact_psum_rel"] = float(rel)
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def child_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    lines = [ln for ln in r.stdout.splitlines() if ln.startswith("RESULT ")]
    assert lines, f"child failed:\n{r.stderr[-2000:]}"
    return json.loads(lines[0][7:])


def test_compressed_allreduce_accurate(child_results):
    assert child_results["allreduce_rel"] < 5e-4  # p16 + pow2 scaling


def test_psum_f32_bypass_exact(child_results):
    assert child_results["bypass_exact"]


def test_error_feedback_residual(child_results):
    assert child_results["residual_nonzero"]


def test_quire_psum_bitexact(child_results):
    """Quire-domain psum == Fraction-exact sum + one rounding, bit-for-bit."""
    assert child_results["quire_psum_exact"]


def test_exact_compressed_psum_accurate(child_results):
    """exact=True inter hop: only the per-device encode rounds, so the error
    is bounded by the p16 encode alone (comfortably under the two-hop path)."""
    assert child_results["exact_psum_rel"] < 5e-4


# ------------------------------------------------------- single-process -------
def test_ftz_matches_rne_to_zero_union():
    """ftz encode == RNE against {0} U posits (checked vs oracle + midpoint)."""
    n, es = 16, 1
    from repro.core.types import PositFmt
    fmt = PositFmt(n, es)
    xs = np.array([0.0, fmt.minpos / 4, fmt.minpos / 2, fmt.minpos * 0.51,
                   fmt.minpos, -fmt.minpos / 4, -fmt.minpos / 2], np.float32)
    got = np.asarray(posit_encode(jnp.asarray(xs), n, es, ftz=True)).astype(int)
    # below or at half-minpos -> 0; above -> minpos code (1 / 2^n-1 for neg)
    want = [0, 0, 0, 1, 1, 0, 0]
    assert list(got) == want, got
    # far from zero, ftz must be identical to standard encode
    rng = np.random.default_rng(0)
    big = jnp.asarray(rng.normal(0, 10, 4096).astype(np.float32))
    assert (np.asarray(posit_encode(big, n, es, ftz=True)) ==
            np.asarray(posit_encode(big, n, es))).all()


@pytest.mark.parametrize("scale,expect_small_es", [(1.0, True), (1e30, False)])
def test_auto_es_scales_with_range(scale, expect_small_es):
    rng = np.random.default_rng(1)
    x = jnp.asarray((rng.normal(0, scale, 1024)).astype(np.float32))
    es = int(auto_es(x, 16))
    assert 0 <= es <= 3
    if expect_small_es:
        assert es == 0
    else:
        assert es >= 2


def test_auto_es_covers_range():
    """Chosen es must put max|x| within posit range (no saturation at the top)."""
    for scale in (1e-6, 1e-2, 1.0, 1e4, 1e12):
        rng = np.random.default_rng(2)
        x = jnp.asarray((rng.normal(0, scale, 512)).astype(np.float32))
        es = int(auto_es(x, 16))
        smax = 14 << es
        amax = float(jnp.max(jnp.abs(x)))
        assert abs(np.log2(amax)) <= smax, (scale, es)


def test_decode_encode_with_ftz_roundtrip():
    """ftz only affects the sub-minpos band: all posit values round-trip."""
    codes = jnp.asarray(np.arange(65536, dtype=np.uint16))
    vals = posit_decode(codes, 16, 2)
    back = posit_encode(vals, 16, 2, ftz=True)
    assert (np.asarray(back) == np.asarray(codes)).all()
