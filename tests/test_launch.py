"""Launch-layer unit tests: collective parsing, sharding rules, roofline math,
param counting, the dry-run cell driver — everything that doesn't need 512
devices."""
import dataclasses

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import cells, get_arch, get_shape
from repro.launch import dryrun
from repro.launch.dryrun import cost_analysis_dict, parse_collectives
from repro.launch.mesh import make_mesh_compat
from repro.launch.roofline import analyse, model_flops, param_count
from repro.launch.sharding import param_spec


HLO_SAMPLE = """
HloModule test
ENTRY main {
  %p0 = f32[16,128]{1,0} parameter(0)
  %ar = f32[16,128]{1,0} all-reduce(%p0), replica_groups={}, to_apply=%add
  %ag = u8[64,128]{1,0} all-gather(%small), dimensions={0}
  %a2a = (u16[8,32]{1,0}, u16[8,32]{1,0}) all-to-all(%x, %y), dimensions={0}
  %rs-start = bf16[4,256]{1,0} reduce-scatter-start(%z), dimensions={0}
  ROOT %cp = f32[2,2]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
}
"""


def test_parse_collectives():
    got = parse_collectives(HLO_SAMPLE)
    assert got["all-reduce"]["bytes"] == 16 * 128 * 4
    assert got["all-gather"]["bytes"] == 64 * 128 * 1
    assert got["all-to-all"]["bytes"] == 2 * 8 * 32 * 2
    assert got["reduce-scatter"]["bytes"] == 4 * 256 * 2
    assert got["collective-permute"]["bytes"] == 2 * 2 * 4
    assert got["all-reduce"]["count"] == 1
    assert got["all-gather"]["by_dtype"] == {"u8": 64 * 128}


def test_parse_collectives_skips_done():
    txt = "%x = f32[8]{0} all-reduce-start(%a)\n%y = f32[8]{0} all-reduce-done(%x)"
    got = parse_collectives(txt)
    assert got["all-reduce"]["count"] == 1  # start counted, done skipped


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


def test_param_spec_rules():
    mesh = FakeMesh()
    # col-parallel: (in, out) -> (data, model)
    assert param_spec("blocks/attn/wq/w", (60, 7168, 7168), mesh) == \
        P(None, "data", "model")
    # row-parallel
    assert param_spec("blocks/mlp/down/w", (60, 20480, 7168), mesh) == \
        P(None, "model", "data")
    # experts: E over model (EP)
    assert param_spec("blocks/moe/w_gate", (16, 64, 2048, 1024), mesh) == \
        P(None, "model", "data", None)
    # embedding: vocab over model when divisible
    assert param_spec("embed/table", (64000, 7168), mesh) == P("model", "data")
    # granite's 49155 vocab is not divisible -> unsharded vocab dim
    assert param_spec("embed/table", (49155, 1536), mesh) == P(None, "data")
    # optimizer moments mirror the parameter
    assert param_spec("mu/blocks/attn/wq/w/m", (60, 7168, 7168), mesh) == \
        P(None, "data", "model")
    # norms replicate
    assert param_spec("blocks/ln1/g", (60, 7168), mesh) == P(None, None)
    # posit-coded weights shard like their float counterparts
    assert param_spec("blocks/attn/wq/w_codes", (60, 7168, 7168), mesh) == \
        P(None, "data", "model")


def test_cells_assignment_matrix():
    """40 cells total; 7 long_500k skips for full-attention archs (DESIGN §6)."""
    all_cells = list(cells(include_skipped=True))
    assert len(all_cells) == 40
    skips = [(c.name, s.name) for c, s, sk in all_cells if sk]
    assert len(skips) == 7
    assert all(s == "long_500k" for _, s in skips)
    runnable = list(cells())
    assert len(runnable) == 33
    long_archs = {c.name for c, s, _ in runnable if s.name == "long_500k"}
    assert long_archs == {"zamba2-7b", "gemma3-4b", "xlstm-125m"}


def test_param_count_sane():
    """Analytic param counts should be within ~15% of the nominal sizes."""
    nominal = {"yi-34b": 34e9, "phi3-mini-3.8b": 3.8e9,
               "qwen2.5-14b": 14e9, "olmoe-1b-7b": 7e9}
    for arch, n in nominal.items():
        total, active = param_count(get_arch(arch))
        assert 0.8 * n < total < 1.25 * n, (arch, total)
        assert active <= total
    # olmoe: ~1B active of ~7B total
    total, active = param_count(get_arch("olmoe-1b-7b"))
    assert active < 0.35 * total


def test_model_flops_scaling():
    cfg = get_arch("phi3-mini-3.8b")
    tr = model_flops(cfg, get_shape("train_4k"))
    pf = model_flops(cfg, get_shape("prefill_32k"))
    dc = model_flops(cfg, get_shape("decode_32k"))
    # train = 6ND on 1M tokens; prefill = 2ND on 1M tokens -> 3x
    assert abs(tr / pf - 3.0) < 1e-6
    # decode: 128 tokens vs 1M -> tiny
    assert dc < pf / 1000


def test_cost_analysis_dict_normalizes_list():
    """Older jax returns cost_analysis() as a one-element list of dicts —
    the run_cell AttributeError this helper fixes."""
    class FakeCompiled:
        def __init__(self, ret):
            self._ret = ret

        def cost_analysis(self):
            return self._ret

    assert cost_analysis_dict(FakeCompiled({"flops": 1.0})) == {"flops": 1.0}
    assert cost_analysis_dict(FakeCompiled([{"flops": 2.0}])) == {"flops": 2.0}
    assert cost_analysis_dict(FakeCompiled([])) == {}
    assert cost_analysis_dict(FakeCompiled(None)) == {}


def test_dryrun_run_cell(monkeypatch):
    """The dry-run driver end to end on a reduced cell and a 1-chip mesh:
    lower, compile, extract memory/cost/collectives without error (covers
    the cost_analysis list/dict normalization in situ)."""
    cfg = get_arch("phi3-mini-3.8b").reduced()
    shape = dataclasses.replace(
        get_shape("decode_32k"), seq_len=64, global_batch=4)
    mesh = make_mesh_compat((1, 1), ("data", "model"),
                            devices=jax.devices()[:1])
    monkeypatch.setattr(dryrun, "get_arch", lambda name: cfg)
    monkeypatch.setattr(dryrun, "get_shape", lambda name: shape)
    monkeypatch.setattr(dryrun, "make_production_mesh",
                        lambda *, multi_pod: mesh)
    res = dryrun.run_cell("phi3-mini-3.8b", "decode_32k", multi_pod=False,
                          policy=dryrun._parse_policy("p8-serve"))
    assert "error" not in res
    assert res["n_chips"] == 1
    assert res["flops_per_device"] >= 0
    assert res["memory"]["argument_bytes"] > 0


def test_roofline_analyse():
    rec = {
        "arch": "phi3-mini-3.8b", "shape": "train_4k", "kind": "train",
        "multi_pod": False, "n_chips": 256,
        "flops_per_device": 1.1e14, "bytes_per_device": 2.0e11,
        "memory": {}, "collectives": {"all-reduce": {"bytes": 5e9, "count": 3}},
    }
    out = analyse(rec)
    assert out["dominant"] in ("compute", "memory", "collective")
    assert out["t_compute_s"] == pytest.approx(1.1e14 / 197e12)
    assert out["t_memory_s"] == pytest.approx(2.0e11 / 819e9)
    assert out["t_collective_s"] == pytest.approx(5e9 / 50e9)
    assert 0 < out["useful_ratio"] < 10
    assert out["roofline_fraction"] > 0
