"""Exhaustive equivalence: table-driven codec (repro.core.lut) vs bit pipeline.

The LUT decode tables and the bucketize-encode boundaries are constructed by
an independent numpy mirror; these tests close the loop by comparing every
reachable input against the jnp bit pipeline (itself validated exhaustively
against the Fraction oracle in test_codec.py).  Decode comparisons are at the
bit-pattern level (NaN payloads included)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import codec, lut
from repro.core.pcsr import OperandSlots as OS, TransPolicy
from repro.core.types import P8_0, P16_1

ALL_ES = (0, 1, 2, 3)


def _bits(x) -> np.ndarray:
    return np.asarray(x, dtype=np.float32).view(np.uint32)


# ----------------------------------------------------------- decode: p8 -------
@pytest.mark.parametrize("es", ALL_ES)
def test_lut_decode_p8_exhaustive(es):
    codes = jnp.asarray(np.arange(256, dtype=np.uint8))
    got = lut.lut_decode_p8(codes, es)
    want = codec.posit_decode(codes, 8, es)
    assert (_bits(got) == _bits(want)).all()


def test_lut_decode_p8_bf16_castable():
    """Every p8 table entry survives the f32 -> bf16 cast losslessly (the
    full-MXU-speed decode contract, DESIGN.md §2)."""
    tab = lut._p8_decode_table()
    # round-trip through bf16 via jnp (numpy has no bf16)
    rt = np.asarray(jnp.asarray(tab).astype(jnp.bfloat16).astype(jnp.float32))
    ok = (rt == tab) | (np.isnan(rt) & np.isnan(tab))
    assert ok.all()


# ----------------------------------------------------------- decode: p16 ------
@pytest.mark.parametrize("es", ALL_ES)
def test_lut_decode_p16_exhaustive(es):
    codes = jnp.asarray(np.arange(65536, dtype=np.uint16))
    got = lut.lut_decode_p16(codes, es)
    want = codec.posit_decode(codes, 16, es)
    assert (_bits(got) == _bits(want)).all()


def test_p16_split_table_is_small():
    """The point of the two-level split: far below a flat 256 KB p16 table."""
    l1b, l1s, lo = lut._p16_decode_tables()
    total = l1b.nbytes + l1s.nbytes + lo.nbytes
    assert total < 128 * 1024, total
    # and the fallback second level covers at most 16 high bytes per es
    assert lo.shape[1] <= 16


# ------------------------------------------------------------- encode: p8 -----
def _encode_sweep() -> np.ndarray:
    """Dense f32 sweep: every rounding boundary +-1 ulp for every es (both
    lattices), powers of two across the range, random normals at several
    scales, subnormals, +-0, NaN/Inf."""
    rng = np.random.default_rng(42)
    parts = [
        np.array([0.0, -0.0, np.inf, -np.inf, np.nan], np.float32),
        (np.float32(2.0) ** rng.integers(-60, 60, 4000)
         * rng.choice([-1, 1], 4000)).astype(np.float32),
        rng.normal(0, 1, 20000).astype(np.float32),
        rng.normal(0, 1e14, 4000).astype(np.float32),   # saturation region
        rng.normal(0, 1e-14, 4000).astype(np.float32),  # sub-minpos region
        np.array([1e-45, -1e-45, 1e-40, -1e-40, 2.0 ** -149, -(2.0 ** -149),
                  2.0 ** -126, -(2.0 ** -126)], np.float32),  # subnormals
    ]
    for es in ALL_ES:
        for ftz in (False, True):
            mids = lut._p8_encode_tables(ftz)[1][es]
            parts += [mids, np.nextafter(mids, np.float32(np.inf)),
                      np.nextafter(mids, np.float32(-np.inf))]
    return np.concatenate(parts).astype(np.float32)


@pytest.mark.parametrize("es", ALL_ES)
@pytest.mark.parametrize("ftz", [False, True])
def test_lut_encode_p8_dense_sweep(es, ftz):
    xs = jnp.asarray(_encode_sweep())
    got = np.asarray(lut.lut_encode_p8(xs, es, ftz=ftz))
    want = np.asarray(codec.posit_encode(xs, 8, es, ftz=ftz))
    bad = got != want
    assert not bad.any(), (np.asarray(xs)[bad][:10], got[bad][:10], want[bad][:10])


@pytest.mark.parametrize("es", ALL_ES)
def test_lut_encode_p8_roundtrip_fixed_points(es):
    """encode(decode(c)) == c through the LUT pair for every code."""
    codes = jnp.asarray(np.arange(256, dtype=np.uint8))
    dec = lut.lut_decode_p8(codes, es)
    enc = np.asarray(lut.lut_encode_p8(dec, es))
    assert (enc == np.asarray(codes)).all()


def test_encode_boundaries_are_p9_values():
    """The bucketize boundaries are the encoding-level rounding flip points:
    the odd codes of P(9, es) interleaving the p8 lattice (DESIGN.md §8) —
    *not* arithmetic midpoints, which differ wherever discarded bits include
    exponent bits.  Spot-check the known divergence: p8/es=1 rounds 2^-11 up
    to 2^-10 (the encoding tie) although minpos=2^-12 is nearer in value."""
    got = int(np.asarray(codec.posit_encode(jnp.float32(2.0 ** -11), 8, 1)))
    assert got == 2  # 2^-10, the even-body side of the encoding tie
    assert int(np.asarray(lut.lut_encode_p8(jnp.float32(2.0 ** -11), 1))) == 2


# ------------------------------------------------------------- dynamic es -----
def test_lut_dynamic_es_single_executable():
    traces = []

    @jax.jit
    def dec(c, es):
        traces.append(1)
        return lut.lut_decode_p16(c, es)

    codes = jnp.asarray(np.arange(65536, dtype=np.uint16))
    for es in ALL_ES:
        got = np.asarray(dec(codes, jnp.int32(es)))
        want = np.asarray(codec.posit_decode(codes, 16, es))
        assert (got.view(np.uint32) == want.view(np.uint32)).all()
    assert len(traces) == 1, "dynamic es must not retrace"


# ------------------------------------------------------- dispatch / pcsr ------
def test_codec_impl_validation():
    with pytest.raises(ValueError):
        lut.resolve_codec_impl("nope")
    with pytest.raises(ValueError):
        OS(codec_impl="nope")
    with pytest.raises(ValueError):
        TransPolicy(codec_impl="nope")
    with pytest.raises(ValueError):
        TransPolicy(epilogue="nope")


def test_decode_with_impl_agrees_across_impls():
    rng = np.random.default_rng(0)
    c8 = jnp.asarray(rng.integers(0, 256, 500).astype(np.uint8))
    c16 = jnp.asarray(rng.integers(0, 65536, 500).astype(np.uint16))
    for es in ALL_ES:
        for impl in ("auto", "lut", "bits"):
            assert (_bits(lut.decode_with_impl(c8, 8, es, impl))
                    == _bits(codec.posit_decode(c8, 8, es))).all()
            assert (_bits(lut.decode_with_impl(c16, 16, es, impl))
                    == _bits(codec.posit_decode(c16, 16, es))).all()


def test_encode_with_impl_agrees_across_impls():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 8, 2000).astype(np.float32))
    for es in ALL_ES:
        want8 = np.asarray(codec.posit_encode(x, 8, es))
        want16 = np.asarray(codec.posit_encode(x, 16, es))
        for impl in ("auto", "lut", "bits"):
            assert (np.asarray(lut.encode_with_impl(x, 8, es, impl)) == want8).all()
            assert (np.asarray(lut.encode_with_impl(x, 16, es, impl)) == want16).all()


def test_posit_dot_codec_impl_bit_identical():
    """The pcsr codec_impl knob changes lowering, never values."""
    from repro.core.dot import posit_dot

    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.normal(0, 1, (16, 32)).astype(np.float32))
    b = jnp.asarray(rng.normal(0, 1, (32, 8)).astype(np.float32))
    ac = codec.posit_encode(a, 8, 0)
    bc = codec.posit_encode(b, 8, 0)
    outs = []
    for impl in ("lut", "bits", "auto"):
        slots = OS(rs1=P8_0, rs2=P8_0, rd=P8_0, codec_impl=impl)
        outs.append(np.asarray(posit_dot(ac, bc, slots)))
    assert (outs[0] == outs[1]).all() and (outs[1] == outs[2]).all()


def test_pcsr_encode_bits_codec_impl_field():
    word = OS(rs1=P8_0, rs2=P16_1, codec_impl="lut").encode_bits()
    assert (word >> 22) & 0b11 == 1  # lut == index 1 in CODEC_IMPLS
