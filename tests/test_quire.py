"""Quire subsystem tests: the exact-accumulation contract.

The load-bearing property: ``quire_read(sum_i qma(a_i, b_i))`` must be
bit-identical to summing the decoded values in *infinite precision* (Fraction
arithmetic via ref_codec) and encoding once — across formats, es values,
NaR, cancellation, and maxpos-overflow saturation. The Pallas kernel, the
scan-based quire_matmul, and the dot.py dataflow must all meet the same bits.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from fractions import Fraction

from repro.core import alu, ref_codec
from repro.core.codec import posit_encode
from repro.core.pcsr import OperandSlots
from repro.core.quire import (
    QuireFmt, quire_accumulate, quire_add_posit, quire_from_posit,
    quire_matmul, quire_read, quire_zero,
)
from repro.core.types import P8_0, P8_2, P16_1, P16_2, F32, PositFmt
from repro.core.dot import posit_dot
from repro.kernels.posit_quire_gemm.posit_quire_gemm import posit_quire_gemm
from repro.kernels.posit_quire_gemm.ref import posit_quire_gemm_ref


def _exact_dot_code(ac, bc, n, es, n_out=None, es_out=None,
                    nb_b=None, es_b=None):
    """Fraction-arithmetic oracle: exact sum of products, single rounding."""
    nb_b = n if nb_b is None else nb_b
    es_b = es if es_b is None else es_b
    no = n if n_out is None else n_out
    eo = es if es_out is None else es_out
    acc, nar = Fraction(0), False
    for x, y in zip(ac, bc):
        va = ref_codec.ref_decode(int(x), n, es)
        vb = ref_codec.ref_decode(int(y), nb_b, es_b)
        if va is None or vb is None:
            nar = True
        else:
            acc += va * vb
    return (1 << (no - 1)) if nar else ref_codec.ref_encode_exact(acc, no, eo)


def _rand_codes(rng, nbits, shape):
    dt = np.uint8 if nbits == 8 else np.uint16
    return rng.integers(0, 1 << nbits, shape).astype(dt)


# ---------------------------------------------------- exact-sum property ------
@pytest.mark.parametrize("es", [0, 1, 2, 3])
def test_quire_dot_exact_vs_fraction_oracle_p8(es):
    """Random p8 codes (NaR included at natural frequency): bit-exact."""
    rng = np.random.default_rng(es)
    M, K = 16, 40
    a = _rand_codes(rng, 8, (M, K))
    b = _rand_codes(rng, 8, (K, 1))
    fmt = PositFmt(8, es)
    got = np.asarray(quire_matmul(jnp.asarray(a), jnp.asarray(b), fmt,
                                  block_k=16))
    want = np.array([[_exact_dot_code(a[i], b[:, 0], 8, es)]
                     for i in range(M)], dtype=np.uint8)
    assert (got == want).all(), np.argwhere(got != want)[:5]


@pytest.mark.parametrize("es", [0, 1, 2, 3])
def test_quire_dot_exact_vs_fraction_oracle_p16(es):
    rng = np.random.default_rng(10 + es)
    M, K = 6, 24
    a = _rand_codes(rng, 16, (M, K))
    b = _rand_codes(rng, 16, (K, 1))
    fmt = PositFmt(16, es)
    got = np.asarray(quire_matmul(jnp.asarray(a), jnp.asarray(b), fmt,
                                  block_k=8))
    want = np.array([[_exact_dot_code(a[i], b[:, 0], 16, es)]
                     for i in range(M)], dtype=np.uint16)
    assert (got == want).all(), np.argwhere(got != want)[:5]


def test_quire_value_scale_distribution():
    """Same property on value-like data (encodes of normals, no NaR)."""
    rng = np.random.default_rng(2)
    for n, es in [(8, 1), (16, 2)]:
        K = 64
        av = rng.normal(0, 3, K).astype(np.float32)
        bv = rng.normal(0, 3, K).astype(np.float32)
        a = np.asarray(posit_encode(jnp.asarray(av), n, es))
        b = np.asarray(posit_encode(jnp.asarray(bv), n, es))
        got = int(np.asarray(quire_matmul(
            jnp.asarray(a[None, :]), jnp.asarray(b[:, None]),
            PositFmt(n, es)))[0, 0])
        assert got == _exact_dot_code(a, b, n, es)


# ------------------------------------------------------------ NaR / edges -----
def test_quire_nar_propagates():
    qf = QuireFmt(16, 1)
    nar = jnp.uint16(1 << 15)
    one = posit_encode(jnp.float32(1.0), 16, 1)
    q = quire_zero((), qf)
    q = quire_accumulate(q, one, one, qf)
    q = quire_accumulate(q, nar, one, qf)   # NaR * x poisons
    q = quire_accumulate(q, one, one, qf)   # ...and stays poisoned
    assert int(np.asarray(quire_read(q, qf))) == 1 << 15


@pytest.mark.parametrize("n,es", [(8, 0), (16, 2)])
def test_quire_overflow_saturates_to_maxpos(n, es):
    """K * maxpos^2 is far beyond maxpos: readout saturates, never wraps/NaRs."""
    dt = np.uint8 if n == 8 else np.uint16
    maxpos = dt((1 << (n - 1)) - 1)
    K = 200
    a = jnp.full((1, K), maxpos, dtype=dt)
    b = jnp.full((K, 1), maxpos, dtype=dt)
    fmt = PositFmt(n, es)
    assert int(np.asarray(quire_matmul(a, b, fmt))[0, 0]) == int(maxpos)
    neg = jnp.full((K, 1), dt((1 << n) - int(maxpos)), dtype=dt)
    assert int(np.asarray(quire_matmul(a, neg, fmt))[0, 0]) \
        == (1 << n) - int(maxpos)


def test_quire_catastrophic_cancellation_is_exact():
    """maxpos^2 - maxpos^2 + minpos^2 == minpos^2 exactly (saturating up to
    minpos at readout) — the case every rounded accumulator loses."""
    qf = QuireFmt(16, 2)
    mx, mn = jnp.uint16(0x7FFF), jnp.uint16(1)
    q = quire_zero((), qf)
    q = quire_accumulate(q, mx, mx, qf)
    q = quire_accumulate(q, mx, mx, qf, subtract=True)
    assert int(np.asarray(quire_read(q, qf))) == 0  # exact zero, not noise
    q = quire_accumulate(q, mn, mn, qf)
    assert int(np.asarray(quire_read(q, qf))) == 1  # minpos survives


# ----------------------------------------------------------- fused alu ops ----
def test_qma_single_product_equals_ref_mul():
    """One qma + qround == exact-product single rounding == ref_mul."""
    rng = np.random.default_rng(3)
    a = _rand_codes(rng, 8, 300)
    b = _rand_codes(rng, 8, 300)
    q = alu.qclr((300,), 8, 1)
    q = alu.qma(q, jnp.asarray(a), jnp.asarray(b), 8, 1)
    got = np.asarray(alu.qround(q, 8, 1))
    want = np.array([ref_codec.ref_mul(int(x), int(y), 8, 1)
                     for x, y in zip(a, b)])
    assert (got == want).all()


def test_qms_and_qneg_invert_qma():
    rng = np.random.default_rng(4)
    a = jnp.asarray(_rand_codes(rng, 16, 64))
    b = jnp.asarray(_rand_codes(rng, 16, 64))
    nar_in = (np.asarray(a) == 1 << 15) | (np.asarray(b) == 1 << 15)
    q = alu.qclr((64,), 16, 2)
    q = alu.qms(alu.qma(q, a, b, 16, 2), a, b, 16, 2)
    got = np.asarray(alu.qround(q, 16, 2))
    assert (got == np.where(nar_in, 1 << 15, 0)).all()
    q2 = alu.qma(alu.qclr((64,), 16, 2), a, b, 16, 2)
    q3 = alu.qneg(alu.qneg(q2, 16), 16)
    assert (np.asarray(alu.qround(q3, 16, 2))
            == np.asarray(alu.qround(q2, 16, 2))).all()


def test_quire_from_posit_roundtrips():
    """inject + read is the identity on every p8 code (incl. 0 and NaR)."""
    codes = jnp.asarray(np.arange(256, dtype=np.uint8))
    for es in (0, 3):
        qf = QuireFmt(8, es)
        back = np.asarray(quire_read(quire_from_posit(codes, qf), qf))
        assert (back == np.arange(256)).all()


def test_quire_add_posit_exact_sum():
    """Sum of posit *values* (not products) via the quire: single rounding."""
    rng = np.random.default_rng(5)
    vals = rng.normal(0, 1, 50).astype(np.float32)
    codes = np.asarray(posit_encode(jnp.asarray(vals), 16, 1))
    qf = QuireFmt(16, 1)
    q = quire_zero((), qf)
    for c in codes:
        q = quire_add_posit(q, jnp.uint16(c), qf)
    got = int(np.asarray(quire_read(q, qf)))
    acc = sum(ref_codec.ref_decode(int(c), 16, 1) for c in codes)
    assert got == ref_codec.ref_encode_exact(acc, 16, 1)


# ----------------------------------------------------------- Pallas kernel ----
@pytest.mark.parametrize("fmt,bm,bn,bk", [
    (P8_0, 8, 8, 16),    # multi-tile every dim incl. k (scratch carry)
    (P16_1, 8, 8, 16),
    (P8_2, 8, 8, 8),
])
def test_quire_kernel_bitexact_vs_ref(fmt, bm, bn, bk):
    rng = np.random.default_rng(6)
    M, K, N = 10, 40, 6  # ragged vs the block shapes -> exercises padding
    a = jnp.asarray(_rand_codes(rng, fmt.nbits, (M, K)))
    b = jnp.asarray(_rand_codes(rng, fmt.nbits, (K, N)))
    es = jnp.asarray([fmt.es] * 3, jnp.int32)
    kw = dict(a_fmt=fmt, b_fmt=fmt, out_fmt=fmt)
    got = posit_quire_gemm(a, b, es, interpret=True, block_m=bm, block_n=bn,
                           block_k=bk, **kw)
    want = posit_quire_gemm_ref(a, b, es, **kw)
    assert got.dtype == want.dtype
    assert (np.asarray(got) == np.asarray(want)).all()


def test_quire_kernel_vs_fraction_oracle():
    """The tiled kernel itself meets the exact-sum bits (not just the ref)."""
    rng = np.random.default_rng(7)
    K = 24
    a = _rand_codes(rng, 16, (4, K))
    b = _rand_codes(rng, 16, (K, 1))
    es = jnp.asarray([1, 1, 1], jnp.int32)
    got = np.asarray(posit_quire_gemm(
        jnp.asarray(a), jnp.asarray(b), es, interpret=True,
        block_m=4, block_n=1, block_k=8, a_fmt=P16_1, b_fmt=P16_1,
        out_fmt=P16_1))
    want = np.array([[_exact_dot_code(a[i], b[:, 0], 16, 1)]
                     for i in range(4)], dtype=np.uint16)
    assert (got == want).all()


def test_quire_kernel_mixed_formats_and_out():
    """p8 x p16 operands, p8 readout — quire sized by the wider operand."""
    rng = np.random.default_rng(8)
    a = _rand_codes(rng, 8, (6, 20))
    b = _rand_codes(rng, 16, (20, 3))
    es = jnp.asarray([0, 1, 2], jnp.int32)
    kw = dict(a_fmt=P8_0, b_fmt=P16_1, out_fmt=P8_2)
    got = np.asarray(posit_quire_gemm(
        jnp.asarray(a), jnp.asarray(b), es, interpret=True,
        block_m=8, block_n=8, block_k=8, **kw))
    ref = np.asarray(posit_quire_gemm_ref(jnp.asarray(a), jnp.asarray(b), es,
                                          **kw))
    assert (got == ref).all() and got.dtype == np.uint8
    want = np.array(
        [[_exact_dot_code(a[i], b[:, j], 8, 0, n_out=8, es_out=2,
                          nb_b=16, es_b=1) for j in range(3)]
         for i in range(6)], dtype=np.uint8)
    assert (got == want).all()


# --------------------------------------------------------------- dataflow -----
def test_dot_quire_dataflow_via_pcsr():
    rng = np.random.default_rng(9)
    a = jnp.asarray(_rand_codes(rng, 16, (8, 16)))
    b = jnp.asarray(_rand_codes(rng, 16, (16, 4)))
    slots = OperandSlots.uniform(P16_1, dataflow="quire")
    got = posit_dot(a, b, slots)                      # impl defaults to pcsr
    also = posit_dot(a, b, OperandSlots.uniform(P16_1), impl="quire")
    want = quire_matmul(a, b, P16_1)
    assert (np.asarray(got) == np.asarray(want)).all()
    assert (np.asarray(also) == np.asarray(want)).all()


def test_dot_quire_rejects_float_slots():
    a = jnp.zeros((4, 4), jnp.uint16)
    b = jnp.zeros((4, 4), jnp.float32)
    with pytest.raises(ValueError, match="posit"):
        posit_dot(a, b, OperandSlots(rs1=P16_1, rs2=F32, rd=P16_1,
                                     dataflow="quire"))


def test_pcsr_dataflow_bits_and_validation():
    slots = OperandSlots.uniform(P16_2, dataflow="quire")
    assert (slots.encode_bits() >> 20) & 0b11 == 2
    assert (OperandSlots.uniform(P16_2).encode_bits() >> 20) & 0b11 == 0
    with pytest.raises(ValueError, match="dataflow"):
        OperandSlots(dataflow="mxu")


def test_quire_dynamic_es_single_trace():
    """es is data: one executable serves every es (the pcsr pes contract)."""
    rng = np.random.default_rng(11)
    a = jnp.asarray(_rand_codes(rng, 16, (4, 8)))
    b = jnp.asarray(_rand_codes(rng, 16, (8, 4)))
    calls = []

    @jax.jit
    def mm(a, b, e):
        calls.append(1)
        return quire_matmul(a, b, P16_1, es_a=e, es_b=e, es_out=e)

    for e in range(4):
        got = np.asarray(mm(a, b, jnp.int32(e)))
        want = np.asarray(quire_matmul(a, b, PositFmt(16, e)))
        assert (got == want).all(), e
    assert len(calls) == 1


# -------------------------------------------------------------- ssm state -----
def test_ssm_quire_state_close_to_f32_and_differentiable():
    from repro.core.pcsr import TransPolicy
    from repro.models.ssm import (SSMCfg, apply_ssm, decode_ssm_step, init_ssm,
                                  init_ssm_state)

    cfg = SSMCfg(d_model=32, d_state=8, head_dim=16, chunk=16)
    p = init_ssm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(12)
    pol_q = TransPolicy.from_names(state="p16_2")
    pol_f = TransPolicy()

    x1 = jnp.asarray(rng.normal(0, 1, (2, 1, 32)).astype(np.float32))
    st = init_ssm_state(2, cfg)
    y_q, st_q = decode_ssm_step(p, cfg, x1, st, pol_q)
    y_f, _ = decode_ssm_step(p, cfg, x1, st, pol_f)
    assert st_q["h"].dtype == jnp.float32  # pytree unchanged (codes-equivalent)
    assert float(jnp.max(jnp.abs(y_q - y_f))) < 1e-2  # p16 quantization only

    xs = jnp.asarray(rng.normal(0, 1, (1, 32, 32)).astype(np.float32))
    assert bool(jnp.isfinite(apply_ssm(p, cfg, xs, pol_q)).all())
    g = jax.grad(lambda pp: apply_ssm(pp, cfg, xs, pol_q).sum())(p)
    leaves = jax.tree.leaves(g)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves)  # STE keeps grads
    assert any(float(jnp.abs(g).max()) > 0 for g in leaves)
