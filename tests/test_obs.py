"""Serving-plane observability tests (DESIGN.md §12).

The load-bearing guarantees (ISSUE 6 acceptance):

* the metrics registry's percentile readout is *bit-identical* to
  ``numpy.percentile`` while the sample buffer is retained, and bounded by
  the log-bucket ratio after the cap drops it,
* the numerics probes stream correct binade histograms from inside
  ``jax.jit`` + ``lax.scan`` (the decode-executable shape), and the
  callbacks bake in at trace time — the probed/plain twin-executable
  mechanism the engine relies on,
* the drift detector fires on a shifted activation distribution and stays
  quiet on in-distribution traffic, end-to-end through a saved calibration
  artifact (``save_artifact -> load_baselines``),
* the engine's metrics agree with its own ``Completion`` records (same
  timestamps, two independent aggregation paths),
* the trace output is schema-valid Chrome trace-event JSON.
"""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.calib.observe import BIN_LO, NBINS, Observer, TensorStats, observing
from repro.configs import get_arch
from repro.core.pcsr import TransPolicy
from repro.launch.engine import ContinuousBatchingEngine, Request
from repro.models.registry import build_model
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               RollingRate, percentile, percentile_ms)
from repro.obs.numerics import (NumericsWatcher, chi2_quantile, drift_score,
                                drift_threshold, load_baselines,
                                normal_quantile)
from repro.obs.trace import TraceRecorder, annotate, named_scope

#: Quarter-decade bucket ratio: the bucket-interpolated percentile error
#: bound once the exact sample buffer is dropped.
_BUCKET_RATIO = 10.0 ** 0.25


def _drain_callbacks(out) -> None:
    jax.block_until_ready(out)
    barrier = getattr(jax, "effects_barrier", None)
    if barrier is not None:
        barrier()


# ----------------------------------------------------------------- metrics ----

def test_percentile_helpers_match_numpy():
    rng = np.random.default_rng(0)
    xs = rng.lognormal(-3, 2, 257).tolist()
    for q in (0, 12.5, 50, 95, 99, 100):
        assert percentile(xs, q) == float(np.percentile(xs, q))
    assert percentile([], 50) == 0.0
    assert percentile_ms([0.0012344], 50) == 1.23       # rounded ms


def test_histogram_exact_percentiles_are_numpy():
    rng = np.random.default_rng(1)
    xs = rng.lognormal(-4, 2, 500)
    h = Histogram("t")
    for x in xs:
        h.observe(float(x))
    assert h.exact and h.n == 500
    p = h.percentiles((50, 95, 99))
    assert p["p50"] == float(np.percentile(xs, 50))
    assert p["p95"] == float(np.percentile(xs, 95))
    assert p["p99"] == float(np.percentile(xs, 99))
    assert h.min == xs.min() and h.max == xs.max()
    assert h.mean == pytest.approx(xs.mean())
    d = h.to_dict()
    assert d["count"] == 500 and d["exact"] and d["p95"] == p["p95"]


def test_histogram_bucket_fallback_is_ratio_bounded():
    rng = np.random.default_rng(2)
    xs = rng.lognormal(-5, 1.5, 2000)
    h = Histogram("t", max_samples=64)
    for x in xs:
        h.observe(float(x))
    assert not h.exact                      # buffer dropped past the cap
    assert sum(h.counts) == h.n == 2000
    for q in (50, 95, 99):
        est = h.percentiles((q,))[f"p{q:g}"]
        true = float(np.percentile(xs, q))
        assert true / _BUCKET_RATIO <= est <= true * _BUCKET_RATIO, \
            f"p{q}: bucket estimate {est} vs numpy {true}"


def test_histogram_bucket_assignment_matches_searchsorted():
    rng = np.random.default_rng(3)
    xs = rng.lognormal(-4, 3, 300)
    h = Histogram("t")
    for x in xs:
        h.observe(float(x))
    want = np.zeros(len(h.buckets) + 1, np.int64)
    np.add.at(want, np.searchsorted(h.buckets, xs, side="left"), 1)
    assert h.counts == want.tolist()


def test_counter_labels_and_gauge():
    c = Counter("finished")
    c.inc(label="eos")
    c.inc(2, label="max_new")
    c.inc(label="eos")
    assert c.value("eos") == 2 and c.value("max_new") == 2
    assert c.total == 4 and c.value("missing") == 0
    assert c.to_dict()["by_label"] == {"eos": 2.0, "max_new": 2.0}
    plain = Counter("n")
    plain.inc(3)
    assert plain.to_dict() == {"total": 3.0}    # unlabeled: no by_label noise
    g = Gauge("occ")
    g.set(0.75)
    assert g.to_dict() == {"value": 0.75}


def test_rolling_rate_window():
    r = RollingRate(window_s=10.0)
    for t in range(10):
        r.add(float(t), 5.0)                    # 5 tok/s for 10 s
    assert r.rate(10.0) == pytest.approx(5.0, rel=0.15)
    # short run: rate over the covered span, not diluted over the window
    r2 = RollingRate(window_s=10.0)
    r2.add(0.0, 10.0)
    r2.add(2.0, 10.0)
    assert r2.rate(2.0) == pytest.approx(10.0)
    # old events slide out
    assert r.rate(100.0) == 0.0


def test_registry_snapshot_and_save(tmp_path):
    m = MetricsRegistry()
    m.counter("steps").inc(7)
    m.gauge("occ").set(0.5)
    m.histogram("lat").observe(0.25)
    m.set_context(arch="yi-34b", mode="continuous")
    snap = m.snapshot()
    assert snap["kind"] == "repro/metrics-snapshot"
    assert snap["arch"] == "yi-34b"
    assert snap["counters"]["steps"]["total"] == 7
    assert snap["histograms"]["lat"]["count"] == 1
    path = tmp_path / "metrics.json"
    m.save(str(path))
    assert json.loads(path.read_text()) == json.loads(json.dumps(snap))
    # create-on-first-use returns the same instrument
    assert m.counter("steps") is m.counter("steps")


def test_prometheus_exposition():
    m = MetricsRegistry()
    m.counter("requests_finished").inc(label="eos")
    m.counter("requests_finished").inc(2, label="max_new")
    m.gauge("slot_occupancy").set(0.5)
    h = m.histogram("decode_step_s")
    for x in (0.001, 0.002, 0.004, 1.5):
        h.observe(x)
    text = m.prometheus()
    lines = text.splitlines()
    assert 'requests_finished_total{reason="eos"} 1' in lines
    assert 'requests_finished_total{reason="max_new"} 2' in lines
    assert "slot_occupancy 0.5" in lines
    assert 'decode_step_s_bucket{le="+Inf"} 4' in lines
    assert "decode_step_s_count 4" in lines
    # cumulative le buckets are monotone and end at the total count
    cum = [int(ln.rsplit(" ", 1)[1]) for ln in lines
           if ln.startswith("decode_step_s_bucket")]
    assert cum == sorted(cum) and cum[-1] == 4


# ------------------------------------------------------------------- trace ----

def test_trace_recorder_chrome_schema(tmp_path):
    tr = TraceRecorder()
    tr.label_track(0, "engine")
    tr.span("decode_step", 1.0, 2.5, tid=0, args={"emitted": 3})
    tr.instant("evict rid=0", 2.5, tid=1)
    doc = tr.to_json()
    assert isinstance(doc["traceEvents"], list) and len(doc["traceEvents"]) == 3
    meta, span, inst = doc["traceEvents"]
    assert meta["ph"] == "M" and meta["args"]["name"] == "engine"
    assert span["ph"] == "X" and span["ts"] == 1e6 and span["dur"] == 1.5e6
    assert inst["ph"] == "i" and inst["s"] == "t" and inst["ts"] == 2.5e6
    for ev in doc["traceEvents"]:
        assert {"name", "ph", "pid", "tid"} <= set(ev)
    path = tmp_path / "trace.json"
    tr.save(str(path))
    assert json.loads(path.read_text())["otherData"]["dropped_events"] == 0


def test_trace_recorder_bounds_memory():
    tr = TraceRecorder(max_events=3)
    for i in range(10):
        tr.span(f"s{i}", i, i + 1)
    assert len(tr.events) == 3 and tr.dropped == 7
    assert tr.to_json()["otherData"]["dropped_events"] == 7


def test_annotate_and_named_scope_are_harmless():
    with annotate("repro.test"), named_scope("repro.test"):
        assert jnp.add(1, 1) == 2


# ------------------------------------------------ probes under jit + scan ----

def _binade_hist(xs: np.ndarray) -> np.ndarray:
    """Numpy oracle for the observer's binade histogram (finite, nonzero)."""
    xs = np.abs(xs[np.isfinite(xs)].astype(np.float64))
    xs = xs[xs > 0]
    e = np.clip(np.floor(np.log2(xs)).astype(int), BIN_LO, BIN_LO + NBINS - 1)
    hist = np.zeros((NBINS,), np.float64)
    np.add.at(hist, e - BIN_LO, 1)
    return hist


def test_observer_streams_exact_binades_from_jit_scan():
    rng = np.random.default_rng(4)
    xs = rng.lognormal(0, 8, (6, 64)).astype(np.float32)
    xs[0, 0] = 0.0
    xs[1, 2] = np.inf

    from repro.calib import observe as obs_mod

    @jax.jit
    def f(xs):
        def body(carry, x):
            obs_mod.record("scan/site", "act", x)
            return carry + x.sum(), ()
        out, _ = jax.lax.scan(body, 0.0, xs)
        return out

    obs = Observer(kinds=("act",))
    with observing(obs):
        _drain_callbacks(f(jnp.asarray(xs)))
    st = obs.get("scan/site", "act")
    assert st.n == xs.size                      # all scan iterations merged
    assert st.nonfinite == 1
    np.testing.assert_array_equal(st.hist, _binade_hist(xs))

    # trace-time baking: the compiled executable keeps its callbacks — a
    # later call OUTSIDE the observing block still streams (this is what
    # lets the engine wrap only the probed twin's first call)
    n0 = st.n
    _drain_callbacks(f(jnp.asarray(xs)))
    assert obs.get("scan/site", "act").n == 2 * n0


def test_observer_kinds_filter_is_trace_time_dead_code():
    obs = Observer(kinds=("act",))
    with observing(obs):
        _drain_callbacks(jax.jit(
            lambda x: (obs.record("w", "weight", x), x + 1)[1])(jnp.ones(8)))
    assert obs.stats == {}                      # weight never even streamed


# ---------------------------------------------------------- drift detection ----

def test_normal_and_chi2_quantiles():
    assert normal_quantile(0.975) == pytest.approx(1.959964, abs=1e-5)
    assert normal_quantile(0.999) == pytest.approx(3.090232, abs=1e-5)
    assert normal_quantile(0.025) == pytest.approx(-1.959964, abs=1e-5)
    with pytest.raises(ValueError):
        normal_quantile(0.0)
    # Wilson–Hilferty vs scipy.stats.chi2.ppf reference values
    assert chi2_quantile(2, 0.999) == pytest.approx(13.8155, rel=0.05)
    assert chi2_quantile(10, 0.999) == pytest.approx(29.5883, rel=0.02)


def _stats_at(binade: int, n: float = 4096.0, spread: int = 3) -> TensorStats:
    """TensorStats with lognormal-ish mass centered on ``binade``."""
    st = TensorStats()
    weights = [1.0, 4.0, 10.0, 4.0, 1.0][:2 * spread - 1]
    total = sum(weights)
    for off, w in zip(range(-spread + 1, spread), weights):
        st.hist[binade + off - BIN_LO] = n * w / total
    st.n = n
    return st


def test_drift_score_quiet_then_fires():
    base = _stats_at(0, n=65536)
    live_same = _stats_at(0, n=8192)
    live_shift = _stats_at(6, n=8192)           # six binades over: drifted
    s0, k0 = drift_score(live_same, base)
    s1, k1 = drift_score(live_shift, base)
    t0 = drift_threshold(8192, 65536, k0)
    t1 = drift_threshold(8192, 65536, k1)
    assert s0 < t0, "identical distribution must stay under threshold"
    assert s1 > t1, "shifted distribution must exceed threshold"
    assert s1 > s0 and k1 > k0                  # disjoint support widens k


def test_drift_threshold_floor_and_degenerate():
    # plentiful samples: the chi2 term shrinks below min_score and the floor
    # takes over (non-iid activations — see numerics.py docstring)
    assert drift_threshold(1e6, 1e6, 5, min_score=0.1) == 0.1
    # scarce samples: the calibrated chi2 term dominates the floor
    assert drift_threshold(20, 20, 5, min_score=0.1) > 0.1
    assert drift_threshold(0, 100, 5) == math.inf
    assert drift_threshold(100, 100, 1) == math.inf
    empty = TensorStats()
    assert drift_score(empty, _stats_at(0)) == (0.0, 0)


def test_watcher_saturation_underflow_rates():
    pol = TransPolicy.from_names(weights="p8_0")
    ms = pol.weights.max_scale
    w = NumericsWatcher(policy=pol, every=1)
    st = TensorStats()
    st.hist[0 - BIN_LO] = 80                    # in-range mass
    st.hist[ms - BIN_LO] = 15                   # at max_scale: clamps to maxpos
    st.hist[-ms - 1 - BIN_LO] = 5               # below -max_scale: minpos
    st.n = 102.0
    st.nonfinite = 2.0
    w.observer.stats[("blocks/mlp/up", "act")] = st
    health = w.check()
    h = health["blocks/mlp/up"]
    assert h.saturation_rate == pytest.approx(0.15)
    assert h.underflow_rate == pytest.approx(0.05)
    assert h.nonfinite == 2.0
    assert h.drift_score is None                # no baseline for this site
    assert not h.drifted and not w.recalibrate


def test_watcher_cadence_rebase_and_latch():
    with pytest.raises(ValueError, match="cadence"):
        NumericsWatcher(every=0)
    w = NumericsWatcher(every=8)
    assert [w.should_probe(i) for i in (0, 1, 7, 8, 16)] == \
        [True, False, False, True, True]

    base = _stats_at(0, n=65536)
    w = NumericsWatcher(baselines={"s": base}, every=1)
    w.observer.stats[("s", "act")] = _stats_at(0, n=1024)
    # rebase: warmup traffic is marked off, the first window starts empty
    w.rebase()
    assert w.check() == {}
    # window 1: drifted traffic -> flag raises
    st = w.observer.stats[("s", "act")]
    shifted = _stats_at(8, n=1024)
    st.hist += shifted.hist
    st.n += shifted.n
    h1 = w.check()
    assert h1["s"].drifted and w.recalibrate
    # window 2: back in distribution -> window health clears but the flag
    # LATCHES (the operator must recalibrate, not wait it out)
    ok = _stats_at(0, n=1024)
    st.hist += ok.hist
    st.n += ok.n
    h2 = w.check()
    assert not h2["s"].drifted
    assert w.recalibrate
    rep = w.report()
    assert rep["recalibrate"] and rep["probe_every"] == 1
    assert rep["sites"]["s"]["drifted"] is False


# ------------------------------------------------- drift e2e via artifact ----

@pytest.fixture(scope="module")
def small_model():
    cfg = get_arch("phi3-mini-3.8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def test_drift_detector_end_to_end(small_model, tmp_path):
    """Calibrate -> save artifact -> load baselines -> serve-time forward:
    in-distribution traffic stays quiet, a scaled parameter set (activation
    distribution shifted by several binades) raises recalibrate."""
    from repro.calib.search import calibrate_model, save_artifact

    cfg, model, params = small_model
    rng = np.random.default_rng(0)
    base = TransPolicy()

    def batch():
        return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32))),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)))}

    pol, report = calibrate_model(
        lambda b: model.loss(params, b, base)[0], [batch(), batch()],
        params, base=base, name="t")
    path = tmp_path / "cal.json"
    save_artifact(str(path), pol, report)
    baselines = load_baselines(str(path))
    assert baselines and all(st.n > 0 for st in baselines.values())
    assert "mlp/up" in baselines or "mlp/gate" in baselines

    def probe_forward(p):
        w = NumericsWatcher(policy=pol, baselines=baselines, every=1)
        with w.observing():
            _drain_callbacks(model.forward(p, batch(), base))
        w.check()
        return w

    # in-distribution: same params, fresh batch from the same token prior
    quiet = probe_forward(params)
    scored = [h for h in quiet.health.values() if h.drift_score is not None]
    assert scored, "baselines must cover observed sites"
    assert not quiet.recalibrate, \
        {h.path: h.drift_score for h in scored if h.drifted}

    # shifted: scaling every weight moves activation binades layer by layer
    loud = probe_forward(jax.tree.map(lambda x: x * 2.0 ** 6, params))
    assert loud.recalibrate
    assert loud.report()["max_drift_score"] > quiet.report()["max_drift_score"]


# -------------------------------------------------------- engine integration ----

@pytest.fixture(scope="module")
def observed_run():
    """One deterministic engine run with all three sinks attached."""
    cfg = get_arch("yi-34b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    policy = TransPolicy.from_names(kv_cache="p8_0", attn_impl="kernel")
    metrics, tracer = MetricsRegistry(), TraceRecorder()
    # the watcher's policy only interprets formats (saturation thresholds);
    # weights stay unquantized in the serving policy above
    numerics = NumericsWatcher(
        policy=TransPolicy.from_names(weights="p8_0"), every=4)
    eng = ContinuousBatchingEngine(
        model, params, policy, max_slots=2, S_max=64,
        metrics=metrics, tracer=tracer, numerics=numerics)
    rng = np.random.default_rng(0)
    for rid, (plen, arr) in enumerate([(12, 0.0), (7, 0.0), (9, 1.0)]):
        eng.submit(Request(
            rid=rid, prompt=rng.integers(0, cfg.vocab, (plen,)).astype(np.int32),
            max_new_tokens=5, arrival_time=arr))
    # deterministic clock: admission at t=2, each decode step one tick later
    eng.admit(now=2.0)
    t = 3.0
    while eng.active.any() or eng.queue:
        if eng.queue and eng.free_slots():
            eng.admit(now=t)
        eng.step(now=t)
        t += 1.0
    return eng, metrics, tracer, numerics


def test_engine_metrics_match_completions(observed_run):
    eng, m, _, _ = observed_run
    comps = eng.completions
    assert len(comps) == 3 and all(c.finish_reason == "max_new" for c in comps)
    assert m.counter("requests_admitted").total == 3
    assert m.counter("requests_finished").value("max_new") == 3
    assert m.counter("decode_steps").total == eng.steps
    assert m.counter("tokens_emitted").total == sum(len(c.tokens) for c in comps)
    # the histograms retained every sample: compare against the Completion
    # records, which were stamped from the same deterministic clock
    for name, want in [
        ("queue_s", [c.queue_s for c in comps]),
        ("ttft_s", [c.ttft_s for c in comps]),
        ("request_s", [c.finished_time - c.admitted_time for c in comps]),
        ("inter_token_s", [dt for c in comps for dt in c.per_token_s()[1:]]),
    ]:
        h = m.histograms[name]
        assert h.exact
        assert sorted(h._samples) == pytest.approx(sorted(want)), name
    assert m.gauge("slot_occupancy").val == 0.0        # drained
    assert m.gauge("queue_depth").val == 0.0
    assert m.histograms["slots_active"].max <= eng.max_slots
    snap = m.snapshot()
    assert snap["histograms"]["inter_token_s"]["count"] == \
        sum(len(c.tokens) - 1 for c in comps)


def test_engine_probes_and_recalibrate_gauge(observed_run):
    eng, m, _, numerics = observed_run
    # cadence 4 with step 0 included: ceil(steps / 4) probed steps
    assert numerics.probes == -(-eng.steps // 4)
    rep = numerics.report()
    assert rep["sites"], "probed steps must populate per-site health"
    assert not rep["recalibrate"]               # no baselines -> never drifts
    assert m.gauge("numerics_recalibrate").val == 0.0
    for h in rep["sites"].values():
        assert h["n"] > 0 and h["nonfinite"] == 0
        assert 0.0 <= h["saturation_rate"] <= 1.0


def test_engine_trace_spans(observed_run):
    eng, _, tracer, _ = observed_run
    doc = tracer.to_json()
    names = [ev["name"] for ev in doc["traceEvents"]]
    assert "engine" in [ev["args"]["name"] for ev in doc["traceEvents"]
                        if ev["ph"] == "M"]
    for rid in (0, 1, 2):
        assert f"queued rid={rid}" in names
        assert f"prefill rid={rid}" in names
        assert f"decode rid={rid}" in names
    assert names.count("decode_step") == eng.steps
    # request lifecycle rides the slot track; the engine track is tid 0
    decode_tids = {ev["tid"] for ev in doc["traceEvents"]
                   if ev["name"] == "decode_step"}
    assert decode_tids == {0}
    evicts = [ev for ev in doc["traceEvents"] if ev["name"].startswith("evict")]
    assert len(evicts) == 3 and all(ev["ph"] == "i" for ev in evicts)
    json.dumps(doc)                             # serializable as-is


def test_engine_cancel_paths():
    cfg = get_arch("yi-34b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    policy = TransPolicy.from_names(kv_cache="p8_0")
    m = MetricsRegistry()
    eng = ContinuousBatchingEngine(model, params, policy, max_slots=1,
                                   S_max=64, metrics=m)
    rng = np.random.default_rng(5)
    for rid in range(3):
        eng.submit(Request(
            rid=rid, prompt=rng.integers(0, cfg.vocab, (8,)).astype(np.int32),
            max_new_tokens=20))
    eng.admit(now=1.0)
    eng.step(now=2.0)
    # mid-flight: evicted with partial tokens, reason recorded
    assert eng.cancel(0, now=3.0)
    assert eng.completions[0].finish_reason == "cancel"
    assert len(eng.completions[0].tokens) == 2  # prefill token + one step
    assert m.counter("requests_finished").value("cancel") == 1
    # queued: dropped without a Completion
    assert eng.cancel(2)
    assert m.counter("requests_cancelled_queued").total == 1
    assert [r.rid for r in eng.queue] == [1]
    # unknown rid
    assert not eng.cancel(99)
    # the freed slot serves the remaining request to completion
    eng.admit(now=4.0)
    while eng.active.any():
        eng.step(now=5.0)
    assert {c.rid for c in eng.completions} == {0, 1}
