"""Substrate tests: optimizer (+posit moments), data pipeline determinism,
checkpoint atomicity/async/elastic restore, fault-tolerance runtime."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.ckpt import (CheckpointManager, gc_tmp, latest_checkpoint,
                                   load_checkpoint, save_checkpoint)
from repro.core.types import P16_1
from repro.data.pipeline import SyntheticLMPipeline
from repro.ft.runtime import (FaultTolerantLoop, PreemptionSignal,
                              StragglerMonitor, with_retries)
from repro.optim import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm
from repro.optim.schedule import cosine_warmup


# ------------------------------------------------------------- optimizer ------
def _quad_problem():
    params = {"w": jnp.asarray([3.0, -2.0, 1.0]), "b": jnp.asarray([0.5])}
    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)
    return params, loss


@pytest.mark.parametrize("fmt", [None, P16_1])
def test_adamw_converges(fmt):
    params, loss = _quad_problem()
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, moment_fmt=fmt)
    state = adamw_init(params, cfg)
    for _ in range(300):
        grads = jax.grad(loss)(params)
        params, state = adamw_update(grads, state, params, cfg)
    assert float(loss(params)) < 1e-2, float(loss(params))


def test_adamw_posit_moments_storage_dtype():
    params, loss = _quad_problem()
    cfg = AdamWConfig(moment_fmt=P16_1)
    state = adamw_init(params, cfg)
    assert state["mu"]["w"]["m"].dtype == jnp.uint16
    grads = jax.grad(loss)(params)
    params, state = adamw_update(grads, state, params, cfg)
    assert state["mu"]["w"]["m"].dtype == jnp.uint16
    assert state["mu"]["w"]["em"].dtype == jnp.float32  # error feedback


def test_error_feedback_tracks_true_moments():
    """Posit-compressed moments + EF must stay close to the f32 trajectory."""
    params, loss = _quad_problem()
    c_f32 = AdamWConfig(lr=0.01, weight_decay=0.0)
    c_p = AdamWConfig(lr=0.01, weight_decay=0.0, moment_fmt=P16_1,
                      error_feedback=True)
    p1, s1 = dict(params), adamw_init(params, c_f32)
    p2, s2 = dict(params), adamw_init(params, c_p)
    for _ in range(100):
        g1 = jax.grad(loss)(p1)
        p1, s1 = adamw_update(g1, s1, p1, c_f32)
        g2 = jax.grad(loss)(p2)
        p2, s2 = adamw_update(g2, s2, p2, c_p)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]),
                               rtol=0.02, atol=5e-3)


def test_clip_and_schedule():
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-5
    np.testing.assert_allclose(
        np.asarray(clipped["a"]), np.asarray([0.6, 0.8]), rtol=1e-5)
    assert float(cosine_warmup(jnp.asarray(0), warmup=10, total=100)) == 0.0
    assert abs(float(cosine_warmup(jnp.asarray(10), warmup=10, total=100)) - 1.0) < 1e-5
    assert float(cosine_warmup(jnp.asarray(100), warmup=10, total=100)) < 0.11


# --------------------------------------------------------------- pipeline -----
def test_pipeline_deterministic_and_sharded():
    kw = dict(vocab=101, seq_len=16, global_batch=8, seed=7)
    p0 = SyntheticLMPipeline(n_shards=2, shard=0, **kw)
    p1 = SyntheticLMPipeline(n_shards=2, shard=1, **kw)
    b0a, b0b = p0.batch_at(3), p0.batch_at(3)
    assert (np.asarray(b0a["tokens"]) == np.asarray(b0b["tokens"])).all()
    b1 = p1.batch_at(3)
    assert not (np.asarray(b0a["tokens"]) == np.asarray(b1["tokens"])).all()
    assert b0a["tokens"].shape == (4, 16)
    # labels are next-token shifted
    assert (np.asarray(b0a["labels"])[:, :-1] == np.asarray(b0a["tokens"])[:, 1:]).all()
    # different steps differ
    b2 = p0.batch_at(4)
    assert not (np.asarray(b0a["tokens"]) == np.asarray(b2["tokens"])).all()


def test_pipeline_has_learnable_structure():
    p = SyntheticLMPipeline(vocab=64, seq_len=256, global_batch=4, seed=0)
    b = p.batch_at(0)
    t = np.asarray(b["tokens"])
    follows = (t[:, 1:] == (t[:, :-1] + p._shift) % 64).mean()
    assert follows > 0.3, follows  # induced bigram structure present


# -------------------------------------------------------------- checkpoint ----
def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"layer": {"w": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32)),
                      "step": jnp.asarray(5, jnp.int32)},
            "moments": [jnp.ones((3,)), jnp.zeros((2, 2))]}


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 3, tree)
    restored, manifest = load_checkpoint(str(tmp_path), tree)
    assert manifest["step"] == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_checkpoint_posit_compressed(tmp_path):
    tree = _tree(1)
    save_checkpoint(str(tmp_path), 1, tree, fmt=P16_1)
    restored, _ = load_checkpoint(str(tmp_path), tree)
    # float leaves round-trip through p16 (small values -> ~1e-3 rel error)
    np.testing.assert_allclose(np.asarray(tree["layer"]["w"]),
                               np.asarray(restored["layer"]["w"]),
                               rtol=1e-3, atol=1e-4)
    # int leaves stay exact
    assert int(restored["layer"]["step"]) == 5
    # and on-disk float payload is half size (p16 codes are uint16)
    import json as _json
    with open(os.path.join(latest_checkpoint(str(tmp_path)),
                           "manifest.json")) as f:
        leaves = _json.load(f)["leaves"]
    w = next(e for e in leaves if e["path"].endswith("w"))
    assert w["codec"] == P16_1.name and w["stored_dtype"] == "uint16"
    assert w["nbytes"] == 8 * 4 * 2, w  # half of the float32 payload


def test_checkpoint_atomicity_crash_sim(tmp_path):
    """A .tmp leftover (simulated crash) must be invisible + collectable."""
    tree = _tree()
    save_checkpoint(str(tmp_path), 1, tree)
    crash = tmp_path / "step_00000002.tmp"
    crash.mkdir()
    (crash / "manifest.json").write_text("{corrupt")
    assert latest_checkpoint(str(tmp_path)).endswith("step_00000001")
    assert gc_tmp(str(tmp_path)) == 1
    assert not crash.exists()


def test_checkpoint_async_manager_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree()
    for step in (1, 2, 3, 4):
        mgr.save_async(step, tree, extra={"next_step": step})
    mgr.wait()
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_00000003", "step_00000004"], steps
    mgr.close()


def test_checkpoint_elastic_resharding(tmp_path):
    """Save under one layout, restore under another: values identical."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(str(tmp_path), 1, tree)
    from repro.launch.mesh import make_mesh_compat
    mesh1 = make_mesh_compat((1,), ("data",))
    sh = {"w": NamedSharding(mesh1, P("data", None))}
    restored, _ = load_checkpoint(str(tmp_path), tree, shardings=sh)
    assert (np.asarray(restored["w"]) == np.asarray(tree["w"])).all()
    assert restored["w"].sharding == sh["w"]


# ------------------------------------------------------------------- FT -------
def test_with_retries():
    calls = []
    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return "ok"
    assert with_retries(flaky, retries=5, base_delay=0.001) == "ok"
    assert len(calls) == 3
    with pytest.raises(ValueError):
        with_retries(lambda: (_ for _ in ()).throw(ValueError()), retries=2,
                     base_delay=0.001)


def test_straggler_monitor():
    m = StragglerMonitor(threshold=3.0)
    assert not m.observe(1.0)
    for _ in range(5):
        assert not m.observe(1.1)
    assert m.observe(10.0)       # 10x the EWMA -> straggler
    assert m.events == 1
    assert not m.observe(1.0)    # baseline not polluted by the outlier


def test_ft_loop_preemption_and_resume(tmp_path):
    """Preempt mid-run, then resume from the forced checkpoint."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    sig = PreemptionSignal()
    loop = FaultTolerantLoop(ckpt=mgr, save_every=100, preemption=sig)

    def step_fn(state, step):
        if step == 4:
            sig.preempt()
        return {"x": state["x"] + 1}

    state, next_step = loop.run({"x": jnp.asarray(0)}, step_fn,
                                start_step=0, num_steps=100)
    assert next_step == 5 and int(state["x"]) == 5
    mgr.wait()

    mgr2 = CheckpointManager(str(tmp_path), keep=3)
    loop2 = FaultTolerantLoop(ckpt=mgr2, save_every=100)
    state2, start = loop2.resume({"x": jnp.asarray(0)})
    assert start == 5 and int(state2["x"]) == 5
    state3, nxt = loop2.run(state2, lambda s, i: {"x": s["x"] + 1},
                            start_step=start, num_steps=3)
    assert nxt == 8 and int(state3["x"]) == 8
    mgr.close(); mgr2.close()
