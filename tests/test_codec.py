"""Bit-exactness tests for the vectorized posit codec vs the pure-Python oracle."""
import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest
# hypothesis is optional (pyproject [test] extras): the module must collect
# without it — the property tests at the bottom skip instead.
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover
    given = settings = st = None

from repro.core import codec, ref_codec
from repro.core.types import PositFmt

ALL_ES = (0, 1, 2, 3)


def _ref_decode_all(n, es):
    return np.array(
        [ref_codec.ref_decode_float(c, n, es) for c in range(1 << n)], dtype=np.float64
    )


# ---------------------------------------------------------------- exhaustive p8
@pytest.mark.parametrize("es", ALL_ES)
def test_p8_decode_exhaustive(es):
    codes = np.arange(256, dtype=np.uint8)
    got = np.asarray(codec.posit_decode(jnp.asarray(codes), 8, es), dtype=np.float64)
    want = _ref_decode_all(8, es)
    ok = (got == want) | (np.isnan(got) & np.isnan(want))
    assert ok.all(), np.where(~ok)


@pytest.mark.parametrize("es", ALL_ES)
def test_p8_roundtrip_exhaustive(es):
    """encode(decode(p)) == p for every code (posits are fixed points of RT)."""
    codes = np.arange(256, dtype=np.uint8)
    dec = codec.posit_decode(jnp.asarray(codes), 8, es)
    enc = np.asarray(codec.posit_encode(dec, 8, es))
    assert (enc == codes).all(), np.where(enc != codes)


# --------------------------------------------------------------- exhaustive p16
@pytest.mark.parametrize("es", ALL_ES)
def test_p16_decode_exhaustive(es):
    codes = np.arange(65536, dtype=np.uint16)
    got = np.asarray(codec.posit_decode(jnp.asarray(codes), 16, es), dtype=np.float64)
    want = _ref_decode_all(16, es)
    ok = (got == want) | (np.isnan(got) & np.isnan(want))
    assert ok.all(), np.where(~ok)


@pytest.mark.parametrize("es", ALL_ES)
def test_p16_roundtrip_exhaustive(es):
    codes = np.arange(65536, dtype=np.uint16)
    dec = codec.posit_decode(jnp.asarray(codes), 16, es)
    enc = np.asarray(codec.posit_encode(dec, 16, es))
    assert (enc == codes).all(), np.where(enc != codes)


# ------------------------------------------------------------------ encode RNE
@pytest.mark.parametrize("n,es", [(8, 0), (8, 2), (16, 1), (16, 3)])
def test_encode_random_floats_vs_oracle(n, es):
    rng = np.random.default_rng(42)
    xs = np.concatenate([
        rng.normal(0, 1, 2000),
        rng.normal(0, 1e12, 400),      # saturation region
        rng.normal(0, 1e-12, 400),     # sub-minpos region
        rng.uniform(-2, 2, 1000),
        np.array([0.0, -0.0, np.inf, -np.inf, np.nan, 1.0, -1.0]),
        np.float32(2.0) ** rng.integers(-40, 40, 300),  # exact powers of two
    ]).astype(np.float32)
    got = np.asarray(codec.posit_encode(jnp.asarray(xs), n, es))
    want = np.array([ref_codec.ref_encode(float(x), n, es) for x in xs])
    assert (got == want).all(), xs[got != want][:10]


@pytest.mark.parametrize("n,es", [(8, 0), (16, 1)])
def test_encode_ties_round_to_even(n, es):
    """Exact midpoints between adjacent posits round to the even code.

    The arithmetic midpoint equals the encoding-level tie only inside uniform
    lattice segments (same regime+exponent), so pairs straddling a spacing
    change are excluded; the f32-representability of the midpoint is also
    checked (always true in uniform segments for n<=16).
    """
    codes = np.arange(2, (1 << (n - 1)) - 2, dtype=np.uint64)
    prev = np.array([ref_codec.ref_decode(int(c) - 1, n, es) for c in codes])
    lo = np.array([ref_codec.ref_decode(int(c), n, es) for c in codes])
    hi = np.array([ref_codec.ref_decode(int(c) + 1, n, es) for c in codes])
    uniform = np.array([(h - l) == (l - p) for p, l, h in zip(prev, lo, hi)])
    mids32 = np.array([float((a + b) / 2) for a, b in zip(lo, hi)], dtype=np.float32)
    exact = np.array(
        [(a + b) / 2 == m for a, b, m in zip(lo, hi, [float(x) for x in mids32])]
    )
    sel = uniform & exact
    assert sel.sum() > len(codes) // 4  # the test must actually cover something
    want = np.array([ref_codec.ref_encode(float(m), n, es) for m in mids32])
    got = np.asarray(codec.posit_encode(jnp.asarray(mids32), n, es)).astype(np.uint64)
    assert (got[sel] == want[sel]).all()
    # and the chosen code is the even one of each adjacent pair
    assert (got[sel] % 2 == 0).all()


def test_saturation_semantics():
    """|x|>=maxpos -> maxpos (not NaR); 0<|x|<minpos -> minpos (not 0)."""
    for n, es in [(8, 0), (8, 3), (16, 1)]:
        fmt = PositFmt(n, es)
        xs = jnp.asarray(
            [fmt.maxpos * 4, -fmt.maxpos * 4, fmt.minpos / 4, -fmt.minpos / 4,
             float(np.finfo(np.float32).max), float(np.finfo(np.float32).tiny) / 8],
            dtype=jnp.float32,
        )
        got = np.asarray(codec.posit_encode(xs, n, es)).astype(np.int64)
        want = np.array([
            fmt.maxpos_code, (1 << n) - fmt.maxpos_code,
            1, (1 << n) - 1,
            fmt.maxpos_code, 1,
        ])
        assert (got == want).all(), (n, es, got, want)


def test_specials():
    for n in (8, 16):
        got = np.asarray(codec.posit_encode(
            jnp.asarray([np.nan, np.inf, -np.inf, 0.0, -0.0], dtype=jnp.float32), n, 1))
        nar = 1 << (n - 1)
        assert list(got.astype(np.int64)) == [nar, nar, nar, 0, 0]
        dec = np.asarray(codec.posit_decode(jnp.asarray([0, nar], dtype=np.uint16 if n == 16 else np.uint8), n, 1))
        assert dec[0] == 0.0 and math.isnan(dec[1])


# ----------------------------------------------------------------- dynamic es
def test_dynamic_es_single_executable():
    """One jitted executable serves every es (the pcsr property)."""
    traces = []

    @jax.jit
    def enc(x, es):
        traces.append(1)
        return codec.posit_encode(x, 16, es)

    rng = np.random.default_rng(3)
    xs = jnp.asarray(rng.normal(0, 10, 512).astype(np.float32))
    outs = [np.asarray(enc(xs, jnp.int32(es))) for es in ALL_ES]
    assert len(traces) == 1, "dynamic es must not retrace"
    for es, out in zip(ALL_ES, outs):
        want = np.asarray(codec.posit_encode(xs, 16, es))
        assert (out == want).all()


def test_es_out_of_range_clamped():
    xs = jnp.asarray(np.linspace(-4, 4, 64, dtype=np.float32))
    hi = np.asarray(codec.posit_encode(xs, 8, 17))
    want = np.asarray(codec.posit_encode(xs, 8, 3))
    assert (hi == want).all()


# ----------------------------------------------------------- hypothesis props
if st is not None:
    @settings(max_examples=200, deadline=None)
    @given(
        st.integers(0, 65535), st.integers(0, 65535),
        st.sampled_from(ALL_ES),
    )
    def test_monotonicity_code_order_is_value_order(ca, cb, es):
        """Signed two's-complement code order == numeric order (posit superpower)."""
        n = 16
        nar = 1 << (n - 1)
        if ca == nar or cb == nar:
            return
        va = ref_codec.ref_decode(ca, n, es)
        vb = ref_codec.ref_decode(cb, n, es)
        sa = ca - (1 << n) if ca >= nar else ca  # signed view
        sb = cb - (1 << n) if cb >= nar else cb
        assert (sa < sb) == (va < vb)


    @settings(max_examples=200, deadline=None)
    @given(st.integers(0, 255), st.sampled_from(ALL_ES))
    def test_negation_symmetry(code, es):
        """decode(twos_complement(c)) == -decode(c)."""
        n = 8
        if code == (1 << (n - 1)):
            return
        v = ref_codec.ref_decode(code, n, es)
        nc = ((1 << n) - code) & ((1 << n) - 1)
        assert ref_codec.ref_decode(nc, n, es) == -v


    @settings(max_examples=300, deadline=None)
    @given(
        st.floats(width=32, allow_nan=False, allow_infinity=False),
        st.sampled_from([(8, 0), (8, 2), (16, 1), (16, 3)]),
    )
    def test_encode_matches_oracle_hypothesis(x, nes):
        n, es = nes
        got = int(np.asarray(codec.posit_encode(jnp.float32(x), n, es)))
        want = ref_codec.ref_encode(float(np.float32(x)), n, es)
        assert got == want, (x, got, want)


    @settings(max_examples=200, deadline=None)
    @given(
        st.floats(-1e6, 1e6, width=32, allow_nan=False),
        st.sampled_from([(8, 1), (16, 2)]),
    )
    def test_quantize_idempotent(x, nes):
        n, es = nes
        fmt = PositFmt(n, es)
        q1 = codec.quantize(jnp.float32(x), fmt)
        q2 = codec.quantize(q1, fmt)
        assert (np.asarray(q1) == np.asarray(q2)) or (np.isnan(q1) and np.isnan(q2))


    @settings(max_examples=150, deadline=None)
    @given(st.floats(-1e4, 1e4, width=32, allow_nan=False), st.sampled_from(ALL_ES))
    def test_rounding_is_nearest(x, es):
        """|x - q(x)| must be <= the distance to both posit neighbours of q(x).

        Holds only inside the non-saturating range: below minpos the standard's
        never-round-to-zero rule deliberately picks minpos over the nearer 0
        (checked separately in test_saturation_semantics).
        """
        n = 16
        x = float(np.float32(x))
        fmt = PositFmt(n, es)
        if x == 0 or not (fmt.minpos <= abs(x) <= fmt.maxpos):
            return
        code = int(np.asarray(codec.posit_encode(jnp.float32(x), n, es)))
        if code == (1 << (n - 1)):
            return
        v = ref_codec.ref_decode(code, n, es)
        # signed neighbours in code space
        s = code - (1 << n) if code >= (1 << (n - 1)) else code
        for nb in (s - 1, s + 1):
            nbc = nb & ((1 << n) - 1)
            if nbc == (1 << (n - 1)):
                continue
            w = ref_codec.ref_decode(nbc, n, es)
            from fractions import Fraction
            xf = Fraction(x)
            # allow ties (RNE picks one of two equidistant)
            assert abs(xf - v) <= abs(xf - w), (x, es, code, float(v), float(w))
else:
    def test_hypothesis_props():
        pytest.importorskip("hypothesis")
