"""Continuous-batching engine + ragged-cache correctness.

The load-bearing guarantees (ISSUE 4 acceptance):

* staggered admission through the engine produces the SAME tokens per
  request as isolated single-request decoding (greedy, both ``attn_impl``
  settings, posit and float KV caches),
* a ragged batch (rows at different lengths) decodes bit-for-bit like each
  row decoded alone,
* the decoded-bytes-per-step model: the kernel path's bytes scale with
  ragged occupancy, the xla path's with allocated S_max.

Both sides of every token comparison run through the *same* compiled
executables (``engine.reset()`` / shared eager ops): XLA:CPU programs are
not bit-identical across separate compilations, and a reduced random-init
model has near-tied logits that would turn compile noise into flaky argmax
flips.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from benchmarks.bench_serving import decoded_kv_bytes_per_step
from repro.configs import get_arch
from repro.core.pcsr import TransPolicy
from repro.launch.engine import (ContinuousBatchingEngine, Request,
                                 poisson_requests)
from repro.launch.serve import kv_cache_bytes
from repro.models import attention as attn
from repro.models.attention import AttnCfg
from repro.models.registry import build_model

S_MAX = 64


@pytest.fixture(scope="module")
def dense_model():
    cfg = get_arch("yi-34b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _run_staggered(eng, p1, p2, n=6):
    eng.submit(Request(rid=0, prompt=p1, max_new_tokens=n))
    eng.admit()
    eng.step()
    eng.step()
    eng.submit(Request(rid=1, prompt=p2, max_new_tokens=n))
    eng.admit()
    while eng.active.any():
        eng.step()
    return {c.rid: c.tokens for c in eng.completions}


def _run_isolated(eng, rid, prompt, n=6):
    eng.reset()
    eng.submit(Request(rid=rid, prompt=prompt, max_new_tokens=n))
    eng.admit()
    while eng.active.any():
        eng.step()
    return eng.completions[0].tokens


@pytest.mark.parametrize("attn_impl", ["kernel", "xla"])
@pytest.mark.parametrize("kv", ["p8_0", None])
def test_staggered_equals_isolated(dense_model, attn_impl, kv):
    """Continuous batching with staggered admissions == per-request isolated
    decoding, greedy, for every attn_impl x cache-format combination."""
    cfg, model, params = dense_model
    policy = TransPolicy.from_names(kv_cache=kv, attn_impl=attn_impl)
    rng = np.random.default_rng(0)
    p1 = rng.integers(0, cfg.vocab, (12,)).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab, (7,)).astype(np.int32)
    eng = ContinuousBatchingEngine(model, params, policy, max_slots=3,
                                   S_max=S_MAX)
    staggered = _run_staggered(eng, p1, p2)
    assert staggered[0] == _run_isolated(eng, 0, p1)
    assert staggered[1] == _run_isolated(eng, 1, p2)


def test_staggered_equals_isolated_gemma3_rolling():
    """Same equivalence over gemma3: local layers use rolling (circular
    window) caches, so staggered rows wrap at different positions."""
    cfg = get_arch("gemma3-4b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    policy = TransPolicy.from_names(kv_cache="p8_0", attn_impl="kernel")
    rng = np.random.default_rng(1)
    # long enough that local layers wrap their window buffers mid-decode
    p1 = rng.integers(0, cfg.vocab, (14,)).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab, (9,)).astype(np.int32)
    eng = ContinuousBatchingEngine(model, params, policy, max_slots=2,
                                   S_max=S_MAX)
    staggered = _run_staggered(eng, p1, p2, n=8)
    assert staggered[0] == _run_isolated(eng, 0, p1, n=8)
    assert staggered[1] == _run_isolated(eng, 1, p2, n=8)


def test_single_slot_engine_matches_multislot(dense_model):
    """max_slots=1: every cache leaf shape matches the B=1 prefill cache, so
    the structural scatter must be bypassed (regression: it silently no-oped
    and decoded against a zero cache)."""
    cfg, model, params = dense_model
    policy = TransPolicy.from_names(kv_cache="p8_0")
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab, (11,)).astype(np.int32)
    eng1 = ContinuousBatchingEngine(model, params, policy, max_slots=1,
                                    S_max=S_MAX)
    eng2 = ContinuousBatchingEngine(model, params, policy, max_slots=2,
                                    S_max=S_MAX)
    t1 = _run_isolated(eng1, 0, prompt)
    t2 = _run_isolated(eng2, 0, prompt)
    # first token comes straight from prefill logits; the rest decode
    # against the written cache — a no-op write would diverge immediately
    assert t1[0] == t2[0]
    assert t1 == t2


def test_vlm_patch_prefix_budget():
    """vlm rows occupy n_patches + prompt_len cache positions: admission
    must budget for the prefix (regression: requests silently truncated by
    cache-full eviction) and serve the full token count when S_max allows."""
    cfg = get_arch("internvl2-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(4))
    policy = TransPolicy.from_names(kv_cache="p8_0")
    rng = np.random.default_rng(4)
    patches = jnp.asarray(rng.normal(
        0, 1, (1, cfg.n_patches, cfg.d_model)).astype(np.float32))
    prompt = rng.integers(0, cfg.vocab, (10,)).astype(np.int32)
    gen = 5
    tight = cfg.n_patches + len(prompt) + gen - 1   # one position short
    eng = ContinuousBatchingEngine(
        model, params, policy, max_slots=2, S_max=tight,
        prefill_kwargs=lambda req: {"patch_embeds": patches})
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=gen))
    with pytest.raises(ValueError, match="prefix"):
        eng.admit()
    eng2 = ContinuousBatchingEngine(
        model, params, policy, max_slots=2, S_max=tight + 1,
        prefill_kwargs=lambda req: {"patch_embeds": patches})
    eng2.submit(Request(rid=0, prompt=prompt, max_new_tokens=gen))
    eng2.admit()
    while eng2.active.any():
        eng2.step()
    assert len(eng2.completions[0].tokens) == gen


def test_slot_recycling_serves_all_requests(dense_model):
    """More requests than slots: eviction frees slots, recycled slots serve
    later requests, every request completes with its full token budget."""
    cfg, model, params = dense_model
    policy = TransPolicy.from_names(kv_cache="p8_0")
    reqs = poisson_requests(5, arrival_rate=0.0, prompt_lens=(6, 9),
                            max_new_tokens=4, vocab=cfg.vocab, seed=2)
    eng = ContinuousBatchingEngine(model, params, policy, max_slots=2,
                                   S_max=S_MAX)
    done = eng.run(reqs)
    assert sorted(c.rid for c in done) == [0, 1, 2, 3, 4]
    assert all(len(c.tokens) == 4 for c in done)
    # recycled: 5 requests through 2 slots
    assert not eng.active.any() and not eng.queue


def test_eos_eviction(dense_model):
    """A request whose greedy stream hits eos_id is evicted immediately."""
    cfg, model, params = dense_model
    policy = TransPolicy.from_names(kv_cache="p8_0")
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, (10,)).astype(np.int32)
    eng = ContinuousBatchingEngine(model, params, policy, max_slots=2,
                                   S_max=S_MAX)
    free_run = _run_isolated(eng, 0, prompt, n=6)
    eos = free_run[2]
    eng2 = ContinuousBatchingEngine(model, params, policy, max_slots=2,
                                    S_max=S_MAX, eos_id=eos)
    got = _run_isolated(eng2, 0, prompt, n=6)
    assert got == free_run[:3]          # stops at (and includes) the EOS


def test_poisson_requests_shape():
    reqs = poisson_requests(8, arrival_rate=4.0, prompt_lens=(5, 7),
                            max_new_tokens=3, vocab=100, seed=0)
    times = [r.arrival_time for r in reqs]
    assert times == sorted(times) and times[-1] > 0
    assert {r.prompt_len for r in reqs} == {5, 7}
    all_zero = poisson_requests(3, arrival_rate=0.0, vocab=100)
    assert all(r.arrival_time == 0.0 for r in all_zero)


# ------------------------------------------------------- ragged attention ----

def _ragged_setup(kv, seed=0, B=2, Hq=4, Hkv=2, hd=32, S=S_MAX):
    rng = np.random.default_rng(seed)
    acfg = AttnCfg(d_model=Hq * hd, n_heads=Hq, n_kv=Hkv, head_dim=hd)
    params = attn.init_attention(jax.random.key(seed), acfg)
    policy = TransPolicy.from_names(kv_cache=kv)
    cache = attn.init_kv_cache(B, S, acfg, policy)
    kv_fill = rng.normal(0, 1, (B, Hkv, S, hd)).astype(np.float32)
    vv_fill = rng.normal(0, 1, (B, Hkv, S, hd)).astype(np.float32)
    cache["k"] = attn._store(cache["k"], jnp.asarray(kv_fill), 0, policy)
    cache["v"] = attn._store(cache["v"], jnp.asarray(vv_fill), 0, policy)
    lens = np.asarray([13, 37], np.int32)[:B]
    cache["len"] = jnp.asarray(lens)
    x_t = jnp.asarray(rng.normal(0, 1, (B, 1, Hq * hd)).astype(np.float32))
    return acfg, params, policy, cache, lens, x_t


@pytest.mark.parametrize("attn_impl", ["kernel", "xla"])
@pytest.mark.parametrize("kv", ["p8_0", None])
def test_ragged_rows_match_single_request_bitexact(attn_impl, kv):
    """Two rows at different lengths must decode bit-for-bit like each row
    decoded alone (the t<=pos scalar-mask regression: self-attention now
    masks per-row by cache["len"] on every path)."""
    acfg, params, policy, cache, lens, x_t = _ragged_setup(kv)
    policy = dataclasses.replace(policy, attn_impl=attn_impl)
    pos = jnp.asarray(lens)                       # per-row write indices
    y2, c2 = attn.decode_attention_step(params, acfg, x_t, cache, pos, policy)
    for b in range(2):
        c1 = {k: (v[b:b + 1] if hasattr(v, "shape") else v)
              for k, v in cache.items()}
        y1, _ = attn.decode_attention_step(
            params, acfg, x_t[b:b + 1], c1, pos[b:b + 1], policy)
        assert (np.asarray(y2[b]) == np.asarray(y1[0])).all(), \
            f"row {b} (len={lens[b]}) diverges from its isolated decode"
    # per-row len advanced
    assert np.asarray(c2["len"]).tolist() == (lens + 1).tolist()


def test_ragged_full_model_logits_bitexact(dense_model):
    """decode_step over a ragged 2-row batch == per-row B=1 decode (logits)."""
    cfg, model, params = dense_model
    policy = TransPolicy.from_names(kv_cache="p8_0")
    rng = np.random.default_rng(5)
    p1 = rng.integers(0, cfg.vocab, (12,)).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab, (7,)).astype(np.int32)
    from repro.launch.engine import _write_slot
    full = model.init_cache(2, S_MAX, policy)
    caches, toks = [], []
    for p in (p1, p2):
        lg, c = model.prefill(params, jnp.asarray(p)[None], policy,
                              S_max=S_MAX)
        caches.append(c)
        toks.append(int(jnp.argmax(lg, -1)[0]))
    full = _write_slot(full, caches[0], jnp.int32(0))
    full = _write_slot(full, caches[1], jnp.int32(1))
    full["lens"] = jnp.asarray([len(p1), len(p2)], jnp.int32)
    lg2, _ = model.decode_step(params, jnp.asarray(toks), full, policy)
    for b, c in enumerate(caches):
        lg1, _ = model.decode_step(params, jnp.asarray(toks[b:b + 1]), c,
                                   policy)
        assert (np.asarray(lg2[b]) == np.asarray(lg1[0])).all(), f"row {b}"


# --------------------------------------------------- decoded-bytes model ------

def test_decoded_bytes_model():
    """Kernel-path decode bytes scale with ragged occupancy; xla-path with
    the allocated cache — the kernel path never decodes the full cache."""
    kw = dict(n_layers=4, n_kv=2, head_dim=64, code_bytes=1)
    for S_max in (512, 2048, 8192):
        for length in (16, 64, 256):
            kb = decoded_kv_bytes_per_step(S_max, length, impl="kernel", **kw)
            xb = decoded_kv_bytes_per_step(S_max, length, impl="xla", **kw)
            assert kb < xb, (S_max, length)
    # kernel: independent of allocation at fixed occupancy
    assert (decoded_kv_bytes_per_step(2048, 64, impl="kernel", **kw)
            == decoded_kv_bytes_per_step(8192, 64, impl="kernel", **kw))
    # xla: scales with allocation even at fixed occupancy
    assert (decoded_kv_bytes_per_step(8192, 64, impl="xla", **kw)
            == 4 * decoded_kv_bytes_per_step(2048, 64, impl="xla", **kw))
    # kernel tracks occupancy in whole tiles
    assert (decoded_kv_bytes_per_step(2048, 512, impl="kernel", **kw)
            == 2 * decoded_kv_bytes_per_step(2048, 256, impl="kernel", **kw))


def test_kv_cache_bytes_counts_kv_only():
    """The KV footprint must count the k/v code arrays, not bookkeeping or
    recurrent state (serve.py kv_bytes_per_token regression)."""
    cfg = get_arch("yi-34b").reduced()
    model = build_model(cfg)
    policy = TransPolicy.from_names(kv_cache="p8_0")
    cache = model.init_cache(2, 32, policy)
    want = 2 * cfg.n_layers * 2 * cfg.n_kv * 32 * cfg.hd  # uint8 k+v
    assert kv_cache_bytes(cache) == want
    # zamba: ssm state is NOT kv cache
    zcfg = get_arch("zamba2-7b").reduced()
    zmodel = build_model(zcfg)
    zcache = zmodel.init_cache(2, 32, policy)
    from repro.launch.serve import cache_bytes
    assert kv_cache_bytes(zcache) < cache_bytes(zcache)
    n_shared = len(zcache["shared_kv"])
    assert kv_cache_bytes(zcache) == \
        n_shared * 2 * 2 * zcfg.n_kv * 32 * zcfg.hd
