"""Deterministic, sharded, checkpointable synthetic LM data pipeline.

Production posture without external data: every batch is a pure function of
(seed, step, shard), so
  * restarts resume exactly (the cursor is one int in the checkpoint),
  * any host can regenerate any shard (elastic re-sharding / straggler
    work-stealing need no data movement),
  * skipping a step for straggler mitigation is deterministic cluster-wide.

The token stream is a Zipf-ish unigram mixture with induced bigram structure so
losses actually fall during the example training runs (pure uniform noise has
no learnable signal).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticLMPipeline:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1
    shard: int = 0

    def __post_init__(self):
        assert self.global_batch % self.n_shards == 0
        self.local_batch = self.global_batch // self.n_shards
        # fixed "language model" defining the synthetic distribution
        rng = np.random.default_rng(self.seed ^ 0x5EED)
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        self._unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._shift = int(rng.integers(1, max(self.vocab - 1, 2)))

    def batch_at(self, step: int) -> dict:
        """Deterministic batch for (step, shard): tokens/labels (B_local, S)."""
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(self.seed), step), self.shard)
        k1, k2 = jax.random.split(key)
        u = jax.random.choice(
            k1, self.vocab, (self.local_batch, self.seq_len),
            p=jnp.asarray(self._unigram, jnp.float32))
        # induced structure: with p=0.5 the next token is (prev + shift) % V,
        # where prev is the *realized* previous token (true bigram chain)
        follow = jax.random.bernoulli(k2, 0.5, u.shape)

        def step(prev, uf):
            ui, fi = uf
            t = jnp.where(fi, (prev + self._shift) % self.vocab, ui)
            return t, t

        _, toks = jax.lax.scan(
            step, u[:, 0], (u.T, follow.T))
        tokens = toks.T
        labels = jnp.concatenate(
            [tokens[:, 1:], tokens[:, :1]], axis=1)  # next-token targets
        return {"tokens": tokens.astype(jnp.int32),
                "labels": labels.astype(jnp.int32)}

    # ---- checkpointable cursor ----
    def state_dict(self, step: int) -> dict:
        return {"step": step, "seed": self.seed, "n_shards": self.n_shards}

    @staticmethod
    def resume_step(state: dict) -> int:
        return int(state["step"])
