from repro.data.pipeline import SyntheticLMPipeline  # noqa: F401
