"""Posit-compressed collectives (shard_map) — the paper's transport-format
insight applied to the slowest links of a multi-pod system.

``compressed_psum``: all-reduce whose *cross-pod* hop moves posit codes
instead of f32/bf16, as a two-hop compressed all-reduce:

    within pod :  psum over ("data",)              — full precision, fast ICI
    hop 1      :  encode -> all_to_all code shards — each pod-rank receives
                  every peer's copy of its own 1/N shard (1–2 B/element)
    local      :  decode + sum (f32)               — the reduction itself
    hop 2      :  encode -> all_gather shards      — reassembled full tensor

Wire bytes per device ≈ 2·(N-1)/N · M · storage_bytes — exactly 2x (p16) or
4x (p8) less than an f32 ring all-reduce at ANY pod count N.

Two uses of the paper's dynamic-es: ``es`` may be chosen per tensor at
runtime (``auto_es``) so one executable serves every gradient scale, and the
f32 error-feedback residual (Karimireddy-style EF) keeps compression unbiased
across steps. All functions are shard_map-compatible (axis names only).

``quire_psum_posit`` / ``exact_psum`` are the PERCIVAL-style counterpoint:
the reduction runs in the quire domain (integer psum of Kulisch limbs), so
the *sum itself* is exact and only encode/readout round — see DESIGN.md §7.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.codec import auto_es, posit_decode, posit_encode
from repro.core.quire import (
    QuireFmt, quire_from_posit, quire_normalize, quire_read,
)
from repro.core.types import PositFmt


def _axis_size(axis: str) -> int:
    """Static size of a named mesh axis (lax.axis_size on current jax; the
    axis-env frame on older releases where it does not exist yet)."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis)
    import jax.core as jcore

    frame = jcore.axis_frame(axis)  # returns the size directly on some versions
    return frame if isinstance(frame, int) else frame.size


def _pow2_scale(x: jax.Array, axis: Optional[str]):
    """Exact power-of-2 normalizer centering |x| at posit's accuracy peak.

    Posit accuracy tapers away from 1.0; gradients live at ~1e-3 where p16_0
    would spend ~10 regime bits. Scaling by 2^-k (k = floor(log2 max|x|)) is
    *exact* (both directions), costs one f32 per tensor, and is the posit
    analogue of fp8 per-tensor scaling (beyond-paper; EXPERIMENTS.md §Perf).
    """
    amax = jnp.max(jnp.abs(x))
    if axis is not None:
        amax = lax.pmax(amax, axis)
    k = jnp.where(amax > 0,
                  jnp.floor(jnp.log2(jnp.maximum(amax, 1e-38))), 0.0)
    inv = jnp.exp2(-k)
    return inv, jnp.exp2(k)


def compressed_allreduce(x: jax.Array, fmt: PositFmt, axis: str,
                         es=None) -> jax.Array:
    """Two-hop posit-compressed all-reduce over `axis` (inside shard_map)."""
    n = _axis_size(axis)
    shape = x.shape
    xf = x.astype(jnp.float32).reshape(-1)
    M = xf.shape[0]
    pad = (-M) % n
    if pad:
        xf = jnp.pad(xf, (0, pad))
    inv, back = _pow2_scale(xf, axis)
    xf = xf * inv
    if es is None:
        es = lax.pmax(auto_es(xf, fmt.nbits), axis)
    codes = posit_encode(xf, fmt.nbits, es, ftz=True).reshape(n, -1)
    # hop 1: everyone sends shard j to rank j (codes, 1–2 B/element)
    recv = lax.all_to_all(codes, axis, split_axis=0, concat_axis=0, tiled=False)
    partial = jnp.sum(posit_decode(recv, fmt.nbits, es), axis=0)  # own shard
    # hop 2: share the reduced shards (codes again)
    out_codes = posit_encode(partial, fmt.nbits, es, ftz=True)
    full = lax.all_gather(out_codes, axis, tiled=True)
    out = posit_decode(full, fmt.nbits, es) * back
    if pad:
        out = out[:M]
    return out.reshape(shape).astype(x.dtype)


def quire_psum_posit(codes: jax.Array, fmt: PositFmt, axis: str,
                     es=None, out_es=None) -> jax.Array:
    """EXACT all-reduce of posit values over `axis` (inside shard_map).

    Each device injects its codes into a quire (exact), the int32 limbs are
    integer-psummed (exact: canonical digits stay in int32 for up to 2^14
    devices), and ONE terminal rounding produces the result — bit-identical
    to summing the decoded values in infinite precision and encoding once.
    NaR on any device poisons the reduction to NaR (flag limbs sum).

    The trade is wire bytes for exactness: the quire payload is
    4*(n_limbs+1) B/element (vs 1-2 B for compressed codes), so this is the
    collective for small precision-critical reductions — losses, norms,
    router statistics, quire-GEMM partials over a sharded K — not bulk
    gradient traffic.
    """
    qf = QuireFmt.for_posit(fmt)
    e = fmt.es if es is None else es
    q = quire_from_posit(codes, qf, es=e)
    q = lax.psum(q, axis)
    q = quire_normalize(q, qf)
    return quire_read(q, qf, es_out=e if out_es is None else out_es)


def exact_psum(x: jax.Array, fmt: PositFmt, axis: str, es=None) -> jax.Array:
    """psum of float tensors through the quire domain (inside shard_map).

    Exactly two roundings total regardless of device count: each device
    encodes its contribution to posit once, the quire-domain sum is exact,
    and the readout rounds once. (A ring/tree float all-reduce re-rounds at
    every hop; ``compressed_allreduce`` re-rounds twice more.) The pow2
    prescale is exact in both directions, so it does not add roundings.
    """
    xf = x.astype(jnp.float32)
    inv, back = _pow2_scale(xf, axis)
    xs = xf * inv
    if es is None:
        es = lax.pmax(auto_es(xs, fmt.nbits), axis)
    codes = posit_encode(xs, fmt.nbits, es, ftz=True)
    total = posit_decode(quire_psum_posit(codes, fmt, axis, es=es),
                         fmt.nbits, es) * back
    return total.astype(x.dtype)


def compressed_psum(x: jax.Array, fmt: Optional[PositFmt], *,
                    intra_axis="data", inter_axis: Optional[str] = "pod",
                    residual: Optional[jax.Array] = None, es=None,
                    exact: bool = False):
    """psum over (intra_axis, inter_axis); the inter hop is posit-compressed.

    Returns (sum, new_residual). fmt=None -> plain psum (IEEE bypass).
    Error feedback: `residual` (f32, same shape as x) carries the quantization
    error of *this device's contribution* into the next step.
    ``exact=True`` runs the inter hop in the quire domain: the per-device
    encode rounding still happens (and still feeds the residual), but the
    cross-pod reduction itself is exact with a single readout rounding —
    the rounded-hop noise of the two-hop path disappears entirely.
    """
    y = lax.psum(x, intra_axis)
    if inter_axis is None:
        return y, residual
    if fmt is None:
        return lax.psum(y, inter_axis), residual

    yf = y.astype(jnp.float32)
    if residual is not None:
        yf = yf + residual
    inv, back = _pow2_scale(yf, inter_axis)
    ys = yf * inv
    if es is None:
        es_t = lax.pmax(auto_es(ys, fmt.nbits), inter_axis)
    else:
        es_t = es
    codes = posit_encode(ys, fmt.nbits, es_t, ftz=True)
    sent = posit_decode(codes, fmt.nbits, es_t) * back
    new_residual = yf - sent
    if exact:
        total = posit_decode(quire_psum_posit(codes, fmt, inter_axis, es=es_t),
                             fmt.nbits, es_t) * back
    else:
        total = compressed_allreduce(sent, fmt, inter_axis, es=es_t)
    return total.astype(x.dtype), new_residual


def compressed_all_gather(x_codes: jax.Array, axis: str, fmt: PositFmt,
                          es=None, out_dtype=jnp.float32) -> jax.Array:
    """all_gather posit codes along `axis`, decode once locally (FSDP unshard):
    the wire moves 1–2-byte codes (2–4x less traffic than f32/bf16)."""
    g = lax.all_gather(x_codes, axis, tiled=True)
    e = fmt.es if es is None else es
    return posit_decode(g, fmt.nbits, e).astype(out_dtype)


def make_grad_sync(mesh, fmt: Optional[PositFmt], *, use_pod_axis: bool,
                   exact: bool = False):
    """Pytree gradient synchronizer built on compressed_psum (see steps.py for
    the shard_map integration into the train step). ``exact=True`` (the
    TransPolicy.exact_collectives bit) makes the cross-pod hop a quire-domain
    exact reduction."""
    axes = ("pod", "data") if use_pod_axis else ("data",)
    n_total = 1
    for a in axes:
        n_total *= mesh.shape[a]

    def sync(grads, residuals):
        flat_g, td = jax.tree.flatten(grads)
        flat_r = (td.flatten_up_to(residuals) if residuals is not None
                  else [None] * len(flat_g))
        outs = []
        for g, r in zip(flat_g, flat_r):
            if use_pod_axis:
                s, r2 = compressed_psum(g, fmt, intra_axis="data",
                                        inter_axis="pod", residual=r,
                                        exact=exact)
            else:
                s, r2 = lax.psum(g, "data"), r
            outs.append((s / n_total, r2))
        return (td.unflatten([o[0] for o in outs]),
                td.unflatten([o[1] for o in outs]))

    return sync
