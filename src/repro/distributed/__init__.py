from repro.distributed.collectives import (  # noqa: F401
    compressed_psum, make_grad_sync,
)
