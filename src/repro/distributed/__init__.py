from repro.distributed.collectives import (  # noqa: F401
    compressed_psum, exact_psum, make_grad_sync, quire_psum_posit,
)
