"""Jitted front door for the fused posit GEMM.

``impl``:
  "pallas"     — the TPU kernel (interpret=True on CPU: same semantics, Python exec)
  "xla"        — XLA-fused path (repro.core.dot); what models use on CPU and what
                 the dry-run lowers — numerically identical contract
  "auto"       — pallas on TPU, xla elsewhere
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.dot import posit_dot
from repro.core.lut import resolve_codec_impl
from repro.core.pack import unpack_p8
from repro.core.pcsr import OperandSlots
from repro.kernels.posit_gemm.posit_gemm import posit_gemm


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def gemm(
    a: jax.Array,
    b: jax.Array,
    slots: OperandSlots,
    *,
    es_a=None, es_b=None, es_out=None,
    bias=None, activation: str = "none", residual=None,
    impl: str = "auto",
    interpret: bool | None = None,
    **block_kw,
) -> jax.Array:
    """O = epilogue(decode(A) @ decode(B)) -> encode, per the pcsr slots.

    ``bias``/``activation``/``residual`` fuse the layer epilogue into the
    kernel's emit step (one launch, one HBM write).  A pcsr with
    ``dataflow="quire"`` (or impl="quire") routes to the exact-accumulation
    kernel package (posit_quire_gemm)."""
    if impl == "quire" or (impl == "auto" and slots.dataflow == "quire"):
        from repro.kernels.posit_quire_gemm.ops import quire_gemm

        if slots.rs2_packed:
            # lane extraction is cheap integer ops; the quire kernel then
            # sees plain p8 codes (its accumulation is format-independent)
            b = unpack_p8(b, k=a.shape[1])
            slots = slots.with_packed(False)
        return quire_gemm(a, b, slots, es_a=es_a, es_b=es_b, es_out=es_out,
                          bias=bias, activation=activation, residual=residual,
                          impl="auto", interpret=interpret, **block_kw)
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "xla"
    def _es(x, fmt):
        if x is not None:
            return x
        return fmt.es if hasattr(fmt, "es") else 0
    # profile tag: every posit-GEMM dispatch carries one scope name so
    # jax.profiler device traces line up with the serving spans (obs/trace)
    from repro.obs import prof
    from repro.obs.trace import named_scope

    def _run():
        with named_scope(f"repro.posit_gemm.{impl}"):
            if impl == "pallas":
                interp = interpret if interpret is not None else not _on_tpu()
                es = jnp.asarray(
                    [_es(es_a, slots.rs1), _es(es_b, slots.rs2),
                     _es(es_out, slots.rd)],
                    dtype=jnp.int32,
                )
                # in-kernel lane decode: the LUT gather off-TPU (interpret),
                # the bit pipeline on Mosaic (gathers are hostile in-kernel)
                codec_impl = ("bits" if _on_tpu() else
                              resolve_codec_impl(slots.codec_impl, 8, "decode"))
                return posit_gemm(
                    a, b, es,
                    a_fmt=slots.rs1, b_fmt=slots.rs2, out_fmt=slots.rd,
                    bias=bias, activation=activation, residual=residual,
                    interpret=interp, b_packed=slots.rs2_packed,
                    codec_impl=codec_impl, **block_kw,
                )
            if impl == "xla":
                return posit_dot(a, b, slots, es_a=es_a, es_b=es_b,
                                 es_out=es_out, bias=bias,
                                 activation=activation,
                                 residual=residual, impl="fused")
            if impl == "unfused":
                return posit_dot(a, b, slots, es_a=es_a, es_b=es_b,
                                 es_out=es_out, bias=bias,
                                 activation=activation,
                                 residual=residual, impl="unfused")
        raise ValueError(f"unknown impl {impl!r}")

    if not prof.is_active():
        return _run()
    return prof.dispatch(
        "gemm", impl, prof.gemm_cost(a, b, slots, bias=bias,
                                     residual=residual),
        _run, primary=a)
