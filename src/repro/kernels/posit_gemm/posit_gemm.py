"""Fused posit GEMM Pallas kernel — the paper's codec-at-the-FPU-boundary, tiled.

Dataflow per (i, j, k) grid step (paper Fig. 2(b) on the TPU memory hierarchy):

    HBM --BlockSpec--> VMEM:  A tile (bm x bk)   posit codes or float
                              B tile (bk x bn)   posit codes or float
                              bias (1 x bn), residual (bm x bn)   [optional]
    VMEM:   [input decoder]   posit -> bf16/f32  (skipped for float operands)
    MXU:    acc(f32) += A' @ B'                  (the "FPU datapath")
    VMEM:   [fused epilogue]  act(acc + bias) + residual      (last k)
    VMEM:   [output encoder]  f32 -> posit       (skipped for float rd)
    VMEM --BlockSpec--> HBM:  O tile (bm x bn)

Posit operands move through HBM as 1–2-byte codes, so a p8 x p8 GEMM reads 4x
fewer HBM bytes than f32 (the paper's scratchpad-savings, Table IV) and the
decode rides in VMEM next to the MXU (the paper's lightweight-codec claim).
The epilogue (bias add, activation, residual add, output encode) runs inside
the same kernel invocation: one launch and one HBM write per layer instead of
a gemm -> bias -> act -> encode chain of four (DESIGN.md §8).

``es`` for (rs1, rs2, rd) arrives as a scalar-prefetch vector — the pcsr: one
compiled kernel serves every exponent size at runtime.

Grid is (m, n, k) with k innermost/arbitrary; a VMEM f32 scratch accumulates
across k tiles (revisited output pattern).  Block sizes are rounded *up* to
hardware-friendly multiples (lane = 128, sublane per dtype) and the operands
padded, never shrunk to ragged tiles: ``min(block, dim)`` on a small dim used
to produce tiles that violate the TPU (sublane, lane) tiling.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import LANE, pad_to, round_block, sublane, tpu_compiler_params

from repro.core.codec import posit_decode, posit_encode
from repro.core.dot import ACTIVATIONS, _apply_activation
from repro.core.lut import _p8_decode_table
from repro.core.pack import split_activations
from repro.core.types import Fmt, PositFmt, compute_dtype_for


def _decode_p8_lane(codes, es, lut_ref):
    """In-kernel p8 decode of one extracted lane: the PR-2 LUT gather where
    the backend tolerates it (``lut_ref`` holds the (4, 256) decode table as
    a kernel input — Pallas kernels can't close over constants), the bit
    pipeline on Mosaic (``lut_ref is None``)."""
    if lut_ref is not None:
        return lut_ref[...][es][codes.astype(jnp.int32)]
    return posit_decode(codes, 8, es)


def _gemm_kernel(
    es_ref,  # scalar prefetch: (3,) int32 = es for rs1, rs2, rd
    *refs,
    a_fmt: Fmt, b_fmt: Fmt, out_fmt: Fmt, compute_dtype, n_k: int,
    activation: str, has_bias: bool, has_residual: bool,
    b_packed: bool = False, codec_impl: str = "bits",
):
    it = iter(refs)
    lut_ref = None
    if b_packed:
        a_lo_ref, a_hi_ref, b_ref = next(it), next(it), next(it)
        if codec_impl == "lut":
            lut_ref = next(it)
    else:
        a_ref, b_ref = next(it), next(it)
    bias_ref = next(it) if has_bias else None
    res_ref = next(it) if has_residual else None
    o_ref, acc_ref = next(it), next(it)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def dec_a(ref):
        a = ref[...]
        if isinstance(a_fmt, PositFmt):
            return posit_decode(a, a_fmt.nbits, es_ref[0]).astype(compute_dtype)
        return a.astype(compute_dtype)

    if b_packed:
        # split-K packed lanes (core/pack.py): the (bk, bn) uint16 tile holds
        # 2*bk p8 codes; each lane extract + decode feeds one full-width MXU
        # contraction against the matching half of A — two dots per tile,
        # half the B words through the BlockSpec pipeline.
        bp = b_ref[...]
        b_lo = _decode_p8_lane(bp & jnp.uint16(0xFF), es_ref[1],
                               lut_ref).astype(compute_dtype)
        b_hi = _decode_p8_lane(bp >> jnp.uint16(8), es_ref[1],
                               lut_ref).astype(compute_dtype)
        acc_ref[...] += (
            jnp.dot(dec_a(a_lo_ref), b_lo, preferred_element_type=jnp.float32)
            + jnp.dot(dec_a(a_hi_ref), b_hi, preferred_element_type=jnp.float32))
    else:
        a = dec_a(a_ref)
        b = b_ref[...]
        if isinstance(b_fmt, PositFmt):
            b = posit_decode(b, b_fmt.nbits, es_ref[1]).astype(compute_dtype)
        else:
            b = b.astype(compute_dtype)
        acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _emit():
        r = acc_ref[...]
        # fused epilogue: act(acc + bias) + residual, all in f32 in VMEM
        if has_bias:
            r = r + bias_ref[...].astype(jnp.float32)
        r = _apply_activation(r, activation)
        if has_residual:
            r = r + res_ref[...].astype(jnp.float32)
        if isinstance(out_fmt, PositFmt):
            o_ref[...] = posit_encode(r, out_fmt.nbits, es_ref[2])
        else:
            o_ref[...] = r.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "a_fmt", "b_fmt", "out_fmt", "block_m", "block_n", "block_k",
        "compute_dtype_name", "activation", "interpret", "b_packed",
        "codec_impl",
    ),
)
def posit_gemm(
    a: jax.Array,
    b: jax.Array,
    es: jax.Array,  # (3,) int32: es for a, b, out (ignored for float slots)
    *,
    a_fmt: Fmt,
    b_fmt: Fmt,
    out_fmt: Fmt,
    bias: Optional[jax.Array] = None,      # (N,) f32
    residual: Optional[jax.Array] = None,  # (M, N) float
    activation: str = "none",
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    compute_dtype_name: Optional[str] = None,
    interpret: bool = False,
    b_packed: bool = False,
    codec_impl: str = "bits",
) -> jax.Array:
    """O = epilogue(decode(A) @ decode(B)), encoded per out_fmt.

    A: (M, K), B: (K, N); epilogue = ``act(acc + bias) + residual`` fused
    into the last k step (one kernel launch, one HBM write per layer).

    ``b_packed=True`` takes B as (ceil(K/2), N) uint16 split-K packed p8
    lanes (core/pack.py): half the B words move HBM->VMEM, both lanes decode
    in VMEM (``codec_impl``: "bits" pipeline, or "lut" gather where the
    backend tolerates it), and each grid step runs two MXU contractions
    against the matching halves of A.
    """
    M, K = a.shape
    if b_packed:
        if not (isinstance(b_fmt, PositFmt) and b_fmt.nbits == 8):
            raise ValueError(f"b_packed requires p8 b_fmt, got {b_fmt}")
        Kh, N = b.shape
        assert Kh == (K + 1) // 2, (a.shape, b.shape)
    else:
        K2, N = b.shape
        assert K == K2, (a.shape, b.shape)
    if activation not in ACTIVATIONS:
        raise ValueError(f"activation must be one of {ACTIVATIONS}, got {activation!r}")
    if compute_dtype_name is None:
        ca, cb = compute_dtype_for(a_fmt), compute_dtype_for(b_fmt)
        compute_dtype = ca if ca == cb else jnp.float32
    else:
        compute_dtype = jnp.dtype(compute_dtype_name)

    if isinstance(out_fmt, PositFmt):
        out_dtype = jnp.uint8 if out_fmt.nbits == 8 else jnp.uint16
    else:
        out_dtype = out_fmt.dtype

    # Lane/sublane-friendly blocks: bm is a sublane dim for *every* array
    # blocked on it (A, the f32 acc/residual, and the output — whose dtype
    # may be narrower than A's), bk a lane dim for A and sublane for B,
    # bn a lane dim for B/out.
    m_mult = max(sublane(a.dtype), sublane(out_dtype), 8)
    k_mult = max(LANE, sublane(b.dtype))
    bm = round_block(M, block_m, m_mult)
    bn = round_block(N, block_n, LANE)
    if b_packed:
        # grid k runs over the *packed* half-K; A splits into the (lo, hi)
        # halves matching the lanes — two BlockSpecs over the two halves
        bk = round_block(Kh, block_k, k_mult)
        a_lo, a_hi = split_activations(a, Kh)  # odd K: zero col pairs pad lane
        a_lo = pad_to(a_lo, (bm, bk))
        a_hi = pad_to(a_hi, (bm, bk))
        b_p = pad_to(b, (bk, bn))
        Mp, Kp = a_lo.shape
        _, Np = b_p.shape
        grid = (Mp // bm, Np // bn, Kp // bk)
        in_specs = [
            pl.BlockSpec((bm, bk), lambda i, j, k, s: (i, k)),
            pl.BlockSpec((bm, bk), lambda i, j, k, s: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k, s: (k, j)),
        ]
        inputs = [a_lo, a_hi, b_p]
        if codec_impl == "lut":
            # the (4, 256) p8 decode table rides along as a (replicated)
            # kernel input — Pallas kernels cannot close over constants
            in_specs.append(pl.BlockSpec((4, 256), lambda i, j, k, s: (0, 0)))
            inputs.append(jnp.asarray(_p8_decode_table()))
    else:
        bk = round_block(K, block_k, k_mult)
        a_p = pad_to(a, (bm, bk))
        b_p = pad_to(b, (bk, bn))
        Mp, Kp = a_p.shape
        _, Np = b_p.shape
        grid = (Mp // bm, Np // bn, Kp // bk)
        in_specs = [
            pl.BlockSpec((bm, bk), lambda i, j, k, s: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k, s: (k, j)),
        ]
        inputs = [a_p, b_p]
    if bias is not None:
        assert bias.shape == (N,), (bias.shape, N)
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k, s: (0, j)))
        inputs.append(pad_to(bias.astype(jnp.float32)[None, :], (1, bn)))
    if residual is not None:
        assert residual.shape == (M, N), (residual.shape, (M, N))
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, k, s: (i, j)))
        inputs.append(pad_to(residual.astype(jnp.float32), (bm, bn)))

    kernel = functools.partial(
        _gemm_kernel,
        a_fmt=a_fmt, b_fmt=b_fmt, out_fmt=out_fmt,
        compute_dtype=compute_dtype, n_k=grid[2],
        activation=activation, has_bias=bias is not None,
        has_residual=residual is not None,
        b_packed=b_packed, codec_impl=codec_impl,
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, s: (i, j)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(jnp.asarray(es, jnp.int32), *inputs)
    return out[:M, :N]
