"""Fused posit GEMM Pallas kernel — the paper's codec-at-the-FPU-boundary, tiled.

Dataflow per (i, j, k) grid step (paper Fig. 2(b) on the TPU memory hierarchy):

    HBM --BlockSpec--> VMEM:  A tile (bm x bk)   posit codes or float
                              B tile (bk x bn)   posit codes or float
    VMEM:   [input decoder]   posit -> bf16/f32  (skipped for float operands)
    MXU:    acc(f32) += A' @ B'                  (the "FPU datapath")
    VMEM:   [output encoder]  f32 -> posit       (skipped for float rd; last k)
    VMEM --BlockSpec--> HBM:  O tile (bm x bn)

Posit operands move through HBM as 1–2-byte codes, so a p8 x p8 GEMM reads 4x
fewer HBM bytes than f32 (the paper's scratchpad-savings, Table IV) and the
decode rides in VMEM next to the MXU (the paper's lightweight-codec claim).

``es`` for (rs1, rs2, rd) arrives as a scalar-prefetch vector — the pcsr: one
compiled kernel serves every exponent size at runtime.

Grid is (m, n, k) with k innermost/arbitrary; a VMEM f32 scratch accumulates
across k tiles (revisited output pattern).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

from repro.core.codec import posit_decode, posit_encode
from repro.core.types import Fmt, PositFmt, compute_dtype_for


def _gemm_kernel(
    es_ref,  # scalar prefetch: (3,) int32 = es for rs1, rs2, rd
    a_ref, b_ref, o_ref, acc_ref,
    *, a_fmt: Fmt, b_fmt: Fmt, out_fmt: Fmt, compute_dtype, n_k: int,
):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    if isinstance(a_fmt, PositFmt):
        a = posit_decode(a, a_fmt.nbits, es_ref[0]).astype(compute_dtype)
    else:
        a = a.astype(compute_dtype)
    b = b_ref[...]
    if isinstance(b_fmt, PositFmt):
        b = posit_decode(b, b_fmt.nbits, es_ref[1]).astype(compute_dtype)
    else:
        b = b.astype(compute_dtype)

    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _emit():
        r = acc_ref[...]
        if isinstance(out_fmt, PositFmt):
            o_ref[...] = posit_encode(r, out_fmt.nbits, es_ref[2])
        else:
            o_ref[...] = r.astype(o_ref.dtype)


def _pad_to(x: jax.Array, mults: tuple) -> jax.Array:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        x = jnp.pad(x, pads)  # 0-codes decode to 0.0 -> contribute nothing
    return x


@functools.partial(
    jax.jit,
    static_argnames=(
        "a_fmt", "b_fmt", "out_fmt", "block_m", "block_n", "block_k",
        "compute_dtype_name", "interpret",
    ),
)
def posit_gemm(
    a: jax.Array,
    b: jax.Array,
    es: jax.Array,  # (3,) int32: es for a, b, out (ignored for float slots)
    *,
    a_fmt: Fmt,
    b_fmt: Fmt,
    out_fmt: Fmt,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    compute_dtype_name: Optional[str] = None,
    interpret: bool = False,
) -> jax.Array:
    """O = decode(A) @ decode(B), encoded per out_fmt. A: (M, K), B: (K, N)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    if compute_dtype_name is None:
        ca, cb = compute_dtype_for(a_fmt), compute_dtype_for(b_fmt)
        compute_dtype = ca if ca == cb else jnp.float32
    else:
        compute_dtype = jnp.dtype(compute_dtype_name)

    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    a_p = _pad_to(a, (bm, bk))
    b_p = _pad_to(b, (bk, bn))
    Mp, Kp = a_p.shape
    _, Np = b_p.shape
    grid = (Mp // bm, Np // bn, Kp // bk)

    if isinstance(out_fmt, PositFmt):
        out_dtype = jnp.uint8 if out_fmt.nbits == 8 else jnp.uint16
    else:
        out_dtype = out_fmt.dtype

    kernel = functools.partial(
        _gemm_kernel,
        a_fmt=a_fmt, b_fmt=b_fmt, out_fmt=out_fmt,
        compute_dtype=compute_dtype, n_k=grid[2],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, k, s: (i, k)),
                pl.BlockSpec((bk, bn), lambda i, j, k, s: (k, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, s: (i, j)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(jnp.asarray(es, jnp.int32), a_p, b_p)
    return out[:M, :N]
