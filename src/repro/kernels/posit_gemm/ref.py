"""Pure-jnp oracle for the fused posit GEMM kernel (untiled, same math)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.codec import posit_decode, posit_encode
from repro.core.dot import apply_epilogue
from repro.core.pack import packed_decode_p8
from repro.core.types import Fmt, PositFmt, compute_dtype_for


def posit_gemm_ref(
    a: jax.Array, b: jax.Array, es,  # (3,) int32
    *, a_fmt: Fmt, b_fmt: Fmt, out_fmt: Fmt, compute_dtype_name=None,
    bias: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
    activation: str = "none",
    b_packed: bool = False,
) -> jax.Array:
    if compute_dtype_name is None:
        ca, cb = compute_dtype_for(a_fmt), compute_dtype_for(b_fmt)
        compute_dtype = ca if ca == cb else jnp.float32
    else:
        compute_dtype = jnp.dtype(compute_dtype_name)
    es = jnp.asarray(es, jnp.int32)
    af = (posit_decode(a, a_fmt.nbits, es[0]) if isinstance(a_fmt, PositFmt) else a)
    if b_packed:
        bf = packed_decode_p8(b, es[1], codec_impl="bits", k=a.shape[1])
    else:
        bf = (posit_decode(b, b_fmt.nbits, es[1])
              if isinstance(b_fmt, PositFmt) else b)
    y = jnp.dot(
        af.astype(compute_dtype), bf.astype(compute_dtype),
        preferred_element_type=jnp.float32,
    )
    if bias is not None or activation != "none" or residual is not None:
        y = apply_epilogue(y, bias, activation, residual)
    if isinstance(out_fmt, PositFmt):
        return posit_encode(y, out_fmt.nbits, es[2])
    return y.astype(out_fmt.dtype)
