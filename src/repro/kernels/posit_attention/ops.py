"""Front door for posit-KV decode attention: pallas on TPU, XLA oracle on CPU."""
from __future__ import annotations

import jax

from repro.kernels.posit_attention.posit_attention import posit_decode_attention
from repro.kernels.posit_attention.ref import posit_decode_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def decode_attention(q, k_codes, v_codes, lengths, es, *, kv_bits,
                     scale=None, impl="auto", interpret=None, block_s=512):
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "xla"
    if impl == "pallas":
        if interpret is None:
            interpret = not _on_tpu()
        return posit_decode_attention(
            q, k_codes, v_codes, lengths, es,
            kv_bits=kv_bits, scale=scale, block_s=block_s, interpret=interpret)
    return posit_decode_attention_ref(
        q, k_codes, v_codes, lengths, es, kv_bits=kv_bits, scale=scale)
