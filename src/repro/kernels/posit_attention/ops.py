"""Front door for posit-KV decode attention.

Implementations (one contract: flash decode over a possibly-ragged KV cache,
``lengths``-masked per batch row, posit codes decoded tile-wise — the full
cache is never materialized in float):

* ``pallas`` — the TPU flash kernel (posit_attention.py): codes stream
  HBM->VMEM and are decoded in VMEM right before the dot.
* ``tiled``  — the off-TPU serving path: an online-softmax ``while_loop``
  over S tiles with a *dynamic* trip count ``ceil(max(lengths)/block_s)``,
  so per-step decode work scales with the longest live sequence in the
  batch, not with ``S_max``.
* ``xla``    — the pure-jnp oracle (ref.py): full-cache decode + dense
  softmax.  Numerics ground truth for tests.
* ``auto``   — pallas on TPU, tiled elsewhere.

``kv_bits=0`` means a float KV cache: every path bypasses the codec and
just upcasts tiles (the ragged masking / tiling contract is unchanged).
``rolling=True`` is circular-buffer validity (gemma3 local layers): every
slot written so far is valid, i.e. lengths are clamped to the buffer size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.codec import posit_decode
from repro.kernels.posit_attention.posit_attention import posit_decode_attention
from repro.kernels.posit_attention.ref import posit_decode_attention_ref

_NEG_INF = -1e30


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("kv_bits", "scale", "block_s"))
def posit_decode_attention_tiled(
    q: jax.Array,          # (B, Hq, d) float
    k_codes: jax.Array,    # (B, Hkv, S, d) posit codes (float when kv_bits=0)
    v_codes: jax.Array,    # (B, Hkv, S, d)
    lengths: jax.Array,    # (B,) int32 — valid KV length per batch row
    es,                    # int32 scalar — pcsr pes for the KV cache
    *,
    kv_bits: int,
    scale: float | None = None,
    block_s: int = 256,
) -> jax.Array:
    """Length-bounded flash decode in plain XLA (the kernel contract off-TPU).

    ``lax.while_loop`` with trip count ``ceil(max(lengths)/block_s)``: tiles
    past the longest live row are never sliced, decoded, or dotted — decode
    bytes per step follow the *ragged* occupancy, not the allocated S_max.
    Rows with length 0 return zeros.
    """
    B, Hq, d = q.shape
    _, Hkv, S, _ = k_codes.shape
    g = Hq // Hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    bs = min(block_s, S)
    S_p = -(-S // bs) * bs
    if S_p != S:  # padded tail is masked off via lengths
        pad = [(0, 0), (0, 0), (0, S_p - S), (0, 0)]
        k_codes = jnp.pad(k_codes, pad)
        v_codes = jnp.pad(v_codes, pad)

    qg = q.reshape(B, Hkv, g, d).astype(jnp.float32) * scale
    lengths = jnp.asarray(lengths, jnp.int32)
    # traced tile count, clamped so an over-long row can't spin the loop
    n_live = -(-jnp.minimum(jnp.max(lengths), S) // bs)

    def decode_tile(codes):
        if kv_bits:
            return posit_decode(codes, kv_bits, es).astype(jnp.float32)
        return codes.astype(jnp.float32)

    def body(carry):
        i, m, l, acc = carry
        kt = decode_tile(jax.lax.dynamic_slice_in_dim(k_codes, i * bs, bs, 2))
        vt = decode_tile(jax.lax.dynamic_slice_in_dim(v_codes, i * bs, bs, 2))
        s = jnp.einsum("bkgd,bktd->bkgt", qg, kt)           # (B,Hkv,g,bs)
        pos = i * bs + jnp.arange(bs)
        valid = pos[None, :] < lengths[:, None]             # (B,bs)
        s = jnp.where(valid[:, None, None, :], s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        # explicit zero for masked slots: an all-masked row keeps m at
        # _NEG_INF, where exp(s - m) == 1 would leak a uniform average
        p = jnp.where(valid[:, None, None, :], jnp.exp(s - m_new), 0.0)
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bkgt,bktd->bkgd", p, vt)
        return i + 1, m_new, l, acc

    init = (jnp.int32(0),
            jnp.full((B, Hkv, g, 1), _NEG_INF, jnp.float32),
            jnp.zeros((B, Hkv, g, 1), jnp.float32),
            jnp.zeros((B, Hkv, g, d), jnp.float32))
    *_, l, acc = jax.lax.while_loop(lambda c: c[0] < n_live, body, init)
    out = acc / jnp.where(l == 0, 1.0, l)
    return out.reshape(B, Hq, d).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("kv_bits", "scale"))
def posit_decode_attention_paged(
    q: jax.Array,            # (B, Hq, d) float
    k_pool: jax.Array,       # (N_blocks, Hkv, bt, d) posit codes (one layer)
    v_pool: jax.Array,       # (N_blocks, Hkv, bt, d)
    block_table: jax.Array,  # (B, W) int32 block ids; >= N_blocks = empty
    lengths: jax.Array,      # (B,) int32 — valid KV length per batch row
    es,                      # int32 scalar — pcsr pes for the KV cache
    *,
    kv_bits: int,
    scale: float | None = None,
) -> jax.Array:
    """The indirection-aware sibling of :func:`posit_decode_attention_tiled`.

    Lowering: ONE batched gather de-pages each row's block-table window into
    a contiguous ``(B, Hkv, W*bt, d)`` code view, which then runs the exact
    tiled online-softmax above — the same compiled attention the slot grid
    uses, at the same (wide) tile size.  The earlier lowering looped the
    online softmax block-by-block (``bt``-sized tiles), which is the right
    shape for a Pallas TPU kernel but ~2x slower in XLA:CPU, where 16-token
    tiles are dispatch-dominated; hoisting the indirection into one gather
    restores grid-path decode cost and makes warm-vs-cold bit-identity
    structural rather than empirical.

    Table entries past a row's length are sentinels (``>= N_blocks``); their
    clamped gather reads whatever lives in an arbitrary real block, so
    masking must silence *values*, not just scores — a recycled block can
    hold NaR codes that decode to NaN, and ``0 * NaN`` would poison the
    accumulator through the masked-out probability.  Zeroing the gathered
    *codes* suffices: code 0 decodes to exact 0.0 in every posit config
    (and is 0.0 already when ``kv_bits == 0``).
    """
    B = q.shape[0]
    N, Hkv, bt, d = k_pool.shape
    W = block_table.shape[1]
    lengths = jnp.asarray(lengths, jnp.int32)
    table = jnp.minimum(jnp.asarray(block_table, jnp.int32), N - 1)

    def depage(pool):
        codes = pool[table]                        # (B, W, Hkv, bt, d)
        codes = jnp.moveaxis(codes, 2, 1)          # (B, Hkv, W, bt, d)
        return codes.reshape(B, Hkv, W * bt, d)

    valid = (jnp.arange(W * bt)[None, :] < lengths[:, None])[:, None, :, None]
    k_codes = jnp.where(valid, depage(k_pool), 0)
    v_codes = jnp.where(valid, depage(v_pool), 0)
    return posit_decode_attention_tiled(q, k_codes, v_codes, lengths, es,
                                        kv_bits=kv_bits, scale=scale)


def decode_attention(q, k_codes, v_codes, lengths, es, *, kv_bits,
                     scale=None, impl="auto", interpret=None, block_s=512,
                     rolling=False):
    """Dispatch one decode-attention step; see module docstring for impls.

    The ``obs.trace.named_scope`` tag makes every decode-attention dispatch
    show up under one name in ``jax.profiler`` device traces, lined up with
    the engine's host-side request spans (DESIGN.md §12); an active
    ``obs.prof`` profiler additionally receives one cost record per dispatch
    (analytic bytes over the allocated S — DESIGN.md §16).
    """
    from repro.obs import prof
    from repro.obs.trace import named_scope

    if rolling:
        # circular window buffer: every slot written so far is valid
        lengths = jnp.minimum(jnp.asarray(lengths, jnp.int32),
                              k_codes.shape[2])
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "tiled"

    def _run():
        with named_scope(f"repro.decode_attention.{impl}"):
            if impl == "pallas":
                interp = interpret if interpret is not None else not _on_tpu()
                return posit_decode_attention(
                    q, k_codes, v_codes, lengths, es, kv_bits=kv_bits,
                    scale=scale, block_s=block_s, interpret=interp)
            if impl == "tiled":
                return posit_decode_attention_tiled(
                    q, k_codes, v_codes, lengths, es, kv_bits=kv_bits,
                    scale=scale, block_s=min(block_s, 256))
            return posit_decode_attention_ref(
                q, k_codes, v_codes, lengths, es, kv_bits=kv_bits, scale=scale)

    if not prof.is_active():
        return _run()
    return prof.dispatch(
        "attention", impl, prof.attention_cost(q, k_codes, kv_bits=kv_bits),
        _run, primary=q)
