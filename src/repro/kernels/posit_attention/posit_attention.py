"""Decode-step attention over a posit-compressed KV cache (Pallas, flash-style).

The paper's memory-savings result (Table IV: P8 fits a 20x20 GEMM where FP32
fits 12x12) applied to the dominant inference bottleneck: the KV cache lives in
HBM as p8/p16 codes (2–4x fewer bytes than bf16/f32), and each K/V tile is
decoded *in VMEM* right before use — decode-step attention is purely
HBM-bandwidth-bound, so cutting payload bytes cuts step latency ~linearly.

One query token per (batch, head): online-softmax accumulation over S tiles.

  grid = (B * Hq, S // bs)            k innermost (arbitrary)
  q:    (B*Hq, d)        float        block (1, d)
  kv:   (B*Hkv, S, d)    posit codes  block (1, bs, d), GQA-mapped index
  out:  (B*Hq, d)        float        block (1, d)
  scratch: m, l (SMEM scalars), acc (VMEM (1, d) f32)

Scalar prefetch: es (1,) int32 + lengths (B,) int32 (valid cache length per
batch row; masked with -inf before the running max).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

from repro.core.codec import posit_decode

_NEG_INF = -1e30


def _attn_kernel(
    es_ref, len_ref,            # scalar prefetch
    q_ref, k_ref, v_ref, o_ref, # blocks
    m_ref, l_ref, acc_ref,      # scratch
    *, kv_bits: int, heads_per_kv: int, hq: int, block_s: int, n_s: int,
    scale: float,
):
    s_idx = pl.program_id(1)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[0, 0] = _NEG_INF
        l_ref[0, 0] = 0.0
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bh = pl.program_id(0)
    b = bh // hq
    length = len_ref[b]

    q = q_ref[...].astype(jnp.float32)                      # (1, d)
    if kv_bits:
        k = posit_decode(k_ref[0], kv_bits, es_ref[0]).astype(jnp.float32)
        v = posit_decode(v_ref[0], kv_bits, es_ref[0]).astype(jnp.float32)
    else:  # kv_bits=0: float KV cache — no codec, tile-wise astype only
        k = k_ref[0].astype(jnp.float32)                    # (bs, d)
        v = v_ref[0].astype(jnp.float32)

    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (1, bs)
    pos = s_idx * block_s + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
    scores = jnp.where(pos < length, scores, _NEG_INF)

    m_prev = m_ref[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(scores))
    alpha = jnp.exp(m_prev - m_new)
    # explicit zero for masked slots: a fully-masked row keeps m at _NEG_INF,
    # where exp(scores - m) == 1 would leak a uniform average of stale V
    p = jnp.where(pos < length, jnp.exp(scores - m_new), 0.0)   # (1, bs)
    l_ref[0, 0] = l_ref[0, 0] * alpha + jnp.sum(p)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[0, 0] = m_new

    @pl.when(s_idx == n_s - 1)
    def _emit():
        l = l_ref[0, 0]
        # length-0 rows (free engine slots) emit exact zeros, not 0/0
        o_ref[...] = (acc_ref[...] / jnp.where(l == 0, 1.0, l)) \
            .astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("kv_bits", "block_s", "interpret", "scale"),
)
def posit_decode_attention(
    q: jax.Array,          # (B, Hq, d) float
    k_codes: jax.Array,    # (B, Hkv, S, d) uint8/uint16 posit codes
    v_codes: jax.Array,    # (B, Hkv, S, d)  (float arrays when kv_bits=0)
    lengths: jax.Array,    # (B,) int32 — valid KV length per batch row
    es,                    # int32 scalar — pcsr pes for the KV cache
    *,
    kv_bits: int,          # 8 | 16 posit codes; 0 = float KV (codec bypassed)
    scale: float | None = None,
    block_s: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, d = q.shape
    Bk, Hkv, S, dk = k_codes.shape
    assert (B, d) == (Bk, dk) and Hq % Hkv == 0, (q.shape, k_codes.shape)
    heads_per_kv = Hq // Hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)

    bs = min(block_s, S)
    S_p = -(-S // bs) * bs
    if S_p != S:  # pad; padded rows are masked off via `lengths`
        pad = [(0, 0), (0, 0), (0, S_p - S), (0, 0)]
        k_codes = jnp.pad(k_codes, pad)
        v_codes = jnp.pad(v_codes, pad)
    n_s = S_p // bs

    q2 = q.reshape(B * Hq, d)
    k2 = k_codes.reshape(B * Hkv, S_p, d)
    v2 = v_codes.reshape(B * Hkv, S_p, d)

    def kv_index(bh, s, *_scalars):
        b = bh // Hq
        h = bh % Hq
        return (b * Hkv + h // heads_per_kv, s, 0)

    kernel = functools.partial(
        _attn_kernel,
        kv_bits=kv_bits, heads_per_kv=heads_per_kv, hq=Hq,
        block_s=bs, n_s=n_s, scale=scale,
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B * Hq, n_s),
            in_specs=[
                pl.BlockSpec((1, d), lambda bh, s, *_: (bh, 0)),
                pl.BlockSpec((1, bs, d), kv_index),
                pl.BlockSpec((1, bs, d), kv_index),
            ],
            out_specs=pl.BlockSpec((1, d), lambda bh, s, *_: (bh, 0)),
            scratch_shapes=[
                pltpu.SMEM((1, 1), jnp.float32),
                pltpu.SMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B * Hq, d), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(jnp.asarray([es], jnp.int32), jnp.asarray(lengths, jnp.int32), q2, k2, v2)
    return out.reshape(B, Hq, d)
