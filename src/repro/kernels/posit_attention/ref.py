"""Pure-jnp oracle for posit-KV decode attention (no tiling, full softmax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.codec import posit_decode


def posit_decode_attention_ref(
    q: jax.Array, k_codes: jax.Array, v_codes: jax.Array,
    lengths: jax.Array, es, *, kv_bits: int, scale: float | None = None,
) -> jax.Array:
    B, Hq, d = q.shape
    _, Hkv, S, _ = k_codes.shape
    g = Hq // Hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    k = posit_decode(k_codes, kv_bits, es).astype(jnp.float32)
    v = posit_decode(v_codes, kv_bits, es).astype(jnp.float32)
    k = jnp.repeat(k, g, axis=1)  # (B, Hq, S, d)
    v = jnp.repeat(v, g, axis=1)
    scores = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32), k) * scale
    pos = jnp.arange(S)[None, None, :]
    scores = jnp.where(pos < lengths[:, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhs,bhsd->bhd", p, v)
    return out.astype(q.dtype)
