"""Pure-jnp oracle for posit-KV decode attention (no tiling, full softmax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.codec import posit_decode


def posit_decode_attention_ref(
    q: jax.Array, k_codes: jax.Array, v_codes: jax.Array,
    lengths: jax.Array, es, *, kv_bits: int, scale: float | None = None,
) -> jax.Array:
    """kv_bits: 8/16 posit codes, or 0 = float KV cache (codec bypassed)."""
    B, Hq, d = q.shape
    _, Hkv, S, _ = k_codes.shape
    g = Hq // Hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    if kv_bits:
        k = posit_decode(k_codes, kv_bits, es).astype(jnp.float32)
        v = posit_decode(v_codes, kv_bits, es).astype(jnp.float32)
    else:
        k = k_codes.astype(jnp.float32)
        v = v_codes.astype(jnp.float32)
    k = jnp.repeat(k, g, axis=1)  # (B, Hq, S, d)
    v = jnp.repeat(v, g, axis=1)
    scores = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32), k) * scale
    valid = (jnp.arange(S)[None, None, :] < lengths[:, None, None])
    scores = jnp.where(valid, scores, -1e30)
    # explicit masked-softmax so a length-0 row returns exact zeros (same
    # contract as the kernel/tiled paths); for live rows the masked slots
    # underflow to 0 in a plain softmax too, so numerics are unchanged
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.where(valid, jnp.exp(scores - m), 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.where(denom == 0, 1.0, denom)
    out = jnp.einsum("bhs,bhsd->bhd", p, v)
    return out.astype(q.dtype)
