"""Front door for the streaming codec: pallas on TPU, plain XLA elsewhere."""
from __future__ import annotations

import jax

from repro.kernels.posit_codec import ref
from repro.kernels.posit_codec.posit_codec import decode_kernel, encode_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def decode(codes, es, *, nbits: int, out_dtype_name="float32", impl="auto",
           interpret=None):
    from repro.obs import prof

    if impl == "auto":
        impl = "pallas" if _on_tpu() else "xla"

    def _run():
        if impl == "pallas":
            interp = interpret if interpret is not None else not _on_tpu()
            return decode_kernel(codes, es, nbits=nbits,
                                 out_dtype_name=out_dtype_name,
                                 interpret=interp)
        return ref.decode_ref(codes, es, nbits=nbits,
                              out_dtype_name=out_dtype_name)

    if not prof.is_active():
        return _run()
    vb = 2.0 if out_dtype_name == "bfloat16" else 4.0
    return prof.dispatch(
        "codec", f"decode/{impl}",
        prof.codec_cost(codes, nbits=nbits, value_bytes=vb), _run,
        primary=codes)


def encode(x, es, *, nbits: int, impl="auto", interpret=None):
    from repro.obs import prof

    if impl == "auto":
        impl = "pallas" if _on_tpu() else "xla"

    def _run():
        if impl == "pallas":
            interp = interpret if interpret is not None else not _on_tpu()
            return encode_kernel(x, es, nbits=nbits, interpret=interp)
        return ref.encode_ref(x, es, nbits=nbits)

    if not prof.is_active():
        return _run()
    return prof.dispatch(
        "codec", f"encode/{impl}",
        prof.codec_cost(x, nbits=nbits, value_bytes=float(x.dtype.itemsize)),
        _run, primary=x)
