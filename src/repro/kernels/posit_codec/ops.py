"""Front door for the streaming codec: pallas on TPU, plain XLA elsewhere."""
from __future__ import annotations

import jax

from repro.kernels.posit_codec import ref
from repro.kernels.posit_codec.posit_codec import decode_kernel, encode_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def decode(codes, es, *, nbits: int, out_dtype_name="float32", impl="auto",
           interpret=None):
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "xla"
    if impl == "pallas":
        if interpret is None:
            interpret = not _on_tpu()
        return decode_kernel(codes, es, nbits=nbits, out_dtype_name=out_dtype_name,
                             interpret=interpret)
    return ref.decode_ref(codes, es, nbits=nbits, out_dtype_name=out_dtype_name)


def encode(x, es, *, nbits: int, impl="auto", interpret=None):
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "xla"
    if impl == "pallas":
        if interpret is None:
            interpret = not _on_tpu()
        return encode_kernel(x, es, nbits=nbits, interpret=interpret)
    return ref.encode_ref(x, es, nbits=nbits)
