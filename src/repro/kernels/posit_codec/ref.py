"""Pure-jnp oracle for the streaming codec kernels."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.codec import posit_decode, posit_encode


def decode_ref(codes, es, *, nbits: int, out_dtype_name: str = "float32"):
    return posit_decode(codes, nbits, es).astype(jnp.dtype(out_dtype_name))


def encode_ref(x, es, *, nbits: int):
    return posit_encode(x.astype(jnp.float32), nbits, es)
