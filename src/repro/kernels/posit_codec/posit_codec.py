"""Elementwise posit decode/encode Pallas kernels (VMEM-tiled streaming codec).

These are the standalone conversion "instructions" (paper Table I) at tensor
granularity: used for checkpoint encode/decode, collective payload
(de)compression, and anywhere a fused consumer kernel is not available.

Layout: ops flatten to (rows, 128) lanes — the VPU-native tile — and stream
row-blocks HBM->VMEM->HBM. The codec math itself is the shared
``repro.core.codec`` source (Mosaic-safe: no clz, only shifts/bitcasts).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

from repro.core.codec import posit_decode, posit_encode

_LANES = 128


def _decode_kernel(es_ref, c_ref, o_ref, *, nbits: int):
    o_ref[...] = posit_decode(c_ref[...], nbits, es_ref[0]).astype(o_ref.dtype)


def _encode_kernel(es_ref, x_ref, o_ref, *, nbits: int):
    o_ref[...] = posit_encode(x_ref[...].astype(jnp.float32), nbits, es_ref[0])


def _tile(x: jax.Array, block_rows: int):
    """Flatten to (rows, 128), padded; returns (tiled, orig_size, rows)."""
    size = x.size
    rows = -(-size // _LANES)
    rows_p = -(-rows // block_rows) * block_rows
    flat = jnp.pad(x.reshape(-1), (0, rows_p * _LANES - size))
    return flat.reshape(rows_p, _LANES), size, rows_p


@functools.partial(
    jax.jit, static_argnames=("nbits", "out_dtype_name", "block_rows", "interpret")
)
def decode_kernel(
    codes: jax.Array, es, *, nbits: int, out_dtype_name: str = "float32",
    block_rows: int = 512, interpret: bool = False,
) -> jax.Array:
    """posit codes (any shape) -> float array of the same shape."""
    shape = codes.shape
    tiled, size, rows_p = _tile(codes, block_rows)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, nbits=nbits),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(rows_p // block_rows,),
            in_specs=[pl.BlockSpec((block_rows, _LANES), lambda i, s: (i, 0))],
            out_specs=pl.BlockSpec((block_rows, _LANES), lambda i, s: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((rows_p, _LANES), jnp.dtype(out_dtype_name)),
        compiler_params=tpu_compiler_params(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(jnp.asarray([es], jnp.int32).reshape(1), tiled)
    return out.reshape(-1)[:size].reshape(shape)


@functools.partial(jax.jit, static_argnames=("nbits", "block_rows", "interpret"))
def encode_kernel(
    x: jax.Array, es, *, nbits: int, block_rows: int = 512, interpret: bool = False,
) -> jax.Array:
    """float array (any shape) -> posit codes of the same shape."""
    shape = x.shape
    tiled, size, rows_p = _tile(x, block_rows)
    out_dtype = jnp.uint8 if nbits == 8 else jnp.uint16
    out = pl.pallas_call(
        functools.partial(_encode_kernel, nbits=nbits),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(rows_p // block_rows,),
            in_specs=[pl.BlockSpec((block_rows, _LANES), lambda i, s: (i, 0))],
            out_specs=pl.BlockSpec((block_rows, _LANES), lambda i, s: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((rows_p, _LANES), out_dtype),
        compiler_params=tpu_compiler_params(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(jnp.asarray([es], jnp.int32).reshape(1), tiled)
    return out.reshape(-1)[:size].reshape(shape)
