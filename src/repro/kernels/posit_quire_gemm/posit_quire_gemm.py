"""Exact-accumulation posit GEMM Pallas kernel — the quire dataflow, tiled.

Dataflow per (i, j, k) grid step (PERCIVAL's quire brought to the TPU memory
hierarchy):

    HBM --BlockSpec--> VMEM:  A tile (bm x bk)  posit codes
                              B tile (bk x bn)  posit codes
    VMEM:   [field decoder]   posit -> (sign, scale, significand) int fields
    VPU:    per-k outer product -> signed radix-2^16 digits, lazily
            accumulated into the QUIRE SCRATCH (bm x bn x L+1 int32) which
            persists in VMEM across the whole k-grid (revisited-output pattern)
    VMEM:   [quire readout]   single RNE rounding -> posit codes   (last k)
    VMEM --BlockSpec--> HBM:  O tile (bm x bn)

Unlike the fused codec GEMM this path never touches the MXU: exactness is the
product, not FLOPs — every a[i,k]*b[k,j] lands in the output element's quire
with no intermediate rounding, matching a Fraction-arithmetic oracle
bit-for-bit. Carries are propagated once per k tile, well inside the
``MAX_DEFERRED`` lazy-carry budget (requires block_k <= MAX_DEFERRED).

``es`` for (rs1, rs2, rd) arrives as a scalar-prefetch vector: the quire's
binary-point anchor is es-independent (DESIGN.md §7), so one compiled kernel
serves every es — and even mixed-es operand pairs.

Note on layout: the quire scratch keeps limbs on the *trailing* axis so the
kernel shares digit/readout code with ``repro.core.quire`` verbatim. A
TPU-lane-optimal variant would transpose limbs to the leading axis; interpret
mode and correctness (the contract this kernel is tested against) are
layout-independent.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

from repro.core.codec import _decode_fields, _es_u32
from repro.core.quire import (
    MAX_DEFERRED, QuireFmt, _product_parts, _scatter, quire_normalize,
    quire_read,
)
from repro.core.types import PositFmt


def _quire_gemm_kernel(
    es_ref,  # scalar prefetch: (3,) int32 = es for rs1, rs2, rd
    a_ref, b_ref, o_ref, q_ref,
    *, a_fmt: PositFmt, b_fmt: PositFmt, out_fmt: PositFmt,
    qfmt: QuireFmt, n_k: int, block_k: int,
):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        q_ref[...] = jnp.zeros_like(q_ref)

    ea, eb = _es_u32(es_ref[0]), _es_u32(es_ref[1])
    na, sa, ga, za, ra = _decode_fields(a_ref[...], a_fmt.nbits, ea)
    nb, sb, gb, zb, rb = _decode_fields(b_ref[...], b_fmt.nbits, eb)

    def step(kk, q):
        col = lambda x: lax.dynamic_slice_in_dim(x, kk, 1, axis=1)  # (bm, 1)
        row = lambda x: lax.dynamic_slice_in_dim(x, kk, 1, axis=0)  # (1, bn)
        parts = _product_parts(
            (col(na), col(sa), col(ga), col(za), col(ra)),
            (row(nb), row(sb), row(gb), row(zb), row(rb)),
            a_fmt.nbits, b_fmt.nbits, qfmt.bias, False)
        return _scatter(q, parts, qfmt.n_limbs)

    q = lax.fori_loop(0, block_k, step, q_ref[...])
    q_ref[...] = quire_normalize(q, qfmt)  # carry budget: one tile of products

    @pl.when(pl.program_id(2) == n_k - 1)
    def _emit():
        o_ref[...] = quire_read(q_ref[...], qfmt,
                                out_nbits=out_fmt.nbits, es_out=es_ref[2])


def _pad_to(x: jax.Array, mults: tuple) -> jax.Array:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        x = jnp.pad(x, pads)  # 0-codes contribute nothing to a quire
    return x


@functools.partial(
    jax.jit,
    static_argnames=(
        "a_fmt", "b_fmt", "out_fmt", "block_m", "block_n", "block_k",
        "interpret",
    ),
)
def posit_quire_gemm(
    a: jax.Array,
    b: jax.Array,
    es: jax.Array,  # (3,) int32: es for a, b, out
    *,
    a_fmt: PositFmt,
    b_fmt: PositFmt,
    out_fmt: PositFmt,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """O = round_once(sum_k decode(A)[i,k] * decode(B)[k,j]), all-posit slots.

    A: (M, K), B: (K, N) posit codes -> (M, N) posit codes in ``out_fmt``.
    The (bm, bn) quire limbs live in VMEM scratch across the k grid.
    """
    for f in (a_fmt, b_fmt, out_fmt):
        if not isinstance(f, PositFmt):
            raise ValueError(f"quire GEMM requires posit slots, got {f}")
    if block_k > MAX_DEFERRED:
        raise ValueError(f"block_k {block_k} exceeds lazy-carry budget "
                         f"{MAX_DEFERRED}")
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    qfmt = QuireFmt(max(a_fmt.nbits, b_fmt.nbits))

    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    a_p = _pad_to(a, (bm, bk))
    b_p = _pad_to(b, (bk, bn))
    Mp, Kp = a_p.shape
    _, Np = b_p.shape
    grid = (Mp // bm, Np // bn, Kp // bk)

    out_dtype = jnp.uint8 if out_fmt.nbits == 8 else jnp.uint16
    kernel = functools.partial(
        _quire_gemm_kernel,
        a_fmt=a_fmt, b_fmt=b_fmt, out_fmt=out_fmt,
        qfmt=qfmt, n_k=grid[2], block_k=bk,
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, k, s: (i, k)),
                pl.BlockSpec((bk, bn), lambda i, j, k, s: (k, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, s: (i, j)),
            scratch_shapes=[pltpu.VMEM((bm, bn, qfmt.limbs_axis), jnp.int32)],
        ),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(jnp.asarray(es, jnp.int32), a_p, b_p)
    return out[:M, :N]
