"""Exact-accumulation posit GEMM Pallas kernel — the quire dataflow, tiled.

Dataflow per (i, j, k) grid step (PERCIVAL's quire brought to the TPU memory
hierarchy):

    HBM --BlockSpec--> VMEM:  A tile (bm x bk)  posit codes
                              B tile (bk x bn)  posit codes
    VMEM:   [field decoder]   posit -> (sign, scale, significand) int fields
    VPU:    per-k outer product -> signed radix-2^16 digits, lazily
            accumulated into the QUIRE SCRATCH (bm x bn x L+1 int32) which
            persists in VMEM across the whole k-grid (revisited-output pattern)
    VMEM:   [quire readout]   single RNE rounding -> posit codes   (last k)
    VMEM --BlockSpec--> HBM:  O tile (bm x bn)

Unlike the fused codec GEMM this path never touches the MXU: exactness is the
product, not FLOPs — every a[i,k]*b[k,j] lands in the output element's quire
with no intermediate rounding, matching a Fraction-arithmetic oracle
bit-for-bit. Carries are propagated once per k tile, well inside the
``MAX_DEFERRED`` lazy-carry budget (requires block_k <= MAX_DEFERRED).

``es`` for (rs1, rs2, rd) arrives as a scalar-prefetch vector: the quire's
binary-point anchor is es-independent (DESIGN.md §7), so one compiled kernel
serves every es — and even mixed-es operand pairs.

Note on layout: the quire scratch keeps limbs on the *trailing* axis so the
kernel shares digit/readout code with ``repro.core.quire`` verbatim. A
TPU-lane-optimal variant would transpose limbs to the leading axis; interpret
mode and correctness (the contract this kernel is tested against) are
layout-independent.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import LANE, pad_to, round_block, sublane, tpu_compiler_params

from repro.core.codec import _decode_fields, _es_u32, posit_encode
from repro.core.dot import ACTIVATIONS, _apply_activation
from repro.core.quire import (
    MAX_DEFERRED, QuireFmt, _product_parts, _scatter, quire_normalize,
    quire_read, quire_read_f32,
)
from repro.core.types import PositFmt


def _quire_gemm_kernel(
    es_ref,  # scalar prefetch: (3,) int32 = es for rs1, rs2, rd
    *refs,
    a_fmt: PositFmt, b_fmt: PositFmt, out_fmt: PositFmt,
    qfmt: QuireFmt, n_k: int, block_k: int,
    activation: str, has_bias: bool, has_residual: bool,
):
    it = iter(refs)
    a_ref, b_ref = next(it), next(it)
    bias_ref = next(it) if has_bias else None
    res_ref = next(it) if has_residual else None
    o_ref, q_ref = next(it), next(it)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        q_ref[...] = jnp.zeros_like(q_ref)

    ea, eb = _es_u32(es_ref[0]), _es_u32(es_ref[1])
    na, sa, ga, za, ra = _decode_fields(a_ref[...], a_fmt.nbits, ea)
    nb, sb, gb, zb, rb = _decode_fields(b_ref[...], b_fmt.nbits, eb)

    def step(kk, q):
        def col(x):
            return lax.dynamic_slice_in_dim(x, kk, 1, axis=1)  # (bm, 1)

        def row(x):
            return lax.dynamic_slice_in_dim(x, kk, 1, axis=0)  # (1, bn)
        parts = _product_parts(
            (col(na), col(sa), col(ga), col(za), col(ra)),
            (row(nb), row(sb), row(gb), row(zb), row(rb)),
            a_fmt.nbits, b_fmt.nbits, qfmt.bias, False)
        return _scatter(q, parts, qfmt.n_limbs)

    q = lax.fori_loop(0, block_k, step, q_ref[...])
    q_ref[...] = quire_normalize(q, qfmt)  # carry budget: one tile of products

    @pl.when(pl.program_id(2) == n_k - 1)
    def _emit():
        if not (has_bias or has_residual or activation != "none"):
            # no epilogue: exact single rounding straight into the posit rd
            o_ref[...] = quire_read(q_ref[...], qfmt,
                                    out_nbits=out_fmt.nbits, es_out=es_ref[2])
            return
        # fused epilogue readout: one exact rounding into f32 (the FPU
        # domain the epilogue computes in), then the output encode —
        # still one launch and one HBM write (DESIGN.md §8)
        r = quire_read_f32(q_ref[...], qfmt)
        if has_bias:
            r = r + bias_ref[...].astype(jnp.float32)
        r = _apply_activation(r, activation)
        if has_residual:
            r = r + res_ref[...].astype(jnp.float32)
        o_ref[...] = posit_encode(r, out_fmt.nbits, es_ref[2])


@functools.partial(
    jax.jit,
    static_argnames=(
        "a_fmt", "b_fmt", "out_fmt", "block_m", "block_n", "block_k",
        "activation", "interpret",
    ),
)
def posit_quire_gemm(
    a: jax.Array,
    b: jax.Array,
    es: jax.Array,  # (3,) int32: es for a, b, out
    *,
    a_fmt: PositFmt,
    b_fmt: PositFmt,
    out_fmt: PositFmt,
    bias: jax.Array = None,      # (N,) f32
    residual: jax.Array = None,  # (M, N) float
    activation: str = "none",
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """O = round_once(sum_k decode(A)[i,k] * decode(B)[k,j]), all-posit slots.

    A: (M, K), B: (K, N) posit codes -> (M, N) posit codes in ``out_fmt``.
    The (bm, bn) quire limbs live in VMEM scratch across the k grid.  With an
    epilogue (bias/activation/residual) the readout is one exact RNE into
    f32, the epilogue applies in-register, and the encode emits — still a
    single launch and HBM write.
    """
    for f in (a_fmt, b_fmt, out_fmt):
        if not isinstance(f, PositFmt):
            raise ValueError(f"quire GEMM requires posit slots, got {f}")
    if activation not in ACTIVATIONS:
        raise ValueError(f"activation must be one of {ACTIVATIONS}, got {activation!r}")
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    qfmt = QuireFmt(max(a_fmt.nbits, b_fmt.nbits))

    out_dtype = jnp.uint8 if out_fmt.nbits == 8 else jnp.uint16
    # lane/sublane-friendly blocks (see posit_gemm): round up + pad, never
    # ragged-shrink; bm must satisfy every array blocked on it (A codes,
    # f32 residual, int32 quire scratch, and the — possibly narrower —
    # output codes).  bk stays within the lazy-carry budget.
    bm = round_block(M, block_m, max(sublane(a.dtype), sublane(out_dtype), 8))
    bn = round_block(N, block_n, LANE)
    bk = round_block(K, block_k, max(LANE, sublane(b.dtype)))
    if bk > MAX_DEFERRED:
        raise ValueError(f"block_k {bk} exceeds lazy-carry budget "
                         f"{MAX_DEFERRED}")
    a_p = pad_to(a, (bm, bk))
    b_p = pad_to(b, (bk, bn))
    Mp, Kp = a_p.shape
    _, Np = b_p.shape
    grid = (Mp // bm, Np // bn, Kp // bk)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k, s: (i, k)),
        pl.BlockSpec((bk, bn), lambda i, j, k, s: (k, j)),
    ]
    inputs = [a_p, b_p]
    if bias is not None:
        assert bias.shape == (N,), (bias.shape, N)
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k, s: (0, j)))
        inputs.append(pad_to(bias.astype(jnp.float32)[None, :], (1, bn)))
    if residual is not None:
        assert residual.shape == (M, N), (residual.shape, (M, N))
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, k, s: (i, j)))
        inputs.append(pad_to(residual.astype(jnp.float32), (bm, bn)))

    kernel = functools.partial(
        _quire_gemm_kernel,
        a_fmt=a_fmt, b_fmt=b_fmt, out_fmt=out_fmt,
        qfmt=qfmt, n_k=grid[2], block_k=bk,
        activation=activation, has_bias=bias is not None,
        has_residual=residual is not None,
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, s: (i, j)),
            scratch_shapes=[pltpu.VMEM((bm, bn, qfmt.limbs_axis), jnp.int32)],
        ),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(jnp.asarray(es, jnp.int32), *inputs)
    return out[:M, :N]
