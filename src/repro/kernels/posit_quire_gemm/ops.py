"""Jitted front door for the exact-accumulation (quire) posit GEMM.

``impl``:
  "pallas"     — the TPU kernel (interpret=True on CPU: same semantics)
  "xla"        — scan-based path (repro.core.quire.quire_matmul); numerically
                 identical contract (both are bit-exact vs the Fraction oracle)
  "auto"       — pallas on TPU, xla elsewhere
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.pcsr import OperandSlots
from repro.core.types import PositFmt
from repro.kernels.posit_quire_gemm.posit_quire_gemm import posit_quire_gemm
from repro.kernels.posit_quire_gemm.ref import posit_quire_gemm_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def quire_gemm(
    a: jax.Array,
    b: jax.Array,
    slots: OperandSlots,
    *,
    es_a=None, es_b=None, es_out=None,
    bias=None, activation: str = "none", residual=None,
    impl: str = "auto",
    interpret: bool | None = None,
    **block_kw,
) -> jax.Array:
    """O = round_once(sum decode(A)*decode(B)) per the pcsr operand slots.

    With an epilogue (bias/activation/residual) the exact sum is rounded
    once into f32, the epilogue applies, and the result encodes — fused
    into the kernel's readout step (DESIGN.md §8)."""
    for name, f in (("rs1", slots.rs1), ("rs2", slots.rs2), ("rd", slots.rd)):
        if not isinstance(f, PositFmt):
            raise ValueError(
                f"quire dataflow requires posit {name}, got {f}: the quire "
                "accumulates posit products exactly; float slots have no "
                "quire representation")
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "xla"

    def _es(x, fmt):
        return fmt.es if x is None else x

    es = jnp.asarray(
        [_es(es_a, slots.rs1), _es(es_b, slots.rs2), _es(es_out, slots.rd)],
        dtype=jnp.int32,
    )

    def _run():
        if impl == "pallas":
            interp = interpret if interpret is not None else not _on_tpu()
            return posit_quire_gemm(
                a, b, es,
                a_fmt=slots.rs1, b_fmt=slots.rs2, out_fmt=slots.rd,
                bias=bias, activation=activation, residual=residual,
                interpret=interp, **block_kw,
            )
        if impl == "xla":
            return posit_quire_gemm_ref(
                a, b, es, a_fmt=slots.rs1, b_fmt=slots.rs2, out_fmt=slots.rd,
                bias=bias, activation=activation, residual=residual)
        raise ValueError(f"unknown impl {impl!r}")

    from repro.obs import prof

    if not prof.is_active():
        return _run()
    # same (M,K)x(K,N) byte/FLOP contract as the rounding GEMM: the quire
    # changes the accumulator, not the mandatory operand traffic
    return prof.dispatch(
        "quire_gemm", impl, prof.gemm_cost(a, b, slots, bias=bias,
                                           residual=residual),
        _run, primary=a)
