"""Pure-jnp oracle for the quire GEMM kernel (untiled scan, same exact math).

Both this and the kernel reduce to ``repro.core.quire`` digit arithmetic, so
they must agree bit-for-bit regardless of tiling — and both are validated
against the Fraction-arithmetic exact-sum oracle in tests/test_quire.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quire import quire_matmul
from repro.core.types import PositFmt


def posit_quire_gemm_ref(
    a: jax.Array, b: jax.Array, es,  # (3,) int32
    *, a_fmt: PositFmt, b_fmt: PositFmt, out_fmt: PositFmt,
) -> jax.Array:
    es = jnp.asarray(es, jnp.int32)
    wide = a_fmt if a_fmt.nbits >= b_fmt.nbits else b_fmt
    return quire_matmul(a, b, wide, es_a=es[0], es_b=es[1],
                        nbits_a=a_fmt.nbits, nbits_b=b_fmt.nbits,
                        out_nbits=out_fmt.nbits, es_out=es[2])
