"""Pure-jnp oracle for the quire GEMM kernel (untiled scan, same exact math).

Both this and the kernel reduce to ``repro.core.quire`` digit arithmetic, so
they must agree bit-for-bit regardless of tiling — and both are validated
against the Fraction-arithmetic exact-sum oracle in tests/test_quire.py.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.codec import posit_encode
from repro.core.dot import apply_epilogue
from repro.core.quire import quire_matmul
from repro.core.types import PositFmt


def posit_quire_gemm_ref(
    a: jax.Array, b: jax.Array, es,  # (3,) int32
    *, a_fmt: PositFmt, b_fmt: PositFmt, out_fmt: PositFmt,
    bias: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
    activation: str = "none",
) -> jax.Array:
    es = jnp.asarray(es, jnp.int32)
    wide = a_fmt if a_fmt.nbits >= b_fmt.nbits else b_fmt
    kw = dict(es_a=es[0], es_b=es[1],
              nbits_a=a_fmt.nbits, nbits_b=b_fmt.nbits)
    if bias is None and activation == "none" and residual is None:
        return quire_matmul(a, b, wide, out_nbits=out_fmt.nbits,
                            es_out=es[2], **kw)
    y = quire_matmul(a, b, wide, as_float=True, **kw)
    y = apply_epilogue(y, bias, activation, residual)
    return posit_encode(y, out_fmt.nbits, es[2])
