"""Pure-jnp oracle for the posit softmax kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.codec import posit_decode, posit_encode


def posit_softmax_ref(codes, es, *, nbits: int):
    x = posit_decode(codes, nbits, es)
    y = jax.nn.softmax(x, axis=-1)
    return posit_encode(y, nbits, es)
