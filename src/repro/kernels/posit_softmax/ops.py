"""Front door for the posit softmax kernel."""
from __future__ import annotations

import jax

from repro.kernels.posit_softmax.posit_softmax import posit_softmax_kernel
from repro.kernels.posit_softmax.ref import posit_softmax_ref


def softmax(codes, es, *, nbits, impl="auto", interpret=None):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "pallas":
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return posit_softmax_kernel(codes, es, nbits=nbits, interpret=interpret)
    return posit_softmax_ref(codes, es, nbits=nbits)
