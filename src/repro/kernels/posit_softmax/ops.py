"""Front door for the posit softmax kernel."""
from __future__ import annotations

import jax

from repro.kernels.posit_softmax.posit_softmax import posit_softmax_kernel
from repro.kernels.posit_softmax.ref import posit_softmax_ref


def softmax(codes, es, *, nbits, impl="auto", interpret=None):
    from repro.obs import prof

    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"

    def _run():
        if impl == "pallas":
            interp = (interpret if interpret is not None
                      else jax.default_backend() != "tpu")
            return posit_softmax_kernel(codes, es, nbits=nbits,
                                        interpret=interp)
        return posit_softmax_ref(codes, es, nbits=nbits)

    if not prof.is_active():
        return _run()
    return prof.dispatch(
        "softmax", impl, prof.softmax_cost(codes, nbits=nbits), _run,
        primary=codes)
