"""Posit softmax Pallas kernel (paper §IV-C benchmark kernel).

Rows of posit-coded logits stream HBM->VMEM, decode, stable-softmax in f32 on
the VPU, re-encode to posit on the way out. Whole class dim per block (the
paper benchmarks softmax-8..128; serving logits fit VMEM comfortably).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

from repro.core.codec import posit_decode, posit_encode


def _softmax_kernel(es_ref, c_ref, o_ref, *, nbits: int, valid_c: int):
    x = posit_decode(c_ref[...], nbits, es_ref[0])
    col = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    x = jnp.where(col < valid_c, x, -jnp.inf)
    m = jnp.max(x, axis=-1, keepdims=True)
    p = jnp.exp(x - m)
    y = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = posit_encode(y, nbits, es_ref[0])


@functools.partial(jax.jit, static_argnames=("nbits", "block_rows", "interpret"))
def posit_softmax_kernel(
    codes: jax.Array, es, *, nbits: int, block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    R, C = codes.shape
    br = min(block_rows, R)
    Rp = -(-R // br) * br
    Cp = -(-C // 128) * 128
    padded = jnp.pad(codes, ((0, Rp - R), (0, Cp - C)))
    out = pl.pallas_call(
        functools.partial(_softmax_kernel, nbits=nbits, valid_c=C),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(Rp // br,),
            in_specs=[pl.BlockSpec((br, Cp), lambda i, s: (i, 0))],
            out_specs=pl.BlockSpec((br, Cp), lambda i, s: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((Rp, Cp), codes.dtype),
        compiler_params=tpu_compiler_params(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(jnp.asarray([es], jnp.int32), padded)
    return out[:R, :C]
