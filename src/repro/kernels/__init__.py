# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Shared Pallas-TPU compatibility shims for the kernel packages."""
from jax.experimental.pallas import tpu as _pltpu


def tpu_compiler_params(**kw):
    """pltpu.CompilerParams across jax versions (renamed from
    TPUCompilerParams in newer releases)."""
    cls = getattr(_pltpu, "CompilerParams", None) or _pltpu.TPUCompilerParams
    return cls(**kw)
