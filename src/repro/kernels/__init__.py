# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Shared Pallas-TPU compatibility shims + tiling helpers for the kernel
packages."""
import jax.numpy as _jnp
from jax.experimental.pallas import tpu as _pltpu

LANE = 128


def tpu_compiler_params(**kw):
    """pltpu.CompilerParams across jax versions (renamed from
    TPUCompilerParams in newer releases)."""
    cls = getattr(_pltpu, "CompilerParams", None) or _pltpu.TPUCompilerParams
    return cls(**kw)


def sublane(dtype) -> int:
    """Minimum second-minor tile multiple for a dtype: 8 for 4-byte types,
    16 for 2-byte, 32 for 1-byte (the 32-bytes-per-sublane TPU packing rule)."""
    return {4: 8, 2: 16, 1: 32}[_jnp.dtype(dtype).itemsize]


def round_block(dim: int, block: int, mult: int) -> int:
    """Hardware-friendly block size: cap at the dim, then round the block
    *up* to ``mult`` — small dims get one padded tile, never a ragged one.
    ``mult`` must be the max sublane/lane requirement over every array that
    shares the blocked axis (inputs, residual, output)."""
    eff = min(block, dim)
    return -(-eff // mult) * mult


def pad_to(x, mults: tuple):
    """Zero-pad trailing-partial dims up to multiples (0-codes decode to 0.0
    and contribute nothing to an accumulator or a quire)."""
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        x = _jnp.pad(x, pads)
    return x
