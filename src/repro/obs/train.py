"""Training-plane telemetry: gradient/activation numerics + step health.

DESIGN.md §16.  The serving plane watches a frozen model's activations drift
away from calibration (§12); during training *everything* moves — the
activations because the data does, the gradients because the loss landscape
does, the optimizer state because both do.  :class:`TrainingTelemetry`
composes the existing observability substrate into the training loop's
probed-twin pattern:

* the driver compiles its train step twice — once plain, once traced under
  ``telemetry.observing()`` with ``make_train_step(..., telemetry=True)`` —
  and routes every ``every``-th step through the probed twin.  The twin's
  executable carries the §11 ``Observer`` callbacks for *both* channels:
  activation histograms at every linear site, plus gradient histograms from
  the ``grad_tap`` cotangent hooks (``calib.observe``), and the extra
  params-sized step metrics (update/param ratio, nonfinite counts).  The
  plain step stays byte-identical to an unobserved build — the same
  trace-time gating §12 relies on, now audited for training executables by
  JP005.
* drift is scored by the same G-test machinery (``obs.numerics``) against
  the calibration artifact's per-site histograms when one is given, or
  against the run's own first probed window (``self_baseline``) when not;
  one drifted site latches ``recalibrate`` — the signal the ROADMAP's
  calibration-in-the-loop item consumes.
* per-step records (loss, grad-norm, update ratio, nonfinite counts) buffer
  as *device* scalars on the step path and are converted + written to a
  bounded JSONL log only at probe boundaries — the host sync and file I/O
  happen off the step path, which is what keeps
  ``benchmarks/bench_train_obs_overhead.py`` under its 5% gate.
* everything surfaces through the ``obs.metrics`` registry: Prometheus
  exposition + the JSON snapshot ``launch/train.py --metrics-out`` writes.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

import numpy as np

from repro.obs.metrics import MetricsRegistry
from repro.obs.numerics import NumericsWatcher, load_baselines

__all__ = ["TrainingTelemetry", "JsonlStepLog"]


class JsonlStepLog:
    """Bounded, buffered JSONL sink for per-step records.

    ``append`` only queues (no I/O); ``flush`` serializes and writes.  After
    ``max_records`` written records the log stops growing and counts drops
    instead — a runaway training job must not fill the disk with telemetry.
    """

    def __init__(self, path: str, *, max_records: int = 65536):
        self.path = path
        self.max_records = max_records
        self.written = 0
        self.dropped = 0
        self._buf: list = []
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "w")

    def append(self, rec: dict) -> None:
        if self.written + len(self._buf) >= self.max_records:
            self.dropped += 1
            return
        self._buf.append(rec)

    def flush(self) -> None:
        if self._buf:
            self._f.write("".join(json.dumps(r) + "\n" for r in self._buf))
            self._f.flush()
            self.written += len(self._buf)
            self._buf = []

    def close(self) -> None:
        self.flush()
        self._f.close()

    def stats(self) -> dict:
        return {"path": self.path, "records": self.written,
                "dropped": self.dropped, "max_records": self.max_records}


def _scalar(v):
    """Device scalar -> python float (deferred host sync happens here)."""
    try:
        return float(np.asarray(v))
    except (TypeError, ValueError):
        return None


class TrainingTelemetry:
    """Probed-twin training telemetry: numerics, step health, drift latch.

    Parameters mirror :class:`~repro.obs.numerics.NumericsWatcher` where they
    overlap.  ``baselines`` may be a path to a ``@cal.json`` calibration
    artifact, a parsed dict of per-site ``TensorStats``, or ``None`` —
    without an artifact every site self-baselines on its first probed window
    (after :meth:`rebase`, so warmup/compile traffic is excluded).
    """

    def __init__(self, policy=None, *, baselines=None, every: int = 64,
                 check_every: int = 4, metrics: Optional[MetricsRegistry]
                 = None, log_path: Optional[str] = None,
                 max_log_records: int = 65536, confidence: float = 0.999,
                 min_score: float = 0.1):
        if isinstance(baselines, str):
            baselines = load_baselines(baselines)
        self.watcher = NumericsWatcher(
            policy, baselines, every=every, confidence=confidence,
            min_score=min_score, kinds=("act", "grad"), self_baseline=True)
        self.policy = policy
        self.every = every
        self.check_every = max(int(check_every), 1)
        self.steps = 0
        self.log = (JsonlStepLog(log_path, max_records=max_log_records)
                    if log_path else None)
        self._pending: list = []       # device-scalar records awaiting drain
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        m = self.metrics
        self._m_steps = m.counter("train_steps_total", "train steps executed")
        self._m_probes = m.counter("train_probes_total",
                                   "steps routed through the probed twin")
        self._m_checks = m.counter("train_drift_checks_total",
                                   "drift checks scored")
        self._m_nonfinite = m.counter(
            "train_nonfinite_total",
            "nonfinite elements seen (labels: grad, opt)")
        self._m_loss = m.gauge("train_loss", "last drained training loss")
        self._m_gnorm = m.gauge("train_grad_norm", "last global grad norm")
        self._m_ratio = m.gauge("train_update_ratio",
                                "last ||delta p|| / ||p||")
        self._m_recal = m.gauge("train_recalibrate",
                                "1 once any site drifts (latched)")
        self._m_drift = m.gauge("train_max_drift_score",
                                "max per-site KL vs baseline")
        self._m_quire_sat = m.gauge(
            "train_quire_saturation",
            "max saturation rate across quire-dataflow sites")
        self._m_step_s = m.histogram("train_step_seconds",
                                     "wall time per train step")

    # -- driver hooks (mirror the engine's NumericsWatcher surface) -----------
    def should_probe(self, step: int) -> bool:
        return self.watcher.should_probe(step)

    def observing(self):
        """Trace the probed-twin executable under this context."""
        return self.watcher.observing()

    def rebase(self) -> None:
        self.watcher.rebase()

    # -- per-step path ---------------------------------------------------------
    def on_step(self, step: int, metrics: dict, *,
                step_s: Optional[float] = None,
                probed: bool = False) -> Optional[dict]:
        """Record one executed step; returns a drift event dict when this
        step's check latched new drift (the driver emits ``train/drift``).

        ``metrics`` is the step function's output dict — device scalars are
        kept un-synced until the next probe-boundary drain.
        """
        self.steps += 1
        self._m_steps.inc()
        if step_s is not None:
            self._m_step_s.observe(step_s)
        rec = {"step": int(step), "probed": bool(probed)}
        if step_s is not None:
            rec["step_s"] = round(step_s, 6)
        rec.update(metrics)
        self._pending.append(rec)
        if not probed:
            return None
        self.watcher.note_probe()
        self._m_probes.inc()
        event = None
        if self.watcher.probes % self.check_every == 0:
            event = self._check()
        self._drain()
        return event

    def _check(self) -> Optional[dict]:
        already = {p for p, h in self.watcher.health.items() if h.drifted}
        health = self.watcher.check()
        self._m_checks.inc()
        self._update_gauges()
        fresh = sorted(p for p, h in health.items()
                       if h.drifted and p not in already)
        if not fresh:
            return None
        return {
            "drifted": fresh,
            "recalibrate": True,
            "check": self.watcher.checks,
            "scores": {p: {"score": health[p].drift_score,
                           "threshold": health[p].drift_threshold}
                       for p in fresh},
        }

    def _update_gauges(self) -> None:
        w = self.watcher
        self._m_recal.set(1.0 if w.recalibrate else 0.0)
        scores = [h.drift_score for h in w.health.values()
                  if h.drift_score is not None]
        if scores:
            self._m_drift.set(max(scores))
        sat = self.quire_saturation()
        if sat is not None:
            self._m_quire_sat.set(sat)

    def _drain(self) -> None:
        """Convert pending device scalars and ship them (off the step path:
        called at probe boundaries and from report/close)."""
        for rec in self._pending:
            out = {}
            for k, v in rec.items():
                out[k] = v if isinstance(v, (int, bool, str)) else _scalar(v)
            if self.log is not None:
                self.log.append(out)
            if out.get("loss") is not None:
                self._m_loss.set(out["loss"])
            if out.get("gnorm") is not None:
                self._m_gnorm.set(out["gnorm"])
            if out.get("update_ratio") is not None:
                self._m_ratio.set(out["update_ratio"])
            for key, label in (("grad_nonfinite", "grad"),
                               ("opt_nonfinite", "opt")):
                if out.get(key):
                    self._m_nonfinite.inc(out[key], label=label)
        self._pending = []
        if self.log is not None:
            self.log.flush()

    # -- readout ---------------------------------------------------------------
    def quire_saturation(self) -> Optional[float]:
        """Max activation saturation rate across quire-dataflow sites (the
        values that clamp to maxpos *before* entering the exact accumulator
        — the quire cannot recover what the encode already lost)."""
        pol = self.policy
        if pol is None:
            return None
        resolve = getattr(pol, "policy_for", None)
        rates = []
        for path, h in self.watcher.health.items():
            site_pol = resolve(path) if resolve is not None else pol
            if getattr(site_pol, "dataflow", None) == "quire" \
                    and h.saturation_rate is not None:
                rates.append(h.saturation_rate)
        return max(rates) if rates else None

    @property
    def recalibrate(self) -> bool:
        return self.watcher.recalibrate

    def report(self) -> dict:
        """JSON block merged into the metrics snapshot (drains first so the
        report covers every executed step)."""
        self._drain()
        numerics = self.watcher.report()
        self._update_gauges()
        return {
            "steps": self.steps,
            "telemetry_every": self.every,
            "check_every_probes": self.check_every,
            "quire_saturation": self.quire_saturation(),
            "numerics": numerics,
            "log": self.log.stats() if self.log is not None else None,
        }

    def close(self) -> None:
        self._drain()
        if self.log is not None:
            self.log.close()
