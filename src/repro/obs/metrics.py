"""Zero-dependency metrics registry for the serving plane (DESIGN.md §12).

Three instrument kinds, all plain Python + numpy (nothing here touches jax —
the registry must be callable from the engine's host loop without adding a
device sync):

* ``Counter``   — monotone event count, optionally labeled (eviction reasons).
* ``Gauge``     — last-written value (slot occupancy, KV-byte utilization).
* ``Histogram`` — latency/duration distribution.  Samples are retained
  exactly up to ``max_samples`` (percentile readout is then *bit-identical*
  to ``numpy.percentile`` — asserted in tests/test_obs.py); past the cap the
  raw buffer is dropped and readout falls back to interpolation over the
  log-spaced bucket counts, which are always maintained and are what the
  Prometheus exposition exports (cumulative ``le`` buckets).

``RollingRate`` is the tokens/s window: ``add(t, n)`` events, ``rate(now)``
over the trailing ``window_s`` seconds.

Export: :meth:`MetricsRegistry.snapshot` (JSON-ready dict, written by
``serve.py --metrics-out``) and :meth:`MetricsRegistry.prometheus`
(text exposition format, version 0.0.4 — the ``# TYPE`` / ``# HELP`` lines
Prometheus' scraper parses).

The percentile helpers at the bottom are the one shared implementation the
repo uses for latency readout (``serve.py`` and ``benchmarks/bench_serving``
both previously hand-rolled ``np.percentile`` wrappers).
"""
from __future__ import annotations

import bisect
import collections
import contextlib
import json
import math
import re
from time import perf_counter as _perf_counter
from typing import Dict, Iterable, Optional, Sequence

import numpy as np

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "RollingRate",
    "percentile", "percentile_ms",
]


# ------------------------------------------------------------- percentiles ----

def percentile(xs: Sequence[float], q: float) -> float:
    """``numpy.percentile`` with an empty-input guard (returns 0.0).

    The single percentile definition every latency report in the repo uses
    (linear interpolation between order statistics — numpy's default).
    """
    xs = np.asarray(xs, np.float64)
    if xs.size == 0:
        return 0.0
    return float(np.percentile(xs, q))


def percentile_ms(xs: Sequence[float], q: float, ndigits: int = 2) -> float:
    """Percentile of second-valued samples, reported in rounded ms."""
    return round(percentile(xs, q) * 1e3, ndigits)


# -------------------------------------------------------------- instruments ----

class Counter:
    """Monotone counter with optional label values (e.g. eviction reason)."""

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self._by_label: Dict[str, float] = collections.defaultdict(float)

    def inc(self, n: float = 1.0, label: str = "") -> None:
        self._by_label[label] += n

    def value(self, label: str = "") -> float:
        return self._by_label.get(label, 0.0)

    @property
    def total(self) -> float:
        return sum(self._by_label.values())

    def to_dict(self) -> dict:
        if set(self._by_label) <= {""}:
            return {"total": self.value()}
        return {"total": self.total, "by_label": dict(self._by_label)}


class Gauge:
    """Last-written value."""

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.val: float = 0.0

    def set(self, v: float) -> None:
        self.val = float(v)

    def to_dict(self) -> dict:
        return {"value": self.val}


#: Default log-spaced bucket boundaries: 1us .. ~100s in quarter-decades —
#: wide enough for queue waits and tight enough that a bucket-interpolated
#: p99 lands within ~1.8x (the quarter-decade ratio) of truth.
_DEFAULT_BUCKETS = tuple(10.0 ** (e / 4.0) for e in range(-24, 9))


class Histogram:
    """Log-bucketed histogram with exact percentiles up to ``max_samples``.

    ``observe(x)`` is O(log buckets).  ``percentiles()`` reads from the raw
    sample buffer while it is still retained (exact — the registry's p50/p95/
    p99 agree with numpy to the bit), else interpolates within the matching
    log bucket (error bounded by the bucket ratio).
    """

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = _DEFAULT_BUCKETS,
                 max_samples: int = 65536):
        self.name, self.help = name, help
        self.buckets = tuple(buckets)
        # bucket counts live in a plain list: observe() is on the engine's
        # per-decode-step hot path, and list indexing + bisect (both C) keep
        # it ~1us where an ndarray searchsorted costs several
        self.counts = [0] * (len(self.buckets) + 1)
        self.n = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.max_samples = max_samples
        self._samples: Optional[list] = []

    def observe(self, x: float) -> None:
        x = float(x)
        self.n += 1
        self.sum += x
        if x < self.min:
            self.min = x
        if x > self.max:
            self.max = x
        # index of the first bucket boundary >= x (its "le" bucket)
        self.counts[bisect.bisect_left(self.buckets, x)] += 1
        if self._samples is not None:
            self._samples.append(x)
            if len(self._samples) > self.max_samples:
                self._samples = None    # cap hit: bucket readout from now on

    @property
    def exact(self) -> bool:
        return self._samples is not None

    def percentiles(self, qs: Iterable[float] = (50, 95, 99)) -> Dict[str, float]:
        if self.n == 0:
            return {f"p{_plabel(q)}": 0.0 for q in qs}
        if self._samples is not None:
            return {f"p{_plabel(q)}": percentile(self._samples, q) for q in qs}
        return {f"p{_plabel(q)}": self._bucket_percentile(q) for q in qs}

    def _bucket_percentile(self, q: float) -> float:
        """Linear interpolation inside the log bucket holding rank q."""
        rank = q / 100.0 * (self.n - 1)
        cum = np.cumsum(self.counts)
        b = int(np.searchsorted(cum, rank + 1, side="left"))
        lo = self.buckets[b - 1] if b > 0 else min(self.min, self.buckets[0])
        hi = self.buckets[b] if b < len(self.buckets) else self.max
        lo = max(lo, self.min)
        hi = min(hi, self.max)
        if hi <= lo:
            return lo
        prev = float(cum[b - 1]) if b > 0 else 0.0
        frac = (rank + 1 - prev) / max(float(self.counts[b]), 1.0)
        return lo + (hi - lo) * min(max(frac, 0.0), 1.0)

    @property
    def mean(self) -> float:
        return self.sum / self.n if self.n else 0.0

    @contextlib.contextmanager
    def time(self):
        """Observe the wall time of a ``with`` block, in seconds (the ft
        serving plane times snapshot save/restore through this)."""
        t0 = _perf_counter()
        try:
            yield self
        finally:
            self.observe(_perf_counter() - t0)

    def to_dict(self) -> dict:
        d = {"count": self.n, "sum": self.sum, "mean": self.mean,
             "min": self.min if self.n else 0.0,
             "max": self.max if self.n else 0.0,
             "exact": self.exact}
        d.update(self.percentiles())
        return d


class RollingRate:
    """Events-per-second over a trailing window (decode tokens/s).

    ``add(t, n)`` appends an event of weight ``n`` at time ``t`` (seconds,
    monotonic clock); ``rate(now)`` sums weights inside ``[now - window_s,
    now]`` and divides by the window.  Old events are dropped as the window
    slides, so memory is bounded by the event rate, not run length.
    """

    def __init__(self, window_s: float = 10.0):
        self.window_s = float(window_s)
        self._events: collections.deque = collections.deque()
        self._in_window = 0.0

    def add(self, t: float, n: float = 1.0) -> None:
        self._events.append((float(t), float(n)))
        self._in_window += n

    def rate(self, now: float) -> float:
        cutoff = now - self.window_s
        ev = self._events
        while ev and ev[0][0] < cutoff:
            self._in_window -= ev.popleft()[1]
        if not ev:
            return 0.0
        # use the genuinely covered span when the run is shorter than the
        # window (a 2s run must not report rate diluted over 10s)
        span = min(self.window_s, max(now - ev[0][0], 1e-9))
        return self._in_window / span


def _plabel(q: float) -> str:
    """p-label formatting: 50 -> '50', 99.9 -> '99_9' (Prometheus-safe)."""
    s = f"{q:g}"
    return s.replace(".", "_")


# ---------------------------------------------------------------- registry ----

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    return _NAME_RE.sub("_", name)


class MetricsRegistry:
    """Create-on-first-use instrument registry.

    One registry per engine/run; ``snapshot()`` is the JSON artifact
    ``serve.py --metrics-out`` writes, ``prometheus()`` the text exposition
    a scrape endpoint would serve.  Extra run-level context (arch, policy,
    numerics snapshot) merges in via ``set_context``.
    """

    def __init__(self):
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}
        self.context: dict = {}

    def counter(self, name: str, help: str = "") -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter(name, help)
        return c

    def gauge(self, name: str, help: str = "") -> Gauge:
        gg = self.gauges.get(name)
        if gg is None:
            gg = self.gauges[name] = Gauge(name, help)
        return gg

    def histogram(self, name: str, help: str = "", **kw) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(name, help, **kw)
        return h

    def set_context(self, **kv) -> None:
        self.context.update(kv)

    # -- export ---------------------------------------------------------------
    def snapshot(self) -> dict:
        return {
            "kind": "repro/metrics-snapshot",
            "version": 1,
            **self.context,
            "counters": {n: c.to_dict() for n, c in sorted(self.counters.items())},
            "gauges": {n: x.to_dict() for n, x in sorted(self.gauges.items())},
            "histograms": {n: h.to_dict()
                           for n, h in sorted(self.histograms.items())},
        }

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1)

    def prometheus(self) -> str:
        """Text exposition format 0.0.4 (the format scrapers parse)."""
        lines = []
        for name, c in sorted(self.counters.items()):
            pn = _prom_name(name) + "_total"
            if c.help:
                lines.append(f"# HELP {pn} {c.help}")
            lines.append(f"# TYPE {pn} counter")
            labels = c._by_label or {"": 0.0}
            for label, v in sorted(labels.items()):
                sel = f'{{reason="{label}"}}' if label else ""
                lines.append(f"{pn}{sel} {v:g}")
        for name, x in sorted(self.gauges.items()):
            pn = _prom_name(name)
            if x.help:
                lines.append(f"# HELP {pn} {x.help}")
            lines.append(f"# TYPE {pn} gauge")
            lines.append(f"{pn} {x.val:g}")
        for name, h in sorted(self.histograms.items()):
            pn = _prom_name(name)
            if h.help:
                lines.append(f"# HELP {pn} {h.help}")
            lines.append(f"# TYPE {pn} histogram")
            cum = 0
            for le, cnt in zip(h.buckets, h.counts):
                cum += int(cnt)
                lines.append(f'{pn}_bucket{{le="{le:g}"}} {cum}')
            lines.append(f'{pn}_bucket{{le="+Inf"}} {h.n}')
            lines.append(f"{pn}_sum {h.sum:g}")
            lines.append(f"{pn}_count {h.n}")
        return "\n".join(lines) + "\n"
