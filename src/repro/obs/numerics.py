"""Posit numerical-health telemetry: serving-time probes + drift detection.

The transprecision premise (per-layer dynamic es matched to the data
distribution, DESIGN.md §11) is only safe while the serving data still looks
like the calibration data.  This module closes that loop:

* **Probes** — the engine periodically routes a decode step through a
  *probed* executable traced under ``calib.observe.observing`` (the same
  debug-callback reduction core calibration uses — nothing is duplicated,
  the probe IS an observer).  Every linear call site then streams its
  activation binade histogram + nonfinite count; cadence (``every`` decode
  steps) bounds the overhead.
* **Health readout** — per site: saturation rate (mass at/above the resolved
  format's ``max_scale`` — values that clamp to maxpos), underflow rate
  (mass below ``-max_scale`` — values that round up to minpos), and the
  NaR/nonfinite count (what posit encodes as NaR).  These are exactly the
  tapered-accuracy failure modes PERCIVAL's quire and the PVU bound in
  hardware; here they become gauges.
* **Drift detection** — the live activation histogram is compared against
  the histogram stored in the calibration artifact (``meta.sites[].act_hist``
  — written by ``calib.search.save_artifact``) via smoothed KL divergence.
  Under the no-drift null, ``2 * N_eff * KL`` is asymptotically
  chi-square(k-1) (the standard G-test statistic), so the threshold is the
  chi-square quantile at ``confidence`` scaled by the effective sample count
  — *calibrated*, not a magic constant — with an absolute floor
  (``min_score``) absorbing the non-iid-ness of real activations (elements
  of one tensor are correlated, so multinomial noise understates variance).
  Any site over threshold raises the ``recalibrate`` flag surfaced in the
  metrics snapshot.

Everything on the host side is numpy on tiny (NBINS,) vectors.  The real
cost is the probed step itself: each observed site ships one
``jax.debug.callback``, and callback dispatch (FFI + GIL, serialized inside
``lax.scan`` layer stacks) runs ~0.3-0.5 ms *per site* on CPU — a probed
step on a reduced test model costs ~10x a plain one.  That cost is a fixed
tax per probe, so the amortized overhead is ``probe_cost / (every *
step_cost)``: the default cadence (``every=1024``) holds a worst-case tiny
model (~1 ms steps) under a few percent, and on production-size models
(10-100x slower steps, same per-site callback tax) the same cadence is
deep in the noise.  ``benchmarks/bench_obs_overhead.py`` measures the full
stack over exact cadence cycles and CI-gates it at <= 5%.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, Optional, Tuple

import numpy as np

from repro.calib.observe import BIN_LO, NBINS, Observer, TensorStats, observing
from repro.core.types import PositFmt

__all__ = [
    "NumericsWatcher", "SiteHealth", "drift_score", "drift_threshold",
    "load_baselines", "chi2_quantile", "normal_quantile",
]


# ----------------------------------------------------- statistics utilities ----

def normal_quantile(p: float) -> float:
    """Inverse standard-normal CDF (Acklam's rational approximation,
    |error| < 1.15e-9 — far below anything a drift threshold can feel)."""
    if not 0.0 < p < 1.0:
        raise ValueError(f"quantile probability must be in (0, 1), got {p}")
    a = (-3.969683028665376e+01, 2.209460984245205e+02,
         -2.759285104469687e+02, 1.383577518672690e+02,
         -3.066479806614716e+01, 2.506628277459239e+00)
    b = (-5.447609879822406e+01, 1.615858368580409e+02,
         -1.556989798598866e+02, 6.680131188771972e+01,
         -1.328068155288572e+01)
    c = (-7.784894002430293e-03, -3.223964580411365e-01,
         -2.400758277161838e+00, -2.549732539343734e+00,
         4.374664141464968e+00, 2.938163982698783e+00)
    d = (7.784695709041462e-03, 3.224671290700398e-01,
         2.445134137142996e+00, 3.754408661907416e+00)
    plow, phigh = 0.02425, 1 - 0.02425
    if p < plow:
        q = math.sqrt(-2 * math.log(p))
        return (((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q
                + c[5]) / ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1)
    if p > phigh:
        return -normal_quantile(1 - p)
    q = p - 0.5
    r = q * q
    return (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r
            + a[5]) * q / (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r
                            + b[4]) * r + 1)


def chi2_quantile(k: int, p: float = 0.999) -> float:
    """Chi-square quantile via the Wilson–Hilferty cube approximation —
    accurate to a few percent for k >= 2, which is all a threshold needs."""
    k = max(int(k), 1)
    z = normal_quantile(p)
    h = 2.0 / (9.0 * k)
    return k * (1.0 - h + z * math.sqrt(h)) ** 3


def drift_score(live: TensorStats, base: TensorStats) -> Tuple[float, int]:
    """Smoothed KL(live || base) over binade distributions, in nats.

    Add-half (Jeffreys) smoothing on both histograms over the union support;
    returns ``(kl_nats, k)`` with ``k`` the union-support bin count (the
    chi-square degrees of freedom + 1).  Zero mass on either side -> (0, 0).
    """
    lh, bh = np.asarray(live.hist, np.float64), np.asarray(base.hist, np.float64)
    n_live, n_base = lh.sum(), bh.sum()
    if n_live <= 0 or n_base <= 0:
        return 0.0, 0
    support = (lh > 0) | (bh > 0)
    k = int(support.sum())
    lp = (lh[support] + 0.5) / (n_live + 0.5 * k)
    bp = (bh[support] + 0.5) / (n_base + 0.5 * k)
    return float(np.sum(lp * np.log(lp / bp))), k


def drift_threshold(n_live: float, n_base: float, k: int, *,
                    confidence: float = 0.999,
                    min_score: float = 0.1) -> float:
    """KL threshold above which drift is declared.

    G-test calibration: under H0, ``2 * N_eff * KL ~ chi2(k - 1)`` with
    ``N_eff = 1 / (1/n_live + 1/n_base)`` (both histograms are empirical, so
    both contribute sampling noise).  ``min_score`` floors the threshold:
    activations are not iid draws, so pure multinomial noise understates the
    benign wobble — the floor is what keeps in-distribution traffic quiet
    (tests/test_obs.py pins both directions).
    """
    if k < 2 or n_live <= 0 or n_base <= 0:
        return math.inf
    n_eff = 1.0 / (1.0 / n_live + 1.0 / n_base)
    return max(chi2_quantile(k - 1, confidence) / (2.0 * n_eff), min_score)


# ----------------------------------------------------------------- baselines ----

def load_baselines(artifact) -> Dict[str, TensorStats]:
    """Per-site calibration activation histograms from an artifact.

    ``artifact`` is a path to the ``@cal.json`` file or its parsed dict.
    Sites saved before histograms existed in the schema are skipped (drift
    is then unavailable for them; rates still report).
    """
    if isinstance(artifact, str):
        with open(artifact) as f:
            artifact = json.load(f)
    out: Dict[str, TensorStats] = {}
    for site in artifact.get("meta", {}).get("sites", ()):
        h = site.get("act_hist")
        if h and h.get("counts"):
            out[site["path"]] = TensorStats.hist_from_json(h)
    return out


# ------------------------------------------------------------------- watcher ----

@dataclasses.dataclass
class SiteHealth:
    """One site's health readout at a drift check."""

    path: str
    n: float                       # elements probed in the window
    saturation_rate: Optional[float]   # mass >= fmt.max_scale (None: no fmt)
    underflow_rate: Optional[float]    # mass < -fmt.max_scale
    nonfinite: float
    drift_score: Optional[float]   # None: no baseline for this site
    drift_threshold: Optional[float]
    drifted: bool
    check_id: int = 0              # watcher.checks when this row was scored
    # training-plane fields (populated when the watcher observes "grad";
    # None on act-only serving probes — to_dict keeps them out of snapshots)
    grad_rms: Optional[float] = None       # RMS cotangent magnitude at site
    grad_nonfinite: Optional[float] = None  # nonfinite cotangent elements

    @property
    def nar_rate(self) -> float:
        """Nonfinite (posit NaR) fraction of the window's elements — the
        per-site breach signal the degradation ladder steps on."""
        return self.nonfinite / self.n if self.n > 0 else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.pop("path")
        d["nar_rate"] = self.nar_rate
        if self.grad_rms is None and self.grad_nonfinite is None:
            d.pop("grad_rms")
            d.pop("grad_nonfinite")
        return d


class NumericsWatcher:
    """Streams per-site numerical health from cadenced probed decode steps.

    The watcher owns an ``Observer``; the engine traces its *probed* decode
    executable under ``watcher.observing()`` so the debug callbacks bake into
    exactly one of its two executables, then routes every ``every``-th step
    through it (DESIGN.md §12 — trace-time activation is what makes the
    unprobed step free).  ``check()`` turns the histograms accumulated since
    the previous check into :class:`SiteHealth` rows and updates the
    ``recalibrate`` flag; ``report()`` is the JSON block merged into the
    metrics snapshot.
    """

    def __init__(self, policy=None, baselines: Optional[Dict[str, TensorStats]]
                 = None, *, every: int = 1024, confidence: float = 0.999,
                 min_score: float = 0.1, window: bool = True,
                 kinds: Tuple[str, ...] = ("act",),
                 self_baseline: bool = False):
        if every < 1:
            raise ValueError(f"probe cadence must be >= 1, got {every}")
        # serving default is act only: weights are static during serving, and
        # filtering at trace time keeps their reductions+callbacks out of the
        # probed executable.  The training telemetry passes ("act", "grad") —
        # grad windows feed the grad_rms/grad_nonfinite health fields.
        self.observer = Observer(kinds=kinds)
        self.policy = policy
        self.baselines = dict(baselines or {})
        self.every = every
        self.confidence = confidence
        self.min_score = min_score
        self.window = window       # False: every check scores the full run
        # self_baseline: a site with no artifact baseline adopts its first
        # scored window as the baseline (training runs without a calibration
        # artifact still get drift detection against their own warm start)
        self.self_baseline = self_baseline
        self.probes = 0            # probed steps executed
        self.checks = 0
        self.recalibrate = False
        self.health: Dict[str, SiteHealth] = {}
        self._mark: Dict[Tuple[str, str], Tuple[float, np.ndarray, float]] = {}

    # -- engine hooks ---------------------------------------------------------
    def should_probe(self, step_index: int) -> bool:
        """Probe on every ``every``-th decode step (step 0 included, so the
        probed executable compiles during warmup, not mid-serve)."""
        return step_index % self.every == 0

    def observing(self):
        """Context manager installing this watcher's observer (trace-time)."""
        return observing(self.observer)

    def note_probe(self) -> None:
        self.probes += 1

    def rebase(self) -> None:
        """Advance the window marks past everything observed so far without
        scoring it — drivers call this after engine warmup so compile-time
        probe traffic (dummy prompts/batches) doesn't pollute the first real
        window."""
        for key, st in self.observer.stats.items():
            self._mark[key] = (st.n, st.hist.copy(), st.nonfinite, st.sum_sq)

    # -- readout --------------------------------------------------------------
    def _site_fmt(self, path: str):
        pol = self.policy
        if pol is None:
            return None
        resolve = getattr(pol, "policy_for", None)
        pol = resolve(path) if resolve is not None else pol
        return pol.weights

    def _window_stats(self, path: str, kind: str = "act") -> TensorStats:
        """Stats accumulated since the previous check (or run start)."""
        st = self.observer.get(path, kind)
        cur = TensorStats()
        if st is None:
            return cur
        prev = self._mark.get((path, kind)) if self.window else None
        cur.n = st.n - (prev[0] if prev else 0.0)
        cur.hist = st.hist - (prev[1] if prev else 0.0)
        cur.nonfinite = st.nonfinite - (prev[2] if prev else 0.0)
        cur.sum_sq = st.sum_sq - (prev[3] if prev else 0.0)
        cur.zeros = cur.n - float(cur.hist.sum()) - cur.nonfinite
        return cur

    def _advance_mark(self, path: str) -> None:
        for kind in self.observer.kinds:
            st = self.observer.get(path, kind)
            if st is not None:
                self._mark[(path, kind)] = (st.n, st.hist.copy(),
                                            st.nonfinite, st.sum_sq)

    def check(self) -> Dict[str, SiteHealth]:
        """Score the window since the last check; advances the window mark.

        Health rows merge into the running view (a site with no traffic this
        window keeps its last readout) and ``recalibrate`` latches: once a
        site drifts, the flag stays raised until the operator recalibrates —
        a later in-distribution window must not silently clear it.
        """
        self.checks += 1
        health: Dict[str, SiteHealth] = {}
        for path in self.observer.paths():
            cur = self._window_stats(path)
            if cur.n <= 0:
                continue
            fmt = self._site_fmt(path)
            nz = float(cur.hist.sum())
            sat = uf = None
            if isinstance(fmt, PositFmt) and nz > 0:
                scales = np.arange(BIN_LO, BIN_LO + NBINS)
                sat = float(cur.hist[scales >= fmt.max_scale].sum() / nz)
                uf = float(cur.hist[scales < -fmt.max_scale].sum() / nz)
            score = thresh = None
            drifted = False
            base = self.baselines.get(path)
            if base is None and self.self_baseline and nz > 0:
                # first scored window becomes this site's baseline: training
                # runs without a calibration artifact still get drift
                # detection anchored at their own warm start (the driver
                # rebase()s past compile/warmup traffic first)
                self.baselines[path] = cur
            elif base is not None:
                score, k = drift_score(cur, base)
                thresh = drift_threshold(
                    nz, float(base.hist.sum()), k,
                    confidence=self.confidence, min_score=self.min_score)
                drifted = bool(score > thresh)
            self.recalibrate |= drifted
            g_rms = g_nf = None
            if "grad" in self.observer.kinds:
                g = self._window_stats(path, "grad")
                if g.n > 0:
                    g_rms = float(np.sqrt(max(g.sum_sq, 0.0) / g.n))
                    g_nf = g.nonfinite
            health[path] = SiteHealth(
                path=path, n=cur.n, saturation_rate=sat, underflow_rate=uf,
                nonfinite=cur.nonfinite, drift_score=score,
                drift_threshold=thresh, drifted=drifted,
                check_id=self.checks, grad_rms=g_rms, grad_nonfinite=g_nf)
            self._advance_mark(path)
        self.health.update(health)
        return health

    def report(self) -> dict:
        """JSON block for the metrics snapshot (runs a final check so a
        report is never stale w.r.t. the last probed steps)."""
        self.check()
        scores = [h.drift_score for h in self.health.values()
                  if h.drift_score is not None]
        return {
            "probes": self.probes,
            "probe_every": self.every,
            "checks": self.checks,
            "recalibrate": self.recalibrate,
            "max_drift_score": max(scores) if scores else None,
            "sites": {p: h.to_dict() for p, h in sorted(self.health.items())},
        }
