"""Request tracing: Chrome-trace timelines + jax profiler annotations.

Two aligned views of the same serving run (DESIGN.md §12):

* **Host spans** — :class:`TraceRecorder` collects per-request lifecycle
  spans (``queued -> admitted -> prefill -> decode -> finished/evicted``)
  and per-step engine spans, serialized as Chrome trace-event JSON
  (``serve.py --trace-out``); open the file in ``chrome://tracing`` or
  Perfetto.  Rows (tids): tid 0 is the engine's decode-step track, tid
  ``slot+1`` is that slot's request timeline — a request's whole life
  (queue wait, prefill, decode) renders as contiguous spans on the slot row
  it was admitted to, so slot churn / occupancy gaps are visible at a
  glance.
* **Device scopes** — :func:`annotate` wraps host-side dispatches in
  ``jax.profiler.TraceAnnotation`` (and :func:`named_scope` tags traced
  computations via ``jax.named_scope``), so a ``jax.profiler`` device trace
  captured alongside carries the same span names and lines up with the
  request timeline.  Both degrade to no-ops when the profiler API is
  missing (old jax) — tracing must never be the thing that breaks serving.

All timestamps are seconds on the caller's monotonic clock
(``time.perf_counter`` epoch); Chrome trace wants integer microseconds, the
conversion happens at serialization.
"""
from __future__ import annotations

import contextlib
import json
from typing import Optional

import jax

__all__ = ["TraceRecorder", "annotate", "named_scope"]


def annotate(name: str):
    """Host-side profiler annotation around a dispatch (no-op without
    jax.profiler support)."""
    ta = getattr(getattr(jax, "profiler", None), "TraceAnnotation", None)
    return ta(name) if ta is not None else contextlib.nullcontext()


def named_scope(name: str):
    """Trace-time scope: tags the ops a traced function emits so device
    profiles show ``name`` (no-op on jax versions without named_scope)."""
    ns = getattr(jax, "named_scope", None)
    return ns(name) if ns is not None else contextlib.nullcontext()


class TraceRecorder:
    """Buffers Chrome trace events; ``save`` writes the JSON object format.

    ``span`` records a complete ("ph": "X") event, ``instant`` a point mark
    ("ph": "i") — both O(1) dict appends on the host, no jax involvement.
    ``max_events`` bounds memory on long runs (drops further events, counts
    the drops — a truncated trace is still valid JSON).
    """

    def __init__(self, max_events: int = 200_000):
        self.events: list = []
        self.max_events = max_events
        self.dropped = 0

    def _push(self, ev: dict) -> None:
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        self.events.append(ev)

    def span(self, name: str, t0: float, t1: float, *, tid: int = 0,
             args: Optional[dict] = None) -> None:
        self._push({
            "name": name, "ph": "X", "pid": 0, "tid": tid,
            "ts": round(t0 * 1e6, 3), "dur": round(max(t1 - t0, 0.0) * 1e6, 3),
            **({"args": args} if args else {}),
        })

    def instant(self, name: str, t: float, *, tid: int = 0,
                args: Optional[dict] = None) -> None:
        self._push({
            "name": name, "ph": "i", "s": "t", "pid": 0, "tid": tid,
            "ts": round(t * 1e6, 3),
            **({"args": args} if args else {}),
        })

    def label_track(self, tid: int, label: str) -> None:
        """Name a tid row (Chrome's thread_name metadata event)."""
        self._push({"name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
                    "args": {"name": label}})

    def to_json(self) -> dict:
        return {
            "traceEvents": list(self.events),
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped},
        }

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)
