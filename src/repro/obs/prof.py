"""Per-kernel cost profiler: call counts, analytic bytes/FLOPs, wall time.

The paper's premise is that posit kernels pay for themselves in moved bytes;
this module measures whether a given run actually *hits* those kernels and
what each dispatch should have cost.  A :class:`KernelProfiler` installed via
``profiling(...)`` receives one record per execution of a
``kernels/posit_{gemm,quire_gemm,attention,codec,softmax}`` entry point (and
of the XLA-fused linear path in ``models.layers`` — the same GEMM contract,
just not hand-lowered), carrying:

* **analytic cost** — FLOPs and mandatory HBM bytes from
  ``launch/roofline.py``'s per-kernel cost model (one formula shared with the
  whole-step roofline analysis, so the two can never disagree);
* **attribution** — the layer path from the innermost :func:`site` context
  (linear sites pass their path directly; ``models.attention`` wraps its
  kernel calls), falling back to family-level aggregation;
* **wall time** — measured with ``block_until_ready`` when the dispatch is
  *eager* (concrete arrays).  Executions under a ``jit`` trace are counted as
  ``traced`` instead: they happen once per compile, not once per step, so
  timing them would be a lie.

Everything is trace-time gated exactly like ``calib.observe``: when no
profiler is installed the hooks are one global read and the entry points are
byte-identical to their un-instrumented selves.  ``report()`` emits the
roofline-attribution JSON (``repro/kernel-profile`` v1) and ``markdown()``
the human table ``launch/train.py --profile-out`` and ``serve.py`` write.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax

__all__ = [
    "KernelProfiler", "KernelRecord", "profiling", "site", "current_site",
    "dispatch", "is_active", "get_active",
    "gemm_cost", "attention_cost", "codec_cost", "softmax_cost",
]

FAMILIES = ("gemm", "quire_gemm", "attention", "codec", "softmax")


def _fmt_bytes(fmt) -> float:
    """Storage bytes per element of a pcsr operand slot (f32 fallback)."""
    return float(getattr(fmt, "storage_bytes", 4))


# ------------------------------------------------- cost extraction helpers ----
# Shapes come off the live arrays (tracers carry shapes too, so these work
# identically under jit traces); formulas live in launch.roofline.  Imported
# lazily: kernels/*/ops.py import this module at call time and must not drag
# the launch package into every kernel import.

def gemm_cost(a, b, slots, *, bias=None, residual=None) -> dict:
    from repro.launch import roofline

    m = 1.0
    for s in a.shape[:-1]:
        m *= s
    return roofline.gemm_cost(
        m, float(a.shape[-1]), float(b.shape[-1]),
        a_bytes=_fmt_bytes(slots.rs1), b_bytes=_fmt_bytes(slots.rs2),
        out_bytes=_fmt_bytes(slots.rd),
        bias=bias is not None, residual=residual is not None)


def linear_cost(x, n: float, *, w_bytes: float, bias: bool = False,
                residual: bool = False) -> dict:
    """A model-side linear y = x @ W: activations at their live width, the
    weight at its at-rest storage width (the fused decode reads codes)."""
    from repro.launch import roofline

    m = 1.0
    for s in x.shape[:-1]:
        m *= s
    xb = float(x.dtype.itemsize)
    return roofline.gemm_cost(m, float(x.shape[-1]), n, a_bytes=xb,
                              b_bytes=w_bytes, out_bytes=xb,
                              bias=bias, residual=residual)


def attention_cost(q, k_codes, *, kv_bits: int) -> dict:
    from repro.launch import roofline

    b, hq, d = q.shape
    hkv, s = k_codes.shape[1], k_codes.shape[2]
    kv_bytes = kv_bits / 8.0 if kv_bits else float(k_codes.dtype.itemsize)
    qb = float(q.dtype.itemsize)
    return roofline.attention_decode_cost(
        float(b), float(hq), float(hkv), float(s), float(d),
        kv_bytes=kv_bytes, q_bytes=qb, out_bytes=qb)


def codec_cost(arr, *, nbits: int, value_bytes: float = 4.0) -> dict:
    from repro.launch import roofline

    n = 1.0
    for s in arr.shape:
        n *= s
    return roofline.codec_cost(n, code_bytes=(nbits + 7) // 8,
                               value_bytes=value_bytes)


def softmax_cost(codes, *, nbits: int) -> dict:
    from repro.launch import roofline

    rows = 1.0
    for s in codes.shape[:-1]:
        rows *= s
    return roofline.softmax_cost(rows, float(codes.shape[-1]),
                                 code_bytes=(nbits + 7) // 8)


# --------------------------------------------------------------- recording ----

@dataclasses.dataclass
class KernelRecord:
    """Accumulated profile of one (path, family, impl) dispatch site."""

    path: str
    family: str
    impl: str
    calls: int = 0           # eager executions (each one timed)
    traced: int = 0          # executions under a jit trace (once per compile)
    flops: float = 0.0
    bytes: float = 0.0
    seconds: float = 0.0     # measured wall time over eager calls

    def to_dict(self) -> dict:
        from repro.launch import roofline

        bt = roofline.bound_times(self.flops, self.bytes)
        d = dataclasses.asdict(self)
        d.update({
            "t_compute_s": bt["t_compute_s"],
            "t_memory_s": bt["t_memory_s"],
            "bound": bt["dominant"],
            "bound_s": bt["bound_s"],
            # achieved-vs-bound: how far the measured time sits above the
            # roofline floor (1.0 = at the bound; CPU interpret-mode runs
            # sit far above it — the ratio is attribution, not a grade)
            "achieved_frac": (bt["bound_s"] / self.seconds
                              if self.seconds > 0 else None),
        })
        return d


_ACTIVE: Optional["KernelProfiler"] = None
_SITE: List[str] = []


def is_active() -> bool:
    return _ACTIVE is not None


def get_active() -> Optional["KernelProfiler"]:
    return _ACTIVE


def current_site() -> str:
    return _SITE[-1] if _SITE else ""


@contextlib.contextmanager
def site(path: str):
    """Attribute kernel dispatches inside the block to layer ``path``."""
    if _ACTIVE is None:
        yield
        return
    _SITE.append(path)
    try:
        yield
    finally:
        _SITE.pop()


@contextlib.contextmanager
def profiling(prof: "KernelProfiler"):
    """Install ``prof`` as the active kernel profiler for the block."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = prof
    try:
        yield prof
    finally:
        _ACTIVE = prev


def dispatch(family: str, impl: str, cost: dict, fn: Callable, *,
             primary=None, path: Optional[str] = None):
    """Run ``fn()`` under the active profiler (entry-point hook).

    ``primary`` is the dispatch's main input array: a ``jax`` tracer means
    this execution is a trace, not a step — counted but never timed.
    Call sites guard with ``is_active()`` so the inactive path never builds
    ``cost``.
    """
    prof = _ACTIVE
    if prof is None:
        return fn()
    traced = isinstance(primary, jax.core.Tracer)
    if traced or not prof.timed:
        out = fn()
        prof.record(family, impl, cost, path=path, traced=True)
        return out
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out)
    prof.record(family, impl, cost, path=path,
                seconds=time.perf_counter() - t0)
    return out


class KernelProfiler:
    """Accumulates :class:`KernelRecord` rows keyed by (path, family, impl)."""

    def __init__(self, *, timed: bool = True):
        self.timed = timed
        self.records: Dict[Tuple[str, str, str], KernelRecord] = {}

    def record(self, family: str, impl: str, cost: dict, *,
               path: Optional[str] = None, seconds: Optional[float] = None,
               traced: bool = False) -> None:
        key = (current_site() if path is None else path, family, impl)
        rec = self.records.get(key)
        if rec is None:
            rec = self.records[key] = KernelRecord(*key)
        if traced:
            rec.traced += 1
        else:
            rec.calls += 1
            rec.seconds += seconds or 0.0
        rec.flops += cost["flops"]
        rec.bytes += cost["bytes"]

    # -- reporting ------------------------------------------------------------
    def report(self, *, measured_total_s: Optional[float] = None) -> dict:
        from repro.launch import roofline

        rows = [self.records[k].to_dict() for k in sorted(self.records)]
        tot_flops = sum(r["flops"] for r in rows)
        tot_bytes = sum(r["bytes"] for r in rows)
        bt = roofline.bound_times(tot_flops, tot_bytes)
        return {
            "version": 1,
            "kind": "repro/kernel-profile",
            "peaks": {"flops": roofline.PEAK_FLOPS, "hbm_bw": roofline.HBM_BW},
            "rows": rows,
            "totals": {
                "dispatches": sum(r["calls"] + r["traced"] for r in rows),
                "flops": tot_flops, "bytes": tot_bytes,
                "bound_s": bt["bound_s"], "bound": bt["dominant"],
                "measured_s": measured_total_s,
                "achieved_frac": (bt["bound_s"] / measured_total_s
                                  if measured_total_s else None),
            },
        }

    def markdown(self, *, measured_total_s: Optional[float] = None) -> str:
        rep = self.report(measured_total_s=measured_total_s)
        lines = [
            "| path | family | impl | calls | traced | GFLOPs | MB moved "
            "| bound | bound_us | measured_us |",
            "|---|---|---|---|---|---|---|---|---|---|",
        ]
        for r in sorted(rep["rows"], key=lambda r: -r["bytes"]):
            lines.append(
                "| {path} | {family} | {impl} | {calls} | {traced} "
                "| {gf:.3f} | {mb:.3f} | {bound} | {bus:.2f} | {mus} |".format(
                    path=r["path"] or "—", family=r["family"], impl=r["impl"],
                    calls=r["calls"], traced=r["traced"],
                    gf=r["flops"] / 1e9, mb=r["bytes"] / 1e6,
                    bound=r["bound"], bus=r["bound_s"] * 1e6,
                    mus=(f"{r['seconds'] * 1e6:.1f}" if r["calls"] else "—")))
        t = rep["totals"]
        lines.append(
            f"\ntotals: {t['dispatches']} dispatches, "
            f"{t['flops'] / 1e9:.3f} GFLOPs, {t['bytes'] / 1e6:.3f} MB, "
            f"{t['bound']}-bound floor {t['bound_s'] * 1e6:.2f} us")
        return "\n".join(lines)

    def save(self, path: str, *, measured_total_s: Optional[float] = None
             ) -> dict:
        """Write the JSON report to ``path`` and the markdown table next to
        it (same stem, ``.md``); returns the report dict."""
        rep = self.report(measured_total_s=measured_total_s)
        with open(path, "w") as f:
            json.dump(rep, f, indent=1)
        with open(os.path.splitext(path)[0] + ".md", "w") as f:
            f.write(self.markdown(measured_total_s=measured_total_s) + "\n")
        return rep
