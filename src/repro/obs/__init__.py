"""Serving- and training-plane observability (DESIGN.md §12/§16).

Wired through ``launch/engine.py``, ``launch/serve.py``, ``launch/train.py``:

* :mod:`repro.obs.metrics`  — zero-dependency counters / gauges / histograms
  with exact percentile readout, JSON snapshot + Prometheus exposition.
* :mod:`repro.obs.trace`    — per-request Chrome-trace span timelines plus
  ``jax.profiler`` annotations so device profiles line up with them.
* :mod:`repro.obs.numerics` — posit numerical-health probes (saturation /
  underflow / NaR rates) and calibration-drift detection against the
  histograms stored in a ``@cal.json`` artifact.
* :mod:`repro.obs.train`    — training-plane telemetry: gradient/activation
  histograms from the probed-twin train step, step-health JSONL log,
  drift-latched ``recalibrate`` flag.
* :mod:`repro.obs.prof`     — per-kernel cost profiler: call counts,
  analytic bytes/FLOPs from the roofline cost model, measured dispatch wall
  time, per-layer-path attribution report.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               MetricsRegistry, RollingRate, percentile,
                               percentile_ms)
from repro.obs.numerics import (NumericsWatcher, drift_score,  # noqa: F401
                                drift_threshold, load_baselines)
from repro.obs.prof import KernelProfiler, profiling  # noqa: F401
from repro.obs.trace import TraceRecorder, annotate, named_scope  # noqa: F401
from repro.obs.train import JsonlStepLog, TrainingTelemetry  # noqa: F401
