"""Serving-plane observability (DESIGN.md §12).

Three layers, wired through ``launch/engine.py`` and ``launch/serve.py``:

* :mod:`repro.obs.metrics`  — zero-dependency counters / gauges / histograms
  with exact percentile readout, JSON snapshot + Prometheus exposition.
* :mod:`repro.obs.trace`    — per-request Chrome-trace span timelines plus
  ``jax.profiler`` annotations so device profiles line up with them.
* :mod:`repro.obs.numerics` — posit numerical-health probes (saturation /
  underflow / NaR rates) and calibration-drift detection against the
  histograms stored in a ``@cal.json`` artifact.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               MetricsRegistry, RollingRate, percentile,
                               percentile_ms)
from repro.obs.numerics import (NumericsWatcher, drift_score,  # noqa: F401
                                drift_threshold, load_baselines)
from repro.obs.trace import TraceRecorder, annotate, named_scope  # noqa: F401
