"""AdamW with optional posit-compressed moments (+ error feedback).

Transprecision applied to optimizer state (the paper's memory-savings claim on
the largest at-rest tensors of a training run): the first/second moments can be
stored as p16/p8 codes, cutting optimizer HBM by 2–4x. An f32 error-feedback
residual per moment keeps the update unbiased over time (beyond-paper; the
residual itself is small and optional).

State layout per leaf:
  float moments:  {"m": f32, "v": f32}
  posit moments:  {"m": uintN, "v": uintN [, "em": f32, "ev": f32]}
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.codec import posit_decode, posit_encode
from repro.core.types import PositFmt


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_fmt: Optional[PositFmt] = None   # posit-compress m and v
    error_feedback: bool = True


def _enc(x, fmt: PositFmt):
    return posit_encode(x, fmt.nbits, fmt.es)


def _dec(x, fmt: PositFmt):
    return posit_decode(x, fmt.nbits, fmt.es)


def adamw_init(params: Any, cfg: AdamWConfig) -> Any:
    def leaf(p):
        def z():
            # fresh buffer each time: sharing one zeros array across moments
            # breaks donation (same buffer donated twice)
            return jnp.zeros(p.shape, jnp.float32)
        if cfg.moment_fmt is None:
            return {"m": z(), "v": z()}
        st = {"m": _enc(z(), cfg.moment_fmt), "v": _enc(z(), cfg.moment_fmt)}
        if cfg.error_feedback:
            st["em"] = z()
            st["ev"] = z()
        return st
    return {"mu": jax.tree.map(leaf, params), "count": jnp.zeros((), jnp.int32)}


def adamw_update(grads: Any, state: Any, params: Any, cfg: AdamWConfig,
                 lr_scale=1.0) -> tuple[Any, Any]:
    count = state["count"] + 1
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def leaf(g, st, p):
        gf = g.astype(jnp.float32)
        if cfg.moment_fmt is None:
            m_prev, v_prev = st["m"], st["v"]
        else:
            m_prev = _dec(st["m"], cfg.moment_fmt)
            v_prev = _dec(st["v"], cfg.moment_fmt)
            if cfg.error_feedback:
                m_prev = m_prev + st["em"]
                v_prev = v_prev + st["ev"]
        m = cfg.b1 * m_prev + (1 - cfg.b1) * gf
        v = cfg.b2 * v_prev + (1 - cfg.b2) * gf * gf
        mh = m / b1c
        vh = v / b2c
        upd = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        if cfg.moment_fmt is None:
            new_st = {"m": m, "v": v}
        else:
            mc, vc = _enc(m, cfg.moment_fmt), _enc(v, cfg.moment_fmt)
            new_st = {"m": mc, "v": vc}
            if cfg.error_feedback:
                new_st["em"] = m - _dec(mc, cfg.moment_fmt)
                new_st["ev"] = v - _dec(vc, cfg.moment_fmt)
        return new_p, new_st

    flat_g, treedef = jax.tree.flatten(grads)
    flat_s = treedef.flatten_up_to(state["mu"])
    flat_p = treedef.flatten_up_to(params)
    out = [leaf(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    return new_params, {"mu": new_mu, "count": count}


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gn
