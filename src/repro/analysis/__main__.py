"""CLI: ``python -m repro.analysis`` — lint + (optionally) jaxpr audit.

    python -m repro.analysis                      # lint the repo, exit != 0
                                                  # on unsuppressed findings
    python -m repro.analysis --policy uniform-p16 # + jaxpr-audit the default
                                                  # arch set under a policy
    python -m repro.analysis --policy @cal.json --arch all
    python -m repro.analysis --root tests/fixtures/analysis   # CI fixtures
    python -m repro.analysis --write-baseline analysis-baseline.json
    python -m repro.analysis --baseline analysis-baseline.json

Exit status is 0 iff no *new* findings: unsuppressed errors not in the
baseline.  ``--json`` writes the full findings report for CI artifacts.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.base import (load_baseline, new_findings, save_baseline)
from repro.analysis.jaxpr_audit import DEFAULT_AUDIT_ARCHS, audit_archs
from repro.analysis.lint import lint_repo


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro numerics auditor + repo-invariant linter")
    ap.add_argument("files", nargs="*",
                    help="repo-relative files to lint (default: scan the repo)")
    ap.add_argument("--root", default=".",
                    help="repo root to lint (fixture trees mirror the repo "
                         "layout so path-scoped rules still apply)")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="write the findings report to this path")
    ap.add_argument("--baseline", default=None,
                    help="accepted-debt baseline to diff against")
    ap.add_argument("--write-baseline", default=None,
                    help="write current findings as the new baseline and exit 0")
    ap.add_argument("--policy", default=None,
                    help="run the jaxpr audit under this precision policy "
                         "(preset name, @artifact.json, or pattern=fmt spec)")
    ap.add_argument("--arch", default=None,
                    help="comma list of registry archs to audit, or 'all' "
                         f"(default: one per family: "
                         f"{','.join(DEFAULT_AUDIT_ARCHS)})")
    args = ap.parse_args(argv)

    findings = lint_repo(args.root, files=args.files or None)

    if args.policy is not None:
        from repro.core.policy import get_precision_policy
        policy = get_precision_policy(args.policy)
        archs = (list(DEFAULT_AUDIT_ARCHS) if args.arch is None
                 else ["all"] if args.arch == "all"
                 else [a.strip() for a in args.arch.split(",") if a.strip()])
        findings.extend(audit_archs(archs, policy))

    if args.write_baseline:
        save_baseline(args.write_baseline, findings)
        print(f"wrote baseline ({args.write_baseline}): "
              f"{len([f for f in findings if not f.suppressed and f.severity == 'error'])} "
              f"fingerprints", file=sys.stderr)
        return 0

    baseline = load_baseline(args.baseline) if args.baseline else None
    new = new_findings(findings, baseline)

    for f in findings:
        print(f.format(), file=sys.stderr)
    if args.json_out:
        with open(args.json_out, "w") as fh:
            json.dump({
                "kind": "repro/analysis-report",
                "version": 1,
                "n_findings": len(findings),
                "n_new": len(new),
                "findings": [f.to_json() for f in findings],
            }, fh, indent=1)
    n_warn = len([f for f in findings if f.severity == "warn"])
    n_sup = len([f for f in findings if f.suppressed])
    print(f"repro.analysis: {len(findings)} finding(s) "
          f"({len(new)} new, {n_warn} warn, {n_sup} noqa)", file=sys.stderr)
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
