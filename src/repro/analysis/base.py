"""Shared finding model for the linter and the jaxpr auditor.

A ``Finding`` is one violation of one rule, carrying enough location to act
on (file:line for lint, arch + layer path for jaxpr hazards) and a stable
``fingerprint`` for the baseline mechanism: fingerprints hash the rule, the
location *identity* (file / layer path, never the line number) and the
offending snippet, so reordering unrelated code does not churn the baseline.

Severity: ``error`` findings gate CI (CLI exits nonzero on new ones);
``warn`` findings are reported but never fail a run — used for advisory
hazards like single dead policy rules, where presets legitimately carry
rules that only some model families match.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Iterable, List, Optional, Set


@dataclasses.dataclass
class Finding:
    rule: str               # "RA003" | "JP001" | ...
    path: str               # repo-relative file, or "arch:trace/layer-path"
    message: str
    line: int = 0           # 1-based source line; 0 = not line-anchored
    snippet: str = ""       # offending source line / eqn text
    severity: str = "error"  # "error" | "warn"
    suppressed: bool = False  # silenced by `# repro: noqa=RULE`

    def fingerprint(self) -> str:
        key = f"{self.rule}|{self.path}|{self.snippet.strip() or self.message}"
        return hashlib.sha1(key.encode()).hexdigest()[:16]

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["fingerprint"] = self.fingerprint()
        return d

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        tag = {"error": "", "warn": " (warn)"}[self.severity]
        sup = " [noqa]" if self.suppressed else ""
        return f"{loc}: {self.rule}{tag}{sup}: {self.message}"


def load_baseline(path: str) -> Set[str]:
    """A baseline file is ``{"kind": "repro/analysis-baseline",
    "fingerprints": [...]}`` — the accepted-debt list the CLI diffs against."""
    with open(path) as f:
        d = json.load(f)
    if d.get("kind") != "repro/analysis-baseline":
        raise ValueError(f"not an analysis baseline: {d.get('kind')!r}")
    return set(d["fingerprints"])


def save_baseline(path: str, findings: Iterable[Finding]) -> None:
    fps = sorted({f.fingerprint() for f in findings
                  if not f.suppressed and f.severity == "error"})
    with open(path, "w") as f:
        json.dump({"kind": "repro/analysis-baseline", "version": 1,
                   "fingerprints": fps}, f, indent=1)


def new_findings(findings: Iterable[Finding],
                 baseline: Optional[Set[str]] = None) -> List[Finding]:
    """The findings that should fail a run: unsuppressed errors whose
    fingerprint is not in the baseline."""
    base = baseline or set()
    return [f for f in findings
            if not f.suppressed and f.severity == "error"
            and f.fingerprint() not in base]
