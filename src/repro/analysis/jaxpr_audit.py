"""Jaxpr numerics auditor — hazards JP001-JP006 (DESIGN.md §15).

The linter checks what the *source* says; this module checks what the traced
program actually *does*.  ``audit_model`` traces ``model.loss`` (float
params, calibration markers installed) and ``model.decode_step`` (posit-
quantized params, the serving executable) for a registry family under a
given policy, then walks the ClosedJaxpr:

* **JP001** — a posit *code* tensor (uint8/uint16 storage) flows into value
  arithmetic (``add``/``mul``/``dot_general``/reductions) without passing
  through a decode.  Codes are an opaque bit domain: the only legal exits
  are bitwise field extraction (decode), gather indexing (LUT decode) and
  equality tests (NaR checks).  Taint analysis: code-dtype inputs seed,
  transport ops propagate, bitwise ops *kill* (that is the decode boundary),
  arithmetic on a tainted operand is the finding.
* **JP002** — a site whose resolved policy declares ``dataflow="quire"``
  still lowers to a float ``dot_general`` (``audit_quire_sites``): the
  exact-accumulation contract silently degraded to FPU accumulate, e.g.
  because the params were never quantized or a code path bypassed
  ``_quire_linear``.
* **JP003** — encode->decode round-trip churn: a decode whose codes came
  straight from an encode in the same executable with no storage boundary
  (KV-cache writes, checkpoint slices) in between — two codec passes where
  a no-op would do.  The training-path straight-through estimator is the
  deliberate exception (its decode output feeds the ``sub`` of
  ``w + stop_grad(qw - wf)``) and is exempted structurally.
* **JP004** — ``convert_element_type`` narrowing f32 -> bf16/f16 feeding a
  reduction (``reduce_sum``/``dot_general``) that *accumulates in the
  narrow dtype* within a few transport hops.  Narrow inputs with an f32
  accumulator (``preferred_element_type``) are the sanctioned pattern and
  do not fire.
* **JP005** — ``debug_callback`` equations baked into the non-probed
  serving executable: a forgotten observer hook re-traces into every decode
  step and stalls the drive loop on host syncs (the §12 probes install
  observers *cadenced*, never in the steady-state executable).
* **JP006** — dead ``PrecisionPolicy`` rules: a non-catchall rule matching
  no linear path in the model (typo'd pattern — the layer it meant to
  schedule silently runs at the base format).  One dead rule is a warning
  (presets legitimately carry rules only some families match); *all*
  non-catchall rules dead is an error.

Findings carry ``arch:trace/layer-path`` locations — the layer path is
recovered from the calibration observer's ``debug_callback`` markers
(``(path, kind)`` keys, the same keying ``calib.observe`` streams stats
under), so a hazard inside a scanned block names the call site that
produced it.
"""
from __future__ import annotations

import fnmatch
import functools
from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.base import Finding
from repro.calib.observe import Observer, observing
from repro.calib.search import calibration_batches
from repro.configs import ARCH_IDS, get_arch
from repro.models.layers import (_RAW_WEIGHT_PATTERNS, _walk_linears,
                                 apply_linear, quantize_params, resolve_policy)
from repro.models.registry import build_model

# One representative per registry family — the CLI's default audit matrix
# (nightly CI runs the full ARCH_IDS cross product).
DEFAULT_AUDIT_ARCHS = (
    "phi3-mini-3.8b",     # dense
    "olmoe-1b-7b",        # moe
    "gemma3-4b",          # gemma3 local/global
    "zamba2-7b",          # ssm hybrid
    "xlstm-125m",         # xlstm
    "whisper-medium",     # encoder-decoder
    "internvl2-2b",       # vlm
)

# Posit code storage dtypes: the taint domain of JP001.
_CODE_DTYPES = (jnp.uint8, jnp.uint16)

# Value-preserving data movement: taint flows through.
_TRANSPORT = frozenset({
    "reshape", "broadcast_in_dim", "transpose", "squeeze", "rev", "copy",
    "slice", "concatenate", "pad", "dynamic_slice", "dynamic_update_slice",
    "gather", "select_n", "scatter", "scatter-add",
})
# Bit-domain ops: field extraction, i.e. the decode boundary — outputs leave
# the code domain.
_BITWISE = frozenset({
    "and", "or", "xor", "not", "shift_left", "shift_right_logical",
    "shift_right_arithmetic", "population_count", "clz",
})
# Value arithmetic: a tainted operand here is the JP001 hazard.
_ARITH = frozenset({
    "add", "sub", "mul", "div", "rem", "pow", "integer_pow", "dot_general",
    "reduce_sum", "reduce_prod", "reduce_max", "reduce_min", "max", "min",
    "exp", "log", "tanh", "logistic", "cumsum",
})
# Storage boundaries that break a JP003 encode->decode chain: codes that
# were *stored* (cache writes/reads, slices of a persisted buffer) are
# decoded legitimately.
_STORAGE = frozenset({
    "dynamic_update_slice", "dynamic_slice", "slice", "gather", "scatter",
    "scatter-add", "concatenate", "pad",
})
_NARROW = (jnp.bfloat16, jnp.float16)


def _is_code(v) -> bool:
    dt = getattr(getattr(v, "aval", None), "dtype", None)
    return dt is not None and any(dt == d for d in _CODE_DTYPES)


def _dtype(v):
    return getattr(getattr(v, "aval", None), "dtype", None)


def _sub_jaxprs(eqn):
    """Every sub-jaxpr an equation closes over (pjit/scan/while/cond/...)."""
    for val in eqn.params.values():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for v in vals:
            if isinstance(v, jax.extend.core.ClosedJaxpr):
                yield v.jaxpr
            elif isinstance(v, jax.extend.core.Jaxpr):
                yield v


def _marker_key(eqn) -> Optional[Tuple[str, str]]:
    """Recover the observer's ``(path, kind)`` key from a debug_callback eqn.

    ``calib.observe.Observer.record`` ships stats through
    ``jax.debug.callback(functools.partial(self._accum, (path, kind), ...))``
    — the key is the partial's first positional arg, however many wrapper
    layers jax's callback machinery adds around it.  Best-effort: returns
    None when no key is found (finding paths then fall back to the trace
    name).
    """
    return _find_key(eqn.params.get("callback"), 0)


def _find_key(obj, depth: int) -> Optional[Tuple[str, str]]:
    if depth > 6 or obj is None:
        return None
    if isinstance(obj, functools.partial):
        for a in obj.args:
            if (isinstance(a, tuple) and len(a) == 2
                    and all(isinstance(s, str) for s in a)
                    and a[1] in ("weight", "act", "grad")):
                return a
        for sub in (obj.func, *obj.args, *obj.keywords.values()):
            k = _find_key(sub, depth + 1)
            if k is not None:
                return k
        return None
    if callable(obj):
        for cell in getattr(obj, "__closure__", None) or ():
            try:
                k = _find_key(cell.cell_contents, depth + 1)
            except ValueError:
                continue
            if k is not None:
                return k
        wrapped = getattr(obj, "__wrapped__", None)
        if wrapped is not None and wrapped is not obj:
            return _find_key(wrapped, depth + 1)
    return None


# ------------------------------------------------------------------ walker ----

class _Audit:
    def __init__(self, trace: str, probed: bool):
        self.trace = trace
        self.probed = probed
        self.findings: List[Finding] = []
        self.marker: Optional[str] = None  # last observer path seen in order

    def _loc(self) -> str:
        return f"{self.trace}/{self.marker}" if self.marker else self.trace

    def add(self, rule: str, message: str, snippet: str,
            severity: str = "error") -> None:
        self.findings.append(Finding(
            rule=rule, path=self._loc(), message=message, snippet=snippet,
            severity=severity))

    # -- one jaxpr (recursing into sub-jaxprs; each seeds its own taint) ----
    def walk(self, jaxpr) -> None:
        tainted: Set = {v for v in (*jaxpr.invars, *jaxpr.constvars)
                        if _is_code(v)}
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            if name == "debug_callback":
                key = _marker_key(eqn)
                if key is not None:
                    self.marker = key[0]
                if not self.probed:
                    self.add(
                        "JP005",
                        "debug_callback baked into a non-probed steady-state "
                        "executable (serving decode or plain train step): "
                        "every step pays a host sync (observers belong on "
                        "the cadenced probe/telemetry-twin executables, "
                        "DESIGN.md §12/§16)",
                        snippet="debug_callback")
                continue
            for sub in _sub_jaxprs(eqn):
                self.walk(sub)
            self._step_taint(eqn, tainted)
        self._churn(jaxpr)
        self._narrowed_reductions(jaxpr)

    # -- JP001 taint propagation -------------------------------------------
    def _step_taint(self, eqn, tainted: Set) -> None:
        name = eqn.primitive.name
        invars = [v for v in eqn.invars if not isinstance(v, jax.extend.core.Literal)]

        def hot(vs) -> bool:
            return any(v in tainted for v in vs)

        if name in _BITWISE:
            return  # field extraction: the decode boundary kills taint
        if name == "convert_element_type":
            out = eqn.outvars[0]
            if hot(invars):
                tainted.add(out)
            elif (_dtype(out) is not None
                  and any(_dtype(out) == d for d in _CODE_DTYPES)
                  and invars and np.issubdtype(_dtype(invars[0]), np.integer)):
                tainted.add(out)  # encode tail: wide int -> code storage
            return
        if name in _TRANSPORT:
            # index-consuming ops: taint rides the *data* operand only — a
            # gather indexed by codes (LUT decode) produces clean values
            if name in ("gather", "dynamic_slice"):
                src = hot(invars[:1])
            elif name in ("dynamic_update_slice", "scatter", "scatter-add"):
                src = hot(invars[:1]) or hot(invars[-1:])
            elif name == "select_n":
                src = hot(invars[1:])
            else:
                src = hot(invars)
            if src:
                tainted.update(eqn.outvars)
            return
        if name in _ARITH and hot(invars):
            culprits = sorted({str(_dtype(v)) for v in invars
                               if v in tainted})
            self.add(
                "JP001",
                f"posit code tensor ({', '.join(culprits)}) used as a value "
                f"operand of `{name}` without decode — codes are an opaque "
                f"bit domain; arithmetic on them is numerically meaningless",
                snippet=f"{name}({', '.join(str(_dtype(v)) for v in eqn.invars)})")
            return
        # comparisons (NaR checks) and everything else: outputs leave taint

    # -- JP003 encode->decode churn ----------------------------------------
    def _churn(self, jaxpr) -> None:
        prod = {v: eqn for eqn in jaxpr.eqns for v in eqn.outvars}
        consumers: Dict = {}
        for eqn in jaxpr.eqns:
            for v in eqn.invars:
                if not isinstance(v, jax.extend.core.Literal):
                    consumers.setdefault(v, []).append(eqn)
        encode_tails = set()
        for eqn in jaxpr.eqns:
            if (eqn.primitive.name == "convert_element_type"
                    and any(_dtype(eqn.outvars[0]) == d for d in _CODE_DTYPES)
                    and np.issubdtype(_dtype(eqn.invars[0]), np.integer)):
                encode_tails.update(eqn.outvars)

        if not encode_tails:
            return

        for eqn in jaxpr.eqns:
            head = None  # the integer codes var this decode consumes
            if (eqn.primitive.name == "bitcast_convert_type"
                    and np.issubdtype(_dtype(eqn.invars[0]), np.integer)
                    and np.issubdtype(_dtype(eqn.outvars[0]), np.floating)):
                head = eqn.invars[0]
            elif (eqn.primitive.name == "gather" and len(eqn.invars) >= 2
                    and np.issubdtype(_dtype(eqn.invars[0]), np.floating)
                    and np.issubdtype(_dtype(eqn.invars[1]), np.integer)):
                head = eqn.invars[1]  # LUT decode: float table, code index
            if head is None or isinstance(head, jax.extend.core.Literal):
                continue
            if not self._reaches_encode(head, prod, encode_tails):
                continue
            if self._is_ste(eqn.outvars[0], consumers):
                continue
            self.add(
                "JP003",
                "encode->decode round trip with no storage boundary in "
                "between: two codec passes where the value was already in "
                "hand (the training-path straight-through estimator is the "
                "exempted exception)",
                snippet=f"churn:{eqn.primitive.name}")

    @staticmethod
    def _reaches_encode(var, prod, encode_tails, limit: int = 400) -> bool:
        """Backward BFS from a decode's code operand through in-register int
        ops; storage ops break the chain (stored codes decode legitimately)."""
        seen = set()
        frontier = [var]
        while frontier and len(seen) < limit:
            v = frontier.pop()
            if v in seen or isinstance(v, jax.extend.core.Literal):
                continue
            seen.add(v)
            if v in encode_tails:
                return True
            eqn = prod.get(v)
            if eqn is None or eqn.primitive.name in _STORAGE:
                continue
            if eqn.primitive.name in (_BITWISE | {
                    "convert_element_type", "reshape", "broadcast_in_dim",
                    "transpose", "squeeze", "rev", "copy", "select_n",
                    "add", "sub", "mul"}):
                frontier.extend(u for u in eqn.invars
                                if not isinstance(u, jax.extend.core.Literal))
        return False

    # decode epilogues between the bitcast/LUT readout and the value proper:
    # NaR select, sign application, dtype casts.  The STE search follows
    # these (and nothing else) forward to find the `qw - wf` sub.
    _DECODE_EPILOGUE = frozenset({
        "convert_element_type", "select_n", "mul", "neg", "reshape",
        "broadcast_in_dim", "transpose", "squeeze", "copy",
        "pjit",  # jnp.where wraps its select in a pjit — pass through it
    })

    @classmethod
    def _is_ste(cls, out, consumers, limit: int = 24) -> bool:
        """Straight-through-estimator shape: the decode output (through the
        decode's own epilogue ops) is an operand of a ``sub`` (the
        ``qw - wf`` of ``effective_weight``)."""
        seen = set()
        frontier = [out]
        while frontier and len(seen) < limit:
            v = frontier.pop()
            if v in seen:
                continue
            seen.add(v)
            for eqn in consumers.get(v, ()):
                if eqn.primitive.name == "sub":
                    return True
                if eqn.primitive.name in cls._DECODE_EPILOGUE:
                    frontier.extend(eqn.outvars)
        return False

    # -- JP004 narrowing upstream of a reduction ---------------------------
    def _narrowed_reductions(self, jaxpr) -> None:
        consumers: Dict = {}
        for eqn in jaxpr.eqns:
            for v in eqn.invars:
                if not isinstance(v, jax.extend.core.Literal):
                    consumers.setdefault(v, []).append(eqn)
        for eqn in jaxpr.eqns:
            if eqn.primitive.name != "convert_element_type":
                continue
            src, out = _dtype(eqn.invars[0]), _dtype(eqn.outvars[0])
            if src != jnp.float32 or not any(out == d for d in _NARROW):
                continue
            frontier = [eqn.outvars[0]]
            for _ in range(3):
                nxt = []
                for v in frontier:
                    for c in consumers.get(v, ()):
                        cn = c.primitive.name
                        if cn in ("reduce_sum", "dot_general") and any(
                                _dtype(c.outvars[0]) == d for d in _NARROW):
                            self.add(
                                "JP004",
                                f"f32 narrowed to {out} and then accumulated "
                                f"in {_dtype(c.outvars[0])} by `{cn}` — "
                                f"narrow inputs are fine, narrow "
                                f"*accumulators* lose the paper's error "
                                f"budget (use preferred_element_type=f32)",
                                snippet=f"narrow:{cn}:{out}")
                            return
                        if cn in _TRANSPORT or cn == "convert_element_type":
                            nxt.extend(c.outvars)
                frontier = nxt
                if not frontier:
                    break


def audit_closed_jaxpr(closed, *, trace: str = "trace",
                       probed: bool = False) -> List[Finding]:
    """Walk one traced executable for JP001/JP003/JP004/JP005.

    ``probed=True`` marks an executable that is *supposed* to carry observer
    callbacks (a calibration or probe trace): JP005 is silenced and the
    callbacks' ``(path, kind)`` keys attribute findings to layer paths.
    """
    a = _Audit(trace, probed)
    a.walk(closed.jaxpr)
    # scans/vmaps replay one body many times; identical findings collapse
    seen, out = set(), []
    for f in a.findings:
        fp = f.fingerprint()
        if fp not in seen:
            seen.add(fp)
            out.append(f)
    return out


# ------------------------------------------------------- JP002 quire sites ----

def _site_params(tree, path: str) -> dict:
    """The (possibly quantized) param dict at a _walk_linears path, with
    scan-stacked leading layer axes sliced off so the dict traces as one
    layer's linear."""
    node = tree
    for seg in path.split("/"):
        if seg:
            node = node[int(seg)] if isinstance(node, (list, tuple)) else node[seg]
    out = {}
    for k, v in node.items():
        if k in ("w", "w_codes", "w_packed") and getattr(v, "ndim", 0) == 3:
            v = v[0]
        elif k == "b" and getattr(v, "ndim", 0) == 2:
            v = v[0]
        out[k] = v
    return out


def _has_float_dot(jaxpr) -> bool:
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "dot_general" and any(
                np.issubdtype(_dtype(v), np.floating) for v in eqn.invars):
            return True
        for sub in _sub_jaxprs(eqn):
            if _has_float_dot(sub):
                return True
    return False


def audit_quire_sites(arch_or_cfg, policy, *, params=None,
                      quantize: bool = True) -> Tuple[List[Finding], int]:
    """JP002: verify every quire-declared linear lowers to quire dataflow.

    Walks the model's linears; for each site whose *resolved* policy says
    ``dataflow="quire"`` with a posit weight format, traces ``apply_linear``
    on that site's (quantized) params and flags any float ``dot_general`` in
    the result — the quire path is pure integer accumulation with one
    terminal rounding, so a float contraction means the exact-accumulation
    contract silently degraded.  ``quantize=False`` audits the float tree
    (the CI seeded-violation fixture: unquantized params at quire sites
    *must* fire).  Returns ``(findings, n_quire_sites)``.
    """
    cfg = get_arch(arch_or_cfg).reduced() if isinstance(arch_or_cfg, str) \
        else arch_or_cfg
    model = build_model(cfg)
    if params is None:
        params = model.init(jax.random.PRNGKey(0))
    tree = quantize_params(params, policy) if quantize else params

    findings: List[Finding] = []
    n_sites = 0
    for path, parent, key in _walk_linears(params, ""):
        if key != "w":
            continue  # MoE expert einsums stay on the fused FPU datapath
        if any(fnmatch.fnmatchcase(path, pat) for pat in _RAW_WEIGHT_PATTERNS):
            continue
        pol = resolve_policy(policy, path)
        if pol.dataflow != "quire" or pol.weights is None:
            continue
        n_sites += 1
        site = _site_params(tree, path)
        d_in = parent["w"].shape[-2]
        x = jax.ShapeDtypeStruct((2, d_in), jnp.float32)
        closed = jax.make_jaxpr(
            lambda pd, xx, _path=path: apply_linear(pd, xx, policy, path=_path)
        )(site, x)
        if _has_float_dot(closed.jaxpr):
            findings.append(Finding(
                rule="JP002",
                path=f"{cfg.name}:{path}",
                message=(
                    "quire-declared site lowers to a float dot_general: the "
                    "exact-accumulation contract degraded to FPU accumulate "
                    "(params not quantized, or the site bypassed "
                    "_quire_linear)"),
                snippet="quire-site:float-dot"))
    return findings, n_sites


# ---------------------------------------------------------- JP006 dead rules --

def dead_rules(policy, params, *, arch: str = "model") -> List[Finding]:
    """Non-catchall PrecisionPolicy rules that win for no linear path."""
    rules = getattr(policy, "rules", None)
    if not rules:
        return []
    paths = [p for p, _, _ in _walk_linears(params, "")]
    live = set()
    for p in paths:
        r = policy.rule_for(p)
        if r is not None:
            live.add(id(r))
    dead = [r for r in rules if r.pattern != "*" and id(r) not in live]
    non_catchall = [r for r in rules if r.pattern != "*"]
    if not dead:
        return []
    if len(dead) == len(non_catchall):
        return [Finding(
            rule="JP006", path=f"{arch}:policy",
            message=(
                f"every non-catchall precision rule is dead "
                f"({', '.join(r.pattern for r in dead)} match no linear "
                f"path): the schedule is a no-op and the whole model runs "
                f"at the base/catch-all format"),
            snippet="dead:all")]
    return [Finding(
        rule="JP006", path=f"{arch}:policy",
        message=(f"precision rule {r.pattern!r} matches no linear path in "
                 f"this model (typo, or a family without that block)"),
        snippet=f"dead:{r.pattern}", severity="warn") for r in dead]


# ------------------------------------------------------ training executables --

def trace_train_step(arch_or_cfg, policy, *, seq: int = 16,
                     telemetry: bool = False, observed: bool = False):
    """Trace one training executable (``make_train_step``) to a ClosedJaxpr.

    ``telemetry`` selects the probed-twin builder (extra params-sized metric
    reductions); ``observed`` traces under a three-channel observer
    (weight/act/grad) so the §11/§16 callbacks — including the ``grad_tap``
    cotangent hooks — bake into the executable.  The four combinations are
    the JP005 truth table for the training plane (see ``audit_train``).
    """
    from repro.launch.steps import make_train_step
    from repro.optim import AdamWConfig, adamw_init

    cfg = get_arch(arch_or_cfg).reduced() if isinstance(arch_or_cfg, str) \
        else arch_or_cfg
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-3,
                          moment_fmt=getattr(policy, "optimizer", None))
    opt = adamw_init(params, opt_cfg)
    batch = calibration_batches(
        cfg, np.random.default_rng(0), 1, batch=2, seq=seq)[0]
    step = make_train_step(model, policy, opt_cfg, warmup=1, total_steps=4,
                           telemetry=telemetry)

    def tr(p, o, b):
        return step(p, o, b, jnp.int32(0))

    if observed:
        obs = Observer(kinds=("weight", "act", "grad"))
        with observing(obs):
            return jax.make_jaxpr(tr)(params, opt, batch)
    return jax.make_jaxpr(tr)(params, opt, batch)


def audit_train(arch: str, policy, *, seq: int = 16) -> List[Finding]:
    """JP005 for the training plane (plus JP001/3/4 over both executables).

    The §16 probed-twin contract: the *plain* train step — the executable
    every non-probed step runs — must carry zero ``debug_callback`` host
    syncs, while the telemetry twin (traced under the observer, grad taps
    live) is exempt exactly like the §12 probe trace.  A leaked observer
    context around the plain step's trace is the seeded positive — it bakes
    the callbacks in and fires.
    """
    findings = audit_closed_jaxpr(
        trace_train_step(arch, policy, seq=seq),
        trace=f"{arch}:train", probed=False)
    findings += audit_closed_jaxpr(
        trace_train_step(arch, policy, seq=seq, telemetry=True,
                         observed=True),
        trace=f"{arch}:train-probed", probed=True)
    return findings


# -------------------------------------------------------------- audit_model ---

def audit_model(arch: str, policy, *, seq: int = 16,
                s_max: int = 32) -> List[Finding]:
    """Trace + audit one registry family under ``policy``.

    Three trace groups: ``loss`` (float params, observer markers installed —
    the calibration executable, JP005-exempt), ``decode`` (posit-quantized
    params, the steady-state serving executable, where a debug_callback is a
    real JP005 hazard), and the training pair from :func:`audit_train` (the
    plain train step is JP005-gated like decode; the telemetry twin is
    exempt).  Adds the JP002 quire-contract sweep when any site resolves to
    quire dataflow, and the JP006 dead-rule scan for PrecisionPolicy
    schedules.
    """
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = calibration_batches(
        cfg, np.random.default_rng(0), 1, batch=2, seq=seq)[0]

    findings: List[Finding] = []

    obs = Observer()
    with observing(obs):
        closed_loss = jax.make_jaxpr(
            lambda p, b: model.loss(p, b, policy))(params, batch)
    findings += audit_closed_jaxpr(
        closed_loss, trace=f"{arch}:loss", probed=True)

    qp = quantize_params(params, policy)
    if cfg.family == "whisper":
        cache = jax.eval_shape(
            lambda p: model.init_cache(p, batch, policy, s_max), qp)
    else:
        cache = jax.eval_shape(lambda: model.init_cache(2, s_max, policy))
    qshapes = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), qp)
    tok = jax.ShapeDtypeStruct((2,), jnp.int32)
    closed_dec = jax.make_jaxpr(
        lambda p, t, c: model.decode_step(p, t, c, policy))(qshapes, tok, cache)
    findings += audit_closed_jaxpr(
        closed_dec, trace=f"{arch}:decode", probed=False)

    findings += audit_train(arch, policy, seq=seq)

    if any(resolve_policy(policy, p).dataflow == "quire"
           for p, _, k in _walk_linears(params, "") if k == "w"):
        qf, _ = audit_quire_sites(cfg, policy, params=params)
        findings += qf

    findings += dead_rules(policy, params, arch=arch)
    return findings


def audit_archs(archs: Sequence[str], policy) -> List[Finding]:
    out: List[Finding] = []
    for a in (ARCH_IDS if archs == ["all"] else archs):
        out.extend(audit_model(a, policy))
    return out
