"""AST repo-invariant linter: rules RA001-RA006 (DESIGN.md §15).

Each rule encodes an invariant a past PR's review round fixed by hand; the
registry is ruff-style (id -> checker over a parsed module), scoped by path
globs so a rule only runs where its invariant applies.  Per-line suppression:

    eng.lens += 1  # repro: noqa=RA006  <- rationale goes in a comment

Suppressed findings are still collected (``suppressed=True``) so the CLI can
report how much is being waived, but they never fail a run.

``stdout_kinds`` is the single enforcement point for the DESIGN.md §14
stdout protocol: it extracts every ``"kind"`` literal a module prints via
``json.dumps`` — tests/test_protocol.py consumes it instead of scraping
source with regexes (ISSUE-9 satellite).
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import pathlib
import re
from typing import Callable, Dict, Iterable, Iterator, List, Optional

from repro.analysis.base import Finding

# Directories lint_repo scans, relative to the repo root.  tests/ is
# deliberately absent: fixtures there *seed* violations, and RA002 exempts
# test timing by construction.
SCAN_DIRS = ("src/repro", "benchmarks", "examples")

_NOQA = re.compile(r"#\s*repro:\s*noqa=([A-Z]{2}\d{3}(?:\s*,\s*[A-Z]{2}\d{3})*)")


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    summary: str
    check: Callable[["LintModule"], Iterator[Finding]]
    paths: tuple  # fnmatch globs over the repo-relative posix path
    excludes: tuple = ()

    def applies(self, rel: str) -> bool:
        if any(fnmatch.fnmatch(rel, pat) for pat in self.excludes):
            return False
        return any(fnmatch.fnmatch(rel, pat) for pat in self.paths)


RULES: Dict[str, Rule] = {}


def _rule(id: str, summary: str, paths: tuple, excludes: tuple = ()):
    def deco(fn):
        RULES[id] = Rule(id, summary, fn, paths, excludes)
        return fn
    return deco


class LintModule:
    """One parsed source file plus its per-line noqa map."""

    def __init__(self, rel: str, source: str):
        self.rel = rel
        self.source = source
        self.tree = ast.parse(source)
        self.lines = source.splitlines()
        self.noqa: Dict[int, set] = {}
        for i, line in enumerate(self.lines, 1):
            m = _NOQA.search(line)
            if m:
                self.noqa[i] = {t.strip() for t in m.group(1).split(",")}

    def finding(self, rule: str, node: ast.AST, message: str,
                severity: str = "error") -> Finding:
        line = getattr(node, "lineno", 0)
        snippet = self.lines[line - 1].strip() if 0 < line <= len(self.lines) \
            else ""
        return Finding(
            rule=rule, path=self.rel, line=line, message=message,
            snippet=snippet, severity=severity,
            suppressed=rule in self.noqa.get(line, ()))


def _call_name(node: ast.Call) -> str:
    """Trailing name of the called function: f(...) -> "f", a.b.f(...) -> "f"."""
    f = node.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


def _dotted(node: ast.expr) -> str:
    """Best-effort dotted spelling of a Name/Attribute chain ("self.d.engine")."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


# ---------------------------------------------------------------- RA001 ----

# Entry points of models.layers / models.attention whose ``path=`` keyword
# keys per-layer policy resolution AND calibration observation: a missing
# path silently resolves the default rule and mis-keys the calib artifact.
_PATH_ENTRY_POINTS = frozenset({
    "apply_linear", "apply_swiglu", "apply_gelu_mlp",
    "apply_attention", "apply_attention_dynwin", "prefill_attention",
    "decode_attention_step", "decode_attention_step_paged",
})


@_rule("RA001",
       "apply_linear / attention entry call sites must pass path=",
       paths=("src/repro/*",))
def _check_ra001(mod: LintModule) -> Iterator[Finding]:
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node)
        if name not in _PATH_ENTRY_POINTS:
            continue
        if any(kw.arg == "path" for kw in node.keywords):
            continue
        yield mod.finding(
            "RA001", node,
            f"{name}() without path=: per-layer policy resolution and "
            f"calibration keying silently fall back to the default path")


# ---------------------------------------------------------------- RA002 ----

@_rule("RA002",
       "no time.time() outside tests (perf paths use perf_counter)",
       paths=("src/repro/*", "benchmarks/*", "examples/*"))
def _check_ra002(mod: LintModule) -> Iterator[Finding]:
    bare_time = any(
        isinstance(n, ast.ImportFrom) and n.module == "time"
        and any(a.name == "time" for a in n.names)
        for n in ast.walk(mod.tree))
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        hit = (isinstance(f, ast.Attribute) and f.attr == "time"
               and isinstance(f.value, ast.Name) and f.value.id == "time") \
            or (bare_time and isinstance(f, ast.Name) and f.id == "time")
        if hit:
            yield mod.finding(
                "RA002", node,
                "time.time() is wall-clock (NTP steps backwards); timing "
                "code uses time.perf_counter()")


# ---------------------------------------------------------------- RA003 ----

def _dict_has_kind(d: ast.expr) -> bool:
    return (isinstance(d, ast.Dict)
            and any(isinstance(k, ast.Constant) and k.value == "kind"
                    for k in d.keys))


@_rule("RA003",
       'launch/ stdout prints are single json.dumps objects with a "kind"',
       paths=("src/repro/launch/*",))
def _check_ra003(mod: LintModule) -> Iterator[Finding]:
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id == "print"):
            continue
        if any(kw.arg == "file" for kw in node.keywords):
            continue  # stderr (or a file object) is human diagnostics
        args = node.args
        ok = (len(args) == 1 and isinstance(args[0], ast.Call)
              and _call_name(args[0]) == "dumps"
              and args[0].args and _dict_has_kind(args[0].args[0]))
        if not ok:
            yield mod.finding(
                "RA003", node,
                'stdout is the §14 protocol: print exactly one '
                'json.dumps({...}) whose dict literal carries a "kind" key '
                '(or route diagnostics to file=sys.stderr)')


def stdout_kinds(paths: Iterable[str],
                 root: Optional[str] = None) -> Dict[str, str]:
    """Every ``"kind"`` literal printed via ``json.dumps`` in ``paths``.

    Returns {kind: repo-relative file that first emits it}.  This is the
    §14-protocol extraction tests/test_protocol.py keys on — the same AST
    walk RA003 enforces, so the protocol has exactly one enforcement point.
    """
    base = pathlib.Path(root) if root else None
    kinds: Dict[str, str] = {}
    for rel in paths:
        p = (base / rel) if base else pathlib.Path(rel)
        tree = ast.parse(p.read_text())
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id == "print"
                    and not any(kw.arg == "file" for kw in node.keywords)
                    and node.args and isinstance(node.args[0], ast.Call)
                    and _call_name(node.args[0]) == "dumps"
                    and node.args[0].args):
                continue
            d = node.args[0].args[0]
            if not isinstance(d, ast.Dict):
                continue
            for k, v in zip(d.keys, d.values):
                if (isinstance(k, ast.Constant) and k.value == "kind"
                        and isinstance(v, ast.Constant)
                        and isinstance(v.value, str)):
                    kinds.setdefault(v.value, str(rel))
    return kinds


# ---------------------------------------------------------------- RA004 ----

@_rule("RA004",
       "no np.savez under checkpoint/ (PR-7 GIL-stall class)",
       paths=("src/repro/checkpoint/*",))
def _check_ra004(mod: LintModule) -> Iterator[Finding]:
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if (isinstance(f, ast.Attribute)
                and f.attr in ("savez", "savez_compressed")
                and isinstance(f.value, ast.Name)
                and f.value.id in ("np", "numpy")):
            yield mod.finding(
                "RA004", node,
                f"np.{f.attr} zips under the GIL and stalls the drive "
                f"thread; checkpoints stream raw .npy members (PR-7)")


# ---------------------------------------------------------------- RA005 ----

# Engine methods that mutate serving state: calling one off the drive
# thread races the in-flight step (DESIGN.md §14 drive-thread contract).
_ENGINE_MUTATORS = frozenset({
    "submit", "admit", "step", "run", "reset", "restore", "cancel",
    "evict", "scrub_slot", "apply_policy",
})


def _engine_expr(node: ast.expr) -> bool:
    """True for an Attribute chain that reaches through ``.engine``."""
    while isinstance(node, ast.Attribute):
        if node.attr == "engine":
            return True
        node = node.value
    return isinstance(node, ast.Name) and node.id == "engine"


@_rule("RA005",
       "engine mutation in server.py only inside the EngineDriver surface",
       paths=("src/repro/launch/server.py",))
def _check_ra005(mod: LintModule) -> Iterator[Finding]:
    driver_spans = [
        (n.lineno, n.end_lineno) for n in ast.walk(mod.tree)
        if isinstance(n, ast.ClassDef) and n.name == "EngineDriver"]

    def inside_driver(node) -> bool:
        ln = getattr(node, "lineno", 0)
        return any(lo <= ln <= hi for lo, hi in driver_spans)

    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                base = t.value if isinstance(t, (ast.Attribute, ast.Subscript)) \
                    else None
                if base is not None and _engine_expr(base) \
                        and not inside_driver(node):
                    yield mod.finding(
                        "RA005", node,
                        f"engine state written outside EngineDriver "
                        f"({_dotted(base) or 'engine'}): route mutations "
                        f"through the drive-thread op() queue")
        elif isinstance(node, ast.Call):
            f = node.func
            if (isinstance(f, ast.Attribute) and f.attr in _ENGINE_MUTATORS
                    and _engine_expr(f.value) and not inside_driver(node)):
                yield mod.finding(
                    "RA005", node,
                    f"engine.{f.attr}() called outside EngineDriver: "
                    f"mutating calls race the in-flight decode step — "
                    f"enqueue through the driver instead")


# ---------------------------------------------------------------- RA006 ----

def _inplace_mutated_attrs(tree: ast.AST) -> set:
    """Attribute names the module mutates in place: ``X.attr[...] = v`` /
    ``X.attr[...] += v`` / ``X.attr += v``."""
    out = set()
    for node in ast.walk(tree):
        targets = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AugAssign):
            targets = [node.target]
        for t in targets:
            if isinstance(t, ast.Subscript) and isinstance(t.value, ast.Attribute):
                out.add(t.value.attr)
            elif isinstance(node, ast.AugAssign) and isinstance(t, ast.Attribute):
                out.add(t.attr)
    return out


@_rule("RA006",
       "no jnp.asarray aliasing of host buffers mutated in place (launch/)",
       paths=("src/repro/launch/*",))
def _check_ra006(mod: LintModule) -> Iterator[Finding]:
    mutated = _inplace_mutated_attrs(mod.tree)
    if not mutated:
        return
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if not (isinstance(f, ast.Attribute) and f.attr == "asarray"
                and isinstance(f.value, ast.Name) and f.value.id == "jnp"):
            continue
        if not node.args:
            continue
        arg = node.args[0]
        if isinstance(arg, ast.Attribute) and arg.attr in mutated:
            yield mod.finding(
                "RA006", node,
                f"jnp.asarray({_dotted(arg)}) may alias the host buffer "
                f"(zero-copy) while .{arg.attr} is mutated in place "
                f"elsewhere — snapshot with .copy() first (PR-4 lens race)")


# ---------------------------------------------------------------- driver ----

def lint_source(source: str, rel: str,
                rules: Optional[Iterable[str]] = None) -> List[Finding]:
    """Lint one source string as repo-relative file ``rel``.

    ``rules=None`` applies every rule whose path scope matches ``rel``;
    an explicit rule list forces those rules regardless of scope (fixture
    tests use this).
    """
    mod = LintModule(rel, source)
    out: List[Finding] = []
    if rules is None:
        active = [r for r in RULES.values() if r.applies(rel)]
    else:
        active = [RULES[rid] for rid in rules]
    for r in active:
        out.extend(r.check(mod))
    return out


def lint_repo(root: str, files: Optional[Iterable[str]] = None) -> List[Finding]:
    """Lint the repo at ``root`` (or just ``files``, repo-relative)."""
    rootp = pathlib.Path(root)
    if files is None:
        files = []
        for d in SCAN_DIRS:
            base = rootp / d
            if base.is_dir():
                files.extend(
                    str(p.relative_to(rootp)) for p in sorted(base.rglob("*.py"))
                    if "__pycache__" not in p.parts)
    out: List[Finding] = []
    for rel in files:
        rel = str(pathlib.PurePosixPath(rel))
        out.extend(lint_source((rootp / rel).read_text(), rel))
    out.sort(key=lambda f: (f.path, f.line, f.rule))
    return out
