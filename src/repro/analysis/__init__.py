"""repro.analysis — mechanical precision-contract checking (DESIGN.md §15).

Two passes over two representations of the same program:

* ``repro.analysis.lint`` — AST repo-invariant linter (rules RA001-RA006,
  ruff-style registry, per-line ``# repro: noqa=RULE`` suppression).  The
  invariants are the ones nearly every PR's review round has fixed by hand:
  linear call sites missing ``path=``, ``time.time()`` on perf paths,
  untagged stdout in ``launch/``, ``np.savez`` GIL stalls, engine mutation
  off the drive thread, ``jnp.asarray`` aliasing of mutated host buffers.
* ``repro.analysis.jaxpr_audit`` — numerics auditor over traced jaxprs
  (hazards JP001-JP006): raw posit-code tensors reaching float arithmetic,
  float ``dot_general`` at quire-declared sites, encode->decode round-trip
  churn, f32->bf16 narrowing upstream of a reduction, ``debug_callback``
  baked into the non-probed decode executable, and dead precision-policy
  rules that match no layer.

CLI: ``python -m repro.analysis [--json out.json] [--policy P] [--baseline
b.json]`` — exits nonzero on new unsuppressed findings.
"""
from repro.analysis.base import (Finding, load_baseline, new_findings,
                                 save_baseline)
from repro.analysis.lint import RULES, lint_repo, lint_source, stdout_kinds

__all__ = [
    "Finding", "RULES", "lint_repo", "lint_source", "stdout_kinds",
    "load_baseline", "save_baseline", "new_findings",
]
