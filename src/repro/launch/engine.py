"""Slot-based continuous-batching serving engine over the ragged KV cache.

The decode batch is a fixed grid of ``max_slots`` slots sharing one model
cache (``model.init_cache(max_slots, S_max, policy)``).  Requests arrive on a
queue (Poisson arrivals in the benchmark driver), are *prefilled into a free
slot* the moment one exists (B=1 prefill, then a scatter of that row into the
batch cache — no other slot is touched or stalled), decode lockstep as one
batch while each row masks by its own ``len``, and are evicted (slot recycled)
on EOS or max-length.  This is exactly the memory-system serving shape the
posit KV cache is for: decode attention is HBM-bound, the cache stores 8/16-bit
posit codes, and the flash-decode kernel path decodes tiles on the fly
(``TransPolicy.attn_impl``, DESIGN.md §10).

The engine is model-agnostic over the decoder families (dense / moe / gemma3 /
vlm / zamba / xlstm): anything ``build_model`` returns with a ``prefill`` entry
point.  Greedy decoding is ``temperature=0``; otherwise temperature / top-k
sampling with a per-engine PRNG key.

Timing note: prefill compiles once per distinct prompt length — drivers that
care about compile time should draw prompt lengths from a small set (the
benchmark uses a handful of buckets).

Observability (DESIGN.md §12): pass ``metrics=`` (a
``repro.obs.MetricsRegistry``), ``tracer=`` (a ``TraceRecorder``) and/or
``numerics=`` (a ``NumericsWatcher``) and the engine feeds them per step and
per request — slot occupancy, admission/eviction counters by reason,
queue/TTFT/per-token latency histograms, decode-step durations, KV-byte
utilization, a rolling tokens/s window, Chrome-trace request spans, and
cadenced numerical-health probes.  All three default to ``None`` and cost
nothing when absent.  The numerics probe works by compiling a *second*
decode executable traced under the watcher's observer (``jax.debug.callback``
hooks bake in at trace time, so the ordinary decode step stays callback-free)
and routing every ``numerics.every``-th step through it.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.trace import annotate

#: Drift checks run every this-many probed steps (each check is a few numpy
#: ops per site on (NBINS,) vectors — cheap, but not per-step cheap).
_CHECK_EVERY_PROBES = 16

#: Wire-schema version of Request / Completion JSON.  Snapshots, the HTTP
#: request plane (launch/server.py), and tests all speak this one schema;
#: ``from_json`` rejects any other version loudly instead of best-effort
#: parsing a shape this build never saw.  Dicts without a ``"v"`` key are
#: read as v1 (pre-versioning snapshots).
SCHEMA_VERSION = 1


def _check_schema_version(d: dict, what: str) -> None:
    v = int(d.get("v", 1))
    if v != SCHEMA_VERSION:
        raise ValueError(
            f"{what} JSON declares schema v{v}; this build speaks only "
            f"v{SCHEMA_VERSION} (refusing to guess at an unknown shape)")


@dataclasses.dataclass
class Request:
    """One generation request."""
    rid: int
    prompt: np.ndarray              # (prompt_len,) int32 token ids
    max_new_tokens: int = 16
    arrival_time: float = 0.0       # seconds since engine start
    deadline_s: Optional[float] = None  # wall-clock budget from admission

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    def to_json(self) -> dict:
        return {"v": SCHEMA_VERSION,
                "rid": self.rid, "prompt": np.asarray(self.prompt).tolist(),
                "max_new_tokens": self.max_new_tokens,
                "arrival_time": self.arrival_time,
                "deadline_s": self.deadline_s}

    @classmethod
    def from_json(cls, d: dict) -> "Request":
        _check_schema_version(d, "Request")
        return cls(rid=int(d["rid"]),
                   prompt=np.asarray(d["prompt"], np.int32),
                   max_new_tokens=int(d["max_new_tokens"]),
                   arrival_time=float(d["arrival_time"]),
                   deadline_s=d.get("deadline_s"))


@dataclasses.dataclass
class Completion:
    """Per-request serving record (tokens + latency breakdown)."""
    rid: int
    prompt_len: int
    tokens: list                    # generated token ids (includes EOS if hit)
    arrival_time: float
    admitted_time: float
    finished_time: float
    token_times: list               # absolute emission time of each token
    # eos | max_new | cache_full | cancel | numerics | timeout
    finish_reason: str = ""

    @property
    def queue_s(self) -> float:
        return self.admitted_time - self.arrival_time

    @property
    def ttft_s(self) -> float:
        """Time to first token (arrival -> first sampled token)."""
        return self.token_times[0] - self.arrival_time

    def per_token_s(self) -> list:
        """Inter-token latencies (first token measured from admission)."""
        starts = [self.admitted_time] + self.token_times[:-1]
        return [t - s for s, t in zip(starts, self.token_times)]

    def to_json(self) -> dict:
        return {"v": SCHEMA_VERSION, **dataclasses.asdict(self)}

    @classmethod
    def from_json(cls, d: dict) -> "Completion":
        _check_schema_version(d, "Completion")
        d = {k: v for k, v in d.items() if k != "v"}
        return cls(**d)


def poisson_requests(n: int, *, arrival_rate: float, prompt_lens=(16, 24, 32),
                     max_new_tokens: int = 16, vocab: int = 32000,
                     seed: int = 0) -> list:
    """n requests with exponential inter-arrival times (rate = req/s).

    ``arrival_rate <= 0`` means everything arrives at t=0 (closed-loop /
    offline batch).  Prompt lengths cycle through ``prompt_lens`` buckets so
    prefill compiles a bounded number of programs.
    """
    rng = np.random.default_rng(seed)
    t = 0.0
    reqs = []
    for i in range(n):
        if arrival_rate > 0:
            t += float(rng.exponential(1.0 / arrival_rate))
        plen = int(prompt_lens[i % len(prompt_lens)])
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, vocab, (plen,)).astype(np.int32),
            max_new_tokens=max_new_tokens, arrival_time=t))
    return reqs


#: Cache containers whose ``k``/``v`` leaves are KV code arrays (shared with
#: serve.py's byte accounting and the ft fault-injection plane).
KV_CONTAINERS = ("kv", "shared_kv", "self", "cross")


def _slot_index(leaf, slot):
    """Index tuple selecting row ``slot`` of a KV leaf.

    KV code arrays come in two layouts: ``(B, H, S, hd)`` (per-layer list —
    gemma3 / zamba shared_kv / encdec) and ``(L, B, H, S, hd)`` (a vmapped
    layer stack).  The batch axis is 0 or 1 by rank.
    """
    return (slice(None), slot) if leaf.ndim == 5 else (slot,)


def map_kv_rows(cache, fn):
    """Apply ``fn(path_keys, leaf)`` to every K/V code leaf; other leaves
    pass through.  The traversal knows which leaves are KV (``k``/``v``
    inside a KV container) so callers (slot scrub, NaR fault injection)
    don't re-derive the cache layout."""
    def visit(path, leaf):
        keys = [p.key for p in path if hasattr(p, "key")]
        if keys and keys[-1] in ("k", "v") \
                and any(k in KV_CONTAINERS for k in keys[:-1]):
            return fn(keys, leaf)
        return leaf
    return jax.tree_util.tree_map_with_path(visit, cache)


def scrub_slot(cache, slot: int):
    """Zero the KV rows of ``slot`` (quarantine): a slot evicted for
    nonfinite logits leaves NaR codes in its cache rows, and the decode grid
    keeps computing over *every* row — without the scrub the dead row would
    feed NaN activations into the numerics probes forever (and re-trip the
    degradation ladder on healthy traffic).  Code 0 decodes to exact 0.0 in
    every posit format, so the scrubbed row is numerically inert."""
    def zero(keys, leaf):
        return leaf.at[_slot_index(leaf, slot)].set(
            jnp.zeros((), leaf.dtype))
    return map_kv_rows(cache, zero)


def _write_slot(full, one, slot):
    """Scatter row 0 of the B=1 cache ``one`` into row ``slot`` of ``full``.

    The batch axis of each leaf is found structurally: it is the unique axis
    where the two shapes differ (the single-request cache was built with the
    same S_max/layout, B=1).  Leaves with identical shapes (the scalar
    ``pos`` counter) are shared state the engine manages itself and are left
    untouched.
    """
    def wr(f, o):
        if f.shape == o.shape:
            return f
        axes = [i for i, (a, b) in enumerate(zip(f.shape, o.shape)) if a != b]
        if len(axes) != 1 or o.shape[axes[0]] != 1:
            raise ValueError(
                f"ambiguous batch axis for cache leaf {f.shape} vs {o.shape}")
        return jax.lax.dynamic_update_slice_in_dim(f, o, slot, axis=axes[0])
    return jax.tree.map(wr, full, one)


def _sample(logits, key, temperature: float, top_k: int):
    """(B, V) logits -> (B,) tokens. temperature==0 is greedy argmax."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    logits = logits / temperature
    if top_k > 0:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1)


class ContinuousBatchingEngine:
    """Admission + decode + eviction over a fixed slot grid.

    Drive it either with :meth:`run` (wall-clock loop honoring request
    arrival times) or manually with :meth:`submit` / :meth:`admit` /
    :meth:`step` (deterministic staggered-admission tests).
    """

    def __init__(self, model, params, policy, *, max_slots: int, S_max: int,
                 eos_id: Optional[int] = None, temperature: float = 0.0,
                 top_k: int = 0, seed: int = 0,
                 prefill_kwargs: Optional[Callable] = None,
                 metrics=None, tracer=None, numerics=None,
                 snapshotter=None, faults=None, watchdog=None,
                 deadline_s: Optional[float] = None,
                 check_every_probes: int = _CHECK_EVERY_PROBES):
        if model.prefill is None:
            raise ValueError(
                f"family {model.cfg.family!r} has no prefill entry point")
        self.model, self.params, self.policy = model, params, policy
        self.max_slots, self.S_max = max_slots, S_max
        self.eos_id, self.temperature, self.top_k = eos_id, temperature, top_k
        # per-arg callable for families needing extra prefill inputs (vlm
        # patch embeds); receives the Request, returns a kwargs dict
        self._prefill_kwargs = prefill_kwargs or (lambda req: {})
        # observability sinks (all optional; None = feature off, zero cost)
        self.metrics, self.tracer, self.numerics = metrics, tracer, numerics
        # fault-tolerance plane (repro.ft.serving, DESIGN.md §13): cadenced
        # crash-safe snapshots, chaos injection under test/bench control, the
        # numerics-driven degradation watchdog, per-request deadlines
        self.snapshotter, self.faults, self.watchdog = \
            snapshotter, faults, watchdog
        self.deadline_s = deadline_s
        self.check_every_probes = check_every_probes
        if tracer is not None:
            tracer.label_track(0, "engine")
            for s in range(max_slots):
                tracer.label_track(s + 1, f"slot {s}")
        self._init_state(seed)
        self._build_executables(policy)
        # the pre-write cache is donated too: admission must not copy the
        # whole S_max cache to update one row
        self._write = jax.jit(_write_slot, donate_argnums=(0,))

    def _build_executables(self, policy) -> None:
        """(Re)build the jitted decode/prefill programs for ``policy``.

        Called at init and by :meth:`apply_policy` when the degradation
        watchdog widens a site's weight format — the KV-cache layout lives on
        the policy *base*, which overlays never touch, so the live cache
        stays valid across a swap.
        """
        model, S_max = self.model, self.S_max
        # the cache is donated: decode updates the KV buffers in place
        # instead of copying S_max-sized arrays every step (the engine never
        # reads a pre-step cache again; on backends without donation support
        # this degrades to the copy)
        self._decode = jax.jit(
            lambda p, t, c: model.decode_step(p, t, c, policy),
            donate_argnums=(2,))
        # the numerics-probed twin: identical computation, but *traced* under
        # the watcher's observer so the per-site debug-callback reductions
        # bake into this executable only — the plain step stays probe-free
        # and the probe cost amortizes over the cadence (DESIGN.md §12)
        self._decode_probed = None
        if self.numerics is not None:
            self._decode_probed = jax.jit(
                lambda p, t, c: model.decode_step(p, t, c, policy),
                donate_argnums=(2,))
        # compiled per distinct prompt length (admission is on the serving
        # critical path; drivers bucket prompt lengths to bound retraces)
        self._prefill = jax.jit(
            lambda p, toks, kw: model.prefill(p, toks, policy,
                                              S_max=S_max, **kw))

    def apply_policy(self, policy) -> None:
        """Swap the serving policy mid-flight (degradation ladder step).

        Only weight-format overlays are legal: the KV-cache format must be
        unchanged, or the live cache's code arrays would be reinterpreted
        under the wrong codec.
        """
        old_kv = getattr(self.policy, "kv_cache", None)
        new_kv = getattr(policy, "kv_cache", None)
        if (old_kv is None) != (new_kv is None) or \
                (old_kv is not None and old_kv.name != new_kv.name):
            raise ValueError(
                f"apply_policy may not change the KV-cache format "
                f"({old_kv} -> {new_kv}); only weight overlays are hot-"
                f"swappable")
        self.policy = policy
        self._build_executables(policy)

    def _init_cache(self):
        """Device-cache construction hook (the paged engine builds block
        pools + a block table here instead of the dense slot grid)."""
        return self.model.init_cache(self.max_slots, self.S_max, self.policy)

    def _init_state(self, seed: int) -> None:
        self._key = jax.random.key(seed)
        self.cache = self._init_cache()
        self.lens = np.zeros((self.max_slots,), np.int32)
        self.last_token = jnp.zeros((self.max_slots,), jnp.int32)
        self.active = np.zeros((self.max_slots,), bool)
        self.slot_req: list = [None] * self.max_slots
        self.slot_tokens: list = [[] for _ in range(self.max_slots)]
        self.slot_token_times: list = [[] for _ in range(self.max_slots)]
        self.slot_admitted = np.zeros((self.max_slots,), np.float64)
        self.queue: list = []          # pending Requests (FIFO)
        self.completions: list = []
        # rid -> [queue.Queue] of live stream subscribers (transient client
        # state: never snapshotted, cleared by reset)
        self._subs: dict = {}
        self.steps = 0                 # decode steps executed
        self.last_now = 0.0            # newest clock value seen (snapshots
        #                                rebase restored timestamps on it)
        # rolling decode-rate window (created lazily; survives _init_state
        # only via the registry's own histograms — the window restarts)
        self._tok_rate = None
        if self.metrics is not None:
            from repro.obs.metrics import RollingRate
            self._tok_rate = RollingRate(window_s=10.0)
            # pre-resolved instrument handles: _observe_step runs per decode
            # step, so it must not pay registry lookups / bucket construction
            m = self.metrics
            self._m_steps = m.counter("decode_steps", "decode steps executed")
            self._m_tokens = m.counter("tokens_emitted",
                                       "sampled tokens (prefill + decode)")
            self._m_step_s = m.histogram("decode_step_s",
                                         "wall time of one grid step")
            self._m_slots = m.histogram(
                "slots_active", "live slots per decode step",
                buckets=tuple(float(b) for b in range(1, self.max_slots + 1)))
            self._m_occ = m.gauge("slot_occupancy", "live slots / max_slots")
            self._m_kv = m.gauge("kv_utilization",
                                 "occupied KV rows / allocated rows")
            self._m_queue = m.gauge("queue_depth", "requests waiting")
            self._m_rate = m.gauge("decode_tok_per_s_window",
                                   "tokens/s over the rolling 10s window")
            self._m_recal = m.gauge(
                "numerics_recalibrate",
                "1 when activation drift exceeded threshold")

    def reset(self, seed: int = 0) -> None:
        """Clear all serving state but keep the compiled decode/write programs.

        Equivalence tests use this to run staggered-admission and
        single-request workloads through the *same executables*: XLA:CPU
        compiles are not bit-stable across program instances, so comparing
        tokens across two engines (or against a hand-rolled B=1 loop) can
        flip a near-tied greedy argmax; within one engine the comparison is
        deterministic."""
        self._init_state(seed)

    # ----------------------------------------------------- snapshot/restore --
    def snapshot(self) -> dict:
        """Full engine state as ``{"arrays": pytree, "meta": json-able}``.

        ``arrays`` is everything device-resident (the ragged posit KV cache,
        per-slot last tokens, the sampler PRNG key as raw key data) — a
        checkpointable pytree.  ``meta`` is the host bookkeeping: slot grid
        (lens, active, admitted stamps), emitted-token buffers, the in-flight
        request per slot, the pending queue, finished completions, and the
        step/probe counters.  Together they are sufficient for
        :meth:`restore` to continue every stream **bit-identically** (same
        policy + same executables + same RNG ⇒ same tokens — posit codecs
        are deterministic, so the restored KV codes replay exactly).
        """
        meta = {
            "version": 1,
            "steps": self.steps,
            "last_now": self.last_now,
            "lens": self.lens.tolist(),
            "active": [bool(a) for a in self.active],
            "slot_admitted": self.slot_admitted.tolist(),
            "slot_tokens": [list(t) for t in self.slot_tokens],
            "slot_token_times": [list(t) for t in self.slot_token_times],
            "slots": [r.to_json() if r is not None else None
                      for r in self.slot_req],
            "queue": [r.to_json() for r in self.queue],
            "completions": [c.to_json() for c in self.completions],
            "probes": self.numerics.probes if self.numerics else 0,
            # config fingerprint: restore asserts these match, a snapshot
            # taken under one policy must not silently continue under another
            "max_slots": self.max_slots,
            "S_max": self.S_max,
            "policy": self.policy.describe(),
            "temperature": self.temperature,
            "top_k": self.top_k,
        }
        # host copies, not live references: the decode step DONATES the cache
        # buffers, so a snapshot holding device references would be silently
        # invalidated by the very next step (np.array forces the copy)
        arrays = jax.tree.map(np.array, {
            "cache": self.cache,
            "last_token": self.last_token,
            "rng_key": jax.random.key_data(self._key),
        })
        return {"arrays": arrays, "meta": meta}

    def snapshot_like(self) -> dict:
        """The arrays pytree a checkpoint restore deserializes into (same
        structure/shapes/dtypes as :meth:`snapshot`'s ``arrays``)."""
        return {"cache": self.cache, "last_token": self.last_token,
                "rng_key": jax.random.key_data(self._key)}

    def restore(self, snap: dict, *, now: float = 0.0) -> None:
        """Install a :meth:`snapshot` (possibly loaded from disk).

        ``now`` rebases every restored timestamp so deadlines and latency
        accounting keep working across a process restart: the shift maps the
        snapshot's ``last_now`` onto the restoring clock's ``now``.
        """
        meta, arrays = snap["meta"], snap["arrays"]
        if (meta["max_slots"], meta["S_max"]) != (self.max_slots, self.S_max):
            raise ValueError(
                f"snapshot grid ({meta['max_slots']} slots, S_max "
                f"{meta['S_max']}) does not match this engine "
                f"({self.max_slots}, {self.S_max})")
        if meta["policy"] != self.policy.describe():
            raise ValueError(
                "snapshot policy does not match this engine's policy:\n"
                f"  snapshot: {meta['policy']}\n"
                f"  engine:   {self.policy.describe()}\n"
                "bit-identical continuation requires the same policy")
        shift = now - float(meta.get("last_now", 0.0))
        self.cache = jax.tree.map(jnp.asarray, arrays["cache"])
        self.last_token = jnp.asarray(arrays["last_token"], jnp.int32)
        self._key = jax.random.wrap_key_data(
            jnp.asarray(arrays["rng_key"], jnp.uint32))
        self.steps = int(meta["steps"])
        self.last_now = now
        self.lens = np.asarray(meta["lens"], np.int32)
        self.active = np.asarray(meta["active"], bool)
        self.slot_admitted = np.asarray(meta["slot_admitted"], np.float64) \
            + shift
        self.slot_tokens = [list(t) for t in meta["slot_tokens"]]
        self.slot_token_times = [[t + shift for t in ts]
                                 for ts in meta["slot_token_times"]]
        # requests carry arrival_time too — deadlines and queue-latency
        # accounting measure from it, so it rebases like every other stamp
        def _req(r):
            req = Request.from_json(r)
            req.arrival_time += shift
            return req
        self.slot_req = [_req(r) if r is not None else None
                         for r in meta["slots"]]
        self.queue = [_req(r) for r in meta["queue"]]
        self.completions = [Completion.from_json(c)
                            for c in meta["completions"]]
        if self.numerics is not None:
            self.numerics.probes = int(meta.get("probes", 0))
        self._sync_lens()
        if self.metrics is not None:
            self.metrics.counter(
                "engine_restores", "snapshots restored into the engine").inc()

    # ---------------------------------------------------------- client API ----
    # The stable engine client surface (DESIGN.md §14): submit() -> rid,
    # results()/result(rid) for finished work, subscribe()/stream(rid) for
    # live token streams, cancel(rid).  The HTTP plane (launch/server.py),
    # benchmarks, and tests all drive the engine through these five.

    def submit(self, req: Request) -> int:
        """Enqueue a request; returns its rid (the stream/cancel handle)."""
        self.queue.append(req)
        return req.rid

    def results(self) -> list:
        """All finished Completions, in finish order."""
        return list(self.completions)

    def result(self, rid: int):
        """The Completion for ``rid``, or None while still in flight."""
        for c in self.completions:
            if c.rid == rid:
                return c
        return None

    def subscribe(self, rid: int):
        """A ``queue.Queue`` of stream events for ``rid``.

        Events are dicts: ``{"event": "token", "rid", "token", "index",
        "t"}`` per emitted token, then one ``{"event": "finish", "rid",
        "finish_reason", "n_tokens"}``.  Anything already emitted (or a
        finished request) is replayed first, so a subscriber attached late
        sees the complete stream.  Queues are thread-safe: the serving
        thread puts, a client thread gets.
        """
        import queue as queue_mod
        q = queue_mod.Queue()
        for slot in range(self.max_slots):
            r = self.slot_req[slot]
            if self.active[slot] and r is not None and r.rid == rid:
                for i, (tok, t) in enumerate(zip(self.slot_tokens[slot],
                                                 self.slot_token_times[slot])):
                    q.put({"event": "token", "rid": rid, "token": tok,
                           "index": i, "t": t})
        for c in self.completions:
            if c.rid == rid:
                for i, (tok, t) in enumerate(zip(c.tokens, c.token_times)):
                    q.put({"event": "token", "rid": rid, "token": tok,
                           "index": i, "t": t})
                q.put({"event": "finish", "rid": rid,
                       "finish_reason": c.finish_reason,
                       "n_tokens": len(c.tokens)})
        self._subs.setdefault(rid, []).append(q)
        return q

    def unsubscribe(self, rid: int, q) -> None:
        subs = self._subs.get(rid)
        if subs and q in subs:
            subs.remove(q)
            if not subs:
                del self._subs[rid]

    def stream(self, rid: int, timeout: Optional[float] = None):
        """Blocking generator over :meth:`subscribe` events; ends after the
        finish event.  Drive the engine from another thread (or interleave
        ``admit``/``step`` with consumption); the asyncio server bridges
        this into ``async for`` via a worker thread."""
        q = self.subscribe(rid)
        try:
            while True:
                ev = q.get(timeout=timeout)
                yield ev
                if ev["event"] == "finish":
                    return
        finally:
            self.unsubscribe(rid, q)

    def _emit_token(self, slot: int, tok: int, t: float) -> None:
        self.slot_tokens[slot].append(tok)
        self.slot_token_times[slot].append(t)
        rid = self.slot_req[slot].rid
        for q in self._subs.get(rid, ()):
            q.put({"event": "token", "rid": rid, "token": tok,
                   "index": len(self.slot_tokens[slot]) - 1, "t": t})

    def _finish(self, comp: Completion) -> None:
        self.completions.append(comp)
        for q in self._subs.get(comp.rid, ()):
            q.put({"event": "finish", "rid": comp.rid,
                   "finish_reason": comp.finish_reason,
                   "n_tokens": len(comp.tokens)})

    # ------------------------------------------------------------- admission --
    def free_slots(self) -> list:
        return [i for i in range(self.max_slots) if not self.active[i]]

    def _can_admit(self, req: Request) -> bool:
        """Beyond a free slot, can the cache take this request right now?
        The slot grid always can (every slot owns S_max rows); the paged
        engine gates on block availability (queueing is the backpressure)."""
        return True

    def _prefill_into_slot(self, req: Request, slot: int):
        """Prefill ``req`` and install its KV into ``slot``; returns
        ``(logits, row_len)``.  The paged engine overrides this with
        prefix-matched block admission."""
        tokens = jnp.asarray(req.prompt, jnp.int32)[None]
        with annotate("repro.prefill"):
            logits, one_cache = self._prefill(
                self.params, tokens, self._prefill_kwargs(req))
        # true cache occupancy after prefill (vlm rows include the patch
        # prefix; recurrent families report their prompt length)
        row_len = int(one_cache["lens"][0])
        if self.max_slots == 1:
            # every leaf shape matches the B=1 prefill cache, so the
            # structural scatter below would be a silent no-op — the
            # single-request cache *is* the batch cache
            self.cache = one_cache
        else:
            self.cache = self._write(self.cache, one_cache,
                                     jnp.int32(slot))
        return logits, row_len

    def admit(self, now: float = 0.0, clock: Optional[Callable] = None) -> int:
        """Prefill queued requests into free slots; returns #admitted.

        The first token of each admitted request is sampled from the prefill
        logits immediately (it is emitted by this call, not by the next
        decode step).  ``clock`` (when given) re-reads the time after the
        prefill executes so the first token's emission time — and therefore
        TTFT — includes prefill cost; without it both stamps use ``now``.
        """
        admitted = 0
        for slot in self.free_slots():
            if not self.queue:
                break
            if not self._can_admit(self.queue[0]):
                break       # FIFO: later requests must not starve the head
            req = self.queue.pop(0)
            t_admit = clock() if clock else now
            if req.prompt_len + req.max_new_tokens > self.S_max:
                raise ValueError(
                    f"request {req.rid}: prompt {req.prompt_len} + "
                    f"max_new {req.max_new_tokens} exceeds S_max {self.S_max}")
            logits, row_len = self._prefill_into_slot(req, slot)
            if row_len + req.max_new_tokens > self.S_max:
                raise ValueError(
                    f"request {req.rid}: prefill occupies {row_len} cache "
                    f"rows (incl. any prefix) + max_new "
                    f"{req.max_new_tokens} exceeds S_max {self.S_max}")
            tok = int(self._next_token(logits)[0])  # blocks on the prefill
            t_first = clock() if clock else now
            self.lens[slot] = row_len
            self.last_token = self.last_token.at[slot].set(tok)
            self.active[slot] = True
            self.slot_req[slot] = req
            self.slot_tokens[slot] = []
            self.slot_token_times[slot] = []
            self.slot_admitted[slot] = t_admit
            self._emit_token(slot, tok, t_first)
            self._sync_lens()
            admitted += 1
            if self.metrics is not None:
                self.metrics.counter(
                    "requests_admitted",
                    "requests prefilled into a slot").inc()
                self.metrics.counter("tokens_emitted",
                                     "sampled tokens (prefill + decode)").inc()
                self.metrics.histogram(
                    "queue_s", "arrival -> admission wait").observe(
                        t_admit - req.arrival_time)
                self.metrics.histogram(
                    "prefill_s", "admission -> first token").observe(
                        t_first - t_admit)
                self.metrics.histogram(
                    "ttft_s", "arrival -> first token").observe(
                        t_first - req.arrival_time)
                if self._tok_rate is not None:
                    self._tok_rate.add(t_first)
            if self.tracer is not None:
                tid = slot + 1
                self.tracer.span(f"queued rid={req.rid}", req.arrival_time,
                                 t_admit, tid=tid,
                                 args={"rid": req.rid,
                                       "prompt_len": req.prompt_len})
                self.tracer.span(f"prefill rid={req.rid}", t_admit, t_first,
                                 tid=tid, args={"rid": req.rid})
            self._maybe_finish(slot, tok, t_first)  # max_new_tokens == 1
        return admitted

    def _next_token(self, logits):
        self._key, sub = jax.random.split(self._key)
        return _sample(logits, sub, self.temperature, self.top_k)

    def _sync_lens(self) -> None:
        """Engine slot lengths are authoritative: push them into the cache's
        per-row positions (freed/recycled slots reset; decode_step increments
        every row, active or not).

        The copy is load-bearing: ``jnp.asarray`` of a host numpy array can
        be zero-copy on CPU, and ``self.lens`` is mutated in place every
        step — an aliased buffer races with the async decode dispatch."""
        self.cache["lens"] = jnp.asarray(self.lens.copy(), jnp.int32)

    # --------------------------------------------------------------- decode ---
    def step(self, now: float = 0.0) -> int:
        """One decode step over the whole slot grid; returns #tokens emitted."""
        self.last_now = max(self.last_now, now)
        if self.faults is not None:
            # chaos layer (repro.ft.serving.FaultPlan): may stall, inject
            # NaR into KV pages, or raise preemption — before the decode so
            # an injected fault is live in THIS step's computation
            self.faults.on_step(self)
        self._evict_expired(now)
        if not self.active.any():
            return 0
        self._prepare_decode(now)
        if not self.active.any():   # pool pressure may have evicted the rest
            return 0
        t0 = time.perf_counter()
        probed = (self.numerics is not None
                  and self.numerics.should_probe(self.steps))
        if probed:
            # trace-time observer installation: the first probed call bakes
            # the per-site reduction callbacks into _decode_probed only
            with self.numerics.observing(), annotate("repro.decode_probed"):
                logits, self.cache = self._decode_probed(
                    self.params, self.last_token, self.cache)
            self.numerics.note_probe()
        else:
            with annotate("repro.decode_step"):
                logits, self.cache = self._decode(self.params, self.last_token,
                                                  self.cache)
        self.steps += 1
        toks = self._next_token(logits)
        # nonfinite-logit quarantine (watchdog only — the reduction is an
        # extra device op per step, so the bare engine never pays it): a slot
        # whose logits went NaR is evicted as a partial Completion instead of
        # sampling garbage, and its cache rows are scrubbed so the dead row
        # cannot poison the shared grid or the numerics probes
        bad = None
        if self.watchdog is not None:
            bad = np.asarray(jnp.any(~jnp.isfinite(logits), axis=-1))
        self.lens += 1          # mirror decode_step's per-row increment
        emitted = 0
        toks_np = np.asarray(toks)
        last_np = np.asarray(self.last_token).copy()
        for slot in range(self.max_slots):
            if not self.active[slot]:
                continue
            if bad is not None and bad[slot]:
                self._quarantine(slot, now)
                last_np[slot] = 0
                continue
            tok = int(toks_np[slot])
            self._emit_token(slot, tok, now)
            last_np[slot] = tok
            emitted += 1
            self._maybe_finish(slot, tok, now)
        self.last_token = jnp.asarray(last_np)
        self._observe_step(now, t0, emitted, probed)
        if self.snapshotter is not None:
            self.snapshotter.on_step(self)
        return emitted

    def _prepare_decode(self, now: float) -> None:
        """Pre-step cache maintenance hook.  The slot grid needs none; the
        paged engine allocates block-boundary pages, runs copy-on-write on
        shared tails, and refreshes the device block table here."""

    def _quarantine(self, slot: int, now: float) -> None:
        """Evict a nonfinite-logit slot and neutralize its KV so the dead
        rows cannot poison the shared grid or the numerics probes."""
        self._evict(slot, now, "numerics")
        self.cache = scrub_slot(self.cache, slot)

    def _release_slot(self, slot: int) -> None:
        """Per-eviction cache cleanup hook (the slot grid reuses rows as-is;
        the paged engine drops the slot's block references)."""

    def inject_nar_into(self, slot: int, count: int) -> None:
        """Chaos hook: poison the first ``count`` occupied KV positions of
        ``slot`` with NaR codes (``ft.FaultPlan`` dispatches here so the
        cache layout stays with the engine that owns it)."""
        from repro.ft.serving import _nar_code

        n = max(1, min(count, max(int(self.lens[slot]), 1)))

        def poison(keys, leaf):
            idx = _slot_index(leaf, slot)
            row = leaf[idx]                 # (..., H, S, hd) or (H, S, hd)
            s_ax = row.ndim - 2             # sequence axis of the row
            sl = [slice(None)] * row.ndim
            sl[s_ax] = slice(0, n)
            row = row.at[tuple(sl)].set(_nar_code(leaf))
            return leaf.at[idx].set(row)
        self.cache = map_kv_rows(self.cache, poison)

    def _deadline_of(self, req) -> Optional[float]:
        return req.deadline_s if req.deadline_s is not None else self.deadline_s

    def _evict_expired(self, now: float) -> None:
        """Per-request wall-clock deadline enforcement (measured from
        arrival): expired in-flight slots are evicted as partial Completions
        with ``finish_reason="timeout"``; expired queued requests are
        retired without ever being admitted."""
        for slot in range(self.max_slots):
            if not self.active[slot]:
                continue
            d = self._deadline_of(self.slot_req[slot])
            if d is not None and now - self.slot_req[slot].arrival_time > d:
                self._evict(slot, now, "timeout")
        kept = []
        for req in self.queue:
            d = self._deadline_of(req)
            if d is not None and now - req.arrival_time > d:
                self._finish(Completion(
                    rid=req.rid, prompt_len=req.prompt_len, tokens=[],
                    arrival_time=req.arrival_time, admitted_time=now,
                    finished_time=now, token_times=[],
                    finish_reason="timeout"))
                if self.metrics is not None:
                    self.metrics.counter(
                        "requests_finished",
                        "requests retired, by reason").inc(label="timeout")
            else:
                kept.append(req)
        self.queue = kept

    def _observe_step(self, now: float, t0: float, emitted: int,
                      probed: bool) -> None:
        """Per-step metrics/trace feed (no device syncs beyond what step()
        already does — ``np.asarray(toks)`` blocked on the decode)."""
        if self.numerics is not None and probed \
                and self.numerics.probes % self.check_every_probes == 0:
            self.numerics.check()
            if self.watchdog is not None:
                # degradation controller (repro.ft.serving): reads the fresh
                # SiteHealth rows, may widen formats via apply_policy
                self.watchdog.maybe_degrade(self)
        if self.metrics is not None:
            dt = time.perf_counter() - t0
            n_active = int(self.active.sum())
            self._m_steps.inc()
            self._m_tokens.inc(emitted)
            self._m_step_s.observe(dt)
            self._m_slots.observe(n_active)
            self._m_occ.set(n_active / self.max_slots)
            self._m_kv.set(int(self.lens.sum())
                           / (self.max_slots * self.S_max))
            self._m_queue.set(len(self.queue))
            self._tok_rate.add(now, emitted)
            self._m_rate.set(self._tok_rate.rate(now))
            if self.numerics is not None:
                self._m_recal.set(float(self.numerics.recalibrate))
        if self.tracer is not None:
            self.tracer.span("decode_step", t0, time.perf_counter(),
                             tid=0, args={"emitted": emitted,
                                          "probed": probed})

    def _maybe_finish(self, slot: int, tok: int, now: float) -> bool:
        req = self.slot_req[slot]
        # precedence: EOS is the semantic finish; max_new the requested cap;
        # cache_full the forced eviction (only reachable when neither hit)
        reason = ""
        if self.eos_id is not None and tok == self.eos_id:
            reason = "eos"
        elif len(self.slot_tokens[slot]) >= req.max_new_tokens:
            reason = "max_new"
        elif self.lens[slot] + 1 >= self.S_max:  # no room for another write
            reason = "cache_full"
        if reason:
            self._evict(slot, now, reason)
        return bool(reason)

    def _evict(self, slot: int, now: float, reason: str) -> None:
        """Retire ``slot``: record the Completion, free the slot, feed sinks."""
        req = self.slot_req[slot]
        comp = Completion(
            rid=req.rid, prompt_len=req.prompt_len,
            tokens=list(self.slot_tokens[slot]),
            arrival_time=req.arrival_time,
            admitted_time=float(self.slot_admitted[slot]),
            finished_time=now,
            token_times=list(self.slot_token_times[slot]),
            finish_reason=reason)
        self._finish(comp)
        self.active[slot] = False
        self.slot_req[slot] = None
        self._release_slot(slot)
        if self.metrics is not None:
            m = self.metrics
            m.counter("requests_finished",
                      "requests retired, by reason").inc(label=reason)
            m.histogram("request_s", "admission -> finish").observe(
                now - comp.admitted_time)
            h = m.histogram("inter_token_s", "time between consecutive tokens")
            for dt in comp.per_token_s()[1:]:   # [0] is prefill, not decode
                h.observe(dt)
        if self.tracer is not None:
            tid = slot + 1
            if comp.token_times:
                self.tracer.span(f"decode rid={req.rid}", comp.token_times[0],
                                 now, tid=tid,
                                 args={"rid": req.rid,
                                       "tokens": len(comp.tokens),
                                       "finish_reason": reason})
            self.tracer.instant(f"evict rid={req.rid} ({reason})", now,
                                tid=tid, args={"rid": req.rid,
                                               "reason": reason})

    def cancel(self, rid: int, now: float = 0.0) -> bool:
        """Cancel a request by id — mid-flight (slot evicted, partial tokens
        recorded as a Completion with ``finish_reason="cancel"``) or still
        queued (dropped, no Completion).  Returns True if anything matched."""
        for slot in range(self.max_slots):
            req = self.slot_req[slot]
            if self.active[slot] and req is not None and req.rid == rid:
                self._evict(slot, now, "cancel")
                return True
        for i, req in enumerate(self.queue):
            if req.rid == rid:
                del self.queue[i]
                # no Completion for a never-admitted request, but live
                # streams must still terminate
                for q in self._subs.get(rid, ()):
                    q.put({"event": "finish", "rid": rid,
                           "finish_reason": "cancel", "n_tokens": 0})
                if self.metrics is not None:
                    self.metrics.counter("requests_cancelled_queued",
                                         "cancelled before admission").inc()
                return True
        return False

    # ------------------------------------------------------------------ run ---
    def run(self, requests: list, *, clock: Optional[Callable] = None,
            preemption=None, straggler=None) -> list:
        """Serve ``requests`` (sorted by arrival_time) to completion.

        ``clock`` defaults to wall time from the first call; arrivals are
        honored against it, so with a Poisson workload the decode batch
        genuinely breathes (slots drain and refill mid-flight).

        The loop also drains state already inside the engine — active slots
        and queued requests installed by :meth:`restore` — so a resumed
        process calls ``run([])`` (or ``run(leftover)``) and every in-flight
        stream continues to completion.

        ``preemption`` (a ``ft.PreemptionSignal``) makes the loop drain-then-
        snapshot on SIGTERM: the in-flight step finishes, every not-yet-
        submitted request joins the queue, a forced snapshot commits (when a
        snapshotter is attached), and the loop exits with work left — the
        successor process restores and finishes it.  ``straggler`` (a
        ``ft.StragglerMonitor``) observes per-step wall times and feeds the
        ``straggler_steps`` counter.
        """
        pending = sorted(requests, key=lambda r: r.arrival_time)
        t0 = time.perf_counter()
        clock = clock or (lambda: time.perf_counter() - t0)
        while pending or self.queue or self.active.any():
            now = clock()
            while pending and pending[0].arrival_time <= now:
                self.submit(pending.pop(0))
            if preemption is not None and preemption.triggered:
                # graceful drain: everything not yet submitted joins the
                # queue so the forced snapshot carries the full workload
                for req in pending:
                    self.submit(req)
                pending = []
                self.last_now = max(self.last_now, clock())
                if self.snapshotter is not None:
                    self.snapshotter.force(self)
                if self.metrics is not None:
                    self.metrics.counter(
                        "engine_preemptions",
                        "graceful drain-then-snapshot exits").inc()
                break
            if self.queue and self.free_slots():
                self.admit(clock=clock)
            if self.active.any():
                ts = time.perf_counter()
                self.step(now=clock())
                if straggler is not None \
                        and straggler.observe(time.perf_counter() - ts) \
                        and self.metrics is not None:
                    self.metrics.counter(
                        "straggler_steps",
                        "decode steps slower than the EWMA threshold").inc()
            elif pending:
                # idle: nothing active, next request not yet arrived
                time.sleep(min(0.001, pending[0].arrival_time - now))
        return list(self.completions)
