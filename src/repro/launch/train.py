"""Training driver — CPU-runnable at reduced scale, production flags for pods.

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --reduced \
        --steps 100 --batch 8 --seq 128 --policy p16-train --ckpt-dir /tmp/ck

Wires together every substrate: config -> model -> policy -> data pipeline ->
AdamW (posit moments optional) -> FT loop (async checkpoints, preemption,
straggler monitor, auto-resume) -> observability (DESIGN.md §16):

* ``--telemetry-every N`` compiles a second, *probed* train-step executable
  (``make_train_step(..., telemetry=True)`` traced under the telemetry
  observer) and routes every N-th step through it — gradient + activation
  binade histograms, update/param ratio, nonfinite counts, drift detection
  against ``--calibration`` (or the run's own first window).  Emits
  ``train/telemetry`` per probe and ``train/drift`` when a site latches.
* ``--metrics-out`` writes the metrics-registry JSON snapshot (+ ``.prom``
  Prometheus exposition alongside) merged with the telemetry report.
* ``--trace-out`` writes a Chrome trace of step spans (probes marked).
* ``--profile-out`` runs one profiled step after training and writes the
  per-kernel roofline-attribution report (JSON + ``.md`` table).
* ``--step-log`` appends the bounded per-step JSONL log (off the step path).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.pcsr import TransPolicy
from repro.checkpoint.ckpt import CheckpointManager
from repro.data.pipeline import SyntheticLMPipeline
from repro.ft.runtime import FaultTolerantLoop, PreemptionSignal
from repro.launch.steps import make_train_step
from repro.models.registry import build_model
from repro.optim import AdamWConfig, adamw_init


def _parse_policy(s: str) -> TransPolicy:
    from repro.launch.dryrun import _parse_policy as pp
    return pp(s)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--policy", default="none")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default=None,
                    help="metrics snapshot JSON (+ .prom exposition)")
    ap.add_argument("--trace-out", default=None,
                    help="Chrome trace of step spans")
    ap.add_argument("--profile-out", default=None,
                    help="per-kernel roofline-attribution report (JSON + .md)")
    ap.add_argument("--telemetry-every", type=int, default=0,
                    help="probe cadence for the telemetry twin (0 = off)")
    ap.add_argument("--step-log", default=None,
                    help="bounded per-step JSONL log path")
    ap.add_argument("--calibration", default=None,
                    help="@cal.json artifact for drift baselines "
                         "(default: self-baseline on the first window)")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    policy = _parse_policy(args.policy)
    model = build_model(cfg)
    opt_cfg = AdamWConfig(lr=args.lr, moment_fmt=policy.optimizer)

    pipe = SyntheticLMPipeline(vocab=cfg.vocab, seq_len=args.seq,
                               global_batch=args.batch, seed=args.seed)
    params = model.init(jax.random.key(args.seed))
    opt_state = adamw_init(params, opt_cfg)

    step_kw = dict(warmup=max(args.steps // 10, 1), total_steps=args.steps)
    step_fn_raw = make_train_step(model, policy, opt_cfg, **step_kw)
    jitted = jax.jit(step_fn_raw, donate_argnums=(0, 1))

    # observability sinks (all off by default; DESIGN.md §16)
    telemetry = tracer = jitted_probed = None
    registry = None
    if args.metrics_out or args.telemetry_every:
        from repro.obs.metrics import MetricsRegistry
        registry = MetricsRegistry()
    if args.telemetry_every:
        from repro.obs.train import TrainingTelemetry
        telemetry = TrainingTelemetry(
            policy=policy, baselines=args.calibration,
            every=args.telemetry_every, metrics=registry,
            log_path=args.step_log)
        # the probed twin: telemetry metrics + observer callbacks bake into
        # THIS executable only — the plain step stays callback-free (JP005)
        jitted_probed = jax.jit(
            make_train_step(model, policy, opt_cfg, telemetry=True,
                            **step_kw),
            donate_argnums=(0, 1))
    if args.trace_out:
        from repro.obs.trace import TraceRecorder
        tracer = TraceRecorder()
        tracer.label_track(0, "train steps")

    def make_batch(step):
        b = pipe.batch_at(step)
        if cfg.family == "whisper":
            k = jax.random.fold_in(jax.random.key(args.seed ^ 0xF0), step)
            b["frames"] = jax.random.normal(
                k, (args.batch, cfg.enc_frames, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            k = jax.random.fold_in(jax.random.key(args.seed ^ 0xF1), step)
            b["patch_embeds"] = jax.random.normal(
                k, (args.batch, cfg.n_patches, cfg.d_model), jnp.float32)
        return b

    history = []
    wall0 = time.perf_counter()

    def step_fn(state, step):
        p, o = state["params"], state["opt"]
        batch = make_batch(step)
        probed = telemetry is not None and telemetry.should_probe(step)
        t0 = time.perf_counter()
        if probed:
            with telemetry.observing():
                p, o, metrics = jitted_probed(p, o, batch, jnp.asarray(step))
        else:
            p, o, metrics = jitted(p, o, batch, jnp.asarray(step))
        t1 = time.perf_counter()
        if tracer is not None:
            tracer.span("probed_step" if probed else "step",
                        t0 - wall0, t1 - wall0,
                        args={"step": step})
        if telemetry is not None:
            event = telemetry.on_step(step, metrics, step_s=t1 - t0,
                                      probed=probed)
            if probed:
                print(json.dumps({
                    "kind": "train/telemetry", "step": step,
                    "probes": telemetry.watcher.probes,
                    "checks": telemetry.watcher.checks,
                    "recalibrate": telemetry.recalibrate,
                    "quire_saturation": telemetry.quire_saturation(),
                    "update_ratio": float(metrics["update_ratio"]),
                    "grad_nonfinite": int(metrics["grad_nonfinite"]),
                    "opt_nonfinite": int(metrics["opt_nonfinite"]),
                }), flush=True)
            if event is not None:
                if tracer is not None:
                    tracer.instant("drift", t1 - wall0, args=event)
                print(json.dumps({"kind": "train/drift", "step": step,
                                  **event}), flush=True)
        if step % args.log_every == 0 or step == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            history.append(m)
            print(json.dumps({"kind": "train/step", **m}), flush=True)
        return {"params": p, "opt": o}

    state = {"params": params, "opt": opt_state}
    try:
        if args.ckpt_dir:
            mgr = CheckpointManager(args.ckpt_dir, keep=2,
                                    fmt=policy.checkpoint)
            loop = FaultTolerantLoop(
                ckpt=mgr, save_every=args.save_every,
                preemption=PreemptionSignal(install_sigterm=True))
            state, start = loop.resume(state)
            if start:
                print(f"[resume] from step {start}", file=sys.stderr)
            t0 = time.perf_counter()
            state, nxt = loop.run(state, step_fn, start_step=start,
                                  num_steps=args.steps - start)
            mgr.wait()
            mgr.close()
            print(json.dumps({"kind": "train/done", "done": nxt,
                              "wall_s": round(time.perf_counter() - t0, 1),
                              **loop.stats}))
        else:
            t0 = time.perf_counter()
            for step in range(args.steps):
                state = step_fn(state, step)
            print(json.dumps({"kind": "train/done", "done": args.steps,
                              "wall_s": round(time.perf_counter() - t0, 1)}))

        if args.profile_out:
            _profile_step(args, step_fn_raw, state, make_batch)
    finally:
        # telemetry flushes in finally: a preempted/crashed run must still
        # leave its step log + metrics snapshot on disk for post-mortem
        if telemetry is not None:
            telemetry.close()
        if registry is not None and args.metrics_out:
            if telemetry is not None:
                registry.set_context(telemetry=telemetry.report())
            registry.set_context(arch=cfg.name, policy=policy.describe(),
                                 steps=args.steps, history=history)
            registry.save(args.metrics_out)
            with open(args.metrics_out + ".prom", "w") as f:
                f.write(registry.prometheus())
        if tracer is not None:
            tracer.save(args.trace_out)
    return state


def _profile_step(args, step_fn_raw, state, make_batch):
    """One eagerly-executed profiled step -> roofline-attribution report.

    Eager (un-jitted) on purpose: every kernel entry point dispatches with
    concrete arrays, so the profiler can time each dispatch; sites inside
    the autodiff trace or scanned layer stacks record as ``traced`` with
    analytic cost only (obs/prof.py).
    """
    from repro.obs import prof

    profiler = prof.KernelProfiler()
    with prof.profiling(profiler):
        t0 = time.perf_counter()
        out = step_fn_raw(state["params"], state["opt"],
                          make_batch(args.steps), jnp.asarray(args.steps))
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
    rep = profiler.save(args.profile_out, measured_total_s=dt)
    print(json.dumps({"kind": "train/profile",
                      "profile_out": args.profile_out,
                      "rows": len(rep["rows"]),
                      "dispatches": rep["totals"]["dispatches"],
                      "bytes": rep["totals"]["bytes"],
                      "bound_s": rep["totals"]["bound_s"],
                      "measured_s": round(dt, 4)}), flush=True)


if __name__ == "__main__":
    main()
