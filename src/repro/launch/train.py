"""Training driver — CPU-runnable at reduced scale, production flags for pods.

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --reduced \
        --steps 100 --batch 8 --seq 128 --policy p16-train --ckpt-dir /tmp/ck

Wires together every substrate: config -> model -> policy -> data pipeline ->
AdamW (posit moments optional) -> FT loop (async checkpoints, preemption,
straggler monitor, auto-resume) -> metrics log.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.pcsr import TransPolicy
from repro.checkpoint.ckpt import CheckpointManager
from repro.data.pipeline import SyntheticLMPipeline
from repro.ft.runtime import FaultTolerantLoop, PreemptionSignal
from repro.launch.steps import make_train_step
from repro.models.registry import build_model
from repro.optim import AdamWConfig, adamw_init


def _parse_policy(s: str) -> TransPolicy:
    from repro.launch.dryrun import _parse_policy as pp
    return pp(s)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--policy", default="none")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    policy = _parse_policy(args.policy)
    model = build_model(cfg)
    opt_cfg = AdamWConfig(lr=args.lr, moment_fmt=policy.optimizer)

    pipe = SyntheticLMPipeline(vocab=cfg.vocab, seq_len=args.seq,
                               global_batch=args.batch, seed=args.seed)
    params = model.init(jax.random.key(args.seed))
    opt_state = adamw_init(params, opt_cfg)

    step_fn_raw = make_train_step(model, policy, opt_cfg,
                                  warmup=max(args.steps // 10, 1),
                                  total_steps=args.steps)
    jitted = jax.jit(step_fn_raw, donate_argnums=(0, 1))

    def make_batch(step):
        b = pipe.batch_at(step)
        if cfg.family == "whisper":
            k = jax.random.fold_in(jax.random.key(args.seed ^ 0xF0), step)
            b["frames"] = jax.random.normal(
                k, (args.batch, cfg.enc_frames, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            k = jax.random.fold_in(jax.random.key(args.seed ^ 0xF1), step)
            b["patch_embeds"] = jax.random.normal(
                k, (args.batch, cfg.n_patches, cfg.d_model), jnp.float32)
        return b

    history = []

    def step_fn(state, step):
        p, o = state["params"], state["opt"]
        p, o, metrics = jitted(p, o, make_batch(step), jnp.asarray(step))
        if step % args.log_every == 0 or step == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            history.append(m)
            print(json.dumps({"kind": "train/step", **m}), flush=True)
        return {"params": p, "opt": o}

    state = {"params": params, "opt": opt_state}
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=2,
                                fmt=policy.checkpoint)
        loop = FaultTolerantLoop(ckpt=mgr, save_every=args.save_every,
                                 preemption=PreemptionSignal(install_sigterm=True))
        state, start = loop.resume(state)
        if start:
            print(f"[resume] from step {start}", file=sys.stderr)
        t0 = time.perf_counter()
        state, nxt = loop.run(state, step_fn, start_step=start,
                              num_steps=args.steps - start)
        mgr.wait()
        mgr.close()
        print(json.dumps({"kind": "train/done", "done": nxt,
                          "wall_s": round(time.perf_counter() - t0, 1),
                          **loop.stats}))
    else:
        t0 = time.perf_counter()
        for step in range(args.steps):
            state = step_fn(state, step)
        print(json.dumps({"kind": "train/done", "done": args.steps,
                          "wall_s": round(time.perf_counter() - t0, 1)}))
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(history, f)
    return state


if __name__ == "__main__":
    main()
