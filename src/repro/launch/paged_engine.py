"""Paged continuous-batching engine: prefix-sharing posit KV over block pools.

``ContinuousBatchingEngine`` gives every slot a dense ``S_max`` KV strip —
simple, but at serving scale it wastes exactly what the posit codecs buy:
rows past a request's live length are dead bytes, and requests sharing a
system prompt store the same prefix codes once *per slot*.  This subclass
swaps the strips for fixed-byte pages (``core.paged_kv``, DESIGN.md §14):

* the device cache is one block pool per layer ``(L, N, Hkv, bt, hd)`` plus
  a per-slot block table ``(max_slots, W)``; attention reads gather tiles
  through the table (``kernels.posit_attention.posit_decode_attention_paged``)
  and decode writes scatter into ``table[b, lens[b] // bt]``;
* admission content-addresses every *full* prefill block by a chained
  blake2b over its token prefix — a request whose prompt starts with an
  already-cached chain claims those blocks (refcount++) instead of storing
  duplicates.  Prefill always runs in full (the bit-exactness contract:
  warm and cold admissions must decode token-for-token identically, so the
  shared bytes must be the bytes a cold prefill would have written — sharing
  dedupes *storage*, not FLOPs);
* :meth:`fork` clones a live request block-for-block (parallel sampling);
  the first divergent write hits copy-on-write in :meth:`_prepare_decode`;
* decode-written blocks are never hashed or shared: the decode path reads
  round-tripped posit KV where prefill wrote from float activations, so a
  decode-filled block's codes are not the codes a prefill of the same
  tokens would produce — publishing them would break warm≡cold exactness.

The capacity story is the paper's lightweight-posit pillar at the cache
level: pages are byte-budgeted, so packed-p8 codes (1 B) double the tokens
per page vs p16 and quadruple vs f32 — at a fixed pool byte budget the
paged engine admits several times the concurrent requests of the slot grid
once prompts overlap (benchmarks/bench_prefix_cache.py gates ≥1.5x decode
tokens/s at 90% overlap).

Only the uniform stacked-cache families (dense / moe) page their KV;
gemma3's window buffers, zamba/xlstm recurrent state, and the vlm patch
prefix (not addressable by token ids) keep the slot grid.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.paged_kv import PagedKVCache, PageGeometry, PoolExhausted
from repro.launch.engine import ContinuousBatchingEngine, Request
from repro.obs.trace import annotate

__all__ = ["PagedContinuousBatchingEngine"]


@functools.partial(jax.jit, donate_argnums=(0,), static_argnames=("n",))
def _copy_span(pool_arr, one_arr, bid, start, n):
    """Copy ``n`` KV rows from a B=1 prefill cache into block ``bid``.

    ``pool_arr``: (L, N, Hkv, bt, hd); ``one_arr``: (L, 1, Hkv, S, hd).
    ``n`` is static (one compile per distinct tail size — prompt-length
    buckets keep that bounded); ``start``/``bid`` are traced so full chunks
    of any position share one program.
    """
    chunk = jax.lax.dynamic_slice_in_dim(one_arr[:, 0], start, n, axis=2)
    return jax.lax.dynamic_update_slice(
        pool_arr, chunk[:, None], (0, bid, 0, 0, 0))


@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_block(pool_arr, src, dst):
    """Device copy-on-write: clone block ``src`` into ``dst`` (all layers)."""
    row = jax.lax.dynamic_slice_in_dim(pool_arr, src, 1, axis=1)
    return jax.lax.dynamic_update_slice(pool_arr, row, (0, dst, 0, 0, 0))


@functools.partial(jax.jit, donate_argnums=(0,), static_argnames=("n",))
def _poison_block(pool_arr, bid, code, n):
    """Overwrite the first ``n`` rows of block ``bid`` with ``code``."""
    L, _, Hkv, _, hd = pool_arr.shape
    bad = jnp.full((L, 1, Hkv, n, hd), code, pool_arr.dtype)
    return jax.lax.dynamic_update_slice(pool_arr, bad, (0, bid, 0, 0, 0))


class PagedContinuousBatchingEngine(ContinuousBatchingEngine):
    """Drop-in engine with paged prefix-sharing KV storage.

    Same client surface (``submit``/``results``/``stream``/``cancel``), same
    drivers (:meth:`run`, snapshot/restore, fault plane).  Extra knobs:
    ``page_bytes`` (per-layer K+V bytes of one block) and ``n_blocks`` (pool
    size; default sizes the pool to the slot grid's byte budget,
    ``max_slots * S_max`` token rows).
    """

    def __init__(self, model, params, policy, *, max_slots: int, S_max: int,
                 page_bytes: int = 2048, n_blocks: Optional[int] = None,
                 **kw):
        if model.decode_step_paged is None or model.init_paged_cache is None:
            raise ValueError(
                f"family {model.cfg.family!r} has no paged decode path "
                f"(only the uniform stacked-cache families page their KV)")
        fmt = policy.kv_cache
        code_bytes = (1 if fmt is not None and fmt.nbits == 8 else
                      2 if fmt is not None or policy.compute_dtype != "f32"
                      else 4)
        from repro.models.transformer import attn_cfg
        acfg = attn_cfg(model.cfg)
        self.geom = PageGeometry(
            n_layers=model.cfg.n_layers, n_kv=acfg.n_kv,
            head_dim=acfg.head_dim, code_bytes=code_bytes,
            page_bytes=page_bytes)
        bt = self.geom.block_tokens
        if S_max % bt:
            # pad up: every slot must be able to hold S_max tokens exactly
            S_max = -(-S_max // bt) * bt
        self.table_width = S_max // bt
        self.n_blocks = (n_blocks if n_blocks is not None
                         else self.geom.blocks_for(max_slots * S_max))
        self.manager: Optional[PagedKVCache] = None   # built in _init_state
        self._table_dirty = False
        super().__init__(model, params, policy, max_slots=max_slots,
                         S_max=S_max, **kw)
        if self.metrics is not None:
            m = self.metrics
            self._m_blocks_free = m.gauge(
                "paged_blocks_free", "allocatable blocks (free + evictable)")
            self._m_blocks_cached = m.gauge(
                "paged_blocks_cached", "refcount-0 blocks held for reuse")
            self._m_prefix_hits = m.counter(
                "paged_prefix_hits", "admissions that reused cached blocks")
            self._m_prefix_tokens = m.counter(
                "paged_prefix_hit_tokens", "prompt tokens served from cache")
            self._m_cow = m.counter(
                "paged_cow_copies", "copy-on-write block clones")

    # ------------------------------------------------------------ state ------
    def _init_state(self, seed: int) -> None:
        self.manager = PagedKVCache(self.geom, n_blocks=self.n_blocks,
                                    max_slots=self.max_slots)
        self._table_dirty = False
        super()._init_state(seed)

    def _build_executables(self, policy) -> None:
        model, S_max = self.model, self.S_max
        self._decode = jax.jit(
            lambda p, t, c: model.decode_step_paged(p, t, c, policy),
            donate_argnums=(2,))
        self._decode_probed = None
        if self.numerics is not None:
            self._decode_probed = jax.jit(
                lambda p, t, c: model.decode_step_paged(p, t, c, policy),
                donate_argnums=(2,))
        # prefill stays the slot-grid program: it writes a dense B=1 strip
        # whose chunks are then scattered into pool blocks (full prefill is
        # the warm≡cold exactness contract — see the module docstring)
        self._prefill = jax.jit(
            lambda p, toks, kw: model.prefill(p, toks, policy,
                                              S_max=S_max, **kw))

    def _init_cache(self):
        return self.model.init_paged_cache(
            self.max_slots, self.n_blocks, self.geom.block_tokens,
            self.table_width, self.policy)

    # ------------------------------------------------------------ admission --
    def _outstanding_growth(self) -> int:
        """Blocks the pool still owes already-admitted slots: each active
        request will grow to ``lens + remaining_decode (+1 for the write of
        its final sampled token)`` rows, and the blocks beyond what its
        table already holds must stay claimable or decode later dies on
        ``PoolExhausted`` mid-stream.  Derived from live engine state (not
        a counter), so it is automatically right after ``restore()``."""
        owed = 0
        for slot in range(self.max_slots):
            req = self.slot_req[slot]
            if not self.active[slot] or req is None:
                continue
            # every future decode step writes exactly one token before
            # sampling the next, and the final sampled token is evicted
            # unwritten — so the row grows by exactly `remaining` rows
            remaining = max(req.max_new_tokens - len(self.slot_tokens[slot]),
                            0)
            final_len = min(int(self.lens[slot]) + remaining, self.S_max)
            owed += max(0, self.geom.blocks_for(final_len)
                        - len(self.manager.tables[slot]))
        return owed

    def _can_admit(self, req: Request) -> bool:
        """Block-budget gate: admit only when the pool can take the whole
        *lifetime* of the request — prompt plus every decode token it may
        generate — on top of the growth already owed to admitted slots.
        Reserving only the prompt would admit requests whose decode growth
        later hits ``PoolExhausted`` and evicts them mid-stream
        (``cache_full``); with lifetime reservation, queueing is the
        backpressure and an admitted stream always runs to completion.
        Matched prefix blocks still referenced by a live slot are free to
        claim; matched blocks parked in the LRU consume availability like
        fresh allocations (claiming them un-caches them).  The final
        sampled token is never written back, hence the ``- 1`` on the
        lifetime.  (COW copies — possible only on forked streams — are
        deliberately NOT reserved here; a fork under a saturated pool may
        still evict with ``cache_full``, the graceful path.)"""
        match = self.manager.match_prefix(req.prompt)
        matched_live = sum(1 for b in match.bids
                           if self.manager.refcount[b] > 0)
        need = self.geom.blocks_for(
            req.prompt_len + req.max_new_tokens - 1) - matched_live
        return need + self._outstanding_growth() <= self.manager.available()

    def _prefill_into_slot(self, req: Request, slot: int):
        """Prefix-matched admission: full prefill, dedup'd storage.

        The full B=1 prefill always runs (matched blocks hold exactly the
        bytes it would write — the warm path must decode bit-for-bit like
        the cold path, and skipping prefill would also skip the non-KV
        activations the first sampled token depends on).  Matched full
        blocks are claimed by reference; only fresh chunks are scattered
        into newly-allocated pool blocks, and fresh *full* chunks are
        content-addressed for the next request to claim.
        """
        mgr, geom = self.manager, self.geom
        bt = geom.block_tokens
        match = mgr.match_prefix(req.prompt)
        tokens = jnp.asarray(req.prompt, jnp.int32)[None]
        with annotate("repro.prefill"):
            logits, one_cache = self._prefill(
                self.params, tokens, self._prefill_kwargs(req))
        row_len = int(one_cache["lens"][0])
        mgr.claim_blocks(match.bids)
        mgr.begin_slot(slot, match.bids)
        if match.bids:
            mgr.hits += 1
            mgr.hit_tokens += match.n_tokens
            if self.metrics is not None:
                self._m_prefix_hits.inc()
                self._m_prefix_tokens.inc(match.n_tokens)
        else:
            mgr.misses += 1
        digests = mgr.chunk_digests(req.prompt)
        parent = match.tail_digest
        one_kv = one_cache["kv"]
        pos = match.n_tokens
        while pos < row_len:
            n = min(bt, row_len - pos)
            try:
                bid = mgr.append_block(slot)
            except PoolExhausted:
                # _can_admit budgeted for this prompt, but a COW burst in
                # the same step can race it; unwind and retry later
                mgr.release_slot(slot)
                raise
            kv = self.cache["kv"]
            kv["k"] = _copy_span(kv["k"], one_kv["k"], jnp.int32(bid),
                                 jnp.int32(pos), n)
            kv["v"] = _copy_span(kv["v"], one_kv["v"], jnp.int32(bid),
                                 jnp.int32(pos), n)
            if n == bt:
                digest, chunk = digests[pos // bt]
                mgr.register_full_block(bid, digest, parent, chunk)
                parent = digest
            pos += n
        self._table_dirty = True
        self._push_table()
        return logits, row_len

    # --------------------------------------------------------------- decode ---
    def _prepare_decode(self, now: float) -> None:
        """Per-step write-path maintenance, before the grid step runs.

        Every active slot is about to scatter one token at
        ``table[slot, lens // bt]`` offset ``lens % bt``; this hook
        guarantees that target is a *private, existing* block: appends a
        fresh block at block boundaries, and copy-on-writes a shared or
        published tail (fork aliases, prefix-claimed tails).  Pool
        exhaustion evicts the slot as ``cache_full`` — its pages come back,
        so the rest of the grid keeps serving.
        """
        mgr, bt = self.manager, self.geom.block_tokens
        for slot in range(self.max_slots):
            if not self.active[slot]:
                continue
            target = int(self.lens[slot])
            try:
                if len(mgr.tables[slot]) * bt <= target:
                    mgr.append_block(slot)
                    self._table_dirty = True
                else:
                    cow = mgr.ensure_writable(slot)
                    if cow is not None:
                        src, dst = cow
                        kv = self.cache["kv"]
                        kv["k"] = _copy_block(kv["k"], jnp.int32(src),
                                              jnp.int32(dst))
                        kv["v"] = _copy_block(kv["v"], jnp.int32(src),
                                              jnp.int32(dst))
                        self._table_dirty = True
                        if self.metrics is not None:
                            self._m_cow.inc()
            except PoolExhausted:
                self._evict(slot, now, "cache_full")
        self._push_table()
        if self.metrics is not None:
            self._m_blocks_free.set(mgr.available())
            self._m_blocks_cached.set(len(mgr.lru))

    def _push_table(self) -> None:
        if self._table_dirty:
            self.cache["table"] = jnp.asarray(
                self.manager.device_table(self.table_width))
            self._table_dirty = False

    # ------------------------------------------------------------- eviction ---
    def _release_slot(self, slot: int) -> None:
        self.manager.release_slot(slot)
        self._table_dirty = True
        self._push_table()

    def _quarantine(self, slot: int, now: float) -> None:
        """Evict a nonfinite-logit slot and zero its *private* blocks (code 0
        decodes to exact 0.0).  Shared blocks are merely released — another
        slot's live prefix must not be scrubbed from under it; a poisoned
        hashed block leaving the index via LRU reuse is the correctness
        backstop (alloc zeroes nothing, but writes overwrite fully)."""
        private = self.manager.private_bids(slot)
        self._evict(slot, now, "numerics")      # releases the references
        kv = self.cache["kv"]
        for bid in private:
            kv["k"] = _poison_block(kv["k"], jnp.int32(bid),
                                    jnp.zeros((), kv["k"].dtype),
                                    self.geom.block_tokens)
            kv["v"] = _poison_block(kv["v"], jnp.int32(bid),
                                    jnp.zeros((), kv["v"].dtype),
                                    self.geom.block_tokens)

    def inject_nar_into(self, slot: int, count: int) -> None:
        """Chaos hook override: poison the slot's *tail* block only.  Head
        blocks may be shared with healthy requests — the fault must stay
        contained to the slot it targets, so the tail is made private
        (copy-on-write) first."""
        from repro.ft.serving import _nar_code
        mgr, bt = self.manager, self.geom.block_tokens
        if not mgr.tables[slot]:
            return
        cow = mgr.ensure_writable(slot)
        kv = self.cache["kv"]
        if cow is not None:
            src, dst = cow
            kv["k"] = _copy_block(kv["k"], jnp.int32(src), jnp.int32(dst))
            kv["v"] = _copy_block(kv["v"], jnp.int32(src), jnp.int32(dst))
            self._table_dirty = True
        bid = mgr.tables[slot][-1]
        occupied = int(self.lens[slot]) - (len(mgr.tables[slot]) - 1) * bt
        n = max(1, min(count, max(occupied, 1), bt))
        kv["k"] = _poison_block(kv["k"], jnp.int32(bid),
                                _nar_code(kv["k"]), n)
        kv["v"] = _poison_block(kv["v"], jnp.int32(bid),
                                _nar_code(kv["v"]), n)
        self._push_table()

    # ----------------------------------------------------------------- fork ---
    def fork(self, rid: int, new_rid: int) -> int:
        """Clone a live request into a free slot, sharing every block
        (parallel sampling / n-best).  Returns the new request's rid.  The
        clone starts from the same position with the same emitted tokens;
        the first post-fork write on either side triggers copy-on-write in
        :meth:`_prepare_decode`, so the streams diverge without copying the
        shared history."""
        import dataclasses as _dc
        src = next((s for s in range(self.max_slots)
                    if self.active[s] and self.slot_req[s] is not None
                    and self.slot_req[s].rid == rid), None)
        if src is None:
            raise ValueError(f"fork: rid {rid} is not in flight")
        free = self.free_slots()
        if not free:
            raise PoolExhausted("fork: no free slot")
        dst = free[0]
        self.manager.fork_slot(src, dst)
        self.lens[dst] = self.lens[src]
        self.last_token = self.last_token.at[dst].set(self.last_token[src])
        self.active[dst] = True
        self.slot_req[dst] = _dc.replace(self.slot_req[src], rid=new_rid)
        self.slot_tokens[dst] = list(self.slot_tokens[src])
        self.slot_token_times[dst] = list(self.slot_token_times[src])
        self.slot_admitted[dst] = self.slot_admitted[src]
        self._sync_lens()
        self._table_dirty = True
        self._push_table()
        return new_rid

    # ----------------------------------------------------- snapshot/restore ---
    def snapshot(self) -> dict:
        snap = super().snapshot()
        # block table + refcounts + hash index ride in the snapshot meta;
        # the geometry line extends the config fingerprint (a snapshot taken
        # under one page layout must never restore into another)
        snap["meta"]["paged"] = self.manager.snapshot_meta()
        return snap

    def restore(self, snap: dict, *, now: float = 0.0) -> None:
        if "paged" not in snap["meta"]:
            raise ValueError(
                "snapshot has no paged-cache state (taken by a slot-grid "
                "engine?) — it cannot restore into a paged engine")
        super().restore(snap, now=now)
        self.manager.restore_meta(snap["meta"]["paged"])
        self._table_dirty = True
        self._push_table()

    # ------------------------------------------------------------- accounting --
    def prefix_stats(self) -> dict:
        """Pool + sharing counters (also fed to metrics gauges per step)."""
        return self.manager.stats()
