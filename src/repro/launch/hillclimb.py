import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: measure (probe) a cell under a named variant.

    PYTHONPATH=src python -m repro.launch.hillclimb --cell olmoe_train --variant bf16

Variants are (policy, cfg-override, step-options) bundles; each probe reports
scan-aware flops/bytes/collective bytes per device plus the roofline terms, so
every hypothesis->change->measure cycle in EXPERIMENTS.md §Perf is one command.
"""
import argparse
import dataclasses
import json

from repro.configs import get_arch, get_shape
from repro.core.pcsr import TransPolicy
from repro.core.policy import PRECISION_PRESETS
from repro.launch import costprobe
from repro.launch.config import ServeConfig
from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, model_flops

CELLS = {
    "olmoe_train": ("olmoe-1b-7b", "train_4k"),
    "zamba_train": ("zamba2-7b", "train_4k"),
    "qwen_decode": ("qwen2.5-14b", "decode_32k"),
    "yi_train": ("yi-34b", "train_4k"),
    "gemma3_decode": ("gemma3-4b", "decode_32k"),
}

VARIANTS = {
    # paper-faithful baseline: FP32 datapath, no posit storage
    "baseline": dict(policy=TransPolicy(), cfg_override={}),
    # TPU-native datapath (paper's FPU=fp32 -> MXU=bf16; DESIGN.md §2)
    "bf16": dict(policy=TransPolicy(compute_dtype="bf16"), cfg_override={}),
    # the paper's technique at the serving bottleneck: posit8 KV cache
    "p8_kv": dict(policy=TransPolicy.from_names(kv_cache="p8_0",
                                                compute_dtype="bf16"),
                  cfg_override={}),
    "p8_kv_f32": dict(policy=TransPolicy.from_names(kv_cache="p8_0"),
                      cfg_override={}),
    # p16 weights at rest (FSDP wire + HBM)
    "p16_weights": dict(policy=TransPolicy.from_names(weights="p16_1",
                                                      compute_dtype="bf16"),
                        cfg_override={}),
    # SSD chunk-size sweep (zamba memory term ∝ chunk length)
    "chunk128": dict(policy=TransPolicy(), cfg_override={"ssm_chunk": 128}),
    "chunk64": dict(policy=TransPolicy(), cfg_override={"ssm_chunk": 64}),
    "chunk128_bf16": dict(policy=TransPolicy(compute_dtype="bf16"),
                          cfg_override={"ssm_chunk": 128}),
}

# Per-layer precision schedules (core/policy.py) as a hillclimb search
# dimension: every preset becomes a variant (over the bf16 datapath), and
# --precision-policy overlays any preset/spec onto any variant's policy
# (accepting @artifact.json to probe a saved calibration).
VARIANTS.update({
    f"prec_{name.replace('-', '_')}": dict(
        policy=pol.with_base(dataclasses.replace(
            pol.base, compute_dtype="bf16")),
        cfg_override={})
    for name, pol in PRECISION_PRESETS.items()
})

# Data-driven schedule (repro.calib, DESIGN.md §11): calibrate on the cell's
# *reduced* config (cheap — a couple of observed forward passes), then probe
# the full-size cell under the emitted per-layer dynamic-es policy.  Layer
# paths are size-independent, so reduced-model rules transfer verbatim.
VARIANTS["prec_calibrated"] = dict(policy="__calibrated__", cfg_override={})


def _calibrated_policy(cfg):
    import jax
    import numpy as np

    from repro.calib.search import calibrate_model, calibration_batches
    from repro.models.registry import build_model

    rcfg = cfg.reduced()
    model = build_model(rcfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    base = TransPolicy(compute_dtype="bf16")
    batches = calibration_batches(rcfg, rng, 2, batch=2, seq=64)
    policy, _ = calibrate_model(
        lambda b: model.loss(params, b, base)[0], batches, params,
        base=base, name=f"calibrated-{rcfg.name}")
    return policy


def run_variant(cell: str, variant: str,
                precision_policy: str | None = None) -> dict:
    arch, shape_name = CELLS[cell]
    v = VARIANTS[variant]
    cfg = get_arch(arch)
    if v["cfg_override"]:
        cfg = dataclasses.replace(cfg, **v["cfg_override"])
    policy = v["policy"]
    if policy == "__calibrated__":
        if precision_policy:
            # the overlay below replaces the rule schedule wholesale —
            # running the calibration first would only throw its result away
            policy = TransPolicy(compute_dtype="bf16")
        else:
            policy = _calibrated_policy(cfg)
    if precision_policy:
        # overlay a per-layer weight schedule onto the variant's base policy
        # (resolution shared with serve.py via ServeConfig.build_policy)
        base = policy.base if hasattr(policy, "base") else policy
        policy, _ = ServeConfig(arch=arch, precision_policy=precision_policy,
                                codec_impl=base.codec_impl,
                                epilogue=base.epilogue,
                                attn_impl=base.attn_impl).build_policy(base)

    # monkey-patch costprobe's binding so probe_cell sees the override
    orig = costprobe.get_arch

    def _arch_override(name):
        return cfg if name == arch else orig(name)

    costprobe.get_arch = _arch_override
    try:
        res = costprobe.probe_cell(arch, shape_name, policy=policy)
    finally:
        costprobe.get_arch = orig

    shape = get_shape(shape_name)
    chips = res["n_chips"]
    t_c = res["flops_per_device"] / PEAK_FLOPS
    t_m = res["bytes_per_device"] / HBM_BW
    t_x = res["coll_per_device"] / ICI_BW
    mf = model_flops(cfg, shape)
    res.update({
        "variant": variant, "cell": cell,
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "dominant": max({"compute": t_c, "memory": t_m, "collective": t_x},
                        key=lambda k: {"compute": t_c, "memory": t_m,
                                       "collective": t_x}[k]),
        "model_flops": mf,
        "roofline_fraction": (mf / chips / PEAK_FLOPS) / max(t_c, t_m, t_x)
        if max(t_c, t_m, t_x) else 0.0,
    })
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=sorted(CELLS))
    ap.add_argument("--variant", required=True, choices=sorted(VARIANTS))
    ap.add_argument("--precision-policy", default=None,
                    help="per-layer weight schedule overlay: preset name or "
                         "pattern=fmt[:packed],... spec (core/policy.py)")
    ap.add_argument("--out-dir", default="experiments/hillclimb")
    args = ap.parse_args(argv)
    res = run_variant(args.cell, args.variant,
                      precision_policy=args.precision_policy)
    print(json.dumps({"kind": "hillclimb/result",
                      **{k: v for k, v in res.items()
                         if not isinstance(v, (list, dict))}}))
    os.makedirs(args.out_dir, exist_ok=True)
    tag = f"{args.cell}__{args.variant}"
    if args.precision_policy:
        tag += f"__{args.precision_policy.replace('*', '_').replace('/', '_')}"
    with open(os.path.join(args.out_dir, f"{tag}.json"), "w") as f:
        json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
