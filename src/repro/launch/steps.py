"""Step builders: the jittable train / prefill / decode functions per
(arch x shape), plus the batch/cache abstract specs the dry-run lowers with.

Two gradient-sync modes (DESIGN.md §5):
  * "gspmd"  (paper-faithful baseline): one jit, GSPMD inserts every
    collective, cross-pod gradient reduction in f32.
  * "posit_pod" (beyond-paper): jax.shard_map manual over the "pod" axis only
    ("data"/"model" stay auto/GSPMD inside); per-pod gradients are posit-
    encoded, all-gathered over the pod links as 1–2-byte codes, decoded and
    summed locally, with f32 error-feedback residuals.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelCfg, ShapeCfg
from repro.core.pcsr import TransPolicy
from repro.core.types import PositFmt
from repro.models.registry import Model
from repro.optim import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm
from repro.optim.schedule import cosine_warmup


# ------------------------------------------------------------- batch specs ----

def abstract_batch(cfg: ModelCfg, shape: ShapeCfg) -> dict:
    B, S = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    batch = {"tokens": tok, "labels": tok}
    if cfg.family == "whisper":
        batch["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.enc_frames, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_patches, cfg.d_model), jnp.float32)
    return batch


def abstract_params(model: Model):
    return jax.eval_shape(lambda: model.init(jax.random.key(0)))


def abstract_cache(model: Model, cfg: ModelCfg, shape: ShapeCfg,
                   policy: TransPolicy):
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "whisper":
        params = abstract_params(model)
        batch = {"frames": jax.ShapeDtypeStruct(
            (B, cfg.enc_frames, cfg.d_model), jnp.float32)}
        return jax.eval_shape(
            lambda p, b: model.init_cache(p, b, policy, S), params, batch)
    return jax.eval_shape(lambda: model.init_cache(B, S, policy))


# -------------------------------------------------------------- train step ----

def _nonfinite_count(tree) -> jax.Array:
    """Elements that are NaN/inf (float leaves) or posit NaR (uintN code
    leaves — the encoded-moment case) across a pytree, as one int32."""
    tot = jnp.int32(0)
    for x in jax.tree.leaves(tree):
        if jnp.issubdtype(x.dtype, jnp.floating):
            tot += jnp.sum(~jnp.isfinite(x), dtype=jnp.int32)
        elif x.dtype in (jnp.uint8, jnp.uint16):
            tot += jnp.sum(x == (1 << (x.dtype.itemsize * 8 - 1)),
                           dtype=jnp.int32)
    return tot


def _sq_norm(tree) -> jax.Array:
    return sum(jnp.sum(x.astype(jnp.float32) ** 2)
               for x in jax.tree.leaves(tree))


def make_train_step(model: Model, policy: TransPolicy, opt_cfg: AdamWConfig,
                    *, warmup: int = 100, total_steps: int = 10_000,
                    grad_sync: str = "gspmd",
                    grad_fmt: Optional[PositFmt] = None,
                    mesh=None, microbatches: int = 1,
                    telemetry: bool = False):
    """Returns train_step(params, opt_state, batch, step) -> (p, o, metrics).

    microbatches > 1: gradient accumulation over sequential microbatches
    (peak activation memory scales ~1/microbatches; grads accumulate in one
    extra params-sized f32 buffer).

    telemetry=True adds params-sized reductions to the metrics dict —
    ``update_ratio`` (||delta p|| / ||p||), ``param_norm``, and nonfinite
    counts over the raw gradients and the new optimizer moments (posit NaR
    codes counted for encoded moments).  Only the *probed twin* executable
    (DESIGN.md §16) is built with this on: the plain step's metrics stay
    byte-identical to the un-instrumented builder.
    """

    def loss_and_grads(params, batch):
        def loss_fn(p, mb):
            return model.loss(p, mb, policy)

        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads

        def split(x):
            return x.reshape(microbatches, x.shape[0] // microbatches,
                             *x.shape[1:])
        mbs = jax.tree.map(split, batch)

        def micro(carry, mb):
            loss_a, metrics_a, grads_a = carry
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            grads_a = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), grads_a, grads)
            metrics_a = jax.tree.map(lambda a, m: a + m, metrics_a, metrics)
            return (loss_a + loss, metrics_a, grads_a), None

        zero_g = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        zero_m = {"ce": jnp.float32(0.0), "aux": jnp.float32(0.0)}
        (loss, metrics, grads), _ = jax.lax.scan(
            micro, (jnp.float32(0.0), zero_m, zero_g), mbs)
        inv = 1.0 / microbatches
        return (loss * inv,
                jax.tree.map(lambda m: m * inv, metrics),
                jax.tree.map(lambda g: g * inv, grads))

    def apply_update(params, opt_state, grads, step, loss, metrics):
        grad_nonfinite = _nonfinite_count(grads) if telemetry else None
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        lr = cosine_warmup(step, warmup=warmup, total=total_steps)
        new_params, new_opt = adamw_update(grads, opt_state, params, opt_cfg,
                                           lr_scale=lr)
        out = {"loss": loss, "gnorm": gnorm, **metrics}
        if telemetry:
            # old and new params coexist here; XLA's donation aliasing only
            # reuses the old buffers once these reductions are consumed
            p_norm = jnp.sqrt(_sq_norm(params))
            upd = jax.tree.map(
                lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                new_params, params)
            out["param_norm"] = p_norm
            out["update_ratio"] = jnp.sqrt(_sq_norm(upd)) / (p_norm + 1e-12)
            out["grad_nonfinite"] = grad_nonfinite
            out["opt_nonfinite"] = _nonfinite_count(new_opt["mu"])
        return new_params, new_opt, out

    if grad_sync == "gspmd":
        def train_step(params, opt_state, batch, step):
            loss, metrics, grads = loss_and_grads(params, batch)
            return apply_update(params, opt_state, grads, step, loss, metrics)
        return train_step

    if grad_sync == "posit_pod":
        assert mesh is not None and "pod" in mesh.axis_names
        assert grad_fmt is not None
        n_pods = mesh.shape["pod"]

        def per_pod(params, opt_state, batch, step):
            # inside: manual over "pod" (per-pod shard of the batch),
            # auto/GSPMD over "data"/"model".
            from repro.distributed.collectives import (compressed_allreduce,
                                                       exact_psum)

            loss, metrics, grads = loss_and_grads(params, batch)

            def sync_leaf(g):
                # two-hop posit-compressed all-reduce on the pod links:
                # pow2 prescale + dynamic es + FTZ (see collectives.py).
                # policy.exact_collectives upgrades the hop to the
                # quire-domain exact reduction (DESIGN.md §7).
                if policy.exact_collectives:
                    return exact_psum(
                        g.astype(jnp.float32) / n_pods, grad_fmt, "pod"
                    ).astype(g.dtype)
                return compressed_allreduce(
                    g.astype(jnp.float32) / n_pods, grad_fmt, "pod"
                ).astype(g.dtype)

            grads = jax.tree.map(sync_leaf, grads)
            loss = jax.lax.pmean(loss, "pod")
            metrics = jax.tree.map(lambda m: jax.lax.pmean(m, "pod"), metrics)
            return apply_update(params, opt_state, grads, step, loss, metrics)

        def train_step(params, opt_state, batch, step):
            return jax.shard_map(
                per_pod,
                mesh=mesh,
                in_specs=(P(), P(), P("pod"), P()),
                out_specs=(P(), P(), P()),
                axis_names={"pod"},
                check_vma=False,
            )(params, opt_state, batch, step)
        return train_step

    raise ValueError(grad_sync)


def make_opt_state(model: Model, opt_cfg: AdamWConfig):
    params = abstract_params(model)
    return jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params)


# -------------------------------------------------------------- serve steps ---

def make_prefill_step(model: Model, cfg: ModelCfg, policy: TransPolicy,
                      shape: ShapeCfg):
    if cfg.family == "whisper":
        def prefill_step(params, batch):
            cache = model.init_cache(params, batch, policy, shape.seq_len)
            logits, cache2 = model.decode_step(
                params, batch["tokens"][:, 0], cache, policy)
            return logits, cache2
        return prefill_step

    def prefill_step(params, batch):
        kw = {}
        if cfg.family == "vlm":
            kw["patch_embeds"] = batch["patch_embeds"]
        return model.prefill(params, batch["tokens"], policy,
                             S_max=shape.seq_len, **kw)
    return prefill_step


def make_decode_step(model: Model, cfg: ModelCfg, policy: TransPolicy):
    def decode_step(params, token_t, cache):
        return model.decode_step(params, token_t, cache, policy)
    return decode_step
