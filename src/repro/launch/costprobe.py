import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Scan-aware cost probes for the roofline (EXPERIMENTS.md §Roofline).

XLA's HLO cost analysis counts while-loop bodies once, so scanned stacks
under-report FLOPs/bytes/collectives by their trip counts. Probes fix this by
measurement, not modeling: lower fully-UNROLLED reduced-depth variants of each
cell at two depths d1 < d2, take the per-period delta, and extrapolate
linearly to the full depth — exact for homogeneous layer stacks:

    C_full = C(d1) + delta * (units_full - units(d1)),
    delta = (C(d2) - C(d1)) / (units(d2) - units(d1))

Depths step in whole heterogeneity periods (gemma3: 6 = 5 local + 1 global;
zamba: 6 mamba + 1 shared; xlstm: 4 = 3 mLSTM + 1 sLSTM), so the delta
captures one full period. Train probes run microbatches=1 (total FLOPs/bytes
are microbatch-invariant; collectives differ <~1/micro in the accumulate sums).

    PYTHONPATH=src python -m repro.launch.costprobe --arch yi-34b --shape train_4k
"""
import argparse
import dataclasses
import json
import sys

from repro.configs import get_arch, get_shape
from repro.core.pcsr import TransPolicy
from repro.launch.mesh import make_production_mesh
from repro.launch.dryrun import (cost_analysis_dict, lower_cell,
                                 parse_collectives, _parse_policy)
from repro.models.unroll import unroll_mode


def _probe_plan(cfg):
    """(period, depths, units_full) per family."""
    if cfg.family == "gemma3":
        period = cfg.local_ratio + 1
        return period, (period, 2 * period), cfg.n_layers / period
    if cfg.family == "zamba":
        period = cfg.shared_attn_every
        return period, (period, 2 * period), cfg.n_layers / period
    if cfg.family == "xlstm":
        period = cfg.slstm_every
        return period, (period, 2 * period), cfg.n_layers / period
    # dense / moe / vlm / whisper: homogeneous
    return 1, (2, 4), float(cfg.n_layers)


def _probe_cfg(cfg, depth: int):
    kw = {"n_layers": depth}
    if cfg.family == "whisper":
        kw["enc_layers"] = depth
    return dataclasses.replace(cfg, **kw)


def _measure(cfg, shape, mesh, policy, grad_sync):
    with unroll_mode():
        lowered = lower_cell(cfg, shape, mesh, policy=policy,
                             grad_sync=grad_sync, force_micro=1)
    compiled = lowered.compile()
    cost = cost_analysis_dict(compiled)
    coll = parse_collectives(compiled.as_text())
    return {
        "flops": cost.get("flops", 0.0),
        "bytes": cost.get("bytes accessed", 0.0),
        "coll": sum(v["bytes"] for v in coll.values()),
        "coll_by_op": {k: v["bytes"] for k, v in coll.items()},
    }


def probe_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               policy: TransPolicy = None, grad_sync: str = "gspmd") -> dict:
    policy = policy or TransPolicy()
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    period, (d1, d2), units_full = _probe_plan(cfg)

    c1 = _measure(_probe_cfg(cfg, d1), shape, mesh, policy, grad_sync)
    c2 = _measure(_probe_cfg(cfg, d2), shape, mesh, policy, grad_sync)
    u1, u2 = d1 / period, d2 / period

    out = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
           "n_chips": mesh.size, "policy": policy.describe(),
           "grad_sync": grad_sync,
           "probe_depths": [d1, d2], "units_full": units_full}
    for key in ("flops", "bytes", "coll"):
        delta = (c2[key] - c1[key]) / (u2 - u1)
        out[key + "_per_device"] = c1[key] + delta * (units_full - u1)
        out[key + "_probe"] = [c1[key], c2[key]]
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=False)
    ap.add_argument("--shape", required=False)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--policy", default="none")
    ap.add_argument("--grad-sync", default="gspmd")
    ap.add_argument("--out-dir", default="experiments/probe")
    args = ap.parse_args(argv)

    from repro.configs import cells
    todo = ([(c.name, s.name) for c, s, _ in cells()] if args.all
            else [(args.arch, args.shape)])
    for arch, shape in todo:
        try:
            res = probe_cell(arch, shape, multi_pod=args.multi_pod,
                             policy=_parse_policy(args.policy),
                             grad_sync=args.grad_sync)
        except Exception as e:
            res = {"arch": arch, "shape": shape,
                   "error": f"{type(e).__name__}: {str(e)[:200]}"}
            print(f"[FAIL] {arch}|{shape}: {res['error']}", file=sys.stderr)
        print(json.dumps({"kind": "costprobe/cell", **res}))
        if args.out_dir:
            os.makedirs(args.out_dir, exist_ok=True)
            mode = "multi" if args.multi_pod else "single"
            tag = f"{arch}__{shape}__{mode}"
            if args.policy != "none":
                tag += "__" + args.policy.replace(",", "_").replace("=", "-")
            if args.grad_sync != "gspmd":
                tag += "__" + args.grad_sync
            with open(os.path.join(args.out_dir, tag + ".json"), "w") as f:
                json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
