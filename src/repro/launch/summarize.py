"""Generate SUMMARY_{single,multi}.md tables from dry-run JSON artifacts.

    PYTHONPATH=src python -m repro.launch.summarize --in-dir experiments/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

V5E_HBM_GB = 16.0


def load(in_dir: str, mode: str):
    rows = []
    for fn in sorted(glob.glob(os.path.join(in_dir, f"*__{mode}.json"))):
        with open(fn) as f:
            rows.append(json.load(f))
    return rows


def fmt(rows, mode):
    out = [f"# Dry-run summary — {mode} mesh",
           "",
           "| arch | shape | compile_s | args GB/dev | temp GB/dev | fits 16GB "
           "| GFLOP/dev | coll MB/dev | top collective |",
           "|---|---|---|---|---|---|---|---|---|"]
    n_ok = n_fail = n_skip = 0
    for r in rows:
        if r.get("skipped"):
            n_skip += 1
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — "
                       f"| skipped: {r['skipped']} |")
            continue
        if r.get("error"):
            n_fail += 1
            out.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | | "
                       f"| {r['error'][:80]} |")
            continue
        n_ok += 1
        m = r["memory"]
        args_gb = m["argument_bytes"] / 1e9
        temp_gb = m["temp_bytes"] / 1e9
        tot = args_gb + temp_gb
        coll = r.get("collectives", {})
        coll_b = sum(v["bytes"] for v in coll.values())
        top = max(coll, key=lambda k: coll[k]["bytes"]) if coll else "-"
        fits = "yes" if tot <= V5E_HBM_GB else f"NO ({tot:.1f})"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compile_s']:.0f} "
            f"| {args_gb:.2f} | {temp_gb:.2f} | {fits} "
            f"| {r['flops_per_device'] / 1e9:.0f} | {coll_b / 1e6:.0f} | {top} |")
    out.insert(1, f"\n{n_ok} compiled, {n_fail} failed, {n_skip} skipped.\n")
    return "\n".join(out) + "\n"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--in-dir", default="experiments/dryrun")
    args = ap.parse_args(argv)
    for mode in ("single", "multi"):
        rows = load(args.in_dir, mode)
        if not rows:
            continue
        path = os.path.join(args.in_dir, f"SUMMARY_{mode}.md")
        with open(path, "w") as f:
            f.write(fmt(rows, mode))
        print(f"wrote {path} ({len(rows)} cells)", file=sys.stderr)


if __name__ == "__main__":
    main()
