import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: device count locks on first backend init.

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract the roofline inputs from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod --out-dir experiments/dryrun

Per cell this prints (and JSON-dumps):
  * compiled.memory_analysis()   — proves the per-device footprint fits
  * compiled.cost_analysis()     — HLO FLOPs / bytes for §Roofline
  * the collective schedule      — op counts + payload bytes by dtype,
                                   parsed from the post-SPMD optimized HLO
"""
import argparse
import json
import re
import sys
import time
from collections import defaultdict

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_arch, get_shape, list_archs
from repro.configs.base import ModelCfg, ShapeCfg
from repro.core.pcsr import TransPolicy
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (batch_specs, cache_specs, decode_token_spec,
                                   tree_param_specs, tree_shardings)
from repro.launch.steps import (abstract_batch, abstract_cache, abstract_params,
                                make_decode_step, make_prefill_step,
                                make_opt_state, make_train_step)
from repro.models.registry import build_model
from repro.models.shardhooks import activation_sharding
from repro.optim import AdamWConfig


def make_sp_hook(mesh):
    """Sequence-parallel activation constraints (DESIGN.md §5, SP):
    the residual stream (B, S, D) shards S over "model" between blocks, so
    remat-saved layer checkpoints shrink by the TP degree."""
    from repro.launch.mesh import batch_axes
    dp = batch_axes(mesh)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    n_tp = mesh.shape["model"]

    from jax.sharding import PartitionSpec as P

    def hook(x, kind):
        if kind == "expert_buffers" and x.ndim == 3:
            e = "model" if x.shape[0] % n_tp == 0 else None
            c = "data" if x.shape[1] % mesh.shape["data"] == 0 else None
            return jax.lax.with_sharding_constraint(x, P(e, c, None))
        if kind != "residual" or x.ndim != 3:
            return x
        b = dp if (x.shape[0] % n_dp == 0 and x.shape[0] >= n_dp) else None
        s = "model" if (x.shape[1] % n_tp == 0 and x.shape[1] >= n_tp) else None
        if b is None and s is None:
            return x
        return jax.lax.with_sharding_constraint(x, P(b, s, None))

    return hook

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_TYPE_RE = re.compile(r"\b(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|"
                      r"u64|u32|u16|u8|pred)\[([0-9,]*)\]")


def parse_collectives(hlo_text: str) -> dict:
    """Sum payload bytes of every collective op in the optimized (post-SPMD,
    per-device) HLO. Payload = result-shape bytes (receive volume bound)."""
    stats = defaultdict(lambda: {"count": 0, "bytes": 0, "by_dtype": defaultdict(int)})
    for line in hlo_text.splitlines():
        ls = line.strip()
        if ls.startswith("ROOT "):
            ls = ls[5:]
        m = re.match(r"%?[\w.\-]+\s*=\s*(.*)$", ls)
        if not m:
            continue
        rhs = m.group(1)
        op = None
        for c in _COLLECTIVES:
            if re.search(rf"\b{c}(-start|-done)?\(", rhs):
                op = c
                break
        if op is None or re.search(rf"\b{op}-done\(", rhs):
            continue  # count -start, skip -done (same payload)
        lhs_types = rhs.split(op)[0]
        total = 0
        for dt, dims in _TYPE_RE.findall(lhs_types):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
            stats[op]["by_dtype"][dt] += n * _DTYPE_BYTES[dt]
        stats[op]["count"] += 1
        stats[op]["bytes"] += total
    return {k: {"count": v["count"], "bytes": v["bytes"],
                "by_dtype": dict(v["by_dtype"])} for k, v in stats.items()}


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` across jax versions: newer jax returns a
    flat dict, older (and some backends) a one-element list of dicts — the
    ``run_cell`` AttributeError of CHANGES.md (PR 2).  Normalize to a dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def lower_cell(cfg: ModelCfg, shape: ShapeCfg, mesh, *,
               policy: TransPolicy, grad_sync: str = "gspmd",
               force_micro: int | None = None):
    """Build + lower the step function for one cell. Returns (lowered, meta)."""
    model = build_model(cfg)
    params_abs = abstract_params(model)
    p_specs = tree_param_specs(params_abs, mesh)
    p_shard = tree_shardings(p_specs, mesh)

    if shape.kind == "train":
        opt_cfg = AdamWConfig(moment_fmt=policy.optimizer)
        opt_abs = make_opt_state(model, opt_cfg)
        o_specs = tree_param_specs(opt_abs, mesh)  # moments mirror params
        o_shard = tree_shardings(o_specs, mesh)
        batch_abs = abstract_batch(cfg, shape)
        b_shard = tree_shardings(batch_specs(cfg, shape, mesh), mesh)
        b_shard = {k: b_shard[k] for k in batch_abs}
        # microbatch so each device sees ~16k tokens per accumulation step
        n_dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
        tokens_per_dev = shape.global_batch * shape.seq_len // n_dp
        micro = max(1, min(8, tokens_per_dev // 16384,
                           shape.global_batch // n_dp))
        if force_micro is not None:
            micro = force_micro
        step_fn = make_train_step(
            model, policy, opt_cfg, grad_sync=grad_sync, mesh=mesh,
            grad_fmt=policy.gradients, microbatches=micro)
        jitted = jax.jit(
            step_fn,
            in_shardings=(p_shard, o_shard, b_shard, None),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1),
        )
        args = (params_abs, opt_abs,
                {k: batch_abs[k] for k in batch_abs},
                jax.ShapeDtypeStruct((), jnp.int32))
    elif shape.kind == "prefill":
        batch_abs = abstract_batch(cfg, shape)
        b_shard = tree_shardings(batch_specs(cfg, shape, mesh), mesh)
        b_shard = {k: b_shard[k] for k in batch_abs}
        cache_abs = abstract_cache(model, cfg, shape, policy)
        c_shard = tree_shardings(cache_specs(cache_abs, cfg, mesh), mesh)
        step_fn = make_prefill_step(model, cfg, policy, shape)
        jitted = jax.jit(step_fn, in_shardings=(p_shard, b_shard),
                         out_shardings=(None, c_shard))
        args = (params_abs, batch_abs)
    elif shape.kind == "decode":
        cache_abs = abstract_cache(model, cfg, shape, policy)
        c_shard = tree_shardings(cache_specs(cache_abs, cfg, mesh), mesh)
        tok_abs = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
        t_shard = jax.NamedSharding(mesh, decode_token_spec(cfg, shape, mesh))
        step_fn = make_decode_step(model, cfg, policy)
        jitted = jax.jit(
            step_fn,
            in_shardings=(p_shard, t_shard, c_shard),
            out_shardings=(None, c_shard),
            donate_argnums=(2,),
        )
        args = (params_abs, tok_abs, cache_abs)
    else:
        raise ValueError(shape.kind)

    with mesh, activation_sharding(make_sp_hook(mesh)):
        lowered = jitted.lower(*args)
    return lowered


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             policy: TransPolicy, grad_sync: str = "gspmd",
             collect_hlo: bool = True) -> dict:
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size

    if shape.name == "long_500k" and not cfg.supports_long_context:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "skipped": "no sub-quadratic path (DESIGN.md §6)"}

    t0 = time.perf_counter()
    lowered = lower_cell(cfg, shape, mesh, policy=policy, grad_sync=grad_sync)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    print(mem, file=sys.stderr)
    cost = cost_analysis_dict(compiled)
    print({k: v for k, v in cost.items()
           if k in ("flops", "bytes accessed") and isinstance(v, (int, float))},
          file=sys.stderr)

    coll = {}
    if collect_hlo:
        txt = compiled.as_text()
        coll = parse_collectives(txt)
        del txt

    result = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "multi_pod": multi_pod, "n_chips": n_chips,
        "grad_sync": grad_sync, "policy": policy.describe(),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_per_device": cost.get("flops", 0.0),
        "bytes_per_device": cost.get("bytes accessed", 0.0),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        "collectives": coll,
    }
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--grad-sync", default="gspmd",
                    choices=["gspmd", "posit_pod"])
    ap.add_argument("--policy", default="none",
                    help="none | p16-train | p8-serve | weights=p8_0,kv=p8_0,...")
    ap.add_argument("--out-dir", default=None)
    ap.add_argument("--no-hlo", action="store_true",
                    help="skip collective parsing (faster)")
    args = ap.parse_args(argv)

    policy = _parse_policy(args.policy)
    cells = []
    if args.all:
        for a in list_archs():
            for s in SHAPES:
                cells.append((a, s.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    ok = True
    for arch, shape in cells:
        tag = f"{arch}|{shape}|{'multi' if args.multi_pod else 'single'}"
        try:
            res = run_cell(arch, shape, multi_pod=args.multi_pod,
                           policy=policy, grad_sync=args.grad_sync,
                           collect_hlo=not args.no_hlo)
        except Exception as e:  # a failing cell is a bug in our sharding
            ok = False
            res = {"arch": arch, "shape": shape, "multi_pod": args.multi_pod,
                   "error": f"{type(e).__name__}: {e}"}
            print(f"[FAIL] {tag}: {res['error']}", file=sys.stderr)
        print(json.dumps({"kind": "dryrun/cell",
                          **{k: v for k, v in res.items()
                             if k != "collectives"}}))
        if args.out_dir:
            os.makedirs(args.out_dir, exist_ok=True)
            mode = "multi" if args.multi_pod else "single"
            fn = os.path.join(args.out_dir, f"{arch}__{shape}__{mode}.json")
            with open(fn, "w") as f:
                json.dump(res, f, indent=1)
    sys.exit(0 if ok else 1)


def _parse_policy(s: str) -> TransPolicy:
    if s in ("none", ""):
        return TransPolicy()
    if s == "p16-train":
        return TransPolicy.from_names(weights="p16_1", gradients="p16_1",
                                      optimizer="p16_1", checkpoint="p16_1")
    if s == "p8-serve":
        return TransPolicy.from_names(weights="p8_0", kv_cache="p8_0",
                                      compute_dtype="bf16")
    kw = {}
    cd = "f32"
    for part in s.split(","):
        k, v = part.split("=")
        if k == "compute":
            cd = v
        else:
            kw[{"kv": "kv_cache"}.get(k, k)] = v
    return TransPolicy.from_names(compute_dtype=cd, **kw)


if __name__ == "__main__":
    main()
