"""Roofline analysis from dry-run JSON artifacts (EXPERIMENTS.md §Roofline).

Terms (per device, TPU v5e targets):
    compute    = HLO_FLOPs_per_device / 197e12          (bf16 MXU peak)
    memory     = HLO_bytes_per_device / 819e9           (HBM bandwidth)
    collective = collective_bytes_per_device / 50e9     (one ICI link, conservative)

plus MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per training step
(3x forward 2ND for fwd+bwd), and the usefulness ratio
MODEL_FLOPS / (HLO_FLOPs_per_device * chips), which exposes remat/dispatch
waste. For inference kinds the model term is 2*N*D_tokens (no backward).

    PYTHONPATH=src python -m repro.launch.roofline --in-dir experiments/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import get_arch, get_shape

PEAK_FLOPS = 197e12     # bf16 per chip
HBM_BW = 819e9          # B/s per chip
ICI_BW = 50e9           # B/s per link (conservative single-link)


# ------------------------------------------------- per-kernel cost model ----
# Analytic FLOPs / bytes-moved per kernel-family dispatch, shared between the
# whole-step analysis below and the per-dispatch profiler (repro.obs.prof),
# so "profiler bytes" and "roofline bytes" cannot drift apart — one formula,
# two consumers.  Bytes are the *mandatory* HBM traffic of the fused op:
# each operand read once at its storage width, the output written once.
# Pure functions of shapes + per-element byte widths: callers (obs.prof)
# extract those from the live arrays / pcsr operand slots.

def gemm_cost(m: float, k: float, n: float, *, a_bytes: float, b_bytes: float,
              out_bytes: float, bias: bool = False,
              residual: bool = False) -> dict:
    """(M,K) x (K,N) fused posit GEMM: decode + dot + epilogue, one launch."""
    byts = m * k * a_bytes + k * n * b_bytes + m * n * out_bytes
    if bias:
        byts += 4.0 * n              # f32 bias vector read
    if residual:
        byts += 4.0 * m * n          # f32 residual read fused into epilogue
    return {"flops": 2.0 * m * k * n, "bytes": float(byts)}


def attention_decode_cost(b: float, hq: float, hkv: float, s: float,
                          d: float, *, kv_bytes: float, q_bytes: float = 4.0,
                          out_bytes: float = 4.0) -> dict:
    """One flash-decode step over a (B,Hkv,S,d) posit-coded KV cache.

    ``s`` is the *allocated* cache length: the analytic bound charges the
    full slot grid (the ragged early-exit only helps past the longest live
    row, which the profiler cannot see from shapes alone)."""
    flops = 4.0 * b * hq * s * d     # q@k^T and p@v, 2 FLOPs/MAC each
    byts = (b * hq * d * (q_bytes + out_bytes)    # q read + out write
            + 2.0 * b * hkv * s * d * kv_bytes)   # K and V code streams
    return {"flops": float(flops), "bytes": float(byts)}


def codec_cost(n: float, *, code_bytes: float, value_bytes: float = 4.0) -> dict:
    """Streaming encode/decode of ``n`` elements (LUT gather / bit pipeline):
    pure memory movement — codes on one side, float values on the other."""
    return {"flops": float(n), "bytes": float(n * (code_bytes + value_bytes))}


def softmax_cost(rows: float, cols: float, *, code_bytes: float) -> dict:
    """Posit-domain softmax over (rows, cols) codes: codes in, codes out;
    ~5 vector ops per element (max, sub, exp, sum, div)."""
    n = rows * cols
    return {"flops": 5.0 * n, "bytes": 2.0 * n * code_bytes}


def bound_times(flops: float, byts: float, coll_bytes: float = 0.0) -> dict:
    """Roofline time terms for one dispatch (or one whole step) on the
    TPU-v5e targets above, plus which term binds."""
    terms = {"compute": flops / PEAK_FLOPS, "memory": byts / HBM_BW,
             "collective": coll_bytes / ICI_BW}
    dominant = max(terms, key=terms.get)
    return {"t_compute_s": terms["compute"], "t_memory_s": terms["memory"],
            "t_collective_s": terms["collective"], "dominant": dominant,
            "bound_s": terms[dominant]}


def param_count(cfg) -> tuple[float, float]:
    """(total, active) parameter counts, embedding included once."""
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab
    hd, H, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv
    att = d * (H * hd) + 2 * d * (Hkv * hd) + (H * hd) * d
    if cfg.family == "moe":
        per_expert = 3 * d * cfg.d_ff
        mlp_total = cfg.n_experts * per_expert + d * cfg.n_experts
        mlp_active = cfg.top_k * per_expert + d * cfg.n_experts
        block_t, block_a = att + mlp_total, att + mlp_active
        total = L * block_t + V * d * (1 if cfg.tie_embeddings else 2)
        active = L * block_a + V * d * (1 if cfg.tie_embeddings else 2)
        return float(total), float(active)
    if cfg.family == "zamba":
        di = 2 * d
        ssm = d * (2 * di + 2 * cfg.ssm_state + di // cfg.ssm_head_dim) + di * d
        shared = att + 3 * d * cfg.d_ff
        n_shared = max(1, cfg.n_layers // max(cfg.shared_attn_every, 1))
        total = L * ssm + shared + V * d * 2
        # shared block runs n_shared times: count FLOPs-active accordingly
        active = L * ssm + n_shared * shared + V * d * 2
        return float(total), float(active)
    if cfg.family == "xlstm":
        di = int(d * 2.0)
        mlstm = d * 2 * di + 3 * di * di + 2 * di * cfg.n_heads + di * d
        slstm = d * 4 * d + d * d // cfg.n_heads * 4 + 2 * d * int(d * 4 / 3)
        n_s = sum(1 for i in range(L) if cfg.slstm_every and i % cfg.slstm_every == 1)
        total = (L - n_s) * mlstm + n_s * slstm + V * d * 2
        return float(total), float(total)
    if cfg.family == "whisper":
        enc = cfg.enc_layers * (att + 2 * d * cfg.d_ff)
        dec = L * (2 * att + 2 * d * cfg.d_ff)
        total = enc + dec + V * d
        return float(total), float(total)
    mlp = 3 * d * cfg.d_ff
    total = L * (att + mlp) + V * d * (1 if cfg.tie_embeddings else 2)
    return float(total), float(total)


def model_flops(cfg, shape) -> float:
    """6*N_active*tokens for train, 2*N_active*tokens for inference."""
    _, active = param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    # decode: one token per sequence
    return 2.0 * active * shape.global_batch


def analyse(rec: dict, probe: dict | None = None) -> dict:
    """probe: matching scan-aware cost probe (launch.costprobe) — preferred
    over the raw compiled numbers, which count while-loop bodies once."""
    if rec.get("skipped") or rec.get("error"):
        return rec
    cfg = get_arch(rec["arch"])
    shape = get_shape(rec["shape"])
    chips = rec["n_chips"]
    if probe and not probe.get("error"):
        fl = probe["flops_per_device"]
        by = probe["bytes_per_device"]
        coll = probe["coll_per_device"]
    else:
        fl = rec["flops_per_device"]
        by = rec["bytes_per_device"]
        coll = sum(v["bytes"] for v in rec.get("collectives", {}).values())

    bt = bound_times(fl, by, coll)
    t_compute, t_memory, t_coll = (bt["t_compute_s"], bt["t_memory_s"],
                                   bt["t_collective_s"])
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = bt["dominant"]
    mf = model_flops(cfg, shape)
    hlo_global = fl * chips
    out = dict(rec)
    out.pop("collectives", None)
    out.update({
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": (mf / hlo_global) if hlo_global else 0.0,
        "collective_bytes": coll,
        "probe_corrected": bool(probe and not probe.get("error")),
        "roofline_fraction": (
            max(terms.values()) and
            (mf / chips / PEAK_FLOPS) / max(terms.values())),
    })
    return out


def fmt_row(a: dict) -> str:
    if a.get("skipped"):
        return (f"| {a['arch']} | {a['shape']} | — | — | — | — | skipped | "
                f"{a['skipped']} |")
    if a.get("error"):
        return f"| {a['arch']} | {a['shape']} | ERROR: {a['error'][:60]} |"
    return ("| {arch} | {shape} | {t_compute_s:.4f} | {t_memory_s:.4f} | "
            "{t_collective_s:.4f} | {useful_ratio:.2f} | {dominant} | "
            "{roofline_fraction:.2f} |").format(**a)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--in-dir", default="experiments/dryrun")
    ap.add_argument("--probe-dir", default="experiments/probe")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)

    rows = []
    for fn in sorted(glob.glob(os.path.join(args.in_dir, f"*__{args.mesh}.json"))):
        if os.path.basename(fn).startswith("SUMMARY"):
            continue
        with open(fn) as f:
            rec = json.load(f)
        probe = None
        pfn = os.path.join(
            args.probe_dir,
            f"{rec.get('arch')}__{rec.get('shape')}__{args.mesh}.json")
        if os.path.exists(pfn):
            with open(pfn) as f:
                probe = json.load(f)
        rows.append(analyse(rec, probe))
    # the markdown table IS this tool's product: a human-facing report,
    # deliberately outside the machine-readable §14 stdout protocol
    print("| arch | shape | t_compute | t_memory | t_collective | useful "  # repro: noqa=RA003
          "| dominant | roofline_frac |")
    print("|---|---|---|---|---|---|---|---|")  # repro: noqa=RA003
    for a in rows:
        print(fmt_row(a))  # repro: noqa=RA003
    n_probe = sum(1 for a in rows if a.get("probe_corrected"))
    print(f"\n({n_probe}/{len(rows)} cells probe-corrected; times in seconds "  # repro: noqa=RA003
          "per step on 256 chips)")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
