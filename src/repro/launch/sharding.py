"""Sharding rules: parameter pytrees, batches, and serving caches -> PartitionSpecs.

Scheme (DESIGN.md §5):
  * FSDP: the *input* feature dim of every weight matrix shards over "data"
  * TP (Megatron pairing): column-parallel out-dims over "model"
    (wq/wk/wv, gate/up, in_proj, lm_head), row-parallel in-dims over "model"
    (wo, down, out_proj) with the complementary dim on "data"
  * EP: expert-count dim of MoE weights over "model"
  * DP: batch dims over ("pod", "data")
  * pod axis: parameters replicated across pods (gradient sync crosses pods)

Every rule degrades gracefully: an axis is only used when the dim divides the
axis size, otherwise that dim stays unsharded (e.g. granite's vocab 49155).
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelCfg, ShapeCfg
from repro.launch.mesh import batch_axes


def _div(dim: int, mesh, axis) -> Optional[str]:
    """axis name if it divides dim, else None."""
    if axis is None or axis not in mesh.axis_names:
        return None
    return axis if dim % mesh.shape[axis] == 0 else None


def _matmul_spec(path: str, shape, mesh) -> P:
    """Spec for a (possibly layer-stacked, possibly posit-coded) weight."""
    core = shape[-2:] if len(shape) >= 2 else shape
    col_parallel = re.search(
        r"(wq|wk|wv|gate|up|in_proj|wx|ffn_up|lm_head|patch_proj|frame_proj"
        r"|wi|wf)/(w|w_codes)$", path) is not None
    row_parallel = re.search(
        r"(wo|down|out_proj|ffn_down)/(w|w_codes)$", path) is not None
    if col_parallel:
        spec = (_div(core[0], mesh, "data"), _div(core[1], mesh, "model"))
    elif row_parallel:
        spec = (_div(core[0], mesh, "model"), _div(core[1], mesh, "data"))
    else:  # e.g. router, generic 2D
        spec = (_div(core[0], mesh, "data"), _div(core[1], mesh, "model"))
    lead = (None,) * (len(shape) - 2)
    return P(*lead, *spec)


def param_spec(path: str, shape, mesh) -> P:
    # optimizer-state leaves mirror their parameter's sharding: strip the
    # moment suffix ("…/w/m", "…/w/v", "…/w/em", "…/w/ev" -> "…/w")
    m = re.match(r"^(?:mu/)?(.*)/(m|v|em|ev)$", path)
    if m:
        path = m.group(1)
    nd = len(shape)
    # --- embeddings ---------------------------------------------------------
    if path.endswith("embed/table"):
        return P(_div(shape[0], mesh, "model"), _div(shape[1], mesh, "data"))
    if "pos_embed" in path:
        return P(*(None,) * nd)
    # --- MoE experts (maybe stacked: (L, E, a, b)) ---------------------------
    if re.search(r"w_(gate|up)(_codes)?$", path):
        lead = (None,) * (nd - 3)
        return P(*lead, _div(shape[-3], mesh, "model"),
                 _div(shape[-2], mesh, "data"), None)
    if re.search(r"w_down(_codes)?$", path):
        lead = (None,) * (nd - 3)
        return P(*lead, _div(shape[-3], mesh, "model"), None,
                 _div(shape[-1], mesh, "data"))
    # --- ssm conv ------------------------------------------------------------
    if "conv_w" in path:
        return P(*(None,) * (nd - 1), _div(shape[-1], mesh, "model"))
    # --- biases: shard col-parallel outputs over model -----------------------
    if path.endswith("/b"):
        if re.search(r"(wq|wk|wv|gate|up|in_proj|wx|ffn_up)/b$", path):
            return P(*(None,) * (nd - 1), _div(shape[-1], mesh, "model"))
        return P(*(None,) * nd)
    # --- slstm recurrent kernel / per-head vectors / norms -------------------
    if nd >= 2 and path.endswith("/w") or path.endswith("_codes"):
        return _matmul_spec(path, shape, mesh)
    return P(*(None,) * nd)


def tree_param_specs(params_shape: Any, mesh) -> Any:
    """Pytree of PartitionSpecs matching a params (or ShapeDtypeStruct) tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        specs.append(param_spec(path, leaf.shape, mesh))
    return jax.tree_util.tree_unflatten(treedef, specs)


def tree_shardings(tree_specs: Any, mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------- batches -----

def batch_specs(cfg: ModelCfg, shape: ShapeCfg, mesh) -> dict:
    dp = batch_axes(mesh)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    bspec = dp if shape.global_batch % n_dp == 0 and shape.global_batch >= n_dp \
        else None
    out = {"tokens": P(bspec, None), "labels": P(bspec, None)}
    if cfg.family == "whisper":
        out["frames"] = P(bspec, None, None)
    if cfg.family == "vlm":
        out["patch_embeds"] = P(bspec, None, None)
    return out


def decode_token_spec(cfg: ModelCfg, shape: ShapeCfg, mesh) -> P:
    dp = batch_axes(mesh)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    return P(dp) if shape.global_batch % n_dp == 0 and shape.global_batch >= n_dp \
        else P(None)


# ------------------------------------------------------------------ caches ----

def _kv_spec(B: int, Hkv: int, S: int, hd: int, mesh, dp) -> P:
    """KV cache (B, Hkv, S, hd): batch over dp + model on heads (else head_dim);
    long-context B=1 falls back to sequence over data + model on heads/hd."""
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    if B % n_dp == 0 and B >= n_dp:
        if _div(Hkv, mesh, "model"):
            return P(dp, "model", None, None)
        # few KV heads: shard the *sequence* over model. Sharding head_dim
        # instead puts the contraction dim on "model" and costs a per-layer
        # all-reduce of the full (B,Hkv,g,T) score tensor (~0.7 GB/layer at
        # decode_32k); with T sharded the only psum is the (B,Hkv,g,hd)
        # output (~3 MB) plus scalar softmax reductions. (§Perf iteration)
        return P(dp, None, _div(S, mesh, "model"), None)
    seq = _div(S, mesh, "data")
    if _div(Hkv, mesh, "model"):
        return P(None, "model", seq, None)
    return P(None, None, seq, _div(hd, mesh, "model"))


# base (unstacked) rank of each cache leaf kind; any extra leading dims are
# layer-stack dims (vmapped init) and stay unsharded
_CACHE_RANKS = {"k": 4, "v": 4, "h": 4, "conv": 3, "C": 4, "n": 3, "m": 2,
                "c": 3, "len": 1}


def cache_specs(cache_shape: Any, cfg: ModelCfg, mesh) -> Any:
    """Specs for a serving-cache pytree (built with jax.eval_shape)."""
    dp = batch_axes(mesh)

    def base_spec(kind: str, s) -> tuple:
        bdp = _first_div(s[0], mesh, dp)
        if kind in ("k", "v"):
            return tuple(_kv_spec(s[0], s[1], s[2], s[3], mesh, dp))
        if kind == "h":        # ssm state (B, nh, p, N)
            if bdp:
                return (bdp, _div(s[1], mesh, "model"), None, None)
            return (None, _div(s[1], mesh, "model"), _div(s[2], mesh, "data"),
                    None)
        if kind == "conv":     # (B, W-1, channels)
            return (bdp, None, _div(s[-1], mesh, "model"))
        if kind == "C":        # mlstm matrix state (B, nh, hd, hd)
            return (bdp, _div(s[1], mesh, "model"), None, None)
        if kind in ("n", "m", "c"):
            return (bdp,) + (None,) * (len(s) - 1)
        return (None,) * len(s)

    def leaf_spec(path: str, leaf):
        s = leaf.shape
        kind = path.rsplit("/", 1)[-1]
        if kind in ("n", "m", "c"):  # small per-head states, never stacked
            return P(_first_div(s[0], mesh, dp), *(None,) * (len(s) - 1))
        if kind not in _CACHE_RANKS or _CACHE_RANKS[kind] > len(s):
            return P(*(None,) * len(s))
        base = _CACHE_RANKS[kind]
        lead = len(s) - base
        return P(*(None,) * lead, *base_spec(kind, s[lead:]))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    specs = []
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        specs.append(leaf_spec(path, leaf))
    return jax.tree_util.tree_unflatten(treedef, specs)


def _first_div(dim: int, mesh, dp) -> Optional[tuple]:
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    return dp if dim % n_dp == 0 and dim >= n_dp else None
