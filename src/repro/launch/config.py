"""ServeConfig: the one typed surface for serving configuration.

serve.py grew ~30 loose argparse flags with cross-flag validation scattered
through ``main()``; hillclimb and the serving benchmarks each re-plumbed the
same engine kwargs by hand.  ``ServeConfig`` replaces that: a single
dataclass that

* round-trips as a versioned JSON document (``kind: "repro/serve-config"``,
  same header convention as ``PrecisionPolicy`` — unknown kinds, versions,
  and fields are rejected loudly, not guessed at);
* generates the CLI (:func:`add_cli_args` derives ``--flag`` names, types,
  choices, and help from the fields), so serve.py's parser cannot drift from
  the schema.  ``--config cfg.json`` loads a document and explicitly-passed
  flags override it (``argparse.SUPPRESS`` keeps unset flags out of the
  namespace entirely);
* owns the cross-field validation (:meth:`validate`) and the derived
  quantities (:meth:`s_max`);
* builds the serving objects (:meth:`build_policy`, :meth:`build_engine`) so
  serve.py, hillclimb, the benchmarks, and the HTTP server construct engines
  through one code path — the resolved config echoes in every
  ``serve/report`` line.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from typing import Optional

__all__ = ["ServeConfig", "add_cli_args", "config_from_args"]

_KIND = "repro/serve-config"
_VERSION = 1


def _f(default, help="", choices=None, cli=True):  # noqa: A002
    return dataclasses.field(default=default, metadata={
        "help": help, "choices": choices, "cli": cli})


@dataclasses.dataclass
class ServeConfig:
    """Everything a serving run needs, in one declared schema."""

    # ----- model / workload -----
    arch: str = _f(None, "architecture name (repro.configs.get_arch)")
    reduced: bool = _f(False, "use the reduced (CI-sized) config")
    batch: int = _f(4, "static batch size (and default --max-slots)")
    prompt_len: int = _f(32, "prompt length in tokens")
    gen: int = _f(16, "tokens to generate per request")
    policy: str = _f("none", "base TransPolicy spec (launch/dryrun grammar)")
    seed: int = _f(0, "PRNG seed (params, workload, sampler)")
    # ----- engine -----
    continuous: bool = _f(False, "continuous batching via launch/engine.py")
    paged: bool = _f(False, "paged prefix-sharing KV cache "
                            "(launch/paged_engine.py; implies --continuous)")
    page_bytes: int = _f(2048, "per-layer K+V bytes of one KV page "
                               "(paged mode; token capacity follows the "
                               "KV code width)")
    n_blocks: Optional[int] = _f(None, "KV pool size in blocks (paged mode; "
                                       "default: the slot grid's byte budget)")
    arrival_rate: float = _f(0.0, "Poisson arrival rate req/s (0 = all at t=0)")
    max_slots: Optional[int] = _f(None, "decode slot grid size (default: "
                                        "--batch)")
    requests: Optional[int] = _f(None, "requests to serve (default: 2*slots)")
    temperature: float = _f(0.0, "0 = greedy; >0 samples (with --top-k)")
    top_k: int = _f(0, "top-k truncation for sampling")
    deadline_s: Optional[float] = _f(None, "per-request wall-clock budget "
                                           "from arrival (finish_reason="
                                           "timeout past it)")
    # ----- precision -----
    precision_policy: Optional[str] = _f(
        None, "per-layer weight schedule: preset, pattern=fmt[@es][:packed] "
              "spec, or @artifact.json (core/policy.py)")
    calibrate: int = _f(0, "run N calibration passes and serve under the "
                           "searched dynamic-es policy (DESIGN.md §11)")
    policy_out: Optional[str] = _f(None, "write the calibration artifact "
                                         "JSON here")
    weight_byte_budget: Optional[str] = _f(
        None, "calibration byte budget: absolute bytes or '<mult>x' the "
              "p8 floor")
    quantize_weights: bool = _f(False, "store weights as real posit codes "
                                       "under the schedule")
    codec_impl: str = _f("auto", "codec lowering", choices=("auto", "lut",
                                                            "bits"))
    epilogue: str = _f("fused", "layer dataflow", choices=("fused", "chained"))
    attn_impl: str = _f("auto", "decode attention dispatch",
                        choices=("auto", "kernel", "xla"))
    # ----- observability -----
    metrics_out: Optional[str] = _f(None, "metrics snapshot JSON path "
                                          "(+ <path>.prom exposition)")
    trace_out: Optional[str] = _f(None, "Chrome-trace/Perfetto timeline path")
    profile_out: Optional[str] = _f(None, "per-kernel roofline-attribution "
                                          "report path (JSON + .md)")
    numerics_watch: int = _f(0, "probe every N-th decode step for posit "
                                "saturation/underflow/NaR and drift")
    # ----- fault tolerance -----
    snapshot_every: int = _f(0, "crash-safe engine snapshot every N steps")
    snapshot_dir: Optional[str] = _f(None, "checkpoint directory for "
                                          "snapshots / --resume")
    resume: bool = _f(False, "restore the newest snapshot and continue")
    degrade: bool = _f(False, "numerics-driven precision degradation ladder")
    chaos_preempt_step: Optional[int] = _f(None, "fault injection: SIGTERM "
                                                 "at decode step N")
    # ----- request plane (launch/server.py) -----
    host: str = _f("127.0.0.1", "HTTP server bind address")
    port: int = _f(8100, "HTTP server port")
    max_queue: int = _f(64, "admission queue bound; beyond it requests get "
                            "429 (backpressure)")

    # ------------------------------------------------------------- schema ----
    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        return {"kind": _KIND, "version": _VERSION, **d}

    @classmethod
    def from_json(cls, d: dict) -> "ServeConfig":
        if d.get("kind") != _KIND:
            raise ValueError(f"not a serve-config document: kind="
                             f"{d.get('kind')!r} (want {_KIND!r})")
        if int(d.get("version", 1)) != _VERSION:
            raise ValueError(
                f"serve-config v{d.get('version')} is not v{_VERSION}; "
                f"refusing to guess at an unknown schema")
        body = {k: v for k, v in d.items() if k not in ("kind", "version")}
        known = {f.name for f in dataclasses.fields(cls)}
        bad = set(body) - known
        if bad:
            raise ValueError(f"unknown serve-config fields {sorted(bad)} "
                             f"(hand-edited document? schema is v{_VERSION})")
        return cls(**body)

    @classmethod
    def load(cls, path: str) -> "ServeConfig":
        with open(path) as f:
            return cls.from_json(json.load(f))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)

    # --------------------------------------------------------- validation ----
    def validate(self) -> "ServeConfig":
        """Cross-field checks (raises ValueError with a CLI-ready message)."""
        if not self.arch:
            raise ValueError("--arch is required (or 'arch' in --config)")
        if self.paged and not self.continuous:
            raise ValueError("--paged rides the continuous-batching engine; "
                             "add --continuous")
        if not self.calibrate and (self.policy_out or self.weight_byte_budget):
            raise ValueError(
                "--policy-out / --weight-byte-budget require --calibrate N "
                "(they configure the calibration search; a loaded "
                "--precision-policy artifact is served as saved)")
        if not self.continuous and (self.trace_out or self.numerics_watch):
            raise ValueError(
                "--trace-out / --numerics-watch instrument the continuous-"
                "batching engine; add --continuous")
        if (self.snapshot_every or self.resume) and not self.snapshot_dir:
            raise ValueError("--snapshot-every / --resume need --snapshot-dir")
        if self.resume and not self.snapshot_every:
            raise ValueError("--resume needs --snapshot-every N (the resumed "
                             "run keeps snapshotting)")
        if self.snapshot_every and not self.continuous:
            raise ValueError("--snapshot-every snapshots the continuous-"
                             "batching engine; add --continuous")
        if self.degrade and not self.numerics_watch:
            raise ValueError("--degrade consumes the numerics watcher's "
                             "health rows; add --numerics-watch N")
        if self.chaos_preempt_step is not None and not self.snapshot_every:
            raise ValueError("--chaos-preempt-step kills a snapshotting run; "
                             "add --snapshot-every N (and --snapshot-dir)")
        if self.deadline_s is not None and not self.continuous:
            raise ValueError("--deadline-s is enforced by the continuous-"
                             "batching engine; add --continuous")
        return self

    # ------------------------------------------------------------ builders ---
    def arch_cfg(self):
        from repro.configs import get_arch
        cfg = get_arch(self.arch)
        return cfg.reduced() if self.reduced else cfg

    def s_max(self, cfg) -> int:
        """Cache rows per slot: prompt + generation budget, plus the patch
        prefix for vlm rows (it lives in the same cache)."""
        return self.prompt_len + self.gen + \
            (cfg.n_patches if cfg.family == "vlm" else 0)

    def build_policy(self, base=None):
        """(TransPolicy-or-PrecisionPolicy, drift_meta) from the precision
        fields — the one resolution path serve.py / hillclimb / benches use.
        ``base`` overrides the ``policy`` spec with an already-built
        TransPolicy (hillclimb's variant table hands these in directly)."""
        from repro.core.policy import get_precision_policy
        from repro.launch.train import _parse_policy
        policy = dataclasses.replace(
            base if base is not None else _parse_policy(self.policy),
            codec_impl=self.codec_impl, epilogue=self.epilogue,
            attn_impl=self.attn_impl)
        drift_meta = None
        if self.precision_policy:
            policy = get_precision_policy(self.precision_policy, base=policy)
            if self.precision_policy.startswith("@"):
                with open(self.precision_policy[1:]) as f:
                    drift_meta = json.load(f)
        return policy, drift_meta

    def build_engine(self, model, params, policy, **sinks):
        """Construct the serving engine this config describes.

        ``sinks`` forwards the observability / ft keywords
        (``metrics=``, ``tracer=``, ``numerics=``, ``snapshotter=``,
        ``watchdog=``, ``faults=``, ``prefill_kwargs=``, ...).
        """
        from repro.launch.engine import ContinuousBatchingEngine
        common = dict(max_slots=self.max_slots or self.batch,
                      S_max=self.s_max(model.cfg),
                      temperature=self.temperature, top_k=self.top_k,
                      seed=self.seed, deadline_s=self.deadline_s, **sinks)
        if self.paged:
            from repro.launch.paged_engine import PagedContinuousBatchingEngine
            return PagedContinuousBatchingEngine(
                model, params, policy, page_bytes=self.page_bytes,
                n_blocks=self.n_blocks, **common)
        return ContinuousBatchingEngine(model, params, policy, **common)


# ------------------------------------------------------------------- CLI ----

def add_cli_args(ap: argparse.ArgumentParser) -> None:
    """Derive the serve CLI from the ServeConfig schema (one flag per field;
    bools are ``store_true``).  Defaults are ``argparse.SUPPRESS`` so
    :func:`config_from_args` can tell "flag passed" from "flag at default"
    and layer overrides on a ``--config`` document."""
    for f in dataclasses.fields(ServeConfig):
        if not f.metadata.get("cli", True):
            continue
        flag = "--" + f.name.replace("_", "-")
        help_ = f.metadata.get("help", "")
        choices = f.metadata.get("choices")
        if f.type in ("bool", bool):
            ap.add_argument(flag, action="store_true",
                            default=argparse.SUPPRESS, help=help_)
            continue
        typ = {"int": int, "float": float, "str": str,
               "Optional[int]": int, "Optional[float]": float,
               "Optional[str]": str}.get(
                   f.type if isinstance(f.type, str) else f.type.__name__,
                   str)
        ap.add_argument(flag, type=typ, choices=choices,
                        default=argparse.SUPPRESS, help=help_)


def config_from_args(args: argparse.Namespace,
                     base: Optional[ServeConfig] = None) -> ServeConfig:
    """Layer explicitly-passed flags over ``base`` (a ``--config`` document)
    or the schema defaults."""
    cfg = base if base is not None else ServeConfig()
    known = {f.name for f in dataclasses.fields(ServeConfig)}
    overrides = {k: v for k, v in vars(args).items() if k in known}
    return dataclasses.replace(cfg, **overrides)
