"""Production mesh construction (pure function — importing this module never
touches jax device state).

Single pod:  (16, 16)      ("data", "model")   = 256 chips (one v5e pod)
Multi pod:   (2, 16, 16)   ("pod", "data", "model") = 512 chips
Production scales the leading "pod" axis (N pods = N x 256 chips); every
sharding rule below only names axes, so the same config runs at any pod count.
"""
from __future__ import annotations

import numpy as np


def make_mesh_compat(shape, axes, devices=None):
    """``jax.make_mesh`` across jax versions (added ~0.4.35; the oldest
    supported pin predates it).  The fallback builds the Mesh directly from
    the device array — equivalent for explicit host-platform device lists
    (make_mesh's extra work is physical-topology-aware ordering, which has
    no effect on CPU meshes)."""
    import jax

    if devices is None:
        devices = jax.devices()[: int(np.prod(shape))]
    mk = getattr(jax, "make_mesh", None)
    if mk is not None:
        return mk(shape, axes, devices=devices)
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run under dryrun.py (it sets xla_force_host_platform_device_count)")
    return make_mesh_compat(shape, axes, devices=devices[:n])


def batch_axes(mesh) -> tuple:
    """The data-parallel axes (pod included when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1
