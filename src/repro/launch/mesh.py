"""Production mesh construction (pure function — importing this module never
touches jax device state).

Single pod:  (16, 16)      ("data", "model")   = 256 chips (one v5e pod)
Multi pod:   (2, 16, 16)   ("pod", "data", "model") = 512 chips
Production scales the leading "pod" axis (N pods = N x 256 chips); every
sharding rule below only names axes, so the same config runs at any pod count.
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run under dryrun.py (it sets xla_force_host_platform_device_count)")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def batch_axes(mesh) -> tuple:
    """The data-parallel axes (pod included when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1
