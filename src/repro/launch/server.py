"""Async streaming request plane over the continuous-batching engine.

A dependency-free asyncio HTTP/1.1 server (stdlib only — ``asyncio`` streams,
``hashlib``/``base64`` for the RFC 6455 WebSocket handshake) that exposes the
engine client API (engine.py: ``submit() -> rid``, ``subscribe``/``stream``,
``cancel``) over the wire:

====================  ========================================================
``POST /v1/generate``   body ``{"prompt": [ids], "max_new_tokens": N,
                        "deadline_s": x?, "stream": bool?, "detach": bool?}``.
                        Non-streaming: responds with the finished
                        ``Completion`` JSON (schema v1, engine.py).
                        ``"stream": true``: chunked NDJSON — one
                        ``{"event": "token"|"finish", ...}`` object per
                        line, exactly the subscribe() events.
                        ``"detach": true``: 202 + ``{"rid": N}`` right away;
                        attach a WebSocket for the tokens.
``GET /v1/stream``      WebSocket upgrade (``?rid=N``): every subscribe()
                        event as one text frame; closes after ``finish``.
                        A late upgrade replays the full stream (engine
                        subscribe semantics).
``POST /v1/cancel``     body ``{"rid": N}`` — cancels queued or mid-flight.
``GET /v1/stats``       engine occupancy, queue depth, prefix-cache stats,
                        resolved ServeConfig.
``GET /healthz``        liveness (200 once the engine thread runs).
``GET /metrics``        Prometheus text exposition of the engine metrics.
====================  ========================================================

Threading model: the engine is single-threaded by design (one JAX device
stream), so ALL engine mutation happens on one background *drive thread*
running the admit/step loop.  Handlers never touch the engine directly —
they post closures onto a thread-safe op inbox (``submit``, ``cancel``)
and get results back through ``concurrent.futures.Future``; token streams
ride the engine's thread-safe subscriber queues, bridged into coroutines
with ``asyncio.to_thread``.

Backpressure: when the admission queue (queued requests + unprocessed ops)
reaches ``ServeConfig.max_queue``, ``/v1/generate`` answers ``429
queue_full`` instead of enqueueing — the client retries, the engine never
builds an unbounded backlog.  A client that disconnects mid-stream gets its
request cancelled (slot evicted, blocks released) on the next drive tick.

    PYTHONPATH=src python -m repro.launch.server --arch yi-34b --reduced \
        --continuous --paged --port 8100

    curl -s localhost:8100/v1/generate -d \
        '{"prompt": [1,2,3], "max_new_tokens": 8}'
"""
from __future__ import annotations

import argparse
import asyncio
import base64
import dataclasses
import concurrent.futures
import hashlib
import json
import queue as queue_mod
import threading
import time
from typing import Optional

import numpy as np

from repro.launch.config import ServeConfig, add_cli_args, config_from_args
from repro.launch.engine import Request

_WS_GUID = "258EAFA5-E914-47DA-95CA-C5AB0DC85B11"


# ------------------------------------------------------------ engine bridge --

class EngineDriver:
    """Owns the drive thread: the only thread that mutates the engine."""

    def __init__(self, engine, max_queue: int):
        self.engine = engine
        self.max_queue = max_queue
        self._ops: queue_mod.Queue = queue_mod.Queue()
        self._stop = threading.Event()
        self._rid_lock = threading.Lock()
        self._next_rid = 0
        self._thread = threading.Thread(target=self._drive, daemon=True,
                                        name="engine-drive")
        self._t0 = time.perf_counter()

    def start(self) -> None:
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        self._thread.join(timeout=10)

    def clock(self) -> float:
        return time.perf_counter() - self._t0

    def _drive(self) -> None:
        eng = self.engine
        while not self._stop.is_set():
            progressed = False
            while True:
                try:
                    op = self._ops.get_nowait()
                except queue_mod.Empty:
                    break
                op(self.clock())
                progressed = True
            try:
                if eng.queue and eng.free_slots():
                    eng.admit(clock=self.clock)
                    progressed = True
                if eng.active.any():
                    eng.step(now=self.clock())
                    progressed = True
            except Exception as e:  # noqa: BLE001 — the plane must survive
                # one poisoned request must not kill serving for everyone:
                # drop the queue head (admit raises before installing it),
                # terminate its stream, keep driving
                print(json.dumps({"kind": "server/error", "error": str(e)}),
                      flush=True)
                if eng.queue:
                    bad = eng.queue.pop(0)
                    for q in eng._subs.get(bad.rid, ()):
                        q.put({"event": "finish", "rid": bad.rid,
                               "finish_reason": "error", "n_tokens": 0})
            if not progressed:
                time.sleep(0.001)

    # ----------------------------------------------------------- client ops --
    def queue_depth(self) -> int:
        return len(self.engine.queue) + self._ops.qsize()

    def submit(self, prompt, max_new_tokens: int,
               deadline_s: Optional[float]):
        """Thread-safe submit+subscribe; returns a Future of (rid, sub_q).

        Subscribing inside the same op as the submit makes the pair atomic
        on the drive thread — no token can be emitted between them, so the
        stream is complete from index 0 without replay races.
        """
        with self._rid_lock:
            rid = self._next_rid
            self._next_rid += 1
        fut: concurrent.futures.Future = concurrent.futures.Future()

        def op(now: float) -> None:
            req = Request(rid=rid,
                          prompt=np.asarray(prompt, np.int32),
                          max_new_tokens=max_new_tokens,
                          arrival_time=now, deadline_s=deadline_s)
            self.engine.submit(req)
            fut.set_result((rid, self.engine.subscribe(rid)))

        self._ops.put(op)
        return fut

    def cancel(self, rid: int) -> "concurrent.futures.Future":
        fut: concurrent.futures.Future = concurrent.futures.Future()
        self._ops.put(lambda now: fut.set_result(
            self.engine.cancel(rid, now=now)))
        return fut

    def unsubscribe(self, rid: int, q) -> None:
        self._ops.put(lambda now: self.engine.unsubscribe(rid, q))

    def stats(self) -> dict:
        eng = self.engine
        d = {"active_slots": int(eng.active.sum()),
             "max_slots": eng.max_slots,
             "queued": len(eng.queue),
             "completions": len(eng.completions),
             "queue_depth": self.queue_depth(),
             "max_queue": self.max_queue}
        if hasattr(eng, "prefix_stats"):
            d["prefix_cache"] = eng.prefix_stats()
        return d


# ------------------------------------------------------------------- server --

class ServingServer:
    """Asyncio HTTP/1.1 + WebSocket front end over an :class:`EngineDriver`."""

    def __init__(self, engine, scfg: ServeConfig, metrics=None):
        self.scfg = scfg
        self.metrics = metrics
        self.driver = EngineDriver(engine, scfg.max_queue)
        self._server: Optional[asyncio.AbstractServer] = None

    async def start(self) -> None:
        self.driver.start()
        self._server = await asyncio.start_server(
            self._handle, self.scfg.host, self.scfg.port)
        # the bound port (port=0 picks a free one — the integration test uses
        # this) is authoritative, not the requested one
        self.port = self._server.sockets[0].getsockname()[1]
        print(json.dumps({"kind": "server/start", "host": self.scfg.host,
                          "port": self.port,
                          "config": self.scfg.to_json()}), flush=True)

    async def serve_forever(self) -> None:
        async with self._server:
            await self._server.serve_forever()

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        self.driver.stop()

    # -------------------------------------------------------------- http ----
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            request_line = await reader.readline()
            if not request_line:
                return
            try:
                method, target, _ = request_line.decode().split(" ", 2)
            except ValueError:
                await _respond(writer, 400, {"error": "bad request line"})
                return
            headers = {}
            while True:
                line = await reader.readline()
                if line in (b"\r\n", b"\n", b""):
                    break
                k, _, v = line.decode().partition(":")
                headers[k.strip().lower()] = v.strip()
            body = b""
            n = int(headers.get("content-length", 0))
            if n:
                body = await reader.readexactly(n)
            path, _, query = target.partition("?")
            params = dict(p.split("=", 1) for p in query.split("&") if "=" in p)
            await self._route(method, path, params, headers, body,
                              reader, writer)
        except (ConnectionResetError, asyncio.IncompleteReadError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError, OSError):
                pass

    async def _route(self, method, path, params, headers, body,
                     reader, writer) -> None:
        if path == "/healthz":
            await _respond(writer, 200, {"ok": True})
        elif path == "/metrics":
            if self.metrics is None:
                await _respond(writer, 404, {"error": "no metrics registry"})
            else:
                await _respond_text(writer, 200, self.metrics.prometheus(),
                                    ctype="text/plain; version=0.0.4")
        elif path == "/v1/stats":
            await _respond(writer, 200,
                           {**self.driver.stats(),
                            "config": self.scfg.to_json()})
        elif path == "/v1/cancel" and method == "POST":
            d = json.loads(body or b"{}")
            ok = await asyncio.wrap_future(self.driver.cancel(int(d["rid"])))
            await _respond(writer, 200, {"cancelled": ok})
        elif path == "/v1/generate" and method == "POST":
            await self._generate(body, reader, writer)
        elif path == "/v1/stream" and \
                headers.get("upgrade", "").lower() == "websocket":
            await self._websocket(params, headers, reader, writer)
        else:
            await _respond(writer, 404, {"error": f"no route {method} {path}"})

    async def _generate(self, body: bytes, reader: asyncio.StreamReader,
                        writer: asyncio.StreamWriter) -> None:
        try:
            d = json.loads(body)
            prompt = d["prompt"]
            if not (isinstance(prompt, list) and prompt and
                    all(isinstance(t, int) for t in prompt)):
                raise ValueError("prompt must be a non-empty list of int ids")
            max_new = int(d.get("max_new_tokens", self.scfg.gen))
            s_max = self.driver.engine.S_max
            if max_new < 1 or len(prompt) + max_new > s_max:
                raise ValueError(
                    f"prompt {len(prompt)} + max_new_tokens {max_new} "
                    f"exceeds this server's S_max {s_max}")
        except (ValueError, KeyError, TypeError) as e:
            await _respond(writer, 400, {"error": str(e)})
            return
        if self.driver.queue_depth() >= self.scfg.max_queue:
            # backpressure: bounded admission queue, client retries
            await _respond(writer, 429, {"error": "queue_full",
                                         "queue_depth":
                                         self.driver.queue_depth()})
            return
        rid, sub = await asyncio.wrap_future(self.driver.submit(
            prompt, max_new, d.get("deadline_s", self.scfg.deadline_s)))
        if d.get("detach"):
            # submit-only: hand back the rid; the client attaches a
            # WebSocket (GET /v1/stream?rid=N) for the token stream
            self.driver.unsubscribe(rid, sub)
            await _respond(writer, 202, {"rid": rid})
        elif d.get("stream"):
            await self._stream_ndjson(rid, sub, reader, writer)
        else:
            await self._await_completion(rid, sub, reader, writer)

    async def _next_event(self, sub, eof: "asyncio.Task"):
        """Next subscriber event, or None when the client hung up first.

        ``sub.get`` polls with a bounded timeout (an abandoned stream must
        not wedge a worker thread forever), and ``eof`` — a read() on the
        client socket — resolves the moment the peer closes, so disconnects
        are noticed even while the stream is idle between tokens.
        """
        while True:
            if eof.done():
                return None
            try:
                return await asyncio.to_thread(sub.get, True, 0.1)
            except queue_mod.Empty:
                continue

    async def _await_completion(self, rid, sub, reader, writer) -> None:
        eof = asyncio.ensure_future(reader.read())
        try:
            while True:
                ev = await self._next_event(sub, eof)
                if ev is None:       # disconnect while we were generating
                    await asyncio.wrap_future(self.driver.cancel(rid))
                    return
                if ev["event"] == "finish":
                    break
            comp = self.driver.engine.result(rid)
            await _respond(writer, 200, comp.to_json() if comp is not None
                           else {"rid": rid, "finish_reason": "cancel",
                                 "tokens": []})
        finally:
            eof.cancel()
            self.driver.unsubscribe(rid, sub)

    async def _stream_ndjson(self, rid, sub, reader, writer) -> None:
        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: application/x-ndjson\r\n"
                     b"Transfer-Encoding: chunked\r\n\r\n")
        eof = asyncio.ensure_future(reader.read())
        try:
            while True:
                ev = await self._next_event(sub, eof)
                if ev is None:
                    # client went away mid-stream: evict, free slot/blocks
                    await asyncio.wrap_future(self.driver.cancel(rid))
                    return
                chunk = (json.dumps(ev) + "\n").encode()
                writer.write(f"{len(chunk):x}\r\n".encode() + chunk + b"\r\n")
                await writer.drain()
                if ev["event"] == "finish":
                    writer.write(b"0\r\n\r\n")
                    await writer.drain()
                    return
        except (ConnectionResetError, BrokenPipeError, OSError):
            await asyncio.wrap_future(self.driver.cancel(rid))
        finally:
            eof.cancel()
            self.driver.unsubscribe(rid, sub)

    # --------------------------------------------------------- websocket ----
    async def _websocket(self, params, headers, reader, writer) -> None:
        key = headers.get("sec-websocket-key", "")
        accept = base64.b64encode(hashlib.sha1(
            (key + _WS_GUID).encode()).digest()).decode()
        writer.write((f"HTTP/1.1 101 Switching Protocols\r\n"
                      f"Upgrade: websocket\r\nConnection: Upgrade\r\n"
                      f"Sec-WebSocket-Accept: {accept}\r\n\r\n").encode())
        await writer.drain()
        rid = int(params.get("rid", -1))
        sub = self.driver.engine.subscribe(rid) if rid >= 0 else None
        if sub is None:
            await _ws_send(writer, json.dumps({"error": "missing rid"}))
            return
        closer = asyncio.ensure_future(_ws_read_until_close(reader, writer))
        try:
            while True:
                ev = await self._next_event(sub, closer)
                if ev is None:
                    # peer closed (or dropped) the socket mid-stream
                    await asyncio.wrap_future(self.driver.cancel(rid))
                    return
                await _ws_send(writer, json.dumps(ev))
                if ev["event"] == "finish":
                    writer.write(b"\x88\x00")  # close frame
                    await writer.drain()
                    return
        except (ConnectionResetError, BrokenPipeError, OSError):
            await asyncio.wrap_future(self.driver.cancel(rid))
        finally:
            closer.cancel()
            self.driver.unsubscribe(rid, sub)


async def _ws_send(writer: asyncio.StreamWriter, text: str) -> None:
    payload = text.encode()
    n = len(payload)
    if n < 126:
        head = bytes([0x81, n])
    elif n < 1 << 16:
        head = b"\x81\x7e" + n.to_bytes(2, "big")
    else:
        head = b"\x81\x7f" + n.to_bytes(8, "big")
    writer.write(head + payload)
    await writer.drain()


async def _ws_read_until_close(reader: asyncio.StreamReader,
                               writer: asyncio.StreamWriter) -> None:
    """Consume client frames (pong pings) until a close frame or EOF."""
    try:
        while True:
            head = await reader.readexactly(2)
            opcode = head[0] & 0x0F
            masked = head[1] & 0x80
            n = head[1] & 0x7F
            if n == 126:
                n = int.from_bytes(await reader.readexactly(2), "big")
            elif n == 127:
                n = int.from_bytes(await reader.readexactly(8), "big")
            mask = await reader.readexactly(4) if masked else b"\0\0\0\0"
            data = bytes(b ^ mask[i % 4]
                         for i, b in enumerate(await reader.readexactly(n)))
            if opcode == 0x8:        # close
                return
            if opcode == 0x9:        # ping -> pong
                writer.write(b"\x8a" + bytes([len(data)]) + data)
                await writer.drain()
    except (asyncio.IncompleteReadError, ConnectionResetError, OSError):
        return


async def _respond(writer, status: int, obj: dict) -> None:
    await _respond_text(writer, status, json.dumps(obj),
                        ctype="application/json")


async def _respond_text(writer, status: int, text: str,
                        ctype: str = "text/plain") -> None:
    phrase = {200: "OK", 202: "Accepted", 400: "Bad Request",
              404: "Not Found", 429: "Too Many Requests"}.get(status, "")
    payload = text.encode()
    writer.write((f"HTTP/1.1 {status} {phrase}\r\n"
                  f"Content-Type: {ctype}\r\n"
                  f"Content-Length: {len(payload)}\r\n"
                  f"Connection: close\r\n\r\n").encode() + payload)
    await writer.drain()


# --------------------------------------------------------------------- main --

def build_server(scfg: ServeConfig) -> ServingServer:
    """Model + engine + server from one validated ServeConfig."""
    import jax

    from repro.models.registry import build_model
    from repro.obs.metrics import MetricsRegistry

    cfg = scfg.arch_cfg()
    policy, _ = scfg.build_policy()
    model = build_model(cfg)
    params = model.init(jax.random.key(scfg.seed))
    metrics = MetricsRegistry()
    engine = scfg.build_engine(model, params, policy, metrics=metrics)
    return ServingServer(engine, scfg, metrics=metrics)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default=None, metavar="CFG.json",
                    help="ServeConfig JSON document; flags override")
    add_cli_args(ap)
    ns = ap.parse_args(argv)
    try:
        base = ServeConfig.load(ns.config) if ns.config else None
        scfg = config_from_args(ns, base=base)
        # the server *is* the request source — the continuous engine is the
        # only mode it can drive, so imply the flag instead of erroring
        scfg = dataclasses.replace(scfg, continuous=True).validate()
    except (ValueError, OSError) as e:
        ap.error(str(e))

    async def _run():
        server = build_server(scfg)
        await server.start()
        try:
            await server.serve_forever()
        except asyncio.CancelledError:
            pass
        finally:
            await server.stop()

    asyncio.run(_run())


if __name__ == "__main__":
    main()
