"""Serving driver: batched prefill + decode with a transprecision KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --reduced \
        --batch 4 --prompt-len 32 --gen 16 --policy p8-serve

Reports tokens/s and the KV-cache HBM footprint under the selected pcsr policy
(the paper's Table-IV memory-savings, at the serving bottleneck).

``--codec-impl`` selects the codec lowering (auto | lut | bits — the
table-driven fast path vs the bit pipeline, repro.core.lut) and
``--epilogue`` the layer dataflow (fused keeps gemm->bias->act->residual->
encode in one op per layer; chained materializes each stage, the baseline
bench_epilogue_fusion measures against).

``--precision-policy`` schedules *per-layer* weight formats over the base
policy (core/policy.py): a preset name (uniform-p16 | p8-weights |
p8-packed | attn-p16-mlp-p8) or an inline ``pattern=fmt[:packed],...`` spec.
``--quantize-weights`` converts the float weights to real posit storage
under that schedule (packed-p8 lanes where the policy says so) instead of
the straight-through fake-quant path, and reports the weight-byte savings.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.policy import get_precision_policy
from repro.launch.train import _parse_policy
from repro.models.layers import policy_weight_bytes, quantize_params
from repro.models.registry import build_model


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache)
               if hasattr(x, "size"))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--policy", default="none")
    ap.add_argument("--precision-policy", default=None,
                    help="per-layer weight schedule: preset name or "
                         "pattern=fmt[:packed],... spec (core/policy.py)")
    ap.add_argument("--quantize-weights", action="store_true",
                    help="store weights as posit codes (packed-p8 lanes "
                         "where the policy says so) instead of fake-quant")
    ap.add_argument("--codec-impl", default="auto", choices=("auto", "lut", "bits"))
    ap.add_argument("--epilogue", default="fused", choices=("fused", "chained"))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    policy = dataclasses.replace(
        _parse_policy(args.policy),
        codec_impl=args.codec_impl, epilogue=args.epilogue)
    if args.precision_policy:
        policy = get_precision_policy(args.precision_policy, base=policy)
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    weight_report = {}
    if args.quantize_weights:
        weight_report = policy_weight_bytes(params, policy)
        params = quantize_params(params, policy)
    S_max = args.prompt_len + args.gen

    rng = np.random.default_rng(args.seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)))

    if cfg.family == "whisper":
        batch = {"frames": jnp.asarray(rng.normal(
            0, 1, (args.batch, cfg.enc_frames, cfg.d_model)).astype(np.float32)),
            "tokens": tokens}
        cache = model.init_cache(params, batch, policy, S_max)
        logits, cache = model.decode_step(params, tokens[:, 0], cache, policy)
    else:
        kw = {}
        if cfg.family == "vlm":
            kw["patch_embeds"] = jnp.asarray(rng.normal(
                0, 1, (args.batch, cfg.n_patches, cfg.d_model)).astype(np.float32))
        t0 = time.time()
        logits, cache = model.prefill(params, tokens, policy, S_max=S_max, **kw)
        print(json.dumps({"prefill_s": round(time.time() - t0, 3)}))

    decode = jax.jit(lambda p, t, c: model.decode_step(p, t, c, policy))
    tok = jnp.argmax(logits, -1)
    out_tokens = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits, -1)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0

    kv_b = cache_bytes(cache)
    print(json.dumps({
        "arch": cfg.name, "policy": policy.describe(),
        "decode_tok_per_s": round(args.batch * (args.gen - 1) / dt, 1),
        "kv_cache_bytes": kv_b,
        "kv_bytes_per_token": kv_b // (args.batch * S_max),
        **weight_report,
        "sample_tokens": np.stack([np.asarray(t) for t in out_tokens], 1)[0][:8]
        .tolist(),
    }))


if __name__ == "__main__":
    main()
