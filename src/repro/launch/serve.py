"""Serving driver: static batched prefill+decode, or continuous batching.

    # static (lockstep) batch
    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --reduced \
        --batch 4 --prompt-len 32 --gen 16 --policy p8-serve

    # continuous batching over the ragged posit KV cache (launch/engine.py)
    PYTHONPATH=src python -m repro.launch.serve --arch yi-34b --reduced \
        --continuous --max-slots 4 --arrival-rate 8 --requests 16 \
        --policy p8-serve --attn-impl kernel

Reports tokens/s and the KV-cache HBM footprint under the selected pcsr policy
(the paper's Table-IV memory savings, at the serving bottleneck).  Decode
throughput is measured *warm*: the first decode step (jit compile) is timed
separately as ``compile_s`` and excluded from ``decode_tok_per_s``.

``--attn-impl`` selects the decode attention dispatch (DESIGN.md §10):
``kernel`` routes every step through the flash-decode front door
(``kernels.posit_attention.ops`` — Pallas on TPU, length-bounded tiled XLA
elsewhere), ``xla`` keeps the full-cache-decode einsum, ``auto`` picks per
layer.  ``--codec-impl`` selects the codec lowering (auto | lut | bits) and
``--epilogue`` the layer dataflow (fused | chained).

``--precision-policy`` schedules *per-layer* weight formats over the base
policy (core/policy.py) — a preset name, a ``pattern=fmt[@es][:packed]``
spec, or ``@path.json`` to load a saved calibration artifact;
``--quantize-weights`` converts the float weights to real posit storage under
that schedule and reports the weight-byte savings.

``--calibrate N`` runs the repro.calib pipeline (DESIGN.md §11) before
serving: N observed forward passes stream per-layer weight/activation
histograms, the analytic posit error model scores every (p8|p16) x es
candidate, and the byte-budgeted search (``--weight-byte-budget``, default
1 byte/weight — the p8 floor) emits the per-layer dynamic-es policy the run
then serves under.  ``--policy-out cal.json`` saves the artifact for
``--precision-policy @cal.json`` reuse::

    PYTHONPATH=src python -m repro.launch.serve --arch phi3-mini-3.8b \
        --reduced --calibrate 4 --policy-out cal.json --quantize-weights

Observability (DESIGN.md §12): ``--metrics-out m.json`` writes the engine's
metrics snapshot (plus a ``m.prom`` Prometheus text exposition alongside),
``--trace-out t.json`` a Chrome-trace/Perfetto request timeline, and
``--numerics-watch N`` probes every N-th decode step for posit saturation /
underflow / NaR rates and calibration drift (baselines come from a
``--precision-policy @cal.json`` artifact or a fresh ``--calibrate`` run)::

    PYTHONPATH=src python -m repro.launch.serve --arch yi-34b --reduced \
        --continuous --precision-policy @cal.json --numerics-watch 8 \
        --metrics-out metrics.json --trace-out trace.json

Every stdout line is one JSON object tagged with a ``"kind"`` key
(``serve/prefill``, ``serve/calibration``, ``serve/policy-out``,
``serve/numerics``, ``serve/report``) so consumers filter by kind instead of
guessing by field names.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.config import ServeConfig, add_cli_args, config_from_args
from repro.launch.engine import (KV_CONTAINERS as _KV_CONTAINERS, Request,
                                 poisson_requests)
from repro.models.layers import policy_weight_bytes, quantize_params
from repro.models.registry import build_model
from repro.obs.metrics import percentile_ms


def cache_bytes(cache) -> int:
    """Total bytes of every array in the cache (bookkeeping included)."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache)
               if hasattr(x, "size"))


def kv_cache_bytes(cache) -> int:
    """Bytes of the K/V arrays only.

    ``len``/``pos``/``lens`` bookkeeping and recurrent state (ssm / xlstm /
    quire carries) are not KV cache and must not inflate the paper's
    kv-bytes-per-token claim — only leaves named ``k``/``v`` inside a KV
    container count.
    """
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(cache)[0]:
        keys = [p.key for p in path if hasattr(p, "key")]
        if keys and keys[-1] in ("k", "v") \
                and any(k in _KV_CONTAINERS for k in keys[:-1]):
            total += leaf.size * leaf.dtype.itemsize
    return total


def _serve_static(args, cfg, model, params, policy, rng, S_max):
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)))
    decode = jax.jit(lambda p, t, c: model.decode_step(p, t, c, policy))
    compile_s = None

    if cfg.family == "whisper":
        batch = {"frames": jnp.asarray(rng.normal(
            0, 1, (args.batch, cfg.enc_frames, cfg.d_model)).astype(np.float32)),
            "tokens": tokens}
        t0 = time.perf_counter()
        cache = model.init_cache(params, batch, policy, S_max)
        # teacher-force the full decoder prompt: every prompt token passes
        # through decode_step (the old path fed tokens[:, 0] and silently
        # dropped the rest of the prompt).  The first step pays jit compile;
        # time it apart so prefill_s stays a throughput number.
        tc = time.perf_counter()
        logits, cache = decode(params, tokens[:, 0], cache)
        jax.block_until_ready(logits)
        compile_s = time.perf_counter() - tc
        for i in range(1, args.prompt_len):
            logits, cache = decode(params, tokens[:, i], cache)
        jax.block_until_ready(logits)
        print(json.dumps({
            "kind": "serve/prefill",
            "prefill_s": round(time.perf_counter() - t0 - compile_s, 3)}))
    else:
        kw = {}
        if cfg.family == "vlm":
            kw["patch_embeds"] = jnp.asarray(rng.normal(
                0, 1, (args.batch, cfg.n_patches, cfg.d_model)).astype(np.float32))
        t0 = time.perf_counter()
        logits, cache = model.prefill(params, tokens, policy, S_max=S_max, **kw)
        print(json.dumps({"kind": "serve/prefill",
                          "prefill_s": round(time.perf_counter() - t0, 3)}))

    tok = jnp.argmax(logits, -1)
    out_tokens = [tok]
    timed_steps = args.gen - 1
    if compile_s is None:
        # warm up one step before the throughput clock: the first decode call
        # pays jit compile, which used to be silently folded into tokens/s
        # (whisper is already warm from teacher-forcing the prompt)
        t0 = time.perf_counter()
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits, -1)
        jax.block_until_ready(tok)
        compile_s = time.perf_counter() - t0
        out_tokens.append(tok)
        timed_steps -= 1

    timed_steps = max(timed_steps, 0)
    t0 = time.perf_counter()
    for _ in range(timed_steps):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits, -1)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    dt = max(time.perf_counter() - t0, 1e-9)

    return {
        "mode": "static",
        "decode_tok_per_s": round(args.batch * timed_steps / dt, 1),
        "compile_s": round(compile_s, 3),
        "sample_tokens": np.stack([np.asarray(t) for t in out_tokens], 1)[0][:8]
        .tolist(),
    }, cache


def _build_observability(args, policy, drift_meta):
    """(metrics, tracer, numerics) sinks from the CLI flags (None = off).

    Drift baselines come from ``drift_meta`` — the calibration artifact dict
    (``--precision-policy @cal.json``) or the fresh ``--calibrate`` search
    report — when it carries per-site ``act_hist`` blocks; without them the
    watcher still reports saturation/underflow/NaR, just no drift scores.
    """
    metrics = tracer = numerics = None
    if args.metrics_out:
        from repro.obs.metrics import MetricsRegistry
        metrics = MetricsRegistry()
    if args.trace_out:
        from repro.obs.trace import TraceRecorder
        tracer = TraceRecorder()
    if args.numerics_watch:
        from repro.obs.numerics import NumericsWatcher, load_baselines
        baselines = load_baselines(drift_meta) if drift_meta else {}
        numerics = NumericsWatcher(policy=policy, baselines=baselines,
                                   every=args.numerics_watch)
    return metrics, tracer, numerics


def _serve_continuous(args, cfg, model, params, policy, rng, S_max,
                      obs=(None, None, None)):
    if model.prefill is None:
        sys.exit(f"--continuous needs a prefill entry point "
                 f"(family {cfg.family!r} has none)")
    max_slots = args.max_slots or args.batch
    n_req = args.requests or 2 * max_slots
    prefill_kwargs = None
    if cfg.family == "vlm":
        patches = jnp.asarray(rng.normal(
            0, 1, (1, cfg.n_patches, cfg.d_model)).astype(np.float32))
        prefill_kwargs = lambda req: {"patch_embeds": patches}  # noqa: E731

    metrics, tracer, numerics = obs
    # fault-tolerance plane (repro.ft.serving, DESIGN.md §13)
    snapshotter = watchdog = preemption = straggler = None
    if args.snapshot_every:
        from repro.ft import EngineSnapshotter, PreemptionSignal
        snapshotter = EngineSnapshotter(
            args.snapshot_dir, every=args.snapshot_every, metrics=metrics)
        # SIGTERM -> finish the in-flight step, drain, force-snapshot, exit
        preemption = PreemptionSignal(install_sigterm=True)
    if args.degrade:
        from repro.ft import DegradationController

        def _log_event(ev):
            print(json.dumps({"kind": "serve/degrade", **ev}))
        watchdog = DegradationController(numerics, metrics=metrics,
                                         on_event=_log_event)
    if metrics is not None:
        from repro.ft import StragglerMonitor
        straggler = StragglerMonitor()

    eng = args.build_engine(
        model, params, policy, prefill_kwargs=prefill_kwargs,
        metrics=metrics, tracer=tracer, numerics=numerics,
        snapshotter=snapshotter, watchdog=watchdog)

    # warm the executables (prefill at the prompt length + the grid decode;
    # 2 steps so the numerics-probed twin AND the plain decode both compile)
    # before the serving clock starts; report compile time separately
    t0 = time.perf_counter()
    eng.submit(Request(rid=-1, prompt=np.zeros((args.prompt_len,), np.int32),
                       max_new_tokens=min(3, args.gen)))
    eng.admit()
    eng.step()
    eng.step()
    eng.reset(seed=args.seed)
    if numerics is not None:
        numerics.rebase()   # drop the warmup probe from the drift window
    compile_s = time.perf_counter() - t0
    if args.chaos_preempt_step is not None:
        # attach AFTER warmup: the warmup steps run under the same step
        # counter and must not consume the trigger
        from repro.ft import FaultPlan
        eng.faults = FaultPlan(preempt_at_step=args.chaos_preempt_step,
                               use_sigterm=True)

    # resume AFTER warmup/reset so the restored state lands in already-
    # compiled executables and nothing of the dummy request survives
    restored = False
    if args.resume and snapshotter is not None:
        restored = snapshotter.restore_into(eng, now=0.0)
        if restored:
            print(json.dumps({
                "kind": "serve/resume", "steps": eng.steps,
                "active_slots": int(eng.active.sum()),
                "queued": len(eng.queue),
                "done": len(eng.completions)}))

    if restored:
        # the snapshot carries the full remaining workload (a preempted run
        # drains every unsubmitted request into the queue before saving)
        reqs = []
    else:
        reqs = poisson_requests(
            n_req, arrival_rate=args.arrival_rate,
            prompt_lens=(args.prompt_len,),
            max_new_tokens=args.gen, vocab=cfg.vocab, seed=args.seed)
    t0 = time.perf_counter()
    try:
        completions = eng.run(reqs, preemption=preemption,
                              straggler=straggler)
    finally:
        if snapshotter is not None:
            snapshotter.close()    # surface any pending async save failure
    makespan = max(time.perf_counter() - t0, 1e-9)

    n_tokens = sum(len(c.tokens) for c in completions)
    per_tok = [t for c in completions for t in c.per_token_s()]
    report = {
        "mode": "continuous",
        "requests": len(completions),
        "max_slots": max_slots,
        "arrival_rate": args.arrival_rate,
        "decode_tok_per_s": round(n_tokens / makespan, 1),
        "decode_steps": eng.steps,
        "compile_s": round(compile_s, 3),
        "p50_token_ms": percentile_ms(per_tok, 50),
        "p95_token_ms": percentile_ms(per_tok, 95),
        "p50_queue_ms": percentile_ms([c.queue_s for c in completions], 50),
        "sample_tokens": completions[0].tokens[:8] if completions else [],
    }
    if snapshotter is not None:
        report["snapshots"] = snapshotter.saves
        report["resumed"] = restored
        report["preempted"] = bool(preemption and preemption.triggered)
        report["in_flight_at_exit"] = int(eng.active.sum()) + len(eng.queue)
    if watchdog is not None:
        report["degradations"] = len(watchdog.events)
    if hasattr(eng, "prefix_stats"):
        report["prefix_cache"] = eng.prefix_stats()
    return report, eng.cache


def _calibrate(args, cfg, model, params, policy):
    """observe -> search -> (optionally) persist; returns (policy, report).

    The emitted PrecisionPolicy keeps ``policy``'s non-weight roles
    (kv_cache, compute dtype, codec/epilogue/attn dispatch) as its base; any
    ``--precision-policy`` rules are superseded by the calibrated schedule.
    The report doubles as the drift baseline for ``--numerics-watch``.
    """
    from repro.calib.search import (calibrate_model, calibration_batches,
                                    save_artifact)

    base = policy.base if hasattr(policy, "base") else policy
    rng = np.random.default_rng(args.seed)
    batches = calibration_batches(cfg, rng, args.calibrate,
                                  batch=args.batch, seq=args.prompt_len)
    # drive model.loss, not forward: the loss graph reaches the lm_head /
    # logits projection, which serving decodes through every step
    cal_policy, report = calibrate_model(
        lambda b: model.loss(params, b, base)[0], batches, params,
        base=base, byte_budget=args.weight_byte_budget,
        name=f"calibrated-{cfg.name}")
    print(json.dumps({"kind": "serve/calibration", "calibration": {
        k: report[k] for k in ("n_sites", "p8_floor_bytes", "byte_budget",
                               "weight_bytes", "predicted_err_score")}}))
    if args.policy_out:
        save_artifact(args.policy_out, cal_policy, report)
        print(json.dumps({"kind": "serve/policy-out",
                          "policy_out": args.policy_out}))
    return cal_policy, report


def main(argv=None):
    # the CLI is generated from the ServeConfig schema (launch/config.py):
    # one flag per field; --config loads a saved document and explicitly-
    # passed flags override it
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default=None, metavar="CFG.json",
                    help="ServeConfig JSON document (kind repro/serve-config"
                         "); explicitly-passed flags override its fields")
    add_cli_args(ap)
    ns = ap.parse_args(argv)
    try:
        base = ServeConfig.load(ns.config) if ns.config else None
        args = config_from_args(ns, base=base).validate()
    except (ValueError, OSError) as e:
        ap.error(str(e))
    run(args)


def run(args: ServeConfig):
    """Serve under a validated :class:`ServeConfig` (the programmatic entry
    point — hillclimb and tests call this with a constructed config)."""
    cfg = args.arch_cfg()
    policy, drift_meta = args.build_policy()
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    if args.calibrate:
        policy, cal_report = _calibrate(args, cfg, model, params, policy)
        drift_meta = {"meta": cal_report}
    weight_report = {}
    if args.quantize_weights:
        weight_report = policy_weight_bytes(params, policy)
        params = quantize_params(params, policy)
    S_max = args.s_max(cfg)

    metrics, tracer, numerics = _build_observability(args, policy, drift_meta)
    profiler = None
    if args.profile_out:
        from repro.obs import prof
        profiler = prof.KernelProfiler()
    rng = np.random.default_rng(args.seed)
    # telemetry flushes in finally: a crash (or an injected fault) mid-serve
    # must still leave the metrics snapshot / trace on disk for post-mortem
    try:
        with contextlib.ExitStack() as stack:
            if profiler is not None:
                stack.enter_context(prof.profiling(profiler))
            t_serve0 = time.perf_counter()
            if args.continuous:
                report, cache = _serve_continuous(
                    args, cfg, model, params, policy, rng, S_max,
                    obs=(metrics, tracer, numerics))
                n_rows = args.max_slots or args.batch
            else:
                report, cache = _serve_static(args, cfg, model, params,
                                              policy, rng, S_max)
                n_rows = args.batch
            serve_s = time.perf_counter() - t_serve0

        if profiler is not None:
            prep = profiler.save(args.profile_out, measured_total_s=serve_s)
            print(json.dumps({"kind": "serve/profile",
                              "profile_out": args.profile_out,
                              "rows": len(prep["rows"]),
                              "dispatches": prep["totals"]["dispatches"],
                              "bytes": prep["totals"]["bytes"],
                              "bound_s": prep["totals"]["bound_s"],
                              "measured_s": round(serve_s, 4)}))

        if numerics is not None:
            nrep = numerics.report()
            print(json.dumps({"kind": "serve/numerics",
                              "recalibrate": nrep["recalibrate"],
                              "probes": nrep["probes"],
                              "max_drift_score": nrep["max_drift_score"]}))
            if metrics is not None:
                metrics.set_context(numerics=nrep)
        if metrics is not None:
            metrics.set_context(arch=cfg.name, policy=policy.describe(),
                                mode=report.get("mode") if args.continuous
                                else "static")

        kv_b = kv_cache_bytes(cache)
        print(json.dumps({
            "kind": "serve/report",
            "arch": cfg.name, "policy": policy.describe(),
            **report,
            "kv_cache_bytes": kv_b,
            "cache_bytes_total": cache_bytes(cache),
            "kv_bytes_per_token": kv_b // (n_rows * S_max),
            **weight_report,
            "config": args.to_json(),
        }))
    finally:
        if metrics is not None:
            metrics.save(args.metrics_out)
            with open(args.metrics_out + ".prom", "w") as f:
                f.write(metrics.prometheus())
        if tracer is not None:
            tracer.save(args.trace_out)


if __name__ == "__main__":
    main()
