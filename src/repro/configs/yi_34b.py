"""Yi-34B: llama-arch GQA dense [arXiv:2403.04652; hf]."""
from repro.configs.base import ModelCfg

CONFIG = ModelCfg(
    name="yi-34b", family="dense",
    n_layers=60, d_model=7168, n_heads=56, n_kv=8, d_ff=20480, vocab=64000,
    rope_base=5_000_000.0,
    supports_long_context=False,  # full attention -> long_500k skipped
)
