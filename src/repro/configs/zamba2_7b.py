"""Zamba2-7B: Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

Simplification noted in DESIGN.md: one shared attention+MLP block applied
every `shared_attn_every` Mamba2 layers (Zamba2 alternates two shared blocks
with per-use LoRA; weight-tying is preserved, LoRA deltas are not).
"""
from repro.configs.base import ModelCfg

CONFIG = ModelCfg(
    name="zamba2-7b", family="zamba",
    n_layers=81, d_model=3584, n_heads=32, n_kv=32, d_ff=14336, vocab=32000,
    ssm_state=64, ssm_head_dim=64, shared_attn_every=6,
    supports_long_context=True,  # SSM path is O(1)/token; shared attn is periodic
)
