"""InternVL2-2B: InternViT frontend (stubbed to patch embeddings) +
InternLM2-1.8B LM backbone [arXiv:2404.16821; hf]."""
from repro.configs.base import ModelCfg

CONFIG = ModelCfg(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv=8, d_ff=8192, vocab=92553,
    n_patches=256,
    supports_long_context=False,  # full attention -> long_500k skipped
)
