"""Whisper-medium: enc-dec, conv frontend stubbed to precomputed frame
embeddings (B, 1500, d) [arXiv:2212.04356].

long_500k is architecturally meaningless (decoder limit 448) -> skipped.
decode_32k stresses the self-KV cache beyond the architectural limit as a
synthetic cell (positions wrap past MAX_TGT); noted in EXPERIMENTS.md.
"""
from repro.configs.base import ModelCfg

CONFIG = ModelCfg(
    name="whisper-medium", family="whisper",
    n_layers=24, d_model=1024, n_heads=16, n_kv=16, d_ff=4096, vocab=51865,
    enc_layers=24, enc_frames=1500, max_target_positions=448,
    supports_long_context=False,
)
