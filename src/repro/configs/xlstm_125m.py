"""xLSTM-125M: mLSTM + sLSTM blocks (7:1-style interleave) [arXiv:2405.04517].

d_ff=0 per assignment: xLSTM blocks carry their own up/down projections
(mLSTM pf=2, sLSTM gated FFN 4/3) instead of a separate transformer FFN.
"""
from repro.configs.base import ModelCfg

CONFIG = ModelCfg(
    name="xlstm-125m", family="xlstm",
    n_layers=12, d_model=768, n_heads=4, n_kv=4, d_ff=0, vocab=50304,
    slstm_every=4,
    supports_long_context=True,  # recurrent: O(1) state per token
)
