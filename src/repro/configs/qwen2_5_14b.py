"""Qwen2.5-14B: GQA dense with QKV bias [hf:Qwen/Qwen2.5 family; hf]."""
from repro.configs.base import ModelCfg

CONFIG = ModelCfg(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv=8, d_ff=13824, vocab=152064,
    qkv_bias=True, rope_base=1_000_000.0,
    supports_long_context=False,  # full attention -> long_500k skipped
)
