"""Phi-3-mini 3.8B: RoPE SwiGLU GQA dense [arXiv:2404.14219]."""
from repro.configs.base import ModelCfg

CONFIG = ModelCfg(
    name="phi3-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=32, n_kv=32, d_ff=8192, vocab=32064,
    supports_long_context=False,  # full attention -> long_500k skipped
)
