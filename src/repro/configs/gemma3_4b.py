"""Gemma-3-4B: 5:1 local:global sliding-window, 128k ctx, head_dim 256,
vocab 262144 [hf:google/gemma-3 family]."""
from repro.configs.base import ModelCfg

CONFIG = ModelCfg(
    name="gemma3-4b", family="gemma3",
    n_layers=34, d_model=2560, n_heads=8, n_kv=4, d_ff=10240, vocab=262144,
    head_dim=256, window=1024, local_ratio=5,
    rope_base=10_000.0, global_rope_base=1_000_000.0,
    tie_embeddings=True,  # gemma ties the 262k-vocab embedding
    supports_long_context=True,  # 5/6 layers sliding-window (sub-quadratic);
                                 # global layers are linear per decode step
)
