"""OLMoE-1B-7B: 64-expert top-8 MoE, 1B active / 7B total [arXiv:2409.02060; hf]."""
from repro.configs.base import ModelCfg

CONFIG = ModelCfg(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv=16, d_ff=1024, vocab=50304,
    n_experts=64, top_k=8,
    supports_long_context=False,  # full attention -> long_500k skipped
)
