"""Granite-3.0 MoE 3B-a800m: 40-expert top-8, fine-grained d_ff=512
[hf:ibm-granite/granite-3.0-1b-a400m-base family; hf]."""
from repro.configs.base import ModelCfg

CONFIG = ModelCfg(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv=8, d_ff=512, vocab=49155,
    n_experts=40, top_k=8,
    supports_long_context=False,  # full attention -> long_500k skipped
)
