"""Assigned-architecture registry: get_arch(name) / list_archs() / SHAPES.

Shapes (assignment): train_4k, prefill_32k, decode_32k, long_500k. long_500k
runs only for archs with a sub-quadratic path (supports_long_context);
DESIGN.md §6 records the skips.
"""
from __future__ import annotations

import importlib

from repro.configs.base import ModelCfg, ShapeCfg

ARCH_IDS = (
    "olmoe-1b-7b", "granite-moe-3b-a800m", "zamba2-7b", "yi-34b",
    "phi3-mini-3.8b", "gemma3-4b", "qwen2.5-14b", "whisper-medium",
    "xlstm-125m", "internvl2-2b",
)

SHAPES = (
    ShapeCfg("train_4k", "train", 4096, 256),
    ShapeCfg("prefill_32k", "prefill", 32768, 32),
    ShapeCfg("decode_32k", "decode", 32768, 128),
    ShapeCfg("long_500k", "decode", 524288, 1),
)


def get_shape(name: str) -> ShapeCfg:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def get_arch(name: str) -> ModelCfg:
    mod = importlib.import_module("repro.configs." + name.replace("-", "_").replace(".", "_"))
    return mod.CONFIG


def list_archs() -> tuple[str, ...]:
    return ARCH_IDS


def cells(include_skipped: bool = False):
    """All (arch, shape) assignment cells; skips filtered unless requested."""
    for a in ARCH_IDS:
        cfg = get_arch(a)
        for s in SHAPES:
            skip = s.name == "long_500k" and not cfg.supports_long_context
            if include_skipped or not skip:
                yield cfg, s, skip
