"""ModelCfg — the static architecture descriptor every model family reads.

One instance per assigned architecture lives in ``repro.configs.<arch>``;
``reduced()`` derives the CPU smoke-test configuration.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    name: str
    family: str              # dense | moe | gemma3 | zamba | xlstm | whisper | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0        # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_base: float = 10000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # gemma3 (sliding-window local : global pattern)
    window: int = 0
    local_ratio: int = 0     # N local layers per 1 global
    global_rope_base: float = 1_000_000.0
    # ssm / zamba
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    shared_attn_every: int = 0
    # whisper
    enc_layers: int = 0
    enc_frames: int = 0
    max_target_positions: int = 0   # architectural decoder limit (0 = unlimited)
    # vlm
    n_patches: int = 0
    # xlstm
    slstm_every: int = 0
    xlstm_chunk: int = 256
    # which shapes the arch supports (DESIGN.md §6)
    supports_long_context: bool = False   # sub-quadratic path for long_500k

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def reduced(self) -> "ModelCfg":
        """Tiny same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 2 if self.family != "zamba" else 7),
            d_model=128,
            n_heads=4,
            n_kv=max(1, min(self.n_kv, 2)),
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            head_dim=32 if self.head_dim else 0,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            window=min(self.window, 16) if self.window else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            ssm_chunk=16,
            enc_layers=min(self.enc_layers, 2),
            enc_frames=min(self.enc_frames, 24) if self.enc_frames else 0,
            n_patches=min(self.n_patches, 8) if self.n_patches else 0,
            xlstm_chunk=16,
            shared_attn_every=min(self.shared_attn_every, 3) if self.shared_attn_every else 0,
        )


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    """One (input-shape) cell of the assignment matrix."""
    name: str                # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                # train | prefill | decode
    seq_len: int
    global_batch: int
