"""Pure-JAX model substrate (params = pytrees of arrays; no flax).

Every linear layer routes through ``layers.apply_linear``, which consults the
run's TransPolicy: float weights compute natively; posit-stored weights decode
at the matmul boundary (serving) or quantize with a straight-through estimator
(training) — the paper's codec-at-the-datapath placement, model-wide.
"""
