"""Scan-vs-unroll switch for cost probing.

XLA's HLO cost analysis counts a while-loop body ONCE, so any scanned stack
(layers, microbatches, loss chunks) under-reports FLOPs/bytes/collectives by
its trip count. For the roofline's cost probes the launcher flips UNROLL on:
every scan_or_unroll site becomes a python loop, making the lowered HLO an
explicit straight-line program whose op counts are exact. Production/lowering
paths keep scans (compact HLO); only reduced-depth probe configs are unrolled.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_UNROLL = False


def set_unroll(v: bool) -> None:
    global _UNROLL
    _UNROLL = v


def unrolled() -> bool:
    return _UNROLL


class unroll_mode:
    def __init__(self, on: bool = True):
        self.on = on

    def __enter__(self):
        self.prev = _UNROLL
        set_unroll(self.on)
        return self

    def __exit__(self, *exc):
        set_unroll(self.prev)
        return False


def scan_or_unroll(f, init, xs, length=None):
    """lax.scan, or an equivalent python loop when UNROLL is on."""
    if not _UNROLL:
        return jax.lax.scan(f, init, xs, length=length)
    if xs is None:
        n = length

        def get(i):
            return None
    else:
        n = jax.tree.leaves(xs)[0].shape[0]

        def get(i):
            return jax.tree.map(lambda a: a[i], xs)
    carry = init
    ys = []
    for i in range(n):
        carry, y = f(carry, get(i))
        ys.append(y)
    if all(y is None for y in ys):
        return carry, None
    stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    return carry, stacked
