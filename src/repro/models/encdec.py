"""Whisper-style encoder-decoder backbone (audio frontend stubbed per the
assignment: ``input_specs()`` provides precomputed (B, frames, d_model) frame
embeddings in place of the conv1d+mel frontend).

Encoder: bidirectional attention + GELU MLP, pre-LayerNorm, sinusoidal pos.
Decoder: causal self-attention + cross-attention + GELU MLP, learned pos,
tied embedding read-out. Serving: encoder runs once; each decoder layer keeps
a self KV cache (posit-compressible) and a prefilled cross KV cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelCfg
from repro.core.pcsr import TransPolicy
from repro.models import attention as attn
from repro.models.attention import AttnCfg
from repro.models.shardhooks import maybe_shard
from repro.models.unroll import scan_or_unroll
from repro.models.layers import (apply_embedding, apply_gelu_mlp,
                                 apply_layernorm, apply_linear,
                                 embedding_logits, init_embedding,
                                 init_gelu_mlp, init_layernorm, init_linear,
                                 sinusoidal_positions)

MAX_TGT = 448  # whisper's architectural decoder length


def _enc_attn_cfg(cfg: ModelCfg) -> AttnCfg:
    return AttnCfg(d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
                   head_dim=cfg.hd, qkv_bias=True, causal=False, use_rope=False)


def _dec_self_cfg(cfg: ModelCfg) -> AttnCfg:
    return AttnCfg(d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
                   head_dim=cfg.hd, qkv_bias=True, causal=True, use_rope=False)


def _dec_cross_cfg(cfg: ModelCfg) -> AttnCfg:
    return AttnCfg(d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
                   head_dim=cfg.hd, qkv_bias=True, causal=False,
                   use_rope=False, is_cross=True)


def init_encdec(key, cfg: ModelCfg) -> dict:
    keys = jax.random.split(key, 6)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": init_layernorm(cfg.d_model),
                "attn": attn.init_attention(k1, _enc_attn_cfg(cfg)),
                "ln2": init_layernorm(cfg.d_model),
                "mlp": init_gelu_mlp(k2, cfg.d_model, cfg.d_ff)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": init_layernorm(cfg.d_model),
                "self": attn.init_attention(k1, _dec_self_cfg(cfg)),
                "ln2": init_layernorm(cfg.d_model),
                "cross": attn.init_attention(k2, _dec_cross_cfg(cfg)),
                "ln3": init_layernorm(cfg.d_model),
                "mlp": init_gelu_mlp(k3, cfg.d_model, cfg.d_ff)}

    ek = jax.random.split(keys[0], cfg.enc_layers)
    dk = jax.random.split(keys[1], cfg.n_layers)
    return {
        "frame_proj": init_linear(keys[2], cfg.d_model, cfg.d_model, bias=True),
        "enc_blocks": jax.vmap(enc_layer)(ek),
        "enc_ln": init_layernorm(cfg.d_model),
        "embed": init_embedding(keys[3], cfg.vocab, cfg.d_model),
        "pos_embed": jax.random.normal(keys[4], (MAX_TGT, cfg.d_model),
                                       jnp.float32) * 0.01,
        "dec_blocks": jax.vmap(dec_layer)(dk),
        "dec_ln": init_layernorm(cfg.d_model),
    }


def encode(params: dict, frames: jax.Array, cfg: ModelCfg,
           policy: TransPolicy, *, remat: bool = True) -> jax.Array:
    """frames: (B, T_enc, D) stub embeddings -> encoder states (B, T_enc, D)."""
    T = frames.shape[1]
    x = apply_linear(params["frame_proj"], frames, policy,
                     path="frame_proj")
    x = x + sinusoidal_positions(T, cfg.d_model)[None].astype(x.dtype)
    ecfg = _enc_attn_cfg(cfg)

    def body(x, p):
        x = maybe_shard(x, "residual")
        h = apply_layernorm(p["ln1"], x)
        x = x + attn.apply_attention(p["attn"], ecfg, h, policy, path="attn")
        h = apply_layernorm(p["ln2"], x)
        return apply_gelu_mlp(p["mlp"], h, policy, residual=x,
                              path="mlp"), None

    fn = jax.checkpoint(body) if remat else body
    x, _ = scan_or_unroll(fn, x, params["enc_blocks"])
    return apply_layernorm(params["enc_ln"], x)


def decode_train(params: dict, tokens: jax.Array, enc_out: jax.Array,
                 cfg: ModelCfg, policy: TransPolicy, *,
                 remat: bool = True) -> jax.Array:
    """tokens: (B, S) -> hidden (B, S, D) (positions wrap past MAX_TGT)."""
    B, S = tokens.shape
    x = apply_embedding(params["embed"], tokens)
    pos_idx = jnp.arange(S) % MAX_TGT
    x = x + params["pos_embed"][pos_idx][None].astype(x.dtype)
    scfg, ccfg = _dec_self_cfg(cfg), _dec_cross_cfg(cfg)

    def body(x, p):
        x = maybe_shard(x, "residual")
        h = apply_layernorm(p["ln1"], x)
        x = x + attn.apply_attention(p["self"], scfg, h, policy, path="self")
        h = apply_layernorm(p["ln2"], x)
        x = x + attn.apply_attention(p["cross"], ccfg, h, policy, path="cross",
                                     xattn_kv=enc_out)
        h = apply_layernorm(p["ln3"], x)
        return apply_gelu_mlp(p["mlp"], h, policy, residual=x,
                              path="mlp"), None

    fn = jax.checkpoint(body) if remat else body
    x, _ = scan_or_unroll(fn, x, params["dec_blocks"])
    return apply_layernorm(params["dec_ln"], x)


def encdec_loss(params: dict, batch: dict, cfg: ModelCfg,
                policy: TransPolicy) -> tuple[jax.Array, dict]:
    enc_out = encode(params, batch["frames"], cfg, policy)
    h = decode_train(params, batch["tokens"], enc_out, cfg, policy)
    logits = embedding_logits(params["embed"], h)
    lp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(lp, batch["labels"][..., None], axis=-1)[..., 0]
    ce = -jnp.mean(ll)
    return ce, {"ce": ce, "aux": jnp.float32(0.0)}


# ----------------------------------------------------------------- serving ----

def init_dec_cache(params: dict, frames: jax.Array, cfg: ModelCfg,
                   policy: TransPolicy, S_max: int) -> dict:
    """Run the encoder and prefill every layer's cross KV cache."""
    B = frames.shape[0]
    enc_out = encode(params, frames, cfg, policy, remat=False)
    T = enc_out.shape[1]
    scfg, ccfg = _dec_self_cfg(cfg), _dec_cross_cfg(cfg)

    def per_layer(p):
        c = attn.init_kv_cache(B, T, ccfg, policy)
        k = apply_linear(p["cross"]["wk"], enc_out, policy,
                         path="cross/wk") \
            .reshape(B, T, cfg.n_kv, cfg.hd)
        v = apply_linear(p["cross"]["wv"], enc_out, policy,
                         path="cross/wv") \
            .reshape(B, T, cfg.n_kv, cfg.hd)
        c["k"] = attn._store(c["k"], k.transpose(0, 2, 1, 3), 0, policy)
        c["v"] = attn._store(c["v"], v.transpose(0, 2, 1, 3), 0, policy)
        c["len"] = jnp.full((B,), T, jnp.int32)
        return c

    cross = jax.vmap(per_layer)(params["dec_blocks"])
    self_kv = jax.vmap(
        lambda _: attn.init_kv_cache(B, S_max, scfg, policy)
    )(jnp.arange(cfg.n_layers))
    return {"cross": cross, "self": self_kv, "pos": jnp.zeros((), jnp.int32),
            "lens": jnp.zeros((B,), jnp.int32)}


def decode_step(params: dict, token_t: jax.Array, cache: dict, cfg: ModelCfg,
                policy: TransPolicy) -> tuple[jax.Array, dict]:
    pos = cache["pos"]
    B = token_t.shape[0]
    lens = cache.get("lens")
    if lens is None:  # pre-ragged hand-built caches: lockstep positions
        lens = jnp.broadcast_to(pos, (B,))
    x = apply_embedding(params["embed"], token_t[:, None])
    # learned positions per row (rows of a continuous batch sit at
    # different decode depths)
    x = x + params["pos_embed"][(lens % MAX_TGT)][:, None].astype(x.dtype)
    scfg, ccfg = _dec_self_cfg(cfg), _dec_cross_cfg(cfg)

    def body(x_carry, layer):
        p, cself, ccross = layer
        h = apply_layernorm(p["ln1"], x_carry)
        a, c2 = attn.decode_attention_step(p["self"], scfg, h, cself, lens, policy,
                                           path="self")
        x2 = x_carry + a
        h = apply_layernorm(p["ln2"], x2)
        a2, _ = attn.decode_attention_step(p["cross"], ccfg, h, ccross, lens, policy,
                                            path="cross")
        x2 = x2 + a2
        h = apply_layernorm(p["ln3"], x2)
        return apply_gelu_mlp(p["mlp"], h, policy, residual=x2, path="mlp"), c2

    x, new_self = scan_or_unroll(
        body, x, (params["dec_blocks"], cache["self"], cache["cross"]))
    h = apply_layernorm(params["dec_ln"], x)
    logits = embedding_logits(params["embed"], h)[:, 0]
    return logits, {"cross": cache["cross"], "self": new_self, "pos": pos + 1,
                    "lens": lens + 1}
