"""Model registry: one uniform interface over all families.

  model = build_model(cfg)
  params = model.init(key)
  loss, metrics = model.loss(params, batch, policy)
  logits, cache = model.prefill(params, ..., policy) / model.decode_step(...)

The VLM family reuses the decoder-only path with a stubbed patch-embedding
prefix (assignment: modality frontends are stubs).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.configs.base import ModelCfg
from repro.models import encdec, transformer


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelCfg
    init: Callable
    loss: Callable            # (params, batch, policy) -> (loss, metrics)
    forward: Callable         # (params, batch, policy) -> hidden
    init_cache: Callable      # serving
    prefill: Callable
    decode_step: Callable
    # paged serving entry points (DESIGN.md §14) — None for families whose
    # cache layout the block pool cannot express (window buffers, recurrent
    # state, patch prefixes)
    init_paged_cache: Callable = None
    decode_step_paged: Callable = None


def build_model(cfg: ModelCfg) -> Model:
    if cfg.family == "whisper":
        return Model(
            cfg=cfg,
            init=lambda key: encdec.init_encdec(key, cfg),
            loss=lambda p, b, pol: encdec.encdec_loss(p, b, cfg, pol),
            forward=lambda p, b, pol: encdec.decode_train(
                p, b["tokens"], encdec.encode(p, b["frames"], cfg, pol), cfg, pol),
            init_cache=lambda p, b, pol, S_max: encdec.init_dec_cache(
                p, b["frames"], cfg, pol, S_max),
            prefill=None,
            decode_step=lambda p, tok, cache, pol: encdec.decode_step(
                p, tok, cache, cfg, pol),
        )

    def loss(p, b, pol):
        return transformer.lm_loss(p, b, cfg, pol)

    def fwd(p, b, pol):
        h, _ = transformer.forward(p, b["tokens"], cfg, pol,
                                   patch_embeds=b.get("patch_embeds"))
        return h

    return Model(
        cfg=cfg,
        init=lambda key: transformer.init_lm(key, cfg),
        loss=loss,
        forward=fwd,
        init_cache=lambda B, S_max, pol: transformer.init_cache(cfg, B, S_max, pol),
        prefill=lambda p, tokens, pol, **kw: transformer.prefill(
            p, tokens, cfg, pol, **kw),
        decode_step=lambda p, tok, cache, pol: transformer.decode_step(
            p, tok, cache, cfg, pol),
        init_paged_cache=(
            lambda B, n_blocks, bt, width, pol: transformer.init_paged_cache(
                cfg, B, n_blocks, bt, width, pol))
        if cfg.family in ("dense", "moe") else None,
        decode_step_paged=(
            lambda p, tok, cache, pol: transformer.decode_step_paged(
                p, tok, cache, cfg, pol))
        if cfg.family in ("dense", "moe") else None,
    )
