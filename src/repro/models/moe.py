"""Mixture-of-Experts with capacity-bounded scatter dispatch (EP-shardable).

Dispatch is gather/scatter-based (MegaBlocks-flavoured), NOT the GShard
one-hot-einsum form: the (T, E, C) dispatch tensor is infeasible at
train-shape token counts, and scatter keeps HLO FLOPs at the true
k * T * D * F scale so the roofline numbers stay honest.

Sharding story (EP over the "model" axis): expert buffers (E, C, D) carry
P("model", None, None); tokens are sharded over ("pod","data"). The
scatter/gather pair between those shardings is exactly the MoE all-to-all,
inserted by GSPMD. Tokens over capacity are dropped (standard top-k semantics).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.calib import observe
from repro.core.pcsr import TransPolicy
from repro.models.layers import apply_linear, effective_weight, init_linear
from repro.models.shardhooks import maybe_shard


def init_moe(key, d: int, f: int, n_experts: int) -> dict:
    kr, kg, ku, kd = jax.random.split(key, 4)
    s_in, s_out = d ** -0.5, f ** -0.5
    return {
        "router": init_linear(kr, d, n_experts),
        "w_gate": jax.random.normal(kg, (n_experts, d, f), jnp.float32) * s_in,
        "w_up": jax.random.normal(ku, (n_experts, d, f), jnp.float32) * s_in,
        "w_down": jax.random.normal(kd, (n_experts, f, d), jnp.float32) * s_out,
    }


def _expert_path(name: str) -> str:
    """The policy/observer site key for a stacked expert tensor — one
    definition so weight records (_expert_weight) and activation records
    (apply_moe) can never silently diverge."""
    return f"moe/{name}"


def _expert_weight(p, name, policy: TransPolicy):
    return effective_weight(
        {"w": p[name]} if name in p else {"w_codes": p[name + "_codes"]},
        policy, path=_expert_path(name))


def apply_moe(p: dict, x: jax.Array, *, top_k: int, capacity_factor: float,
              policy: TransPolicy) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (same shape, aux load-balancing loss)."""
    B, S, D = x.shape
    T = B * S
    # experts may be stored as float ("w_gate") or posit codes after
    # quantize_params ("w_gate_codes") — same (E, D, F) shape either way
    E = (p["w_gate"] if "w_gate" in p else p["w_gate_codes"]).shape[0]
    xf = x.reshape(T, D)

    logits = apply_linear(p["router"], xf, policy,
                          path="moe/router").astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, top_k)                          # (T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # Switch-style aux loss: E * sum_e fraction_tokens(e) * mean_prob(e)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=1), axis=0
    ) / top_k
    aux = E * jnp.sum(me * ce)

    C = int(-(-T * top_k * capacity_factor // E))
    C = max(8, -(-C // 8) * 8)

    flat_e = top_e.reshape(-1)                                   # (T*k,)
    # position of each assignment within its expert (token-major order),
    # via stable sort + group starts: O(n log n). (A (T*k, E) one-hot cumsum
    # is the textbook form but lowers to a reduce-window whose cost — and on
    # some backends runtime — is quadratic in tokens.)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(flat_e.shape[0], dtype=jnp.int32) - starts[sorted_e]
    flat_pos = jnp.zeros_like(rank).at[order].set(rank)
    keep = flat_pos < C

    xk = jnp.repeat(xf, top_k, axis=0)                           # (T*k, D)
    upd = jnp.where(keep[:, None], xk.astype(jnp.float32), 0.0)
    buffers = jnp.zeros((E, C, D), jnp.float32).at[
        flat_e, jnp.minimum(flat_pos, C - 1)].add(upd)           # EP all-to-all
    buffers = maybe_shard(buffers, "expert_buffers")

    cd = jnp.float32 if policy.compute_dtype == "f32" else jnp.bfloat16
    h = buffers.astype(cd)
    wg = _expert_weight(p, "w_gate", policy).astype(cd)
    wu = _expert_weight(p, "w_up", policy).astype(cd)
    wd = _expert_weight(p, "w_down", policy).astype(cd)
    if observe.is_active():
        # expert GEMMs don't route through apply_linear: stream the dispatch
        # buffers as the activations of the stacked expert-weight sites
        observe.record(_expert_path("w_gate"), "act", h)
        observe.record(_expert_path("w_up"), "act", h)
    g = jnp.einsum("ecd,edf->ecf", h, wg, preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", h, wu, preferred_element_type=jnp.float32)
    act = jax.nn.silu(g) * u
    if observe.is_active():
        observe.record(_expert_path("w_down"), "act", act)
    out_buf = jnp.einsum("ecf,efd->ecd", act.astype(cd), wd,
                         preferred_element_type=jnp.float32)     # (E, C, D)
    out_buf = maybe_shard(out_buf, "expert_buffers")

    gathered = out_buf[flat_e, jnp.minimum(flat_pos, C - 1)]     # (T*k, D)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    weighted = gathered.reshape(T, top_k, D) * top_p[..., None]
    y = jnp.sum(weighted, axis=1).astype(x.dtype).reshape(B, S, D)
    return y, aux
