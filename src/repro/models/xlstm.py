"""xLSTM blocks: mLSTM (matrix memory, chunk-parallel) and sLSTM (scalar
memory, sequential scan — elementwise, so the while-loop FLOPs are negligible).

mLSTM recurrence (per head, stabilized exponential gating):
    C_t = f_t C_{t-1} + i_t (v_t ⊗ k_t),   n_t = f_t n_{t-1} + i_t k_t
    y_t = (C_t q_t) / max(|n_t · q_t|, 1)
evaluated chunk-parallel with log-gate cumsums (TFLA-style) over a static
python chunk loop. Decode is the O(1) recurrence. States stay f32 (DESIGN §6).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.pcsr import TransPolicy
from repro.models.layers import (apply_linear, apply_rmsnorm, init_linear,
                                 init_rmsnorm)
from repro.models.unroll import scan_or_unroll


@dataclasses.dataclass(frozen=True)
class XLSTMCfg:
    d_model: int
    n_heads: int
    chunk: int = 256
    proj_factor: float = 2.0  # mLSTM up-projection

    @property
    def d_inner(self) -> int:
        return int(self.d_model * self.proj_factor)

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads


# --------------------------------------------------------------- mLSTM --------

def init_mlstm(key, cfg: XLSTMCfg) -> dict:
    ku, kq, kk, kv, ki, kf, ko, kd = jax.random.split(key, 8)
    d, di = cfg.d_model, cfg.d_inner
    return {
        "up": init_linear(ku, d, 2 * di),       # -> [x_inner, gate z]
        "wq": init_linear(kq, di, di),
        "wk": init_linear(kk, di, di),
        "wv": init_linear(kv, di, di),
        "wi": init_linear(ki, di, cfg.n_heads),
        "wf": init_linear(kf, di, cfg.n_heads),
        "norm": init_rmsnorm(di),
        "down": init_linear(kd, di, d, scale=di ** -0.5),
    }


def apply_mlstm(p: dict, cfg: XLSTMCfg, x: jax.Array, policy: TransPolicy) -> jax.Array:
    B, S, _ = x.shape
    nh, hd, di = cfg.n_heads, cfg.head_dim, cfg.d_inner
    L = min(cfg.chunk, S)
    n_chunks = -(-S // L)
    Sp = n_chunks * L

    ug = apply_linear(p["up"], x, policy, path="blk/up")
    xi, z = ug[..., :di], ug[..., di:]
    q = apply_linear(p["wq"], xi, policy, path="blk/wq").reshape(B, S, nh, hd)
    k = apply_linear(p["wk"], xi, policy, path="blk/wk").reshape(B, S, nh, hd) * (hd ** -0.5)
    v = apply_linear(p["wv"], xi, policy, path="blk/wv").reshape(B, S, nh, hd)
    ig = apply_linear(p["wi"], xi, policy,
                      path="blk/wi").astype(jnp.float32)  # (B,S,nh) log-space
    fg = jax.nn.log_sigmoid(apply_linear(p["wf"], xi, policy, path="blk/wf").astype(jnp.float32))

    if Sp != S:
        pad4 = ((0, 0), (0, Sp - S), (0, 0), (0, 0))
        q, k, v = (jnp.pad(a, pad4) for a in (q, k, v))
        ig = jnp.pad(ig, ((0, 0), (0, Sp - S), (0, 0)), constant_values=-1e30)
        fg = jnp.pad(fg, ((0, 0), (0, Sp - S), (0, 0)))

    qc = q.reshape(B, n_chunks, L, nh, hd).astype(jnp.float32)
    kc = k.reshape(B, n_chunks, L, nh, hd).astype(jnp.float32)
    vc = v.reshape(B, n_chunks, L, nh, hd).astype(jnp.float32)
    igc = ig.reshape(B, n_chunks, L, nh)
    fgc = fg.reshape(B, n_chunks, L, nh)
    seg = jnp.cumsum(fgc, axis=2)                  # within-chunk log decay
    total = seg[:, :, -1, :]

    def chunk_body(carry, inputs):
        C, n, m = carry
        qq, kk_, vv, ii, ss, tt = inputs
        # stabilizer for this chunk: max over intra log-weights and carry
        log_intra = ss[:, :, None, :] - ss[:, None, :, :] + ii[:, None, :, :]
        causal = jnp.tril(jnp.ones((L, L), bool))[None, :, :, None]
        log_intra = jnp.where(causal, log_intra, -1e30)
        m_intra = jnp.max(log_intra, axis=2)               # (B, L, nh)
        m_carry = m[:, None, :] + ss                       # (B, L, nh)
        m_t = jnp.maximum(m_intra, m_carry)
        w = jnp.exp(log_intra - m_t[:, :, None, :])        # (B, L, L, nh)
        scores = jnp.einsum("bshd,bthd->bsth", qq, kk_)    # (B, L, L, nh)
        wq = w * scores
        y_intra = jnp.einsum("bsth,bthd->bshd", wq, vv)
        n_intra = jnp.sum(wq, axis=2)                      # (B, L, nh)
        # carried-state contribution
        carry_scale = jnp.exp(m_carry - m_t)               # (B, L, nh)
        y_carry = jnp.einsum("bshd,bhed->bshe", qq, C) * carry_scale[..., None]
        n_carry = jnp.einsum("bshd,bhd->bsh", qq, n) * carry_scale
        n_den = jnp.abs(n_intra + n_carry)
        # normalizer floor "1" lives in absolute units -> exp(-m_t) here
        y = (y_intra + y_carry) / jnp.maximum(n_den, jnp.exp(-m_t))[..., None]
        # state update (log-stabilized)
        m_new = jnp.maximum(m + tt, jnp.max(ii + tt[:, None, :] - ss, axis=1))
        carry_w = jnp.exp(ii + tt[:, None, :] - ss - m_new[:, None, :])  # (B,L,nh)
        decay = jnp.exp(m + tt - m_new)                         # (B, nh)
        C = C * decay[:, :, None, None] + jnp.einsum(
            "bthd,bthe,bth->bhde", vv, kk_, carry_w)
        n = n * decay[:, :, None] + jnp.einsum("bthd,bth->bhd", kk_, carry_w)
        return (C, n, m_new), y

    init = (jnp.zeros((B, nh, hd, hd), jnp.float32),   # (v ⊗ k) memory
            jnp.zeros((B, nh, hd), jnp.float32),
            jnp.full((B, nh), -1e30, jnp.float32))     # stabilizer (log)
    xs_c = (qc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
            vc.transpose(1, 0, 2, 3, 4), igc.transpose(1, 0, 2, 3),
            seg.transpose(1, 0, 2, 3), total.transpose(1, 0, 2))
    _, ys = scan_or_unroll(jax.checkpoint(chunk_body), init, xs_c)

    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, Sp, nh, hd)[:, :S] \
        .reshape(B, S, di)
    y = apply_rmsnorm(p["norm"], y.astype(x.dtype))
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return apply_linear(p["down"], y, policy, path="blk/down")


def init_mlstm_state(B: int, cfg: XLSTMCfg) -> dict:
    nh, hd = cfg.n_heads, cfg.head_dim
    return {
        "C": jnp.zeros((B, nh, hd, hd), jnp.float32),
        "n": jnp.zeros((B, nh, hd), jnp.float32),
        "m": jnp.full((B, nh), -1e30, jnp.float32),
    }


def decode_mlstm_step(p: dict, cfg: XLSTMCfg, x_t: jax.Array, state: dict,
                      policy: TransPolicy) -> tuple[jax.Array, dict]:
    B = x_t.shape[0]
    nh, hd, di = cfg.n_heads, cfg.head_dim, cfg.d_inner
    ug = apply_linear(p["up"], x_t, policy, path="blk/up")
    xi, z = ug[..., :di], ug[..., di:]
    q = apply_linear(p["wq"], xi, policy, path="blk/wq").reshape(B, nh, hd).astype(jnp.float32)
    k = (apply_linear(p["wk"], xi, policy, path="blk/wk").reshape(B, nh, hd) * (hd ** -0.5)) \
        .astype(jnp.float32)
    v = apply_linear(p["wv"], xi, policy, path="blk/wv").reshape(B, nh, hd).astype(jnp.float32)
    ig = apply_linear(p["wi"], xi, policy, path="blk/wi").astype(jnp.float32).reshape(B, nh)
    fg = jax.nn.log_sigmoid(apply_linear(p["wf"], xi, policy, path="blk/wf").astype(jnp.float32)) \
        .reshape(B, nh)
    m_new = jnp.maximum(state["m"] + fg, ig)
    decay = jnp.exp(state["m"] + fg - m_new)
    inw = jnp.exp(ig - m_new)
    C = state["C"] * decay[:, :, None, None] + jnp.einsum(
        "bhd,bhe,bh->bhde", v, k, inw)
    n = state["n"] * decay[:, :, None] + k * inw[:, :, None]
    y = jnp.einsum("bhde,bhe->bhd", C, q.reshape(B, nh, hd))
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", n, q.reshape(B, nh, hd)))
    y = y / jnp.maximum(den, jnp.exp(-m_new))[:, :, None]
    y = apply_rmsnorm(p["norm"], y.reshape(B, 1, di).astype(x_t.dtype))
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x_t.dtype)
    return apply_linear(p["down"], y, policy, path="blk/down"), {"C": C, "n": n, "m": m_new}


# --------------------------------------------------------------- sLSTM --------

def init_slstm(key, cfg: XLSTMCfg) -> dict:
    kx, kr, kf, kd = jax.random.split(key, 4)
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    f = int(d * 4 / 3 / 8) * 8
    return {
        # input projections for (z, i, f, o) gates
        "wx": init_linear(kx, d, 4 * d),
        "r": jax.random.normal(kr, (nh, dh, 4 * dh), jnp.float32) * dh ** -0.5,
        "norm": init_rmsnorm(d),
        "ffn_up": init_linear(kf, d, 2 * f),
        "ffn_down": init_linear(kd, f, d, scale=f ** -0.5),
    }


def apply_slstm(p: dict, cfg: XLSTMCfg, x: jax.Array, policy: TransPolicy) -> jax.Array:
    """Sequential scalar-memory recurrence (lax.scan; elementwise body)."""
    B, S, d = x.shape
    nh = cfg.n_heads
    dh = d // nh
    gates_x = apply_linear(p["wx"], x, policy, path="blk/wx").astype(jnp.float32)  # (B,S,4d)

    def step(carry, gx):
        c, n, m, h = carry                      # each (B, nh, dh) / m: (B,nh,dh)
        rec = jnp.einsum("bhd,hde->bhe", h, p["r"]).reshape(B, nh, 4 * dh)
        g = gx.reshape(B, nh, 4 * dh) + rec
        zt = jnp.tanh(g[..., :dh])
        it = g[..., dh:2 * dh]                  # log-space input gate
        ft = jax.nn.log_sigmoid(g[..., 2 * dh:3 * dh])
        ot = jax.nn.sigmoid(g[..., 3 * dh:])
        m_new = jnp.maximum(ft + m, it)
        i_s = jnp.exp(it - m_new)
        f_s = jnp.exp(ft + m - m_new)
        c_new = f_s * c + i_s * zt
        n_new = f_s * n + i_s
        h_new = ot * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, m_new, h_new), h_new

    z0 = jnp.zeros((B, nh, dh), jnp.float32)
    init = (z0, z0, jnp.full((B, nh, dh), -1e30), z0)
    _, hs = jax.lax.scan(step, init, gates_x.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2, 3).reshape(B, S, d).astype(x.dtype)
    y = apply_rmsnorm(p["norm"], x + y)
    u = apply_linear(p["ffn_up"], y, policy, path="blk/ffn_up")
    f = u.shape[-1] // 2
    h = jax.nn.gelu(u[..., :f].astype(jnp.float32)).astype(x.dtype) * u[..., f:]
    return apply_linear(p["ffn_down"], h, policy, path="blk/ffn_down")


def init_slstm_state(B: int, cfg: XLSTMCfg) -> dict:
    nh, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
    z = jnp.zeros((B, nh, dh), jnp.float32)
    return {"c": z, "n": z, "m": jnp.full((B, nh, dh), -1e30), "h": z}


def decode_slstm_step(p: dict, cfg: XLSTMCfg, x_t: jax.Array, state: dict,
                      policy: TransPolicy) -> tuple[jax.Array, dict]:
    B, _, d = x_t.shape
    nh = cfg.n_heads
    dh = d // nh
    gx = apply_linear(p["wx"], x_t, policy, path="blk/wx").astype(jnp.float32)[:, 0]
    rec = jnp.einsum("bhd,hde->bhe", state["h"], p["r"]).reshape(B, nh, 4 * dh)
    g = gx.reshape(B, nh, 4 * dh) + rec
    zt = jnp.tanh(g[..., :dh])
    it = g[..., dh:2 * dh]
    ft = jax.nn.log_sigmoid(g[..., 2 * dh:3 * dh])
    ot = jax.nn.sigmoid(g[..., 3 * dh:])
    m_new = jnp.maximum(ft + state["m"], it)
    i_s = jnp.exp(it - m_new)
    f_s = jnp.exp(ft + state["m"] - m_new)
    c_new = f_s * state["c"] + i_s * zt
    n_new = f_s * state["n"] + i_s
    h_new = ot * c_new / jnp.maximum(n_new, 1.0)
    y = apply_rmsnorm(p["norm"], x_t + h_new.reshape(B, 1, d).astype(x_t.dtype))
    u = apply_linear(p["ffn_up"], y, policy, path="blk/ffn_up")
    f = u.shape[-1] // 2
    h = jax.nn.gelu(u[..., :f].astype(jnp.float32)).astype(x_t.dtype) * u[..., f:]
    out = apply_linear(p["ffn_down"], h, policy, path="blk/ffn_down")
    return out, {"c": c_new, "n": n_new, "m": m_new, "h": h_new}
