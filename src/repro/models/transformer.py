"""Decoder-only LM assembly for the dense / moe / gemma3 / zamba / xlstm
families: init, forward (train), sequence-chunked loss, and the serving path
(cache init / prefill / decode_step).

Layer stacks are built as *segments*: maximal homogeneous runs of layers whose
params are stacked and applied with lax.scan (remat-wrapped) — one HLO body per
segment regardless of depth. Heterogeneous patterns (gemma3 local/global rope
and window, xlstm mLSTM/sLSTM, zamba shared-attention interleave) become
per-layer scalar arrays fed as scan xs, or segment boundaries.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelCfg
from repro.core.pcsr import TransPolicy
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.attention import AttnCfg
from repro.models.shardhooks import maybe_shard
from repro.models.unroll import scan_or_unroll
from repro.models.layers import (apply_embedding, apply_linear, apply_rmsnorm,
                                 apply_swiglu, embedding_logits, init_embedding,
                                 init_linear, init_rmsnorm, init_swiglu)

LOSS_CHUNK = 1024  # sequence-chunked CE to bound peak logits memory


# ---------------------------------------------------------------------------
# layer-pattern metadata
# ---------------------------------------------------------------------------

def attn_cfg(cfg: ModelCfg, *, window: int = 0, rope_base: float | None = None,
             causal: bool = True, is_cross: bool = False,
             use_rope: bool = True) -> AttnCfg:
    return AttnCfg(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
        head_dim=cfg.hd, qkv_bias=cfg.qkv_bias,
        rope_base=cfg.rope_base if rope_base is None else rope_base,
        causal=causal, window=window, is_cross=is_cross, use_rope=use_rope,
    )


def gemma3_layer_meta(cfg: ModelCfg):
    """Per-layer (window, rope_base) arrays: local_ratio local per 1 global.

    Built in numpy so the pattern stays concrete under jit tracing (prefill
    reads individual entries as python scalars).
    """
    import numpy as np

    period = cfg.local_ratio + 1
    is_global = np.asarray(
        [(i % period) == cfg.local_ratio for i in range(cfg.n_layers)])
    window = np.where(is_global, 0, cfg.window).astype(np.int32)
    rope = np.where(is_global, cfg.global_rope_base, cfg.rope_base) \
        .astype(np.float32)
    return window, rope


def _stack_init(fn, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(fn)(keys)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_lm(key, cfg: ModelCfg) -> dict:
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {"embed": init_embedding(keys[0], cfg.vocab, cfg.d_model)}
    acfg = attn_cfg(cfg)

    if cfg.family in ("dense", "moe", "gemma3", "vlm"):
        def one(k):
            ks = jax.random.split(k, 4)
            p = {
                "ln1": init_rmsnorm(cfg.d_model),
                "attn": attn.init_attention(ks[0], acfg),
                "ln2": init_rmsnorm(cfg.d_model),
            }
            if cfg.family == "moe":
                p["moe"] = moe_mod.init_moe(ks[1], cfg.d_model, cfg.d_ff,
                                            cfg.n_experts)
            else:
                p["mlp"] = init_swiglu(ks[1], cfg.d_model, cfg.d_ff)
            return p
        params["blocks"] = _stack_init(one, keys[1], cfg.n_layers)

    elif cfg.family == "zamba":
        scfg = _zamba_ssm_cfg(cfg)
        def one(k):
            return {"ln": init_rmsnorm(cfg.d_model),
                    "ssm": ssm_mod.init_ssm(k, scfg)}
        params["blocks"] = _stack_init(one, keys[1], cfg.n_layers)
        ks = jax.random.split(keys[2], 3)
        params["shared"] = {
            "ln1": init_rmsnorm(cfg.d_model),
            "attn": attn.init_attention(ks[0], acfg),
            "ln2": init_rmsnorm(cfg.d_model),
            "mlp": init_swiglu(ks[1], cfg.d_model, cfg.d_ff),
        }

    elif cfg.family == "xlstm":
        xcfg = _xlstm_cfg(cfg)
        mo, so = [], []
        for i in range(cfg.n_layers):
            (so if _is_slstm(cfg, i) else mo).append(i)
        km = jax.random.split(keys[1], max(len(mo), 1))
        ksl = jax.random.split(keys[2], max(len(so), 1))
        params["mlstm"] = jax.vmap(
            lambda k: {"ln": init_rmsnorm(cfg.d_model),
                       "blk": xlstm_mod.init_mlstm(k, xcfg)})(km[:len(mo)]) \
            if mo else {}
        params["slstm"] = jax.vmap(
            lambda k: {"ln": init_rmsnorm(cfg.d_model),
                       "blk": xlstm_mod.init_slstm(k, xcfg)})(ksl[:len(so)]) \
            if so else {}
    else:
        raise ValueError(cfg.family)

    params["final_norm"] = init_rmsnorm(cfg.d_model)
    if not cfg.tie_embeddings:
        params["lm_head"] = init_linear(keys[3], cfg.d_model, cfg.vocab)
    if cfg.family == "vlm" or cfg.n_patches:
        params["patch_proj"] = init_linear(keys[4], cfg.d_model, cfg.d_model)
    return params


def _zamba_ssm_cfg(cfg: ModelCfg) -> ssm_mod.SSMCfg:
    return ssm_mod.SSMCfg(d_model=cfg.d_model, d_state=cfg.ssm_state,
                          head_dim=cfg.ssm_head_dim, chunk=cfg.ssm_chunk)


def _xlstm_cfg(cfg: ModelCfg) -> xlstm_mod.XLSTMCfg:
    return xlstm_mod.XLSTMCfg(d_model=cfg.d_model, n_heads=cfg.n_heads,
                              chunk=cfg.xlstm_chunk)


def _is_slstm(cfg: ModelCfg, i: int) -> bool:
    return cfg.slstm_every > 0 and (i % cfg.slstm_every == 1)


# ---------------------------------------------------------------------------
# forward (train / no cache)
# ---------------------------------------------------------------------------

def _gemma3_is_global(cfg: ModelCfg, i: int) -> bool:
    return (i % (cfg.local_ratio + 1)) == cfg.local_ratio


def forward(params: dict, tokens: jax.Array, cfg: ModelCfg,
            policy: TransPolicy, *, patch_embeds: Optional[jax.Array] = None,
            remat: bool = True) -> tuple[jax.Array, jax.Array]:
    """tokens: (B, S) -> hidden (B, S_total, D), aux loss. (vlm: patches prefix)."""
    x = apply_embedding(params["embed"], tokens)
    if patch_embeds is not None:
        pe = apply_linear(params["patch_proj"], patch_embeds, policy,
                          path="patch_proj")
        x = jnp.concatenate([pe.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    aux_total = jnp.float32(0.0)

    if cfg.family in ("dense", "moe", "gemma3", "vlm"):
        acfg = attn_cfg(cfg)
        if cfg.family == "gemma3":
            win_arr, rope_arr = gemma3_layer_meta(cfg)
        else:
            win_arr = jnp.zeros((cfg.n_layers,), jnp.int32)
            rope_arr = jnp.full((cfg.n_layers,), cfg.rope_base, jnp.float32)

        def body(carry, layer):
            x, aux = carry
            x = maybe_shard(x, "residual")
            p, win, rope = layer
            h = apply_rmsnorm(p["ln1"], x, cfg.norm_eps)
            a = attn.apply_attention_dynwin(p["attn"], acfg, h, policy,
                                            window=win, rope_base=rope,
                                            path="attn")
            x = x + a
            h = apply_rmsnorm(p["ln2"], x, cfg.norm_eps)
            if "moe" in p:
                y, aux_l = moe_mod.apply_moe(
                    p["moe"], h, top_k=cfg.top_k,
                    capacity_factor=cfg.capacity_factor, policy=policy)
                y = x + y
            else:
                # block residual fuses into the down-projection epilogue
                y, aux_l = apply_swiglu(p["mlp"], h, policy, residual=x,
                                        path="mlp"), 0.0
            return (y, aux + aux_l), None

        fn = jax.checkpoint(body) if remat else body
        (x, aux_total), _ = scan_or_unroll(
            fn, (x, aux_total),
            (params["blocks"], jnp.asarray(win_arr), jnp.asarray(rope_arr)))

    elif cfg.family == "zamba":
        scfg = _zamba_ssm_cfg(cfg)
        acfg = attn_cfg(cfg)

        def ssm_body(x, p):
            x = maybe_shard(x, "residual")
            h = apply_rmsnorm(p["ln"], x, cfg.norm_eps)
            return x + ssm_mod.apply_ssm(p["ssm"], scfg, h, policy), None

        fn = jax.checkpoint(ssm_body) if remat else ssm_body

        def shared_body(x, sp):
            h = apply_rmsnorm(sp["ln1"], x, cfg.norm_eps)
            x = x + attn.apply_attention(sp["attn"], acfg, h, policy, path="attn")
            h = apply_rmsnorm(sp["ln2"], x, cfg.norm_eps)
            return apply_swiglu(sp["mlp"], h, policy, residual=x, path="mlp")

        if remat:
            shared_body = jax.checkpoint(shared_body)
        sp = params["shared"]
        for seg_start, seg_len, use_shared in _zamba_segments(cfg):
            seg = jax.tree.map(lambda a: a[seg_start:seg_start + seg_len],
                               params["blocks"])
            x, _ = scan_or_unroll(fn, x, seg)
            if use_shared:
                x = shared_body(x, sp)

    elif cfg.family == "xlstm":
        xcfg = _xlstm_cfg(cfg)

        def m_body(x, p):
            x = maybe_shard(x, "residual")
            h = apply_rmsnorm(p["ln"], x, cfg.norm_eps)
            return x + xlstm_mod.apply_mlstm(p["blk"], xcfg, h, policy)

        def s_body(x, p):
            h = apply_rmsnorm(p["ln"], x, cfg.norm_eps)
            return x + xlstm_mod.apply_slstm(p["blk"], xcfg, h, policy)

        if remat:
            m_body, s_body = jax.checkpoint(m_body), jax.checkpoint(s_body)
        mi = si = 0
        for i in range(cfg.n_layers):
            if _is_slstm(cfg, i):
                x = s_body(x, jax.tree.map(lambda a: a[si], params["slstm"]))
                si += 1
            else:
                x = m_body(x, jax.tree.map(lambda a: a[mi], params["mlstm"]))
                mi += 1
    else:
        raise ValueError(cfg.family)

    return apply_rmsnorm(params["final_norm"], x, cfg.norm_eps), aux_total


def _zamba_segments(cfg: ModelCfg):
    """Yield (start, len, apply_shared_after) covering all n_layers."""
    if not cfg.shared_attn_every:
        return [(0, cfg.n_layers, False)]
    segs = []
    start = 0
    while start < cfg.n_layers:
        ln = min(cfg.shared_attn_every, cfg.n_layers - start)
        segs.append((start, ln, ln == cfg.shared_attn_every))
        start += ln
    return segs


def logits_fn(params: dict, h: jax.Array, cfg: ModelCfg,
              policy: TransPolicy) -> jax.Array:
    if cfg.tie_embeddings:
        return embedding_logits(params["embed"], h)
    return apply_linear(params["lm_head"], h, policy,
                        path="lm_head").astype(jnp.float32)


def lm_loss(params: dict, batch: dict, cfg: ModelCfg, policy: TransPolicy,
            *, aux_weight: float = 0.01) -> tuple[jax.Array, dict]:
    """Sequence-chunked cross-entropy. batch: tokens (B,S), labels (B,S)."""
    h, aux = forward(params, batch["tokens"], cfg, policy,
                     patch_embeds=batch.get("patch_embeds"))
    if "patch_embeds" in batch and batch["patch_embeds"] is not None:
        h = h[:, batch["patch_embeds"].shape[1]:]  # loss over text positions only
    labels = batch["labels"]
    B, S, D = h.shape
    n_chunks = max(1, S // LOSS_CHUNK)
    Sc = S // n_chunks

    def chunk_loss(carry, hc_lc):
        hc, lc = hc_lc
        lg = logits_fn(params, hc, cfg, policy)
        lp = jax.nn.log_softmax(lg, axis=-1)
        ll = jnp.take_along_axis(lp, lc[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(ll), None

    hs = h[:, :n_chunks * Sc].reshape(B, n_chunks, Sc, D).transpose(1, 0, 2, 3)
    ls = labels[:, :n_chunks * Sc].reshape(B, n_chunks, Sc).transpose(1, 0, 2)
    total, _ = scan_or_unroll(jax.checkpoint(chunk_loss), jnp.float32(0.0), (hs, ls))
    ce = -total / (B * n_chunks * Sc)
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelCfg, B: int, S_max: int, policy: TransPolicy) -> dict:
    acfg = attn_cfg(cfg)
    cache: dict[str, Any] = {}
    if cfg.family in ("dense", "moe", "gemma3", "vlm"):
        def one_cache(i):
            # gemma3 local layers only need a window-sized cache
            if cfg.family == "gemma3":
                period = cfg.local_ratio + 1
                is_global = (i % period) == cfg.local_ratio
                s = S_max if is_global else min(S_max, cfg.window)
            else:
                s = S_max
            return attn.init_kv_cache(B, s, acfg, policy)
        if cfg.family == "gemma3":
            cache["kv"] = [one_cache(i) for i in range(cfg.n_layers)]
        else:
            cache["kv"] = jax.vmap(
                lambda _: attn.init_kv_cache(B, S_max, acfg, policy)
            )(jnp.arange(cfg.n_layers))
    elif cfg.family == "zamba":
        scfg = _zamba_ssm_cfg(cfg)
        cache["ssm"] = jax.vmap(
            lambda _: ssm_mod.init_ssm_state(B, scfg))(jnp.arange(cfg.n_layers))
        n_shared = sum(1 for *_x, s in _zamba_segments(cfg) if s)
        cache["shared_kv"] = [
            attn.init_kv_cache(B, S_max, acfg, policy) for _ in range(n_shared)]
    elif cfg.family == "xlstm":
        xcfg = _xlstm_cfg(cfg)
        cache["mlstm"] = [xlstm_mod.init_mlstm_state(B, xcfg)
                          for i in range(cfg.n_layers) if not _is_slstm(cfg, i)]
        cache["slstm"] = [xlstm_mod.init_slstm_state(B, xcfg)
                          for i in range(cfg.n_layers) if _is_slstm(cfg, i)]
    cache["pos"] = jnp.zeros((), jnp.int32)
    # per-row sequence positions (ragged continuous batching: each slot sits
    # at its own next-write index; lockstep serving keeps them all equal)
    cache["lens"] = jnp.zeros((B,), jnp.int32)
    return cache


def init_paged_cache(cfg: ModelCfg, B: int, n_blocks: int, block_tokens: int,
                     table_width: int, policy: TransPolicy) -> dict:
    """Paged serving cache (DESIGN.md §14): one block pool per layer stacked
    on a leading L axis, a per-slot block table shared by every layer, and
    the same ragged ``lens`` bookkeeping as the slot grid.

    Only the uniform stacked-cache families page their KV: gemma3's
    window-sized local buffers, zamba/xlstm recurrent state, and the vlm
    patch prefix (not addressable by token ids, so block hashes cannot
    cover it) all keep the slot grid.
    """
    if cfg.family not in ("dense", "moe"):
        raise ValueError(
            f"paged KV serves the uniform stacked-cache families "
            f"(dense/moe); {cfg.family!r} keeps the slot grid")
    acfg = attn_cfg(cfg)
    return {
        "kv": jax.vmap(lambda _: attn.init_paged_kv_pool(
            n_blocks, block_tokens, acfg, policy))(jnp.arange(cfg.n_layers)),
        # sentinel-filled: every entry out of bounds until the engine
        # installs real tables (writes drop, reads are masked)
        "table": jnp.full((B, table_width), n_blocks, jnp.int32),
        "pos": jnp.zeros((), jnp.int32),
        "lens": jnp.zeros((B,), jnp.int32),
    }


def decode_step_paged(params: dict, token_t: jax.Array, cache: dict,
                      cfg: ModelCfg, policy: TransPolicy) -> tuple:
    """One token for the whole slot grid over the paged KV pool.

    The same layer scan as :func:`decode_step`'s dense/moe body, with the
    per-layer cache slice swapped for (pool slice, shared block table):
    each row writes at ``table[b, lens[b] // bt]`` offset ``lens[b] % bt``
    and attention gathers its tiles through the table.
    """
    if cfg.family not in ("dense", "moe"):
        raise ValueError(f"decode_step_paged: unsupported family {cfg.family!r}")
    lens, table = cache["lens"], cache["table"]
    x = apply_embedding(params["embed"], token_t[:, None])
    acfg = attn_cfg(cfg)

    def body(x_carry, layer):
        p, pool = layer
        h = apply_rmsnorm(p["ln1"], x_carry, cfg.norm_eps)
        a, pool2 = attn.decode_attention_step_paged(
            p["attn"], acfg, h, pool, table, lens, policy, path="attn")
        x2 = x_carry + a
        h = apply_rmsnorm(p["ln2"], x2, cfg.norm_eps)
        if "moe" in p:
            y, _ = moe_mod.apply_moe(
                p["moe"], h, top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor, policy=policy)
        else:
            y = apply_swiglu(p["mlp"], h, policy, path="mlp")
        return x2 + y, pool2

    x, new_kv = scan_or_unroll(body, x, (params["blocks"], cache["kv"]))
    new_cache = dict(cache)
    new_cache["kv"] = new_kv
    h = apply_rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_fn(params, h, cfg, policy)[:, 0]
    new_cache["pos"] = cache["pos"] + 1
    new_cache["lens"] = lens + 1
    return logits, new_cache


def decode_step(params: dict, token_t: jax.Array, cache: dict, cfg: ModelCfg,
                policy: TransPolicy) -> tuple[jax.Array, dict]:
    """One token for the whole batch. token_t: (B,) int32 -> logits (B, V).

    Positions are per-row (``cache["lens"]``): rows of a continuous batch
    each write at their own sequence index and mask by their own length; a
    lockstep batch simply keeps every row's position equal.  ``cache["pos"]``
    stays the scalar step counter for lockstep callers.
    """
    pos = cache["pos"]
    B = token_t.shape[0]
    # per-row next-write positions; fall back to the scalar counter for
    # hand-built caches that predate the ragged layout
    lens = cache.get("lens")
    if lens is None:
        lens = jnp.broadcast_to(pos, (B,))
    x = apply_embedding(params["embed"], token_t[:, None])
    new_cache = dict(cache)

    if cfg.family in ("dense", "moe", "gemma3", "vlm"):
        acfg = attn_cfg(cfg)
        if cfg.family == "gemma3":
            kvs = []
            for i in range(cfg.n_layers):
                p = jax.tree.map(lambda a: a[i], params["blocks"])
                is_global = _gemma3_is_global(cfg, i)
                a_i = attn_cfg(
                    cfg, window=0 if is_global else cfg.window,
                    rope_base=cfg.global_rope_base if is_global else cfg.rope_base)
                h = apply_rmsnorm(p["ln1"], x, cfg.norm_eps)
                # local layers use a rolling window cache position
                c = cache["kv"][i]
                p_eff = lens if is_global else lens % c["k"].shape[2]
                a, c2 = attn.decode_attention_step(
                    p["attn"], a_i, h, c, p_eff, policy,
                    rolling=not is_global, abs_pos=lens, path="attn")
                kvs.append(c2)
                x = x + a
                h = apply_rmsnorm(p["ln2"], x, cfg.norm_eps)
                x = x + apply_swiglu(p["mlp"], h, policy, path="mlp")
            new_cache["kv"] = kvs
        else:
            def body(x_carry, layer):
                p, c = layer
                h = apply_rmsnorm(p["ln1"], x_carry, cfg.norm_eps)
                a, c2 = attn.decode_attention_step(p["attn"], acfg, h, c, lens,
                                                   policy, path="attn")
                x2 = x_carry + a
                h = apply_rmsnorm(p["ln2"], x2, cfg.norm_eps)
                if "moe" in p:
                    y, _ = moe_mod.apply_moe(
                        p["moe"], h, top_k=cfg.top_k,
                        capacity_factor=cfg.capacity_factor, policy=policy)
                else:
                    y = apply_swiglu(p["mlp"], h, policy, path="mlp")
                return x2 + y, c2
            x, new_kv = scan_or_unroll(body, x, (params["blocks"], cache["kv"]))
            new_cache["kv"] = new_kv

    elif cfg.family == "zamba":
        scfg = _zamba_ssm_cfg(cfg)
        acfg = attn_cfg(cfg)

        def body(x_carry, layer):
            p, st = layer
            h = apply_rmsnorm(p["ln"], x_carry, cfg.norm_eps)
            y, st2 = ssm_mod.decode_ssm_step(p["ssm"], scfg, h, st, policy)
            return x_carry + y, st2

        sp = params["shared"]
        new_states, shared_kvs = [], []
        shared_i = 0
        for seg_start, seg_len, use_shared in _zamba_segments(cfg):
            seg_p = jax.tree.map(lambda a: a[seg_start:seg_start + seg_len],
                                 params["blocks"])
            seg_s = jax.tree.map(lambda a: a[seg_start:seg_start + seg_len],
                                 cache["ssm"])
            x, st2 = scan_or_unroll(body, x, (seg_p, seg_s))
            new_states.append(st2)
            if use_shared:
                h = apply_rmsnorm(sp["ln1"], x, cfg.norm_eps)
                a, c2 = attn.decode_attention_step(
                    sp["attn"], acfg, h, cache["shared_kv"][shared_i], lens,
                    policy, path="attn")
                shared_kvs.append(c2)
                x = x + a
                h = apply_rmsnorm(sp["ln2"], x, cfg.norm_eps)
                x = x + apply_swiglu(sp["mlp"], h, policy, path="mlp")
                shared_i += 1
        new_cache["ssm"] = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *new_states)
        new_cache["shared_kv"] = shared_kvs

    elif cfg.family == "xlstm":
        xcfg = _xlstm_cfg(cfg)
        mi = si = 0
        new_m, new_s = [], []
        for i in range(cfg.n_layers):
            if _is_slstm(cfg, i):
                p = jax.tree.map(lambda a: a[si], params["slstm"])
                h = apply_rmsnorm(p["ln"], x, cfg.norm_eps)
                y, st = xlstm_mod.decode_slstm_step(
                    p["blk"], xcfg, h, cache["slstm"][si], policy)
                new_s.append(st)
                si += 1
            else:
                p = jax.tree.map(lambda a: a[mi], params["mlstm"])
                h = apply_rmsnorm(p["ln"], x, cfg.norm_eps)
                y, st = xlstm_mod.decode_mlstm_step(
                    p["blk"], xcfg, h, cache["mlstm"][mi], policy)
                new_m.append(st)
                mi += 1
            x = x + y
        new_cache["mlstm"], new_cache["slstm"] = new_m, new_s

    h = apply_rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_fn(params, h, cfg, policy)[:, 0]
    new_cache["pos"] = pos + 1
    if "lens" in cache:
        new_cache["lens"] = lens + 1
    return logits, new_cache


def prefill(params: dict, tokens: jax.Array, cfg: ModelCfg,
            policy: TransPolicy, *, S_max: Optional[int] = None,
            patch_embeds: Optional[jax.Array] = None) -> tuple[jax.Array, dict]:
    """Run the full prompt, build the cache, return last-position logits.

    Implemented as forward() + cache build from the same K/V projections would
    duplicate compute; for clarity and dry-run fidelity we run the attention
    prefill path per layer (full-sequence SDPA that also writes the cache).
    """
    B, S = tokens.shape
    S_max = S_max or S
    cache = init_cache(cfg, B, S_max, policy)
    x = apply_embedding(params["embed"], tokens)
    if patch_embeds is not None:
        pe = apply_linear(params["patch_proj"], patch_embeds, policy,
                          path="patch_proj")
        x = jnp.concatenate([pe.astype(x.dtype), x], axis=1)

    if cfg.family in ("dense", "moe", "gemma3", "vlm"):
        acfg = attn_cfg(cfg)
        if cfg.family == "gemma3":
            win_arr, rope_arr = gemma3_layer_meta(cfg)
            kvs = []
            for i in range(cfg.n_layers):
                p = jax.tree.map(lambda a: a[i], params["blocks"])
                a_i = attn_cfg(cfg, window=int(win_arr[i]),
                               rope_base=float(rope_arr[i]))
                h = apply_rmsnorm(p["ln1"], x, cfg.norm_eps)
                a, c2 = attn.prefill_attention(p["attn"], a_i, h,
                                               cache["kv"][i], policy,
                                               path="attn")
                kvs.append(c2)
                x = x + a
                h = apply_rmsnorm(p["ln2"], x, cfg.norm_eps)
                x = x + apply_swiglu(p["mlp"], h, policy, path="mlp")
            cache["kv"] = kvs
        else:
            def body(x_carry, layer):
                p, c = layer
                x_carry = maybe_shard(x_carry, "residual")
                h = apply_rmsnorm(p["ln1"], x_carry, cfg.norm_eps)
                a, c2 = attn.prefill_attention(p["attn"], acfg, h, c, policy,
                                               path="attn")
                x2 = x_carry + a
                h = apply_rmsnorm(p["ln2"], x2, cfg.norm_eps)
                if "moe" in p:
                    y, _ = moe_mod.apply_moe(
                        p["moe"], h, top_k=cfg.top_k,
                        capacity_factor=cfg.capacity_factor, policy=policy)
                else:
                    y = apply_swiglu(p["mlp"], h, policy, path="mlp")
                return x2 + y, c2
            x, new_kv = scan_or_unroll(
                jax.checkpoint(body), x, (params["blocks"], cache["kv"]))
            cache["kv"] = new_kv
    else:
        # recurrent families: run the training forward then seed states by a
        # single decode over the last token (states carry no prompt history
        # here — full recurrent prefill is exercised via forward(); this path
        # is used by serving examples with short prompts)
        h, _ = forward(params, tokens, cfg, policy, remat=False)
        hN = apply_rmsnorm(params["final_norm"], h[:, -1:], cfg.norm_eps)
        logits = logits_fn(params, hN, cfg, policy)[:, 0]
        cache["pos"] = jnp.asarray(S, jnp.int32)
        cache["lens"] = jnp.full((B,), S, jnp.int32)
        return logits, cache

    h = apply_rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = logits_fn(params, h, cfg, policy)[:, 0]
    cache["pos"] = jnp.asarray(x.shape[1], jnp.int32)
    cache["lens"] = jnp.full((B,), x.shape[1], jnp.int32)
    return logits, cache
