"""Mamba-2 (SSD) block — chunked-parallel training form + O(1) decode step.

The recurrence  h_t = exp(dt_t * A) h_{t-1} + dt_t * (B_t ⊗ x_t),
                y_t = C_t · h_t + D ∘ x_t
is evaluated chunk-parallel for training (intra-chunk attention-like matmuls,
inter-chunk state carry over a *static python loop* so every FLOP is visible
in the lowered HLO — keeps the roofline honest, unlike a lax.scan while-loop),
and as a single elementwise state update for decode.

Recurrent-state precision (DESIGN.md §7): by default the carried state h is
f32 — naively re-rounding it to a posit every step would compound error. With
``policy.state`` set to a posit format, the state is instead carried at posit
precision through a QUIRE: each step's update
    h' = round_once( decay (x) h  +  dt * (B ⊗ x) )
accumulates the decay*state product and the input injection *exactly* in a
Kulisch accumulator and rounds ONCE — the update error of a true
posit-state recurrence with hardware quire support (PERCIVAL), not the
doubled mul-round+add-round of a quire-free PAU. The training (chunked) path
applies the same carry between chunks via a straight-through estimator:
forward values are quire-exact, gradients flow through the f32 recurrence
(the quire is integer arithmetic and has no derivative).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.codec import posit_decode, posit_encode
from repro.core.pcsr import TransPolicy
from repro.core.quire import (
    QuireFmt, quire_accumulate, quire_add_posit, quire_read, quire_zero,
)
from repro.models.layers import apply_linear, init_linear
from repro.models.unroll import scan_or_unroll


def _quire_state_update(h: jax.Array, decay: jax.Array, inject: jax.Array,
                        policy: TransPolicy) -> jax.Array:
    """One recurrent carry h' = decay*h + inject at the policy's state format.

    policy.state=None -> plain f32 update. Otherwise both products land in a
    per-element quire (decay and h encoded to the state format once, the f32
    ``inject`` term encoded once) and the new state is a single rounding of
    the exact sum. Wrapped in a straight-through estimator so the chunked
    training path stays differentiable: forward is the quire value, backward
    is the f32 recurrence.

    h: (..., P, N); decay: broadcastable against h's leading axes (expanded
    with trailing singletons); inject: same shape as h.
    """
    decay_b = decay[..., None, None]
    h_f32 = h * decay_b + inject
    fmt = policy.state
    if fmt is None:
        return h_f32
    qf = QuireFmt.for_posit(fmt)
    h_c = posit_encode(h, fmt.nbits, fmt.es)
    d_c = posit_encode(decay_b, fmt.nbits, fmt.es)  # broadcasts in the quire
    u_c = posit_encode(inject, fmt.nbits, fmt.es)
    q = quire_zero(h.shape, qf)
    q = quire_accumulate(q, d_c, h_c, qf)
    q = quire_add_posit(q, u_c, qf)
    h_q = posit_decode(quire_read(q, qf), fmt.nbits, fmt.es)
    # NaR (can only arrive via non-finite f32 inputs) falls back to the f32
    # path rather than poisoning the whole recurrence with NaN.
    h_q = jnp.where(jnp.isnan(h_q), h_f32, h_q)
    return h_f32 + jax.lax.stop_gradient(h_q - h_f32)


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_model: int
    d_state: int = 64
    expand: int = 2
    head_dim: int = 64       # p
    conv_width: int = 4
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def init_ssm(key, cfg: SSMCfg) -> dict:
    """Projections are separate (not one fused in_proj): each output then
    carries its own TP sharding and the z/x/B/C/dt splits never slice across
    shard boundaries (a fused 2*di+2N+nh projection forces GSPMD to reshard
    at every misaligned slice — measured 4x collective blowup on zamba2)."""
    kz, kx, kb_, kc_, kt, kcv, ko = jax.random.split(key, 7)
    di, N, nh = cfg.d_inner, cfg.d_state, cfg.n_heads
    return {
        "z_proj": init_linear(kz, cfg.d_model, di),
        "x_proj": init_linear(kx, cfg.d_model, di),
        "B_proj": init_linear(kb_, cfg.d_model, N),
        "C_proj": init_linear(kc_, cfg.d_model, N),
        "dt_proj": init_linear(kt, cfg.d_model, nh),
        # depthwise causal convs: conv(concat) == concat(convs), kept separate
        "conv_x": {"w": jax.random.normal(kcv, (cfg.conv_width, di),
                                          jnp.float32) * 0.2,
                   "b": jnp.zeros((di,), jnp.float32)},
        "conv_B": {"w": jnp.full((cfg.conv_width, N), 0.25, jnp.float32),
                   "b": jnp.zeros((N,), jnp.float32)},
        "conv_C": {"w": jnp.full((cfg.conv_width, N), 0.25, jnp.float32),
                   "b": jnp.zeros((N,), jnp.float32)},
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_g": jnp.ones((di,), jnp.float32),
        "out_proj": init_linear(ko, di, cfg.d_model, scale=di ** -0.5),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along S. xbc: (B, S, Ch); w: (W, Ch)."""
    W = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(W):  # static, tiny
        out = out + pad[:, i:i + xbc.shape[1], :] * w[i]
    return jax.nn.silu(out + b)


def _gated_rmsnorm(x, z, g, eps=1e-6):
    xf = x.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return xf * jax.lax.rsqrt(var + eps) * g


def apply_ssm(p: dict, cfg: SSMCfg, x: jax.Array, policy: TransPolicy) -> jax.Array:
    """Training / prefill. x: (B, S, D) with S a multiple of... any S (padded)."""
    B, S, _ = x.shape
    di, N, nh, hp = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.head_dim
    L = min(cfg.chunk, S)
    n_chunks = -(-S // L)
    Sp = n_chunks * L

    z = apply_linear(p["z_proj"], x, policy, path="ssm/z_proj")
    xs_r = _causal_conv(apply_linear(p["x_proj"], x, policy, path="ssm/x_proj"),
                        p["conv_x"]["w"], p["conv_x"]["b"])
    Bm = _causal_conv(apply_linear(p["B_proj"], x, policy, path="ssm/B_proj"),
                      p["conv_B"]["w"], p["conv_B"]["b"])     # (B, S, N)
    Cm = _causal_conv(apply_linear(p["C_proj"], x, policy, path="ssm/C_proj"),
                      p["conv_C"]["w"], p["conv_C"]["b"])     # (B, S, N)
    xs = xs_r.reshape(B, S, nh, hp)
    dt = apply_linear(p["dt_proj"], x, policy, path="ssm/dt_proj")
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B, S, nh)
    A = -jnp.exp(p["A_log"])                       # (nh,) negative

    if Sp != S:
        pad = ((0, 0), (0, Sp - S), (0, 0), (0, 0))
        xs = jnp.pad(xs, pad)
        Bm, Cm = (jnp.pad(a, ((0, 0), (0, Sp - S), (0, 0))) for a in (Bm, Cm))
        dt = jnp.pad(dt, ((0, 0), (0, Sp - S), (0, 0)))

    xs = xs.reshape(B, n_chunks, L, nh, hp).astype(jnp.float32)
    Bc = Bm.reshape(B, n_chunks, L, N).astype(jnp.float32)
    Cc = Cm.reshape(B, n_chunks, L, N).astype(jnp.float32)
    dtc = dt.reshape(B, n_chunks, L, nh)

    dA = dtc * A                                   # (B, nc, L, nh) log-decay
    seg = jnp.cumsum(dA, axis=2)                   # within-chunk cumulative
    total = seg[:, :, -1, :]                       # (B, nc, nh)

    def chunk_body(h, inputs):
        xc, bc, cc, dtk, segc, tot = inputs
        # intra-chunk: scores[s,t] = (C_s·B_t) * exp(seg_s - seg_t) * dt_t, t<=s
        # (mask inside the exponent: exp of the masked positive diffs would be
        # inf and poison the backward pass via 0*inf)
        scores = jnp.einsum("bsn,btn->bst", cc, bc)[:, :, :, None]
        logdecay = segc[:, :, None, :] - segc[:, None, :, :]
        causal = jnp.tril(jnp.ones((L, L), bool))[None, :, :, None]
        decay = jnp.exp(jnp.where(causal, logdecay, -1e30))
        w = scores * decay * dtk[:, None, :, :]
        y_intra = jnp.einsum("bsth,bthp->bshp", w, xc)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bsn,bhpn,bsh->bshp", cc, h, jnp.exp(segc))
        # state update: h' = exp(total) h + sum_t exp(total - seg_t) dt_t B_t x_t
        # (quire-carried at posit precision when policy.state is set)
        carry_w = jnp.exp(tot[:, None, :] - segc) * dtk   # (B, L, nh)
        h = _quire_state_update(
            h, jnp.exp(tot),
            jnp.einsum("btn,bthp,bth->bhpn", bc, xc, carry_w), policy)
        return h, y_intra + y_inter

    h0 = jnp.zeros((B, nh, hp, N), jnp.float32)
    xs_c = (xs.transpose(1, 0, 2, 3, 4), Bc.transpose(1, 0, 2, 3),
            Cc.transpose(1, 0, 2, 3), dtc.transpose(1, 0, 2, 3),
            seg.transpose(1, 0, 2, 3), total.transpose(1, 0, 2))
    _, ys = scan_or_unroll(jax.checkpoint(chunk_body), h0, xs_c)

    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, Sp, nh, hp)[:, :S]
    y = y + xs.reshape(B, Sp, nh, hp)[:, :S] * p["D"][None, None, :, None]
    y = _gated_rmsnorm(y.reshape(B, S, di), z, p["norm_g"])
    return apply_linear(p["out_proj"], y.astype(x.dtype), policy, path="ssm/out_proj")


# ------------------------------------------------------------- decode step ----

def init_ssm_state(B: int, cfg: SSMCfg) -> dict:
    return {
        "h": jnp.zeros((B, cfg.n_heads, cfg.head_dim, cfg.d_state), jnp.float32),
        "conv": jnp.zeros((B, cfg.conv_width - 1, cfg.d_inner), jnp.float32),
        "convBC": jnp.zeros((B, cfg.conv_width - 1, 2 * cfg.d_state),
                            jnp.float32),
    }


def decode_ssm_step(p: dict, cfg: SSMCfg, x_t: jax.Array, state: dict,
                    policy: TransPolicy) -> tuple[jax.Array, dict]:
    """x_t: (B, 1, D) -> (B, 1, D); O(1) state update."""
    B = x_t.shape[0]
    di, N, nh, hp = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.head_dim
    z = apply_linear(p["z_proj"], x_t, policy, path="ssm/z_proj")
    x_in = apply_linear(p["x_proj"], x_t, policy, path="ssm/x_proj")[:, 0].astype(jnp.float32)
    bc_in = jnp.concatenate(
        [apply_linear(p["B_proj"], x_t, policy, path="ssm/B_proj")[:, 0],
         apply_linear(p["C_proj"], x_t, policy, path="ssm/C_proj")[:, 0]], -1).astype(jnp.float32)
    hist = jnp.concatenate([state["conv"], x_in[:, None, :]], axis=1)
    histBC = jnp.concatenate([state["convBC"], bc_in[:, None, :]], axis=1)
    wBC = jnp.concatenate([p["conv_B"]["w"], p["conv_C"]["w"]], -1)
    bBC = jnp.concatenate([p["conv_B"]["b"], p["conv_C"]["b"]], -1)
    xt = jax.nn.silu(jnp.einsum("bwc,wc->bc", hist, p["conv_x"]["w"])
                     + p["conv_x"]["b"]).reshape(B, nh, hp)
    bct = jax.nn.silu(jnp.einsum("bwc,wc->bc", histBC, wBC) + bBC)
    Bt, Ct = bct[:, :N], bct[:, N:]
    dtt = jax.nn.softplus(
        apply_linear(p["dt_proj"], x_t, policy, path="ssm/dt_proj")[:, 0].astype(jnp.float32)
        + p["dt_bias"])  # (B, nh)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dtt * A)                                    # (B, nh)
    h = _quire_state_update(
        state["h"], decay, jnp.einsum("bn,bhp,bh->bhpn", Bt, xt, dtt), policy)
    y = jnp.einsum("bhpn,bn->bhp", h, Ct) + xt * p["D"][None, :, None]
    y = _gated_rmsnorm(y.reshape(B, 1, di), z, p["norm_g"])
    out = apply_linear(p["out_proj"], y.astype(x_t.dtype), policy, path="ssm/out_proj")
    new_state = {"h": h, "conv": hist[:, 1:], "convBC": histBC[:, 1:]}
    return out, new_state
