"""Parameter init + core layer ops (linear, norm, rotary, MLP).

Parameter convention: params are nested dicts of jnp arrays. Posit-stored
weights appear as ``{"w_codes": uintN, ...}`` after ``quantize_params``; float
weights as ``{"w": floatN}``. The TransPolicy (static) says how to interpret
them — mirroring how the paper's pcsr, not the register file, carries format.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.codec import posit_encode
from repro.core.dot import apply_epilogue, posit_matmul_wx
from repro.core.lut import decode_with_impl
from repro.core.pcsr import TransPolicy
from repro.core.types import PositFmt, compute_dtype_for


def _compute_dtype(policy: TransPolicy):
    return jnp.float32 if policy.compute_dtype == "f32" else jnp.bfloat16


# ------------------------------------------------------------------ linear ----

def init_linear(key, d_in: int, d_out: int, *, bias: bool = False,
                scale: Optional[float] = None, dtype=jnp.float32) -> dict:
    if scale is None:
        scale = d_in ** -0.5
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def quantize_linear(p: dict, fmt: PositFmt) -> dict:
    """Convert a float linear param dict to posit storage (serving path)."""
    q = {"w_codes": posit_encode(p["w"].astype(jnp.float32), fmt.nbits, fmt.es)}
    if "b" in p:
        q["b"] = p["b"]  # biases stay float: O(d) storage, numerically sensitive
    return q


def effective_weight(p: dict, policy: TransPolicy, es=None) -> jax.Array:
    """The weight as seen by the matmul datapath.

    * posit codes       -> decode (exact; bf16 target for p8)
    * float + posit pol -> straight-through quantize (training: master weights
                           stay f32, forward sees posit-rounded values)
    * float, no policy  -> as-is (IEEE bypass)
    """
    if "w_codes" in p:
        fmt = policy.weights
        assert fmt is not None, "posit-coded params need policy.weights"
        return decode_with_impl(p["w_codes"], fmt.nbits,
                                fmt.es if es is None else es, policy.codec_impl)
    w = p["w"]
    fmt = policy.weights
    if fmt is not None:
        wf = w.astype(jnp.float32)
        e = fmt.es if es is None else es
        qw = decode_with_impl(
            posit_encode(wf, fmt.nbits, e), fmt.nbits, e, policy.codec_impl)
        w = w + jax.lax.stop_gradient(qw - wf).astype(w.dtype)
    return w


def apply_linear(p: dict, x: jax.Array, policy: TransPolicy, es=None, *,
                 activation: str = "none",
                 residual: Optional[jax.Array] = None) -> jax.Array:
    """y = act(x @ W + b) + residual, epilogue fused with the GEMM.

    Posit-coded weights route through ``posit_matmul_wx`` so the decode, the
    matmul and the whole epilogue stay one fused op (one kernel launch / HBM
    write on the serving path); ``policy.epilogue == "chained"`` materializes
    every stage instead (the benchmark baseline).
    """
    cd = _compute_dtype(policy)
    if "w_codes" in p:
        fmt = policy.weights
        assert fmt is not None, "posit-coded params need policy.weights"
        return posit_matmul_wx(
            x.astype(cd), p["w_codes"], fmt, es=es, compute_dtype=cd,
            bias=p.get("b"), activation=activation, residual=residual,
            codec_impl=policy.codec_impl, epilogue=policy.epilogue,
            out_dtype=x.dtype)
    w = effective_weight(p, policy, es).astype(cd)
    y = jnp.matmul(x.astype(cd), w, preferred_element_type=jnp.float32)
    if "b" in p or activation != "none" or residual is not None:
        y = apply_epilogue(y, p.get("b"), activation, residual,
                           chained=policy.epilogue == "chained")
    return y.astype(x.dtype)


# ------------------------------------------------------------------- norms ----

def init_rmsnorm(d: int) -> dict:
    return {"g": jnp.ones((d,), jnp.float32)}


def apply_rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * p["g"]).astype(x.dtype)


def init_layernorm(d: int) -> dict:
    return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def apply_layernorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * p["g"] + p["b"]).astype(x.dtype)


# ----------------------------------------------------------------- rotary -----

def rope_freqs(head_dim: int, base: float = 10000.0) -> jax.Array:
    return 1.0 / (base ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, base: float = 10000.0) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, base)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-jnp.log(10000.0) / d))
    pe = jnp.zeros((n, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# -------------------------------------------------------------------- MLPs ----

def init_swiglu(key, d: int, f: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_linear(k1, d, f),
        "up": init_linear(k2, d, f),
        "down": init_linear(k3, f, d, scale=f ** -0.5),
    }


def apply_swiglu(p: dict, x: jax.Array, policy: TransPolicy, *,
                 residual: Optional[jax.Array] = None) -> jax.Array:
    """silu fuses into the gate GEMM's epilogue; an optional block residual
    fuses into the down-projection (3 fused ops per MLP instead of 6+)."""
    g = apply_linear(p["gate"], x, policy, activation="silu")
    u = apply_linear(p["up"], x, policy)
    h = g * u
    return apply_linear(p["down"], h, policy, residual=residual)


def init_gelu_mlp(key, d: int, f: int, *, bias: bool = True) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "up": init_linear(k1, d, f, bias=bias),
        "down": init_linear(k2, f, d, bias=bias, scale=f ** -0.5),
    }


def apply_gelu_mlp(p: dict, x: jax.Array, policy: TransPolicy, *,
                   residual: Optional[jax.Array] = None) -> jax.Array:
    """gelu fuses into the up-projection epilogue; optional block residual
    fuses into the down-projection."""
    h = apply_linear(p["up"], x, policy, activation="gelu")
    return apply_linear(p["down"], h, policy, residual=residual)


# -------------------------------------------------------------- embeddings ----

def init_embedding(key, vocab: int, d: int) -> dict:
    return {"table": jax.random.normal(key, (vocab, d), jnp.float32) * (d ** -0.5)}


def apply_embedding(p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


def embedding_logits(p: dict, h: jax.Array) -> jax.Array:
    """Tied read-out: h @ table.T."""
    return jnp.matmul(
        h.astype(jnp.float32), p["table"].T, preferred_element_type=jnp.float32)
