"""Parameter init + core layer ops (linear, norm, rotary, MLP).

Parameter convention: params are nested dicts of jnp arrays. Posit-stored
weights appear as ``{"w_codes": uintN, ...}`` after ``quantize_params``; float
weights as ``{"w": floatN}``. The TransPolicy (static) says how to interpret
them — mirroring how the paper's pcsr, not the register file, carries format.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.calib import observe
from repro.core.codec import posit_encode
from repro.core.dot import apply_epilogue, posit_dot, posit_matmul_wx
from repro.core.lut import decode_with_impl, encode_with_impl
from repro.core.pack import pack_p8, packed_decode_p8
from repro.core.pcsr import OperandSlots, TransPolicy
from repro.core.types import F32, PositFmt


def _compute_dtype(policy: TransPolicy):
    return jnp.float32 if policy.compute_dtype == "f32" else jnp.bfloat16


def resolve_policy(policy, path: str = "") -> TransPolicy:
    """Per-layer policy resolution (DESIGN.md §9).

    A ``PrecisionPolicy`` (core/policy.py) resolves through its rule list for
    the given layer path; a plain ``TransPolicy`` passes through unchanged.
    Every linear call site hands its path here, so one object can schedule
    p16 attention x packed-p8 MLP across a whole model.
    """
    resolve = getattr(policy, "policy_for", None)
    return resolve(path) if resolve is not None else policy


# ------------------------------------------------------------------ linear ----

def init_linear(key, d_in: int, d_out: int, *, bias: bool = False,
                scale: Optional[float] = None, dtype=jnp.float32) -> dict:
    if scale is None:
        scale = d_in ** -0.5
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def quantize_linear(p: dict, fmt: PositFmt, *, packed: bool = False) -> dict:
    """Convert a float linear param dict to posit storage (serving path).

    ``packed=True`` stores p8 codes two-per-uint16-lane (core/pack.py):
    half the weight words at rest and on the wire, identical numerics.
    """
    codes = posit_encode(p["w"].astype(jnp.float32), fmt.nbits, fmt.es)
    if packed:
        if fmt.nbits != 8:
            raise ValueError(f"packed weight storage requires p8, got {fmt}")
        q = {"w_packed": pack_p8(codes)}
    else:
        q = {"w_codes": codes}
    if "b" in p:
        q["b"] = p["b"]  # biases stay float: O(d) storage, numerically sensitive
    return q


def effective_weight(p: dict, policy: TransPolicy, es=None, path: str = "") -> jax.Array:
    """The weight as seen by the matmul datapath.

    * posit codes       -> decode (exact; bf16 target for p8); packed lanes
                           decode both bytes (bit-identical to unpacked)
    * float + posit pol -> straight-through quantize (training: master weights
                           stay f32, forward sees posit-rounded values)
    * float, no policy  -> as-is (IEEE bypass)
    """
    policy = resolve_policy(policy, path)
    if "w_packed" in p:
        fmt = policy.weights
        assert fmt is not None and fmt.nbits == 8, \
            "packed params need a p8 policy.weights"
        return packed_decode_p8(p["w_packed"], fmt.es if es is None else es,
                                codec_impl=policy.codec_impl)
    if "w_codes" in p:
        fmt = policy.weights
        assert fmt is not None, "posit-coded params need policy.weights"
        return decode_with_impl(p["w_codes"], fmt.nbits,
                                fmt.es if es is None else es, policy.codec_impl)
    w = p["w"]
    if observe.is_active():
        # calibration-mode forward (DESIGN.md §11): stream this site's float
        # weight statistics; the same path string keys the emitted rules
        observe.record(path, "weight", w)
    fmt = policy.weights
    if fmt is not None:
        wf = w.astype(jnp.float32)
        e = fmt.es if es is None else es
        qw = decode_with_impl(
            posit_encode(wf, fmt.nbits, e), fmt.nbits, e, policy.codec_impl)
        w = w + jax.lax.stop_gradient(qw - wf).astype(w.dtype)
    return w


def apply_linear(p: dict, x: jax.Array, policy: TransPolicy, es=None, *,
                 activation: str = "none",
                 residual: Optional[jax.Array] = None,
                 path: str = "") -> jax.Array:
    """y = act(x @ W + b) + residual, epilogue fused with the GEMM.

    Posit-coded weights route through ``posit_matmul_wx`` so the decode, the
    matmul and the whole epilogue stay one fused op (one kernel launch / HBM
    write on the serving path); packed-p8 storage ("w_packed") moves half the
    weight words and decodes both lanes in the same fused op.
    ``policy.epilogue == "chained"`` materializes every stage instead (the
    benchmark baseline).  ``path`` is this layer's name for per-layer
    ``PrecisionPolicy`` resolution (DESIGN.md §9).
    """
    policy = resolve_policy(policy, path)
    if observe.is_active():
        observe.record(path, "act", x)
        # training-plane channel (DESIGN.md §16): the cotangent dL/dx
        # arriving at this site streams to the "grad" histogram under
        # value_and_grad — a no-op unless the observer asked for gradients
        x = observe.grad_tap(path, x)
    from repro.obs import prof
    if not prof.is_active():
        return _linear_resolved(p, x, policy, es, activation=activation,
                                residual=residual, path=path)
    # per-layer roofline attribution (DESIGN.md §16): the XLA-fused linear
    # is the same GEMM contract the pallas kernel implements, so it records
    # under the "gemm" family with this site's path; quire-dataflow linears
    # additionally hit the codec/quire entry-point hooks downstream
    packed = "w_packed" in p
    coded = packed or "w_codes" in p
    fmt = policy.weights
    w_bytes = float(fmt.storage_bytes) if coded and fmt is not None else 4.0
    wkey = "w_packed" if packed else ("w_codes" if "w_codes" in p else "w")
    impl = ("quire" if coded and policy.dataflow == "quire"
            else "xla" if not coded else "fused")
    return prof.dispatch(
        "gemm", impl,
        prof.linear_cost(x, float(p[wkey].shape[-1]), w_bytes=w_bytes,
                         bias="b" in p, residual=residual is not None),
        lambda: _linear_resolved(p, x, policy, es, activation=activation,
                                 residual=residual, path=path),
        primary=x, path=path)


def _linear_resolved(p: dict, x: jax.Array, policy: TransPolicy, es, *,
                     activation: str, residual: Optional[jax.Array],
                     path: str) -> jax.Array:
    """apply_linear past policy resolution + observability hooks."""
    cd = _compute_dtype(policy)
    packed = "w_packed" in p
    if packed or "w_codes" in p:
        fmt = policy.weights
        assert fmt is not None, "posit-coded params need policy.weights"
        if policy.dataflow == "quire":
            return _quire_linear(p, x, policy, fmt, es, activation=activation,
                                 residual=residual, packed=packed)
        return posit_matmul_wx(
            x.astype(cd), p["w_packed"] if packed else p["w_codes"], fmt,
            es=es, compute_dtype=cd,
            bias=p.get("b"), activation=activation, residual=residual,
            codec_impl=policy.codec_impl, epilogue=policy.epilogue,
            out_dtype=x.dtype, packed=packed)
    w = effective_weight(p, policy, es, path=path).astype(cd)
    y = jnp.matmul(x.astype(cd), w, preferred_element_type=jnp.float32)
    if "b" in p or activation != "none" or residual is not None:
        y = apply_epilogue(y, p.get("b"), activation, residual,
                           chained=policy.epilogue == "chained")
    return y.astype(x.dtype)


def _quire_linear(p: dict, x: jax.Array, policy: TransPolicy, fmt: PositFmt,
                  es, *, activation: str, residual: Optional[jax.Array],
                  packed: bool) -> jax.Array:
    """dataflow="quire" lowering of a posit-coded linear (DESIGN.md §7/§9).

    Activations encode once into ``policy.activations`` (the weight format
    when unset), every product lands exactly in a Kulisch quire, and the
    single terminal rounding reads out straight into f32 for the epilogue —
    no float dot_general anywhere, which is the contract the jaxpr auditor
    (repro.analysis) asserts mechanically at quire-declared sites.
    """
    afmt = policy.activations if policy.activations is not None else fmt
    slots = OperandSlots(rs1=afmt, rs2=fmt, rd=F32, dataflow="quire",
                         codec_impl=policy.codec_impl, rs2_packed=packed)
    K = x.shape[-1]
    N = (p["w_packed"] if packed else p["w_codes"]).shape[-1]
    x2 = x.reshape(-1, K)
    res2 = None
    if residual is not None:
        res2 = jnp.broadcast_to(residual, x.shape[:-1] + (N,)).reshape(-1, N)
    a_codes = encode_with_impl(x2.astype(jnp.float32), afmt.nbits, afmt.es,
                               policy.codec_impl)
    y = posit_dot(a_codes, p["w_packed"] if packed else p["w_codes"], slots,
                  es_b=es, bias=p.get("b"), activation=activation,
                  residual=res2, epilogue=policy.epilogue)
    return y.reshape(x.shape[:-1] + (N,)).astype(x.dtype)


# linear-shaped param-dict keys quantize_params recognizes: the {"w": ...}
# convention plus MoE's stacked expert tensors (effective_weight handles
# "<name>_codes" for those; packing applies to plain linears only).
_MOE_WEIGHT_KEYS = ("w_gate", "w_up", "w_down")

# Param paths quantize_params must leave alone even though they look like
# linears: SSM causal-conv kernels are {"w", "b"} dicts consumed raw by
# _causal_conv (O(width*C) storage — not worth posit-coding anyway).
_RAW_WEIGHT_PATTERNS = ("*conv*",)


def _walk_linears(tree, path=""):
    """Yield (path, parent, key_kind) for every linear-shaped param dict."""
    if isinstance(tree, dict):
        if "w" in tree and getattr(tree["w"], "ndim", 0) >= 2:
            yield path, tree, "w"
        for k in _MOE_WEIGHT_KEYS:
            if k in tree and getattr(tree[k], "ndim", 0) >= 2:
                yield (f"{path}/{k}" if path else k), tree, k
        for k, v in tree.items():
            if k in ("w",) + _MOE_WEIGHT_KEYS:
                continue
            yield from _walk_linears(v, f"{path}/{k}" if path else k)
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _walk_linears(v, f"{path}/{i}" if path else str(i))


def quantize_params(params, policy):
    """Quantize every linear weight to its per-layer policy format.

    Walks the param tree; each linear dict {"w": ...} at path P becomes posit
    storage per ``resolve_policy(policy, P)`` — packed-p8 lanes when the
    resolved policy says ``pack_weights`` (and the contraction dim is even;
    odd dims keep unpacked codes), plain codes otherwise, untouched when the
    resolved weights format is None.  MoE expert stacks ("w_gate"/"w_up"/
    "w_down") quantize to "<name>_codes" (unpacked — the expert einsum path
    reads whole tensors).  Returns a new tree; float master params are not
    modified.
    """
    import fnmatch

    out = _copy_dicts(params)
    for path, parent, key in _walk_linears(out, ""):
        if any(fnmatch.fnmatchcase(path, pat) for pat in _RAW_WEIGHT_PATTERNS):
            continue
        pol = resolve_policy(policy, path)
        fmt = pol.weights
        if fmt is None:
            continue
        if key == "w":
            packed = (pol.pack_weights and fmt.nbits == 8
                      and parent["w"].shape[-2] % 2 == 0)
            q = quantize_linear(parent, fmt, packed=packed)
            parent.pop("w")
            parent.update(q)
        else:  # stacked MoE expert weights
            parent[key + "_codes"] = posit_encode(
                parent.pop(key).astype(jnp.float32), fmt.nbits, fmt.es)
    return out


def _copy_dicts(tree):
    """Deep-copy the dict/list spine of a param tree (leaves shared)."""
    if isinstance(tree, dict):
        return {k: _copy_dicts(v) for k, v in tree.items()}
    if isinstance(tree, list):
        return [_copy_dicts(v) for v in tree]
    if isinstance(tree, tuple):
        return tuple(_copy_dicts(v) for v in tree)
    return tree


def policy_weight_bytes(params, policy) -> dict:
    """Storage model: linear-weight bytes at rest under ``policy`` vs f32.

    The Table-IV memory-savings number at model scale — packed p8 counts one
    byte per value (two codes per uint16 lane)."""
    import fnmatch

    f32_b = policy_b = 0
    for path, parent, key in _walk_linears(params, ""):
        w = parent[key]
        n = int(w.size)
        f32_b += 4 * n
        pol = resolve_policy(policy, path)
        fmt = pol.weights
        raw = any(fnmatch.fnmatchcase(path, pat) for pat in _RAW_WEIGHT_PATTERNS)
        policy_b += n * (fmt.storage_bytes if fmt is not None and not raw else 4)
    return {"weight_bytes_f32": f32_b, "weight_bytes_policy": policy_b}


# ------------------------------------------------------------------- norms ----

def init_rmsnorm(d: int) -> dict:
    return {"g": jnp.ones((d,), jnp.float32)}


def apply_rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * p["g"]).astype(x.dtype)


def init_layernorm(d: int) -> dict:
    return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def apply_layernorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * p["g"] + p["b"]).astype(x.dtype)


# ----------------------------------------------------------------- rotary -----

def rope_freqs(head_dim: int, base: float = 10000.0) -> jax.Array:
    return 1.0 / (base ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, base: float = 10000.0) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, base)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-jnp.log(10000.0) / d))
    pe = jnp.zeros((n, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# -------------------------------------------------------------------- MLPs ----

def init_swiglu(key, d: int, f: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_linear(k1, d, f),
        "up": init_linear(k2, d, f),
        "down": init_linear(k3, f, d, scale=f ** -0.5),
    }


def apply_swiglu(p: dict, x: jax.Array, policy: TransPolicy, *,
                 residual: Optional[jax.Array] = None,
                 path: str = "mlp") -> jax.Array:
    """silu fuses into the gate GEMM's epilogue; an optional block residual
    fuses into the down-projection (3 fused ops per MLP instead of 6+)."""
    g = apply_linear(p["gate"], x, policy, activation="silu",
                     path=f"{path}/gate")
    u = apply_linear(p["up"], x, policy, path=f"{path}/up")
    h = g * u
    return apply_linear(p["down"], h, policy, residual=residual,
                        path=f"{path}/down")


def init_gelu_mlp(key, d: int, f: int, *, bias: bool = True) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "up": init_linear(k1, d, f, bias=bias),
        "down": init_linear(k2, f, d, bias=bias, scale=f ** -0.5),
    }


def apply_gelu_mlp(p: dict, x: jax.Array, policy: TransPolicy, *,
                   residual: Optional[jax.Array] = None,
                   path: str = "mlp") -> jax.Array:
    """gelu fuses into the up-projection epilogue; optional block residual
    fuses into the down-projection."""
    h = apply_linear(p["up"], x, policy, activation="gelu",
                     path=f"{path}/up")
    return apply_linear(p["down"], h, policy, residual=residual,
                        path=f"{path}/down")


# -------------------------------------------------------------- embeddings ----

def init_embedding(key, vocab: int, d: int) -> dict:
    return {"table": jax.random.normal(key, (vocab, d), jnp.float32) * (d ** -0.5)}


def apply_embedding(p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


def embedding_logits(p: dict, h: jax.Array) -> jax.Array:
    """Tied read-out: h @ table.T."""
    return jnp.matmul(
        h.astype(jnp.float32), p["table"].T, preferred_element_type=jnp.float32)
