"""Attention: GQA/MQA/MHA, causal / bidirectional / sliding-window / cross,
training (full-sequence) and serving (KV-cache prefill + decode) paths.

KV-cache transprecision (the paper's memory-savings claim at the serving
bottleneck): when ``policy.kv_cache`` is a posit format, the cache is stored as
uint8/16 codes; new K/V are encoded on write and tiles are decoded at the
attention boundary (Pallas kernel on TPU, identical-contract XLA path on CPU).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.codec import posit_decode, posit_encode
from repro.core.pcsr import TransPolicy
from repro.kernels.posit_attention import ops as attn_ops
from repro.models.layers import apply_linear, apply_rope, init_linear
from repro.obs import prof
from repro.models.unroll import scan_or_unroll, unrolled

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnCfg:
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    qkv_bias: bool = False
    rope_base: float = 10000.0
    use_rope: bool = True
    causal: bool = True
    window: int = 0          # >0: sliding-window (local) attention
    is_cross: bool = False   # cross-attention (kv from encoder; no rope/causal)


def init_attention(key, cfg: AttnCfg) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    return {
        "wq": init_linear(kq, d, H * hd, bias=cfg.qkv_bias),
        "wk": init_linear(kk, d, Hkv * hd, bias=cfg.qkv_bias),
        "wv": init_linear(kv, d, Hkv * hd, bias=cfg.qkv_bias),
        "wo": init_linear(ko, H * hd, d, scale=(H * hd) ** -0.5),
    }


def _split_heads(x: jax.Array, n: int, hd: int) -> jax.Array:
    return x.reshape(*x.shape[:-1], n, hd)


Q_CHUNK = 512  # query-block size for memory-efficient attention


def _sdpa_block(qg, k, v, scale, *, offset, causal, window):
    """One query block. qg: (B,Lq,Hkv,g,hd); k/v: (B,T,Hkv,hd).

    offset: absolute position of the block's first query. window may be a
    traced scalar (0 = unbounded). Returns (B, Lq, Hkv, g, hd).
    """
    B, Lq = qg.shape[:2]
    T = k.shape[1]
    scores = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    if causal or window is not None:
        qp = jnp.arange(Lq)[:, None] + offset
        kp = jnp.arange(T)[None, :]
        m = jnp.ones((Lq, T), bool)
        if causal:
            m &= kp <= qp
        if window is not None:
            weff = jnp.where(window > 0, window, T + 1)
            m &= kp > qp - weff
        scores = jnp.where(m[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))


def _sdpa(q, k, v, scale, *, causal=True, window=None, q_chunk=Q_CHUNK):
    """Memory-efficient SDPA: scan over query blocks so only a
    (B, H, q_chunk, T) score slab is ever live (the XLA-path stand-in for the
    Pallas flash kernel on TPU). q: (B,S,H,hd), k/v: (B,T,Hkv,hd)."""
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, S, Hkv, g, hd)
    if unrolled():
        # cost probes: one full-S block so every attention FLOP is HLO-visible
        out = _sdpa_block(qg, k, v, scale, offset=0, causal=causal,
                          window=window)
        return out.reshape(B, S, H, hd)
    if S <= q_chunk:
        out = _sdpa_block(qg, k, v, scale, offset=0, causal=causal,
                          window=window)
        return out.reshape(B, S, H, hd)
    nc = -(-S // q_chunk)
    Sp = nc * q_chunk
    if Sp != S:
        qg = jnp.pad(qg, ((0, 0), (0, Sp - S), (0, 0), (0, 0), (0, 0)))
    qb = qg.reshape(B, nc, q_chunk, Hkv, g, hd).transpose(1, 0, 2, 3, 4, 5)

    def body(_, idx_qb):
        i, qblk = idx_qb
        out = _sdpa_block(qblk, k, v, scale, offset=i * q_chunk,
                          causal=causal, window=window)
        return None, out

    # remat: without it lax.scan saves every chunk's (B,H,Lq,T) score slab for
    # the backward pass — exactly the S^2 buffer the chunking is here to avoid
    body = jax.checkpoint(body)
    _, outs = scan_or_unroll(body, None, (jnp.arange(nc), qb))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sp, H, hd)
    return out[:, :S]


def make_mask(S: int, T: int, *, causal: bool, window: int,
              offset: int = 0) -> Optional[jax.Array]:
    """(S, T) bool; query position i corresponds to absolute position i+offset."""
    if not causal and window <= 0:
        return None
    qp = jnp.arange(S)[:, None] + offset
    kp = jnp.arange(T)[None, :]
    m = jnp.ones((S, T), bool)
    if causal:
        m &= kp <= qp
    if window > 0:
        m &= kp > qp - window
    return m


def apply_attention(params: dict, cfg: AttnCfg, x: jax.Array,
                    policy: TransPolicy, *,
                    xattn_kv: Optional[jax.Array] = None,
                    positions: Optional[jax.Array] = None,
                    path: str = "attn") -> jax.Array:
    """Training / prefill full-sequence attention. x: (B, S, D).

    ``path`` names this attention instance for per-layer policy
    resolution ("attn" | "self" | "cross" — must match the param-tree
    key so quantize-time and apply-time formats agree, DESIGN.md §9).
    """
    B, S, _ = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q = _split_heads(apply_linear(params["wq"], x, policy, path=f"{path}/wq"), H, hd)
    kv_src = xattn_kv if cfg.is_cross else x
    k = _split_heads(apply_linear(params["wk"], kv_src, policy, path=f"{path}/wk"), Hkv, hd)
    v = _split_heads(apply_linear(params["wv"], kv_src, policy, path=f"{path}/wv"), Hkv, hd)
    if cfg.use_rope and not cfg.is_cross:
        if positions is None:
            positions = jnp.arange(S)[None]
        q = apply_rope(q, positions, cfg.rope_base)
        k = apply_rope(k, positions, cfg.rope_base)
    out = _sdpa(q, k, v, hd ** -0.5,
                causal=cfg.causal and not cfg.is_cross,
                window=cfg.window if (cfg.window and not cfg.is_cross) else None)
    return apply_linear(params["wo"], out.reshape(B, S, H * hd), policy,
                        path=f"{path}/wo")


def apply_attention_dynwin(params: dict, cfg: AttnCfg, x: jax.Array,
                           policy: TransPolicy, *, window, rope_base,
                           positions: Optional[jax.Array] = None,
                           path: str = "attn") -> jax.Array:
    """apply_attention with window / rope_base as *traced* per-layer scalars.

    Lets heterogeneous layer patterns (gemma3 5-local:1-global) run under one
    lax.scan body: window==0 means unbounded (full causal).
    """
    B, S, _ = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q = _split_heads(apply_linear(params["wq"], x, policy, path=f"{path}/wq"), H, hd)
    k = _split_heads(apply_linear(params["wk"], x, policy, path=f"{path}/wk"), Hkv, hd)
    v = _split_heads(apply_linear(params["wv"], x, policy, path=f"{path}/wv"), Hkv, hd)
    if cfg.use_rope:
        if positions is None:
            positions = jnp.arange(S)[None]
        q = apply_rope(q, positions, rope_base)
        k = apply_rope(k, positions, rope_base)
    out = _sdpa(q, k, v, hd ** -0.5, causal=True, window=window)
    return apply_linear(params["wo"], out.reshape(B, S, H * hd), policy,
                        path=f"{path}/wo")


# ------------------------------------------------------------- KV cache -------

def init_kv_cache(B: int, S_max: int, cfg: AttnCfg, policy: TransPolicy) -> dict:
    """Cache layout (B, Hkv, S_max, hd); posit codes if policy.kv_cache set."""
    fmt = policy.kv_cache
    if fmt is not None:
        dt = jnp.uint8 if fmt.nbits == 8 else jnp.uint16
    else:
        dt = jnp.float32 if policy.compute_dtype == "f32" else jnp.bfloat16
    shape = (B, cfg.n_kv, S_max, cfg.head_dim)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
            "len": jnp.zeros((B,), jnp.int32)}


def _store(cache_arr, new, pos, policy):
    """Write (B, Hkv, s, hd) ``new`` at sequence offset ``pos``.

    ``pos`` is either a scalar (lockstep batch / prefill block write) or a
    (B,) vector of per-row write indices with s == 1 (ragged decode: every
    row of a continuous batch sits at its own sequence position).  Per-row
    writes use a scatter; out-of-bounds rows (recycled engine slots past
    S_max) are dropped by JAX scatter semantics.
    """
    fmt = policy.kv_cache
    if fmt is not None:
        new = posit_encode(new.astype(jnp.float32), fmt.nbits, fmt.es)
    else:
        new = new.astype(cache_arr.dtype)
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 1:
        B = cache_arr.shape[0]
        return cache_arr.at[jnp.arange(B), :, pos].set(new[:, :, 0],
                                                       mode="drop")
    return jax.lax.dynamic_update_slice(
        cache_arr, new, (0, 0, pos, 0))


def _load(cache_arr, policy):
    fmt = policy.kv_cache
    if fmt is not None:
        return posit_decode(cache_arr, fmt.nbits, fmt.es)
    return cache_arr.astype(jnp.float32)


def prefill_attention(params: dict, cfg: AttnCfg, x: jax.Array, cache: dict,
                      policy: TransPolicy,
                      xattn_kv: Optional[jax.Array] = None,
                      path: str = "attn") -> tuple:
    """Full-sequence attention that also fills the KV cache. x: (B, S, D)."""
    B, S, _ = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q = _split_heads(apply_linear(params["wq"], x, policy, path=f"{path}/wq"), H, hd)
    kv_src = xattn_kv if cfg.is_cross else x
    k = _split_heads(apply_linear(params["wk"], kv_src, policy, path=f"{path}/wk"), Hkv, hd)
    v = _split_heads(apply_linear(params["wv"], kv_src, policy, path=f"{path}/wv"), Hkv, hd)
    if cfg.use_rope and not cfg.is_cross:
        pos = jnp.arange(S)[None]
        q = apply_rope(q, pos, cfg.rope_base)
        k = apply_rope(k, pos, cfg.rope_base)
    T = k.shape[1]
    out = _sdpa(q, k, v, hd ** -0.5,
                causal=cfg.causal and not cfg.is_cross,
                window=cfg.window if (cfg.window and not cfg.is_cross) else None)
    y = apply_linear(params["wo"], out.reshape(B, S, H * hd), policy,
                        path=f"{path}/wo")
    cache = dict(cache)
    kt, vt = k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)  # (B,Hkv,T,hd)
    Sc = cache["k"].shape[2]
    if T > Sc:
        # rolling window buffer (gemma3 local layers): keep the last Sc
        # positions, placed at their (pos % Sc) slots so decode can continue
        kt = jnp.roll(kt[:, :, T - Sc:], shift=T % Sc, axis=2)
        vt = jnp.roll(vt[:, :, T - Sc:], shift=T % Sc, axis=2)
    cache["k"] = _store(cache["k"], kt, 0, policy)
    cache["v"] = _store(cache["v"], vt, 0, policy)
    cache["len"] = jnp.full_like(cache["len"], min(T, Sc))
    return y, cache


def init_paged_kv_pool(n_blocks: int, block_tokens: int, cfg: AttnCfg,
                       policy: TransPolicy) -> dict:
    """One layer's paged KV pool: ``(n_blocks, Hkv, block_tokens, hd)`` codes.

    Same dtype rule as :func:`init_kv_cache`; the per-slot ``len`` lives with
    the engine (``cache["lens"]``), and the block table is shared across
    layers — every layer of a slot uses the same block ids (DESIGN.md §14).
    """
    fmt = policy.kv_cache
    if fmt is not None:
        dt = jnp.uint8 if fmt.nbits == 8 else jnp.uint16
    else:
        dt = jnp.float32 if policy.compute_dtype == "f32" else jnp.bfloat16
    shape = (n_blocks, cfg.n_kv, block_tokens, cfg.head_dim)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def _store_paged(pool_arr, new, bids, offs, policy):
    """Scatter (B, Hkv, 1, hd) ``new`` into ``pool[bids[b], :, offs[b]]``.

    Sentinel block ids (>= n_blocks) drop the write — inactive slots point
    every table entry out of bounds, so the lockstep grid step is a no-op
    for them (mirrors the recycled-slot ``mode="drop"`` in :func:`_store`).
    """
    fmt = policy.kv_cache
    if fmt is not None:
        new = posit_encode(new.astype(jnp.float32), fmt.nbits, fmt.es)
    else:
        new = new.astype(pool_arr.dtype)
    bids = jnp.asarray(bids, jnp.int32)
    offs = jnp.asarray(offs, jnp.int32)
    # advanced indices (bids, offs) straddle the ':' so the joint batch axis
    # moves to front: target (B, Hkv, hd) matches new[:, :, 0]
    return pool_arr.at[bids, :, offs].set(new[:, :, 0], mode="drop")


def decode_attention_step_paged(params: dict, cfg: AttnCfg, x_t: jax.Array,
                                pool: dict, block_table: jax.Array,
                                lens, policy: TransPolicy,
                                path: str = "attn") -> tuple:
    """One decode step over a paged KV pool (DESIGN.md §14).

    ``pool`` holds one layer's ``{"k", "v"}`` block arrays
    ``(N, Hkv, bt, hd)``; ``block_table`` is the slot grid's ``(B, W)``
    indirection and ``lens`` the per-row write index (= valid length before
    this token).  The engine guarantees the write target
    ``block_table[b, lens[b] // bt]`` is a *private* block (copy-on-write
    runs before the step), so no two rows ever scatter into the same page.
    Attention reads route through the indirection-aware tiled kernel.
    """
    B, _, _ = x_t.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    bt = pool["k"].shape[2]
    q = _split_heads(apply_linear(params["wq"], x_t, policy,
                                  path=f"{path}/wq"), H, hd)
    kn = _split_heads(apply_linear(params["wk"], x_t, policy,
                                   path=f"{path}/wk"), Hkv, hd)
    vn = _split_heads(apply_linear(params["wv"], x_t, policy,
                                   path=f"{path}/wv"), Hkv, hd)
    lens = jnp.asarray(lens, jnp.int32)
    if cfg.use_rope:
        p1 = jnp.broadcast_to(lens[:, None], (B, 1))
        q = apply_rope(q, p1, cfg.rope_base)
        kn = apply_rope(kn, p1, cfg.rope_base)
    bids = jnp.take_along_axis(jnp.asarray(block_table, jnp.int32),
                               (lens // bt)[:, None], axis=1)[:, 0]
    offs = lens % bt
    new_pool = dict(pool)
    new_pool["k"] = _store_paged(pool["k"], kn.transpose(0, 2, 1, 3),
                                 bids, offs, policy)
    new_pool["v"] = _store_paged(pool["v"], vn.transpose(0, 2, 1, 3),
                                 bids, offs, policy)
    fmt = policy.kv_cache
    with prof.site(path):
        out = attn_ops.posit_decode_attention_paged(
            q.reshape(B, H, hd), new_pool["k"], new_pool["v"], block_table,
            lens + 1, fmt.es if fmt is not None else 0,
            kv_bits=fmt.nbits if fmt is not None else 0)
    y = apply_linear(params["wo"], out.reshape(B, 1, H * hd).astype(x_t.dtype),
                     policy, path=f"{path}/wo")
    return y, new_pool


def resolve_attn_impl(policy: TransPolicy, cfg: AttnCfg, *,
                      rolling: bool = False) -> str:
    """Resolve ``policy.attn_impl`` for one decode-step attention layer.

    "kernel" routes the step through ``kernels.posit_attention.ops`` (Pallas
    flash decode on TPU, length-bounded tiled XLA path elsewhere — the cache
    is decoded tile-wise, never materialized in full).  The kernel contract
    covers per-row ``len`` masking, rolling (circular-buffer) windows, and
    read-only cross caches; a non-rolling sliding window (a windowed layer
    whose cache is larger than the window) needs the windowed mask only the
    xla path implements.
    """
    impl = getattr(policy, "attn_impl", "auto")
    if impl == "xla":
        return "xla"
    if cfg.window > 0 and not rolling and not cfg.is_cross:
        if impl == "kernel":
            # refuse rather than silently measure xla-vs-xla: the kernel
            # has no windowed mask for a cache larger than the window
            raise ValueError(
                "attn_impl='kernel' cannot serve a non-rolling "
                f"sliding-window layer (window={cfg.window}); use a "
                "window-sized rolling cache or attn_impl='auto'/'xla'")
        return "xla"
    return "kernel"


def decode_attention_step(params: dict, cfg: AttnCfg, x_t: jax.Array,
                          cache: dict, pos, policy: TransPolicy,
                          *, rolling: bool = False,
                          abs_pos=None, path: str = "attn") -> tuple:
    """One decode step. x_t: (B, 1, D); pos: the *cache write index* — an
    int32 scalar (lockstep batch) or a (B,) vector (ragged continuous batch,
    every row at its own position).

    rolling=True: the cache is a circular window buffer (gemma3 local layers):
    every slot written so far is valid and the window bound is implicit in the
    buffer size. ``abs_pos`` is the absolute sequence position for RoPE when it
    differs from the write index (defaults to pos; scalar or (B,)).

    Masking is uniformly ``cache["len"]``-driven per batch row (cross reads
    the prefilled length; self counts the token written this step), so ragged
    batches attend correctly on every path.
    """
    B, _, _ = x_t.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q = _split_heads(apply_linear(params["wq"], x_t, policy,
                                  path=f"{path}/wq"), H, hd)   # (B,1,H,hd)
    if cfg.is_cross:
        # cross-attention reads the (already prefilled) encoder cache only
        new_cache = cache
        lens = cache["len"]
    else:
        kn = _split_heads(apply_linear(params["wk"], x_t, policy, path=f"{path}/wk"), Hkv, hd)
        vn = _split_heads(apply_linear(params["wv"], x_t, policy, path=f"{path}/wv"), Hkv, hd)
        if cfg.use_rope:
            ap = jnp.asarray(pos if abs_pos is None else abs_pos, jnp.int32)
            p1 = jnp.broadcast_to(jnp.atleast_1d(ap)[:, None], (B, 1))
            q = apply_rope(q, p1, cfg.rope_base)
            kn = apply_rope(kn, p1, cfg.rope_base)
        new_cache = dict(cache)
        new_cache["k"] = _store(cache["k"], kn.transpose(0, 2, 1, 3), pos, policy)
        new_cache["v"] = _store(cache["v"], vn.transpose(0, 2, 1, 3), pos, policy)
        # clamp at the buffer size: a slot never holds more than S_cache valid
        # positions (rolling buffers wrap; recycled engine slots would
        # otherwise grow `len` without bound between eviction and reuse)
        new_cache["len"] = jnp.minimum(cache["len"] + 1, cache["k"].shape[2])
        lens = new_cache["len"]

    impl = resolve_attn_impl(policy, cfg, rolling=rolling)
    if impl == "kernel":
        fmt = policy.kv_cache
        with prof.site(path):
            out = attn_ops.decode_attention(
                q.reshape(B, H, hd),
                new_cache["k"], new_cache["v"], lens,
                fmt.es if fmt is not None else 0,
                kv_bits=fmt.nbits if fmt is not None else 0,
                rolling=rolling)
        out = out.reshape(B, 1, H * hd)
    else:
        k = _load(new_cache["k"], policy)   # (B,Hkv,T,hd)
        v = _load(new_cache["v"], policy)
        S_cache = k.shape[2]
        qf = q.reshape(B, Hkv, H // Hkv, hd).astype(jnp.float32) * (hd ** -0.5)
        scores = jnp.einsum("bkgd,bktd->bkgt", qf, k)
        t = jnp.arange(S_cache)[None, None, None, :]
        lb = jnp.broadcast_to(jnp.asarray(lens, jnp.int32), (B,))
        if rolling:
            # circular buffer: every slot written so far is valid
            lb = jnp.minimum(lb, S_cache)
        valid = t < lb[:, None, None, None]
        if cfg.window > 0 and not rolling and not cfg.is_cross:
            pr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
            valid &= t > (pr - cfg.window)[:, None, None, None]
        scores = jnp.where(valid, scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgt,bktd->bkgd", p, v).reshape(B, 1, H * hd)
    y = apply_linear(params["wo"], out.astype(x_t.dtype), policy,
                     path=f"{path}/wo")
    return y, new_cache
