"""Activation-sharding hook: the launcher injects sequence-parallel / TP
constraints without the model code depending on a mesh.

Model code calls ``maybe_shard(x, kind)``; by default a no-op (single-device
training, smoke tests). The dry-run/production launcher installs a hook that
applies ``jax.lax.with_sharding_constraint`` with the run's mesh axes:

  kind="residual"  — the inter-block stream (B, S, D): batch over dp axes and
                     S over "model" (Megatron-style sequence parallelism: the
                     remat-saved layer checkpoints shrink by the TP degree)
  kind="logits"    — (B, S, V): vocab over "model"
"""
from __future__ import annotations

from typing import Callable, Optional

_HOOK: Optional[Callable] = None


def set_activation_sharding(hook: Optional[Callable]) -> None:
    global _HOOK
    _HOOK = hook


def maybe_shard(x, kind: str):
    return _HOOK(x, kind) if _HOOK is not None else x


class activation_sharding:
    """Context manager used by launchers around trace/lower time."""

    def __init__(self, hook):
        self.hook = hook

    def __enter__(self):
        set_activation_sharding(self.hook)
        return self

    def __exit__(self, *exc):
        set_activation_sharding(None)
        return False
