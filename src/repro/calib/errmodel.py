"""Analytic posit round-trip error model over log2-magnitude histograms.

Posit tapered accuracy in one formula: a value with binary scale
``s = floor(log2|x|)`` stored as P(n, es) gets

    k  = floor(s / 2^es)                  regime value
    r  = k + 2   (k >= 0)                 regime run incl. terminator
         1 - k   (k < 0)
    f  = max(0, n - 1 - r - es)           fraction bits (the significand
                                          width the paper's Fig. 1(d)
                                          accuracy wedge is made of)

so precision is maximal near |x| = 1 and decays by one fraction bit per
regime step — *which* binades get the bits is exactly what ``es`` selects.
This module turns a calibration histogram (``calib.observe``) into the
expected round-trip squared relative error for every (p8|p16) x es candidate,
closed-form per binade:

* in-range binade, f fraction bits: RNE on a uniform grid of spacing
  ``2^(s-f)`` over values ``m * 2^s`` with m ~ U[1, 2):
      E[(dx/x)^2] = (2^-2f / 12) * E[1/m^2] = 2^-2f / 24
* saturation (s >= max_scale) / underflow-to-minpos (s < -max_scale): the
  codec clamps to ``v = c * 2^s`` (c = maxpos/2^s resp. minpos/2^s), exactly:
      E[(v/x - 1)^2] = c^2/2 - 2 c ln2 + 1
* regime-truncated exponent (es bits cut off by a long regime, te bits
  missing): representable scales thin out to every ``g = 2^te``-th binade.
  The codec rounds at the *encoding* level (RNE on the code integer, not at
  arithmetic value midpoints — DESIGN.md §8): the first dropped bit is the
  MSB of the truncated exponent field, so a binade at offset ``d = s mod g``
  inside the scale gap rounds down to ``2^(s-d)`` when ``d < g/2`` and up to
  ``2^(s-d+g)`` when ``d >= g/2`` — each a clamp-to-one-value with
  ``c = 2^-d`` resp. ``2^(g-d)``, closed-form exact.

Validated against measured codec round-trips (exhaustive p8 sweep over all
binades x es, p16 regime-boundary sweep) in tests/test_calib.py; the clamp,
truncated-es and f=0 branches are exact up to regime-boundary effects, the
f >= 1 branch is a <~10% approximation.
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import numpy as np

from repro.calib.observe import BIN_LO, NBINS, TensorStats
from repro.core.types import ES_MAX, ES_MIN, PositFmt

_LN2 = math.log(2.0)

#: Second moment of m ~ U[1, 2): E[m^2] = 7/3 — converts per-binade relative
#: error into absolute squared error (E[x^2 | binade s] = 7/3 * 4^s).
_M2 = 7.0 / 3.0

#: Every weight-format candidate the calibration search scores.
CANDIDATES = tuple(PositFmt(n, es) for n in (8, 16)
                   for es in range(ES_MIN, ES_MAX + 1))

#: Exact E[rel^2] for a zero-fraction-bit binade (neighbors one binade apart,
#: encoding-level RNE boundary at m = 1.5):
#:   int_1^1.5 (1/m - 1)^2 dm  +  int_1.5^2 (2/m - 1)^2 dm  ~= 0.03834
_F0_SQ_ERR = (1.5 - 2.0 / 3.0 - 2.0 * math.log(1.5)) \
    + (7.0 / 6.0 - 4.0 * math.log(4.0 / 3.0))


def significand_bits(nbits: int, es: int, s: int) -> Tuple[int, int]:
    """(fraction bits, truncated es bits) for binade ``s`` under P(nbits, es).

    The regime-dependent significand width — posit tapered accuracy as an
    integer function of the binade.
    """
    k = math.floor(s / (1 << es))
    r = k + 2 if k >= 0 else 1 - k
    t = nbits - 1 - r                    # bits left after sign + regime
    f = max(0, t - es)
    es_avail = min(es, max(0, t))
    return f, es - es_avail


def _clamp_sq_err(c: float) -> float:
    """E[(c/m - 1)^2] for m ~ U[1, 2): exact clamp-to-one-value error."""
    return c * c / 2.0 - 2.0 * c * _LN2 + 1.0


def expected_sq_rel_err(nbits: int, es: int, s: int) -> float:
    """Expected squared relative round-trip error for values uniform in the
    binade [2^s, 2^(s+1)) encoded to P(nbits, es) and decoded back."""
    max_scale = (nbits - 2) << es
    if s >= max_scale:                       # saturate to maxpos
        return _clamp_sq_err(2.0 ** (max_scale - s))
    if s < -max_scale:                       # round up to minpos (no ftz)
        return _clamp_sq_err(2.0 ** (-max_scale - s))
    f, te = significand_bits(nbits, es, s)
    if te > 0:
        g = 1 << te                          # binades per representable scale
        d = s % g                            # offset inside the scale gap
        c = 2.0 ** (g - d) if d >= g // 2 else 2.0 ** (-d)
        return _clamp_sq_err(c)
    if f == 0:
        return _F0_SQ_ERR
    return 4.0 ** (-f) / 24.0


def _bin_scales() -> np.ndarray:
    return np.arange(BIN_LO, BIN_LO + NBINS)


@functools.lru_cache(maxsize=None)
def _err_profile(nbits: int, es: int) -> np.ndarray:
    """Vector of expected_sq_rel_err over every histogram binade (read-only:
    callers only np.dot against it)."""
    return np.asarray([expected_sq_rel_err(nbits, es, int(s))
                       for s in _bin_scales()])


def tensor_sq_rel_err(stats: TensorStats, fmt: PositFmt) -> float:
    """Histogram-weighted expected squared *relative* round-trip error.

    Zeros encode exactly and contribute 0; the result is a mean over all
    elements (zero mass included in the denominator), matching a measured
    ``mean(((decode(encode(x)) - x) / x)^2, where x != 0 else 0)``.
    """
    return float(np.dot(stats.probs, _err_profile(fmt.nbits, fmt.es)))


def tensor_abs_sq_err(stats: TensorStats, fmt: PositFmt) -> float:
    """Expected *absolute* squared error per element, E[(dx)^2].

    Couples the per-binade relative error with the per-binade magnitude
    (E[x^2 | s] = 7/3 * 4^s for in-binade-uniform values), so binades where
    tapered accuracy runs out of fraction bits are charged by how much signal
    actually lives there — this is the quantity the byte-budgeted search
    minimizes (propagated through x @ W, see calib.search).
    """
    scales = _bin_scales().astype(np.float64)
    mag2 = _M2 * np.exp2(2.0 * scales)
    return float(np.dot(stats.probs,
                        _err_profile(fmt.nbits, fmt.es) * mag2))


def outlier_mass(stats: TensorStats, fmt: PositFmt) -> float:
    """Fraction of (nonzero) mass outside the format's representable range —
    the saturation/underflow witness reported per layer in the artifact."""
    s = _bin_scales()
    out = (s >= fmt.max_scale) | (s < -fmt.max_scale)
    return float(np.sum(stats.probs[out]))


def measured_sq_rel_err(nbits: int, es: int, s: int,
                        n_samples: int = 65536, seed: int = 0) -> float:
    """Mean squared relative round-trip error measured through the real codec
    for values uniform in the binade [2^s, 2^(s+1)) — the validation oracle
    the analytic model is tested against.

    Uniform *random* sampling, not a linspace: an even grid phase-locks with
    the 2^f-cell quantization grid (every sample lands at the same offset in
    its cell, biasing the estimate arbitrarily — to 0 when they coincide).
    """
    import jax.numpy as jnp

    from repro.core.codec import posit_decode, posit_encode

    m = np.random.default_rng(seed).uniform(1.0, 2.0, n_samples)
    x = (m * 2.0 ** float(s)).astype(np.float32)
    xj = jnp.asarray(x)
    back = np.asarray(posit_decode(posit_encode(xj, nbits, es), nbits, es),
                      np.float64)
    rel = (back - x.astype(np.float64)) / x.astype(np.float64)
    return float(np.mean(rel * rel))
