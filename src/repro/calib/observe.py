"""Calibration observers — streaming per-tensor statistics from a forward pass.

A calibration pass runs the model's ordinary forward code under
``observing(Observer())``; every linear call site (``models.layers
.apply_linear`` / ``effective_weight``, keyed by the same layer-path strings
``resolve_policy`` sees) then streams *reduced* statistics for its weight and
activation tensors to the observer:

* ``abs_max``      — saturation / dynamic-range witness,
* ``hist``         — a log2-magnitude histogram: count of values with
                     ``floor(log2|x|) == s`` per binade ``s`` (the exact
                     quantity posit tapered accuracy is parameterized by —
                     ``calib.errmodel`` maps it to expected round-trip error
                     per ``(nbits, es)`` candidate),
* ``sum_sq``       — RMS magnitude (layer-importance weighting in the search),
* ``zeros``        — exact zeros (posit encodes them exactly; excluded from
                     the error integral).

Nothing else crosses the device->host boundary: the per-tensor reduction is
one 2-float head plus an int32 ``NBINS + 1`` count vector (the extra slot is
the nonfinite count — the serving numerics probes' NaR/inf witness, free for
calibration) shipped through ``jax.debug.callback``, so the hooks work
identically inside ``lax.scan`` stacks and ``jax.checkpoint`` bodies, and no
activation trace is ever materialized.  (Counts ride in int32 — a float32
scatter-add saturates at 2^24 per binade, which one full-size linear
exceeds.)  Call sites check ``is_active()`` at trace time — when no observer
is installed the hook is dead code and costs nothing.

This reduction core is shared by two consumers: calibration
(``calib.search`` — this module's original client) and the serving-plane
numerical-health probes (``repro.obs.numerics``), which install the same
``Observer`` under a cadenced decode executable and read saturation /
underflow / drift off the same histograms (DESIGN.md §12).

Stats are keyed by ``(path, kind)`` with ``kind in ("weight", "act",
"grad")``.  All depth-layers of a scanned stack share one call-site path, so
their statistics merge into one histogram — exactly the granularity at which
``PrecisionPolicy`` rules resolve (DESIGN.md §9/§11).

The ``"grad"`` kind is the training-plane channel (DESIGN.md §16): under
``jax.value_and_grad``, :func:`grad_tap` — a ``custom_vjp`` identity whose
backward rule records its cotangent — streams the gradient arriving at each
linear site's input through the same reduction.  The tap only enters the
trace when the active observer asks for gradients, so forward-only consumers
(calibration, serving probes) and un-observed training steps never carry it.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Binade range covered by the histogram: floor(log2|x|) in [BIN_LO, BIN_HI].
# BIN_HI must be >= the largest max_scale whose saturation we need to *see*:
# p8 es3 saturates at 2^48, so the top bin sits above it (s=48 mass must not
# clamp into an in-range bin, where it would be scored as truncated-es error
# instead of the ~4x larger clamp error and vanish from outlier_mass).  p16
# es2/es3 saturation (2^56 / 2^112) still clamps into the top bin — that
# only ever *under*-states the error of astronomically large outliers.
BIN_LO = -80
NBINS = 130
BIN_HI = BIN_LO + NBINS - 1

KINDS = ("weight", "act", "grad")


@dataclasses.dataclass
class TensorStats:
    """Mergeable streamed statistics of one tensor (or stream of tensors)."""

    n: float = 0.0                 # total elements seen (zeros included)
    zeros: float = 0.0             # exact zeros
    abs_max: float = 0.0
    sum_sq: float = 0.0
    nonfinite: float = 0.0         # NaN/inf elements (posit NaR witness)
    hist: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((NBINS,), np.float64))
    size: int = 0                  # per-record element count (static shape)
    shape: Tuple[int, ...] = ()    # shape of one recorded tensor

    def merge_vec(self, size: int, shape: Tuple[int, ...],
                  head: np.ndarray, counts: np.ndarray) -> None:
        """Fold one streamed record: head [abs_max, sum_sq], int32 counts.

        ``counts`` is the NBINS-binade histogram with one trailing slot for
        the nonfinite count (a bare NBINS histogram — old records — means
        nonfinite 0).
        """
        counts = np.asarray(counts, np.float64)
        self.n += float(size)
        self.abs_max = max(self.abs_max, float(head[0]))
        self.sum_sq += float(head[1])
        if counts.shape[0] == NBINS + 1:
            self.nonfinite += float(counts[-1])
            counts = counts[:-1]
        self.hist += counts
        self.zeros = self.n - float(self.hist.sum()) - self.nonfinite
        self.size = size
        self.shape = tuple(shape)

    @property
    def rms(self) -> float:
        return float(np.sqrt(self.sum_sq / self.n)) if self.n else 0.0

    @property
    def probs(self) -> np.ndarray:
        """Per-binade probability mass (zeros excluded from every bin; the
        zero fraction is ``zeros / n``)."""
        return self.hist / self.n if self.n else self.hist

    def nonzero_frac(self) -> float:
        return 1.0 - self.zeros / self.n if self.n else 0.0

    def hist_json(self) -> dict:
        """Compact JSON form of the binade histogram (artifact schema §11/§12):
        leading/trailing zero bins trimmed, ``bin_lo`` anchors the rest.
        The drift detector (``repro.obs.numerics``) loads these back as the
        calibration-time baseline distribution."""
        nz = np.flatnonzero(self.hist)
        if nz.size == 0:
            return {"bin_lo": 0, "counts": [], "n": self.n}
        lo, hi = int(nz[0]), int(nz[-1])
        return {"bin_lo": BIN_LO + lo,
                "counts": [int(c) for c in self.hist[lo:hi + 1]],
                "n": self.n}

    @staticmethod
    def hist_from_json(d: dict) -> "TensorStats":
        """Inverse of ``hist_json``: a TensorStats holding just the
        distribution (n + hist) — enough for drift scoring."""
        st = TensorStats()
        st.n = float(d.get("n", 0.0))
        for i, c in enumerate(d.get("counts", ())):
            b = int(d["bin_lo"]) + i - BIN_LO
            if 0 <= b < NBINS:
                st.hist[b] = float(c)
        st.zeros = st.n - float(st.hist.sum())
        return st


def _stat_vec(arr: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Device-side reduction: ([abs_max, sum_sq], int32 counts[NBINS + 1]).

    ``counts[:NBINS]`` is the binade histogram, ``counts[-1]`` the nonfinite
    count (NaN/inf — what would encode to posit NaR; the serving probes'
    health witness).  Counts accumulate in int32: a float32 scatter-add
    silently saturates at 2^24 per binade, which a single full-size linear
    (~1e8 elements) exceeds.
    """
    x = jnp.abs(arr.astype(jnp.float32)).reshape(-1)
    finite = jnp.isfinite(x)
    x = jnp.where(finite, x, 0.0)
    nonzero = x > 0.0
    # frexp gives x = m * 2^e with m in [0.5, 1): floor(log2|x|) == e - 1,
    # exactly (no float-log rounding at binade boundaries)
    _, e = jnp.frexp(x)
    idx = jnp.where(finite, jnp.clip(e - 1, BIN_LO, BIN_HI) - BIN_LO, NBINS)
    counts = jnp.zeros((NBINS + 1,), jnp.int32).at[idx].add(
        (nonzero | ~finite).astype(jnp.int32))
    head = jnp.stack([jnp.max(x, initial=0.0), jnp.sum(x * x)])
    return head, counts


class Observer:
    """Accumulates ``TensorStats`` per ``(path, kind)`` key on the host.

    ``kinds`` restricts which tensor kinds stream: calibration wants weights
    and activations (the default); the serving numerics probes pass
    ``("act",)`` — weights are static during serving — and the training
    telemetry probes pass ``("act", "grad")``.  Because the filter applies at
    *trace* time, the skipped kinds' reductions and callbacks never enter the
    probed executable.  ``"grad"`` is deliberately not in the default: it
    inserts :func:`grad_tap` wrappers into observed forwards, which
    forward-only consumers have no use for.
    """

    def __init__(self, kinds: Tuple[str, ...] = ("weight", "act")):
        assert all(k in KINDS for k in kinds), kinds
        self.kinds = tuple(kinds)
        self.stats: Dict[Tuple[str, str], TensorStats] = {}

    # -- host side -----------------------------------------------------------
    def _accum(self, key: Tuple[str, str], size: int,
               shape: Tuple[int, ...], head, hist) -> None:
        st = self.stats.get(key)
        if st is None:
            st = self.stats[key] = TensorStats()
        st.merge_vec(size, shape, np.asarray(head), np.asarray(hist))

    # -- trace side ----------------------------------------------------------
    def record(self, path: str, kind: str, arr: jax.Array) -> None:
        assert kind in KINDS, kind
        if kind not in self.kinds:
            return
        head, hist = _stat_vec(arr)
        jax.debug.callback(
            functools.partial(self._accum, (path, kind),
                              int(arr.size), tuple(arr.shape)),
            head, hist)

    # -- results -------------------------------------------------------------
    def paths(self) -> Tuple[str, ...]:
        return tuple(sorted({p for p, _ in self.stats}))

    def get(self, path: str, kind: str) -> Optional[TensorStats]:
        return self.stats.get((path, kind))


_ACTIVE: Optional[Observer] = None


def is_active() -> bool:
    return _ACTIVE is not None


def get_active() -> Optional[Observer]:
    return _ACTIVE


@contextlib.contextmanager
def observing(obs: Observer):
    """Install ``obs`` as the active calibration observer for the block."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = obs
    try:
        yield obs
    finally:
        _ACTIVE = prev


def record(path: str, kind: str, arr: jax.Array) -> None:
    """Call-site hook: stream stats for ``arr`` if an observer is active.

    This is the function ``models.layers`` calls next to every
    ``resolve_policy``; it must stay free to call when inactive (plain global
    read at trace time).
    """
    if _ACTIVE is not None:
        _ACTIVE.record(path, kind, arr)


# ------------------------------------------------------------ gradient tap ----

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _grad_tap(path: str, x):
    return x


def _grad_tap_fwd(path: str, x):
    return x, None


def _grad_tap_bwd(path: str, _res, g):
    # Runs once per backward trace (custom_vjp bwd is not replayed by
    # jax.checkpoint the way forward residual recomputation is), so the grad
    # histogram counts every cotangent element exactly once per step.
    record(path, "grad", g)
    return (g,)


_grad_tap.defvjp(_grad_tap_fwd, _grad_tap_bwd)


def grad_tap(path: str, x: jax.Array) -> jax.Array:
    """Identity whose cotangent streams to the active observer's ``"grad"``
    channel, keyed by the same ``path`` the act/weight records use.

    Trace-time gated exactly like :func:`record`: when no observer wants
    gradients the function returns ``x`` untouched and the executable carries
    neither the custom_vjp wrapper nor the backward callback.
    """
    if _ACTIVE is not None and "grad" in _ACTIVE.kinds:
        return _grad_tap(path, x)
    return x


def collect_stats(forward_fn, batches) -> Observer:
    """Run ``forward_fn`` over ``batches`` under a fresh observer.

    ``forward_fn(batch)`` is any callable that executes the model's forward
    code (e.g. ``lambda b: model.forward(params, b, policy)``).  Returns the
    populated observer after draining all pending host callbacks.
    """
    obs = Observer()
    with observing(obs):
        for batch in batches:
            out = forward_fn(batch)
            jax.block_until_ready(out)
    # debug.callback effects are asynchronous; drain them before reading stats
    barrier = getattr(jax, "effects_barrier", None)
    if barrier is not None:
        barrier()
    return obs
