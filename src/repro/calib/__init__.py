"""repro.calib — data-driven dynamic-es calibration (DESIGN.md §11).

Three layers:

* ``observe``  — calibration-mode forward pass streaming per-tensor log2
                 histograms from every linear call site,
* ``errmodel`` — analytic tapered-accuracy round-trip error model per
                 (p8|p16) x es candidate,
* ``search``   — byte-budgeted knapsack emitting a ``PrecisionPolicy``
                 artifact (observe -> search -> quantize).

``observe`` and ``errmodel`` are import-light (models.layers imports the
observe hook); ``search`` joins against the model layer walker and is
re-exported lazily to keep the import graph acyclic.
"""
from repro.calib.errmodel import (CANDIDATES, expected_sq_rel_err,
                                  measured_sq_rel_err, outlier_mass,
                                  significand_bits, tensor_abs_sq_err,
                                  tensor_sq_rel_err)
from repro.calib.observe import (Observer, TensorStats, collect_stats,
                                 is_active, observing, record)

__all__ = [
    "CANDIDATES", "Observer", "TensorStats", "calibrate_model",
    "collect_stats", "expected_sq_rel_err", "is_active",
    "measured_sq_rel_err", "observing", "outlier_mass", "record",
    "save_artifact", "significand_bits", "tensor_abs_sq_err",
    "tensor_sq_rel_err",
]


def __getattr__(name):
    # search imports models.layers (which imports calib.observe): load on
    # first use instead of at package import to keep the cycle one-way.
    # importlib, not ``from repro.calib import search`` — the from-import
    # re-enters this __getattr__ before the submodule binds and recurses.
    if name in ("calibrate_model", "save_artifact", "search"):
        import importlib

        search = importlib.import_module("repro.calib.search")
        return getattr(search, name) if name != "search" else search
    raise AttributeError(name)
