"""Byte-budgeted precision search: observer stats -> PrecisionPolicy artifact.

The knapsack: each linear call site i (all depth-layers of a scanned stack
share one site, matching PrecisionPolicy rule granularity) must pick a weight
format c from ``errmodel.CANDIDATES``; minimize the predicted end-to-end
error subject to a weight-byte budget

    min  sum_i  S_i(c_i)      s.t.  sum_i n_i * bytes(c_i) <= B

where the per-site score is the propagated output perturbation of y = x @ W:

    S_i(c) = n_i * act_rms_i^2 * E[(dW)^2 | c]        (errmodel.tensor_abs_sq_err)

(E||x . dW||^2 ~= d_in * act_rms^2 * E[dW^2] per output element; summing over
outputs and depth layers gives n_i = total weight count at the site as the
multiplier).  With only two byte levels (p8 = 1 B/value, p16 = 2 B/value) the
knapsack is a classic marginal-utility greedy, which is optimal here up to
the last item: every site starts at its best-es p8 candidate (the 1-byte
floor — per-site es choice alone is what beats the uniform-es presets), then
sites are upgraded to their best-es p16 candidate in decreasing
error-reduction-per-byte order until the budget is exhausted.

The emitted ``PrecisionPolicy`` carries one anchored rule per site (resolved
by suffix matching both at quantize-time tree paths and decode-time call-site
paths, DESIGN.md §9) plus a final ``weights=None`` catch-all that pins
anything unobserved to the base policy, and serializes to the JSON artifact
schema in DESIGN.md §11.
"""
from __future__ import annotations

import dataclasses
import fnmatch
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.calib import errmodel
from repro.calib.observe import Observer, TensorStats, collect_stats
from repro.core.pcsr import TransPolicy
from repro.core.policy import LayerRule, PrecisionPolicy
from repro.core.types import PositFmt


@dataclasses.dataclass
class SitePlan:
    """One call site's slice of the knapsack."""

    path: str                     # observed call-site path (== rule pattern)
    n_weights: int                # total weight elements resolving to this site
    pack_ok: bool                 # plain "w" linears with even d_in everywhere
    w_stats: TensorStats
    act_rms: float                # importance weight (1.0 when unobserved)
    act_stats: Optional[TensorStats] = None  # full act distribution (drift
    #                               baseline persisted in the artifact, §12)

    def score(self, fmt: PositFmt) -> float:
        return (self.n_weights * self.act_rms ** 2
                * errmodel.tensor_abs_sq_err(self.w_stats, fmt))

    def bytes_at(self, fmt: PositFmt) -> int:
        return self.n_weights * fmt.storage_bytes

    def best(self, nbits: int) -> Tuple[PositFmt, float]:
        cands = [(self.score(c), c.es, c)
                 for c in errmodel.CANDIDATES if c.nbits == nbits]
        s, _, c = min(cands)
        return c, s


def _site_for(tree_path: str, sites: Iterable[str]) -> Optional[str]:
    """The observed site a quantize-time tree path resolves to — the same
    suffix match ``core.policy`` rules use, so plan and policy agree."""
    for site in sites:
        if fnmatch.fnmatchcase(tree_path, site) \
                or fnmatch.fnmatchcase(tree_path, "*/" + site):
            return site
    return None


def build_site_plans(params, observer: Observer) -> List[SitePlan]:
    """Join observer stats with the real param tree.

    Weight *sizes* come from the tree (a scanned stack's site sees per-layer
    slices, but the tree holds the full (L, d_in, d_out) stack — byte
    accounting must match ``policy_weight_bytes``); weight/activation
    *statistics* come from the observer.  Tree linears with no observed site
    (e.g. params a forward pass never touches) are left out — the emitted
    catch-all pins them to the base policy.
    """
    # lazy import: models.layers imports calib.observe (the hook), so the
    # calib package must not import models at module scope
    from repro.models.layers import _RAW_WEIGHT_PATTERNS, _walk_linears

    observed = [p for p in observer.paths()
                if observer.get(p, "weight") is not None]
    agg: Dict[str, dict] = {}
    for tree_path, parent, key in _walk_linears(params, ""):
        if any(fnmatch.fnmatchcase(tree_path, pat)
               for pat in _RAW_WEIGHT_PATTERNS):
            continue
        site = _site_for(tree_path, observed)
        if site is None:
            continue
        w = parent[key]
        a = agg.setdefault(site, {"n": 0, "pack_ok": True})
        a["n"] += int(np.prod(w.shape))
        # packed lanes need a plain {"w": ...} linear with even contraction
        # dim (quantize_params applies the same predicate)
        a["pack_ok"] &= (key == "w" and w.shape[-2] % 2 == 0)

    plans = []
    for site, a in sorted(agg.items()):
        act = observer.get(site, "act")
        plans.append(SitePlan(
            path=site, n_weights=a["n"], pack_ok=a["pack_ok"],
            w_stats=observer.get(site, "weight"),
            act_rms=act.rms if act is not None and act.rms > 0 else 1.0,
            act_stats=act))
    return plans


def p8_floor_bytes(plans: List[SitePlan]) -> int:
    """The 1-byte-per-weight floor — the ``p8-weights`` preset's budget."""
    return sum(p.n_weights for p in plans)


def resolve_budget(byte_budget, floor: int) -> int:
    """Budget spellings: None -> the p8 floor; ``"1.5x"`` -> multiple of the
    floor (so ``1x`` = p8-weights bytes, ``2x`` = p16 everywhere); an int (or
    digit string) -> absolute bytes."""
    if byte_budget is None:
        return floor
    if isinstance(byte_budget, str):
        s = byte_budget.strip().lower()
        if s.endswith("x"):
            return int(round(float(s[:-1]) * floor))
        return int(s)
    return int(byte_budget)


def search(plans: List[SitePlan], byte_budget=None
           ) -> Tuple[Dict[str, PositFmt], dict]:
    """Greedy knapsack over sites; returns ({site: fmt}, report).

    ``byte_budget=None`` means the p8 floor (every site stays 1 B/value and
    only es is allocated — the equal-bytes configuration the acceptance
    criterion compares against the ``p8-weights`` preset); see
    ``resolve_budget`` for the other spellings.
    """
    floor = p8_floor_bytes(plans)
    budget = resolve_budget(byte_budget, floor)
    if budget < floor:
        raise ValueError(
            f"weight byte budget {budget} is below the p8 floor {floor} "
            f"(1 byte per weight is the smallest storage this stack has)")

    choice: Dict[str, PositFmt] = {}
    scores: Dict[str, float] = {}
    upgrades = []
    for p in plans:
        c8, s8 = p.best(8)
        c16, s16 = p.best(16)
        choice[p.path], scores[p.path] = c8, s8
        if s16 < s8:
            # error reduction per extra byte if this site goes p16
            upgrades.append((-(s8 - s16) / p.n_weights, p.path, c16, s16))

    spent = floor
    for _, path, c16, s16 in sorted(upgrades):
        plan = next(p for p in plans if p.path == path)
        extra = plan.n_weights        # p16 doubles this site's bytes
        if spent + extra > budget:
            continue
        spent += extra
        choice[path], scores[path] = c16, s16

    total_score = sum(scores.values())
    report = {
        "byte_budget": budget,
        "p8_floor_bytes": floor,
        "weight_bytes": spent,
        "predicted_err_score": total_score,
        "sites": [{
            "path": p.path,
            "n_weights": p.n_weights,
            "fmt": choice[p.path].name,
            "packed": bool(choice[p.path].nbits == 8 and p.pack_ok),
            "act_rms": round(p.act_rms, 6),
            "w_rms": round(p.w_stats.rms, 6),
            "w_abs_max": p.w_stats.abs_max,
            "outlier_mass": errmodel.outlier_mass(p.w_stats, choice[p.path]),
            "predicted_sq_rel_err": errmodel.tensor_sq_rel_err(
                p.w_stats, choice[p.path]),
            # calibration-time activation binade histogram: the drift
            # baseline repro.obs.numerics compares live traffic against
            **({"act_hist": p.act_stats.hist_json()}
               if p.act_stats is not None else {}),
        } for p in plans],
    }
    return choice, report


def emit_policy(plans: List[SitePlan], choice: Dict[str, PositFmt],
                base=None, name: str = "calibrated") -> PrecisionPolicy:
    """Materialize the search result as an ordered-rule PrecisionPolicy."""
    rules = [LayerRule(p.path, choice[p.path],
                       packed=choice[p.path].nbits == 8 and p.pack_ok)
             for p in plans]
    rules.append(LayerRule("*", None))   # pin unobserved layers to the base
    return PrecisionPolicy(base=base if base is not None else TransPolicy(),
                           rules=tuple(rules), name=name)


def calibration_batches(cfg, rng, n: int, *, batch: int = 2,
                        seq: int = 64) -> List[dict]:
    """``n`` random loss-shaped batches for ``cfg``'s model family.

    Tokens + labels always (calibration drives ``model.loss`` so the lm_head
    site is observed), plus the vlm patch / whisper frame modality inputs.
    The one definition every calibration driver shares (``serve
    --calibrate``, hillclimb ``prec_calibrated``, ``bench_calibration``) —
    family handling must not diverge between them.
    """
    import jax.numpy as jnp

    batches = []
    for _ in range(n):
        b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (batch, seq))),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (batch, seq)))}
        if cfg.family == "vlm":
            b["patch_embeds"] = jnp.asarray(rng.normal(
                0, 1, (batch, cfg.n_patches, cfg.d_model)).astype(np.float32))
        elif cfg.family == "whisper":
            b["frames"] = jnp.asarray(rng.normal(
                0, 1, (batch, cfg.enc_frames, cfg.d_model)).astype(np.float32))
        batches.append(b)
    return batches


def calibrate_model(forward_fn, batches, params, *, base=None,
                    byte_budget=None, name: str = "calibrated"
                    ) -> Tuple[PrecisionPolicy, dict]:
    """observe -> search -> policy, end to end.

    ``forward_fn(batch)`` runs the model's forward code (any callable);
    ``batches`` is the calibration set; ``params`` the float param tree the
    byte accounting walks; ``base`` supplies every non-weight role of the
    emitted policy.  Returns ``(policy, report)`` where ``report`` is the
    JSON-ready calibration record (also embedded in saved artifacts as
    ``meta``).
    """
    observer = collect_stats(forward_fn, batches)
    plans = build_site_plans(params, observer)
    if not plans:
        raise ValueError(
            "calibration observed no linear call sites — did the forward "
            "pass run under float (unquantized) params?")
    choice, report = search(plans, byte_budget)
    policy = emit_policy(plans, choice, base=base, name=name)
    report["n_sites"] = len(plans)
    report["name"] = name
    return policy, report


def save_artifact(path: str, policy: PrecisionPolicy, report: dict) -> None:
    """Write the calibration artifact: the policy JSON plus the search
    report under ``meta`` (ignored on load — ``from_json`` reads only the
    policy fields, so hand-edited artifacts stay loadable)."""
    import json

    doc = policy.to_json()
    doc["meta"] = report
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
