"""Pure-posit integer ALU — the PERCIVAL-style "parallel PAU" baseline.

The paper argues *against* this design point: PERCIVAL [5] / CLARINET [6] embed
a complete posit arithmetic unit next to the FPU (+132% LUTs / +135% FFs at FPU
level, Table II). To quantify that trade-off in our setting we implement true
posit arithmetic — add and multiply computed entirely in integer bit
manipulation, never touching a float — and benchmark it against the paper's
codec+FPU path (decode -> MXU float op -> encode).

Numerics note (documented fidelity gap, DESIGN.md §2): a true PAU rounds the
*exact* sum/product once. The paper's codec+FPU path rounds in FP32 first and
in the posit encode second (double rounding). For all supported formats the
product path is f32-exact (<=14-bit significands, product <=28 bits < 24? no —
28 > 24), so the two designs can differ in the last bit; this module is the
single-rounding reference, validated against ``ref_codec.ref_add/ref_mul``.

Layout invariants (all uint32/int32, no int64):
  * significands carry the hidden bit at bit SIGW-1 (SIGW = 6 for p8, 14 for p16)
  * the add datapath places the hidden bit at bit 27, leaving 14 guard bits —
    alignment shifts <= 14 are exact, larger shifts set a sticky flag handled
    with the floor/fraction trick so RNE stays exact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.codec import (
    EsLike, _decode_fields, _encode_fields, _es_u32, _sigw, _u32, _U32,
)

from repro.core.quire import QuireFmt, quire_accumulate, quire_negate, quire_read, quire_zero

_HID = 27  # hidden-bit position in the add datapath


def posit_mul(a: jax.Array, b: jax.Array, nbits: int, es: EsLike) -> jax.Array:
    """True posit multiply: exact product, single RNE rounding."""
    n = nbits
    esl = _es_u32(es)
    na, sa, ga, za, ra = _decode_fields(a, n, esl)
    nb, sb, gb, zb, rb = _decode_fields(b, n, esl)

    neg = na ^ nb
    scale = sa + sb
    p = ga * gb  # <= 28 bits: [2^(2w-2), 2^(2w-1))
    w = _sigw(n)
    hi = p >= (_u32(1) << _u32(2 * w - 1))  # product in [2,4)
    scale = scale + hi.astype(jnp.int32)
    # drop the hidden bit, left-align the fraction at bit 31
    frac = jnp.where(hi, p - (_u32(1) << _u32(2 * w - 1)), p - (_u32(1) << _u32(2 * w - 2)))
    frac_la = jnp.where(hi, frac << _u32(32 - (2 * w - 1)), frac << _u32(32 - (2 * w - 2)))
    sticky = jnp.zeros(p.shape, dtype=bool)

    code = _encode_fields(neg, scale, frac_la, sticky, n, esl)
    code = jnp.where(za | zb, _u32(0), code)
    code = jnp.where(ra | rb, _u32(1 << (n - 1)), code)
    return code.astype(jnp.uint8 if n == 8 else jnp.uint16)


def posit_add(a: jax.Array, b: jax.Array, nbits: int, es: EsLike) -> jax.Array:
    """True posit add: exact sum, single RNE rounding (floor/fraction sticky)."""
    n = nbits
    esl = _es_u32(es)
    na, sa, ga, za, ra = _decode_fields(a, n, esl)
    nb, sb, gb, zb, rb = _decode_fields(b, n, esl)
    w = _sigw(n)

    # promote significands: hidden bit at _HID (14 guard bits below)
    ma = (ga << _u32(_HID - (w - 1))).astype(jnp.int32)
    mb = (gb << _u32(_HID - (w - 1))).astype(jnp.int32)

    a_big = (sa > sb) | ((sa == sb) & (ma >= mb))
    s_hi = jnp.where(a_big, sa, sb)
    s_lo = jnp.where(a_big, sb, sa)
    m_hi = jnp.where(a_big, ma, mb)
    m_lo = jnp.where(a_big, mb, ma)
    n_hi = jnp.where(a_big, na, nb)
    n_lo = jnp.where(a_big, nb, na)

    shift = jnp.minimum(s_hi - s_lo, 31).astype(_U32)
    lost = (m_lo.astype(_U32) & ((_u32(1) << shift) - 1)) != 0
    m_lo_sh = (m_lo.astype(_U32) >> shift).astype(jnp.int32)

    sgn_hi = jnp.where(n_hi, jnp.int32(-1), jnp.int32(1))
    sgn_lo = jnp.where(n_lo, jnp.int32(-1), jnp.int32(1))
    v = sgn_hi * m_hi + sgn_lo * m_lo_sh
    # exact value = v + sgn_lo * eps, eps in (0,1) iff lost. Take floor:
    v = v - (lost & n_lo).astype(jnp.int32)
    neg_r = v < 0
    mag = jnp.where(neg_r, -v, v).astype(_U32)
    # if floor < 0 and a fraction exists, magnitude = |floor| - (1 - eps')
    mag = mag - (lost & neg_r).astype(_U32)
    sticky = lost

    exact_zero = (mag == 0) & ~sticky
    mag_safe = jnp.maximum(mag, _u32(1))
    h = (31 - lax.clz(mag_safe.astype(jnp.int32))).astype(jnp.int32)  # MSB position
    scale = s_hi + (h - _HID)
    frac_la = (mag_safe << (_u32(31) - h.astype(_U32))) << 1

    code = _encode_fields(neg_r, scale, frac_la, sticky, n, esl)
    code = jnp.where(exact_zero, _u32(0), code)
    code = jnp.where(za, b.astype(_U32) & _u32((1 << n) - 1), code)
    code = jnp.where(zb & ~za, a.astype(_U32) & _u32((1 << n) - 1), code)
    code = jnp.where(ra | rb, _u32(1 << (n - 1)), code)
    return code.astype(jnp.uint8 if n == 8 else jnp.uint16)


def posit_sub(a: jax.Array, b: jax.Array, nbits: int, es: EsLike) -> jax.Array:
    """a - b via two's-complement negation of b (posit negation is exact)."""
    n = nbits
    nb = ((_u32(1 << n) - b.astype(_U32)) & _u32((1 << n) - 1))
    return posit_add(a, nb.astype(b.dtype), n, es)


# =====================================================================
# fused quire ops — PERCIVAL's quire ISA (qmadd.s / qmsub.s / qclr / qneg /
# qround.p) at op granularity. The quire state itself lives in
# ``repro.core.quire``; these are the ALU-level fused entry points: a
# multiply whose exact product is accumulated with NO intermediate rounding.
# =====================================================================

def qclr(batch_shape, nbits: int, es: int = 2):
    """Cleared quire for P(nbits, es) — PERCIVAL ``qclr``."""
    return quire_zero(batch_shape, QuireFmt(nbits, es))


def qma(q: jax.Array, a: jax.Array, b: jax.Array, nbits: int,
        es: EsLike) -> jax.Array:
    """q += a * b exactly (PERCIVAL ``qmadd.s``): no rounding until qround."""
    return quire_accumulate(q, a, b, QuireFmt(nbits), es_a=es, es_b=es)


def qms(q: jax.Array, a: jax.Array, b: jax.Array, nbits: int,
        es: EsLike) -> jax.Array:
    """q -= a * b exactly (PERCIVAL ``qmsub.s``)."""
    return quire_accumulate(q, a, b, QuireFmt(nbits), es_a=es, es_b=es,
                            subtract=True)


def qneg(q: jax.Array, nbits: int) -> jax.Array:
    """Exact quire negation (PERCIVAL ``qneg``)."""
    return quire_negate(q, QuireFmt(nbits))


def qround(q: jax.Array, nbits: int, es: EsLike) -> jax.Array:
    """quire -> posit code, the single terminal RNE (PERCIVAL ``qround.p``)."""
    return quire_read(q, QuireFmt(nbits), es_out=es)
