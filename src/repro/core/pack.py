"""Packed posit-8 lanes — two p8 codes per 16-bit word (DESIGN.md §9).

The paper's multi/mixed-precision lever: narrow posit operands share wider
vector lanes, so one op moves (and one decode step produces) two values.  Here
the memory-system analogue: a p8 weight matrix travels through HBM/VMEM as
uint16 lanes holding two codes each, halving the *word count* the BlockSpec
pipeline moves versus a widen-to-p16 layout (and matching the PVU's packed
posit vector lanes, which PERCIVAL lacks).

**Split-K layout.** For a (K, N) weight matrix with half-K ``Kh = ceil(K/2)``:

    packed[r, c] = codes[r, c]  |  codes[r + Kh, c] << 8        (r < Kh)

i.e. the low byte carries row ``r`` and the high byte carries row ``r + Kh``
(an odd K pads one zero row — 0-codes decode to 0.0 and contribute nothing to
any accumulator).  Split-K rather than interleaved-K so consumers never need
strided slices: lane extraction gives two *contiguous* (Kh, N) operand halves,
and a GEMM becomes

    A @ decode(packed) == A[:, :Kh] @ decode(lo) + A[:, Kh:] @ decode(hi)

— two full-width MXU contractions per tile, no gather/interleave step
(``kernels/posit_gemm`` maps the two A halves as two BlockSpecs over the same
array).  Packing applies along the *contraction* axis of the last two dims;
leading (stacked-layer) batch dims pass through untouched.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.codec import EsLike
from repro.core.lut import decode_with_impl


def packed_half_k(k: int) -> int:
    """Rows of the packed array for a K-row unpacked operand."""
    return (k + 1) // 2


def pack_p8(codes: jax.Array) -> jax.Array:
    """(..., K, N) uint8 p8 codes -> (..., ceil(K/2), N) uint16 packed lanes."""
    k = codes.shape[-2]
    kh = packed_half_k(k)
    lo = codes[..., :kh, :].astype(jnp.uint16)
    hi = codes[..., kh:, :].astype(jnp.uint16)
    if k % 2:  # zero-pad the missing high lane of the last row
        pad = [(0, 0)] * (codes.ndim - 2) + [(0, 1), (0, 0)]
        hi = jnp.pad(hi, pad)
    return lo | (hi << jnp.uint16(8))


def unpack_p8(packed: jax.Array, k: Optional[int] = None) -> jax.Array:
    """Inverse of ``pack_p8``: (..., Kh, N) uint16 -> (..., K, N) uint8 codes.

    ``k`` trims the zero pad row of an odd-K pack (default: 2*Kh).
    """
    lo = (packed & jnp.uint16(0xFF)).astype(jnp.uint8)
    hi = (packed >> jnp.uint16(8)).astype(jnp.uint8)
    out = jnp.concatenate([lo, hi], axis=-2)
    if k is not None:
        out = out[..., :k, :]
    return out


def packed_decode_p8(packed: jax.Array, es: EsLike, *,
                     codec_impl: str = "auto",
                     k: Optional[int] = None) -> jax.Array:
    """Decode both lanes of a packed array -> (..., K, N) f32.

    One byte-extract per lane (``unpack_p8`` — the single home of the lane
    layout outside the Pallas kernel body), then the p8 decode (the PR-2 LUT
    gather under ``codec_impl in ("auto", "lut")`` on gather-friendly
    backends) — the decode cost is identical to unpacked codes; only the
    bytes moved halve.
    """
    return decode_with_impl(unpack_p8(packed, k), 8, es, codec_impl)


def split_activations(x: jax.Array, kh: int) -> tuple[jax.Array, jax.Array]:
    """Split the contraction axis of ``x`` (..., K) into the (lo, hi) halves
    matching a split-K packed weight: ``x_lo`` pairs with the low lanes
    (rows [0, Kh)), ``x_hi`` with the high lanes (rows [Kh, 2*Kh); zero-padded
    when K is odd)."""
    k = x.shape[-1]
    x_lo = x[..., :kh]
    x_hi = x[..., kh:]
    if k < 2 * kh:
        pad = [(0, 0)] * (x.ndim - 1) + [(0, 2 * kh - k)]
        x_hi = jnp.pad(x_hi, pad)
    return x_lo, x_hi
