"""Posit GEMM front door — the paper's Fig. 2(b) dataflow at op granularity.

Three dataflows, selected by the pcsr (``OperandSlots.dataflow``) or the
``impl`` override:

* ``fused``  (ours): posit operands are decoded tile-by-tile *inside* the matmul
  (Pallas kernel on TPU; XLA-fused jnp path elsewhere), the MXU/FPU computes in
  float, and the result is optionally encoded on the way out. One HBM read of
  1–2-byte posit words per operand — the codec rides along for free.
* ``unfused`` ([7]-style, PPU-light): a *separate* conversion pass materializes
  the full decoded f32 tensor in HBM before the matmul (and a separate encode
  pass after). Two extra HBM round-trips per operand — the analogue of [7]'s two
  extra conversion instructions per operation, which cost it 2.54x throughput.
* ``quire`` (PERCIVAL-style, beyond-paper): every posit product accumulates
  *exactly* in a software Kulisch accumulator (repro.core.quire), with a single
  rounding at readout — zero accumulation error, at integer-datapath cost.
  Requires all-posit slots; see ``kernels.posit_quire_gemm`` for the tiled
  TPU version of the same contract.

Operand formats come from an ``OperandSlots`` pcsr (per-slot pfmt/pprec/pes):
float slots bypass the codec entirely (IEEE-754 compatibility), posit slots
decode with their (possibly traced) es. Mixed posit x float GEMMs fall out.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.codec import EsLike, posit_decode, posit_encode
from repro.core.pcsr import OperandSlots
from repro.core.types import Fmt, PositFmt, compute_dtype_for


def _decode_operand(x: jax.Array, fmt: Fmt, es: Optional[EsLike], compute_dtype) -> jax.Array:
    if isinstance(fmt, PositFmt):
        return posit_decode(x, fmt.nbits, fmt.es if es is None else es).astype(compute_dtype)
    return x.astype(compute_dtype)


def _encode_result(y: jax.Array, fmt: Fmt, es: Optional[EsLike]) -> jax.Array:
    if isinstance(fmt, PositFmt):
        return posit_encode(y, fmt.nbits, fmt.es if es is None else es)
    return y.astype(compute_dtype_for(fmt))


def _quire_dot(a, b, slots, *, es_a=None, es_b=None, es_out=None,
               dimension_numbers=None):
    """dataflow="quire": exact accumulation through repro.core.quire."""
    from repro.core.quire import quire_matmul  # core->quire, no cycle w/ dot

    for name, f in (("rs1", slots.rs1), ("rs2", slots.rs2), ("rd", slots.rd)):
        if not isinstance(f, PositFmt):
            raise ValueError(
                f"quire dataflow requires posit {name}, got {f}: float slots "
                "have no exact quire representation (use fused/unfused)")
    if dimension_numbers is not None:
        raise NotImplementedError(
            "quire dataflow supports plain (M,K)@(K,N) contractions")
    if a.ndim != 2 or b.ndim != 2:
        raise NotImplementedError(
            f"quire dataflow is 2-D GEMM only, got {a.shape} @ {b.shape}")
    wide = slots.rs1 if slots.rs1.nbits >= slots.rs2.nbits else slots.rs2
    return quire_matmul(
        a, b, wide,
        es_a=slots.rs1.es if es_a is None else es_a,
        es_b=slots.rs2.es if es_b is None else es_b,
        nbits_a=slots.rs1.nbits, nbits_b=slots.rs2.nbits,
        out_nbits=slots.rd.nbits,
        es_out=slots.rd.es if es_out is None else es_out,
    )


def posit_dot(
    a: jax.Array,
    b: jax.Array,
    slots: OperandSlots,
    *,
    es_a: Optional[EsLike] = None,
    es_b: Optional[EsLike] = None,
    es_out: Optional[EsLike] = None,
    impl: Optional[str] = None,
    compute_dtype=None,
    dimension_numbers=None,
) -> jax.Array:
    """General dot with per-operand pcsr formats.

    a/b: float arrays, or uint8/uint16 posit-code arrays per ``slots``.
    impl: "fused" (ours) | "unfused" ([7]-style baseline) | "quire" (exact
    accumulation, single terminal rounding); ``None`` defers to the pcsr's
    ``slots.dataflow``. fused/unfused accumulate in f32 (the MXU/FPU
    datapath, like the paper's FP32 FPU); quire accumulates exactly.
    """
    if impl is None:
        impl = slots.dataflow
    if impl not in ("fused", "unfused", "quire"):
        raise ValueError(f"impl must be fused|unfused|quire, got {impl}")
    if impl == "quire":
        return _quire_dot(a, b, slots, es_a=es_a, es_b=es_b, es_out=es_out,
                          dimension_numbers=dimension_numbers)
    if compute_dtype is None:
        # lossless-decode dtype: bf16 only if *both* operands allow it
        ca = compute_dtype_for(slots.rs1)
        cb = compute_dtype_for(slots.rs2)
        compute_dtype = ca if ca == cb else jnp.float32

    if impl == "unfused":
        # Materialize full decoded tensors in HBM (optimization barrier keeps XLA
        # from re-fusing them into the matmul — this is the point of the baseline).
        af = _decode_operand(a, slots.rs1, es_a, compute_dtype)
        bf = _decode_operand(b, slots.rs2, es_b, compute_dtype)
        af = jax.lax.optimization_barrier(af)
        bf = jax.lax.optimization_barrier(bf)
    else:
        af = _decode_operand(a, slots.rs1, es_a, compute_dtype)
        bf = _decode_operand(b, slots.rs2, es_b, compute_dtype)

    if dimension_numbers is None:
        y = jnp.matmul(af, bf, preferred_element_type=jnp.float32)
    else:
        y = jax.lax.dot_general(af, bf, dimension_numbers, preferred_element_type=jnp.float32)

    if impl == "unfused":
        y = jax.lax.optimization_barrier(y)
    return _encode_result(y, slots.rd, es_out)


def posit_matmul_wx(
    x: jax.Array,
    w_codes: jax.Array,
    w_fmt: PositFmt,
    *,
    es: Optional[EsLike] = None,
    compute_dtype=None,
    out_dtype=None,
) -> jax.Array:
    """x @ decode(W) — the weights-only fast path used by TransLinear.

    x: (..., K) float; w_codes: (K, N) posit codes. Output float (..., N).
    For p8 weights the decode is bf16-exact, so the MXU runs at full bf16 speed.
    """
    if compute_dtype is None:
        compute_dtype = compute_dtype_for(w_fmt)
    wf = posit_decode(w_codes, w_fmt.nbits, w_fmt.es if es is None else es)
    y = jnp.matmul(
        x.astype(compute_dtype),
        wf.astype(compute_dtype),
        preferred_element_type=jnp.float32,
    )
    return y.astype(out_dtype if out_dtype is not None else x.dtype)


# GEMV / elementwise helpers for the paper's §IV-C benchmarks --------------------

def posit_gemv(A: jax.Array, x: jax.Array, slots: OperandSlots, *, impl: str = "fused"):
    return posit_dot(A, x[..., None], slots, impl=impl)[..., 0]


def posit_softmax(codes: jax.Array, fmt: PositFmt, *, es: Optional[EsLike] = None,
                  axis: int = -1) -> jax.Array:
    """softmax over posit-stored logits, result re-encoded (paper §IV-C)."""
    x = posit_decode(codes, fmt.nbits, fmt.es if es is None else es)
    y = jax.nn.softmax(x, axis=axis)
    return posit_encode(y, fmt.nbits, fmt.es if es is None else es)
