"""Posit GEMM front door — the paper's Fig. 2(b) dataflow at op granularity.

Three dataflows, selected by the pcsr (``OperandSlots.dataflow``) or the
``impl`` override:

* ``fused``  (ours): posit operands are decoded tile-by-tile *inside* the matmul
  (Pallas kernel on TPU; XLA-fused jnp path elsewhere), the MXU/FPU computes in
  float, and the result is optionally encoded on the way out. One HBM read of
  1–2-byte posit words per operand — the codec rides along for free.
* ``unfused`` ([7]-style, PPU-light): a *separate* conversion pass materializes
  the full decoded f32 tensor in HBM before the matmul (and a separate encode
  pass after). Two extra HBM round-trips per operand — the analogue of [7]'s two
  extra conversion instructions per operation, which cost it 2.54x throughput.
* ``quire`` (PERCIVAL-style, beyond-paper): every posit product accumulates
  *exactly* in a software Kulisch accumulator (repro.core.quire), with a single
  rounding at readout — zero accumulation error, at integer-datapath cost.
  Requires all-posit slots; see ``kernels.posit_quire_gemm`` for the tiled
  TPU version of the same contract.

Operand formats come from an ``OperandSlots`` pcsr (per-slot pfmt/pprec/pes):
float slots bypass the codec entirely (IEEE-754 compatibility), posit slots
decode with their (possibly traced) es. Mixed posit x float GEMMs fall out.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.codec import EsLike, posit_decode, posit_encode
from repro.core.lut import decode_with_impl, encode_with_impl
from repro.core.pack import packed_decode_p8, unpack_p8
from repro.core.pcsr import OperandSlots
from repro.core.types import Fmt, PositFmt, compute_dtype_for

# Activations a fused epilogue can apply (gelu is the tanh approximation —
# jax.nn.gelu's default — which also lowers through Mosaic).
ACTIVATIONS = ("none", "gelu", "silu", "relu")


def _apply_activation(y: jax.Array, activation: str) -> jax.Array:
    if activation == "none":
        return y
    if activation == "gelu":
        return jax.nn.gelu(y)
    if activation == "silu":
        return jax.nn.silu(y)
    if activation == "relu":
        return jax.nn.relu(y)
    raise ValueError(f"activation must be one of {ACTIVATIONS}, got {activation!r}")


def apply_epilogue(y: jax.Array, bias: Optional[jax.Array],
                   activation: str, residual: Optional[jax.Array],
                   *, chained: bool = False) -> jax.Array:
    """The GEMM epilogue contract: ``act(y + bias) + residual``, in f32.

    ``chained=True`` puts an optimization barrier between every stage — each
    intermediate is materialized, the [7]-style separate-pass baseline the
    fused path is benchmarked against (bench_epilogue_fusion).
    """
    barrier = jax.lax.optimization_barrier if chained else (lambda t: t)
    y = y.astype(jnp.float32)
    if bias is not None:
        y = barrier(y) + bias.astype(jnp.float32)
    if activation != "none":
        y = _apply_activation(barrier(y), activation)
    if residual is not None:
        y = barrier(y) + residual.astype(jnp.float32)
    return y


@dataclasses.dataclass(frozen=True)
class FormatPlan:
    """Resolved dispatch plan for one (rs1, rs2) format pair (DESIGN.md §9).

    The format-pair dispatch table, applied uniformly across all three
    dataflows:

        rs1 \\ rs2   p8            p16           float
        p8          bf16 MXU      f32 MXU       bf16/f32 per float fmt
        p16         f32 MXU       f32 MXU       f32 MXU
        float       per float fmt f32 MXU       native (codec bypassed)

    * compute dtype is the *lossless-decode* meet of the two operands
      (`compute_dtype_for`): bf16 only when both formats decode exactly into
      bf16, else f32 — so a mixed p8 x p16 GEMM is exact in f32 while a
      p8 x p8 GEMM runs the MXU at full bf16 speed.
    * a packed rs2 (two p8 codes per uint16 lane) decodes both lanes and is
      otherwise format-identical to unpacked p8 — packing changes bytes
      moved, never numerics.
    * the quire dataflow additionally requires all-posit slots; its
      accumulation is es/nbits-independent (the anchor covers every format),
      so any posit format pair — mixed nbits, mixed es, packed — lands in
      one exact accumulator.
    """

    compute_dtype_name: str   # "bfloat16" | "float32" — MXU/FPU datapath
    decode_a: bool            # rs1 runs the posit codec
    decode_b: bool            # rs2 runs the posit codec
    packed_b: bool            # rs2 arrives as packed uint16 lanes
    quire_ok: bool            # all-posit slots: quire dataflow is legal
    encode_out: bool          # rd is posit: result re-encodes

    @property
    def compute_dtype(self):
        return jnp.dtype(self.compute_dtype_name)


def format_pair_plan(slots: OperandSlots) -> FormatPlan:
    """Resolve an OperandSlots pcsr into the dispatch plan for its
    (rs1, rs2) format pair."""
    ca = compute_dtype_for(slots.rs1)
    cb = compute_dtype_for(slots.rs2)
    cd = ca if ca == cb else jnp.float32
    return FormatPlan(
        compute_dtype_name=jnp.dtype(cd).name,
        decode_a=isinstance(slots.rs1, PositFmt),
        decode_b=isinstance(slots.rs2, PositFmt),
        packed_b=slots.rs2_packed,
        quire_ok=all(isinstance(f, PositFmt)
                     for f in (slots.rs1, slots.rs2, slots.rd)),
        encode_out=isinstance(slots.rd, PositFmt),
    )


def _decode_operand(x: jax.Array, fmt: Fmt, es: Optional[EsLike], compute_dtype,
                    codec_impl: str = "auto") -> jax.Array:
    if isinstance(fmt, PositFmt):
        return decode_with_impl(x, fmt.nbits, fmt.es if es is None else es,
                                codec_impl).astype(compute_dtype)
    return x.astype(compute_dtype)


def _encode_result(y: jax.Array, fmt: Fmt, es: Optional[EsLike],
                   codec_impl: str = "auto") -> jax.Array:
    if isinstance(fmt, PositFmt):
        return encode_with_impl(y, fmt.nbits, fmt.es if es is None else es,
                                codec_impl)
    return y.astype(compute_dtype_for(fmt))


def _quire_dot(a, b, slots, *, es_a=None, es_b=None, es_out=None,
               dimension_numbers=None, bias=None, activation="none",
               residual=None, chained=False):
    """dataflow="quire": exact accumulation through repro.core.quire.

    rs1/rs2 must be posit (float inputs have no exact quire representation);
    rd may be a *float* format — the readout is then ``quire_read_f32``, a
    single RNE of the exact sum straight into the FPU domain (the layer-level
    dataflow="quire" contract: no accumulation rounding, no float matmul).
    """
    from repro.core.quire import quire_matmul  # core->quire, no cycle w/ dot

    for name, f in (("rs1", slots.rs1), ("rs2", slots.rs2)):
        if not isinstance(f, PositFmt):
            raise ValueError(
                f"quire dataflow requires posit {name}, got {f}: float slots "
                "have no exact quire representation (use fused/unfused)")
    if dimension_numbers is not None:
        raise NotImplementedError(
            "quire dataflow supports plain (M,K)@(K,N) contractions")
    if a.ndim != 2 or b.ndim != 2:
        raise NotImplementedError(
            f"quire dataflow is 2-D GEMM only, got {a.shape} @ {b.shape}")
    if slots.rs2_packed:
        # lane extraction is a handful of integer ops; the quire then
        # accumulates the mixed product exactly like unpacked codes
        b = unpack_p8(b, k=a.shape[1])
    wide = slots.rs1 if slots.rs1.nbits >= slots.rs2.nbits else slots.rs2
    kw = dict(
        es_a=slots.rs1.es if es_a is None else es_a,
        es_b=slots.rs2.es if es_b is None else es_b,
        nbits_a=slots.rs1.nbits, nbits_b=slots.rs2.nbits,
    )
    posit_out = isinstance(slots.rd, PositFmt)
    if posit_out and bias is None and activation == "none" and residual is None:
        # no epilogue: keep the exact quire->posit readout (single rounding
        # straight into the output format)
        return quire_matmul(
            a, b, wide, out_nbits=slots.rd.nbits,
            es_out=slots.rd.es if es_out is None else es_out, **kw)
    # epilogue: one exact rounding into f32 (the FPU domain the epilogue
    # computes in), then encode — same numerics contract as the fused path
    y = quire_matmul(a, b, wide, as_float=True, **kw)
    y = apply_epilogue(y, bias, activation, residual, chained=chained)
    return _encode_result(y, slots.rd, es_out, slots.codec_impl)


def posit_dot(
    a: jax.Array,
    b: jax.Array,
    slots: OperandSlots,
    *,
    es_a: Optional[EsLike] = None,
    es_b: Optional[EsLike] = None,
    es_out: Optional[EsLike] = None,
    impl: Optional[str] = None,
    compute_dtype=None,
    dimension_numbers=None,
    bias: Optional[jax.Array] = None,
    activation: str = "none",
    residual: Optional[jax.Array] = None,
    epilogue: str = "fused",
) -> jax.Array:
    """General dot with per-operand pcsr formats.

    a/b: float arrays, or uint8/uint16 posit-code arrays per ``slots``.
    impl: "fused" (ours) | "unfused" ([7]-style baseline) | "quire" (exact
    accumulation, single terminal rounding); ``None`` defers to the pcsr's
    ``slots.dataflow``. fused/unfused accumulate in f32 (the MXU/FPU
    datapath, like the paper's FP32 FPU); quire accumulates exactly.

    ``bias``/``activation``/``residual`` are the fused layer epilogue:
    ``encode(act(a@b + bias) + residual)`` rides with the GEMM — one launch
    and one HBM write per layer.  ``epilogue="chained"`` materializes every
    stage instead (the benchmark baseline, see ``apply_epilogue``).
    """
    if impl is None:
        impl = slots.dataflow
    if impl not in ("fused", "unfused", "quire"):
        raise ValueError(f"impl must be fused|unfused|quire, got {impl}")
    chained = epilogue == "chained"
    has_epilogue = bias is not None or activation != "none" or residual is not None
    if impl == "quire":
        return _quire_dot(a, b, slots, es_a=es_a, es_b=es_b, es_out=es_out,
                          dimension_numbers=dimension_numbers,
                          bias=bias, activation=activation, residual=residual,
                          chained=chained)
    plan = format_pair_plan(slots)
    if compute_dtype is None:
        # lossless-decode dtype: bf16 only if *both* operands allow it
        compute_dtype = plan.compute_dtype

    if plan.packed_b:
        # two p8 codes per uint16 lane (core/pack.py split-K layout): decode
        # both lanes, trim the odd-K pad row back to rs1's contraction length
        if dimension_numbers is not None:
            raise NotImplementedError(
                "packed rs2 supports plain (.., K) @ (Kh, N) contractions")
        bf = packed_decode_p8(
            b, slots.rs2.es if es_b is None else es_b,
            codec_impl=slots.codec_impl, k=a.shape[-1]).astype(compute_dtype)
    else:
        bf = _decode_operand(b, slots.rs2, es_b, compute_dtype, slots.codec_impl)
    af = _decode_operand(a, slots.rs1, es_a, compute_dtype, slots.codec_impl)
    if impl == "unfused":
        # Materialize full decoded tensors in HBM (optimization barrier keeps XLA
        # from re-fusing them into the matmul — this is the point of the baseline).
        af = jax.lax.optimization_barrier(af)
        bf = jax.lax.optimization_barrier(bf)

    if dimension_numbers is None:
        y = jnp.matmul(af, bf, preferred_element_type=jnp.float32)
    else:
        y = jax.lax.dot_general(af, bf, dimension_numbers, preferred_element_type=jnp.float32)

    if impl == "unfused":
        y = jax.lax.optimization_barrier(y)
    if has_epilogue:
        y = apply_epilogue(y, bias, activation, residual,
                           chained=chained or impl == "unfused")
    return _encode_result(y, slots.rd, es_out, slots.codec_impl)


def posit_matmul_wx(
    x: jax.Array,
    w_codes: jax.Array,
    w_fmt: PositFmt,
    *,
    es: Optional[EsLike] = None,
    compute_dtype=None,
    out_dtype=None,
    bias: Optional[jax.Array] = None,
    activation: str = "none",
    residual: Optional[jax.Array] = None,
    out_fmt: Optional[PositFmt] = None,
    es_out: Optional[EsLike] = None,
    codec_impl: str = "auto",
    epilogue: str = "fused",
    packed: bool = False,
) -> jax.Array:
    """x @ decode(W) — the weights-only fast path used by TransLinear.

    x: (..., K) float; w_codes: (K, N) posit codes. Output float (..., N),
    or posit codes when ``out_fmt`` is given (the serving layer's fused
    gemm -> bias -> activation -> residual -> encode, one HBM write).
    For p8 weights the decode is bf16-exact, so the MXU runs at full bf16
    speed.  ``epilogue="chained"`` is the materialize-every-stage baseline.
    ``packed=True`` takes w_codes as (ceil(K/2), N) uint16 packed p8 lanes
    (core/pack.py) — half the weight bytes through the memory system,
    bit-identical numerics.
    """
    if compute_dtype is None:
        compute_dtype = compute_dtype_for(w_fmt)
    if packed:
        if w_fmt.nbits != 8:
            raise ValueError(f"packed weights require p8, got {w_fmt}")
        wf = packed_decode_p8(w_codes, w_fmt.es if es is None else es,
                              codec_impl=codec_impl, k=x.shape[-1])
    else:
        wf = decode_with_impl(w_codes, w_fmt.nbits,
                              w_fmt.es if es is None else es, codec_impl)
    chained = epilogue == "chained"
    if chained:
        wf = jax.lax.optimization_barrier(wf)
    y = jnp.matmul(
        x.astype(compute_dtype),
        wf.astype(compute_dtype),
        preferred_element_type=jnp.float32,
    )
    if bias is not None or activation != "none" or residual is not None:
        y = apply_epilogue(y, bias, activation, residual, chained=chained)
    if out_fmt is not None:
        if chained:
            y = jax.lax.optimization_barrier(y)
        return encode_with_impl(y, out_fmt.nbits,
                                out_fmt.es if es_out is None else es_out,
                                codec_impl)
    return y.astype(out_dtype if out_dtype is not None else x.dtype)


# GEMV / elementwise helpers for the paper's §IV-C benchmarks --------------------

def posit_gemv(A: jax.Array, x: jax.Array, slots: OperandSlots, *, impl: str = "fused"):
    return posit_dot(A, x[..., None], slots, impl=impl)[..., 0]


def posit_softmax(codes: jax.Array, fmt: PositFmt, *, es: Optional[EsLike] = None,
                  axis: int = -1) -> jax.Array:
    """softmax over posit-stored logits, result re-encoded (paper §IV-C)."""
    x = posit_decode(codes, fmt.nbits, fmt.es if es is None else es)
    y = jax.nn.softmax(x, axis=axis)
    return posit_encode(y, fmt.nbits, fmt.es if es is None else es)
