"""Pure-Python scalar posit codec oracle.

Deliberately slow and obviously correct: integer/Fraction arithmetic only, one
value at a time. Everything else in the framework (vectorized JAX codec, Pallas
kernels, the integer ALU) is validated against this module.

Contract (posit standard 2022 semantics, matching the paper's hardware):
  * P(n, es): sign | regime | exponent(es bits) | fraction; two's-complement
    negation; 0b0..0 == 0; 0b10..0 == NaR.
  * decode is exact (every P(n<=16, es<=3) value is an exact binary64/32 value).
  * encode rounds to nearest-even **on the posit encoding**, with saturation:
    |x| >= maxpos -> +-maxpos (never NaR), 0 < |x| <= minpos -> +-minpos (never 0),
    NaN/Inf -> NaR.
"""
from __future__ import annotations

import math
from fractions import Fraction


def _check(n: int, es: int) -> None:
    assert n in (8, 16), n
    assert 0 <= es <= 3, es


def ref_decode(code: int, n: int, es: int):
    """Decode an n-bit posit code -> exact Fraction (or None for NaR)."""
    _check(n, es)
    code &= (1 << n) - 1
    if code == 0:
        return Fraction(0)
    if code == 1 << (n - 1):
        return None  # NaR
    sign = (code >> (n - 1)) & 1
    body = ((1 << n) - code) & ((1 << n) - 1) if sign else code
    # body now has sign bit 0 and n-1 meaningful bits below it.
    r0 = (body >> (n - 2)) & 1
    m = 0
    i = n - 2
    while i >= 0 and ((body >> i) & 1) == r0:
        m += 1
        i -= 1
    # i is now the terminator position (or -1 if the regime fills the body).
    k = (m - 1) if r0 == 1 else -m
    rem_bits = max(i, 0)
    rem = body & ((1 << i) - 1) if i > 0 else 0
    if es <= rem_bits:
        e = rem >> (rem_bits - es)
        frac_bits = rem_bits - es
        frac = rem & ((1 << frac_bits) - 1)
    else:
        e = rem << (es - rem_bits)  # truncated exponent field: present bits are MSBs
        frac_bits = 0
        frac = 0
    scale = (k << es) + e
    sig = Fraction((1 << frac_bits) + frac, 1 << frac_bits)  # 1.frac
    val = sig * (Fraction(2) ** scale)
    return -val if sign else val


def ref_decode_float(code: int, n: int, es: int) -> float:
    """Decode to a Python float (exact for all supported formats); NaR -> nan."""
    v = ref_decode(code, n, es)
    if v is None:
        return math.nan
    return float(v)


def ref_encode_exact(x: Fraction, n: int, es: int) -> int:
    """Encode an exact rational value -> n-bit posit code with RNE + saturation."""
    _check(n, es)
    if x == 0:
        return 0
    sign = x < 0
    a = -x if sign else x
    smax = (n - 2) << es
    maxpos = Fraction(2) ** smax
    minpos = Fraction(2) ** (-smax)
    if a >= maxpos:
        body = (1 << (n - 1)) - 1
    elif a <= minpos:
        body = 1
    else:
        # normalize: a = (1 + frac) * 2^scale, frac in [0, 1)
        scale = 0
        while a >= 2:
            a /= 2
            scale += 1
        while a < 1:
            a *= 2
            scale -= 1
        frac = a - 1  # Fraction in [0,1)
        k = scale >> es
        e = scale - (k << es)
        r_len = (k + 2) if k >= 0 else (1 - k)
        t = (n - 1) - r_len
        assert t >= 0, (n, es, scale, k)
        reg = (((1 << (k + 1)) - 1) << 1) if k >= 0 else 1
        fb = t - es  # fraction bits that fit
        if fb >= 0:
            scaled = frac * (1 << fb)
            fpart = int(scaled)  # floor
            rem = scaled - fpart
            tail = (e << fb) | fpart
            # guard bit = next fraction bit; sticky = anything below it
            rem2 = rem * 2
            g = int(rem2)
            sticky = (rem2 - g) != 0
        else:
            cut = -fb
            tail = e >> cut
            g = (e >> (cut - 1)) & 1
            sticky = (e & ((1 << (cut - 1)) - 1)) != 0 or frac != 0
        body = (reg << max(t, 0)) | tail
        if g and (sticky or (body & 1)):
            body += 1
        body = min(body, (1 << (n - 1)) - 1)
    code = ((1 << n) - body) & ((1 << n) - 1) if sign else body
    return code


def ref_encode(x: float, n: int, es: int) -> int:
    """Encode a Python float (e.g. an exact f32 value) -> n-bit posit code."""
    _check(n, es)
    if math.isnan(x) or math.isinf(x):
        return 1 << (n - 1)  # NaR
    if x == 0:
        return 0
    return ref_encode_exact(Fraction(x), n, es)


# ---- exact posit arithmetic reference (for the ALU / PAU baseline) -------------

def ref_add(code_a: int, code_b: int, n: int, es: int) -> int:
    """True posit addition: exact sum, single posit rounding (quire-free PAU)."""
    va, vb = ref_decode(code_a, n, es), ref_decode(code_b, n, es)
    if va is None or vb is None:
        return 1 << (n - 1)
    return ref_encode_exact(va + vb, n, es)


def ref_mul(code_a: int, code_b: int, n: int, es: int) -> int:
    """True posit multiplication: exact product, single posit rounding."""
    va, vb = ref_decode(code_a, n, es), ref_decode(code_b, n, es)
    if va is None or vb is None:
        return 1 << (n - 1)
    return ref_encode_exact(va * vb, n, es)


def ref_convert(code: int, n_in: int, es_in: int, n_out: int, es_out: int) -> int:
    """posit -> posit conversion through the exact value (single rounding).

    Matches the paper's fcvt.pfmt.pfmt instructions, which pass through the FPU's
    FP32 datapath: for all supported (n, es) the decode is f32-exact, so
    exact-value conversion and through-FP32 conversion agree bit-for-bit.
    """
    v = ref_decode(code, n_in, es_in)
    if v is None:
        return 1 << (n_out - 1)
    return ref_encode_exact(v, n_out, es_out)
