"""Format descriptors for posit and IEEE-754 numbers.

The paper's pcsr fields map here:
  pfmt  -> Fmt.kind  ("posit" | "float")
  pprec -> Fmt.nbits (8 | 16 for posit; 16/32 for float)
  pes   -> es        (dynamic: may be a traced scalar at op level; this module
                      holds the *static* descriptor side)

Posit P(n, es) value layout (MSB first):  sign | regime | exponent(es) | fraction
  - negation is two's complement of the whole n-bit word
  - 0b0..0 == 0, 0b10..0 == NaR (maps to NaN)
  - useed = 2**(2**es); maxpos = useed**(n-2); minpos = useed**-(n-2)
"""
from __future__ import annotations

import dataclasses
from typing import Union

import numpy as np

# es is clamped to this range framework-wide: scale = k*2^es + e must stay in
# fp32 normal-exponent range for n<=16 ((n-2)*2^es <= 112 < 127). The paper's
# pes field is 3 bits wide but the same fp32-overflow argument it uses to
# exclude P32 bounds usable es at <= 3 for P16.
ES_MIN = 0
ES_MAX = 3

MASK32 = 0xFFFFFFFF


@dataclasses.dataclass(frozen=True)
class PositFmt:
    """Static descriptor of a posit format P(nbits, es)."""

    nbits: int  # 8 or 16
    es: int     # 0..3 (static default; ops may override with a traced scalar)

    def __post_init__(self):
        if self.nbits not in (8, 16):
            raise ValueError(f"posit nbits must be 8 or 16, got {self.nbits}")
        if not (ES_MIN <= self.es <= ES_MAX):
            raise ValueError(f"posit es must be in [{ES_MIN},{ES_MAX}], got {self.es}")

    # ---- bit-level constants -------------------------------------------------
    @property
    def kind(self) -> str:
        return "posit"

    @property
    def name(self) -> str:
        return f"p{self.nbits}_{self.es}"

    @property
    def sign_mask(self) -> int:
        return 1 << (self.nbits - 1)

    @property
    def code_mask(self) -> int:
        return (1 << self.nbits) - 1

    @property
    def nar_code(self) -> int:
        return self.sign_mask

    @property
    def maxpos_code(self) -> int:
        return self.sign_mask - 1  # 0b0111..1

    @property
    def minpos_code(self) -> int:
        return 1

    # ---- value-level constants ----------------------------------------------
    @property
    def max_scale(self) -> int:
        """Largest power-of-two scale: (n-2) * 2^es."""
        return (self.nbits - 2) << self.es

    @property
    def maxpos(self) -> float:
        return float(2.0 ** self.max_scale)

    @property
    def minpos(self) -> float:
        return float(2.0 ** (-self.max_scale))

    @property
    def storage_dtype(self):
        return np.uint8 if self.nbits == 8 else np.uint16

    @property
    def storage_bytes(self) -> int:
        return self.nbits // 8

    def with_es(self, es: int) -> "PositFmt":
        return PositFmt(self.nbits, es)


@dataclasses.dataclass(frozen=True)
class FloatFmt:
    """IEEE-754 (or bfloat16) descriptor — the 'bypass codec' side of pcsr."""

    name: str  # "f32" | "bf16" | "f16"

    def __post_init__(self):
        if self.name not in ("f32", "bf16", "f16"):
            raise ValueError(f"unknown float format {self.name}")

    @property
    def kind(self) -> str:
        return "float"

    @property
    def nbits(self) -> int:
        return 32 if self.name == "f32" else 16

    @property
    def storage_bytes(self) -> int:
        return self.nbits // 8

    @property
    def dtype(self):
        import jax.numpy as jnp

        return {"f32": jnp.float32, "bf16": jnp.bfloat16, "f16": jnp.float16}[self.name]


Fmt = Union[PositFmt, FloatFmt]

# Canonical instances -----------------------------------------------------------
P8_0 = PositFmt(8, 0)
P8_1 = PositFmt(8, 1)
P8_2 = PositFmt(8, 2)
P8_3 = PositFmt(8, 3)
P16_0 = PositFmt(16, 0)
P16_1 = PositFmt(16, 1)
P16_2 = PositFmt(16, 2)
P16_3 = PositFmt(16, 3)
F32 = FloatFmt("f32")
BF16 = FloatFmt("bf16")
F16 = FloatFmt("f16")

_REGISTRY: dict[str, Fmt] = {
    f.name: f
    for f in (P8_0, P8_1, P8_2, P8_3, P16_0, P16_1, P16_2, P16_3, F32, BF16, F16)
}


def get_format(name: str) -> Fmt:
    """Look up a format by name, e.g. 'p8_0', 'p16_1', 'f32', 'bf16'."""
    if name not in _REGISTRY:
        raise KeyError(f"unknown format {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def compute_dtype_for(fmt: Fmt):
    """The lossless-decode compute dtype for a storage format (DESIGN.md §2).

    P8 (<=5 fraction bits, |scale|<=48) decodes exactly into bfloat16 -> full-speed
    MXU. P16 (up to 13 fraction bits) needs float32. Floats compute as themselves
    (bf16 upcasts to itself; f16 upcasts to f32 on TPU VPU).
    """
    import jax.numpy as jnp

    if isinstance(fmt, PositFmt):
        return jnp.bfloat16 if fmt.nbits == 8 else jnp.float32
    return {"f32": jnp.float32, "bf16": jnp.bfloat16, "f16": jnp.float32}[fmt.name]
