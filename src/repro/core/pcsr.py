"""PCSR — the framework analogue of the paper's posit control & status register.

The hardware pcsr (paper Fig. 2(c)) holds, for three input operand slots and one
output slot:
    pfmt  (1 bit)  — posit vs IEEE float (bypass the codec entirely)
    pprec (1 bit)  — 8- vs 16-bit posit
    pes   (3 bits) — exponent size

Here the same runtime knobs are carried as a policy object. Two layers:

* ``OperandSlots`` — the literal pcsr: formats for (rs1, rs2, rs3, rd) of a
  single op. Used by ``repro.core.dot`` for mixed-format GEMMs.
* ``TransPolicy`` — the systems-level extension: which format each *tensor
  role* in a model uses (weights / activations / gradients / KV cache /
  optimizer moments / collectives / checkpoint). This is what a training or
  serving run is configured with.

``es`` values are kept as plain ints here; ops lower them as traced scalars so
changing es at runtime does not retrace (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.types import F32, Fmt, PositFmt, get_format


# Accumulation dataflows a dot-like op can run under (repro.core.dot):
#   fused    — decode inside the matmul, f32 FPU accumulation (the paper)
#   unfused  — [7]-style separate conversion passes, same numerics as fused
#   quire    — PERCIVAL-style exact Kulisch accumulation, single terminal
#              rounding (repro.core.quire / kernels.posit_quire_gemm)
DATAFLOWS = ("fused", "unfused", "quire")

# Codec implementations (repro.core.lut): "bits" is the ~40-op integer
# pipeline (the only option inside Mosaic kernel bodies), "lut" the
# table/gather fast path, "auto" picks per backend.
CODEC_IMPLS = ("auto", "lut", "bits")

# Epilogue dataflows for dot-like ops (repro.core.dot): "fused" keeps
# bias/activation/residual/encode in the producing kernel (one HBM write);
# "chained" materializes each stage — the [7]-style round-trip baseline.
EPILOGUES = ("fused", "chained")

# Decode-step attention implementations (models.attention /
# kernels.posit_attention.ops): "kernel" routes each step through the
# flash-decode front door (Pallas on TPU, length-bounded tiled XLA path
# elsewhere — the cache is decoded tile-wise at the attention boundary, never
# materialized in full); "xla" is the in-model full-cache decode + dense
# einsum baseline; "auto" resolves to "kernel" wherever the kernel contract
# covers the layer (everything except non-rolling sliding-window caches).
ATTN_IMPLS = ("auto", "kernel", "xla")

# Accumulation dataflows a whole-run policy can declare for its linear
# layers (models.layers.apply_linear).  A subset of DATAFLOWS: "unfused" is
# a benchmark baseline, not a policy anyone serves under.  "quire" routes
# every posit-coded linear through the exact Kulisch accumulator — no float
# dot_general at declared sites, one terminal rounding into the FPU domain —
# and is what repro.analysis's jaxpr auditor verifies mechanically.
POLICY_DATAFLOWS = ("fused", "quire")


@dataclasses.dataclass(frozen=True)
class OperandSlots:
    """Per-op format config: 3 input slots + 1 output slot (the literal pcsr).

    ``dataflow`` is the beyond-paper pcsr bit pair selecting the accumulation
    path; it is a *static* field (it changes the lowered program, unlike es
    which stays a traced scalar).  ``codec_impl`` selects the codec
    implementation the op's decodes/encodes lower to (also static).
    """

    rs1: Fmt = F32
    rs2: Fmt = F32
    rs3: Fmt = F32  # fused-op third operand (e.g. addend of FMA / bias)
    rd: Fmt = F32
    dataflow: str = "fused"
    codec_impl: str = "auto"
    # Packed-lane storage for the weight slot (DESIGN.md §9): rs2 travels as
    # uint16 lanes holding two p8 codes each (core/pack.py split-K layout).
    # Static, like dataflow — it changes operand shapes and the lowered kernel.
    rs2_packed: bool = False

    def __post_init__(self):
        if self.dataflow not in DATAFLOWS:
            raise ValueError(
                f"dataflow must be one of {DATAFLOWS}, got {self.dataflow!r}")
        if self.codec_impl not in CODEC_IMPLS:
            raise ValueError(
                f"codec_impl must be one of {CODEC_IMPLS}, got {self.codec_impl!r}")
        if self.rs2_packed and not (
                isinstance(self.rs2, PositFmt) and self.rs2.nbits == 8):
            raise ValueError(
                f"rs2_packed requires a p8 rs2 (two codes per 16-bit lane), "
                f"got {self.rs2}")

    @classmethod
    def uniform(cls, fmt: Fmt, dataflow: str = "fused",
                codec_impl: str = "auto") -> "OperandSlots":
        return cls(rs1=fmt, rs2=fmt, rs3=fmt, rd=fmt, dataflow=dataflow,
                   codec_impl=codec_impl)

    def with_dataflow(self, dataflow: str) -> "OperandSlots":
        return dataclasses.replace(self, dataflow=dataflow)

    def with_codec_impl(self, codec_impl: str) -> "OperandSlots":
        return dataclasses.replace(self, codec_impl=codec_impl)

    def with_packed(self, rs2_packed: bool = True) -> "OperandSlots":
        return dataclasses.replace(self, rs2_packed=rs2_packed)

    def encode_bits(self) -> int:
        """Pack into the paper's 4x(1+1+3)-bit register layout (for display),
        plus our dataflow extension in bits 20-21 (00 fused / 01 unfused /
        10 quire), the codec_impl extension in bits 22-23 (00 auto /
        01 lut / 10 bits) and the rs2 packed-lane bit in bit 24."""
        word = 0
        for i, f in enumerate((self.rs1, self.rs2, self.rs3, self.rd)):
            pfmt = 1 if isinstance(f, PositFmt) else 0
            pprec = 1 if (isinstance(f, PositFmt) and f.nbits == 16) else 0
            pes = f.es if isinstance(f, PositFmt) else 0
            word |= pfmt << i
            word |= pprec << (4 + i)
            word |= pes << (8 + 3 * i)
        word |= DATAFLOWS.index(self.dataflow) << 20
        word |= CODEC_IMPLS.index(self.codec_impl) << 22
        word |= int(self.rs2_packed) << 24
        return word


# Tensor roles a policy can assign a storage format to.
ROLES = (
    "weights",        # linear-layer parameters at rest / on the FSDP wire
    "activations",    # inter-layer activations (residual stream stays compute dtype)
    "gradients",      # gradient transport (cross-pod all-reduce payload)
    "kv_cache",       # attention KV cache at rest in HBM
    "optimizer",      # Adam moments at rest
    "collectives",    # generic collective payloads (compressed psum)
    "checkpoint",     # on-disk format
    "state",          # recurrent state (SSM/xLSTM h): quire-carried update
)


@dataclasses.dataclass(frozen=True)
class TransPolicy:
    """Which storage format each tensor role uses. ``None`` = native compute dtype.

    This is the whole-run pcsr: e.g. P16 weights + P8 KV cache + P16 gradient
    compression, while compute stays on the MXU in bf16/f32 (the paper's FPU).
    """

    weights: Optional[PositFmt] = None
    activations: Optional[PositFmt] = None
    gradients: Optional[PositFmt] = None
    kv_cache: Optional[PositFmt] = None
    optimizer: Optional[PositFmt] = None
    collectives: Optional[PositFmt] = None
    checkpoint: Optional[PositFmt] = None
    state: Optional[PositFmt] = None    # posit recurrent state, quire update
    compute_dtype: str = "f32"  # "f32" | "bf16" — the FPU-datapath dtype
    # Exact quire-domain psum for posit collective payloads: one encode
    # rounding per device + one readout rounding total, instead of re-rounding
    # at every reduction hop (distributed.collectives.quire_psum_posit).
    exact_collectives: bool = False
    # Codec implementation every layer-level decode/encode lowers to
    # (repro.core.lut): "auto" | "lut" | "bits".
    codec_impl: str = "auto"
    # Layer epilogue dataflow (repro.core.dot): "fused" keeps
    # bias/activation/residual/encode with the GEMM, "chained" materializes
    # each stage (the benchmark baseline).
    epilogue: str = "fused"
    # Packed-lane weight storage (core/pack.py): p8 weight codes travel two
    # per 16-bit lane through the memory system (DESIGN.md §9).  Only
    # meaningful for p8 weights; quantize_params / apply_linear consult it.
    pack_weights: bool = False
    # Decode-step attention dispatch (DESIGN.md §10): "kernel" sends every
    # decode step through kernels.posit_attention.ops (tile-wise in-VMEM
    # decode), "xla" keeps the full-cache-decode einsum path, "auto" picks
    # kernel wherever its contract covers the layer.
    attn_impl: str = "auto"
    # Linear-layer accumulation dataflow (repro.core.dot): "fused" decodes
    # into the f32/bf16 FPU matmul (the paper), "quire" accumulates every
    # posit product exactly with one terminal rounding (PERCIVAL; DESIGN.md
    # §7).  Applies to posit-coded plain linears; MoE expert-stack einsums
    # and the float-master training path stay on the fused FPU datapath.
    dataflow: str = "fused"

    def __post_init__(self):
        if self.dataflow not in POLICY_DATAFLOWS:
            raise ValueError(
                f"policy dataflow must be one of {POLICY_DATAFLOWS}, "
                f"got {self.dataflow!r}")
        if self.pack_weights and not (
                self.weights is not None and self.weights.nbits == 8):
            raise ValueError(
                "pack_weights requires p8 weights (two codes per lane), "
                f"got weights={self.weights}")
        if self.codec_impl not in CODEC_IMPLS:
            raise ValueError(
                f"codec_impl must be one of {CODEC_IMPLS}, got {self.codec_impl!r}")
        if self.epilogue not in EPILOGUES:
            raise ValueError(
                f"epilogue must be one of {EPILOGUES}, got {self.epilogue!r}")
        if self.attn_impl not in ATTN_IMPLS:
            raise ValueError(
                f"attn_impl must be one of {ATTN_IMPLS}, got {self.attn_impl!r}")

    def fmt_for(self, role: str) -> Optional[PositFmt]:
        if role not in ROLES:
            raise KeyError(f"unknown tensor role {role!r}; known: {ROLES}")
        return getattr(self, role)

    @classmethod
    def from_names(cls, compute_dtype: str = "f32",
                   exact_collectives: bool = False,
                   codec_impl: str = "auto", epilogue: str = "fused",
                   pack_weights: bool = False, attn_impl: str = "auto",
                   dataflow: str = "fused",
                   **roles: Optional[str]) -> "TransPolicy":
        kw = {"exact_collectives": exact_collectives,
              "codec_impl": codec_impl, "epilogue": epilogue,
              "pack_weights": pack_weights, "attn_impl": attn_impl,
              "dataflow": dataflow}
        for role, name in roles.items():
            if name is None or name == "none":
                kw[role] = None
                continue
            fmt = get_format(name)
            if not isinstance(fmt, PositFmt):
                raise ValueError(f"role {role} must be a posit format or none, got {name}")
            kw[role] = fmt
        return cls(compute_dtype=compute_dtype, **kw)

    def to_json(self) -> dict:
        """JSON-ready dict: format roles by name, knobs verbatim.

        Round-trips through ``TransPolicy.from_json`` — the persistence layer
        calibration artifacts (DESIGN.md §11) embed their base policy with.
        """
        d = {role: (f.name if (f := self.fmt_for(role)) is not None else None)
             for role in ROLES}
        d.update(compute_dtype=self.compute_dtype,
                 exact_collectives=self.exact_collectives,
                 codec_impl=self.codec_impl, epilogue=self.epilogue,
                 pack_weights=self.pack_weights, attn_impl=self.attn_impl,
                 dataflow=self.dataflow)
        return d

    @classmethod
    def from_json(cls, d: dict) -> "TransPolicy":
        """Inverse of ``to_json``; unknown keys are rejected loudly."""
        known = set(ROLES) | {"compute_dtype", "exact_collectives",
                              "codec_impl", "epilogue", "pack_weights",
                              "attn_impl", "dataflow"}
        bad = set(d) - known
        if bad:
            raise ValueError(f"unknown TransPolicy fields {sorted(bad)}")
        kw = dict(d)
        for role in ROLES:
            if kw.get(role) is not None:
                fmt = get_format(kw[role])
                if not isinstance(fmt, PositFmt):
                    raise ValueError(
                        f"role {role} must be a posit format, got {kw[role]!r}")
                kw[role] = fmt
        return cls(**kw)

    def describe(self) -> str:
        parts = [f"compute={self.compute_dtype}"]
        for role in ROLES:
            f = self.fmt_for(role)
            parts.append(f"{role}={f.name if f else '-'}")
        if self.exact_collectives:
            parts.append("exact_collectives")
        if self.codec_impl != "auto":
            parts.append(f"codec={self.codec_impl}")
        if self.epilogue != "fused":
            parts.append(f"epilogue={self.epilogue}")
        if self.pack_weights:
            parts.append("packed_weights")
        if self.attn_impl != "auto":
            parts.append(f"attn={self.attn_impl}")
        if self.dataflow != "fused":
            parts.append(f"dataflow={self.dataflow}")
        return " ".join(parts)


# Canonical policies used across examples/benchmarks -----------------------------
FP32_POLICY = TransPolicy()  # pure IEEE path: every codec bypassed
BF16_COMPUTE = TransPolicy(compute_dtype="bf16")
P16_WEIGHTS = TransPolicy.from_names(weights="p16_1")
P8_WEIGHTS = TransPolicy.from_names(weights="p8_0", compute_dtype="bf16")
P8_SERVE = TransPolicy.from_names(weights="p8_0", kv_cache="p8_0", compute_dtype="bf16")
P16_TRAIN = TransPolicy.from_names(
    weights="p16_1", gradients="p16_1", optimizer="p16_1", checkpoint="p16_1"
)
# Exact-accumulation flavor: posit state carried through a quire, gradient
# psum in the quire domain (single rounding per device + readout).
P16_QUIRE = dataclasses.replace(
    TransPolicy.from_names(weights="p16_1", gradients="p16_1", state="p16_1"),
    exact_collectives=True,
)
