"""Table-driven codec fast paths — LUT decode + bucketize encode.

The bit-pipeline codec (``repro.core.codec``) spends ~40 integer ops per
element.  That is the right trade inside a Mosaic kernel body (vector ALU ops
are cheap, gathers are hostile), but on gather-friendly backends a posit-8
decode is literally a 256-entry table lookup and a posit-16 decode almost is.
This module provides the table side of the codec, bit-exact against the
pipeline:

* **p8 decode**: one dense ``(4 es, 256)`` float32 table.  Every p8 value has
  at most 5 fraction bits and |scale| <= 48, so the table entries are exact
  f32 (and exactly bf16-castable, DESIGN.md §2).  NaR is stored as NaN, zero
  as +0.0 — decode is a single gather.

* **p16 decode**: a two-level split table (DESIGN.md §8).  The 16-bit code is
  split (after two's-complement sign strip) into ``hi = absc >> 8`` and
  ``lo = absc & 0xFF``.  For most ``hi`` bytes the regime, its terminator and
  all ``es`` exponent bits fit inside the high byte, so sign/scale and the
  high fraction bits are a function of ``hi`` alone and ``lo`` is pure
  fraction: ``fbits = L1_BITS[es, hi] | (lo << L1_SHIFT[es, hi])``.  The few
  ``hi`` bytes whose regime/exponent spill into the low byte (<= 16 of 128
  per es) fall back to a dense second-level table ``LO[es, slot, lo]``.
  Total: ~70 KB of tables instead of the 256 KB a flat p16 table would need.

* **p8 encode**: monotonicity-based bucketize.  Signed p8 code order *is*
  value order (the posit superpower), so encoding is ``searchsorted`` of the
  input against the 253 midpoints between adjacent decoded values, with RNE
  tie-handling (exact midpoints go to the even code) and the posit specials
  (NaN/Inf -> NaR, +-0 -> 0, never-round-to-zero saturation at minpos).  All
  p8 midpoints are exactly f32-representable (adjacent posits are <= 2^es
  octaves apart, so a midpoint needs <= 8+6 mantissa bits), which makes the
  comparison against f32 inputs exact — asserted at table-build time.

``codec_impl`` policy knob (``OperandSlots.codec_impl`` /
``TransPolicy.codec_impl``): "bits" forces the pipeline, "lut" forces tables,
"auto" picks tables only where they measure faster — the p8 decode on
gather-friendly backends (cpu/gpu XLA); see ``resolve_codec_impl`` and
BENCH_codec.json.  Pallas kernel bodies always use the pipeline.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.core.codec import EsLike, _es_u32, _u32, _U32, _NAN_BITS, posit_decode, posit_encode

CODEC_IMPLS = ("auto", "lut", "bits")

_MASK32 = 0xFFFFFFFF


# =====================================================================
# table construction (numpy, module-import free of jax tracing)
# =====================================================================

def _np_decode(codes: np.ndarray, nbits: int, es: int) -> np.ndarray:
    """Vectorized numpy mirror of ``codec.posit_decode`` (build-time oracle).

    Bit-for-bit the same integer pipeline; independence from the jnp codec is
    established by the exhaustive LUT==pipeline equivalence tests.
    """
    n = nbits
    c = codes.astype(np.int64) & ((1 << n) - 1)
    sign = (c >> (n - 1)) & 1
    absc = np.where(sign == 1, ((1 << n) - c) & ((1 << n) - 1), c)
    r0 = (absc >> (n - 2)) & 1
    w = np.where(r0 == 1, (~absc) & ((1 << (n - 1)) - 1), absc)
    # exact floor-log2 via frexp (ints < 2^15 are exact in f64)
    p = np.frexp(np.maximum(w, 1).astype(np.float64))[1] - 1
    m = np.where(w == 0, n - 1, (n - 2) - p)
    k = np.where(r0 == 1, m - 1, -m)
    y = (absc << (33 - n)) & _MASK32
    rem = (y << (m + 1)) & _MASK32
    e = (rem >> 24) >> (8 - es)
    frac_la = (rem << es) & _MASK32
    mant23 = frac_la >> 9
    scale = k * (1 << es) + e
    fbits = (sign << 31) | (((scale + 127) & 0xFF) << 23) | mant23
    out = fbits.astype(np.uint32).view(np.float32)
    out = np.where(c == 0, np.float32(0.0), out)
    nan = np.uint32(_NAN_BITS).view(np.float32)
    return np.where(c == (1 << (n - 1)), nan, out).astype(np.float32)


@functools.lru_cache(maxsize=None)
def _p8_decode_table() -> np.ndarray:
    """(4, 256) f32: table[es, code] == posit_decode(code, 8, es)."""
    return np.stack([_np_decode(np.arange(256), 8, es) for es in range(4)])


def _p16_hi_class(hi: int, es: int):
    """Classify a high byte of absc: return (scale, m) if the regime, its
    terminator and all es exponent bits fit in the 7 body bits, else None."""
    body = hi & 0x7F  # absc bit 15 is 0; body bits 14..8 live in hi bits 6..0
    r0 = (body >> 6) & 1
    run = 0
    for i in range(6, -1, -1):
        if ((body >> i) & 1) == r0:
            run += 1
        else:
            break
    if run == 7 or run + 1 + es > 7:
        return None
    m = run
    k = m - 1 if r0 == 1 else -m
    e = (body >> (6 - m - es)) & ((1 << es) - 1)
    return k * (1 << es) + e, m


@functools.lru_cache(maxsize=None)
def _p16_decode_tables():
    """Two-level split tables for p16 decode (see module docstring).

    Returns (l1_bits (4,128) int32, l1_shift (4,128) int32,
    lo_tab (4, S, 256) f32).  l1_bits >= 0 holds the base f32 bit pattern of
    the *absolute* value (sign 0) with the low byte's fraction contribution
    missing; l1_bits < 0 encodes ``-(slot+1)`` into lo_tab.
    """
    l1_bits = np.zeros((4, 128), np.int32)
    l1_shift = np.zeros((4, 128), np.int32)
    slot_codes: list[list[np.ndarray]] = []
    max_slots = 0
    for es in range(4):
        rows = []
        for hi in range(128):
            cls = _p16_hi_class(hi, es)
            if cls is None:
                l1_bits[es, hi] = -(len(rows) + 1)
                rows.append(_np_decode(
                    (hi << 8) | np.arange(256), 16, es))
            else:
                scale, m = cls
                base_mant = (hi << (17 + m + es)) & 0x7FFFFF
                l1_bits[es, hi] = ((scale + 127) << 23) | base_mant
                l1_shift[es, hi] = 9 + m + es
        slot_codes.append(rows)
        max_slots = max(max_slots, len(rows))
    lo_tab = np.zeros((4, max_slots, 256), np.float32)
    for es in range(4):
        for s, row in enumerate(slot_codes[es]):
            lo_tab[es, s] = row
    return l1_bits, l1_shift, lo_tab


@functools.lru_cache(maxsize=None)
def _p8_encode_tables(ftz: bool):
    """Bucketize-encode tables per es: (codes (4,V) uint8, mids (4,V-1) f32,
    tie_up (4,V-1) bool).  V = 255 with zero in the lattice (ftz) else 254.

    ``codes`` lists the non-NaR codes in ascending *value* order (== signed
    code order).  ``mids[i]`` is the *encoding-level* decision boundary
    between values i and i+1: posit rounding is RNE on the truncated
    encoding, whose flip point between adjacent n-bit codes c and c+1
    (signed) is exactly the value of the (n+1)-bit posit with signed code
    2c+1 — the arithmetic midpoint only inside uniform lattice segments, and
    the guard-bit boundary where discarded bits include exponent bits
    (DESIGN.md §8).  ``tie_up[i]`` says an exact tie (x equals the boundary,
    empty sticky) rounds to the upper neighbour — the even code of the pair.
    All P(9, es) boundary values are exactly f32-representable (<= 7
    significand bits, |scale| <= 56) — asserted below.
    """
    V = 255 if ftz else 254
    codes_t = np.zeros((4, V), np.uint8)
    mids_t = np.zeros((4, V - 1), np.float32)
    tie_t = np.zeros((4, V - 1), bool)
    for es in range(4):
        codes = np.array([c for c in range(256)
                          if c != 0x80 and (ftz or c != 0)], np.uint8)
        signed = codes.astype(np.int8)
        order = np.argsort(signed)
        codes = codes[order]
        vals = _np_decode(codes, 8, es).astype(np.float64)
        assert (np.diff(vals) > 0).all(), "p8 values must be strictly ordered"
        s = signed[order].astype(np.int64)  # ascending signed codes
        mids = _np_decode((2 * s[:-1] + 1) & 0x1FF, 9, es).astype(np.float64)
        assert (mids > vals[:-1]).all() and (mids < vals[1:]).all(), \
            "P9 boundaries must interleave the p8 lattice"
        assert (mids.astype(np.float32).astype(np.float64) == mids).all(), \
            "p8 rounding boundaries must be exactly f32-representable"
        codes_t[es] = codes
        mids_t[es] = mids.astype(np.float32)
        tie_t[es] = (codes[1:] % 2) == 0  # ties go to the even code
    return codes_t, mids_t, tie_t


# =====================================================================
# LUT codec ops (jnp; gather-based)
# =====================================================================

def lut_decode_p8(codes: jax.Array, es: EsLike) -> jax.Array:
    """p8 decode as one (4, 256)-table gather; bit-exact vs posit_decode."""
    tab = jnp.asarray(_p8_decode_table())
    esl = _es_u32(es).astype(jnp.int32)
    return tab[esl][codes.astype(jnp.int32) & 0xFF]


def lut_decode_p16(codes: jax.Array, es: EsLike) -> jax.Array:
    """p16 decode via the two-level split table; bit-exact vs posit_decode."""
    l1b_np, l1s_np, lo_np = _p16_decode_tables()
    l1b, l1s, lo_tab = (jnp.asarray(l1b_np), jnp.asarray(l1s_np),
                        jnp.asarray(lo_np))
    esl = _es_u32(es).astype(jnp.int32)
    c = codes.astype(_U32) & _u32(0xFFFF)
    neg = (c >> _u32(15)) == 1
    absc = jnp.where(neg, (_u32(1 << 16) - c) & _u32(0xFFFF), c)
    hi = (absc >> _u32(8)).astype(jnp.int32)   # 0..128 (128 only for NaR)
    lo = (absc & _u32(0xFF)).astype(jnp.int32)
    hic = jnp.minimum(hi, 127)

    b = l1b[esl][hic]
    sh = l1s[esl][hic].astype(_U32)
    fast = lax.bitcast_convert_type(
        b.astype(_U32) | (lo.astype(_U32) << sh), jnp.float32)
    slot = jnp.clip(-b - 1, 0, lo_tab.shape[1] - 1)
    slow = lo_tab[esl][slot, lo]
    v = jnp.where(b >= 0, fast, slow)
    v = jnp.where(neg, -v, v)
    nan = lax.bitcast_convert_type(
        jnp.full(c.shape, _NAN_BITS, dtype=_U32), jnp.float32)
    return jnp.where(c == _u32(1 << 15), nan, v)


def lut_encode_p8(x: jax.Array, es: EsLike, ftz: bool = False) -> jax.Array:
    """p8 encode by bucketizing against the 253 decoded-value midpoints.

    RNE with exact ties to the even code; NaN/Inf -> NaR; +-0 -> 0; standard
    never-round-to-zero saturation (the zero-less lattice's minpos bucket
    covers all of (0, minpos)).  ftz=True keeps zero in the lattice, which is
    exactly the ftz contract of ``posit_encode`` (|x| <= minpos/2 -> 0).
    """
    codes_np, mids_np, tie_np = _p8_encode_tables(ftz)
    codes_t, mids_t, tie_t = (jnp.asarray(codes_np), jnp.asarray(mids_np),
                              jnp.asarray(tie_np))
    esl = _es_u32(es).astype(jnp.int32)
    xf = x.astype(jnp.float32)
    bits = lax.bitcast_convert_type(xf, _U32)
    a_bits = bits & _u32(0x7FFFFFFF)
    is_zero = a_bits == 0
    is_nar = a_bits >= _u32(0x7F800000)

    mids = mids_t[esl]          # (V-1,) f32
    tie_up = tie_t[esl]
    codes = codes_t[esl]
    n_mids = mids.shape[0]
    idx = jnp.searchsorted(mids, xf, side="left").astype(jnp.int32)
    i2 = jnp.minimum(idx, n_mids - 1)
    tie = (idx < n_mids) & (mids[i2] == xf)
    idx = idx + (tie & tie_up[i2]).astype(jnp.int32)
    code = codes[idx]

    # Sub-minpos region via exact integer magnitude compare (monotone f32 bit
    # patterns): float comparisons can't be trusted here — backends may flush
    # subnormal inputs to zero before the searchsorted compares.  minpos is
    # 2^-(6<<es), always f32-normal, so its bit pattern is a pure exponent.
    neg = (bits >> _u32(31)) == 1
    minpos_bits = ((jnp.int32(127) - (jnp.int32(6) << esl)) << 23).astype(_U32)
    tiny = (~is_zero) & (a_bits < minpos_bits)
    sat = jnp.where(neg, jnp.uint8(0xFF), jnp.uint8(1))
    if ftz:
        # RNE against the {0} U posits lattice: |x| <= minpos/2 -> 0 (the tie
        # at exactly minpos/2 goes to the even code 0), else -> +-minpos.
        half_bits = minpos_bits - _u32(1 << 23)
        code = jnp.where(tiny, jnp.where(a_bits <= half_bits, jnp.uint8(0), sat),
                         code)
    else:
        code = jnp.where(tiny, sat, code)  # never-round-to-zero
    code = jnp.where(is_zero, jnp.uint8(0), code)
    return jnp.where(is_nar, jnp.uint8(0x80), code)


# =====================================================================
# impl dispatch — the codec_impl pcsr knob
# =====================================================================

def _gather_friendly() -> bool:
    return jax.default_backend() in ("cpu", "gpu")


def resolve_codec_impl(impl: str, nbits: int = 8, op: str = "decode") -> str:
    """Resolve 'auto' to a concrete implementation for (op, format, backend).

    'auto' picks the LUT only where BENCH_codec shows it winning: the p8
    decode gather on gather-friendly backends (~3.5x the bit pipeline on CPU
    XLA).  The p16 split-table decode and the p8 bucketize encode lose to
    the pipeline there (binary search / two-level gathers cost more than the
    integer ops), so 'auto' keeps 'bits' for them; 'lut' forces the tables
    wherever they exist.
    """
    if impl not in CODEC_IMPLS:
        raise ValueError(f"codec_impl must be one of {CODEC_IMPLS}, got {impl!r}")
    if impl == "auto":
        if op == "decode" and nbits == 8 and _gather_friendly():
            return "lut"
        return "bits"
    return impl


def decode_with_impl(codes: jax.Array, nbits: int, es: EsLike,
                     impl: str = "auto") -> jax.Array:
    """posit -> f32 via the selected codec implementation (bit-exact both ways)."""
    if resolve_codec_impl(impl, nbits, "decode") == "lut":
        return lut_decode_p8(codes, es) if nbits == 8 else lut_decode_p16(codes, es)
    return posit_decode(codes, nbits, es)


def encode_with_impl(x: jax.Array, nbits: int, es: EsLike,
                     impl: str = "auto", ftz: bool = False) -> jax.Array:
    """f32 -> posit via the selected implementation.  The bucketize fast path
    exists for p8 only; p16 always takes the bit pipeline."""
    if nbits == 8 and resolve_codec_impl(impl, nbits, "encode") == "lut":
        return lut_encode_p8(x, es, ftz=ftz)
    return posit_encode(x, nbits, es, ftz=ftz)
